"""Before-vs-after benchmark of the analytical/simulation control plane.

Times the seed (pre-vectorization) implementations — embedded here verbatim
so the comparison stays honest as the library evolves — against the current
fast paths, and writes the results to ``BENCH_control_plane.json`` so the
perf trajectory is tracked from this PR onward.

    PYTHONPATH=src python benchmarks/control_plane.py [--quick] [--out PATH]

Cases (full mode sizes):
  buzen                n=256, C=64      pure-Python double loop vs lfilter
  buzen_batch          B=64 thetas      per-vector loop vs one batched pass
  mean_queue_lengths   n=256, C=64      per-node loop vs one matrix op
  expected_delays      n=256, C=64      seed pipeline vs vectorized pipeline
  optimize_general     n=256, C=64      one finite-difference mirror-descent
                                        step (n+1 seed bound evals) vs one
                                        analytic-gradient step
  simulate             n=1000, T=200k   O(n)-per-event seed sim vs O(1) sim
"""
from __future__ import annotations

import argparse
import heapq
import json
import sys
import time
from collections import deque
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (  # noqa: E402
    BoundConstants,
    JacksonNetwork,
    SimConfig,
    bound_value_and_grad,
    buzen_normalizing_constants,
    simulate_batch,
)
from repro.core.jackson import _buzen_reference  # noqa: E402
from repro.core.theory import generalized_bound, optimal_eta  # noqa: E402


# --------------------------------------------------------------------- #
# seed ("before") implementations, frozen copies of the pre-PR code
# --------------------------------------------------------------------- #
def _seed_mean_queue_lengths(theta: np.ndarray, G: np.ndarray, N: int) -> np.ndarray:
    out = np.zeros(theta.size)
    for i in range(theta.size):
        pows = np.cumprod(np.full(N, theta[i]))
        out[i] = float(np.dot(pows, G[N - 1 :: -1][:N] / G[N]))
    return out


def _seed_expected_delays(mu: np.ndarray, p: np.ndarray, C: int) -> np.ndarray:
    theta = p / mu
    th = theta / theta.max()
    G = _buzen_reference(th, C)
    ql = _seed_mean_queue_lengths(th, G, C - 1)
    s = float(theta.max())
    lam = float(G[C - 1] / G[C] / s)
    return lam * (ql + 1.0) / mu * (C - 1.0) / C


def _seed_bound_for_p(mu: np.ndarray, p: np.ndarray, k: BoundConstants) -> float:
    m = _seed_expected_delays(mu, p, k.C)
    eta = optimal_eta(p, m, k)
    return generalized_bound(eta, p, m, k)


def _seed_fd_step(mu: np.ndarray, p: np.ndarray, k: BoundConstants) -> np.ndarray:
    """One finite-difference gradient of the seed mirror-descent loop."""
    n = p.size
    g = np.zeros(n)
    v0 = _seed_bound_for_p(mu, p, k)
    h = 1e-4 / n
    for i in range(n):
        q = p.copy()
        q[i] += h
        q /= q.sum()
        g[i] = (_seed_bound_for_p(mu, q, k) - v0) / h
    return g


class _SeedSim:
    """The pre-PR simulator: O(n) deque scans + O(n) rng.choice per event."""

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.n = int(np.asarray(cfg.mu).size)
        self.mu = np.asarray(cfg.mu, dtype=np.float64)
        self.p = np.asarray(cfg.p, dtype=np.float64)
        self.rng = np.random.default_rng(cfg.seed)
        self.now = 0.0
        self.step_idx = 0
        self.queues = [deque() for _ in range(self.n)]
        self.heap: list[tuple[float, int, int]] = []
        self._seq = 0
        self._inservice_seq = [-1] * self.n
        self.delays: list[list[int]] = [[] for _ in range(self.n)]
        self.time_delays: list[list[float]] = [[] for _ in range(self.n)]
        self.queue_len_sum = np.zeros(self.n)
        self.queue_len_tw = np.zeros(self.n)
        self._task_counter = 0
        if cfg.C > self.n:
            nodes = [i % self.n for i in range(cfg.C)]
        else:
            nodes = list(self.rng.choice(self.n, size=cfg.C, replace=False, p=None))
        for nd in nodes:
            self._enqueue(int(nd), 0)

    def _service_time(self, node: int) -> float:
        if self.cfg.service == "exp":
            return float(self.rng.exponential(1.0 / self.mu[node]))
        return float(1.0 / self.mu[node])

    def _start_service(self, node: int) -> None:
        self._seq += 1
        self._inservice_seq[node] = self._seq
        heapq.heappush(self.heap, (self.now + self._service_time(node), self._seq, node))

    def _enqueue(self, node: int, dispatch_step: int) -> None:
        self._task_counter += 1
        self.queues[node].append((self._task_counter, dispatch_step, self.now))
        if len(self.queues[node]) == 1:
            self._start_service(node)

    def queue_lengths(self) -> np.ndarray:
        return np.array([len(q) for q in self.queues])

    def step(self) -> tuple[int, int]:
        while True:
            t_done, seq, node = heapq.heappop(self.heap)
            if self._inservice_seq[node] == seq:
                break
        self.queue_len_tw += self.queue_lengths() * (t_done - self.now)
        self.now = t_done
        tid, disp_step, disp_time = self.queues[node].popleft()
        self.delays[node].append(self.step_idx - disp_step)
        self.time_delays[node].append(self.now - disp_time)
        if self.queues[node]:
            self._start_service(node)
        k_new = int(self.rng.choice(self.n, p=self.p))
        self._enqueue(k_new, self.step_idx + 1)
        self.queue_len_sum += self.queue_lengths()
        self.step_idx += 1
        return node, k_new


# --------------------------------------------------------------------- #
# harness
# --------------------------------------------------------------------- #
def _timeit(fn, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def run(quick: bool) -> dict:
    rng = np.random.default_rng(0)
    results = []

    def record(name, before_us, after_us, note=""):
        entry = {
            "name": name,
            "before_us": round(before_us, 2),
            "after_us": round(after_us, 2),
            "speedup": round(before_us / after_us, 2),
            "note": note,
        }
        results.append(entry)
        print(f"{name:42s} {before_us/1e3:10.2f} ms -> {after_us/1e3:8.3f} ms   "
              f"x{entry['speedup']:.1f}")

    # --- buzen -------------------------------------------------------- #
    n, C = (64, 16) if quick else (256, 64)
    th = rng.uniform(0.05, 1.0, n)
    th /= th.max()
    record(
        f"buzen(n={n},C={C})",
        _timeit(lambda: _buzen_reference(th, C)),
        _timeit(lambda: buzen_normalizing_constants(th, C)),
    )

    # --- batched buzen ------------------------------------------------ #
    B = 16 if quick else 64
    TH = rng.uniform(0.05, 1.0, (B, n))
    TH /= TH.max(axis=1, keepdims=True)
    record(
        f"buzen_batch(B={B},n={n},C={C})",
        _timeit(lambda: [_buzen_reference(TH[b], C) for b in range(B)]),
        _timeit(lambda: buzen_normalizing_constants(TH, C)),
        note="grid evaluation as used by optimize_two_cluster",
    )

    # --- mean queue lengths ------------------------------------------- #
    p = rng.uniform(0.1, 1.0, n)
    p /= p.sum()
    net = JacksonNetwork(mu=np.ones(n), p=p, C=C)
    G = net._G

    def _mql_uncached():
        net._ql_cache.clear()
        return net.mean_queue_lengths()

    record(
        f"mean_queue_lengths(n={n},C={C})",
        _timeit(lambda: _seed_mean_queue_lengths(net.theta, G, C)),
        _timeit(_mql_uncached),
        note="uncached; repeat reads hit the per-N memo",
    )

    # --- expected delays (full pipeline) ------------------------------ #
    mu = rng.uniform(0.5, 8.0, n)
    record(
        f"expected_delays(n={n},C={C})",
        _timeit(lambda: _seed_expected_delays(mu, p, C)),
        _timeit(lambda: JacksonNetwork(mu=mu, p=p, C=C).expected_delays()),
    )

    # --- optimize_general: one optimizer step ------------------------- #
    k = BoundConstants(A=100.0, L=1.0, B=20.0, C=C, T=10_000)
    record(
        f"optimize_general_step(n={n},C={C})",
        _timeit(lambda: _seed_fd_step(mu, p, k), warmup=0, iters=1),
        _timeit(lambda: bound_value_and_grad(mu, p, k)),
        note="per mirror-descent step: (n+1) seed bound evals vs one analytic "
        "value+gradient; whole-run speedup is this ratio at equal iters",
    )

    # --- simulate ------------------------------------------------------ #
    ns, T = (200, 10_000) if quick else (1000, 200_000)
    mu_s = rng.uniform(0.5, 4.0, ns)
    p_s = rng.uniform(0.1, 1.0, ns)
    p_s /= p_s.sum()
    cfg = SimConfig(mu=mu_s, p=p_s, C=ns // 2, T=T, seed=0, record_delays=True)

    def run_seed_sim():
        sim = _SeedSim(cfg)
        for _ in range(T):
            sim.step()

    record(
        f"simulate(n={ns},T={T})",
        _timeit(run_seed_sim, warmup=0, iters=1),
        _timeit(lambda: simulate_batch(cfg), warmup=0, iters=1),
    )

    return {
        "bench": "control_plane",
        "quick": quick,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "results": results,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small sizes (CI smoke)")
    ap.add_argument(
        "--out",
        default=str(Path(__file__).resolve().parent.parent / "BENCH_control_plane.json"),
        help="output JSON path",
    )
    args = ap.parse_args()
    payload = run(args.quick)
    Path(args.out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
