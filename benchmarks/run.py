"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,table2]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from . import kernel_micro, paper_figures, roofline, training_race

    benches = {
        "fig1": paper_figures.bench_fig1_transient,
        "table1": paper_figures.bench_table1_bounds,
        "fig2_3": paper_figures.bench_fig2_fig3_optimal_p,
        "fig4": paper_figures.bench_fig4_vs_baselines,
        "fig5": paper_figures.bench_fig5_delays,
        "fig11": paper_figures.bench_fig11_optimal_delays,
        "fig12": paper_figures.bench_fig12_3cluster,
        "table2": training_race.bench_table2_accuracy,
        "kernels": kernel_micro.bench_kernels,
        "roofline": roofline.bench_roofline,
    }
    selected = [s for s in args.only.split(",") if s] or list(benches)

    print("name,us_per_call,derived")
    failures = 0
    for key in selected:
        fn = benches[key]
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{key},0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}", flush=True)
        print(f"# {key} done in {time.time()-t0:.1f}s", file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark group(s) failed")


if __name__ == "__main__":
    main()
