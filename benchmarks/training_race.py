"""Table 2 / Fig. 6: CS-step accuracy race between FL methods."""
from __future__ import annotations

import time

import numpy as np

from repro.configs.base import FLConfig
from repro.fl import run_experiment

from .common import row


def bench_table2_accuracy(steps: int = 400, seeds: tuple = (0, 1)):
    """Mean +- std accuracy at equal CS steps (synthetic non-iid stand-in)."""
    out = []
    accs: dict[str, list[float]] = {m: [] for m in ("gen_async", "async_sgd", "fedbuff", "favano")}
    t_us = {}
    for seed in seeds:
        flc = FLConfig(n_clients=20, concurrency=10, server_steps=steps,
                       speed_ratio=10.0, seed=seed)
        for m in accs:
            t0 = time.perf_counter()
            # favano's clock is rounds, not completions: match grad budget
            mf = flc if m != "favano" else FLConfig(**{**flc.__dict__, "server_steps": max(steps // 10, 1)})
            r = run_experiment(mf, m, eta=0.08, eval_every=mf.server_steps)
            t_us[m] = (time.perf_counter() - t0) * 1e6
            accs[m].append(float(r.eval_acc[-1]))
    for m, vals in accs.items():
        out.append(row(f"table2_{m}", t_us[m],
                       f"acc={np.mean(vals):.3f}+-{np.std(vals):.3f}"))
    order_ok = np.mean(accs["gen_async"]) >= np.mean(accs["fedbuff"])
    out.append(row("table2_ordering_genasync_beats_fedbuff", 0.0, order_ok))
    return out
