"""Shared benchmark utilities: timing + CSV row emission."""
from __future__ import annotations

import time


def timeit(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time of fn(*args) in microseconds."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def row(name: str, us: float, derived) -> tuple[str, float, str]:
    return (name, us, str(derived))
