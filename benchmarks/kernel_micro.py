"""Kernel microbenchmarks: jnp reference path wall-time on CPU.

(The Pallas kernels themselves run in interpret mode on CPU — Python-speed,
not representative; the jnp path is what the CPU dry-run executes and the
number reported as us_per_call.  On TPU the same entry points dispatch the
compiled kernels.)"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

from .common import row, timeit


def bench_kernels():
    rng = np.random.default_rng(0)
    out = []

    # flash attention ref (B=1,S=512,H=8,K=2,D=64)
    q = jnp.asarray(rng.normal(size=(1, 512, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us = timeit(lambda: fa(q, k, v).block_until_ready(), iters=5)
    flops = 4 * 512 * 512 * 8 * 64
    out.append(row("kernel_attention_ref_512", us, f"gflops_s={flops/us/1e3:.1f}"))

    # ssd scan ref
    x = jnp.asarray(rng.normal(size=(2, 512, 8, 64)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (2, 512, 8)), jnp.float32)
    A = -jnp.ones((8,), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(2, 512, 64)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(2, 512, 64)), jnp.float32)
    ssd = jax.jit(lambda *a: ref.ssd_scan_ref(*a, chunk=64)[0])
    us = timeit(lambda: ssd(x, dt, A, Bm, Cm).block_until_ready(), iters=5)
    out.append(row("kernel_ssd_ref_512", us, "chunk=64"))

    # moe gmm ref
    xg = jnp.asarray(rng.normal(size=(8, 256, 256)), jnp.bfloat16)
    wg = jnp.asarray(rng.normal(size=(8, 256, 512)), jnp.bfloat16)
    gm = jax.jit(ref.moe_gmm_ref)
    us = timeit(lambda: gm(xg, wg).block_until_ready(), iters=5)
    flops = 2 * 8 * 256 * 256 * 512
    out.append(row("kernel_moe_gmm_ref", us, f"gflops_s={flops/us/1e3:.1f}"))

    # weighted update ref
    w = jnp.asarray(rng.normal(size=(4_000_000,)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(4_000_000,)), jnp.float32)
    wu = jax.jit(lambda w, g: ref.weighted_update_ref(w, g, jnp.float32(0.1))[0])
    us = timeit(lambda: wu(w, g).block_until_ready(), iters=5)
    gbps = 3 * 4e6 * 4 / us / 1e3
    out.append(row("kernel_weighted_update_4M", us, f"gb_s={gbps:.1f}"))
    return out


def sweep_block_tiles(P: int = 8192, rows_R: int = 17, Es=(8, 16),
                      iters: int = 3, save: bool = True):
    """Column-tile sweep for the blocked-update kernels (autotune source).

    Times `block_prefix_update` / `block_scatter_rows` at every tile in
    `autotune.TILE_CANDIDATES` for each micro-block size E, records each
    winner in the cached autotune table keyed (backend, P, E) — the table
    `repro.kernels.ops` consults on the blocked engine's pallas path.  On
    CPU the kernels execute in interpret mode: those timings are honest for
    this backend (and stored under "cpu", so they never leak to TPU) but
    Python-speed — the sweep sizes default small accordingly.
    """
    from repro.kernels import autotune
    from repro.kernels.weighted_update import (
        BLOCK_TILE,
        block_prefix_update,
        block_scatter_rows,
    )

    backend = jax.default_backend()
    interpret = backend != "tpu"
    if P % BLOCK_TILE:
        raise ValueError(f"P={P} must be a multiple of BLOCK_TILE={BLOCK_TILE}")
    rng = np.random.default_rng(0)
    out = []
    for E in Es:
        snaps = jnp.asarray(rng.normal(size=(rows_R, P)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(P,)), jnp.float32)
        D = jnp.asarray(rng.normal(size=(E, P)) * 1e-3, jnp.float32)
        slots = jnp.asarray(rng.integers(0, rows_R - 1, size=E), jnp.int32)
        for name, fn, mid in (
            ("block_prefix_update", block_prefix_update, D),
            ("block_scatter_rows", block_scatter_rows, D),
        ):
            best_tile, best_us = None, float("inf")
            for tile in autotune.TILE_CANDIDATES:
                if tile > P:
                    continue
                us = timeit(
                    lambda: jax.block_until_ready(
                        fn(snaps, w, mid, slots, interpret=interpret, tile=tile)
                    ),
                    iters=iters,
                )
                out.append(row(f"{name}_P{P}_E{E}_tile{tile}", us,
                               f"backend={backend}"))
                if us < best_us:
                    best_tile, best_us = tile, us
            if save and best_tile is not None:
                autotune.record(name, backend, P, E, best_tile, us=best_us)
            out.append(row(f"{name}_P{P}_E{E}_best", best_us,
                           f"tile={best_tile}"))
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep-tiles", action="store_true",
                    help="tile sweep for the blocked-update kernels; "
                         "records winners in the autotune table")
    ap.add_argument("--P", type=int, default=8192)
    ap.add_argument("--E", type=int, nargs="*", default=[8, 16])
    ap.add_argument("--no-save", action="store_true")
    a = ap.parse_args()
    rows = (sweep_block_tiles(P=a.P, Es=tuple(a.E), save=not a.no_save)
            if a.sweep_tiles else bench_kernels())
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
