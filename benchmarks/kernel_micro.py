"""Kernel microbenchmarks: jnp reference path wall-time on CPU.

(The Pallas kernels themselves run in interpret mode on CPU — Python-speed,
not representative; the jnp path is what the CPU dry-run executes and the
number reported as us_per_call.  On TPU the same entry points dispatch the
compiled kernels.)"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

from .common import row, timeit


def bench_kernels():
    rng = np.random.default_rng(0)
    out = []

    # flash attention ref (B=1,S=512,H=8,K=2,D=64)
    q = jnp.asarray(rng.normal(size=(1, 512, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us = timeit(lambda: fa(q, k, v).block_until_ready(), iters=5)
    flops = 4 * 512 * 512 * 8 * 64
    out.append(row("kernel_attention_ref_512", us, f"gflops_s={flops/us/1e3:.1f}"))

    # ssd scan ref
    x = jnp.asarray(rng.normal(size=(2, 512, 8, 64)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (2, 512, 8)), jnp.float32)
    A = -jnp.ones((8,), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(2, 512, 64)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(2, 512, 64)), jnp.float32)
    ssd = jax.jit(lambda *a: ref.ssd_scan_ref(*a, chunk=64)[0])
    us = timeit(lambda: ssd(x, dt, A, Bm, Cm).block_until_ready(), iters=5)
    out.append(row("kernel_ssd_ref_512", us, "chunk=64"))

    # moe gmm ref
    xg = jnp.asarray(rng.normal(size=(8, 256, 256)), jnp.bfloat16)
    wg = jnp.asarray(rng.normal(size=(8, 256, 512)), jnp.bfloat16)
    gm = jax.jit(ref.moe_gmm_ref)
    us = timeit(lambda: gm(xg, wg).block_until_ready(), iters=5)
    flops = 2 * 8 * 256 * 256 * 512
    out.append(row("kernel_moe_gmm_ref", us, f"gflops_s={flops/us/1e3:.1f}"))

    # weighted update ref
    w = jnp.asarray(rng.normal(size=(4_000_000,)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(4_000_000,)), jnp.float32)
    wu = jax.jit(lambda w, g: ref.weighted_update_ref(w, g, jnp.float32(0.1))[0])
    us = timeit(lambda: wu(w, g).block_until_ready(), iters=5)
    gbps = 3 * 4e6 * 4 / us / 1e3
    out.append(row("kernel_weighted_update_4M", us, f"gb_s={gbps:.1f}"))
    return out
