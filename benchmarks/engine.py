"""Training-engine benchmark: Python per-event loop vs compiled scan engine.

Runs the §5 federated experiment (MLP classifier, heterogeneous client
speeds) through both server loops at identical configuration and event law,
and writes ``BENCH_engine.json``.  The headline case is n=256 clients, C=64
in flight, T=5000 CS steps on CPU: the Python loop pays a host<->device round
trip per CS step (batch generation, dispatch of the jitted grad, per-leaf
tree updates), the scan engine replays the pre-simulated event stream as one
XLA program.  The speedup is therefore largest in the dispatch-bound regime
(small per-step gradient work — typical FL client models); a compute-bound
case (batch 128) is included for calibration.

    PYTHONPATH=src python benchmarks/engine.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time
from dataclasses import replace
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

# The stream benchmark shards scenarios across CPU "devices" (the host-export
# path is serial Python and cannot); XLA_FLAGS must be set before jax loads.
if "--stream" in sys.argv and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.cpu_count()}"
    )

from repro.configs.base import FLConfig  # noqa: E402
from repro.core import ServerConfig, run_fedbuff, run_generalized_async_sgd  # noqa: E402
from repro.data.pipeline import FederatedClassification, make_client_speeds  # noqa: E402
from repro.fl.engine import DeviceFLClients, FLClients, MLPClassifier, run_matrix  # noqa: E402


def _best(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        gc.collect()
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _compare(data, mu, n, C, T, hidden, batch, method="gen_async", Z=10,
             reps=4):
    model = MLPClassifier(data.dim, data.num_classes, hidden=hidden, seed=0)
    host = FLClients(data, model, batch_size=batch)
    dev = DeviceFLClients(data, model, batch_size=batch, shard_size=512, seed=0)
    cfg = ServerConfig(n=n, C=C, T=T, eta=0.05, mu=mu, seed=0,
                       weighting="importance" if method == "gen_async" else "plain")
    cfg_scan = replace(cfg, engine="scan")

    def once(clients, c):
        if method == "fedbuff":
            run_fedbuff(model.init_params, clients, c, Z=Z)
        else:
            run_generalized_async_sgd(model.init_params, clients, c)

    cold_s = _best(lambda: once(dev, cfg_scan), 1)       # includes compile
    # interleave reps so machine-load noise hits both engines alike
    py_s = scan_s = float("inf")
    for _ in range(reps):
        py_s = min(py_s, _best(lambda: once(host, cfg), 1))
        scan_s = min(scan_s, _best(lambda: once(dev, cfg_scan), 1))
    return py_s, cold_s, scan_s


def run(quick: bool) -> dict:
    n, C, T = (32, 8, 500) if quick else (256, 64, 5000)
    data = FederatedClassification(n_clients=n, seed=0)
    mu = make_client_speeds(n, 0.5, 10.0, seed=0)
    results = []

    def record(name, python_s, scan_s, note=""):
        entry = {
            "name": name,
            "python_s": round(python_s, 3),
            "scan_s": round(scan_s, 3),
            "speedup": round(python_s / scan_s, 2),
            "note": note,
        }
        results.append(entry)
        print(f"{name:52s} {python_s:8.2f} s -> {scan_s:7.3f} s   x{entry['speedup']:.1f}")

    # --- headline: dispatch-bound FL config ------------------------------ #
    py_s, cold_s, scan_s = _compare(data, mu, n, C, T, hidden=32, batch=16)
    record(
        f"fl_mlp_gen_async(n={n},C={C},T={T},h=32,b=16)", py_s, scan_s,
        note=f"warm scan (incl. host stream export); cold run with compile "
        f"was {cold_s:.2f}s",
    )

    # --- fedbuff through both engines ------------------------------------ #
    py_fb, _, sc_fb = _compare(data, mu, n, C, T, hidden=32, batch=16,
                               method="fedbuff")
    record(f"fl_mlp_fedbuff(n={n},C={C},T={T},h=32,b=16)", py_fb, sc_fb)

    # --- compute-bound calibration point --------------------------------- #
    py_c, _, sc_c = _compare(data, mu, n, C, T, hidden=128, batch=128,
                             reps=2)
    record(
        f"fl_mlp_gen_async(n={n},C={C},T={T},h=128,b=128)", py_c, sc_c,
        note="compute-bound: both engines dominated by the same gradient "
        "FLOPs; speedup here is pure dispatch overhead removal",
    )

    # --- scenario matrix: amortization across vmapped streams ------------ #
    seeds = (0, 1) if quick else (0, 1, 2, 3)
    flc = FLConfig(n_clients=n, concurrency=C, server_steps=T // 2,
                   sampling="uniform", speed_ratio=10.0, seed=0)
    mat_s = _best(lambda: run_matrix(
        flc, seeds=seeds, policies=("uniform", "optimal"),
        speed_ratios=(1.0, 10.0), eval_every=max(T // 20, 10), data=data,
    ), 1)
    n_scen = len(seeds) * 2 * 2
    results.append({
        "name": f"run_matrix({n_scen}_scenarios,T={T // 2})",
        "total_s": round(mat_s, 3),
        "per_scenario_s": round(mat_s / n_scen, 3),
        "note": "seeds x {uniform, optimal} x heterogeneity in ONE compiled "
        "call (incl. compile + host stream exports)",
    })
    print(f"run_matrix: {n_scen} scenarios in {mat_s:.2f}s "
          f"({mat_s / n_scen:.3f}s/scenario)")

    return {
        "bench": "engine",
        "quick": quick,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "results": results,
    }


# --------------------------------------------------------------------- #
# stream benchmark: fused on-device event generation vs the host-export
# path, at scenario-matrix scale -> BENCH_stream.json
# --------------------------------------------------------------------- #
def run_stream(quick: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core import BoundConstants, SimConfig, export_stream
    from repro.core.sampling import bound_for_p, optimize_general
    from repro.core.stream_device import stats_stream_fn

    n, C, T = (64, 16, 500) if quick else (256, 64, 5000)
    D = jax.device_count()
    data = FederatedClassification(n_clients=n, seed=0)
    mu = make_client_speeds(n, 0.5, 10.0, seed=0)
    p = np.full(n, 1.0 / n)
    results = []

    def record(name, host_s, dev_s, note=""):
        entry = {
            "name": name,
            "host_s": round(host_s, 3),
            "device_s": round(dev_s, 3),
            "speedup": round(host_s / dev_s, 2),
            "note": note,
        }
        results.append(entry)
        print(f"{name:48s} host {host_s:7.3f}s -> device {dev_s:7.3f}s  "
              f"x{entry['speedup']:.2f}")

    # --- stream layer: event generation + running statistics ----------- #
    # both sides produce the queueing observables (delays, occupancy,
    # completions): host = export_stream with delay recording, device = the
    # fused stats scan the adaptive control loop consumes
    gen = stats_stream_fn(n, C, T)
    for B in ((4,) if quick else (16, 64)):
        def host_once():
            for b in range(B):
                export_stream(
                    SimConfig(mu=mu, p=p, C=C, T=T, seed=b, record_delays=True)
                )

        keys = jax.random.split(jax.random.PRNGKey(0), B)
        mus = jnp.asarray(np.broadcast_to(mu, (B, n)).copy(), jnp.float32)
        ps = jnp.full((B, n), 1.0 / n, jnp.float32)
        if D > 1 and B % D == 0:
            f = jax.pmap(jax.vmap(gen))
            dev_args = tuple(a.reshape((D, B // D) + a.shape[1:])
                             for a in (keys, mus, ps))
        else:
            f = jax.jit(jax.vmap(gen))
            dev_args = (keys, mus, ps)
        jax.block_until_ready(f(*dev_args))  # compile
        host_s = _best(host_once, 3)
        dev_s = _best(lambda: jax.block_until_ready(f(*dev_args)), 3)
        record(
            f"stream_matrix(B={B},n={n},C={C},T={T})", host_s, dev_s,
            note=f"host: serial export_stream(record_delays) per scenario; "
            f"device: fused stats scan sharded over {D} device(s) — both "
            f"produce per-node delay/occupancy statistics",
        )

    # --- run_matrix end-to-end: zero host pre-simulation ---------------- #
    seeds = (0, 1) if quick else (0, 1, 2, 3)
    flc = FLConfig(n_clients=n, concurrency=C, server_steps=T,
                   sampling="uniform", speed_ratio=10.0, seed=0)
    eval_every = max(T // 4, 10)
    kwargs = dict(seeds=seeds, policies=("uniform", "optimal"),
                  speed_ratios=(1.0, 10.0), eval_every=eval_every, data=data)
    n_scen = len(seeds) * 2 * 2
    run_matrix(flc, stream="device", **kwargs)   # compile
    dev_s = _best(lambda: run_matrix(flc, stream="device", **kwargs), 2)
    run_matrix(flc, stream="host", **kwargs)     # compile
    host_s = _best(lambda: run_matrix(flc, stream="host", **kwargs), 2)
    record(
        f"run_matrix({n_scen}_scenarios,T={T})", host_s, dev_s,
        note="end-to-end training matrix (warm); both paths share the "
        "gradient FLOPs — the device path removes the serial host "
        f"pre-simulation and shards scenarios over {D} device(s)",
    )

    # --- adaptive sampling: device-only capability ---------------------- #
    flc_a = flc.replace(stream="device", adaptive=True, refresh_every=max(T // 20, 10),
                        speed_ratio=10.0)
    m = run_matrix(flc_a, seeds=seeds, policies=("uniform",),
                   speed_ratios=(10.0,), eval_every=T, data=data)
    ad_s = _best(lambda: run_matrix(flc_a, seeds=seeds, policies=("uniform",),
                                    speed_ratios=(10.0,), eval_every=T,
                                    data=data), 1)
    mu_h = make_client_speeds(n, 0.5, 10.0, seed=0)
    k = BoundConstants(C=C, T=T)
    p_fin = m.extras["p_final"][:, 0, 0].mean(0)
    p_fin = np.maximum(p_fin, 1e-12) / p_fin.sum()
    b_ad = bound_for_p(mu_h, p_fin, k)[0]
    opt = optimize_general(mu_h, k, iters=500)
    entry = {
        "name": f"run_matrix_adaptive({len(seeds)}_scenarios,T={T})",
        "device_s": round(ad_s, 3),
        "bound_adaptive": round(float(b_ad), 4),
        "bound_static_opt": round(float(opt.bound), 4),
        "bound_uniform": round(float(opt.uniform_bound), 4),
        "gap_vs_static_opt": round(float(b_ad / opt.bound - 1.0), 4),
        "note": "adaptive-from-uniform control loop (host path cannot run "
        "this); gap_vs_static_opt is the bound excess over optimize_general",
    }
    results.append(entry)
    print(f"adaptive: {ad_s:.2f}s  bound {b_ad:.4f} vs static-opt "
          f"{opt.bound:.4f} (uniform {opt.uniform_bound:.4f})")

    return {
        "bench": "stream",
        "quick": quick,
        "devices": D,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "results": results,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small sizes (CI smoke)")
    ap.add_argument("--stream", action="store_true",
                    help="benchmark the fused device stream vs the host-export "
                    "path (writes BENCH_stream.json by default)")
    ap.add_argument("--out", default=None, help="output JSON path")
    args = ap.parse_args()
    name = "BENCH_stream.json" if args.stream else "BENCH_engine.json"
    out = args.out or str(Path(__file__).resolve().parent.parent / name)
    payload = run_stream(args.quick) if args.stream else run(args.quick)
    Path(out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
