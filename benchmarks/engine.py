"""Training-engine benchmark: Python per-event loop vs compiled scan engine.

Runs the §5 federated experiment (MLP classifier, heterogeneous client
speeds) through both server loops at identical configuration and event law,
and writes ``BENCH_engine.json``.  The headline case is n=256 clients, C=64
in flight, T=5000 CS steps on CPU: the Python loop pays a host<->device round
trip per CS step (batch generation, dispatch of the jitted grad, per-leaf
tree updates), the scan engine replays the pre-simulated event stream as one
XLA program.  The speedup is therefore largest in the dispatch-bound regime
(small per-step gradient work — typical FL client models); a compute-bound
case (batch 128) is included for calibration.

``--stream`` benchmarks the fused on-device event generator against the
host-export path (``BENCH_stream.json``); ``--block`` sweeps the blocked
(event micro-batched) engine against the per-event scan at several block
sizes and end-to-end through ``run_matrix`` (``BENCH_block.json``); ``--scale`` sweeps the sparse O(C) stream and the
class-collapsed control plane across n up to 10^6, recording per-event cost
flatness in n (``BENCH_scale.json``).

Every row records ``block_size``, ``devices``, ``dtype`` and separates
compile time (``cold_s``: first call including trace+compile) from the
steady-state ``warm_s``.

    PYTHONPATH=src python benchmarks/engine.py [--quick] [--stream|--block]
                                               [--out PATH]
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time
from dataclasses import replace
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

# The stream benchmark shards scenarios across CPU "devices" (the host-export
# path is serial Python and cannot), and the block benchmark lane-shards
# micro-blocks across 2 of them; XLA_FLAGS must be set before jax loads.
if "XLA_FLAGS" not in os.environ:
    if "--stream" in sys.argv:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={os.cpu_count() or 1}"
        )
    elif "--block" in sys.argv:
        # exactly 2: the sharded rows use 2 lanes, and forcing more would
        # skew the unsharded timings' XLA threading vs prior BENCH files
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

from repro.configs.base import FLConfig  # noqa: E402
from repro.core import ServerConfig, run_fedbuff, run_generalized_async_sgd  # noqa: E402
from repro.data.pipeline import FederatedClassification, make_client_speeds  # noqa: E402
from repro.fl.engine import DeviceFLClients, FLClients, MLPClassifier, run_matrix  # noqa: E402

DTYPE = "float32"


def _devices() -> int:
    import jax

    return jax.device_count()


def _best(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        gc.collect()
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _row(name, *, block_size=1, note="", **fields) -> dict:
    entry = {
        "name": name,
        "block_size": block_size,
        "devices": _devices(),
        "dtype": DTYPE,
        **{k: (round(v, 3) if isinstance(v, float) else v)
           for k, v in fields.items()},
        "note": note,
    }
    return entry


def _compare(data, mu, n, C, T, hidden, batch, method="gen_async", Z=10,
             reps=4):
    model = MLPClassifier(data.dim, data.num_classes, hidden=hidden, seed=0)
    host = FLClients(data, model, batch_size=batch)
    dev = DeviceFLClients(data, model, batch_size=batch, shard_size=512, seed=0)
    cfg = ServerConfig(n=n, C=C, T=T, eta=0.05, mu=mu, seed=0,
                       weighting="importance" if method == "gen_async" else "plain")
    cfg_scan = replace(cfg, engine="scan")

    def once(clients, c):
        if method == "fedbuff":
            run_fedbuff(model.init_params, clients, c, Z=Z)
        else:
            run_generalized_async_sgd(model.init_params, clients, c)

    cold_s = _best(lambda: once(dev, cfg_scan), 1)       # includes compile
    # interleave reps so machine-load noise hits both engines alike
    py_s = scan_s = float("inf")
    for _ in range(reps):
        py_s = min(py_s, _best(lambda: once(host, cfg), 1))
        scan_s = min(scan_s, _best(lambda: once(dev, cfg_scan), 1))
    return py_s, cold_s, scan_s


def run(quick: bool) -> dict:
    n, C, T = (32, 8, 500) if quick else (256, 64, 5000)
    data = FederatedClassification(n_clients=n, seed=0)
    mu = make_client_speeds(n, 0.5, 10.0, seed=0)
    results = []

    def record(name, python_s, cold_s, scan_s, note=""):
        entry = _row(
            name, python_s=python_s, cold_s=cold_s, warm_s=scan_s,
            speedup=round(python_s / scan_s, 2), note=note,
        )
        results.append(entry)
        print(f"{name:52s} {python_s:8.2f} s -> {scan_s:7.3f} s   "
              f"x{entry['speedup']:.1f}  (cold {cold_s:.2f}s)")

    # --- headline: dispatch-bound FL config ------------------------------ #
    py_s, cold_s, scan_s = _compare(data, mu, n, C, T, hidden=32, batch=16)
    record(
        f"fl_mlp_gen_async(n={n},C={C},T={T},h=32,b=16)", py_s, cold_s, scan_s,
        note="warm scan (incl. host stream export); cold_s = first call "
        "with trace+compile",
    )

    # --- fedbuff through both engines ------------------------------------ #
    py_fb, cold_fb, sc_fb = _compare(data, mu, n, C, T, hidden=32, batch=16,
                                     method="fedbuff")
    record(f"fl_mlp_fedbuff(n={n},C={C},T={T},h=32,b=16)", py_fb, cold_fb, sc_fb)

    # --- compute-bound calibration point --------------------------------- #
    py_c, cold_c, sc_c = _compare(data, mu, n, C, T, hidden=128, batch=128,
                                  reps=2)
    record(
        f"fl_mlp_gen_async(n={n},C={C},T={T},h=128,b=128)", py_c, cold_c, sc_c,
        note="compute-bound: both engines dominated by the same gradient "
        "FLOPs; speedup here is pure dispatch overhead removal",
    )

    # --- scenario matrix: amortization across vmapped streams ------------ #
    seeds = (0, 1) if quick else (0, 1, 2, 3)
    flc = FLConfig(n_clients=n, concurrency=C, server_steps=T // 2,
                   sampling="uniform", speed_ratio=10.0, seed=0)
    kwargs = dict(seeds=seeds, policies=("uniform", "optimal"),
                  speed_ratios=(1.0, 10.0), eval_every=max(T // 20, 10),
                  data=data)
    mat_cold = _best(lambda: run_matrix(flc, **kwargs), 1)
    mat_s = _best(lambda: run_matrix(flc, **kwargs), 1)
    n_scen = len(seeds) * 2 * 2
    results.append(_row(
        f"run_matrix({n_scen}_scenarios,T={T // 2})",
        cold_s=mat_cold, warm_s=mat_s,
        per_scenario_s=round(mat_s / n_scen, 3),
        note="seeds x {uniform, optimal} x heterogeneity in ONE compiled "
        "call (warm; incl. host stream exports)",
    ))
    print(f"run_matrix: {n_scen} scenarios in {mat_s:.2f}s "
          f"({mat_s / n_scen:.3f}s/scenario, cold {mat_cold:.2f}s)")

    return {
        "bench": "engine",
        "quick": quick,
        "devices": _devices(),
        "dtype": DTYPE,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "results": results,
    }


# --------------------------------------------------------------------- #
# blocked engine benchmark: event micro-batching vs the per-event scan
# -> BENCH_block.json
# --------------------------------------------------------------------- #
def run_block(quick: bool) -> dict:
    n, C, T = (32, 8, 500) if quick else (256, 64, 5000)
    data = FederatedClassification(n_clients=n, seed=0)
    mu = make_client_speeds(n, 0.5, 10.0, seed=0)
    block_sizes = (4, 8) if quick else (4, 8, 16)
    results = []

    def bench_config(hidden, batch, tag, reps):
        model = MLPClassifier(data.dim, data.num_classes, hidden=hidden, seed=0)
        dev = DeviceFLClients(data, model, batch_size=batch, shard_size=512,
                              seed=0)
        cfg = ServerConfig(n=n, C=C, T=T, eta=0.05, mu=mu, seed=0,
                           engine="scan", collect_extras=False)

        def once(c):
            run_generalized_async_sgd(model.init_params, dev, c)

        base_cold = _best(lambda: once(cfg), 1)
        base_warm = _best(lambda: once(cfg), reps)
        results.append(_row(
            f"{tag}(n={n},C={C},T={T},h={hidden},b={batch})",
            block_size=1, devices=1, cold_s=base_cold, warm_s=base_warm,
            speedup=1.0,
            note="per-event scan baseline (host stream, extras pruned)",
        ))
        print(f"{tag} E=1 : {base_warm:7.3f}s (baseline)")
        best = (1, base_warm)
        for E in block_sizes:
            cfg_b = replace(cfg, block_size=E)
            cold = _best(lambda: once(cfg_b), 1)
            warm = _best(lambda: once(cfg_b), reps)
            results.append(_row(
                f"{tag}(n={n},C={C},T={T},h={hidden},b={batch})",
                block_size=E, devices=1, cold_s=cold, warm_s=warm,
                speedup=round(base_warm / warm, 2),
                note="blocked scan: conflict-free micro-blocks, vmapped "
                "gradients + prefix-sum update",
            ))
            print(f"{tag} E={E:<2d}: {warm:7.3f}s  x{base_warm / warm:.2f}")
            if warm < best[1]:
                best = (E, warm)
        return best

    # --- compute-bound config (the ISSUE 4 target config) ---------------- #
    best_cb = bench_config(128, 128, "blocked_gen_async", reps=2)
    # --- dispatch-bound config ------------------------------------------- #
    best_db = bench_config(32, 16, "blocked_gen_async", reps=3)

    # --- segmentation quality: greedy vs DP cut + measured E selection --- #
    # hardware-independent rows: lane utilization is a pure function of the
    # event stream (the delay distribution), so these hold on any backend
    from repro.core import EventBlocks, SimConfig, export_stream, select_block_size

    E_seg = block_sizes[1]
    st = export_stream(SimConfig(mu=mu, p=np.full(n, 1.0 / n), C=C, T=T, seed=0))
    seg_util = {}
    for method in ("greedy", "dp"):
        b = EventBlocks.from_stream(st, E_seg, method=method)
        seg_util[method] = b.utilization
        results.append(_row(
            f"segmentation_{method}(n={n},C={C},T={T},E={E_seg})",
            block_size=E_seg, devices=1,  # pure host math, device-independent
            utilization=round(b.utilization, 4),
            padded_lanes=b.padded_lanes, blocks=b.B,
            note="mean lane utilization T/(B*E) of the cut on the "
            "dispatch-bound stream; DP is the exact minimum-padding cut "
            "(greedy matches it — hereditary validity)",
        ))
        print(f"segmentation {method:6s} E={E_seg}: util "
              f"{b.utilization:.3f} ({b.padded_lanes} padded lanes)")
    assert seg_util["dp"] >= seg_util["greedy"]
    E_auto, utils = select_block_size(st.slot, block_size_max=16, devices=1)
    results.append(_row(
        f"select_block_size(n={n},C={C},T={T})",
        block_size=E_auto, devices=1,  # pure host math, device-independent
        utilization=round(utils[E_auto], 4),
        note="largest E with measured lane utilization >= 0.5; candidates "
        + str({e: round(u, 3) for e, u in sorted(utils.items())}),
    ))
    print(f"select_block_size -> E={E_auto} (util {utils[E_auto]:.3f})")

    # --- lane-sharded blocked run: devices=2 ------------------------------ #
    import jax

    if jax.device_count() >= 2:
        E_sh = E_seg
        model = MLPClassifier(data.dim, data.num_classes, hidden=32, seed=0)
        dev = DeviceFLClients(data, model, batch_size=16, shard_size=512,
                              seed=0)
        cfg_b = ServerConfig(n=n, C=C, T=T, eta=0.05, mu=mu, seed=0,
                             engine="scan", collect_extras=False,
                             block_size=E_sh)

        def once_sh(c):
            run_generalized_async_sgd(model.init_params, dev, c)

        once_sh(cfg_b)
        base_warm = _best(lambda: once_sh(cfg_b), 2)
        cfg_sh = replace(cfg_b, devices=2)
        sh_cold = _best(lambda: once_sh(cfg_sh), 1)
        sh_warm = _best(lambda: once_sh(cfg_sh), 2)
        results.append(_row(
            f"blocked_gen_async_sharded(n={n},C={C},T={T},h=32,b=16,E={E_sh})",
            block_size=E_sh, devices=2, cold_s=sh_cold, warm_s=sh_warm,
            speedup=round(base_warm / sh_warm, 2),
            note="E lanes sharded over 2 forced host devices (one "
            "all-gather per block); on this shared-FLOP CPU host the row "
            "pins mechanism + collective overhead, not a speedup — the "
            "lane-division payoff needs real accelerators",
        ))
        print(f"lane-sharded E={E_sh} devices=2: {sh_warm:.3f}s "
              f"(unsharded {base_warm:.3f}s)")

    # --- run_matrix end-to-end: blocked vs per-event --------------------- #
    seeds = (0, 1) if quick else (0, 1, 2, 3)
    flc = FLConfig(n_clients=n, concurrency=C, server_steps=T // 2,
                   sampling="uniform", speed_ratio=10.0, seed=0)
    kwargs = dict(seeds=seeds, policies=("uniform", "optimal"),
                  speed_ratios=(1.0, 10.0), eval_every=max(T // 20, 10),
                  data=data)
    n_scen = len(seeds) * 2 * 2
    E = best_db[0] if best_db[0] > 1 else block_sizes[0]
    run_matrix(flc, **kwargs)                      # compile per-event
    mat_ev = _best(lambda: run_matrix(flc, **kwargs), 2)
    mat_blk_cold = _best(lambda: run_matrix(flc, block_size=E, **kwargs), 1)
    mat_blk = _best(lambda: run_matrix(flc, block_size=E, **kwargs), 2)
    results.append(_row(
        f"run_matrix({n_scen}_scenarios,T={T // 2})",
        block_size=1, devices=1, warm_s=mat_ev, speedup=1.0,
        note="per-event run_matrix baseline (warm, host streams)",
    ))
    results.append(_row(
        f"run_matrix({n_scen}_scenarios,T={T // 2})",
        block_size=E, devices=1, cold_s=mat_blk_cold, warm_s=mat_blk,
        speedup=round(mat_ev / mat_blk, 2),
        note="blocked run_matrix (warm, host streams; scenario-vmapped "
        "micro-blocks)",
    ))
    print(f"run_matrix E=1: {mat_ev:.2f}s   E={E}: {mat_blk:.2f}s  "
          f"x{mat_ev / mat_blk:.2f}")

    return {
        "bench": "block",
        "quick": quick,
        "devices": _devices(),
        "dtype": DTYPE,
        "cpu_count": os.cpu_count(),
        "backend": jax.default_backend(),
        "best": {"compute_bound_E": best_cb[0], "dispatch_bound_E": best_db[0]},
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "results": results,
        "note": "blocked speedups are hardware-dependent: the batched "
        "per-event-weight gradients become batched GEMMs, which pay off on "
        "wide parallel backends (TPU/GPU/many-core); on narrow CPU hosts "
        "the compute-bound config is already FLOP-saturated and the "
        "greedy conflict-free blocks carry padding overhead",
    }


# --------------------------------------------------------------------- #
# fault-tolerance benchmark: guard / checkpoint overhead on fault-free
# runs, fault-injected runs, adaptive-vs-static gap -> BENCH_faults.json
# --------------------------------------------------------------------- #
def run_faults(quick: bool) -> dict:
    import shutil
    import tempfile

    import jax
    import jax.numpy as jnp

    from repro.core import (
        BoundConstants,
        FaultConfig,
        GuardConfig,
        estimate_mu,
        make_fused_runner,
    )
    from repro.core.sampling import bound_for_p, optimize_general

    n, C, T = (32, 8, 500) if quick else (256, 64, 5000)
    h, b, E = ((32, 16, 8) if quick else (128, 128, 8))
    data = FederatedClassification(n_clients=n, seed=0)
    mu = make_client_speeds(n, 0.5, 10.0, seed=0)
    model = MLPClassifier(data.dim, data.num_classes, hidden=h, seed=0)
    dev = DeviceFLClients(data, model, batch_size=b, shard_size=512, seed=0)
    guard = GuardConfig(max_grad_norm=1e3, stale_cutoff=4 * C)
    fault = FaultConfig(off_rate=0.2, on_rate=1.0, crash_rate=0.05,
                        timeout_rate=0.1)
    base_cfg = ServerConfig(n=n, C=C, T=T, eta=0.05, mu=mu, seed=0,
                            engine="scan", stream="device", block_size=E,
                            collect_extras=False)
    results = []

    def once(c):
        return run_generalized_async_sgd(model.init_params, dev, c)

    def timed(c, reps=3):
        cold = _best(lambda: once(c), 1)
        warm = _best(lambda: once(c), reps)
        return cold, warm

    # --- fault-free overhead ladder: baseline -> +guard -> +guard+ckpt --- #
    # acceptance: guards + checkpointing must stay within 5% of the fused
    # baseline wall time on fault-free runs.  Reps are interleaved across
    # the three configs (same idiom as _compare) so machine-load drift hits
    # every rung alike instead of inflating whichever config runs last.
    ckdir = tempfile.mkdtemp(prefix="bench_faults_ck_")
    try:
        g_cfg = replace(base_cfg, guard=guard)
        ck_cfg = replace(base_cfg, guard=guard, ckpt_dir=ckdir,
                         ckpt_every=max(T // 5, 100))
        base_cold = _best(lambda: once(base_cfg), 1)
        g_cold = _best(lambda: once(g_cfg), 1)
        ck_cold = _best(lambda: once(ck_cfg), 1)
        base_warm = g_warm = ck_warm = float("inf")
        for _ in range(2 if quick else 4):
            base_warm = min(base_warm, _best(lambda: once(base_cfg), 1))
            g_warm = min(g_warm, _best(lambda: once(g_cfg), 1))
            ck_warm = min(ck_warm, _best(lambda: once(ck_cfg), 1))
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)

    results.append(_row(
        f"fused_baseline(n={n},C={C},T={T},E={E},h={h},b={b})",
        cold_s=base_cold, warm_s=base_warm, overhead_pct=0.0,
        note="blocked fused device stream (E-event micro-batches), no "
        "faults/guard/ckpt (overhead reference)",
    ))
    print(f"baseline          : {base_warm:7.3f}s")

    g_pct = 100.0 * (g_warm / base_warm - 1.0)
    results.append(_row(
        f"fused_guard(n={n},C={C},T={T},E={E},h={h},b={b})",
        cold_s=g_cold, warm_s=g_warm,
        overhead_pct=round(g_pct, 2),
        note="divergence + staleness guard on every candidate update "
        "(fault-free: nothing rejected, pure screening cost)",
    ))
    print(f"+guard            : {g_warm:7.3f}s ({g_pct:+.1f}%)")

    ck_pct = 100.0 * (ck_warm / base_warm - 1.0)
    results.append(_row(
        f"fused_guard_ckpt(n={n},C={C},T={T},E={E},h={h},b={b})",
        cold_s=ck_cold, warm_s=ck_warm,
        overhead_pct=round(ck_pct, 2),
        ckpt_every=max(T // 5, 100),
        within_5pct=bool(ck_pct <= 5.0),
        note="guard + full engine-state checkpoint every T/5 events "
        "(chunked scan + async host-side save); acceptance gate is the "
        "5% overhead budget vs the fused baseline",
    ))
    print(f"+guard+ckpt       : {ck_warm:7.3f}s ({ck_pct:+.1f}%)")

    # --- fault-injected run: churn + crashes + timeouts ------------------ #
    f_cfg = replace(base_cfg, faults=fault, guard=guard, collect_extras=True)
    f_cold, f_warm = timed(f_cfg, reps=2)
    _, tr = once(f_cfg)
    kind = np.asarray(tr.extras["kind_count"]).tolist()
    results.append(_row(
        f"fused_faulted(n={n},C={C},T={T},E={E},h={h},b={b})",
        cold_s=f_cold, warm_s=f_warm,
        overhead_pct=round(100.0 * (f_warm / base_warm - 1.0), 2),
        kind_count={"complete": kind[0], "flip": kind[1],
                    "crash": kind[2], "timeout": kind[3]},
        guard_rejects=int(np.asarray(tr.extras["guard_rejects"])),
        stale_drops=int(np.asarray(tr.extras["stale_drops"])),
        note="Markov on/off churn + crash-with-task-loss + straggler "
        "timeouts injected in-program; non-completion events apply no "
        "update and re-dispatch",
    ))
    print(f"faulted           : {f_warm:7.3f}s  kinds={kind}")

    # --- adaptive under faults vs static-optimal on survivor rates ------- #
    # acceptance: the controller's final p must be within 10% of the
    # static-optimal bound for the service rates it can observe (the
    # busy-time-gated estimate_mu — availability gating keeps it unbiased
    # for the rate-while-up)
    n_a, C_a, T_a = (8, 4, 2000) if quick else (8, 4, 6000)
    mu_a = np.array([2.0] * 4 + [1.0] * 4, np.float32)
    fault_a = FaultConfig(off_rate=np.array([0.0] * 6 + [5.0] * 2),
                          on_rate=np.ones(8), crash_rate=0.1,
                          timeout_rate=0.1)
    targ = jnp.arange(n_a, dtype=jnp.float32)

    def quad_grad(j, w, k):
        return jax.tree_util.tree_map(lambda x: x - targ[j], w)

    k_a = BoundConstants(C=C_a, T=T_a)
    runner = make_fused_runner(quad_grad, n_a, C_a, T_a, adaptive=True,
                               refresh_every=300, bound=k_a, fault=fault_a)
    args = ({"a": jnp.zeros(3, jnp.float32)}, jnp.asarray(mu_a),
            jnp.full(n_a, 1 / n_a), jax.random.PRNGKey(1), 0.01)
    ad_cold = _best(lambda: jax.block_until_ready(runner(*args)), 1)
    _, _, ex = runner(*args)
    p_fin = np.asarray(ex["p_final"], np.float64)
    p_fin = p_fin / p_fin.sum()
    mu_hat = np.asarray(estimate_mu(jnp.asarray(ex["comp"]),
                                    jnp.asarray(ex["busy_time"])), np.float64)
    b_ad = float(bound_for_p(mu_hat, p_fin, k_a)[0])
    b_opt = float(optimize_general(mu_hat, k_a).bound)
    gap = b_ad / b_opt - 1.0
    results.append(_row(
        f"adaptive_under_faults(n={n_a},C={C_a},T={T_a})",
        cold_s=ad_cold,
        bound_adaptive=round(b_ad, 4), bound_static_opt=round(b_opt, 4),
        gap_vs_static_opt=round(gap, 4), within_10pct=bool(gap <= 0.10),
        mu_hat=[round(float(x), 3) for x in mu_hat],
        note="hard-churn cluster (2 nodes up ~1/6 of the time) + crashes + "
        "timeouts; controller's final p scored against the static optimum "
        "for its own busy-time-gated rate estimates (10% acceptance gate)",
    ))
    print(f"adaptive gap      : {100 * gap:+.2f}% "
          f"(bound {b_ad:.4f} vs opt {b_opt:.4f})")

    return {
        "bench": "faults",
        "quick": quick,
        "devices": _devices(),
        "dtype": DTYPE,
        "cpu_count": os.cpu_count(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "results": results,
        "note": "overhead_pct rows are vs the fused no-guard/no-ckpt "
        "baseline on the same fault-free event law; kill-and-resume "
        "bitwise identity is locked by tests/test_ckpt.py (incl. a real "
        "SIGKILL subprocess in the slow tier), not timed here",
    }


# --------------------------------------------------------------------- #
# scenario library: how far the paper's exponential p* sits from the
# simulated optimum under each service/availability law
# -> BENCH_scenarios.json
# --------------------------------------------------------------------- #
def _measured_node_delays(stream):
    """Per-node completion-counted mean delays from an exported stream.

    The bound's m_i is the staleness in *server updates*; scenario streams
    interleave stage/flip rows, so the merged-step delay column overcounts.
    Replays the slot bookkeeping to count completions between dispatch and
    completion, exactly as the update staleness the replay engine sees.
    """
    disp = np.zeros(stream.C, np.int64)
    comp = 0
    dsum = np.zeros(stream.n)
    dcnt = np.zeros(stream.n, np.int64)
    kind = stream.kind
    for k in range(stream.T):
        if kind is not None and kind[k] != 0:  # KIND_COMPLETE == 0
            continue
        s = stream.slot[k]
        j = stream.J[k]
        dsum[j] += comp - disp[s]
        dcnt[j] += 1
        comp += 1
        disp[s] = comp
    return dsum / np.maximum(dcnt, 1), dcnt


def run_scenarios(quick: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core import BoundConstants, make_fused_runner
    from repro.core import stream_device as sd
    from repro.core.sampling import (
        _two_cluster_p_batch,
        optimize_general,
        two_cluster_p_vector,
    )
    from repro.core.scenario import SCENARIOS
    from repro.core.theory import generalized_bound, optimal_eta

    if quick:
        n, n_f, C, T, grid, names = 8, 4, 4, 3_000, 7, [
            "erlang2", "hyperexp2", "onoff", "erlang2_onoff"]
    else:
        n, n_f, C, T, grid, names = 16, 8, 8, 20_000, 13, [
            "erlang2", "erlang4", "hyperexp2", "onoff", "onoff_slow",
            "erlang2_onoff"]
    ratio = 4.0
    mu = np.full(n, 1.0)
    mu[:n_f] = ratio
    k = BoundConstants(C=C, T=T)

    # candidate family: two-cluster p vectors over the fast-node probability
    ps = np.linspace(0.25 / n, 0.93 / n_f, grid)
    P = _two_cluster_p_batch(n, n_f, ps)

    # the paper's exponential-law optimum (Theorem 1 + product-form delays)
    exp_opt = optimize_general(mu, k)
    p_exp = np.asarray(exp_opt.p, np.float64)
    p_exp /= p_exp.sum()

    def measured_bound(p, sc, seeds):
        """Theorem-1 RHS with per-node delays *measured* under scenario
        ``sc`` at sampling ``p`` (averaged over ``seeds``)."""
        vals = []
        for seed in seeds:
            stream = sd.generate_stream(
                mu, p, C, T, seed=seed,
                scenario=sc if sc.enabled else None,
            )
            m, _ = _measured_node_delays(stream)
            eta = optimal_eta(p, m, k)
            vals.append(generalized_bound(eta, p, m, k))
        return float(np.mean(vals))

    def quad_grad(j, w, kk):
        targ = jnp.arange(n, dtype=jnp.float32)
        return jax.tree_util.tree_map(lambda x: x - targ[j], w)

    seeds = (0, 1) if quick else (0, 1, 2)
    results = []
    for name in names:
        sc = SCENARIOS[name]
        t0 = time.perf_counter()
        curve = np.array([measured_bound(P[i], sc, seeds)
                          for i in range(grid)])
        i_star = int(np.argmin(curve))
        b_exp = measured_bound(p_exp, sc, seeds)

        # adaptive control loop under the same scenario: the controller
        # re-optimizes p from busy-time-gated rate estimates (exponential
        # MVA model), scored against the simulated optimum
        runner = make_fused_runner(quad_grad, n, C, T, adaptive=True,
                                   refresh_every=max(T // 8, 100), bound=k,
                                   scenario=sc)
        _, _, ex = runner({"a": jnp.zeros(3, jnp.float32)}, jnp.asarray(mu),
                          jnp.full(n, 1.0 / n), jax.random.PRNGKey(7), 0.01)
        p_ad = np.asarray(ex["p_final"], np.float64)
        p_ad = np.maximum(p_ad, 1e-9)
        p_ad /= p_ad.sum()
        b_ad = measured_bound(p_ad, sc, seeds)
        # simulated optimum = best candidate evaluated under this law (the
        # two-cluster grid plus the exponential p* and the adaptive p, all
        # scored on the same seeds), so the gaps are >= 0 by construction
        b_star = float(min(curve[i_star], b_exp, b_ad))
        gap_exp = b_exp / b_star - 1.0
        gap_ad = b_ad / b_star - 1.0

        mod = sc.modulation
        results.append(_row(
            f"{name}(n={n},C={C},T={T},ratio={ratio})",
            scenario=name,
            service_scv=round(sc.service.scv(), 3),
            modulated=bool(mod is not None and mod.enabled),
            p_fast_grid=[round(float(x), 5) for x in ps],
            bound_curve=[round(float(x), 5) for x in curve],
            p_fast_star=round(float(ps[i_star]), 5),
            p_fast_exp=round(float(p_exp[0]), 5),
            p_fast_adaptive=round(float(p_ad[:n_f].mean()), 5),
            bound_sim_opt=round(b_star, 5),
            bound_at_exp_pstar=round(b_exp, 5),
            bound_adaptive=round(b_ad, 5),
            gap_exp_pstar_pct=round(100.0 * gap_exp, 2),
            gap_adaptive_pct=round(100.0 * gap_ad, 2),
            wall_s=round(time.perf_counter() - t0, 2),
            note="measured Theorem-1 bound (per-node completion-counted "
            "delays from the scenario stream); gap_* is vs the best "
            "two-cluster p found by simulation under this law",
        ))
        print(f"{name:15s} q*={ps[i_star]:.4f} exp p*={p_exp[0]:.4f} "
              f"gap(exp)={100 * gap_exp:+.2f}% gap(adapt)={100 * gap_ad:+.2f}%")

    return {
        "bench": "scenarios",
        "quick": quick,
        "devices": _devices(),
        "dtype": DTYPE,
        "cpu_count": os.cpu_count(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n": n, "C": C, "T": T, "n_fast": n_f, "speed_ratio": ratio,
        "exp_theory_bound": round(float(exp_opt.bound), 5),
        "results": results,
        "note": "exponential-law p* (paper Theorem 1) scored under each "
        "scenario's simulated law vs the per-scenario simulated optimum "
        "over the two-cluster family; adaptive rows run the in-program "
        "control loop under the scenario and score its final p the same "
        "way.  Law correctness is locked by tests/test_scenarios.py.",
    }


# --------------------------------------------------------------------- #
# serving plane: overhead of the merged open-queue inference stream,
# overload shedding, and staleness SLO -> BENCH_serve.json
# --------------------------------------------------------------------- #
def run_serve_bench(quick: bool) -> dict:
    from repro.core import JacksonNetwork, ServingConfig
    from repro.core.serving import hist_quantile
    from repro.data.pipeline import make_client_speeds as _speeds

    n, C, T = (32, 8, 1500) if quick else (128, 32, 8000)
    b = 16
    data = FederatedClassification(n_clients=n, seed=0)
    mu = _speeds(n, 0.5, 10.0, seed=0)
    # size the serving plane relative to the *training* event rate so the
    # load labels (moderate / 2x overload) mean the same thing at every n
    lam_train = JacksonNetwork(mu=mu, p=np.full(n, 1 / n), C=C).throughput()
    nu = 0.4 * lam_train
    moderate = ServingConfig(
        arrival_rate=0.5 * nu, serve_rate=nu, queue_cap=8,
        deadline=4.0 / nu, max_retries=2,
        backoff_base=0.5 / nu, backoff_cap=4.0 / nu,
    )
    overload = replace(moderate, arrival_rate=2.0 * nu)

    def make_runner(h):
        model = MLPClassifier(data.dim, data.num_classes, hidden=h, seed=0)
        dev = DeviceFLClients(data, model, batch_size=b, shard_size=512,
                              seed=0)
        return model, dev

    def cfg_for(serving, T_, extras=False):
        return ServerConfig(
            n=n, C=C, T=T_, eta=0.05, mu=mu, seed=0, engine="scan",
            stream="device", sparse=False, collect_extras=extras,
            serving=serving,
        )

    def serve_fields(sv: ServingConfig) -> dict:
        """Traffic + policy labels every row must carry."""
        return dict(
            arrival_rate=float(sv.arrival_rate),
            serve_rate=float(sv.serve_rate),
            queue_cap=int(sv.queue_cap),        # admission threshold
            bucket_rate=float(sv.bucket_rate),
            deadline=float(sv.deadline),
            max_retries=int(sv.max_retries),    # retry policy
            backoff_base=float(sv.backoff_base),
            backoff_cap=float(sv.backoff_cap),
        )

    results = []

    # --- overhead ladders: no-serving baseline -> moderate live traffic.
    # Two model sizes: `rep` is a representative FL workload (the <= 5%
    # acceptance gate row); `dispatch` shrinks the gradient until the scan
    # machinery dominates — the serve plane's worst case, reported for
    # transparency (its per-step cost is a few fixed microseconds, so the
    # percentage is inflated exactly when the model is unrealistically
    # tiny). Interleaved warm reps so host load drift hits both arms.
    def ladder(tag, h, T_, gate):
        model, dev = make_runner(h)
        once = lambda c: run_generalized_async_sgd(model.init_params, dev, c)
        base_cfg = cfg_for(None, T_)
        mod_cfg = cfg_for(moderate, T_)
        base_cold = _best(lambda: once(base_cfg), 1)
        mod_cold = _best(lambda: once(mod_cfg), 1)
        base_warm = mod_warm = float("inf")
        for _ in range(2 if quick else 6):
            base_warm = min(base_warm, _best(lambda: once(base_cfg), 1))
            mod_warm = min(mod_warm, _best(lambda: once(mod_cfg), 1))
        pct = 100.0 * (mod_warm / base_warm - 1.0)
        results.append(_row(
            f"fused_baseline_{tag}(n={n},C={C},T={T_},h={h},b={b})",
            cold_s=base_cold, warm_s=base_warm, overhead_pct=0.0,
            note="per-event fused device stream, no serving "
            f"(overhead reference for the {tag} ladder)",
        ))
        results.append(_row(
            f"serving_moderate_{tag}(n={n},C={C},T={T_},h={h},b={b})",
            cold_s=mod_cold, warm_s=mod_warm, overhead_pct=pct,
            **serve_fields(moderate),
            note="open stream merged into the event race at rho=0.5 "
            "(arrival = half the serve rate); overhead vs no-serving "
            "baseline on one host"
            + (" — acceptance gate is <= 5%" if gate else
               "; dispatch-bound worst case (gradient cost shrunk until "
               "the scan machinery dominates), not the gate row"),
        ))
        print(f"[{tag}] baseline : {base_warm:7.3f}s")
        print(f"[{tag}] moderate : {mod_warm:7.3f}s  ({pct:+.1f}%)")
        return pct

    ladder("rep", 128, T // 2, gate=True)
    ladder("dispatch", 32, T, gate=False)

    # --- moderate-load + 2x overload counters (collect_extras on) ------- #
    model, dev = make_runner(32)
    once = lambda c: run_generalized_async_sgd(model.init_params, dev, c)
    for tag, sv in (("moderate", moderate), ("overload_2x", overload)):
        _, tr = once(cfg_for(sv, T, extras=True))
        ex = tr.extras
        arr = int(ex["serve_arrivals"])
        acct = (int(ex["serve_served"]) + int(ex["serve_shed"])
                + int(ex["serve_timed_out"]) + int(ex["serve_pending"]))
        assert arr == acct, f"conservation broke: {arr} != {acct}"
        assert int(ex["serve_qdepth_max"]) <= sv.R, "queue depth unbounded"
        served = max(int(ex["serve_served"]), 1)
        results.append(_row(
            f"serving_{tag}_counters(n={n},C={C},T={T})",
            arrivals=arr,
            served=int(ex["serve_served"]),
            shed=int(ex["serve_shed"]),
            timed_out=int(ex["serve_timed_out"]) + int(ex["serve_pending"]),
            retried=int(ex["serve_retried"]),
            shed_frac=round(int(ex["serve_shed"]) / max(arr, 1), 4),
            qdepth_max=int(ex["serve_qdepth_max"]),
            sojourn_mean=float(ex["serve_sojourn_sum"]) / served,
            sojourn_p99=hist_quantile(ex["serve_sojourn_hist"], 0.99),
            staleness_p50=hist_quantile(ex["serve_stale_hist"], 0.50, lo=0),
            staleness_p99=hist_quantile(ex["serve_stale_hist"], 0.99, lo=0),
            **serve_fields(sv),
            note=("conservation checked exact (served+shed+timed_out=="
                  "arrivals); shed_frac is the honest loss rate on this "
                  "1-host CPU run, not an idealized projection"
                  + ("; 2x overload: arrivals at twice the serve rate"
                     if tag == "overload_2x" else "")),
        ))
        print(f"{tag:12s}: arrivals={arr} shed={int(ex['serve_shed'])} "
              f"({100 * int(ex['serve_shed']) / max(arr, 1):.1f}%) "
              f"p99_staleness={hist_quantile(ex['serve_stale_hist'], 0.99, lo=0):.0f} steps")

    return {
        "bench": "serve",
        "quick": quick,
        "devices": _devices(),
        "dtype": DTYPE,
        "cpu_count": os.cpu_count(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "results": results,
        "note": "merged open/closed event race (repro.core.serving): "
        "serving rows carry their full traffic + admission + retry policy; "
        "staleness quantiles are in server steps behind the known-good "
        "snapshot; guard-never-served and bitwise ckpt properties are "
        "locked by tests/test_serving.py, not timed here",
    }


# --------------------------------------------------------------------- #
# real-model LM benchmark: compiled scan/blocked engine vs the per-event
# Python LM loop on the same LMTask shards -> BENCH_lm.json
# --------------------------------------------------------------------- #
def run_lm_bench(quick: bool) -> dict:
    import jax

    from repro.configs import smoke_config
    from repro.fl import LMTask, run_experiment

    n, C = (8, 4) if quick else (16, 8)
    results = []

    def once(flc, task, engine, eval_every):
        run_experiment(flc, "gen_async", eta=0.05, eval_every=eval_every,
                       engine=engine, task=task)

    def compare(tag, cfg, *, T, batch, seq, shard, block_size=1, reps=2,
                note=""):
        task = LMTask(cfg=cfg, batch_size=batch, seq_len=seq, shard_size=shard)
        flc = FLConfig(n_clients=n, concurrency=C, server_steps=T,
                       sampling="uniform", seed=0, block_size=block_size)
        cold_s = _best(lambda: once(flc, task, "scan", T), 1)
        py_s = scan_s = float("inf")
        for _ in range(reps):  # interleaved so load noise hits both alike
            py_s = min(py_s, _best(lambda: once(flc, task, "python", T), 1))
            scan_s = min(scan_s, _best(lambda: once(flc, task, "scan", T), 1))
        entry = _row(
            tag, block_size=block_size, python_s=py_s, cold_s=cold_s,
            warm_s=scan_s, speedup=round(py_s / scan_s, 2), note=note,
        )
        results.append(entry)
        print(f"{tag:56s} {py_s:8.2f} s -> {scan_s:7.3f} s   "
              f"x{entry['speedup']:.1f}  (cold {cold_s:.2f}s)")
        return task, flc, scan_s

    # --- dispatch-bound: tiny transformer, host sync dominates ----------- #
    # per-event gradient work is microseconds of FLOPs; the Python loop pays
    # a host round trip (dispatch + eval of the jitted grad + tree update)
    # per CS step, the scan engine none — this is the >=5x headline row
    T_d = 1000 if quick else 2000
    tiny = smoke_config("granite-3-2b").replace(
        num_layers=1, d_model=32, num_heads=1, num_kv_heads=1, head_dim=32,
        d_ff=64, vocab_size=64)
    compare(
        f"lm_tiny_gen_async(n={n},C={C},T={T_d},L=1,d=32,b=1,s=8)", tiny,
        T=T_d, batch=1, seq=8, shard=64, reps=3,
        note="dispatch-bound: 1-layer d_model=32 transformer, per-event "
        "host sync dominates the Python loop; warm scan replays the "
        "whole run as one XLA program",
    )

    # --- compute-bound: smoke config, real GEMM-heavy gradient ----------- #
    T_c = 48 if quick else 240
    smoke = smoke_config("granite-3-2b")
    task_c, flc_c, scan_c = compare(
        f"lm_smoke_gen_async(n={n},C={C},T={T_c},L=2,d=256,b=8,s=64)", smoke,
        T=T_c, batch=8, seq=64, shard=128, reps=2,
        note="compute-bound: smoke transformer (2L, d_model=256, vocab=512)"
        ", both engines dominated by the same gradient FLOPs; speedup is "
        "the removed dispatch overhead only on a FLOP-saturated host",
    )

    # --- micro-block gradient batching on the real model ----------------- #
    E = 4 if quick else 8
    flc_b = flc_c.replace(block_size=E)
    blk_cold = _best(lambda: once(flc_b, task_c, "scan", T_c), 1)
    blk_warm = _best(lambda: once(flc_b, task_c, "scan", T_c), 2)
    results.append(_row(
        f"lm_smoke_gen_async(n={n},C={C},T={T_c},L=2,d=256,b=8,s=64)",
        block_size=E, cold_s=blk_cold, warm_s=blk_warm,
        speedup=round(scan_c / blk_warm, 2),
        note="blocked scan vs per-event scan (speedup vs the E=1 warm row): "
        "E transformer gradients at E distinct dispatch snapshots batched "
        "into one vmapped call.  On this host the batching LOSES: vmapping "
        "the gradient over E different parameter vectors multiplies the "
        "weight working set by E, and XLA:CPU lowers the weight-batched "
        "GEMMs to loops, so a narrow cache-bound CPU pays ~linear-in-E "
        "per-event cost.  The mechanism targets accelerators, where the "
        "per-step latency the batching removes dominates (the "
        "classification rows in BENCH_block.json, whose single shared "
        "weight matrix keeps the working set flat, do win on CPU)",
    ))
    print(f"lm_smoke blocked E={E}: {blk_warm:7.3f}s  "
          f"x{scan_c / blk_warm:.2f} vs per-event scan")

    return {
        "bench": "lm",
        "quick": quick,
        "devices": _devices(),
        "dtype": DTYPE,
        "cpu_count": os.cpu_count(),
        "backend": jax.default_backend(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "results": results,
        "note": "both engines consume identical LMTask shards (same jitted "
        "gradient, same minibatches -> scan == python to float tolerance); "
        "python_s is the per-event Python LM loop, warm_s the compiled "
        "engine after its one-off trace+compile (cold_s).  Recorded on "
        "this host honestly: a narrow CPU container is FLOP-saturated on "
        "the smoke config, so its speedup reflects dispatch-overhead "
        "removal only, and micro-block batching loses outright (see the "
        "block_size row note) — the dispatch-bound tiny row is where the "
        ">=5x claim lives",
    }


# --------------------------------------------------------------------- #
# stream benchmark: fused on-device event generation vs the host-export
# path, at scenario-matrix scale -> BENCH_stream.json
# --------------------------------------------------------------------- #
def run_stream(quick: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core import BoundConstants, SimConfig, export_stream
    from repro.core.sampling import bound_for_p, optimize_general
    from repro.core.stream_device import stats_stream_fn

    n, C, T = (64, 16, 500) if quick else (256, 64, 5000)
    D = jax.device_count()
    data = FederatedClassification(n_clients=n, seed=0)
    mu = make_client_speeds(n, 0.5, 10.0, seed=0)
    p = np.full(n, 1.0 / n)
    results = []

    def record(name, host_s, dev_s, cold_s=None, note=""):
        entry = _row(
            name, host_s=host_s, device_s=dev_s,
            speedup=round(host_s / dev_s, 2),
            **({"cold_s": cold_s} if cold_s is not None else {}),
            note=note,
        )
        results.append(entry)
        print(f"{name:48s} host {host_s:7.3f}s -> device {dev_s:7.3f}s  "
              f"x{entry['speedup']:.2f}")

    # --- stream layer: event generation + running statistics ----------- #
    # both sides produce the queueing observables (delays, occupancy,
    # completions): host = export_stream with delay recording, device = the
    # fused stats scan the adaptive control loop consumes
    gen = stats_stream_fn(n, C, T)
    for B in ((4,) if quick else (16, 64)):
        def host_once():
            for b in range(B):
                export_stream(
                    SimConfig(mu=mu, p=p, C=C, T=T, seed=b, record_delays=True)
                )

        keys = jax.random.split(jax.random.PRNGKey(0), B)
        mus = jnp.asarray(np.broadcast_to(mu, (B, n)).copy(), jnp.float32)
        ps = jnp.full((B, n), 1.0 / n, jnp.float32)
        if D > 1 and B % D == 0:
            f = jax.pmap(jax.vmap(gen))
            dev_args = tuple(a.reshape((D, B // D) + a.shape[1:])
                             for a in (keys, mus, ps))
        else:
            f = jax.jit(jax.vmap(gen))
            dev_args = (keys, mus, ps)
        cold_s = _best(lambda: jax.block_until_ready(f(*dev_args)), 1)
        host_s = _best(host_once, 3)
        dev_s = _best(lambda: jax.block_until_ready(f(*dev_args)), 3)
        record(
            f"stream_matrix(B={B},n={n},C={C},T={T})", host_s, dev_s,
            cold_s=cold_s,
            note=f"host: serial export_stream(record_delays) per scenario; "
            f"device: fused stats scan sharded over {D} device(s) — both "
            f"produce per-node delay/occupancy statistics",
        )

    # --- run_matrix end-to-end: zero host pre-simulation ---------------- #
    seeds = (0, 1) if quick else (0, 1, 2, 3)
    flc = FLConfig(n_clients=n, concurrency=C, server_steps=T,
                   sampling="uniform", speed_ratio=10.0, seed=0)
    eval_every = max(T // 4, 10)
    kwargs = dict(seeds=seeds, policies=("uniform", "optimal"),
                  speed_ratios=(1.0, 10.0), eval_every=eval_every, data=data)
    n_scen = len(seeds) * 2 * 2
    dev_cold = _best(lambda: run_matrix(flc, stream="device", **kwargs), 1)
    dev_s = _best(lambda: run_matrix(flc, stream="device", **kwargs), 2)
    run_matrix(flc, stream="host", **kwargs)     # compile
    host_s = _best(lambda: run_matrix(flc, stream="host", **kwargs), 2)
    record(
        f"run_matrix({n_scen}_scenarios,T={T})", host_s, dev_s,
        cold_s=dev_cold,
        note="end-to-end training matrix (warm); both paths share the "
        "gradient FLOPs — the device path removes the serial host "
        f"pre-simulation and shards scenarios over {D} device(s)",
    )

    # --- adaptive sampling: device-only capability ---------------------- #
    flc_a = flc.replace(stream="device", adaptive=True, refresh_every=max(T // 20, 10),
                        speed_ratio=10.0)
    m = run_matrix(flc_a, seeds=seeds, policies=("uniform",),
                   speed_ratios=(10.0,), eval_every=T, data=data)
    ad_s = _best(lambda: run_matrix(flc_a, seeds=seeds, policies=("uniform",),
                                    speed_ratios=(10.0,), eval_every=T,
                                    data=data), 1)
    mu_h = make_client_speeds(n, 0.5, 10.0, seed=0)
    k = BoundConstants(C=C, T=T)
    p_fin = m.extras["p_final"][:, 0, 0].mean(0)
    p_fin = np.maximum(p_fin, 1e-12) / p_fin.sum()
    b_ad = bound_for_p(mu_h, p_fin, k)[0]
    opt = optimize_general(mu_h, k, iters=500)
    results.append(_row(
        f"run_matrix_adaptive({len(seeds)}_scenarios,T={T})",
        device_s=ad_s,
        bound_adaptive=round(float(b_ad), 4),
        bound_static_opt=round(float(opt.bound), 4),
        bound_uniform=round(float(opt.uniform_bound), 4),
        gap_vs_static_opt=round(float(b_ad / opt.bound - 1.0), 4),
        note="adaptive-from-uniform control loop (host path cannot run "
        "this); gap_vs_static_opt is the bound excess over optimize_general",
    ))
    print(f"adaptive: {ad_s:.2f}s  bound {b_ad:.4f} vs static-opt "
          f"{opt.bound:.4f} (uniform {opt.uniform_bound:.4f})")

    return {
        "bench": "stream",
        "quick": quick,
        "devices": D,
        "dtype": DTYPE,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "results": results,
    }


# --------------------------------------------------------------------- #
# scale benchmark: per-event cost of the sparse O(C) stream across n,
# dense-oracle parity, and the class-collapsed control plane at n=1e6
# -> BENCH_scale.json
# --------------------------------------------------------------------- #
def run_scale(quick: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core import BoundConstants
    from repro.core import stream_device as sd
    from repro.core.sampling import optimize_general

    C = 64
    T = 4000 if quick else 20_000
    ns = (1_000, 10_000) if quick else (1_000, 10_000, 100_000, 1_000_000)
    results = []
    per_event_us = {}

    def two_class_mu(n):
        rng = np.random.default_rng(7)
        return np.where(rng.random(n) < 0.3, 2.5, 1.0)

    for n in ns:
        mu = two_class_mu(n)
        p = np.full(n, 1.0 / n)
        spec, mu_m, p_m = sd.build_class_spec(mu, p)
        spec_dev = spec.device()
        gen = sd.sparse_stats_stream_fn(spec.m, C, T)
        f = jax.jit(lambda key, mu, p: gen(key, mu, p, spec_dev))
        args = (jax.random.PRNGKey(0), jnp.asarray(mu_m, jnp.float32),
                jnp.asarray(p_m, jnp.float32))
        cold_s = _best(lambda: jax.block_until_ready(f(*args)), 1)
        warm_s = _best(lambda: jax.block_until_ready(f(*args)), 3)
        stats, state = jax.block_until_ready(f(*args))
        occ_sum = float(np.asarray(stats.occ_sum, np.float64).sum()) / T
        delay_mean = float(
            sd.kahan_value(stats.delay_sum, stats.delay_sum_c).sum()) / T
        t_final = float(sd.kahan_value(state.t, state.t_c))
        lam_emp = T / t_final
        _, lam_mva = sd.mva_throughput_delays(
            mu_m, p_m, C, counts=np.asarray(spec.counts)
        )
        per_event_us[n] = warm_s / T * 1e6
        results.append(_row(
            f"sparse_stream(n={n},C={C},T={T})",
            cold_s=cold_s, warm_s=warm_s,
            per_event_us=round(per_event_us[n], 3),
            occ_mean_sum=round(occ_sum, 4),
            mean_delay=round(delay_mean, 4),
            lam_empirical=round(lam_emp, 4),
            lam_mva=round(float(lam_mva), 4),
            classes=spec.m,
            note="sparse O(C) slot state + O(log m) class dispatch; "
            "occ_mean_sum ~ C, mean_delay ~ C-1 (Little's law), "
            "lam_empirical ~ class-collapsed MVA throughput",
        ))
        print(f"sparse n={n:>9,}: warm {warm_s:7.3f}s  "
              f"{per_event_us[n]:6.2f}us/event  lam {lam_emp:9.2f} "
              f"(mva {float(lam_mva):9.2f})")

        # dense oracle on the overlapping sizes: same law, O(n) per event
        if n <= 10_000:
            gen_d = sd.stats_stream_fn(n, C, T)
            fd = jax.jit(gen_d)
            args_d = (jax.random.PRNGKey(0), jnp.asarray(mu, jnp.float32),
                      jnp.asarray(p, jnp.float32))
            cold_d = _best(lambda: jax.block_until_ready(fd(*args_d)), 1)
            warm_d = _best(lambda: jax.block_until_ready(fd(*args_d)), 3)
            stats_d = jax.block_until_ready(fd(*args_d))
            occ_d = float(np.asarray(stats_d.occ_sum, np.float64).sum()) / T
            delay_d = float(
                sd.kahan_value(stats_d.delay_sum, stats_d.delay_sum_c).sum()) / T
            results.append(_row(
                f"dense_stream(n={n},C={C},T={T})",
                cold_s=cold_d, warm_s=warm_d,
                per_event_us=round(warm_d / T * 1e6, 3),
                occ_mean_sum=round(occ_d, 4),
                mean_delay=round(delay_d, 4),
                sparse_speedup=round(warm_d / warm_s, 2),
                note="dense (n,C) parity oracle: O(n) race per event — "
                "law-identical observables, cost grows with n",
            ))
            print(f"dense  n={n:>9,}: warm {warm_d:7.3f}s  "
                  f"{warm_d / T * 1e6:6.2f}us/event  "
                  f"(sparse x{warm_d / warm_s:.2f})")

    n_lo, n_hi = min(ns), max(ns)
    flat_ratio = per_event_us[n_hi] / per_event_us[n_lo]
    results.append(_row(
        f"flatness(n={n_lo}->{n_hi})",
        per_event_us_small=round(per_event_us[n_lo], 3),
        per_event_us_large=round(per_event_us[n_hi], 3),
        ratio=round(flat_ratio, 3),
        within_2x=bool(flat_ratio <= 2.0),
        note="acceptance: per-event wall-clock at the largest n within "
        "2x of the smallest on the sparse stream",
    ))
    print(f"flatness {n_lo:,} -> {n_hi:,}: x{flat_ratio:.3f} "
          f"(within 2x: {flat_ratio <= 2.0})")

    # class-collapsed control plane at the largest n: the full
    # optimize-general loop that the dense path cannot even allocate
    n_ctrl = n_hi
    mu_c = two_class_mu(n_ctrl)
    k = BoundConstants(C=C, T=T)
    t0 = time.perf_counter()
    res = optimize_general(mu_c, k, iters=40)
    ctrl_s = time.perf_counter() - t0
    results.append(_row(
        f"optimize_general(n={n_ctrl},C={C})",
        warm_s=ctrl_s,
        bound=round(float(res.bound), 6),
        uniform_bound=round(float(res.uniform_bound), 6),
        relative_improvement=round(float(res.relative_improvement), 4),
        note="class-collapsed mirror descent (O(m*C) per step, 40 iters); "
        "the dense analytic path is O(n*C) per step with an (n,C) "
        "occupancy matrix",
    ))
    print(f"optimize_general n={n_ctrl:,}: {ctrl_s:.2f}s  "
          f"improv {res.relative_improvement:.3f}")

    return {
        "bench": "scale",
        "quick": quick,
        "devices": _devices(),
        "dtype": DTYPE,
        "cpu_count": os.cpu_count(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "results": results,
        "note": "sparse stream state is O(C) and dispatch O(log m); the "
        "dense rows are the parity oracle (law-identical observables). "
        "Law parity is locked by tests/test_scale.py; this file records "
        "the per-event flatness acceptance",
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small sizes (CI smoke)")
    ap.add_argument("--stream", action="store_true",
                    help="benchmark the fused device stream vs the host-export "
                    "path (writes BENCH_stream.json by default)")
    ap.add_argument("--block", action="store_true",
                    help="benchmark the blocked (event micro-batched) engine "
                    "vs the per-event scan (writes BENCH_block.json)")
    ap.add_argument("--faults", action="store_true",
                    help="benchmark fault-tolerance costs: guard/checkpoint "
                    "overhead on fault-free runs, fault-injected runs, and "
                    "the adaptive-vs-static gap under churn (writes "
                    "BENCH_faults.json)")
    ap.add_argument("--scale", action="store_true",
                    help="benchmark the sparse O(C) stream + class-collapsed "
                    "control plane across n up to 1e6: per-event cost must "
                    "stay flat in n (writes BENCH_scale.json)")
    ap.add_argument("--lm", action="store_true",
                    help="benchmark the real-model path: compiled scan / "
                    "blocked engine vs the per-event Python LM loop on "
                    "identical LMTask shards (writes BENCH_lm.json)")
    ap.add_argument("--serve", action="store_true",
                    help="benchmark the serving plane: merged open-queue "
                    "overhead vs the no-serving baseline, 2x-overload "
                    "shedding, and staleness SLO (writes BENCH_serve.json)")
    ap.add_argument("--scenarios", action="store_true",
                    help="sweep the scenario registry (phase-type service + "
                    "modulated availability): measured gap between the "
                    "paper's exponential p* and the per-scenario simulated "
                    "optimum, plus the adaptive loop (writes "
                    "BENCH_scenarios.json)")
    ap.add_argument("--out", default=None, help="output JSON path")
    args = ap.parse_args()
    if sum((args.stream, args.block, args.faults, args.scale, args.lm,
            args.serve, args.scenarios)) > 1:
        ap.error("--stream, --block, --faults, --scale, --lm, --serve and "
                 "--scenarios are mutually exclusive")
    name = ("BENCH_stream.json" if args.stream
            else "BENCH_block.json" if args.block
            else "BENCH_faults.json" if args.faults
            else "BENCH_scale.json" if args.scale
            else "BENCH_lm.json" if args.lm
            else "BENCH_serve.json" if args.serve
            else "BENCH_scenarios.json" if args.scenarios
            else "BENCH_engine.json")
    out = args.out or str(Path(__file__).resolve().parent.parent / name)
    payload = (run_stream(args.quick) if args.stream
               else run_block(args.quick) if args.block
               else run_faults(args.quick) if args.faults
               else run_scale(args.quick) if args.scale
               else run_lm_bench(args.quick) if args.lm
               else run_serve_bench(args.quick) if args.serve
               else run_scenarios(args.quick) if args.scenarios
               else run(args.quick))
    Path(out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
