"""Training-engine benchmark: Python per-event loop vs compiled scan engine.

Runs the §5 federated experiment (MLP classifier, heterogeneous client
speeds) through both server loops at identical configuration and event law,
and writes ``BENCH_engine.json``.  The headline case is n=256 clients, C=64
in flight, T=5000 CS steps on CPU: the Python loop pays a host<->device round
trip per CS step (batch generation, dispatch of the jitted grad, per-leaf
tree updates), the scan engine replays the pre-simulated event stream as one
XLA program.  The speedup is therefore largest in the dispatch-bound regime
(small per-step gradient work — typical FL client models); a compute-bound
case (batch 128) is included for calibration.

    PYTHONPATH=src python benchmarks/engine.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import gc
import json
import sys
import time
from dataclasses import replace
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs.base import FLConfig  # noqa: E402
from repro.core import ServerConfig, run_fedbuff, run_generalized_async_sgd  # noqa: E402
from repro.data.pipeline import FederatedClassification, make_client_speeds  # noqa: E402
from repro.fl.engine import DeviceFLClients, FLClients, MLPClassifier, run_matrix  # noqa: E402


def _best(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        gc.collect()
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _compare(data, mu, n, C, T, hidden, batch, method="gen_async", Z=10,
             reps=4):
    model = MLPClassifier(data.dim, data.num_classes, hidden=hidden, seed=0)
    host = FLClients(data, model, batch_size=batch)
    dev = DeviceFLClients(data, model, batch_size=batch, shard_size=512, seed=0)
    cfg = ServerConfig(n=n, C=C, T=T, eta=0.05, mu=mu, seed=0,
                       weighting="importance" if method == "gen_async" else "plain")
    cfg_scan = replace(cfg, engine="scan")

    def once(clients, c):
        if method == "fedbuff":
            run_fedbuff(model.init_params, clients, c, Z=Z)
        else:
            run_generalized_async_sgd(model.init_params, clients, c)

    cold_s = _best(lambda: once(dev, cfg_scan), 1)       # includes compile
    # interleave reps so machine-load noise hits both engines alike
    py_s = scan_s = float("inf")
    for _ in range(reps):
        py_s = min(py_s, _best(lambda: once(host, cfg), 1))
        scan_s = min(scan_s, _best(lambda: once(dev, cfg_scan), 1))
    return py_s, cold_s, scan_s


def run(quick: bool) -> dict:
    n, C, T = (32, 8, 500) if quick else (256, 64, 5000)
    data = FederatedClassification(n_clients=n, seed=0)
    mu = make_client_speeds(n, 0.5, 10.0, seed=0)
    results = []

    def record(name, python_s, scan_s, note=""):
        entry = {
            "name": name,
            "python_s": round(python_s, 3),
            "scan_s": round(scan_s, 3),
            "speedup": round(python_s / scan_s, 2),
            "note": note,
        }
        results.append(entry)
        print(f"{name:52s} {python_s:8.2f} s -> {scan_s:7.3f} s   x{entry['speedup']:.1f}")

    # --- headline: dispatch-bound FL config ------------------------------ #
    py_s, cold_s, scan_s = _compare(data, mu, n, C, T, hidden=32, batch=16)
    record(
        f"fl_mlp_gen_async(n={n},C={C},T={T},h=32,b=16)", py_s, scan_s,
        note=f"warm scan (incl. host stream export); cold run with compile "
        f"was {cold_s:.2f}s",
    )

    # --- fedbuff through both engines ------------------------------------ #
    py_fb, _, sc_fb = _compare(data, mu, n, C, T, hidden=32, batch=16,
                               method="fedbuff")
    record(f"fl_mlp_fedbuff(n={n},C={C},T={T},h=32,b=16)", py_fb, sc_fb)

    # --- compute-bound calibration point --------------------------------- #
    py_c, _, sc_c = _compare(data, mu, n, C, T, hidden=128, batch=128,
                             reps=2)
    record(
        f"fl_mlp_gen_async(n={n},C={C},T={T},h=128,b=128)", py_c, sc_c,
        note="compute-bound: both engines dominated by the same gradient "
        "FLOPs; speedup here is pure dispatch overhead removal",
    )

    # --- scenario matrix: amortization across vmapped streams ------------ #
    seeds = (0, 1) if quick else (0, 1, 2, 3)
    flc = FLConfig(n_clients=n, concurrency=C, server_steps=T // 2,
                   sampling="uniform", speed_ratio=10.0, seed=0)
    mat_s = _best(lambda: run_matrix(
        flc, seeds=seeds, policies=("uniform", "optimal"),
        speed_ratios=(1.0, 10.0), eval_every=max(T // 20, 10), data=data,
    ), 1)
    n_scen = len(seeds) * 2 * 2
    results.append({
        "name": f"run_matrix({n_scen}_scenarios,T={T // 2})",
        "total_s": round(mat_s, 3),
        "per_scenario_s": round(mat_s / n_scen, 3),
        "note": "seeds x {uniform, optimal} x heterogeneity in ONE compiled "
        "call (incl. compile + host stream exports)",
    })
    print(f"run_matrix: {n_scen} scenarios in {mat_s:.2f}s "
          f"({mat_s / n_scen:.3f}s/scenario)")

    return {
        "bench": "engine",
        "quick": quick,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "results": results,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small sizes (CI smoke)")
    ap.add_argument(
        "--out",
        default=str(Path(__file__).resolve().parent.parent / "BENCH_engine.json"),
        help="output JSON path",
    )
    args = ap.parse_args()
    payload = run(args.quick)
    Path(args.out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
