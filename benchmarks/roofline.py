"""§Roofline reader: aggregates dry-run artifacts into the roofline table.

Reads artifacts/dryrun/*.json (produced by repro.launch.dryrun) and emits
one row per (arch × shape × mesh × rules): the three terms, the dominant
bottleneck, and MODEL_FLOPS/HLO_FLOPS.  Also usable standalone:

    PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun] [--md]
"""
from __future__ import annotations

import glob
import json
import os

from .common import row


def load_records(d: str = "artifacts/dryrun") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def bench_roofline(d: str = "artifacts/dryrun"):
    out = []
    recs = [r for r in load_records(d) if r.get("ok")]
    for r in recs:
        t = r["roofline"]
        u = r.get("useful_flops_ratio")
        out.append(
            row(
                f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}_{r['rules']}",
                r.get("compile_s", 0.0) * 1e6,
                f"compute={t['compute_s']:.4f}s;memory={t['memory_s']:.4f}s;"
                f"collective={t['collective_s']:.4f}s;dom={r['dominant']};"
                f"useful={u if u is None else round(u, 3)}",
            )
        )
    n_ok = len(recs)
    out.append(row("roofline_pairs_ok", 0.0, n_ok))
    return out


def markdown_table(d: str = "artifacts/dryrun", mesh: str = "16x16", rules: str = "default") -> str:
    recs = [r for r in load_records(d)
            if r.get("ok") and r["mesh"] == mesh and r["rules"] == rules]
    recs.sort(key=lambda r: (r["arch"], r["shape"]))
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant | MODEL/HLO FLOPs | per-dev args (GiB) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        t = r["roofline"]
        u = r.get("useful_flops_ratio")
        args_gib = r["memory"].get("argument_bytes", 0) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | **{r['dominant']}** "
            f"| {u if u is None else f'{u:.3f}'} | {args_gib:.2f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    if args.md:
        print(markdown_table(args.dir, mesh=args.mesh))
    else:
        for name, us, derived in bench_roofline(args.dir):
            print(f"{name},{us:.1f},{derived}")
