"""One benchmark per paper table/figure.  Each returns CSV rows
(name, us_per_call, derived)."""
from __future__ import annotations

import numpy as np

from repro.core import (
    BoundConstants,
    JacksonNetwork,
    SimConfig,
    asyncsgd_bound,
    asyncsgd_eta_max,
    fedbuff_bound,
    fedbuff_eta_max,
    generalized_bound,
    optimal_eta,
    optimize_two_cluster,
    simulate,
    three_cluster_delay_bounds,
    two_cluster_delay_bounds,
)
from repro.core.theory import BoundConstants as BC

from .common import row, timeit


def bench_fig1_transient():
    """Fig. 1: m_{i,k} becomes stationary after ~O(n) steps."""
    out = []
    for n in (10, 50):
        mu = np.array([10.0] * 5 + [1.0] * (n - 5))
        p = np.full(n, 1 / n)
        us = timeit(
            lambda: simulate(SimConfig(mu=mu, p=p, C=n, T=500, seed=0, record_delays=True)),
            iters=3,
        )
        res = simulate(SimConfig(mu=mu, p=p, C=n, T=5000, seed=0, record_delays=True))
        d = np.asarray(res.delays[0], float)
        half = len(d) // 2
        gap = abs(np.mean(d[:half]) - np.mean(d[half:])) / max(np.mean(d), 1e-9)
        out.append(row(f"fig1_transient_n{n}", us, f"stationarity_gap={gap:.3f}"))
    return out


def _min_over_eta(bound_fn, eta_cap: float) -> float:
    """min over eta in (0, cap] by golden section on log-eta."""
    if eta_cap <= 0:
        return float("inf")
    etas = np.geomspace(eta_cap * 1e-4, eta_cap, 200)
    return float(min(bound_fn(e) for e in etas))


def _gen_bound_indicator(mu, p, k):
    """Theorem-1 bound with the 1{K_{k+1}=i} indicator kept in m_{i,k}
    (stationary value p_i * m̂_i).  At uniform p this nearly coincides with
    the AsyncSGD bound — the two are the same algorithm there — making the
    cross-method comparison apples-to-apples."""
    net = JacksonNetwork(mu=mu, p=p, C=k.C)
    m = p * net.expected_delays()
    return generalized_bound(optimal_eta(p, m, k), p, m, k)


def _gen_optimal_indicator(mu_f, mu_s, n, n_f, k, grid=60):
    from repro.core.sampling import two_cluster_p_vector

    mu = np.array([mu_f] * n_f + [mu_s] * (n - n_f))
    best = (np.full(n, 1 / n), np.inf)
    for pf in np.geomspace(1e-4 / n, (1 - 1e-6) / n_f, grid):
        p = two_cluster_p_vector(n, n_f, pf)
        b = _gen_bound_indicator(mu, p, k)
        if b < best[1]:
            best = (p, b)
    return best


def _baseline_taus(mu, p, C):
    """tau_max / tau_c / tau_sum estimates for the baselines' bounds.

    Deterministic service (paper's comparison setting): tau_max = C x slow
    work time, converted to server steps via the network throughput.
    tau_sum_i ~ p_i T m_i (node i completes ~p_i T tasks, each delayed m_i).
    """
    net = JacksonNetwork(mu=mu, p=p, C=C)
    lam = net.throughput()
    tau_max = C * (1.0 / mu.min()) * lam
    m = net.expected_delays()
    return tau_max, float(C), m


def bench_table1_bounds():
    """Table 1: the three methods' bounds, each at ITS OWN optimal eta."""
    k = BoundConstants(A=100, L=1, B=20, C=10, T=10_000)
    n, n_f = 100, 90
    mu = np.array([8.0] * n_f + [1.0] * (n - n_f))
    p = np.full(n, 1 / n)

    def compute():
        _, g_opt = _gen_optimal_indicator(8.0, 1.0, n, n_f, k)
        g_uni = _gen_bound_indicator(mu, p, k)
        tau_max, tau_c, m = _baseline_taus(mu, p, k.C)
        tau_sum = p * k.T * m
        fb = _min_over_eta(lambda e: fedbuff_bound(e, tau_max, n, k),
                           fedbuff_eta_max(tau_max, k))
        asg = _min_over_eta(lambda e: asyncsgd_bound(e, tau_c, tau_sum, k),
                            asyncsgd_eta_max(tau_c, tau_max, k))
        return g_opt, g_uni, fb, asg, tau_max

    us = timeit(compute, iters=1, warmup=0)
    g_opt, g_uni, fb, asg, tau_max = compute()
    fb_exp = fedbuff_bound(0.01, float("inf"), n, k)
    return [
        row("table1_generalized_optimal_p", us, f"bound={g_opt:.3f}"),
        row("table1_generalized_uniform_p", us, f"bound={g_uni:.3f}"),
        row("table1_fedbuff_det", us, f"bound={fb:.3f};tau_max={tau_max:.0f}"),
        row("table1_asyncsgd_det", us, f"bound={asg:.3f}"),
        row("table1_fedbuff_exp_service", us, f"bound={fb_exp}"),
        row("table1_asyncsgd_exp_service", us,
            f"bound={asyncsgd_bound(0.01, float('inf'), np.full(n, np.inf), k)}"),
    ]


def bench_fig2_fig3_optimal_p():
    """Figs. 2-3: optimal p and improvement vs mu_f, for several C."""
    out = []
    n, n_f = 100, 90
    for C in (10, 50):
        k = BoundConstants(A=100, L=1, B=20, C=C, T=10_000)
        for mu_f in (2.0, 4.0, 8.0, 16.0):
            us = timeit(lambda: optimize_two_cluster(mu_f, 1.0, n, n_f, k, grid=25), iters=1, warmup=0)
            res = optimize_two_cluster(mu_f, 1.0, n, n_f, k)
            out.append(
                row(
                    f"fig2_3_C{C}_muf{int(mu_f)}",
                    us,
                    f"p_fast={res.p[0]:.2e};improvement={100*res.relative_improvement:.1f}%",
                )
            )
    return out


def bench_fig4_vs_baselines():
    """Fig. 4: improvement of GenAsyncSGD (optimal p) over the baselines'
    bounds, each baseline at its own optimal eta (deterministic service)."""
    out = []
    n, n_f = 100, 90
    k = BoundConstants(A=100, L=1, B=20, C=10, T=10_000)
    for mu_f in (2.0, 8.0, 16.0):
        mu = np.array([mu_f] * n_f + [1.0] * (n - n_f))
        p = np.full(n, 1 / n)
        _, g_opt = _gen_optimal_indicator(mu_f, 1.0, n, n_f, k)
        tau_max, tau_c, m = _baseline_taus(mu, p, k.C)
        tau_sum = p * k.T * m
        fb = _min_over_eta(lambda e: fedbuff_bound(e, tau_max, n, k),
                           fedbuff_eta_max(tau_max, k))
        asg = _min_over_eta(lambda e: asyncsgd_bound(e, tau_c, tau_sum, k),
                            asyncsgd_eta_max(tau_c, tau_max, k))
        imp_fb = 100 * (fb - g_opt) / fb
        imp_as = 100 * (asg - g_opt) / asg
        out.append(row(f"fig4_muf{int(mu_f)}", 0.0,
                       f"vs_fedbuff={imp_fb:.1f}%;vs_asyncsgd={imp_as:.1f}%"))
    return out


def bench_fig5_delays():
    """Fig. 5 / App. F: saturated 2-cluster delays — sim vs closed form."""
    n, n_f, C = 10, 5, 1000
    mu = np.array([1.2] * n_f + [1.0] * (n - n_f))
    p = np.full(n, 1 / n)

    us = timeit(
        lambda: simulate(SimConfig(mu=mu, p=p, C=C, T=50_000, seed=0, record_delays=True)),
        iters=1, warmup=0,
    )
    res = simulate(SimConfig(mu=mu, p=p, C=C, T=400_000, seed=0, record_delays=True))
    d = res.mean_delay_per_node()
    bf, bs = two_cluster_delay_bounds(n, n_f, 1.2, 1.0, C)
    est = JacksonNetwork(mu=mu, p=p, C=C).expected_delays()
    return [
        row("fig5_fast_sim_vs_theory", us,
            f"sim={np.mean(d[:n_f]):.0f};jackson={est[0]:.0f};closed_bound={bf:.0f};paper=59"),
        row("fig5_slow_sim_vs_theory", us,
            f"sim={np.mean(d[n_f:]):.0f};jackson={est[-1]:.0f};closed_bound={bs:.0f};paper=1938"),
    ]


def bench_fig11_optimal_delays():
    """App. F.2 (Fig. 11): delays under the optimal sampling scheme."""
    n, n_f, C = 10, 5, 1000
    mu = np.array([1.2] * n_f + [1.0] * (n - n_f))
    p_f = 7.5e-3
    p = np.array([p_f] * n_f + [2 / n - p_f] * (n - n_f))
    uni = simulate(SimConfig(mu=mu, p=np.full(n, 1 / n), C=C, T=400_000, seed=0,
                             record_delays=True))
    opt = simulate(SimConfig(mu=mu, p=p, C=C, T=400_000, seed=0, record_delays=True))
    du, do = uni.mean_delay_per_node(), opt.mean_delay_per_node()
    return [
        row("fig11_delay_reduction_fast", 0.0,
            f"ratio={np.mean(du[:n_f])/np.mean(do[:n_f]):.1f}x;paper=10x"),
        row("fig11_delay_reduction_slow", 0.0,
            f"ratio={np.mean(du[n_f:])/np.mean(do[n_f:]):.1f}x;paper=2x"),
    ]


def bench_fig12_3cluster():
    """App. G (Fig. 12): 3-cluster saturated delays — sim vs closed forms."""
    n, C = 9, 1000
    mu = np.array([10.0] * 3 + [1.2] * 3 + [1.0] * 3)
    p = np.full(n, 1 / n)
    res = simulate(SimConfig(mu=mu, p=p, C=C, T=400_000, seed=0, record_delays=True))
    d = res.mean_delay_per_node()
    busy_frac = res.queue_len_tw[:3].sum() / res.t[-1] / 3
    mf, mm, ms = three_cluster_delay_bounds(9, 3, 6, 10.0, 1.2, 1.0, C,
                                            p_fast_busy=min(busy_frac, 1.0))
    return [
        row("fig12_fast", 0.0, f"sim={np.mean(d[:3]):.1f};theory<={mf:.1f};paper~1"),
        row("fig12_medium", 0.0, f"sim={np.mean(d[3:6]):.0f};theory<={mm:.0f};paper~55"),
        row("fig12_slow", 0.0, f"sim={np.mean(d[6:]):.0f};theory<={ms:.0f};paper~2935"),
    ]
