#!/usr/bin/env python3
"""Docs reference checker: every `path/to/file.py:symbol` reference and
relative markdown link in docs/ and README.md must resolve.

Conventions checked
-------------------
* ```path/file.py:symbol```  (backticked, repo-relative) — the file must
  exist and define ``symbol``: for dotted symbols (``Class.method``) the
  first component must appear as a ``def``/``class`` or a module-level
  assignment, and each later component must appear as a ``def`` or an
  attribute assignment somewhere in the file.
* ``[text](relative/path)`` — the target must exist (http(s)/mailto links
  are not fetched).
* code fences must be balanced (the cheapest markdown-lint signal that a
  doc was truncated or mis-pasted).

Run from anywhere:  ``python tools/check_docs.py``  (exit 1 on any error;
also exercised by tests/test_docs.py so tier-1 catches dead references).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

SYMREF = re.compile(r"`([A-Za-z0-9_\-./]+\.py):([A-Za-z_][A-Za-z0-9_.]*)`")
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def doc_files(root: Path = ROOT) -> list[Path]:
    return sorted((root / "docs").glob("*.md")) + [root / "README.md"]


def _component_defined(text: str, name: str, first: bool) -> bool:
    esc = re.escape(name)
    pats = [rf"^\s*(?:async\s+)?def\s+{esc}\b", rf"^\s*class\s+{esc}\b"]
    if first:
        pats.append(rf"^{esc}\s*[:=]")          # module-level constant
    else:
        pats.append(rf"^\s*(?:self\.)?{esc}\s*[:=]")  # field / attribute
    return any(re.search(p, text, re.M) for p in pats)


def symbol_defined(path: Path, symbol: str) -> bool:
    text = path.read_text()
    parts = symbol.split(".")
    return all(
        _component_defined(text, part, first=(i == 0))
        for i, part in enumerate(parts)
    )


def check_file(md: Path, root: Path = ROOT) -> list[str]:
    errors: list[str] = []
    text = md.read_text()
    if text.count("```") % 2:
        errors.append(f"{md.name}: unbalanced code fences")
    for m in SYMREF.finditer(text):
        rel, sym = m.groups()
        target = root / rel
        if not target.exists():
            errors.append(f"{md.name}: missing file {rel}")
        elif not symbol_defined(target, sym):
            errors.append(f"{md.name}: {rel} does not define `{sym}`")
    for m in LINK.finditer(text):
        href = m.group(1)
        if href.startswith(("http://", "https://", "mailto:", "#")):
            continue
        href = href.split("#", 1)[0]
        if not href:
            continue
        target = (md.parent / href).resolve()
        if not target.exists():
            errors.append(f"{md.name}: dead link {m.group(1)}")
    return errors


def main() -> int:
    errors: list[str] = []
    files = doc_files()
    for md in files:
        if not md.exists():
            errors.append(f"missing doc file: {md}")
            continue
        errors.extend(check_file(md))
    if errors:
        print("\n".join(errors))
        print(f"\n{len(errors)} error(s) across {len(files)} file(s)")
        return 1
    n_refs = sum(len(SYMREF.findall(md.read_text())) for md in files)
    print(f"docs OK: {len(files)} files, {n_refs} symbol references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
