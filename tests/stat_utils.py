"""Shared statistical-law testing harness.

Every stochastic assertion in the test suite goes through one of these
helpers so the tolerance policy lives in exactly one place and is
**seed-stable**: tests draw from fixed-seed streams and compare against a
critical value, so a passing test passes forever (no flaky re-rolls) and a
failure means the law itself is off, not the luck of the draw.

Policy
------
* Goodness-of-fit (categorical frequencies): Pearson chi-square against the
  ``ALPHA = 1e-3`` critical value.  With fixed seeds this is a deterministic
  bound; 1e-3 leaves headroom above the ~1-sigma wobble of a 40k-event
  stream while still catching a mis-scaled rate on the first run.
* Time averages of 2-state Markov chains: z-test with the Markov-chain CLT
  variance ``2*pi_on*pi_off/((q_on+q_off)*t)``, bound ``|z| < Z_BOUND = 4``.
* Moments (mean / squared CV of service laws): z-test on the sample mean and
  a delta-method z-test on the sample SCV, same ``Z_BOUND``.
* Continuous laws: one-sample Kolmogorov-Smirnov at ``ALPHA``.
* Little's law in the closed network: every completed task sees ``C - 1``
  other completions on average — a *structural* identity, so the tolerance
  is a plain relative error, not a CLT band.
"""
from __future__ import annotations

import numpy as np

ALPHA = 1e-3     # chi-square / KS significance level
Z_BOUND = 4.0    # |z| bound for CLT-normal checks


# ------------------------------------------------------------------ #
# chi-square goodness of fit
# ------------------------------------------------------------------ #
def chi_square_stat(counts, expected) -> float:
    """Pearson statistic sum (O - E)^2 / E over cells with E > 0."""
    counts = np.asarray(counts, float)
    expected = np.asarray(expected, float)
    keep = expected > 0
    return float(np.sum((counts[keep] - expected[keep]) ** 2 / expected[keep]))


def assert_chi_square(counts, expected, df: int | None = None,
                      alpha: float = ALPHA, label: str = "") -> float:
    """Assert observed ``counts`` match ``expected`` cell counts.

    ``df`` defaults to ``len(expected) - 1`` (all-cells multinomial); pass a
    smaller value when cells were grouped or parameters estimated.
    Returns the statistic so callers can log it.
    """
    from scipy.stats import chi2

    expected = np.asarray(expected, float)
    if df is None:
        df = int(np.sum(expected > 0)) - 1
    stat = chi_square_stat(counts, expected)
    crit = float(chi2.ppf(1 - alpha, df=df))
    assert stat < crit, (
        f"chi-square{' [' + label + ']' if label else ''}: "
        f"stat={stat:.2f} >= crit={crit:.2f} (df={df}, alpha={alpha})"
    )
    return stat


def assert_frequencies(draws, probs, alpha: float = ALPHA,
                       label: str = "") -> float:
    """Categorical draws (array of indices) match probabilities ``probs``."""
    probs = np.asarray(probs, float)
    counts = np.bincount(np.asarray(draws), minlength=len(probs))
    return assert_chi_square(counts, len(draws) * probs, alpha=alpha,
                             label=label)


# ------------------------------------------------------------------ #
# 2-state Markov availability: stationary share z-test
# ------------------------------------------------------------------ #
def assert_onoff_stationary(frac_on, q_off: float, q_on: float,
                            horizon: float, z_bound: float = Z_BOUND):
    """Time-averaged on-fraction(s) match pi_on = q_on/(q_on+q_off).

    ``frac_on`` may be scalar or per-node array; ``horizon`` is the physical
    time the average was taken over.  Uses the Markov-chain CLT variance
    2*pi_on*pi_off / ((q_on+q_off)*horizon).
    """
    frac_on = np.asarray(frac_on, float)
    pi_on = q_on / (q_on + q_off)
    var = 2.0 * pi_on * (1.0 - pi_on) / ((q_on + q_off) * float(horizon))
    z = (frac_on - pi_on) / np.sqrt(var)
    assert np.all(np.abs(z) < z_bound), (
        f"on/off stationarity: frac={frac_on}, pi_on={pi_on:.4f}, z={z}"
    )


# ------------------------------------------------------------------ #
# moment checks (service-law mean and squared CV)
# ------------------------------------------------------------------ #
def assert_mean(samples, mean: float, z_bound: float = Z_BOUND):
    """Sample mean within z_bound standard errors of ``mean``."""
    x = np.asarray(samples, float)
    se = x.std(ddof=1) / np.sqrt(x.size)
    z = (x.mean() - mean) / max(se, 1e-30)
    assert abs(z) < z_bound, f"mean: got {x.mean():.4f}, want {mean:.4f}, z={z:.2f}"


def assert_scv(samples, scv: float, z_bound: float = Z_BOUND):
    """Sample squared coefficient of variation var/mean^2 matches ``scv``.

    Delta-method standard error from the empirical 4th central moment, so
    heavy-tailed laws (hyperexponential) get the wide band they need.
    """
    x = np.asarray(samples, float)
    m, v = x.mean(), x.var(ddof=1)
    got = v / m**2
    c = x - m
    # var(SCV_hat) ~ [m4 - v^2 + 4 v scv (v - m*skew-term)] / (N m^4); keep the
    # dominant m4 - v^2 term plus the mean-uncertainty cross term
    m3, m4 = np.mean(c**3), np.mean(c**4)
    var_scv = (m4 - v**2) / m**4 + 4 * v**2 * v / (m**6) - 4 * v * m3 / (m**5)
    se = np.sqrt(max(var_scv, 1e-30) / x.size)
    z = (got - scv) / max(se, 1e-30)
    assert abs(z) < z_bound, f"SCV: got {got:.3f}, want {scv:.3f}, z={z:.2f}"


def assert_ks(samples, cdf, alpha: float = ALPHA):
    """One-sample Kolmogorov-Smirnov test of ``samples`` against ``cdf``."""
    from scipy.stats import kstest

    res = kstest(np.asarray(samples, float), cdf)
    assert res.pvalue > alpha, (
        f"KS: D={res.statistic:.4f}, p={res.pvalue:.2e} <= alpha={alpha}"
    )


# ------------------------------------------------------------------ #
# Little's law in the closed network
# ------------------------------------------------------------------ #
def assert_little(delay_steps, C: int, rel: float = 0.02):
    """Closed-network Little's law: mean step-counted delay == C - 1.

    Each completed task saw, on average, exactly the other ``C - 1``
    in-flight completions; this is structural (population is pinned at C),
    so the tolerance is a plain relative error.
    """
    got = float(np.mean(np.asarray(delay_steps, float)))
    want = float(C - 1)
    assert abs(got - want) <= rel * max(want, 1.0), (
        f"Little: mean delay {got:.3f} vs C-1 = {want} (rel {rel})"
    )


def assert_occupancy_conserved(queue_len_sum, C: int, T: int):
    """Event-sampled total occupancy of the closed network is exactly C*T."""
    total = int(np.sum(np.asarray(queue_len_sum)))
    assert total == C * T, f"occupancy sum {total} != C*T = {C * T}"
