"""Generalized AsyncSGD server: unbiasedness, Lemma-9 invariant, convergence."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    BoundConstants,
    ServerConfig,
    eta_max,
    generalized_bound,
    optimal_eta,
    run_fedavg,
    run_fedbuff,
    run_generalized_async_sgd,
)


class Quadratic:
    """Clients hold quadratics f_i(w) = 0.5 ||w - c_i||^2; f* at mean(c_i)."""

    def __init__(self, n, d=4, noise=0.0, seed=0):
        rng = np.random.default_rng(seed)
        self.c = rng.normal(size=(n, d))
        self.noise = noise
        self.rng = np.random.default_rng(seed + 1)
        self.d = d

    def grad(self, i, w, k):
        g = w - self.c[i]
        if self.noise:
            g = g + self.noise * self.rng.normal(size=w.shape)
        return g

    def optimum(self):
        return self.c.mean(axis=0)

    def loss(self, w):
        return 0.5 * np.mean(np.sum((w[None] - self.c) ** 2, axis=1))


class TestAlgorithmOne:
    def test_converges_to_optimum_uniform(self):
        n = 8
        prob = Quadratic(n)
        # constant-step async SGD has a noise floor ~ eta*G (client drift);
        # average the tail iterates to remove it
        cfg = ServerConfig(n=n, C=4, T=6000, eta=0.02, seed=0)
        w, _ = run_generalized_async_sgd(np.zeros(prob.d), prob, cfg)
        np.testing.assert_allclose(w, prob.optimum(), atol=0.2)

    def test_converges_with_nonuniform_sampling(self):
        """Importance weighting keeps the fixed point unbiased for ANY p.

        Constant-step async SGD fluctuates around the fixed point, so the
        endpoint depends on the event-stream realization; average the tail
        iterates to test the fixed point itself."""
        n = 8
        prob = Quadratic(n, seed=3)
        p = np.array([0.4, 0.2, 0.1, 0.1, 0.05, 0.05, 0.05, 0.05])
        iterates = []
        cfg = ServerConfig(n=n, C=4, T=20_000, eta=0.02, p=p, seed=1, eval_every=100)
        run_generalized_async_sgd(
            np.zeros(prob.d), prob, cfg,
            eval_fn=lambda w: iterates.append(np.array(w)) or 0.0,
        )
        w_tail = np.mean(iterates[len(iterates) // 2 :], axis=0)
        np.testing.assert_allclose(w_tail, prob.optimum(), atol=0.25)

    def test_plain_weighting_biased_under_nonuniform(self):
        """Without the 1/(n p_j) factor, non-uniform sampling shifts the
        fixed point toward over-sampled clients — the bias Alg. 1 removes."""
        n = 4
        prob = Quadratic(n, seed=5)
        p = np.array([0.7, 0.1, 0.1, 0.1])
        cfg = ServerConfig(n=n, C=2, T=12_000, eta=0.02, p=p, seed=2, weighting="plain")
        w, _ = run_generalized_async_sgd(np.zeros(prob.d), prob, cfg)
        biased_target = (p[:, None] * prob.c).sum(axis=0)  # p-weighted mean
        d_unbiased = np.linalg.norm(w - prob.optimum())
        d_biased = np.linalg.norm(w - biased_target)
        assert d_biased < d_unbiased  # sits near the p-weighted mean instead

    def test_virtual_iterate_inflight_cardinality(self):
        """Lemma 9(i): the number of in-flight tasks is constant (= C)."""
        n = 6
        prob = Quadratic(n)
        cfg = ServerConfig(n=n, C=5, T=200, eta=0.1, seed=4, track_virtual=True)
        _, tr = run_generalized_async_sgd(np.zeros(prob.d), prob, cfg)
        assert set(tr.inflight_cardinality) == {5}

    def test_snapshot_semantics(self):
        """Gradients must be evaluated at dispatch-time parameters."""

        class Recorder(Quadratic):
            def __init__(self, *a, **kw):
                super().__init__(*a, **kw)
                self.seen = []

            def grad(self, i, w, k):
                self.seen.append(np.array(w))
                return super().grad(i, w, k)

        n = 4
        prob = Recorder(n)
        cfg = ServerConfig(n=n, C=4, T=50, eta=0.5, seed=0)
        run_generalized_async_sgd(np.zeros(prob.d), prob, cfg)
        # with C=4 in flight and eta large, at least one gradient must have
        # been computed on stale (non-current) parameters
        assert len(prob.seen) == 50

    def test_works_on_pytrees(self):
        n = 4

        class TreeQuad:
            def __init__(self):
                self.c = [np.full(3, i, dtype=float) for i in range(n)]

            def grad(self, i, w, k):
                return {"a": w["a"] - self.c[i], "b": 2.0 * w["b"]}

        prob = TreeQuad()
        w0 = {"a": np.zeros(3), "b": np.ones(2)}
        cfg = ServerConfig(n=n, C=2, T=4000, eta=0.05, seed=0)
        w, _ = run_generalized_async_sgd(w0, prob, cfg)
        np.testing.assert_allclose(w["a"], np.mean(range(n)), atol=0.3)
        np.testing.assert_allclose(w["b"], 0.0, atol=1e-3)


class TestBaselines:
    def test_fedbuff_converges(self):
        n = 8
        prob = Quadratic(n)
        iterates = []
        cfg = ServerConfig(n=n, C=4, T=10_000, eta=0.05, seed=0, eval_every=100)
        run_fedbuff(
            np.zeros(prob.d), prob, cfg, Z=5,
            eval_fn=lambda w: iterates.append(np.array(w)) or 0.0,
        )
        w_tail = np.mean(iterates[len(iterates) // 2 :], axis=0)
        np.testing.assert_allclose(w_tail, prob.optimum(), atol=0.12)

    def test_favano_converges(self):
        from repro.core import run_favano

        n = 8
        prob = Quadratic(n)
        cfg = ServerConfig(n=n, C=4, T=300, eta=0.05, seed=0)
        w, _ = run_favano(np.zeros(prob.d), prob, cfg, period=1.0)
        np.testing.assert_allclose(w, prob.optimum(), atol=0.15)

    def test_fedavg_converges(self):
        n = 8
        prob = Quadratic(n)
        cfg = ServerConfig(n=n, C=4, T=500, eta=0.3, seed=0)
        w, _ = run_fedavg(np.zeros(prob.d), prob, cfg, clients_per_round=8)
        np.testing.assert_allclose(w, prob.optimum(), atol=0.05)  # full-participation averaging is exact


class TestBounds:
    def test_eta_max_positive_and_bounded(self):
        k = BoundConstants()
        p = np.full(10, 0.1)
        m = np.full(10, 5.0)
        e = eta_max(p, m, k)
        assert 0 < e < 1.0

    def test_optimal_eta_minimizes(self):
        k = BoundConstants()
        p = np.full(10, 0.1)
        m = np.full(10, 5.0)
        e = optimal_eta(p, m, k)
        g0 = generalized_bound(e, p, m, k)
        for mult in (0.5, 0.9, 1.1, 2.0):
            e2 = min(e * mult, eta_max(p, m, k))
            assert g0 <= generalized_bound(e2, p, m, k) + 1e-9

    def test_uniform_optimal_as_T_to_inf(self):
        """Paper: with T -> inf the p-dependent terms vanish; uniform wins."""
        k = BoundConstants(T=10**8, C=5)
        m = np.full(6, 3.0)
        u = np.full(6, 1 / 6)
        gu = generalized_bound(optimal_eta(u, m, k), u, m, k)
        rng = np.random.default_rng(0)
        for _ in range(10):
            q = rng.uniform(0.3, 1.0, 6)
            q /= q.sum()
            gq = generalized_bound(optimal_eta(q, m, k), q, m, k)
            assert gu <= gq + 1e-12
