"""Fault injection (churn / crashes / straggler timeouts) + guarded updates.

Covers: `FaultConfig` validation, structural invariants of fault-injected
host streams, availability-chain stationarity (z-test against the CTMC
stationary law), exact python-vs-scan fault parity, conservation (Little) of
the closed network under timeouts, the control-plane dead-node regressions
(`estimate_mu` / `ctrl_refresh`), the divergence/staleness guard on every
engine path, and the adaptive controller's bound gap on the survivor
network under injected faults.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    KIND_COMPLETE,
    KIND_CRASH,
    KIND_FLIP,
    KIND_TIMEOUT,
    BoundConstants,
    ClosedNetworkSim,
    FaultConfig,
    GuardConfig,
    ServerConfig,
    SimConfig,
    ctrl_refresh,
    estimate_mu,
    export_stream,
    make_fused_runner,
    run_generalized_async_sgd,
    step_scales,
)
from repro.core.sampling import bound_for_p, optimize_general


def _leaves(w):
    return np.concatenate(
        [np.asarray(x, np.float64).ravel() for x in jax.tree_util.tree_leaves(w)]
    )


FAULT = FaultConfig(off_rate=0.3, on_rate=1.0, crash_rate=0.1, timeout_rate=0.2)


class _QuadSource:
    """grad = w - target_j, usable from both engines."""

    def __init__(self, n):
        self.targ = np.arange(n, dtype=np.float32)

    def grad(self, j, w, k):
        return {"a": np.asarray(w["a"]) - self.targ[j]}

    def device_grad(self, j, w, k):
        return {"a": w["a"] - jnp.asarray(self.targ)[j]}


# ------------------------------------------------------------------ #
# FaultConfig
# ------------------------------------------------------------------ #
def test_fault_config_validation():
    with pytest.raises(ValueError):
        FaultConfig(crash_rate=-1.0).resolve(4)
    with pytest.raises(ValueError):
        FaultConfig(off_rate=np.nan).resolve(4)
    assert not FaultConfig().enabled
    assert FaultConfig(timeout_rate=0.5).enabled
    # per-node arrays broadcast and hash
    fc = FaultConfig(crash_rate=np.array([0.1, 0.2]))
    off, on, crash, timeout = fc.resolve(2)
    assert crash.tolist() == [0.1, 0.2]
    assert isinstance(hash(fc.cache_key()), int)


def test_fault_requires_exponential_service():
    cfg = SimConfig(mu=np.ones(4), p=np.full(4, 0.25), C=2, T=10,
                    service="det", fault=FAULT)
    with pytest.raises(ValueError):
        ClosedNetworkSim(cfg)


# ------------------------------------------------------------------ #
# host stream invariants under faults
# ------------------------------------------------------------------ #
def test_fault_stream_invariants():
    n, C, T = 6, 3, 2000
    mu = np.linspace(0.5, 2.0, n)
    p = np.full(n, 1 / n)
    stream = export_stream(
        SimConfig(mu=mu, p=p, C=C, T=T, seed=3, fault=FAULT)
    )
    kinds = stream.kind
    assert kinds is not None and kinds.shape == (T,)
    flips = kinds == KIND_FLIP
    # flips touch no task: trash slot, no dispatch
    assert (stream.slot[flips] == C).all()
    assert (stream.K[flips] == -1).all()
    # task movements keep the FIFO/slot bookkeeping exact
    moves = ~flips
    assert (stream.slot[moves] < C).all()
    assert (stream.K[moves] >= 0).all()
    # all four kinds actually occur at these rates
    counts = np.bincount(kinds, minlength=4)
    assert (counts > 0).all()
    # the replay scale masks every non-completion event
    scale = step_scales(stream, 0.1, p, "importance")
    assert (scale[kinds != KIND_COMPLETE] == 0).all()
    assert (scale[kinds == KIND_COMPLETE] > 0).all()
    # event times are non-decreasing across the merged trace
    assert (np.diff(stream.t) >= 0).all()


def test_availability_stationarity():
    """Time-averaged availability matches the 2-state chain's stationary law.

    For the on/off chain with rates (q_off, q_on), pi_on = q_on/(q_on+q_off)
    and the time-average over [0, t] is asymptotically normal with variance
    2*pi_on*pi_off / ((q_on+q_off) * t) (Markov-chain CLT); we assert every
    node's z-score is within 4 sigma.
    """
    q_off, q_on = 0.4, 1.2
    n, C, T = 5, 3, 40_000
    cfg = SimConfig(mu=np.full(n, 1.0), p=np.full(n, 1 / n), C=C, T=T,
                    seed=11, fault=FaultConfig(off_rate=q_off, on_rate=q_on))
    sim = ClosedNetworkSim(cfg)
    sim.run(T)
    assert sim.avail_tw is not None
    from stat_utils import assert_onoff_stationary

    assert_onoff_stationary(sim.avail_tw / sim.now, q_off, q_on, sim.now)


# ------------------------------------------------------------------ #
# python-vs-scan fault parity (the oracle check)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("block_size", [1, 6])
def test_python_scan_fault_parity(block_size):
    n, C, T = 6, 3, 400
    src = _QuadSource(n)
    w0 = {"a": jnp.zeros(5, jnp.float32)}
    guard = GuardConfig(max_grad_norm=50.0, stale_cutoff=60)
    base = dict(n=n, C=C, T=T, eta=0.05, mu=np.linspace(0.5, 2.0, n),
                seed=7, faults=FAULT, guard=guard)
    w_py, tr_py = run_generalized_async_sgd(
        w0, src, ServerConfig(**base, engine="python")
    )
    w_sc, tr_sc = run_generalized_async_sgd(
        w0, src, ServerConfig(**base, engine="scan", block_size=block_size)
    )
    assert np.max(np.abs(_leaves(w_py) - _leaves(w_sc))) < 1e-5
    # same merged event stream => identical kind counts and guard counters
    np.testing.assert_array_equal(
        tr_py.extras["kind_count"], tr_sc.extras["kind_count"]
    )
    assert tr_py.extras["stale_drops"] == tr_sc.extras["stale_drops"]
    assert tr_py.extras["guard_rejects"] == tr_sc.extras["guard_rejects"]


def test_fused_fault_blocked_matches_per_event():
    """Device stream: the blocked window replays fault events exactly."""
    n, C, T = 8, 4, 300
    src = _QuadSource(n)
    w0 = {"a": jnp.zeros(5, jnp.float32)}
    outs = []
    for E in (1, 8):
        runner = make_fused_runner(
            src.device_grad, n, C, T, block_size=E, fault=FAULT,
            guard=GuardConfig(max_grad_norm=50.0, stale_cutoff=60),
        )
        w, _, extras = runner(
            w0, jnp.linspace(0.5, 2.0, n), jnp.full(n, 1 / n),
            jax.random.PRNGKey(2), 0.05,
        )
        outs.append((_leaves(w), np.asarray(extras["kind_count"])))
    assert np.array_equal(outs[0][0], outs[1][0])
    np.testing.assert_array_equal(outs[0][1], outs[1][1])


# ------------------------------------------------------------------ #
# conservation (Little's law variant) under timeouts
# ------------------------------------------------------------------ #
def test_closed_network_conservation_under_timeouts():
    """Crashes/timeouts re-dispatch instantly, so the closed network keeps
    exactly C tasks in flight: the event-sampled total occupancy is C*T and
    the time-averaged total occupancy is C (Little's law with the in-system
    population forced by the closed loop)."""
    n, C, T = 6, 4, 5000
    cfg = SimConfig(mu=np.linspace(0.5, 2.0, n), p=np.full(n, 1 / n), C=C,
                    T=T, seed=5, fault=FAULT)
    sim = ClosedNetworkSim(cfg)
    sim.run(T)
    from stat_utils import assert_occupancy_conserved

    assert_occupancy_conserved(sim.queue_len_sum, C, T)
    # device side: time-averaged occupancy from the fused engine's stats
    src = _QuadSource(n)
    runner = make_fused_runner(
        src.device_grad, n, C, 2000, fault=FAULT
    )
    _, _, extras = runner(
        {"a": jnp.zeros(3, jnp.float32)}, jnp.linspace(0.5, 2.0, n),
        jnp.full(n, 1 / n), jax.random.PRNGKey(0), 0.01,
    )
    assert abs(float(np.sum(extras["occ_time_avg"])) - C) < 1e-3
    # movement accounting: completions + crashes + timeouts + flips = T
    assert int(np.sum(extras["kind_count"])) == 2000


# ------------------------------------------------------------------ #
# control-plane dead-node regressions
# ------------------------------------------------------------------ #
def test_estimate_mu_dead_node_regression():
    comp = jnp.array([10, 0, 5], jnp.int32)
    busy = jnp.array([5.0, 0.0, 2.5], jnp.float32)
    est = estimate_mu(comp, busy)
    assert np.isfinite(np.asarray(est)).all()
    assert float(est[1]) > 0  # dark node floors, never 0 or NaN
    # all-dead window (no completions anywhere) stays finite
    est0 = estimate_mu(jnp.zeros(3, jnp.int32), jnp.zeros(3, jnp.float32))
    assert np.isfinite(np.asarray(est0)).all() and (np.asarray(est0) > 0).all()


def test_ctrl_refresh_dead_node_regression():
    k = BoundConstants(C=4, T=1000)
    p = jnp.full(6, 1 / 6)
    comp = jnp.array([50, 40, 30, 20, 10, 0], jnp.int32)
    busy = jnp.array([10.0, 10.0, 10.0, 10.0, 10.0, 0.0], jnp.float32)
    p2 = np.asarray(ctrl_refresh(p, comp, busy, k))
    assert np.isfinite(p2).all()
    assert abs(p2.sum() - 1.0) < 1e-5
    assert (p2 > 0).all()  # floored, never collapses to 0
    # degenerate: a refresh from an all-zero window is a no-op-ish simplex
    p3 = np.asarray(ctrl_refresh(p, jnp.zeros(6, jnp.int32),
                                 jnp.zeros(6, jnp.float32), k))
    assert np.isfinite(p3).all() and abs(p3.sum() - 1.0) < 1e-5


# ------------------------------------------------------------------ #
# divergence / staleness guard
# ------------------------------------------------------------------ #
class _SpikeSource:
    """Emits a norm-exploding gradient whenever the server step hits the
    spike cadence, and a NaN gradient at one specific step."""

    def __init__(self, n, spike_every=50, nan_step=125):
        self.targ = np.arange(n, dtype=np.float32)
        self.spike_every = spike_every
        self.nan_step = nan_step

    def device_grad(self, j, w, k):
        g = w["a"] - jnp.asarray(self.targ)[j]
        spike = (k % self.spike_every) == (self.spike_every - 1)
        g = jnp.where(spike, g + 1e6, g)
        g = jnp.where(k == self.nan_step, jnp.full_like(g, jnp.nan), g)
        return {"a": g}

    def grad(self, j, w, k):
        g = np.asarray(w["a"]) - self.targ[j]
        if (k % self.spike_every) == (self.spike_every - 1):
            g = g + 1e6
        if k == self.nan_step:
            g = np.full_like(g, np.nan)
        return {"a": g}


@pytest.mark.parametrize("engine,stream,block_size", [
    ("python", "host", 1),
    ("scan", "host", 1),
    ("scan", "host", 6),
    ("scan", "device", 1),
    ("scan", "device", 8),
])
def test_guard_rejects_divergent_updates(engine, stream, block_size):
    n, C, T = 6, 3, 300
    src = _SpikeSource(n)
    w0 = {"a": jnp.zeros(5, jnp.float32)}
    cfg = ServerConfig(
        n=n, C=C, T=T, eta=0.05, mu=np.linspace(0.5, 2.0, n), seed=7,
        engine=engine, stream=stream, block_size=block_size,
        guard=GuardConfig(max_grad_norm=100.0),
    )
    w, tr = run_generalized_async_sgd(w0, src, cfg)
    assert np.isfinite(_leaves(w)).all()
    assert tr.extras["guard_rejects"] > 0
    # without the guard the same source destroys the iterate
    cfg_open = ServerConfig(
        n=n, C=C, T=T, eta=0.05, mu=np.linspace(0.5, 2.0, n), seed=7,
        engine=engine, stream=stream, block_size=block_size,
    )
    w_open, _ = run_generalized_async_sgd(w0, src, cfg_open)
    assert not np.isfinite(_leaves(w_open)).all() or (
        np.abs(_leaves(w_open)).max() > 1e4
    )


def test_stale_cutoff_drops_old_updates():
    """A tiny cutoff under heavy churn must drop some completions, and the
    guarded run differs from the unguarded one (the drops are real)."""
    n, C, T = 6, 3, 500
    src = _QuadSource(n)
    w0 = {"a": jnp.zeros(5, jnp.float32)}
    base = dict(n=n, C=C, T=T, eta=0.05, mu=np.linspace(0.2, 1.0, n),
                seed=3, faults=FAULT)
    w_g, tr_g = run_generalized_async_sgd(
        w0, src, ServerConfig(**base, engine="scan",
                              guard=GuardConfig(stale_cutoff=10))
    )
    assert tr_g.extras["stale_drops"] > 0
    w_u, _ = run_generalized_async_sgd(
        w0, src, ServerConfig(**base, engine="scan")
    )
    assert np.abs(_leaves(w_g) - _leaves(w_u)).max() > 0
    # python oracle agrees on the drop count (same merged stream)
    w_p, tr_p = run_generalized_async_sgd(
        w0, src, ServerConfig(**base, engine="python",
                              guard=GuardConfig(stale_cutoff=10))
    )
    assert tr_p.extras["stale_drops"] == tr_g.extras["stale_drops"]
    assert np.max(np.abs(_leaves(w_p) - _leaves(w_g))) < 1e-5


def test_guard_fedbuff_composition_rejected():
    from repro.core import run_fedbuff

    n = 4
    src = _QuadSource(n)
    w0 = {"a": jnp.zeros(3, jnp.float32)}
    cfg = ServerConfig(n=n, C=2, T=50, eta=0.05, engine="scan",
                       faults=FAULT)
    with pytest.raises(ValueError):
        run_fedbuff(w0, src, cfg, Z=5)


# ------------------------------------------------------------------ #
# adaptive sampling on the survivor network
# ------------------------------------------------------------------ #
@pytest.mark.slow
def test_adaptive_under_faults_tracks_survivor_bound():
    """Under churn + crashes + timeouts, the adaptive controller's final p
    must be within 10% of the static-optimal bound for the survivor rates
    it can observe — the busy-time-gated MLE `estimate_mu` (availability
    gating keeps it unbiased for the service-rate-while-up, which is what
    the refresh objective is defined over)."""
    n, C, T = 8, 4, 6000
    mu = np.array([2.0] * 4 + [1.0] * 4)
    # half the slow cluster churns hard: available ~1/6 of the time
    off = np.array([0.0] * 6 + [5.0] * 2)
    on = np.ones(8)
    fault = FaultConfig(off_rate=off, on_rate=on, crash_rate=0.1,
                        timeout_rate=0.1)
    src = _QuadSource(n)
    k = BoundConstants(C=C, T=T)
    runner = make_fused_runner(
        src.device_grad, n, C, T, adaptive=True, refresh_every=300,
        bound=k, fault=fault,
    )
    _, _, extras = runner(
        {"a": jnp.zeros(3, jnp.float32)}, jnp.asarray(mu, jnp.float32),
        jnp.full(n, 1 / n), jax.random.PRNGKey(1), 0.01,
    )
    p_final = np.asarray(extras["p_final"], np.float64)
    assert np.isfinite(p_final).all() and abs(p_final.sum() - 1) < 1e-4
    p_final = p_final / p_final.sum()  # f32 -> f64 renormalization
    mu_hat = np.asarray(
        estimate_mu(jnp.asarray(extras["comp"]),
                    jnp.asarray(extras["busy_time"])), np.float64
    )
    g_adapt, _, _ = bound_for_p(mu_hat, p_final, k)
    g_opt = optimize_general(mu_hat, k).bound
    assert g_adapt <= 1.10 * g_opt, (g_adapt, g_opt, p_final)
    # the busy-gated MLE really does see through the churn: the hard-churn
    # nodes' estimates recover their while-up service rate, not the tiny
    # availability-discounted throughput
    assert (mu_hat[6:] > 0.5).all(), mu_hat
