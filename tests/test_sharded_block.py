"""Lane-sharded blocked engine: sharded == unsharded on 2 simulated devices.

The lane shard (`engine_scan._make_block_step(lane_axis=...)`) partitions
each micro-block's E gradient lanes across devices and recombines them with
one all-gather per block; these tests lock that the sharded runner is
numerically the unsharded runner (≤1e-5 in fp32 — the only difference is
per-device re-association of the fp32 lane prefix).

Device-count-dependent cases run in ONE subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` (the main pytest
process keeps its single CPU device); guard-rail cases run in-process.
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_SUBPROC_SNIPPET = textwrap.dedent(
    """
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import (EventBlocks, SimConfig, blocked_inputs,
                            export_stream, jit_fused_runner, jit_runner,
                            step_scales)

    class Quadratic:
        def __init__(self, n, d=4, seed=0):
            rng = np.random.default_rng(seed)
            self.c_dev = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
            self.d = d
        def device_grad(self, j, w, k):
            return w - self.c_dev[j]

    out = {}
    n, T, E = 8, 500, 4
    p = np.random.default_rng(1).uniform(0.5, 1.5, n); p /= p.sum()
    w0 = jnp.zeros(4, jnp.float32)

    def maxdiff(a, b):
        return float(np.max(np.abs(np.asarray(a) - np.asarray(b))))

    # gen_async, host blocked, C in {1, 4}
    for C in (1, 4):
        prob = Quadratic(n)
        st = export_stream(SimConfig(mu=np.ones(n), p=p, C=C, T=T, seed=3))
        blocks = EventBlocks.from_stream(st, E)
        args = blocked_inputs(blocks, step_scales(st, 0.02, p, "importance"))
        arrs = tuple(map(jnp.asarray, args[:5]))
        w1, _ = jit_runner(prob.device_grad, C, block_size=E)(
            w0, *arrs, chunk_blocks=args[5], n_chunks=args[6])
        w2, _ = jit_runner(prob.device_grad, C, block_size=E, lane_devices=2)(
            w0, *arrs, chunk_blocks=args[5], n_chunks=args[6])
        out[f"gen_async_C{C}"] = maxdiff(w1, w2)

    # FedBuff, host blocked
    prob = Quadratic(n)
    C = 4
    st = export_stream(SimConfig(mu=np.ones(n), p=np.full(n, 1/n), C=C, T=T,
                                 seed=0))
    blocks = EventBlocks.from_stream(st, E)
    args = blocked_inputs(blocks, step_scales(st, 0.05, np.full(n, 1/n),
                                              "plain"))
    arrs = tuple(map(jnp.asarray, args[:5]))
    w1, _ = jit_runner(prob.device_grad, C, fedbuff_Z=5, block_size=E)(
        w0, *arrs, chunk_blocks=args[5], n_chunks=args[6])
    w2, _ = jit_runner(prob.device_grad, C, fedbuff_Z=5, block_size=E,
                       lane_devices=2)(
        w0, *arrs, chunk_blocks=args[5], n_chunks=args[6])
    out["fedbuff_Z5"] = maxdiff(w1, w2)

    # fused device stream, blocked window sharded
    prob = Quadratic(n)
    key = jax.random.PRNGKey(5)
    mu = jnp.ones(n); p0 = jnp.asarray(p, jnp.float32)
    f1 = jit_fused_runner(prob.device_grad, n, C, T, block_size=E)
    f2 = jit_fused_runner(prob.device_grad, n, C, T, block_size=E,
                          lane_devices=2)
    out["fused"] = maxdiff(f1(w0, mu, p0, key, 0.02)[0],
                           f2(w0, mu, p0, key, 0.02)[0])

    # scenario x lane 2-D mesh (1 x 2), vmapped fused
    mus = jnp.stack([mu, mu * 2.0])
    ps = jnp.stack([p0, jnp.full(n, 1/n)])
    keys = jax.random.split(key, 2)
    b1 = jit_fused_runner(prob.device_grad, n, C, T, block_size=E,
                          vmap_scenarios=True)
    b2 = jit_fused_runner(prob.device_grad, n, C, T, block_size=E,
                          vmap_scenarios=True, lane_devices=2)
    out["fused_vmap_mesh"] = maxdiff(b1(w0, mus, ps, keys, 0.02)[0],
                                     b2(w0, mus, ps, keys, 0.02)[0])
    print(json.dumps(out))
    """
)


class TestShardedParity:
    @pytest.mark.slow  # subprocess compiling ~10 sharded programs on 2 devices
    def test_sharded_matches_unsharded_on_two_devices(self):
        repo_root = str(pathlib.Path(__file__).resolve().parent.parent)
        res = subprocess.run(
            [sys.executable, "-c", _SUBPROC_SNIPPET],
            capture_output=True, text=True, timeout=600,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                 "HOME": os.environ.get("HOME", "/root")},
            cwd=repo_root,
        )
        assert res.returncode == 0, res.stderr[-3000:]
        out = json.loads(res.stdout.strip().splitlines()[-1])
        for name, diff in out.items():
            assert diff <= 1e-5, f"{name}: sharded-vs-unsharded diff {diff}"


class TestShardedGuardRails:
    """Validation paths that don't need extra devices."""

    def _quad(self):
        import jax.numpy as jnp

        class Quadratic:
            c_dev = jnp.asarray(np.zeros((8, 4), np.float32))

            def device_grad(self, j, w, k):
                return w - self.c_dev[j]

        return Quadratic()

    def test_per_event_rejects_lane_devices(self):
        from repro.core import jit_runner

        with pytest.raises(ValueError, match="block_size > 1"):
            jit_runner(self._quad().device_grad, 4, lane_devices=2)

    def test_block_size_must_divide(self):
        from repro.core import jit_runner

        with pytest.raises(ValueError, match="multiple of"):
            jit_runner(self._quad().device_grad, 4, block_size=3,
                       lane_devices=2)

    def test_more_lanes_than_devices_rejected(self):
        import jax

        from repro.core import jit_runner

        too_many = jax.device_count() + 1
        with pytest.raises(ValueError, match="visible"):
            jit_runner(self._quad().device_grad, 4,
                       block_size=2 * too_many, lane_devices=too_many)

    def test_server_config_devices_requires_blocked(self):
        from repro.core import ServerConfig, run_generalized_async_sgd

        cfg = ServerConfig(n=8, C=4, T=50, eta=0.1, engine="scan", devices=2)
        with pytest.raises(ValueError, match="block"):
            run_generalized_async_sgd(
                np.zeros(4, np.float32), self._quad(), cfg
            )
