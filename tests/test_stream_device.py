"""Device-resident event stream + fused engine + adaptive control loop.

Law-level parity of `stream_device.generate_stream` against the host
`ClosedNetworkSim` oracle (the realizations differ; the distributions must
not), structural invariants of the fused engine, exactness of the jnp
control-plane port, and convergence of the adaptive sampling loop.
"""
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    BoundConstants,
    JacksonNetwork,
    ServerConfig,
    SimConfig,
    generate_stream,
    make_bound_value_and_grad,
    make_runner,
    mva_throughput_delays,
    run_fedbuff,
    run_generalized_async_sgd,
    simulate,
)
from repro.core.sampling import bound_for_p, bound_value_and_grad, optimize_general
from repro.core.stream_device import optimal_eta_jnp
from repro.core.theory import optimal_eta

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency
    HAVE_HYPOTHESIS = False


def _nonuniform_p(n, seed=1):
    p = np.random.default_rng(seed).uniform(0.5, 1.5, n)
    return p / p.sum()


def _check_stream(stream):
    """FIFO conservation, Lemma-9 in-flight count, slot uniqueness, delays.

    Same replay check as tests/test_engine.py, applied to the on-device
    generator's export — including that the device-computed `delay_steps`
    match an exact host recomputation from (J, K, slot, init_nodes).
    """
    C, n, T = stream.C, stream.n, stream.T
    fifo = [list() for _ in range(n)]
    for s, node in enumerate(stream.init_nodes):
        fifo[node].append((0, int(s)))
    outstanding = {int(s) for s in range(C)}
    for k in range(T):
        j, k_new, s = int(stream.J[k]), int(stream.K[k]), int(stream.slot[k])
        assert fifo[j], "completion at a client with no outstanding task"
        disp_step, disp_slot = fifo[j].pop(0)   # FIFO: oldest dispatch completes
        assert disp_slot == s, "slot must belong to the oldest in-flight task"
        assert int(stream.delay_steps[k]) == k - disp_step
        outstanding.discard(s)
        assert len(outstanding) == C - 1        # Lemma 9: C-1 tasks in flight
        fifo[k_new].append((k + 1, s))
        outstanding.add(s)
        assert len(outstanding) == C            # freed slot reused exactly once
    assert sum(len(q) for q in fifo) == C


class Quadratic:
    def __init__(self, n, d=4, seed=0):
        rng = np.random.default_rng(seed)
        self.c = rng.normal(size=(n, d)).astype(np.float32)
        self.c_dev = jnp.asarray(self.c)
        self.d = d

    def grad(self, i, w, k):
        return w - self.c[i]

    def device_grad(self, j, w, k):
        return w - self.c_dev[j]


# ------------------------------------------------------------------ #
# device event stream: invariants + distributional parity
# ------------------------------------------------------------------ #
class TestDeviceStream:
    @pytest.mark.parametrize("C,init", [(1, "distinct"), (3, "distinct"),
                                        (12, "distinct"), (4, "sampled"),
                                        (9, "distinct")])  # 9 > n: round-robin
    def test_invariants(self, C, init):
        n = 5
        p = _nonuniform_p(n, seed=C + 2)
        mu = np.random.default_rng(C).uniform(0.3, 4.0, n)
        _check_stream(generate_stream(mu, p, C, T=400, seed=C, init=init))

    def test_deterministic_given_seed(self):
        mu, p = np.array([1.0, 2.0]), np.array([0.5, 0.5])
        s1 = generate_stream(mu, p, C=3, T=500, seed=7)
        s2 = generate_stream(mu, p, C=3, T=500, seed=7)
        np.testing.assert_array_equal(s1.J, s2.J)
        np.testing.assert_array_equal(s1.slot, s2.slot)
        np.testing.assert_allclose(s1.t, s2.t)

    def test_chi_square_completions_and_dispatches(self):
        """J and K frequencies match the stationary shares.

        Flow balance on the complete graph gives lambda_i = p_i Lambda, so
        the completion counts (J) — not just the dispatch draws (K) — must
        be multinomial-close to T * p.
        """
        from stat_utils import assert_frequencies

        n, T = 6, 40_000
        p = np.array([0.3, 0.25, 0.2, 0.1, 0.1, 0.05])
        mu = np.random.default_rng(2).uniform(0.5, 4.0, n)
        stream = generate_stream(mu, p, C=4, T=T, seed=0)
        assert_frequencies(stream.K, p, label="dispatch")
        assert_frequencies(stream.J, p, label="completion")

    def test_littles_law_and_occupancy(self):
        """sum_i p_i m_i = C-1 and running occupancy vs product form / oracle."""
        n, C, T = 6, 4, 40_000
        p = _nonuniform_p(n, seed=3)
        mu = np.random.default_rng(4).uniform(0.5, 4.0, n)
        stream = generate_stream(mu, p, C, T=T, seed=1)
        # every completed task saw C-1 other completions on average
        from stat_utils import assert_little

        assert_little(stream.delay_steps, C)
        # time-weighted occupancy matches the exact product form
        net = JacksonNetwork(mu=mu, p=p, C=C)
        np.testing.assert_allclose(
            stream.queue_len_tw / stream.t[-1], net.mean_queue_lengths(),
            rtol=0.12, atol=0.06,
        )
        # event-sampled (Palm) occupancy matches the host oracle's Palm view
        host = simulate(SimConfig(mu=mu, p=p, C=C, T=T, seed=1))
        np.testing.assert_allclose(
            stream.queue_len_sum / T, host.queue_len_sum / T, rtol=0.1, atol=0.05
        )

    def test_delay_means_match_host_sim(self):
        """Per-node delay means: device stream vs ClosedNetworkSim, same law."""
        n, C, T = 6, 4, 40_000
        p = _nonuniform_p(n, seed=1)
        mu = np.random.default_rng(0).uniform(0.5, 4.0, n)
        dev = generate_stream(mu, p, C, T=T, seed=0)
        host = simulate(SimConfig(mu=mu, p=p, C=C, T=T, seed=0, record_delays=True))
        d_dev = np.array([np.mean(d) for d in dev.delays])
        d_host = host.mean_delay_per_node()
        np.testing.assert_allclose(d_dev, d_host, rtol=0.2, atol=0.2)
        # time axis: throughput agrees between the two simulators
        assert dev.t[-1] == pytest.approx(host.t[-1], rel=0.05)

    def test_replayable_through_host_engine(self):
        """A device-generated stream drives the replay engine like a host one."""
        from repro.core import step_scales

        n, C, T = 6, 3, 300
        prob = Quadratic(n)
        p = _nonuniform_p(n)
        stream = generate_stream(np.ones(n), p, C, T=T, seed=5)
        scale = step_scales(stream, 0.05, p, "importance")
        run = make_runner(prob.device_grad, C=C)
        w, _ = jax.jit(run)(jnp.zeros(prob.d), jnp.asarray(stream.J),
                            jnp.asarray(stream.slot), jnp.asarray(scale))
        assert np.all(np.isfinite(np.asarray(w)))


# ------------------------------------------------------------------ #
# jnp control plane == numpy control plane
# ------------------------------------------------------------------ #
class TestControlPlanePort:
    @pytest.mark.parametrize("C", [1, 4, 64])
    def test_mva_matches_buzen(self, C):
        n = 16
        rng = np.random.default_rng(C)
        mu = rng.uniform(0.5, 8.0, n)
        p = _nonuniform_p(n, seed=C + 1)
        net = JacksonNetwork(mu=mu, p=p, C=C)
        m, lam = mva_throughput_delays(mu, p, C)
        np.testing.assert_allclose(np.asarray(m), net.expected_delays(), rtol=1e-5)
        assert float(lam) == pytest.approx(net.throughput(), rel=1e-5)

    def test_bound_value_and_grad_match_numpy(self):
        n = 12
        rng = np.random.default_rng(5)
        mu = rng.uniform(0.5, 6.0, n)
        p = _nonuniform_p(n, seed=6)
        k = BoundConstants(C=6, T=3000)
        vg = make_bound_value_and_grad(k)
        v_j, g_j = vg(jnp.asarray(p), jnp.asarray(mu))
        v_n, _, _, g_n = bound_value_and_grad(mu, p, k)
        assert float(v_j) == pytest.approx(v_n, rel=1e-5)
        np.testing.assert_allclose(
            np.asarray(g_j), g_n, rtol=1e-4, atol=1e-4 * np.abs(g_n).max()
        )

    def test_optimal_eta_newton_matches_roots(self):
        n = 8
        for seed in (0, 1, 2):
            rng = np.random.default_rng(seed)
            mu = rng.uniform(0.5, 6.0, n)
            p = _nonuniform_p(n, seed=seed + 3)
            k = BoundConstants(C=4, T=1000 * (seed + 1))
            m, _ = mva_throughput_delays(mu, p, k.C)
            eta_j = optimal_eta_jnp(jnp.asarray(p), m, k)
            eta_n = optimal_eta(p, np.asarray(m), k)
            assert float(eta_j) == pytest.approx(eta_n, rel=1e-5)


# ------------------------------------------------------------------ #
# fused engine: law parity with the replay oracle + adaptive control
# ------------------------------------------------------------------ #
class TestFusedEngine:
    N, C, T = 8, 4, 3000

    def test_matches_python_engine_in_law(self):
        """Same fixed point (mean of client optima, by unbiasedness) and
        comparable residual spread — realizations differ, laws must not."""
        prob = Quadratic(self.N)
        p = _nonuniform_p(self.N)
        mu = np.random.default_rng(2).uniform(0.5, 4.0, self.N)
        run = make_runner(prob.device_grad, C=self.C, stream="device",
                          n=self.N, T=self.T)
        target = prob.c.mean(0)
        resid = []
        for seed in (0, 1, 2):
            w, _, _ = jax.jit(run)(jnp.zeros(prob.d), jnp.asarray(mu),
                                   jnp.asarray(p), jax.random.PRNGKey(seed), 0.05)
            resid.append(np.linalg.norm(np.asarray(w) - target))
        cfg = ServerConfig(n=self.N, C=self.C, T=self.T, eta=0.05, p=p, mu=mu,
                           seed=0, weighting="importance")
        w_py, _ = run_generalized_async_sgd(np.zeros(prob.d, np.float32), prob, cfg)
        resid_py = np.linalg.norm(np.asarray(w_py) - target)
        # SGD noise ball around the shared fixed point: same scale
        assert np.mean(resid) < 5 * max(resid_py, 0.05)
        assert resid_py < 5 * max(np.mean(resid), 0.05)

    def test_extras_invariants(self):
        prob = Quadratic(self.N)
        p = _nonuniform_p(self.N)
        mu = np.random.default_rng(3).uniform(0.5, 4.0, self.N)
        run = make_runner(prob.device_grad, C=self.C, stream="device",
                          n=self.N, T=self.T)
        _, _, ex = jax.jit(run)(jnp.zeros(prob.d), jnp.asarray(mu),
                                jnp.asarray(p), jax.random.PRNGKey(0), 0.05)
        t = np.asarray(ex["t"])
        assert t.shape == (self.T,) and np.all(np.diff(t) >= 0)
        assert int(np.asarray(ex["comp"]).sum()) == self.T
        # Little's law on the on-device delay accumulators
        assert float(np.asarray(ex["delay_sum"]).sum()) / self.T == pytest.approx(
            self.C - 1, rel=0.05
        )
        assert float(np.asarray(ex["occ_mean"]).sum()) == pytest.approx(self.C, abs=1e-3)

    def test_eval_curve_and_tail(self):
        """Chunked eval + non-divisible tail steps both execute."""
        prob = Quadratic(self.N)
        run = make_runner(prob.device_grad, C=self.C, stream="device",
                          n=self.N, T=1150, eval_fn=lambda w: jnp.sum(w**2),
                          eval_every=300)
        w, evals, ex = jax.jit(run)(jnp.zeros(prob.d), jnp.ones(self.N),
                                    jnp.full(self.N, 1 / self.N),
                                    jax.random.PRNGKey(0), 0.05)
        assert evals.shape == (3,)          # evals at 300/600/900; tail 1050..1150
        assert ex["t"].shape == (1150,)
        assert np.all(np.isfinite(np.asarray(evals)))

    def test_fedbuff_fused_runs(self):
        prob = Quadratic(self.N)
        cfg = ServerConfig(n=self.N, C=self.C, T=800, eta=0.05, seed=0,
                           weighting="plain", engine="scan", stream="device")
        w_dev, tr = run_fedbuff(np.zeros(prob.d, np.float32), prob, cfg, Z=5)
        assert np.all(np.isfinite(np.asarray(w_dev)))
        assert tr.times.shape == (800,)
        # law parity with the host-stream fedbuff: same noise-ball scale
        w_host, _ = run_fedbuff(np.zeros(prob.d, np.float32), prob,
                                replace(cfg, stream="host"), Z=5)
        assert np.linalg.norm(np.asarray(w_dev) - np.asarray(w_host)) < 1.0

    def test_vmap_over_scenarios_matches_single(self):
        prob = Quadratic(self.N)
        p = _nonuniform_p(self.N)
        run = make_runner(prob.device_grad, C=self.C, stream="device",
                          n=self.N, T=400)
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        mus = jnp.broadcast_to(jnp.ones(self.N), (3, self.N))
        ps = jnp.broadcast_to(jnp.asarray(p), (3, self.N))
        wb, _, exb = jax.jit(jax.vmap(run, in_axes=(None, 0, 0, 0, None)))(
            jnp.zeros(prob.d), mus, ps, keys, 0.05
        )
        for b in range(3):
            w1, _, ex1 = jax.jit(run)(jnp.zeros(prob.d), mus[b], ps[b], keys[b], 0.05)
            np.testing.assert_allclose(np.asarray(wb[b]), np.asarray(w1), atol=1e-6)
            np.testing.assert_allclose(np.asarray(exb["t"][b]), np.asarray(ex1["t"]),
                                       rtol=1e-5)

    def test_validation_errors(self):
        prob = Quadratic(self.N)
        with pytest.raises(ValueError):  # adaptive needs refresh cadence
            make_runner(prob.device_grad, C=self.C, stream="device",
                        n=self.N, T=100, adaptive=True)
        with pytest.raises(ValueError):  # adaptive is an Alg.-1 feature
            make_runner(prob.device_grad, C=self.C, stream="device",
                        n=self.N, T=100, adaptive=True, refresh_every=10,
                        fedbuff_Z=5)
        with pytest.raises(TypeError):   # device stream needs n and T
            make_runner(prob.device_grad, C=self.C, stream="device")
        with pytest.raises(ValueError):  # det service is host-only
            run_generalized_async_sgd(
                np.zeros(2, np.float32), prob,
                ServerConfig(n=self.N, C=2, T=10, eta=0.1, engine="scan",
                             stream="device", service="det"),
            )


class TestAdaptiveControl:
    def test_converges_to_static_optimum_two_cluster(self):
        """Adaptive p (from measured rates) reaches the optimize_general
        bound within 5% on a two-cluster network, starting from uniform."""
        n, C, T = 16, 4, 6000
        mu = np.array([8.0] * 8 + [1.0] * 8)
        k = BoundConstants(C=C, T=T)
        grad_fn = lambda j, w, kk: w * 0.0  # control loop only
        run = make_runner(grad_fn, C=C, stream="device", n=n, T=T,
                          adaptive=True, refresh_every=200, bound=k)
        _, _, ex = jax.jit(run)(jnp.zeros(2), jnp.asarray(mu),
                                jnp.full(n, 1.0 / n), jax.random.PRNGKey(1), 0.0)
        p_fin = np.asarray(ex["p_final"], np.float64)
        p_fin /= p_fin.sum()
        opt = optimize_general(mu, k, iters=300)
        b_ad = bound_for_p(mu, p_fin, k)[0]
        assert b_ad <= 1.05 * opt.bound
        # and it actually moved: beats uniform decisively
        assert b_ad < 0.99 * opt.uniform_bound
        # fast nodes under-sampled relative to slow, like the static optimum
        assert p_fin[0] < p_fin[-1]
        # trajectory monotone-ish: last refresh no worse than the first few
        traj = np.asarray(ex["p_traj"], np.float64)
        b0 = bound_for_p(mu, traj[0] / traj[0].sum(), k)[0]
        assert b_ad <= b0 + 1e-12

    def test_importance_scale_uses_dispatch_time_p(self):
        """Under a changing p the weighted update must use each task's
        dispatch-time probability: with eta != 0 and adaptive on, the run
        stays finite and unbiased toward the quadratic fixed point."""
        n, C, T = 8, 3, 4000
        prob = Quadratic(n)
        mu = np.ones(n)
        run = make_runner(prob.device_grad, C=C, stream="device", n=n, T=T,
                          adaptive=True, refresh_every=250,
                          bound=BoundConstants(C=C, T=T))
        w, _, ex = jax.jit(run)(jnp.zeros(prob.d), jnp.asarray(mu),
                                jnp.full(n, 1.0 / n), jax.random.PRNGKey(0), 0.05)
        target = prob.c.mean(0)
        assert np.linalg.norm(np.asarray(w) - target) < 0.6


class TestDeviceMatrix:
    def test_run_matrix_device_zero_host_presimulation(self, monkeypatch):
        """stream='device' must never touch the host simulator."""
        import repro.fl.engine as fle
        from repro.configs.base import FLConfig
        from repro.data.pipeline import FederatedClassification

        def _boom(*a, **kw):
            raise AssertionError("host pre-simulation on the device path")

        monkeypatch.setattr(fle, "export_stream", _boom)
        flc = FLConfig(n_clients=8, concurrency=3, server_steps=90)
        data = FederatedClassification(n_clients=8, seed=0)
        m = fle.run_matrix(flc, seeds=(0, 1), policies=("uniform", "optimal"),
                           speed_ratios=(1.0, 4.0), eval_every=45, data=data,
                           stream="device")
        assert m.final_acc.shape == (2, 2, 2)
        assert m.eval_acc.shape == (2, 2, 2, 2)
        assert np.all(np.diff(m.eval_times, axis=-1) >= 0)
        assert m.extras["stream"] == "device"
        # on-device queueing observables ride along per scenario
        assert m.extras["mean_delays"].shape == (2, 2, 2, 8)
        np.testing.assert_allclose(m.extras["p_final"].sum(-1), 1.0, atol=1e-5)

    def test_run_matrix_adaptive_beats_uniform(self):
        """Adaptive rows end with a better bound than their uniform start."""
        from repro.configs.base import FLConfig
        from repro.data.pipeline import FederatedClassification
        from repro.fl import run_matrix

        n, C, T = 12, 4, 2000
        flc = FLConfig(n_clients=n, concurrency=C, server_steps=T,
                       speed_ratio=8.0, stream="device", adaptive=True,
                       refresh_every=200)
        data = FederatedClassification(n_clients=n, seed=0)
        m = run_matrix(flc, seeds=(0,), policies=("uniform",),
                       speed_ratios=(8.0,), eval_every=T, data=data)
        from repro.data.pipeline import make_client_speeds

        mu = make_client_speeds(n, flc.frac_fast, 8.0, seed=flc.seed)
        k = BoundConstants(C=C, T=T)
        p_fin = m.extras["p_final"][0, 0, 0]
        p_fin = np.maximum(p_fin, 1e-12) / p_fin.sum()
        assert bound_for_p(mu, p_fin, k)[0] < bound_for_p(mu, np.full(n, 1 / n), k)[0]


# ------------------------------------------------------------------ #
# optional hypothesis sweep (kept off tier-1 via the importorskip pattern)
# ------------------------------------------------------------------ #
if HAVE_HYPOTHESIS:

    @st.composite
    def stream_params(draw):
        n = draw(st.integers(2, 8))
        C = draw(st.integers(1, 12))
        T = draw(st.integers(10, 200))
        seed = draw(st.integers(0, 2**16))
        init = draw(st.sampled_from(["distinct", "sampled"]))
        mu = np.array([draw(st.floats(0.2, 8.0)) for _ in range(n)])
        praw = np.array([draw(st.floats(0.05, 1.0)) for _ in range(n)])
        return mu, praw / praw.sum(), C, T, seed, init

    class TestDeviceStreamHypothesis:
        @given(params=stream_params())
        @settings(max_examples=15, deadline=None)
        def test_invariants(self, params):
            mu, p, C, T, seed, init = params
            _check_stream(generate_stream(mu, p, C, T=T, seed=seed, init=init))
