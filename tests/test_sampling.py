"""Optimal-sampling layer: the paper's headline qualitative claims."""
import numpy as np
import pytest

from repro.core import (
    BoundConstants,
    bound_for_p,
    optimize_general,
    optimize_two_cluster,
    two_cluster_p_vector,
)


class TestTwoCluster:
    def test_fast_clients_sampled_less(self):
        """The counter-intuitive headline: p_fast* < 1/n < p_slow*."""
        n, n_f = 100, 90
        k = BoundConstants(A=100, L=1, B=20, C=10, T=10_000)
        res = optimize_two_cluster(8.0, 1.0, n, n_f, k)
        assert res.p[0] < 1.0 / n < res.p[-1]
        assert res.relative_improvement > 0.1

    def test_improvement_grows_with_speed_gap(self):
        n, n_f = 100, 90
        k = BoundConstants(A=100, L=1, B=20, C=10, T=10_000)
        imps = [
            optimize_two_cluster(mf, 1.0, n, n_f, k).relative_improvement
            for mf in (2.0, 4.0, 16.0)
        ]
        assert imps[0] < imps[1] < imps[2]

    def test_optimal_p_matches_paper_magnitude(self):
        """Paper: p* ~ 7.3e-3 at mu_f=16 with n=100, n_f=90."""
        k = BoundConstants(A=100, L=1, B=20, C=10, T=10_000)
        res = optimize_two_cluster(16.0, 1.0, 100, 90, k)
        assert 3e-3 < res.p[0] < 9.5e-3

    def test_p_vector_simplex(self):
        p = two_cluster_p_vector(10, 4, 0.05)
        assert p.sum() == pytest.approx(1.0)
        assert np.all(p > 0)
        with pytest.raises(ValueError):
            two_cluster_p_vector(10, 4, 0.3)  # 4 * 0.3 > 1

    def test_optimum_beats_uniform_and_neighbors(self):
        n, n_f = 50, 25
        k = BoundConstants(C=10, T=5_000)
        mu = np.array([4.0] * n_f + [1.0] * (n - n_f))
        res = optimize_two_cluster(4.0, 1.0, n, n_f, k)
        assert res.bound <= res.uniform_bound + 1e-9
        for mult in (0.7, 1.3):
            q = two_cluster_p_vector(n, n_f, float(res.p[0] * mult))
            bq, _, _ = bound_for_p(mu, q, k)
            assert res.bound <= bq * 1.02


class TestGeneral:
    def test_mirror_descent_beats_uniform_heterogeneous(self):
        rng = np.random.default_rng(0)
        n = 8
        mu = rng.uniform(0.5, 8.0, n)
        k = BoundConstants(C=6, T=2_000)
        res = optimize_general(mu, k, iters=40)
        assert res.bound <= res.uniform_bound * 1.001
        assert res.p.sum() == pytest.approx(1.0)

    def test_homogeneous_stays_uniform(self):
        n = 6
        mu = np.full(n, 2.0)
        k = BoundConstants(C=4, T=2_000)
        res = optimize_general(mu, k, iters=30)
        np.testing.assert_allclose(res.p, 1.0 / n, atol=0.02)
