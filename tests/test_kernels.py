"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency (pip install .[dev])")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.weighted_update import weighted_update

_rng = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,S,H,K,D,T,window,offset,bq,bk",
        [
            (2, 128, 4, 2, 64, 128, 0, 0, 64, 64),
            (1, 256, 8, 4, 64, 256, 64, 0, 128, 64),
            (1, 64, 4, 1, 128, 64, 0, 0, 32, 32),     # MQA
            (1, 128, 4, 4, 128, 384, 0, 256, 64, 128),  # decode-ish offset
            (2, 64, 6, 2, 32, 64, 16, 0, 64, 64),     # narrow window
        ],
    )
    def test_matches_reference(self, dtype, B, S, H, K, D, T, window, offset, bq, bk):
        q = jnp.asarray(_rng.normal(size=(B, S, H, D)), dtype)
        k = jnp.asarray(_rng.normal(size=(B, T, K, D)), dtype)
        v = jnp.asarray(_rng.normal(size=(B, T, K, D)), dtype)
        out = flash_attention(q, k, v, causal=True, window=window, q_offset=offset, bq=bq, bk=bk)
        exp = ref.flash_attention_ref(q, k, v, causal=True, window=window, q_offset=offset)
        np.testing.assert_allclose(
            out.astype(jnp.float32), exp.astype(jnp.float32), **_tol(dtype)
        )

    @given(
        S=st.sampled_from([64, 128, 192]),
        H=st.sampled_from([2, 4]),
        G=st.sampled_from([1, 2]),
        window=st.sampled_from([0, 32]),
    )
    @settings(max_examples=10, deadline=None)
    def test_property_sweep(self, S, H, G, window):
        K = H // G
        D = 32
        q = jnp.asarray(_rng.normal(size=(1, S, H, D)), jnp.float32)
        k = jnp.asarray(_rng.normal(size=(1, S, K, D)), jnp.float32)
        v = jnp.asarray(_rng.normal(size=(1, S, K, D)), jnp.float32)
        out = flash_attention(q, k, v, window=window, bq=64, bk=64)
        exp = ref.flash_attention_ref(q, k, v, window=window)
        np.testing.assert_allclose(out, exp, atol=1e-4, rtol=1e-4)


class TestSSDScan:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,S,H,P,N,chunk", [
        (2, 128, 3, 32, 16, 32),
        (1, 64, 2, 64, 128, 64),
        (1, 256, 4, 16, 8, 16),
    ])
    def test_matches_reference(self, dtype, B, S, H, P, N, chunk):
        x = jnp.asarray(_rng.normal(size=(B, S, H, P)), dtype)
        dt = jnp.asarray(_rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
        A = -jnp.asarray(_rng.uniform(0.5, 2.0, (H,)), jnp.float32)
        Bm = jnp.asarray(_rng.normal(size=(B, S, N)), dtype)
        Cm = jnp.asarray(_rng.normal(size=(B, S, N)), dtype)
        y, s = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
        ye, se = ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk)
        np.testing.assert_allclose(y.astype(jnp.float32), ye.astype(jnp.float32), **_tol(dtype))
        np.testing.assert_allclose(s, se, atol=1e-2 if dtype == jnp.bfloat16 else 1e-4)


class TestMoEGMM:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("E,C,D,F,bc,bf,bd", [
        (4, 256, 128, 256, 128, 128, 128),
        (2, 128, 256, 128, 64, 64, 128),
        (8, 64, 64, 64, 64, 64, 64),
    ])
    def test_matches_reference(self, dtype, E, C, D, F, bc, bf, bd):
        x = jnp.asarray(_rng.normal(size=(E, C, D)), dtype)
        w = jnp.asarray(_rng.normal(size=(E, D, F)), dtype)
        out = moe_gmm(x, w, bc=bc, bf=bf, bd=bd)
        exp = ref.moe_gmm_ref(x, w)
        np.testing.assert_allclose(
            out.astype(jnp.float32), exp.astype(jnp.float32), **_tol(dtype)
        )

    def test_block_shape_invariance(self):
        x = jnp.asarray(_rng.normal(size=(2, 256, 256)), jnp.float32)
        w = jnp.asarray(_rng.normal(size=(2, 256, 256)), jnp.float32)
        o1 = moe_gmm(x, w, bc=64, bf=64, bd=64)
        o2 = moe_gmm(x, w, bc=256, bf=128, bd=256)
        np.testing.assert_allclose(o1, o2, atol=1e-4)


class TestWeightedUpdate:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [(17,), (1000, 37), (8, 128), (3, 5, 7)])
    @pytest.mark.parametrize("momentum", [0.0, 0.9])
    def test_matches_reference(self, dtype, shape, momentum):
        w = jnp.asarray(_rng.normal(size=shape), dtype)
        g = jnp.asarray(_rng.normal(size=shape), dtype)
        m = jnp.zeros(shape, jnp.float32) if momentum else None
        scale = jnp.float32(0.37)
        ow, om = weighted_update(w, g, scale, m=m, momentum=momentum)
        ew, em = ref.weighted_update_ref(w, g, scale, m=m, momentum=momentum)
        np.testing.assert_allclose(
            ow.astype(jnp.float32), ew.astype(jnp.float32), **_tol(dtype)
        )
        if momentum:
            np.testing.assert_allclose(om, em, atol=1e-5)

    def test_importance_weight_semantics(self):
        """scale = eta/(n p_j): doubling p_j halves the applied step."""
        w = jnp.ones((64,), jnp.float32)
        g = jnp.ones((64,), jnp.float32)
        eta, n = 0.1, 10
        w1, _ = weighted_update(w, g, jnp.float32(eta / (n * 0.05)))
        w2, _ = weighted_update(w, g, jnp.float32(eta / (n * 0.10)))
        np.testing.assert_allclose(w - w1, 2.0 * (w - w2), rtol=1e-6)

    @given(n=st.integers(1, 5000), seed=st.integers(0, 100))
    @settings(max_examples=15, deadline=None)
    def test_padding_correct_any_size(self, n, seed):
        r = np.random.default_rng(seed)
        w = jnp.asarray(r.normal(size=(n,)), jnp.float32)
        g = jnp.asarray(r.normal(size=(n,)), jnp.float32)
        ow, _ = weighted_update(w, g, jnp.float32(0.5))
        np.testing.assert_allclose(ow, w - 0.5 * g, atol=1e-6)
