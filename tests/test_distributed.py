"""Distribution layer: sharding rules, HLO cost model, small-mesh lowering.

Device-count-dependent tests run in a subprocess so the main pytest process
keeps its single CPU device (per the dry-run isolation requirement).
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.shardings import DEFAULT_RULES, logical_to_pspec


class TestLogicalRules:
    def test_divisible_assignment(self):
        class FakeMesh:
            shape = {"data": 4, "model": 4}

        spec = logical_to_pspec(("embed", "mlp"), {"embed": "data", "mlp": "model"},
                                (64, 128), FakeMesh())
        assert spec == jax.sharding.PartitionSpec("data", "model")

    def test_indivisible_falls_back(self):
        class FakeMesh:
            shape = {"data": 4, "model": 16}

        # 3352 % 16 != 0 -> drop the model assignment
        spec = logical_to_pspec(("embed", "mlp"), {"embed": "data", "mlp": "model"},
                                (64, 3352), FakeMesh())
        assert spec == jax.sharding.PartitionSpec("data", None)

    def test_tuple_prefix_fallback(self):
        class FakeMesh:
            shape = {"pod": 2, "data": 16, "model": 16}

        # batch 32 divisible by pod*data=32 -> full tuple kept
        spec = logical_to_pspec(("batch",), {"batch": ("pod", "data")}, (32,), FakeMesh())
        assert spec == jax.sharding.PartitionSpec(("pod", "data"))
        # batch 2 only divisible by pod -> prefix
        spec = logical_to_pspec(("batch",), {"batch": ("pod", "data")}, (2,), FakeMesh())
        assert spec == jax.sharding.PartitionSpec("pod")

    def test_axis_used_once_per_tensor(self):
        class FakeMesh:
            shape = {"model": 4}

        spec = logical_to_pspec(
            ("heads", "mlp"), {"heads": "model", "mlp": "model"}, (8, 8), FakeMesh()
        )
        assert spec == jax.sharding.PartitionSpec("model", None)


class TestHloCostModel:
    def test_scan_trip_count_flops(self):
        W = jnp.ones((7, 64, 64), jnp.float32)
        x0 = jnp.ones((32, 64), jnp.float32)

        def step(x, w):
            return x @ w, None

        f = jax.jit(lambda x, W: jax.lax.scan(step, x, W)[0])
        txt = f.lower(x0, W).compile().as_text()
        r = analyze_hlo(txt)
        assert r["flops"] == pytest.approx(7 * 2 * 32 * 64 * 64, rel=0.01)

    def test_nested_scan(self):
        def outer(x, Ws):
            def inner(x, w):
                return x @ w, None

            x, _ = jax.lax.scan(inner, x, Ws)
            return x, None

        W2 = jnp.ones((3, 5, 32, 32), jnp.float32)
        g = jax.jit(lambda x, W2: jax.lax.scan(outer, x, W2)[0])
        txt = g.lower(jnp.ones((16, 32)), W2).compile().as_text()
        r = analyze_hlo(txt)
        assert r["flops"] == pytest.approx(3 * 5 * 2 * 16 * 32 * 32, rel=0.01)

    def test_plain_dot_exact(self):
        f = jax.jit(lambda a, b: a @ b)
        txt = f.lower(jnp.ones((128, 64)), jnp.ones((64, 32))).compile().as_text()
        assert analyze_hlo(txt)["flops"] == 2 * 128 * 32 * 64


_SUBPROC_SNIPPET = textwrap.dedent(
    """
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.configs import smoke_config, SHAPES, input_specs, for_shape
    from repro.configs.base import ShapeConfig
    from repro.launch.dryrun import build_step
    from repro.launch import shardings as SH

    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    out = {}
    shape = ShapeConfig("t", 64, 8, "train")
    for arch in ["yi_6b", "qwen2_moe_a2_7b", "mamba2_130m", "zamba2_2_7b"]:
        cfg = smoke_config(arch).replace(moe_group_size=64)
        fn, args = build_step(cfg, shape, mesh, dict(SH.DEFAULT_RULES))
        compiled = fn.lower(*args).compile()
        txt = compiled.as_text()
        has_coll = any(c in txt for c in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all"))
        out[arch] = bool(has_coll)
    dshape = ShapeConfig("d", 64, 8, "decode")
    for arch in ["yi_6b", "mamba2_130m"]:
        cfg = smoke_config(arch)
        fn, args = build_step(cfg, dshape, mesh, dict(SH.DEFAULT_RULES))
        fn.lower(*args).compile()
        out[arch + "_decode"] = True
    print(json.dumps(out))
    """
)


class TestSmallMeshLowering:
    @pytest.mark.slow  # subprocess compiling 6 sharded programs on 8 host devices
    def test_smoke_archs_lower_on_2x4_mesh(self):
        repo_root = str(pathlib.Path(__file__).resolve().parent.parent)
        res = subprocess.run(
            [sys.executable, "-c", _SUBPROC_SNIPPET],
            capture_output=True, text=True, timeout=600,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                 "HOME": os.environ.get("HOME", "/root")},
            cwd=repo_root,
        )
        assert res.returncode == 0, res.stderr[-3000:]
        out = json.loads(res.stdout.strip().splitlines()[-1])
        # sharded training must communicate
        assert out["yi_6b"] and out["qwen2_moe_a2_7b"]
        assert out["yi_6b_decode"] and out["mamba2_130m_decode"]
