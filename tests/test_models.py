"""Per-architecture smoke tests + model-level equivalences.

Every assigned architecture instantiates a REDUCED same-family variant
(2 layers, d_model<=512, <=4 experts), runs one forward + one train step on
CPU, and asserts output shapes and no NaNs.  Decode paths are checked for
exact consistency with the full-sequence forward.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.configs.base import OptimConfig
from repro.models import api
from repro.models.module import abstract_params, init_params, param_count
from repro.optim import make_optimizer

B, S = 2, 32


def _batch(cfg, rng, with_labels=True):
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.frontend == "audio_stub":
        b = {"embeds": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)}
        if with_labels:
            b["labels"] = toks
    elif cfg.frontend == "vision_stub":
        st = S - cfg.num_patches
        b = {
            "tokens": toks[:, :st],
            "patch_embeds": jnp.asarray(
                rng.normal(size=(B, cfg.num_patches, cfg.d_model)), jnp.float32
            ),
        }
        if with_labels:
            b["labels"] = toks[:, :st]
    else:
        b = {"tokens": toks}
        if with_labels:
            b["labels"] = toks
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = smoke_config(arch)
        params = init_params(api.model_meta(cfg), jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = _batch(cfg, rng, with_labels=False)
        logits, aux = api.forward(params, batch, cfg)
        s_expect = S if cfg.frontend != "vision_stub" else S
        assert logits.shape == (B, s_expect, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    def test_train_step_no_nans(self, arch):
        cfg = smoke_config(arch)
        params = init_params(api.model_meta(cfg), jax.random.PRNGKey(1))
        opt = make_optimizer(OptimConfig(name="adamw", lr=1e-3))
        opt_state = opt.init(params)
        rng = np.random.default_rng(1)
        batch = _batch(cfg, rng)
        new_params, new_opt, metrics = api.train_step(
            params, opt_state, batch, cfg, opt, sampling_weight=1.3
        )
        assert bool(jnp.isfinite(metrics["loss"]))
        assert bool(jnp.isfinite(metrics["grad_norm"]))
        # params actually moved
        moved = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
            params, new_params,
        )
        assert max(jax.tree_util.tree_leaves(moved)) > 0

    def test_decode_step_shapes(self, arch):
        cfg = smoke_config(arch)
        params = init_params(api.model_meta(cfg), jax.random.PRNGKey(2))
        spec = api.init_cache(cfg, B, S)
        cache = jax.tree_util.tree_map(
            lambda s: jnp.full(s.shape, -1, s.dtype)
            if (s.dtype == jnp.int32 and s.ndim == 1)
            else jnp.zeros(s.shape, s.dtype),
            spec,
        )
        if cfg.frontend == "audio_stub":
            batch = {"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.float32)}
        else:
            batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
        out, new_cache = api.serve_step(params, cache, batch, cfg)
        assert out["logits"].shape == (B, cfg.vocab_size)
        assert out["next_ids"].shape == (B,)
        assert bool(jnp.all(jnp.isfinite(out["logits"].astype(jnp.float32))))
        assert int(new_cache["pos"]) == 1

    def test_full_config_abstract_params(self, arch):
        """The FULL assigned config builds abstract params (no allocation)."""
        cfg = get_config(arch)
        n = param_count(api.model_meta(cfg))
        expected_range = {
            "internvl2_26b": (15e9, 30e9),     # backbone only (no ViT)
            "starcoder2_7b": (6e9, 8e9),
            "musicgen_medium": (1e9, 2.5e9),
            "arctic_480b": (400e9, 520e9),
            "qwen2_5_32b": (28e9, 36e9),
            "mamba2_130m": (0.1e9, 0.2e9),
            "qwen2_moe_a2_7b": (12e9, 17e9),   # total (2.7B active)
            "yi_6b": (5e9, 7e9),
            "granite_3_2b": (2e9, 3.5e9),
            "zamba2_2_7b": (2e9, 3.5e9),
        }[arch]
        assert expected_range[0] < n < expected_range[1], f"{arch}: {n:,}"
        abstract_params(api.model_meta(cfg))  # must not allocate/crash


class TestEquivalences:
    @pytest.mark.parametrize("arch", ["yi_6b", "mamba2_130m", "zamba2_2_7b", "qwen2_moe_a2_7b"])
    def test_decode_matches_forward(self, arch):
        cfg = smoke_config(arch)
        if cfg.family == "moe":
            # capacity drops are group-size dependent (prefill groups B*S
            # tokens, decode groups B); ample capacity makes paths identical
            cfg = cfg.replace(capacity_factor=16.0)
        params = init_params(api.model_meta(cfg), jax.random.PRNGKey(3))
        rng = np.random.default_rng(3)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 16)), jnp.int32)
        full, _ = api.forward(params, {"tokens": toks}, cfg)
        spec = api.init_cache(cfg, B, 16)
        cache = jax.tree_util.tree_map(
            lambda s: jnp.full(s.shape, -1, s.dtype)
            if (s.dtype == jnp.int32 and s.ndim == 1)
            else jnp.zeros(s.shape, s.dtype),
            spec,
        )
        for t in range(16):
            lg, cache = api.decode_step(params, cache, {"tokens": toks[:, t : t + 1]}, cfg)
            np.testing.assert_allclose(lg, full[:, t], atol=2e-4)

    def test_gqa_equals_mha_when_kv_equals_heads(self):
        """GQA with K == H must equal standard MHA math."""
        from repro.models.layers import _sdpa

        cfg = smoke_config("yi_6b").replace(num_heads=4, num_kv_heads=4)
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(2, 8, 4, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, 8, 4, 16)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, 8, 4, 16)), jnp.float32)
        mask = jnp.tril(jnp.ones((8, 8), bool))[None, None]
        out = _sdpa(q, k, v, mask, cfg)
        # manual MHA
        sc = jnp.einsum("bshd,bthd->bhst", q, k) / 4.0
        sc = jnp.where(mask, sc, -1e30)
        ref = jnp.einsum("bhst,bthd->bshd", jax.nn.softmax(sc, -1), v)
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_sliding_window_matches_full_for_short_seq(self):
        """window >= S is a no-op."""
        cfg = smoke_config("yi_6b")
        params = init_params(api.model_meta(cfg), jax.random.PRNGKey(4))
        rng = np.random.default_rng(4)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 16)), jnp.int32)
        full, _ = api.forward(params, {"tokens": toks}, cfg.replace(sliding_window=0))
        win, _ = api.forward(params, {"tokens": toks}, cfg.replace(sliding_window=999))
        np.testing.assert_allclose(full, win, atol=1e-5)
        narrow, _ = api.forward(params, {"tokens": toks}, cfg.replace(sliding_window=4))
        assert float(jnp.max(jnp.abs(narrow - full))) > 1e-3  # window actually bites

    def test_moe_routing_mass_conservation(self):
        """Top-k gate weights are normalized per token."""
        from repro.models.layers import _route_group
        from repro.models.module import init_params as ip

        cfg = smoke_config("qwen2_moe_a2_7b")
        from repro.models.layers import moe_meta

        params = ip(moe_meta(cfg), jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        xg = jnp.asarray(rng.normal(size=(64, cfg.d_model)), jnp.float32)
        out, aux = _route_group(params, xg, cfg)
        assert out.shape == xg.shape
        assert float(aux) > 0.5  # load-balance loss ~ E * sum f*P >= 1 at uniform

    def test_moe_sort_dispatch_equals_einsum(self):
        """Beyond-paper sort-based dispatch is bit-compatible with GShard
        one-hot dispatch (same token->slot priority & capacity drops)."""
        from repro.models.layers import _route_group, _route_group_sorted, moe_meta
        from repro.models.module import init_params as ip

        for arch in ("qwen2_moe_a2_7b", "arctic_480b"):
            cfg = smoke_config(arch)
            params = ip(moe_meta(cfg), jax.random.PRNGKey(1))
            rng = np.random.default_rng(7)
            xg = jnp.asarray(rng.normal(size=(96, cfg.d_model)), jnp.float32)
            o1, a1 = _route_group(params, xg, cfg)
            o2, a2 = _route_group_sorted(params, xg, cfg)
            np.testing.assert_allclose(o1, o2, atol=1e-5)
            np.testing.assert_allclose(a1, a2, atol=1e-6)

    def test_moe_full_model_sort_dispatch(self):
        cfg = smoke_config("qwen2_moe_a2_7b").replace(moe_dispatch="sort")
        params = init_params(api.model_meta(cfg), jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        loss, _ = api.loss_fn(params, {"tokens": toks, "labels": toks}, cfg)
        assert bool(jnp.isfinite(loss))

    def test_mamba_state_handoff(self):
        """Chunked prefill then recurrent decode == one long chunked pass."""
        from repro.models.mamba2 import ssd_chunked, ssd_recurrent_step

        rng = np.random.default_rng(5)
        Bz, S1, S2, H, P, N = 1, 32, 8, 2, 8, 8
        S = S1 + S2
        x = jnp.asarray(rng.normal(size=(Bz, S, H, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.3, (Bz, S, H)), jnp.float32)
        A = -jnp.asarray(rng.uniform(0.5, 1.5, (H,)), jnp.float32)
        Bm = jnp.asarray(rng.normal(size=(Bz, S, N)), jnp.float32)
        Cm = jnp.asarray(rng.normal(size=(Bz, S, N)), jnp.float32)
        y_all, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
        _, h = ssd_chunked(x[:, :S1], dt[:, :S1], A, Bm[:, :S1], Cm[:, :S1], chunk=8)
        for t in range(S1, S):
            y_t, h = ssd_recurrent_step(h, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
            np.testing.assert_allclose(y_t, y_all[:, t], atol=1e-4)
