"""Resilient serving plane: open-queue inference under live traffic.

Covers: `ServingConfig` validation and the capped-backoff property,
request conservation (served + shed + timed_out == arrivals, *exactly*)
under a 2x overload burst with bounded queue depth, the never-serve-a-
guard-rejected-update property (the serving read path's checksum stays
finite while poison gradients are injected), host-oracle law parity for
the merged open/closed race, the analytic mixed product-form factors
(`jackson.mixed_serving_analysis`) against the simulated plane, the
training-vs-SLO tradeoff optimizer, and serving-state checkpoint
truncate-and-resume bitwise equality.
"""
import os
import shutil

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    BoundConstants,
    GuardConfig,
    ServerConfig,
    ServingConfig,
    jit_fused_runner,
    mixed_serving_analysis,
    optimize_general,
    optimize_tradeoff,
    run_generalized_async_sgd,
    simulate_serving_host,
)
from repro.core.serving import HIST_BUCKETS, backoff_delay, hist_quantile
from repro.ckpt import checkpoint as ck

_N, _C = 8, 4
_MU = np.linspace(0.5, 2.0, _N).astype(np.float32)
_P = np.full(_N, 1 / _N, np.float32)
_TARG = jnp.arange(_N, dtype=jnp.float32)


def _grad(j, w, k):
    return {"a": w["a"] - _TARG[j]}


_W0 = {"a": jnp.zeros(6, jnp.float32)}

# 2x overload: lambda = 2 * nu, with timeouts and retries active
_OVERLOAD = ServingConfig(
    arrival_rate=6.0, serve_rate=3.0, queue_cap=5,
    deadline=1.0, max_retries=2, backoff_base=0.1, backoff_cap=0.4,
)


def _run(serving, T=2000, seed=0, guard=None, grad=_grad, eta=0.05):
    runner = jit_fused_runner(grad, _N, _C, T, serving=serving, guard=guard)
    w, _, extras = runner(
        _W0, jnp.asarray(_MU), jnp.asarray(_P), jax.random.PRNGKey(seed), eta
    )
    return w, {k: np.asarray(v) for k, v in extras.items()}


# ------------------------------------------------------------------ #
# config validation + backoff property
# ------------------------------------------------------------------ #
def test_serving_config_validation():
    assert not ServingConfig().enabled
    assert _OVERLOAD.enabled
    with pytest.raises(ValueError):
        ServingConfig(arrival_rate=1.0, serve_rate=0.0).validate()
    with pytest.raises(ValueError):
        ServingConfig(arrival_rate=1.0, queue_cap=0).validate()
    with pytest.raises(ValueError):
        ServingConfig(arrival_rate=1.0, queue_cap=8, table_cap=3).validate()
    with pytest.raises(ValueError):
        ServingConfig(arrival_rate=1.0, backoff_base=0.0).validate()
    # auto table sizing: queue_cap live requests + retry parkers + 1
    assert ServingConfig(queue_cap=8, max_retries=2).R == 11
    assert ServingConfig(queue_cap=8, table_cap=20).R == 20
    assert isinstance(hash(_OVERLOAD.cache_key()), int)


def test_backoff_delay_never_exceeds_cap():
    # property: for any attempt count and any (base, cap) the mean retry
    # delay is min(base * 2**(attempt-1), cap) and never exceeds cap
    for base, cap in [(0.1, 0.4), (0.25, 2.0), (1.0, 1.0), (0.5, 64.0)]:
        cfg = ServingConfig(backoff_base=base, backoff_cap=cap)
        att = jnp.arange(1, 40)
        d = np.asarray(backoff_delay(cfg, att))
        assert (d <= cap + 1e-6).all()
        assert (d > 0).all()
        # doubling until the cap binds
        expect = np.minimum(base * 2.0 ** (np.arange(1, 40) - 1.0), cap)
        np.testing.assert_allclose(d, expect, rtol=1e-6)


def test_hist_quantile_geometric_midpoint():
    h = np.zeros(HIST_BUCKETS, np.int64)
    h[3] = 100
    assert hist_quantile(h, 0.5, lo=0) == pytest.approx(2.0**3.5)
    assert np.isnan(hist_quantile(np.zeros(HIST_BUCKETS), 0.5))


# ------------------------------------------------------------------ #
# conservation + bounded depth under 2x overload
# ------------------------------------------------------------------ #
def test_overload_conservation_exact_and_depth_bounded():
    w, ex = _run(_OVERLOAD, T=2000)
    arr = int(ex["serve_arrivals"])
    acct = (int(ex["serve_served"]) + int(ex["serve_shed"])
            + int(ex["serve_timed_out"]) + int(ex["serve_pending"]))
    assert arr == acct  # exact, not approximate
    assert arr > 100  # the overload actually generated traffic
    assert int(ex["serve_shed"]) > 0  # 2x overload must shed
    # admission control bounds the in-system depth by queue_cap no matter
    # the overload factor (the table parks backoff requests beyond it)
    assert int(ex["serve_qdepth_max"]) <= _OVERLOAD.R
    # the training plane kept running underneath
    assert int(ex["serve_kg_step"]) > 0
    assert np.isfinite(np.asarray(w["a"])).all()


def test_token_bucket_admission_sheds_more():
    # a starving token bucket must shed at least as much as depth-only
    # admission under identical traffic
    base = ServingConfig(arrival_rate=4.0, serve_rate=4.0, queue_cap=8)
    bucket = ServingConfig(arrival_rate=4.0, serve_rate=4.0, queue_cap=8,
                           bucket_rate=0.5, bucket_cap=2.0)
    _, ex0 = _run(base, T=1500, seed=7)
    _, ex1 = _run(bucket, T=1500, seed=7)
    assert int(ex1["serve_shed"]) > int(ex0["serve_shed"])
    arr = int(ex1["serve_arrivals"])
    acct = (int(ex1["serve_served"]) + int(ex1["serve_shed"])
            + int(ex1["serve_timed_out"]) + int(ex1["serve_pending"]))
    assert arr == acct


# ------------------------------------------------------------------ #
# guard-rejected updates are never observable via the read path
# ------------------------------------------------------------------ #
def test_guard_rejected_update_never_served():
    # client 3 emits a non-finite gradient in a mid-run window while serve
    # traffic is live; the guard rejects it, the known-good pointer stays
    # on the last accepted row, and the serving read-path checksum (the
    # served rows' means) remains finite throughout.
    def poison_grad(j, w, k):
        bad = (j == 3) & (k >= 200) & (k < 1200)
        g = w["a"] - _TARG[j]
        return {"a": jnp.where(bad, jnp.float32(jnp.inf), g)}

    guard = GuardConfig(max_grad_norm=1e3)
    w, ex = _run(_OVERLOAD, T=2000, guard=guard, grad=poison_grad)
    assert int(ex["guard_rejects"]) > 0  # the poison window really fired
    assert int(ex["serve_served"]) > 50  # serving stayed live meanwhile
    assert np.isfinite(float(ex["serve_checksum"]))  # read path never saw it
    assert np.isfinite(np.asarray(w["a"])).all()
    # degraded-mode observability: staleness histogram recorded the serves
    assert int(ex["serve_stale_hist"].sum()) == int(ex["serve_served"])


def test_unguarded_poison_does_poison_the_read_path():
    # control for the test above: with the guard off, the same injection
    # must reach the served snapshots and blow up the checksum — proving
    # the previous test's finiteness is the guard's doing, not vacuous.
    def poison_grad(j, w, k):
        bad = (j == 3) & (k >= 200) & (k < 1200)
        g = w["a"] - _TARG[j]
        return {"a": jnp.where(bad, jnp.float32(jnp.inf), g)}

    _, ex = _run(_OVERLOAD, T=2000, guard=None, grad=poison_grad)
    assert not np.isfinite(float(ex["serve_checksum"]))


# ------------------------------------------------------------------ #
# host-oracle law parity for the merged race
# ------------------------------------------------------------------ #
def test_device_matches_host_oracle_law():
    # The serving marginal of the merged CTMC is independent of the
    # training state, so the standalone host simulation follows the same
    # law. Compare outcome fractions and mean sojourn, pooling device
    # seeds; the host side averages 20 independent horizons.
    cfg = ServingConfig(arrival_rate=2.5, serve_rate=3.0, queue_cap=5,
                        deadline=0.8, max_retries=1, backoff_base=0.2,
                        backoff_cap=0.8)
    dev = {"arrivals": 0, "served": 0, "shed": 0, "timed_out": 0,
           "sojourn": 0.0, "t": 0.0}
    for seed in range(3):
        _, ex = _run(cfg, T=4000, seed=seed)
        dev["arrivals"] += int(ex["serve_arrivals"])
        dev["served"] += int(ex["serve_served"])
        dev["shed"] += int(ex["serve_shed"])
        dev["timed_out"] += int(ex["serve_timed_out"]) + int(ex["serve_pending"])
        dev["sojourn"] += float(ex["serve_sojourn_sum"])
        dev["t"] += float(ex["serve_t_final"])
    horizon = dev["t"] / 3
    host = {"arrivals": 0, "served": 0, "shed": 0, "timed_out": 0}
    sjs = []
    for seed in range(20):
        h = simulate_serving_host(cfg, horizon, seed=seed)
        for k in host:
            host[k] += h[k]
        sjs += h["sojourns"]
    # arrival rate observed (Poisson thinning sanity on both planes)
    assert dev["arrivals"] / dev["t"] == pytest.approx(
        cfg.arrival_rate, rel=0.15)
    for k in ("served", "shed", "timed_out"):
        f_dev = dev[k] / dev["arrivals"]
        f_host = host[k] / host["arrivals"]
        assert abs(f_dev - f_host) < 0.06, (k, f_dev, f_host)
    w_dev = dev["sojourn"] / dev["served"]
    w_host = float(np.mean(sjs))
    assert w_dev == pytest.approx(w_host, rel=0.25)


def test_host_oracle_conservation():
    cfg = ServingConfig(arrival_rate=5.0, serve_rate=2.0, queue_cap=4,
                        deadline=0.5, max_retries=2)
    h = simulate_serving_host(cfg, 200.0, seed=1)
    assert h["arrivals"] == h["served"] + h["shed"] + h["timed_out"]
    assert h["shed"] > 0


# ------------------------------------------------------------------ #
# analytic plane: mixed product-form + tradeoff optimizer
# ------------------------------------------------------------------ #
def test_mixed_analysis_matches_simulated_plane():
    # no timeouts/bucket -> the serving factor is exactly M/M/1/K; the
    # simulated shed fraction and mean depth must match the closed form
    cfg = ServingConfig(arrival_rate=4.0, serve_rate=3.0, queue_cap=5)
    res = mixed_serving_analysis(
        _MU, _P, _C, arrival_rate=cfg.arrival_rate,
        serve_rate=cfg.serve_rate, queue_cap=cfg.queue_cap)
    shed, arr, qd, t = 0, 0, 0.0, 0.0
    for seed in range(3):
        _, ex = _run(cfg, T=4000, seed=seed)
        shed += int(ex["serve_shed"])
        arr += int(ex["serve_arrivals"])
        qd += float(ex["serve_qdepth_time"])
        t += float(ex["serve_t_final"])
    assert shed / arr == pytest.approx(res.block_prob, abs=0.035)
    assert qd / t == pytest.approx(res.mean_queue, rel=0.15)
    assert res.rho == pytest.approx(4.0 / 3.0)


def test_optimize_tradeoff_trades_throughput_for_slo():
    mu = np.concatenate([np.full(6, 4.0), np.full(6, 1.0)])
    k = BoundConstants(C=6, T=2000)
    sv = ServingConfig(arrival_rate=3.0, serve_rate=4.0, queue_cap=8)
    lam_u = mixed_serving_analysis(
        mu, np.full(12, 1 / 12), 6, arrival_rate=3.0, serve_rate=4.0,
        queue_cap=8).lambda_train
    t0 = optimize_tradeoff(mu, k, sv, weight=0.0,
                           update_capacity=1.2 * lam_u, iters=50)
    t5 = optimize_tradeoff(mu, k, sv, weight=5.0,
                           update_capacity=1.2 * lam_u, iters=50)
    # with the penalty active the optimum sacrifices training throughput
    # to relieve serve-plane interference...
    assert t5.serving.lambda_train < t0.serving.lambda_train
    # ...and buys a strictly better serving sojourn
    assert t5.serving.mean_sojourn < t0.serving.mean_sojourn
    # weight=0 degenerates to the plain bound optimizer
    g = optimize_general(mu, k, iters=50)
    assert t0.bound == pytest.approx(g.bound, rel=0.02)


# ------------------------------------------------------------------ #
# serving state checkpoints bitwise (rides the engine carry)
# ------------------------------------------------------------------ #
def test_serving_ckpt_truncate_and_resume_bitwise(tmp_path):
    d = str(tmp_path / "serve_ckpt")
    src_targ = np.linspace(-1, 1, _N)

    class _Src:
        def device_grad(self, j, w, k):
            return {"a": w["a"] - jnp.asarray(src_targ, jnp.float32)[j]}

    def run(resume):
        cfg = ServerConfig(
            n=_N, C=_C, T=400, eta=0.05, seed=3, engine="scan",
            stream="device", sparse=False, serving=_OVERLOAD,
            ckpt_dir=d, ckpt_every=100, resume=resume,
        )
        return run_generalized_async_sgd(_W0, _Src(), cfg)

    w_full, tr_full = run(False)
    for s in ck.available_steps(d):
        if s > 200:
            shutil.rmtree(os.path.join(d, f"step_{s:010d}"))
    w_res, tr_res = run(True)
    bits = lambda x: np.asarray(x).view(np.uint32)
    assert (bits(w_full["a"]) == bits(w_res["a"])).all()
    for k in tr_full.extras:
        if k.startswith("serve_"):
            assert np.array_equal(
                np.asarray(tr_full.extras[k]), np.asarray(tr_res.extras[k])
            ), k
