"""Smoke tests for the serving driver (`repro.launch.serve`).

The driver was previously exercised by no test or CI job and could rot
silently; these pin the public surface: decode-only mode (the historical
batched prefill+decode loop) and the coupled mode (training under live
serve traffic, then decode from the trained weights) both run on a tiny
smoke config with finite logits and exact request conservation.
"""
import numpy as np

from repro.launch.serve import run_serve

_TINY = [
    "--arch", "mamba2-130m", "--preset", "small",
    "--batch", "2", "--prompt-len", "4", "--steps", "4",
]


def test_decode_only_smoke():
    r = run_serve(_TINY + ["--train-steps", "0"])
    assert r["logits_finite"]
    assert r["generated"].shape == (2, 4)  # (batch, decode steps)
    assert r["tok_per_s"] > 0
    assert "serve_arrivals" not in r  # no training plane requested


def test_train_under_traffic_then_decode_smoke():
    r = run_serve(_TINY + [
        "--train-steps", "40", "--clients", "4", "--concurrency", "2",
        "--arrival-rate", "2.0", "--serve-rate", "4.0",
        "--deadline", "1.0", "--max-retries", "1",
    ])
    # decode plane: the trained weights produce finite logits
    assert r["logits_finite"]
    assert r["generated"].shape == (2, 4)
    # training plane: the merged run accounted for every request exactly
    arr = int(r["serve_arrivals"])
    acct = (int(r["serve_served"]) + int(r["serve_shed"])
            + int(r["serve_timed_out"]) + int(r["serve_pending"]))
    assert arr == acct
    assert int(r["serve_kg_step"]) > 0
    assert np.isfinite(float(r["serve_checksum"]))
    assert r["train_wall_s"] > 0
