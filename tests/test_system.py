"""End-to-end behaviour tests for the paper's system.

The full pipeline: heterogeneous clients -> Jackson analysis -> optimal
sampling -> asynchronous training -> the paper's qualitative claims hold.
"""
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import (
    BoundConstants,
    JacksonNetwork,
    SimConfig,
    asyncsgd_bound,
    fedbuff_bound,
    generalized_bound,
    optimal_eta,
    optimize_two_cluster,
    simulate,
)
from repro.fl import run_experiment, sampling_for
from repro.data.pipeline import make_client_speeds


class TestPaperClaims:
    """Each test pins one claim from the paper."""

    def test_claim_optimal_sampling_undersamples_fast(self):
        """§2 worked example: fast clients get p* < 1/n."""
        k = BoundConstants(A=100, L=1, B=20, C=10, T=10_000)
        res = optimize_two_cluster(8.0, 1.0, 100, 90, k)
        assert res.p[0] < 1.0 / 100

    def test_claim_delays_drop_under_optimal_sampling(self):
        """App. F.2: optimal sampling divides delays ~10x fast / ~2x slow."""
        n, n_f, C = 10, 5, 1000
        mu = np.array([1.2] * n_f + [1.0] * (n - n_f))
        uni = simulate(SimConfig(mu=mu, p=np.full(n, 1 / n), C=C, T=250_000, seed=0,
                                 record_delays=True))
        p_f = 7.5e-3
        p_opt = np.array([p_f] * n_f + [2 / n - p_f] * (n - n_f))
        opt = simulate(SimConfig(mu=mu, p=p_opt, C=C, T=250_000, seed=0,
                                 record_delays=True))
        d_uni = uni.mean_delay_per_node()
        d_opt = opt.mean_delay_per_node()
        fast_ratio = np.mean(d_uni[:n_f]) / np.mean(d_opt[:n_f])
        slow_ratio = np.mean(d_uni[n_f:]) / np.mean(d_opt[n_f:])
        # paper reports ~10x fast / ~2x slow at T=1e6 (fully stationary);
        # at T=2.5e5 the transient damps the measured ratios
        assert fast_ratio > 2.0
        assert slow_ratio > 1.5

    def test_claim_bounds_beat_baselines_under_exponential(self):
        """Table 1: with exponential service, tau_max is unbounded => FedBuff
        and AsyncSGD bounds are vacuous while Generalized AsyncSGD is finite."""
        k = BoundConstants(A=100, L=1, B=20, C=10, T=10_000)
        n = 100
        mu = np.array([8.0] * 90 + [1.0] * 10)
        p = np.full(n, 1 / n)
        net = JacksonNetwork(mu=mu, p=p, C=k.C)
        m = net.expected_delays()
        g = generalized_bound(optimal_eta(p, m, k), p, m, k)
        assert np.isfinite(g)
        assert fedbuff_bound(0.01, float("inf"), n, k) == float("inf")
        assert asyncsgd_bound(0.01, float("inf"), np.full(n, np.inf), k) == float("inf")

    def test_claim_training_ordering(self):
        """Fig. 6 / Table 2 ordering: GenAsync >= AsyncSGD > FedBuff at equal
        CS steps with heterogeneous speeds (synthetic stand-in for CIFAR)."""
        flc = FLConfig(n_clients=20, concurrency=10, server_steps=400,
                       speed_ratio=10.0, seed=1)
        accs = {}
        for m in ("gen_async", "async_sgd", "fedbuff"):
            accs[m] = run_experiment(flc, m, eta=0.08, eval_every=400).eval_acc[-1]
        assert accs["gen_async"] > accs["fedbuff"]
        assert accs["gen_async"] >= accs["async_sgd"] - 0.02

    def test_claim_transient_delays_stationary(self):
        """Fig. 1: m_{i,k} becomes stationary after a warmup ~ O(n)."""
        n = 10
        mu = np.array([10.0] * 5 + [1.0] * 5)
        p = np.full(n, 1 / n)
        res = simulate(SimConfig(mu=mu, p=p, C=n, T=20_000, seed=0, record_delays=True))
        d = np.asarray(res.delays[0], dtype=float)  # node 0 delays over time
        first, second = d[len(d) // 4 : len(d) // 2], d[len(d) // 2 :]
        assert abs(np.mean(first) - np.mean(second)) < 3 * np.std(d) / np.sqrt(len(d) / 4) + 1.0

    def test_sampling_for_policy_wiring(self):
        flc = FLConfig(n_clients=10, concurrency=5, server_steps=100, sampling="optimal")
        mu = make_client_speeds(10, 0.5, 10.0, seed=0)
        p = sampling_for(flc, mu)
        assert p.sum() == pytest.approx(1.0)
        assert p[mu == 10.0].mean() < p[mu == 1.0].mean()
        flc_u = FLConfig(n_clients=10, concurrency=5, server_steps=100, sampling="uniform")
        np.testing.assert_allclose(sampling_for(flc_u, mu), 0.1)
