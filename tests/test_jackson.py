"""Closed Jackson network: Buzen algorithm, stationary laws, delay estimates."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency (pip install .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core import (
    JacksonNetwork,
    SimConfig,
    buzen_normalizing_constants,
    gamma_ratio,
    simulate,
    three_cluster_delay_bounds,
    two_cluster_delay_bounds,
)


def _random_net(rng, n, C):
    mu = rng.uniform(0.5, 5.0, n)
    p = rng.uniform(0.1, 1.0, n)
    p /= p.sum()
    return JacksonNetwork(mu=mu, p=p, C=C)


class TestBuzen:
    def test_matches_bruteforce_distribution(self):
        rng = np.random.default_rng(0)
        for trial in range(5):
            net = _random_net(rng, n=3, C=4)
            bf = net.brute_force_distribution()
            assert sum(bf.values()) == pytest.approx(1.0)
            ql_bf = np.zeros(3)
            for s, v in bf.items():
                ql_bf += np.array(s) * v
            np.testing.assert_allclose(net.mean_queue_lengths(), ql_bf, rtol=1e-10)

    def test_normalizing_constant_bruteforce(self):
        theta = np.array([0.5, 1.0, 0.25])
        G = buzen_normalizing_constants(theta, 3)
        # H_3 = sum over compositions of 3 into 3 parts of prod theta^x
        from repro.core.jackson import _compositions

        H3 = sum(np.prod(theta ** np.array(x)) for x in _compositions(3, 3))
        assert G[3] == pytest.approx(H3)

    def test_tail_prob_identity(self):
        rng = np.random.default_rng(1)
        net = _random_net(rng, n=4, C=5)
        bf = net.brute_force_distribution()
        for i in range(4):
            for c in range(1, 6):
                truth = sum(v for s, v in bf.items() if s[i] >= c)
                assert net.queue_tail_prob(i, c) == pytest.approx(truth, abs=1e-12)

    @given(
        n=st.integers(2, 6),
        C=st.integers(1, 8),
        seed=st.integers(0, 10_000),
    )
    @settings(max_examples=30, deadline=None)
    def test_queue_lengths_sum_to_C(self, n, C, seed):
        net = _random_net(np.random.default_rng(seed), n, C)
        assert net.mean_queue_lengths().sum() == pytest.approx(C, rel=1e-9)

    @given(n=st.integers(2, 5), C=st.integers(2, 10), seed=st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_throughput_monotone_in_C(self, n, C, seed):
        rng = np.random.default_rng(seed)
        mu = rng.uniform(0.5, 5.0, n)
        p = rng.uniform(0.1, 1.0, n)
        p /= p.sum()
        lam1 = JacksonNetwork(mu=mu, p=p, C=C).throughput()
        lam2 = JacksonNetwork(mu=mu, p=p, C=C + 1).throughput()
        assert lam2 >= lam1 - 1e-12  # more tasks never reduce throughput
        assert lam1 <= mu.sum() + 1e-12


class TestAgainstSimulation:
    def test_time_avg_queue_lengths(self):
        mu = np.array([1.0, 2.0, 0.5])
        p = np.array([0.3, 0.3, 0.4])
        net = JacksonNetwork(mu=mu, p=p, C=4)
        res = simulate(SimConfig(mu=mu, p=p, C=4, T=150_000, seed=3))
        np.testing.assert_allclose(
            res.time_avg_queue_lengths(), net.mean_queue_lengths(), rtol=0.05
        )

    def test_throughput(self):
        mu = np.array([1.0, 2.0, 0.5])
        p = np.array([0.3, 0.3, 0.4])
        net = JacksonNetwork(mu=mu, p=p, C=4)
        res = simulate(SimConfig(mu=mu, p=p, C=4, T=150_000, seed=3))
        assert res.throughput() == pytest.approx(net.throughput(), rel=0.03)

    def test_palm_sojourn_times(self):
        """Arrival theorem + FIFO: E^{C-1}[S_i] = (E^{C-1}[X_i]+1)/mu_i."""
        mu = np.array([1.0, 2.0, 0.5])
        p = np.array([0.3, 0.3, 0.4])
        net = JacksonNetwork(mu=mu, p=p, C=4)
        res = simulate(SimConfig(mu=mu, p=p, C=4, T=150_000, seed=5, record_delays=True))
        theory = [(net.mean_queue_lengths(ntasks=3)[i] + 1) / mu[i] for i in range(3)]
        sim = [np.mean(d) for d in res.time_delays]
        np.testing.assert_allclose(sim, theory, rtol=0.05)

    def test_delay_estimates_order_and_scale(self):
        """m̂_i tracks simulation within ~35% and preserves ordering."""
        mu = np.array([3.0, 3.0, 1.0, 1.0])
        p = np.full(4, 0.25)
        net = JacksonNetwork(mu=mu, p=p, C=8)
        res = simulate(SimConfig(mu=mu, p=p, C=8, T=200_000, seed=7, record_delays=True))
        est = net.expected_delays()
        sim = res.mean_delay_per_node()
        assert est[2] > est[0]  # slow nodes wait longer (in steps)
        np.testing.assert_allclose(est, sim, rtol=0.35)
        # upper bound really bounds
        assert np.all(net.delay_upper_bounds() >= sim * 0.95)

    def test_little_law_in_steps(self):
        """Mean delay over completed tasks = C - 1 (each task sees C-1 others)."""
        mu = np.array([2.0, 1.0])
        p = np.array([0.6, 0.4])
        res = simulate(SimConfig(mu=mu, p=p, C=5, T=100_000, seed=11, record_delays=True))
        all_delays = np.concatenate([np.asarray(d) for d in res.delays])
        assert np.mean(all_delays) == pytest.approx(4.0, rel=0.03)


class TestSaturatedRegime:
    def test_paper_numerical_example(self):
        """Paper §4: n=10, mu_f=1.2, mu_s=1.0, C=1000 -> ~50 fast / ~1950 slow."""
        mu = np.array([1.2] * 5 + [1.0] * 5)
        net = JacksonNetwork(mu=mu, p=np.full(10, 0.1), C=1000)
        est = net.expected_delays()
        assert est[0] == pytest.approx(50.0, rel=0.15)
        assert est[-1] == pytest.approx(1950.0, rel=0.05)

    def test_two_cluster_closed_form(self):
        m_f, m_s = two_cluster_delay_bounds(n=10, n_f=5, mu_f=1.2, mu_s=1.0, C=1000)
        # paper: m_f <= ~5n (45.8 with lambda=11), m_s ~ 195 * lambda
        assert m_f == pytest.approx(45.83, rel=0.01)
        assert m_s == pytest.approx(2145.0, rel=0.01)

    def test_three_cluster_closed_form(self):
        m_f, m_m, m_s = three_cluster_delay_bounds(
            n=9, n_f=3, n_m=6, mu_f=10.0, mu_m=1.2, mu_s=1.0, C=1000
        )
        assert m_f < m_m < m_s
        lam = 3 * 10.0 + 3 * 1.2 + 3 * 1.0
        assert m_m == pytest.approx(lam / 1.2 / 0.2, rel=0.01)

    def test_gamma_ratio_limits(self):
        assert gamma_ratio(5, 1e6) == pytest.approx(1.0, abs=1e-6)
        assert 0.0 < gamma_ratio(5, 1.0) < 1.0
