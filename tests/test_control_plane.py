"""Vectorized control plane: new fast paths vs seed scalar semantics.

Covers the PR's acceptance invariants without optional dependencies:
  * batched / lfilter Buzen == pure-Python seed Buzen (<= 1e-10 relative),
  * O(C) single-node unconvolution/reconvolution round-trips,
  * vectorized mean_queue_lengths == seed per-node loop,
  * analytic simplex gradient == finite differences (both eta regimes),
  * the exact Little's-law identity sum_i p_i m_i = C - 1,
  * incremental simulator accumulators == O(n) shadow recomputation on
    identical seeds.
"""
import numpy as np
import pytest

from repro.core import (
    BoundConstants,
    ClosedNetworkSim,
    JacksonNetwork,
    SimConfig,
    batched_expected_delays,
    bound_for_p,
    bound_for_p_batch,
    bound_value_and_grad,
    buzen_add_node,
    buzen_normalizing_constants,
    buzen_remove_node,
    buzen_replace_node,
    optimize_general,
    simulate,
    simulate_batch,
)
from repro.core.jackson import _buzen_reference


def _random_simplex(rng, n):
    p = rng.uniform(0.1, 1.0, n)
    return p / p.sum()


class TestBuzenFastPaths:
    def test_scalar_matches_seed_reference(self):
        rng = np.random.default_rng(0)
        for n, C in [(3, 4), (20, 15), (100, 40)]:
            th = rng.uniform(0.05, 1.0, n)
            th /= th.max()
            G = buzen_normalizing_constants(th, C)
            G0 = _buzen_reference(th, C)
            np.testing.assert_allclose(G, G0, rtol=1e-10)

    def test_batched_matches_scalar(self):
        rng = np.random.default_rng(1)
        B, n, C = 9, 40, 25
        TH = rng.uniform(0.05, 1.0, (B, n))
        TH /= TH.max(axis=1, keepdims=True)
        GB = buzen_normalizing_constants(TH, C)
        assert GB.shape == (B, C + 1)
        for b in range(B):
            np.testing.assert_allclose(
                GB[b], buzen_normalizing_constants(TH[b], C), rtol=1e-10
            )

    def test_unconvolve_matches_smaller_network(self):
        rng = np.random.default_rng(2)
        th = rng.uniform(0.1, 1.0, 12)
        C = 10
        G = buzen_normalizing_constants(th, C)
        for i in (0, 5, 11):
            G_minus = buzen_remove_node(G, th[i])
            G_direct = buzen_normalizing_constants(np.delete(th, i), C)
            np.testing.assert_allclose(G_minus, G_direct, rtol=1e-10)

    def test_reconvolve_roundtrip_and_replace(self):
        rng = np.random.default_rng(3)
        th = rng.uniform(0.1, 1.0, 15)
        C = 12
        G = buzen_normalizing_constants(th, C)
        np.testing.assert_allclose(
            buzen_add_node(buzen_remove_node(G, th[4]), th[4]), G, rtol=1e-10
        )
        G_rep = buzen_replace_node(G, th[4], 0.63)
        th2 = th.copy()
        th2[4] = 0.63
        np.testing.assert_allclose(
            G_rep, buzen_normalizing_constants(th2, C), rtol=1e-10
        )

    def test_unconvolve_dominant_node_raises_instead_of_garbage(self):
        """Removing the dominant node cancels catastrophically; the primitive
        must refuse rather than silently return wrong constants."""
        th = np.full(64, 0.02)
        th[0] = 1.0
        G = buzen_normalizing_constants(th, 64)
        with pytest.raises(FloatingPointError):
            buzen_remove_node(G, th[0])

    def test_input_validation(self):
        with pytest.raises(ValueError):
            buzen_normalizing_constants(np.array([0.5, -1.0]), 3)
        with pytest.raises(ValueError):
            buzen_normalizing_constants(np.zeros((2, 2, 2)) + 0.5, 3)
        with pytest.raises(ValueError):
            buzen_normalizing_constants(np.array([0.5]), -1)


class TestVectorizedQueueLengths:
    def _mql_seed_loop(self, net, N):
        out = np.zeros(net.n)
        for i in range(net.n):
            pows = np.cumprod(np.full(N, net.theta[i]))
            out[i] = float(np.dot(pows, net._G[N - 1 :: -1][:N] / net._G[N]))
        return out

    def test_matches_seed_loop(self):
        rng = np.random.default_rng(4)
        for n, C in [(4, 3), (12, 20), (50, 8)]:
            net = JacksonNetwork(
                mu=rng.uniform(0.5, 5.0, n), p=_random_simplex(rng, n), C=C
            )
            for N in (C, C - 1):
                np.testing.assert_allclose(
                    net.mean_queue_lengths(ntasks=N),
                    self._mql_seed_loop(net, N),
                    rtol=1e-10,
                )

    def test_occupancy_matrix_consistent(self):
        rng = np.random.default_rng(5)
        net = JacksonNetwork(mu=rng.uniform(0.5, 5.0, 7), p=_random_simplex(rng, 7), C=9)
        E = net.occupancy_matrix()
        assert E.shape == (7, 10)
        for N in range(10):
            np.testing.assert_allclose(
                E[:, N], net.mean_queue_lengths(ntasks=N), rtol=1e-8, atol=1e-12
            )
        # population constraint at every column
        np.testing.assert_allclose(E.sum(axis=0), np.arange(10), rtol=1e-9)

    def test_littles_law_identity(self):
        """sum_i p_i m_i = C - 1 exactly (normalized), = C exactly (raw)."""
        rng = np.random.default_rng(6)
        for n, C in [(5, 2), (10, 8), (30, 12)]:
            mu = rng.uniform(0.5, 5.0, n)
            p = _random_simplex(rng, n)
            net = JacksonNetwork(mu=mu, p=p, C=C)
            assert float(p @ net.expected_delays()) == pytest.approx(C - 1, abs=1e-10)
            assert float(p @ net.expected_delays(normalized=False)) == pytest.approx(
                C, abs=1e-10
            )

    def test_batched_delays_match_scalar(self):
        rng = np.random.default_rng(7)
        n, C = 16, 11
        mu = rng.uniform(0.5, 5.0, n)
        P = np.stack([_random_simplex(rng, n) for _ in range(6)])
        m_b, lam_b = batched_expected_delays(mu, P, C)
        for b in range(6):
            net = JacksonNetwork(mu=mu, p=P[b], C=C)
            np.testing.assert_allclose(m_b[b], net.expected_delays(), rtol=1e-10)
            assert lam_b[b] == pytest.approx(net.throughput(), rel=1e-10)


class TestAnalyticGradient:
    def _fd_projected(self, mu, p, k, h=1e-7):
        """Central difference of f(q/sum q) — the simplex-projected gradient."""
        fd = np.zeros(p.size)
        for i in range(p.size):
            qp = p.copy()
            qp[i] += h
            qm = p.copy()
            qm[i] -= h
            fd[i] = (
                bound_for_p(mu, qp / qp.sum(), k)[0]
                - bound_for_p(mu, qm / qm.sum(), k)[0]
            ) / (2 * h)
        return fd

    def test_matches_finite_difference_interior_eta(self):
        rng = np.random.default_rng(8)
        mu = rng.uniform(0.5, 8.0, 8)
        p = _random_simplex(rng, 8)
        k = BoundConstants(A=100, L=1, B=20, C=6, T=2_000)
        _, _, _, g = bound_value_and_grad(mu, p, k)
        g_proj = g - float(g @ p)
        fd = self._fd_projected(mu, p, k)
        np.testing.assert_allclose(g_proj, fd, rtol=1e-5, atol=1e-8 * np.abs(fd).max())

    def test_matches_finite_difference_capped_eta(self):
        """When eta* sits on the eta_max cap the d eta_max/dp term kicks in."""
        from repro.core import eta_max, optimal_eta

        rng = np.random.default_rng(9)
        mu = rng.uniform(0.5, 8.0, 8)
        p = _random_simplex(rng, 8)
        k = BoundConstants(A=1e7, L=1, B=20, C=6, T=50)
        _, eta, m, g = bound_value_and_grad(mu, p, k)
        assert eta == pytest.approx(eta_max(p, m, k))  # cap really active
        g_proj = g - float(g @ p)
        fd = self._fd_projected(mu, p, k)
        np.testing.assert_allclose(g_proj, fd, rtol=1e-5, atol=1e-8 * np.abs(fd).max())

    def test_batched_bound_matches_scalar(self):
        rng = np.random.default_rng(10)
        n = 12
        mu = rng.uniform(0.5, 6.0, n)
        k = BoundConstants(C=5, T=3_000)
        P = np.stack([_random_simplex(rng, n) for _ in range(5)])
        vals, etas, ms = bound_for_p_batch(mu, P, k)
        for b in range(5):
            v0, e0, m0 = bound_for_p(mu, P[b], k)
            assert vals[b] == pytest.approx(v0, rel=1e-10)
            assert etas[b] == pytest.approx(e0, rel=1e-10)
            np.testing.assert_allclose(ms[b], m0, rtol=1e-10)

    def test_analytic_optimizer_not_worse_than_fd(self):
        rng = np.random.default_rng(11)
        mu = rng.uniform(0.5, 8.0, 8)
        k = BoundConstants(C=6, T=2_000)
        res_an = optimize_general(mu, k, iters=40)
        res_fd = optimize_general(mu, k, iters=40, method="fd")
        assert res_an.bound <= res_fd.bound * 1.02
        assert res_an.bound <= res_an.uniform_bound + 1e-12


class TestIncrementalSimulator:
    @pytest.mark.parametrize("service", ["exp", "det"])
    def test_accumulators_match_shadow_recompute(self, service):
        """New O(1) counters == the seed O(n)-per-step recomputation, same seed."""
        cfg = SimConfig(
            mu=np.array([1.0, 2.0, 0.5, 3.0, 1.5]),
            p=np.array([0.3, 0.25, 0.2, 0.15, 0.1]),
            C=6,
            T=3_000,
            service=service,
            seed=13,
        )
        sim = ClosedNetworkSim(cfg)
        shadow_sum = np.zeros(sim.n)
        shadow_tw = np.zeros(sim.n)
        for _ in range(cfg.T):
            q_pre = sim.queue_lengths()
            t_pre = sim.now
            sim.step()
            shadow_tw += q_pre * (sim.now - t_pre)
            shadow_sum += sim.queue_lengths()
        np.testing.assert_allclose(sim.queue_len_sum, shadow_sum, rtol=1e-10)
        np.testing.assert_allclose(sim.queue_len_tw, shadow_tw, rtol=1e-10)

    def test_mid_run_reads_are_consistent(self):
        """The lazy flush must be transparent to interleaved reads."""
        cfg = SimConfig(
            mu=np.array([1.0, 0.7, 2.0]), p=np.array([0.5, 0.3, 0.2]), C=4, T=200, seed=1
        )
        sim = ClosedNetworkSim(cfg)
        shadow_sum = np.zeros(sim.n)
        for k in range(cfg.T):
            sim.step()
            shadow_sum += sim.queue_lengths()
            if k % 17 == 0:  # interleaved reads must not disturb the counters
                np.testing.assert_allclose(sim.queue_len_sum, shadow_sum, rtol=1e-12)
                assert sim.total_tasks() == cfg.C
        np.testing.assert_allclose(sim.queue_len_sum, shadow_sum, rtol=1e-12)

    def test_simulate_batch_deterministic_and_equivalent(self):
        cfg = SimConfig(
            mu=np.array([1.0, 2.0]), p=np.array([0.6, 0.4]), C=3, T=2_000, seed=42
        )
        r1 = simulate_batch(cfg, block=512)
        r2 = simulate_batch(cfg, block=512)
        np.testing.assert_array_equal(r1.J, r2.J)
        np.testing.assert_array_equal(r1.t, r2.t)
        # block size changes the realization, not the law: invariants hold
        r3 = simulate_batch(cfg, block=64)
        assert r3.queue_len_last.sum() == cfg.C
        assert np.all(np.diff(r3.t) >= 0)

    def test_stationary_laws_still_hold(self):
        """End-to-end: the rewritten simulator still matches the closed forms."""
        mu = np.array([1.0, 2.0, 0.5])
        p = np.array([0.3, 0.3, 0.4])
        net = JacksonNetwork(mu=mu, p=p, C=4)
        res = simulate(SimConfig(mu=mu, p=p, C=4, T=120_000, seed=3, record_delays=True))
        np.testing.assert_allclose(
            res.time_avg_queue_lengths(), net.mean_queue_lengths(), rtol=0.05
        )
        assert res.throughput() == pytest.approx(net.throughput(), rel=0.03)
        all_delays = np.concatenate([np.asarray(d) for d in res.delays])
        assert np.mean(all_delays) == pytest.approx(net.C - 1, rel=0.03)
