"""Event-driven simulator invariants: flat delay-recording semantics
(dependency-free) + hypothesis property tests (optional dev dependency)."""
import numpy as np
import pytest

from repro.core import ClosedNetworkSim, SimConfig, export_stream, simulate

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency
    HAVE_HYPOTHESIS = False


class TestDelayRecordingSemantics:
    """The flat-array invariant documented in the queue_sim module docstring:
    delay records are stored flat in completion order — record k belongs to
    node J[k] — and every per-node view is a lazy regrouping of that pair."""

    def _cfg(self, **kw):
        mu = np.array([2.0, 1.0, 0.5, 1.5])
        return SimConfig(mu=mu, p=np.full(4, 0.25), C=3, T=400, seed=7,
                         record_delays=True, **kw)

    def test_flat_array_invariant(self):
        cfg = self._cfg()
        stream = export_stream(cfg)
        # flat form: one int64 record per CS step, aligned with (J, K, t) —
        # a CS-step delay is bounded by T, which exceeds int32 on T > 2^31
        assert stream.delay_steps is not None
        assert stream.delay_steps.shape == (cfg.T,)
        assert stream.delay_steps.dtype == np.int64
        assert np.all(stream.delay_steps >= 0)
        # record k is the delay of the task completing at step k (node J[k]):
        # regrouping the flat pair by J in event order IS the per-node view
        regrouped = [
            stream.delay_steps[stream.J == i].tolist()
            for i in range(stream.n)
        ]
        assert regrouped == stream.delays
        # the simulator's own flat property agrees with the exported stream
        sim = ClosedNetworkSim(cfg)
        sim.run(cfg.T)
        np.testing.assert_array_equal(sim.delay_steps, stream.delay_steps)
        assert sim.delays == stream.delays

    def test_off_by_default(self):
        cfg = SimConfig(mu=np.ones(3), p=np.full(3, 1 / 3), C=2, T=50, seed=0)
        stream = export_stream(cfg)
        assert stream.delay_steps is None and stream.delays is None
        res = simulate(cfg)
        assert res.delays is None
        with pytest.raises(ValueError, match="record_delays"):
            res.mean_delay_per_node()


if HAVE_HYPOTHESIS:

    @st.composite
    def sim_configs(draw):
        n = draw(st.integers(2, 8))
        C = draw(st.integers(1, 12))
        T = draw(st.integers(10, 300))
        seed = draw(st.integers(0, 2**16))
        service = draw(st.sampled_from(["exp", "det"]))
        mu = np.array([draw(st.floats(0.2, 8.0)) for _ in range(n)])
        praw = np.array([draw(st.floats(0.05, 1.0)) for _ in range(n)])
        return SimConfig(mu=mu, p=praw / praw.sum(), C=C, T=T, service=service, seed=seed,
                         record_delays=True)

    class TestInvariantsHypothesis:
        @given(cfg=sim_configs())
        @settings(max_examples=40, deadline=None)
        def test_task_conservation(self, cfg):
            """Closed network: total in-flight tasks constant == C at every step."""
            sim = ClosedNetworkSim(cfg)
            assert sim.total_tasks() == cfg.C
            for _ in range(min(cfg.T, 100)):
                sim.step()
                assert sim.total_tasks() == cfg.C

        @given(cfg=sim_configs())
        @settings(max_examples=20, deadline=None)
        def test_time_monotone_and_delays_nonnegative(self, cfg):
            res = simulate(cfg)
            assert np.all(np.diff(res.t) >= 0)
            for d in res.delays:
                assert all(x >= 0 for x in d)

        @given(cfg=sim_configs())
        @settings(max_examples=20, deadline=None)
        def test_completions_at_busy_nodes_only(self, cfg):
            sim = ClosedNetworkSim(cfg)
            for _ in range(min(cfg.T, 80)):
                before = sim.queue_lengths()
                j, k = sim.step()
                assert before[j] >= 1  # complete only where a task was queued


class TestInvariants:
    def test_deterministic_given_seed(self):
        cfg = SimConfig(mu=np.array([1.0, 2.0]), p=np.array([0.5, 0.5]), C=3, T=500, seed=42)
        r1, r2 = simulate(cfg), simulate(cfg)
        np.testing.assert_array_equal(r1.J, r2.J)
        np.testing.assert_array_equal(r1.K, r2.K)
        np.testing.assert_array_equal(r1.t, r2.t)

    def test_deterministic_service_faster_node_completes_more(self):
        mu = np.array([4.0, 1.0])
        res = simulate(SimConfig(mu=mu, p=np.array([0.5, 0.5]), C=2, T=5000, service="det", seed=0))
        counts = np.bincount(res.J, minlength=2)
        assert counts[0] > counts[1]

    def test_routing_follows_p(self):
        p = np.array([0.8, 0.2])
        res = simulate(SimConfig(mu=np.array([1.0, 1.0]), p=p, C=4, T=20_000, seed=1))
        frac = np.bincount(res.K, minlength=2) / res.K.size
        np.testing.assert_allclose(frac, p, atol=0.02)

    def test_exp_vs_det_same_mean_similar_delays(self):
        """Paper: delay statistics barely depend on the service distribution."""
        mu = np.array([2.0] * 3 + [1.0] * 3)
        p = np.full(6, 1 / 6)
        d_exp = simulate(SimConfig(mu=mu, p=p, C=12, T=60_000, service="exp", seed=0,
                                   record_delays=True)).mean_delay_per_node()
        d_det = simulate(SimConfig(mu=mu, p=p, C=12, T=60_000, service="det", seed=0,
                                   record_delays=True)).mean_delay_per_node()
        np.testing.assert_allclose(d_exp, d_det, rtol=0.25)
