"""Large-n scaling fixes (million-client closed network).

Locks the four numeric bugfixes and the sparse O(C) stream:

  * segment-tree dispatch is unbiased at n = 1e5 (the fp32 inverse-CDF
    clamp it replaces biased the tail) and never selects zero-weight leaves;
  * Kahan-compensated time accumulators keep advancing past the fp32
    stall point t ~ 2^24 (regression fails on a plain float32 sum);
  * log-space Buzen stays finite where the linear convolution overflows,
    inverts exactly (add/remove roundtrip), and at n = 1e6, C = 1e3 the
    class-collapsed constants reproduce the MVA throughput to <= 1e-5;
  * `delay_steps` exports are int64 end to end (int32 wraps on T > 2^31);
  * the sparse class-collapsed stream matches the dense (n,C) oracle in
    law — occupancy, delay moments, completion shares, fault kind
    counts — and the class-collapsed control plane matches the dense
    recurrences to <= 1e-5.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import stream_device as sd
from repro.core.engine_scan import make_runner
from repro.core.jackson import (
    buzen_log_add_node,
    buzen_log_normalizing_constants,
    buzen_log_remove_node,
    buzen_normalizing_constants,
)
from repro.core.queue_sim import FaultConfig, SimConfig, export_stream
from repro.core.sampling import _mva_delays_f64, optimize_general
from repro.core.stream_device import (
    BoundConstants,
    build_class_spec,
    generate_stream,
    kahan_add,
    kahan_value,
    mva_throughput_delays,
    tree_build,
    tree_sample,
    tree_update,
)


def _two_class_mu(n, seed=7, frac=0.3, ratio=2.5):
    rng = np.random.default_rng(seed)
    return np.where(rng.random(n) < frac, ratio, 1.0)


# ------------------------------------------------------------------ #
# segment-tree sampler: unbiasedness at large n, zero-weight safety
# ------------------------------------------------------------------ #
class TestSegmentTree:
    def test_chi_square_unbiased_at_1e5(self):
        """Group frequencies of 1e5-leaf draws match the weight shares.

        The clamped fp32 inverse-CDF this replaces systematically starved
        the tail at this size (cumsum error ~ n*eps pushes late boundaries
        past 1.0); the pairwise tree keeps O(log n) ulp error and the
        descent re-splits mass exactly, so a chi-square over weight groups
        must accept.
        """
        from stat_utils import assert_chi_square

        n, groups, S = 100_000, 10, 40_000
        per = n // groups
        # group g has constant leaf weight g+1; last group weight 0
        w = np.repeat(np.arange(1.0, groups + 1), per).astype(np.float32)
        w[-per:] = 0.0
        tree = tree_build(jnp.asarray(w))
        u = jax.random.uniform(jax.random.PRNGKey(0), (S,))
        idx = np.asarray(jax.jit(jax.vmap(lambda x: tree_sample(tree, x)))(u))
        assert idx.min() >= 0 and idx.max() < n
        assert np.all(w[idx] > 0), "zero-weight leaf selected"
        got = np.bincount(idx // per, minlength=groups)[: groups - 1]
        share = np.arange(1.0, groups) / np.arange(1.0, groups).sum()
        assert_chi_square(got, S * share, df=groups - 2, label="tree groups")

    def test_zero_weight_boundaries(self):
        """Interior zeros and u at the CDF edges never pick a dead leaf."""
        w = jnp.asarray([0.0, 2.0, 0.0, 0.0, 1.0, 0.0, 3.0, 0.0])
        tree = tree_build(w)
        us = jnp.asarray([0.0, 2.0 / 6.0, 3.0 / 6.0, 1.0 - 1e-7, 0.5])
        idx = np.asarray(jax.vmap(lambda x: tree_sample(tree, x))(us))
        assert np.all(np.asarray(w)[idx] > 0)

    def test_update_matches_rebuild(self):
        rng = np.random.default_rng(3)
        w = rng.uniform(0.0, 2.0, 37).astype(np.float32)
        tree = tree_build(jnp.asarray(w))
        for i, v in [(0, 5.0), (36, 0.0), (17, 1.25)]:
            w[i] = v
            tree = tree_update(tree, i, jnp.float32(v))
        np.testing.assert_allclose(np.asarray(tree),
                                   np.asarray(tree_build(jnp.asarray(w))),
                                   rtol=1e-6, atol=1e-6)


# ------------------------------------------------------------------ #
# Kahan time accumulators: regression past the fp32 stall point
# ------------------------------------------------------------------ #
class TestKahanAccumulator:
    def test_plain_fp32_stalls_kahan_does_not(self):
        """At t ~ 2^25 a 0.5-step increment rounds to zero in fp32.

        This is the exact failure mode of the old `StreamState.t` on
        T ~ 1e8 runs; the compensated pair keeps the true total to
        float64 accuracy.  The plain-sum assertion is the regression:
        it fails if anyone reverts the accumulator to a bare float32.
        """
        t0, dt, steps = np.float32(2.0**25), np.float32(0.5), 400
        plain = t0
        s, c = t0, np.float32(0.0)
        for _ in range(steps):
            plain = np.float32(plain + dt)
            s, c = kahan_add(s, c, dt)
        assert plain == t0, "fp32 stall regime changed — update the test"
        total = kahan_value(s, c)
        assert abs(total - (float(t0) + 0.5 * steps)) < 1e-3

    def test_kahan_matches_float64_on_random_stream(self):
        rng = np.random.default_rng(0)
        dts = rng.exponential(0.01, 20_000).astype(np.float32)
        s, c = np.float32(2.0**24), np.float32(0.0)
        for dt in dts:
            s, c = kahan_add(s, c, dt)
        exact = 2.0**24 + np.sum(dts.astype(np.float64))
        assert abs(kahan_value(s, c) - exact) / exact < 1e-7


# ------------------------------------------------------------------ #
# log-space Buzen: overflow-free normalizing constants
# ------------------------------------------------------------------ #
class TestLogBuzen:
    def test_matches_linear_small(self):
        theta = np.random.default_rng(1).uniform(0.2, 2.0, 8)
        C = 12
        lG = buzen_log_normalizing_constants(theta, C)
        np.testing.assert_allclose(
            lG, np.log(buzen_normalizing_constants(theta, C)),
            rtol=1e-10, atol=1e-10)

    def test_counts_collapse_matches_expanded(self):
        theta_m = np.array([0.4, 1.1, 2.3])
        counts = np.array([4, 5, 6])
        C = 10
        lG_c = buzen_log_normalizing_constants(theta_m, C, counts=counts)
        lG_d = buzen_log_normalizing_constants(np.repeat(theta_m, counts), C)
        np.testing.assert_allclose(lG_c, lG_d, rtol=1e-9, atol=1e-9)

    def test_finite_where_linear_overflows(self):
        theta = np.full(50, 100.0)
        C = 200
        with np.errstate(over="ignore", invalid="ignore"):
            G = buzen_normalizing_constants(theta, C)
        assert not np.all(np.isfinite(G)), "overflow regime changed"
        lG = buzen_log_normalizing_constants(theta, C)
        assert np.all(np.isfinite(lG))
        # closed-form check: G[c] = binom(c + 49, 49) 100^c in this
        # symmetric case; test the throughput ratio instead of G itself
        lam = np.exp(lG[C - 1] - lG[C])  # = (C/(C+49))/100
        np.testing.assert_allclose(lam, (C / (C + 49.0)) / 100.0, rtol=1e-10)

    def test_add_remove_roundtrip(self):
        theta = np.random.default_rng(2).uniform(0.3, 3.0, 6)
        C = 16
        lG = buzen_log_normalizing_constants(theta, C)
        lth = float(np.log(theta[2]))
        lG_removed = buzen_log_remove_node(lG, lth)
        np.testing.assert_allclose(
            lG_removed,
            buzen_log_normalizing_constants(np.delete(theta, 2), C),
            rtol=1e-8, atol=1e-8)
        np.testing.assert_allclose(buzen_log_add_node(lG_removed, lth), lG,
                                   rtol=1e-8, atol=1e-8)

    def test_million_node_constants_match_mva(self):
        """n = 1e6, C = 1e3: class-collapsed log-Buzen vs f64 MVA.

        The linear convolution cannot represent these constants at all
        (G[C] ~ 1e3800); the NB-series log path computes them in O(m*C^2)
        and its throughput G[C-1]/G[C] must agree with the (independent)
        counts-weighted MVA recurrence.
        """
        n, C = 1_000_000, 1_000
        counts = np.array([300_000, 700_000])
        mu_m = np.array([2.5, 1.0])
        p_m = np.full(2, 1.0 / n)
        lG = buzen_log_normalizing_constants(p_m / mu_m, C, counts=counts)
        assert np.all(np.isfinite(lG))
        lam_buzen = np.exp(lG[C - 1] - lG[C])
        _, lam_mva = _mva_delays_f64(mu_m, p_m, counts, C)
        np.testing.assert_allclose(lam_buzen, lam_mva, rtol=1e-5)


# ------------------------------------------------------------------ #
# int64 delay exports
# ------------------------------------------------------------------ #
class TestInt64Delays:
    def test_host_export_int64(self):
        cfg = SimConfig(mu=np.ones(5), p=np.full(5, 0.2), C=3, T=200,
                        seed=1, record_delays=True)
        assert export_stream(cfg).delay_steps.dtype == np.int64

    def test_host_fault_export_int64(self):
        cfg = SimConfig(mu=np.ones(5), p=np.full(5, 0.2), C=3, T=200,
                        seed=1, record_delays=True,
                        fault=FaultConfig(off_rate=0.05, on_rate=0.5))
        assert export_stream(cfg).delay_steps.dtype == np.int64

    def test_device_export_int64(self):
        stream = generate_stream(np.ones(5), np.full(5, 0.2), C=3, T=200,
                                 seed=0)
        assert stream.delay_steps.dtype == np.int64


# ------------------------------------------------------------------ #
# sparse O(C) stream vs dense (n,C) oracle: law-level parity
# ------------------------------------------------------------------ #
class TestSparseDenseParity:
    @pytest.mark.parametrize("n,T", [(1_000, 20_000), (10_000, 8_000)])
    def test_stream_law_parity(self, n, T):
        """Occupancy, delay moment and completion shares agree in law.

        Realizations differ (the sparse race runs over C + m clocks, the
        dense over n), so the comparison is distributional: per-class
        time-averaged occupancy and completion shares within sampling
        noise, total occupancy exactly C, mean delay at the Little's-law
        value C-1 for both.
        """
        C = 64
        mu = _two_class_mu(n)
        p = np.full(n, 1.0 / n)
        spec, mu_m, p_m = build_class_spec(mu, p)
        sdev = spec.device()

        gen_s = sd.sparse_stats_stream_fn(spec.m, C, T)
        st_s, state = jax.jit(
            lambda k, mu_, p_: gen_s(k, mu_, p_, sdev)
        )(jax.random.PRNGKey(0), jnp.asarray(mu_m, jnp.float32),
          jnp.asarray(p_m, jnp.float32))
        gen_d = sd.stats_stream_fn(n, C, T)
        st_d = jax.jit(gen_d)(jax.random.PRNGKey(1),
                              jnp.asarray(mu, jnp.float32),
                              jnp.asarray(p, jnp.float32))

        # dense per-node stats aggregated to classes via the spec layout
        inv = np.asarray(spec.inv_cls)
        m = spec.m

        def agg(x):
            return np.bincount(inv, weights=np.asarray(x, np.float64),
                               minlength=m)

        t_s = kahan_value(state.t, state.t_c)
        occ_s = kahan_value(st_s.occ_tw, st_s.occ_tw_c) / t_s
        occ_d = agg(kahan_value(st_d.occ_tw, st_d.occ_tw_c))
        occ_d /= occ_d.sum() / C  # normalize by the dense run's own t
        np.testing.assert_allclose(occ_s.sum(), C, rtol=1e-5)
        np.testing.assert_allclose(occ_s / C, occ_d / C, atol=0.05)

        comp_s = np.asarray(st_s.comp, np.float64)
        comp_d = agg(st_d.comp)
        assert comp_s.sum() == T and comp_d.sum() == T
        np.testing.assert_allclose(comp_s / T, comp_d / T, atol=0.03)

        delay_s = float(kahan_value(st_s.delay_sum, st_s.delay_sum_c).sum())
        delay_d = float(kahan_value(st_d.delay_sum, st_d.delay_sum_c).sum())
        assert abs(delay_s / T - (C - 1)) < 0.5 * np.sqrt(C)
        assert abs(delay_s / T - delay_d / T) < 0.5 * np.sqrt(C)

        # and the sparse run matches the class-collapsed MVA occupancy:
        # Q_c = count_c p_c m_c C/(C-1) (Little + the (C-1)/C convention)
        md, _ = mva_throughput_delays(mu_m, p_m, C,
                                      counts=tuple(int(c)
                                                   for c in spec.counts))
        occ_mva = (np.asarray(spec.counts) * np.asarray(p_m)
                   * np.asarray(md, np.float64) * C / (C - 1.0))
        np.testing.assert_allclose(occ_s, occ_mva, rtol=0.05)

    def test_fault_kind_count_parity(self):
        """Sparse and dense fault streams see the same event mix."""
        n, C, T = 1_000, 8, 4_000
        mu = _two_class_mu(n)
        p = np.full(n, 1.0 / n)
        spec, mu_m, p_m = build_class_spec(mu, p)
        fc = FaultConfig(crash_rate=0.02, timeout_rate=0.05,
                         off_rate=0.01, on_rate=0.3)
        d = 4
        c = np.random.default_rng(0).normal(size=(n, d)).astype(np.float32)
        c_dev = jnp.asarray(c)
        grad = lambda j, w, k: w - c_dev[j]  # noqa: E731

        run_d = make_runner(grad, C=C, stream="device", n=n, T=T, fault=fc)
        run_s = make_runner(grad, C=C, stream="device", n=n, T=T, fault=fc,
                            classes=spec)
        kc_d = np.zeros(4)
        kc_s = np.zeros(4)
        for seed in range(2):
            _, _, ex = jax.jit(run_d)(
                jnp.zeros(d), jnp.asarray(mu, jnp.float32),
                jnp.asarray(p, jnp.float32), jax.random.PRNGKey(seed), 0.05)
            kc_d += np.asarray(ex["kind_count"], np.float64)
            _, _, ex = jax.jit(run_s)(
                jnp.zeros(d), jnp.asarray(mu_m, jnp.float32),
                jnp.asarray(p_m, jnp.float32), jax.random.PRNGKey(seed), 0.05)
            kc_s += np.asarray(ex["kind_count"], np.float64)
        np.testing.assert_allclose(kc_d / kc_d.sum(), kc_s / kc_s.sum(),
                                   atol=0.04)

    def test_sparse_requires_class_constant_fault_rates(self):
        n = 100
        mu = _two_class_mu(n)
        spec, _, _ = build_class_spec(mu, np.full(n, 1.0 / n))
        fc = FaultConfig(crash_rate=np.linspace(0.01, 0.2, n))
        with pytest.raises(ValueError, match="varies within speed class"):
            sd.resolve_fault_rates_classes(fc, spec)


# ------------------------------------------------------------------ #
# class-collapsed control plane vs dense recurrences
# ------------------------------------------------------------------ #
class TestControlPlaneCollapse:
    @pytest.mark.parametrize("n", [1_000, 10_000])
    def test_mva_counts_matches_dense(self, n):
        """Collapsed f32 MVA hits the f64 truth to <= 1e-5; dense to f32 noise.

        The counts-weighted recurrence sums m terms where the dense one
        sums n, so the collapsed path is the *tighter* of the two in
        float32 — the dense cross-check tolerance covers its own
        length-n dot-product rounding (~n*eps), not a model difference.
        """
        C = 32
        mu = _two_class_mu(n)
        p = np.full(n, 1.0 / n, np.float32)
        spec, mu_m, p_m = build_class_spec(mu, p)
        md_c, lam_c = mva_throughput_delays(
            mu_m, p_m, C, counts=tuple(int(c) for c in spec.counts))
        md_d, lam_d = mva_throughput_delays(mu, p, C)
        md_64, lam_64 = _mva_delays_f64(np.asarray(mu_m, np.float64),
                                        np.asarray(p_m, np.float64),
                                        np.asarray(spec.counts), C)
        np.testing.assert_allclose(float(lam_c), lam_64, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(md_c), md_64, rtol=1e-5)
        np.testing.assert_allclose(float(lam_d), lam_64, rtol=1e-4)
        np.testing.assert_allclose(
            np.asarray(md_c)[np.asarray(spec.inv_cls)], np.asarray(md_d),
            rtol=1e-4)

    def test_optimize_general_collapse_matches_dense(self):
        n = 2_000
        mu = _two_class_mu(n)
        k = BoundConstants(C=16, T=4_000)
        dense = optimize_general(mu, k, iters=30, collapse=False)
        coll = optimize_general(mu, k, iters=30, collapse=True)
        assert coll.p.shape == (n,)
        np.testing.assert_allclose(coll.bound, dense.bound, rtol=1e-3)
        np.testing.assert_allclose(coll.uniform_bound, dense.uniform_bound,
                                   rtol=1e-6)
        # same optimum: per-class mass within a relative hair
        np.testing.assert_allclose(np.sort(coll.p), np.sort(dense.p),
                                   rtol=5e-2)

    def test_optimize_general_runs_at_1e6(self):
        mu = _two_class_mu(50_000)  # the collapsed path is O(m*C) per iter:
        k = BoundConstants(C=16, T=4_000)  # size-independent above this
        res = optimize_general(mu, k, iters=10)
        assert res.p.shape == (50_000,)
        assert np.isfinite(res.bound)
        np.testing.assert_allclose(res.p.sum(), 1.0, rtol=1e-6)


# ------------------------------------------------------------------ #
# ServerConfig wiring: sparse="auto"/True picks the collapsed engine
# ------------------------------------------------------------------ #
class TestServerConfigSparse:
    class _Quadratic:
        def __init__(self, n, d=4, seed=0):
            rng = np.random.default_rng(seed)
            self.c = rng.normal(size=(n, d)).astype(np.float32)
            self.c_dev = jnp.asarray(self.c)
            self.d = d

        def grad(self, i, w, k):
            return w - self.c[i]

        def device_grad(self, j, w, k):
            return w - self.c_dev[j]

    def test_sparse_true_matches_dense_in_law(self):
        from repro.core import ServerConfig, run_generalized_async_sgd

        n, C, T = 300, 8, 2_000
        mu = _two_class_mu(n)
        prob = self._Quadratic(n)
        target = prob.c.mean(0)

        outs = {}
        for sparse in (False, True):
            cfg = ServerConfig(n=n, C=C, T=T, eta=0.05, mu=mu, seed=0,
                               engine="scan", stream="device", sparse=sparse)
            w, trace = run_generalized_async_sgd(
                np.zeros(prob.d, np.float32), prob, cfg)
            mql = np.asarray(trace.mean_queue_lengths, np.float64)
            assert mql.shape == (n,)
            np.testing.assert_allclose(mql.sum(), C, rtol=1e-3)
            assert trace.extras["p_final"].shape == (n,)
            outs[sparse] = np.linalg.norm(np.asarray(w) - target)
        assert outs[True] < 5 * max(outs[False], 0.05)
        assert outs[False] < 5 * max(outs[True], 0.05)
