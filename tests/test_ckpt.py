"""Checkpoint codec + engine kill-and-resume tests.

Two layers:

1. ``repro.ckpt.checkpoint`` codec round-trips: mixed-dtype trees (incl.
   the bf16 uint-view encoding), NamedTuple treedefs, rotation bookkeeping
   and user metadata.
2. ``repro.core.engine_ckpt`` resume semantics: truncating the checkpoint
   directory to an intermediate step and re-running with ``resume=True``
   must reproduce the uninterrupted run *bitwise* — across device/host
   streams, per-event/blocked paths and fp32/bf16 snapshot rings — and a
   genuinely SIGKILLed process (slow test) must resume the same way.
"""
import os
import shutil
import signal
import subprocess
import sys
import textwrap
from typing import NamedTuple

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ck
from repro.core import (
    FaultConfig,
    GuardConfig,
    SimConfig,
    blocked_inputs,
    export_blocks,
    export_stream,
    run_checkpointed,
    run_checkpointed_host,
    run_checkpointed_host_blocked,
    step_scales,
)
from repro.core import engine_scan as es

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


def _bits(tree):
    """Concatenate all leaves as raw bytes (bitwise comparison helper)."""
    return np.concatenate(
        [np.asarray(x).ravel().view(np.uint8) for x in jax.tree_util.tree_leaves(tree)]
    )


def _zeros_like(tree):
    return jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), tree)


# ---------------------------------------------------------------------------
# codec round-trips
# ---------------------------------------------------------------------------


class _Inner(NamedTuple):
    m: jnp.ndarray
    v: jnp.ndarray


class _Outer(NamedTuple):
    w: dict
    opt: _Inner
    step: jnp.ndarray


def test_roundtrip_mixed_dtypes(tmp_path):
    tree = {
        "f32": jnp.linspace(-3.0, 7.0, 11, dtype=jnp.float32),
        "bf16": jnp.linspace(-2.0, 2.0, 9).astype(jnp.bfloat16),
        "i32": jnp.arange(-4, 4, dtype=jnp.int32),
        "nested": (jnp.ones((2, 3), jnp.float32), {"u": jnp.zeros(5, jnp.int32)}),
    }
    ck.save(str(tmp_path), 7, tree)
    back = ck.restore(str(tmp_path), 7, _zeros_like(tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
    assert (_bits(tree) == _bits(back)).all()


def test_roundtrip_namedtuple_tree(tmp_path):
    tree = _Outer(
        w={"a": jnp.full(4, 1.5, jnp.bfloat16)},
        opt=_Inner(m=jnp.ones(3, jnp.float32), v=jnp.full(3, 0.25, jnp.float32)),
        step=jnp.asarray(17, jnp.int32),
    )
    ck.save(str(tmp_path), 3, tree)
    back = ck.restore(str(tmp_path), 3, _zeros_like(tree))
    assert isinstance(back, _Outer) and isinstance(back.opt, _Inner)
    assert (_bits(tree) == _bits(back)).all()


def test_bf16_codec_is_bitwise_exact(tmp_path):
    # adversarial bit patterns: every exponent, NaN payloads, signed zeros.
    # An astype(float32) round-trip would normalize some of these; the
    # uint16-view codec must preserve them verbatim.
    bits = np.arange(0, 1 << 16, 7, dtype=np.uint16)
    arr = jnp.asarray(bits).view(jnp.bfloat16)
    ck.save(str(tmp_path), 1, {"x": arr})
    back = ck.restore(str(tmp_path), 1, {"x": jnp.zeros_like(arr)})
    assert np.asarray(back["x"]).dtype == np.asarray(arr).dtype
    assert (np.asarray(back["x"]).view(np.uint16) == bits).all()


def test_rotation_latest_and_metadata(tmp_path):
    tree = {"x": jnp.arange(3, dtype=jnp.float32)}
    for s in (10, 20, 30, 40):
        ck.save(str(tmp_path), s, tree, metadata={"step": s, "tag": "t"}, keep=3)
    assert ck.available_steps(str(tmp_path)) == [20, 30, 40]
    assert ck.latest_step(str(tmp_path)) == 40
    meta = ck.load_metadata(str(tmp_path), 30)
    assert meta["step"] == 30 and meta["tag"] == "t"


# ---------------------------------------------------------------------------
# truncate-and-resume bitwise across engine paths
# ---------------------------------------------------------------------------

_N, _C, _T = 8, 4, 200
_MU = np.linspace(0.5, 2.0, _N).astype(np.float32)
_P = np.full(_N, 1 / _N, np.float32)
_W0 = {"a": jnp.zeros(6, jnp.float32), "b": jnp.ones(3, jnp.float32)}
_TARG = jnp.arange(_N, dtype=jnp.float32)
_FAULT = FaultConfig(off_rate=0.3, on_rate=1.0, crash_rate=0.1, timeout_rate=0.2)


def _grad(j, w, k):
    return jax.tree_util.tree_map(lambda x: x - _TARG[j], w)


def _loss(w):
    return sum(jnp.sum(x**2) for x in jax.tree_util.tree_leaves(w))


def _truncate(d, keep_step):
    for s in ck.available_steps(d):
        if s > keep_step:
            shutil.rmtree(os.path.join(d, f"step_{s:010d}"))


def _host_arrays():
    cfg = SimConfig(mu=_MU, p=_P, C=_C, T=_T, seed=5, fault=_FAULT)
    stream = export_stream(cfg)
    scale = es.step_scales(stream, 0.05, _P, "importance")
    return cfg, stream, scale


def _run_fused_f32(d, resume):
    return run_checkpointed(
        _grad, _N, _C, _T, w0=_W0, mu=_MU, p0=_P, key=jax.random.PRNGKey(3),
        eta=0.05, ckpt_dir=d, ckpt_every=50, eval_fn=_loss, eval_every=25,
        adaptive=True, refresh_every=25, fault=_FAULT,
        guard=GuardConfig(max_grad_norm=100.0, stale_cutoff=50), resume=resume,
    )


def _run_fused_bf16_blocked(d, resume):
    return run_checkpointed(
        _grad, _N, _C, _T, w0=_W0, mu=_MU, p0=_P, key=jax.random.PRNGKey(3),
        eta=0.05, ckpt_dir=d, ckpt_every=50, eval_fn=_loss, eval_every=50,
        block_size=8, snapshot_dtype=jnp.bfloat16, fault=_FAULT,
        guard=GuardConfig(max_grad_norm=100.0, stale_cutoff=50), resume=resume,
    )


def _run_host_bf16(d, resume):
    _, stream, scale = _host_arrays()
    return run_checkpointed_host(
        _grad, _C, _W0, stream.J, stream.slot, scale,
        ckpt_dir=d, ckpt_every=50, eval_fn=_loss, eval_every=25,
        guard=GuardConfig(max_grad_norm=100.0), snapshot_dtype=jnp.bfloat16,
        resume=resume,
    )


def _run_host_blocked(d, resume):
    cfg, stream, scale = _host_arrays()
    blocks = export_blocks(cfg, block_size=8, cut_every=50)
    J, slot, sc, k, mask, cb, nc = blocked_inputs(blocks, scale, eval_every=50)
    return run_checkpointed_host_blocked(
        _grad, _C, 8, _W0, J, slot, sc, k, mask,
        group_events=50, chunk_blocks=cb, n_chunks=nc,
        ckpt_dir=d, ckpt_every=50, eval_fn=_loss,
        guard=GuardConfig(max_grad_norm=100.0), resume=resume,
    )


_PATHS = {
    "fused_f32": _run_fused_f32,
    "fused_bf16_blocked": _run_fused_bf16_blocked,
    "host_bf16": _run_host_bf16,
    "host_blocked": _run_host_blocked,
}


@pytest.mark.parametrize("path", sorted(_PATHS))
def test_truncate_and_resume_bitwise(tmp_path, path):
    run = _PATHS[path]
    d = str(tmp_path / path)
    full = run(d, False)
    _truncate(d, 100)
    res = run(d, True)
    assert (_bits(full[0]) == _bits(res[0])).all()
    ef, er = np.asarray(full[1]), np.asarray(res[1])
    assert ef.shape == er.shape and (ef == er).all()


def test_resume_from_final_checkpoint_is_noop(tmp_path):
    # cursor == T in the latest checkpoint: resume must not re-run the tail.
    d = str(tmp_path / "final")
    full = _run_host_bf16(d, False)
    res = _run_host_bf16(d, True)
    assert (_bits(full[0]) == _bits(res[0])).all()
    assert (np.asarray(full[1]) == np.asarray(res[1])).all()


def test_resume_fingerprint_mismatch_raises(tmp_path):
    d = str(tmp_path / "fp")
    _, stream, scale = _host_arrays()
    kwargs = dict(ckpt_dir=d, ckpt_every=50, eval_fn=_loss, eval_every=25,
                  snapshot_dtype=jnp.bfloat16)
    run_checkpointed_host(
        _grad, _C, _W0, stream.J, stream.slot, scale,
        guard=GuardConfig(max_grad_norm=100.0), resume=False, **kwargs)
    with pytest.raises(ValueError, match="fingerprint"):
        run_checkpointed_host(
            _grad, _C, _W0, stream.J, stream.slot, scale,
            guard=GuardConfig(max_grad_norm=99.0), resume=True, **kwargs)


def test_run_experiment_resume_bitwise(tmp_path):
    from repro.configs.base import FLConfig
    from repro.fl.engine import run_experiment

    flc = FLConfig(n_clients=8, concurrency=4, server_steps=120, seed=1,
                   engine="scan")
    fault = FaultConfig(off_rate=0.2, on_rate=1.0, crash_rate=0.05,
                        timeout_rate=0.1)
    guard = GuardConfig(max_grad_norm=1e3, stale_cutoff=80)
    d = str(tmp_path / "fl")
    r1 = run_experiment(flc, "gen_async", eval_every=60, faults=fault,
                        guard=guard, ckpt_dir=d, ckpt_every=60)
    _truncate(d, 60)
    r2 = run_experiment(flc, "gen_async", eval_every=60, faults=fault,
                        guard=guard, ckpt_dir=d, ckpt_every=60, resume=True)
    assert (_bits(r1.final_params) == _bits(r2.final_params)).all()


# ---------------------------------------------------------------------------
# background-writer failure surfacing (_AsyncSaver)
# ---------------------------------------------------------------------------


def test_async_saver_unwritable_dir_raises_and_reaps(tmp_path, monkeypatch):
    # A read-only checkpoint dir (EROFS; the test injects the failure at
    # the save layer because the suite may run as root, which chmod does
    # not stop).  The background writer captures the OSError; the driver
    # must surface it at the next put()/close() AND reap the worker thread
    # — the regression was a writer thread leaked forever on the queue
    # when put() raised.
    import threading

    from repro.core import engine_ckpt as ec

    def boom(*a, **k):
        raise OSError(30, "Read-only file system")

    monkeypatch.setattr(ec, "_save_state", boom)
    before = threading.active_count()
    with pytest.raises(OSError, match="Read-only file system"):
        _run_fused_f32(str(tmp_path / "ro"), False)
    assert threading.active_count() == before  # no leaked writer thread


def test_async_saver_abort_idempotent(tmp_path, monkeypatch):
    import time

    from repro.core import engine_ckpt as ec

    def boom(*a, **k):
        raise OSError(28, "No space left on device")

    monkeypatch.setattr(ec, "_save_state", boom)
    saver = ec._AsyncSaver(str(tmp_path), "fp", keep=2)
    saver.put(1, {"x": np.zeros(2)}, np.zeros(1))
    for _ in range(200):  # wait for the worker to capture the failure
        if saver._err is not None:
            break
        time.sleep(0.01)
    assert saver._err is not None
    with pytest.raises(OSError, match="No space left"):
        saver.put(2, {"x": np.zeros(2)}, np.zeros(1))
    assert not saver._worker.is_alive()  # put() reaped it before raising
    saver.abort()  # idempotent after the reap
    saver.abort()
    with pytest.raises(OSError, match="No space left"):
        saver.close()  # close still surfaces the captured error


# ---------------------------------------------------------------------------
# true kill-and-resume: child process SIGKILLs itself mid-run
# ---------------------------------------------------------------------------

_CHILD = textwrap.dedent("""
    import os, signal, sys
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.ckpt import checkpoint as ck
    from repro.core import FaultConfig, GuardConfig, run_checkpointed

    n_saves = [0]
    _orig_save = ck.save

    def killing_save(*args, **kwargs):
        _orig_save(*args, **kwargs)
        n_saves[0] += 1
        if n_saves[0] == 2:
            os.kill(os.getpid(), signal.SIGKILL)

    ck.save = killing_save

    targ = jnp.arange(8, dtype=jnp.float32)
    def grad(j, w, k):
        return jax.tree_util.tree_map(lambda x: x - targ[j], w)

    run_checkpointed(
        grad, 8, 4, 200,
        w0={{"a": jnp.zeros(6, jnp.float32), "b": jnp.ones(3, jnp.float32)}},
        mu=np.linspace(0.5, 2.0, 8).astype(np.float32),
        p0=np.full(8, 1 / 8, np.float32), key=jax.random.PRNGKey(3), eta=0.05,
        ckpt_dir=sys.argv[1], ckpt_every=50,
        fault=FaultConfig(off_rate=0.3, on_rate=1.0, crash_rate=0.1,
                          timeout_rate=0.2),
        guard=GuardConfig(max_grad_norm=100.0, stale_cutoff=50))
    raise SystemExit("child survived past the kill point")
""")


@pytest.mark.slow
def test_sigkill_mid_run_then_resume_bitwise(tmp_path):
    d_kill = str(tmp_path / "killed")
    d_ref = str(tmp_path / "reference")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD.format(src=SRC), d_kill],
        capture_output=True, text=True, timeout=300,
    )
    assert proc.returncode == -signal.SIGKILL, (proc.returncode, proc.stderr)
    steps = ck.available_steps(d_kill)
    assert steps and max(steps) < 200, steps  # died mid-run with real ckpts

    def run(d, resume):
        return run_checkpointed(
            _grad, _N, _C, _T, w0=_W0, mu=_MU, p0=_P,
            key=jax.random.PRNGKey(3), eta=0.05, ckpt_dir=d, ckpt_every=50,
            fault=_FAULT,
            guard=GuardConfig(max_grad_norm=100.0, stale_cutoff=50),
            resume=resume)

    resumed = run(d_kill, True)
    reference = run(d_ref, False)
    assert (_bits(resumed[0]) == _bits(reference[0])).all()
