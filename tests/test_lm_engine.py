"""Real-model path: per-leaf codec, kernel VJPs, LMTask engine parity.

Four layers:

1. ``_snapshot_codec`` per-leaf packing: mixed *float* trees (bf16 matmul
   weights + fp32 norms) pack into one promoted-dtype master vector and
   unpack back to the original per-leaf dtypes; non-float leaves fall back
   to per-leaf buffers; ``snapshot_dtype`` composes on top.
2. Kernel VJPs: ``jax.grad`` through the Pallas ``flash_attention`` /
   ``ssd_scan`` / ``moe_gmm`` wrappers must match ``jax.grad`` through the
   jnp references (the custom_vjp backward IS the reference VJP — this
   pins the wiring: residuals, nondiff args, cotangent structure).
3. ``_cached_fl_setup`` memoization keys on (seed, task.cache_key()), not
   on the seed alone: two different tasks over the same dataset must not
   silently share one model (regression test).
4. LMTask end-to-end: the compiled scan engine (per-event and blocked)
   reproduces the per-event Python LM loop on identical shards, a mixed
   bf16/fp32 tree trains through the blocked flat-packed ring, and an LM
   run checkpoints and resumes bitwise.
"""
import os
import shutil

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ck
from repro.configs import smoke_config
from repro.configs.base import FLConfig
from repro.core import engine_scan as es
from repro.data.pipeline import FederatedClassification
from repro.fl import ClassificationTask, LMTask, run_experiment
from repro.fl.engine import DeviceTaskClients, TaskSetup, _cached_fl_setup
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.ssd_scan import ssd_scan

_rng = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


def _tiny_cfg():
    return smoke_config("granite-3-2b").replace(
        num_layers=1, d_model=32, num_heads=1, num_kv_heads=1, head_dim=32,
        d_ff=64, vocab_size=64)


def _bits(tree):
    return np.concatenate(
        [np.asarray(x).ravel().view(np.uint8) for x in jax.tree_util.tree_leaves(tree)]
    )


# ---------------------------------------------------------------------------
# kernel VJPs vs reference VJPs
# ---------------------------------------------------------------------------


class TestKernelVJP:
    """Grads through the kernel wrappers vs grads through the references.

    The probe loss ``sum(out * probe)`` keeps the cotangent independent of
    the kernel's forward rounding (in bf16 the kernel and reference
    *forwards* differ by one ulp in places; a nonlinear loss would feed
    that difference back through the cotangent and swamp the comparison).
    What remains is exactly what the test pins: the custom_vjp wiring —
    residuals, nondiff-arg plumbing and cotangent structure.
    """

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_flash_attention_grads(self, dtype):
        q = jnp.asarray(_rng.normal(size=(1, 64, 2, 32)), dtype)
        k = jnp.asarray(_rng.normal(size=(1, 64, 1, 32)), dtype)
        v = jnp.asarray(_rng.normal(size=(1, 64, 1, 32)), dtype)
        probe = jnp.asarray(_rng.normal(size=(1, 64, 2, 32)), jnp.float32)

        def loss(fn):
            return lambda q, k, v: jnp.sum(fn(q, k, v).astype(jnp.float32) * probe)

        gk = jax.grad(loss(lambda q, k, v: flash_attention(q, k, v, bq=32, bk=32)),
                      argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(loss(ref.flash_attention_ref), argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gr):
            assert a.dtype == b.dtype
            np.testing.assert_allclose(
                a.astype(jnp.float32), b.astype(jnp.float32), **_tol(dtype)
            )

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_ssd_scan_grads(self, dtype):
        x = jnp.asarray(_rng.normal(size=(1, 64, 2, 16)), dtype)
        dt = jnp.asarray(_rng.uniform(0.01, 0.2, (1, 64, 2)), jnp.float32)
        A = -jnp.asarray(_rng.uniform(0.5, 2.0, (2,)), jnp.float32)
        Bm = jnp.asarray(_rng.normal(size=(1, 64, 8)), dtype)
        Cm = jnp.asarray(_rng.normal(size=(1, 64, 8)), dtype)
        py = jnp.asarray(_rng.normal(size=(1, 64, 2, 16)), jnp.float32)
        ps = jnp.asarray(_rng.normal(size=(1, 2, 8, 16)), jnp.float32)

        def loss(fn):
            def f(x, dt, A, Bm, Cm):
                y, s = fn(x, dt, A, Bm, Cm, chunk=32)
                return jnp.sum(y.astype(jnp.float32) * py) + jnp.sum(s * ps)
            return f

        gk = jax.grad(loss(ssd_scan), argnums=(0, 1, 2, 3, 4))(x, dt, A, Bm, Cm)
        gr = jax.grad(loss(ref.ssd_scan_ref), argnums=(0, 1, 2, 3, 4))(x, dt, A, Bm, Cm)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(
                a.astype(jnp.float32), b.astype(jnp.float32), **_tol(dtype)
            )

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_moe_gmm_grads(self, dtype):
        x = jnp.asarray(_rng.normal(size=(2, 64, 64)), dtype)
        w = jnp.asarray(_rng.normal(size=(2, 64, 64)), dtype)
        probe = jnp.asarray(_rng.normal(size=(2, 64, 64)), jnp.float32)

        def loss(fn):
            return lambda x, w: jnp.sum(fn(x, w).astype(jnp.float32) * probe)

        gk = jax.grad(loss(lambda x, w: moe_gmm(x, w, bc=64, bf=64, bd=64)),
                      argnums=(0, 1))(x, w)
        gr = jax.grad(loss(ref.moe_gmm_ref), argnums=(0, 1))(x, w)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(
                a.astype(jnp.float32), b.astype(jnp.float32), **_tol(dtype)
            )


# ---------------------------------------------------------------------------
# per-leaf snapshot codec
# ---------------------------------------------------------------------------


class TestSnapshotCodec:
    def test_mixed_float_tree_roundtrips(self):
        w0 = {
            "w": jnp.asarray(_rng.normal(size=(4, 3)), jnp.bfloat16),
            "norm": jnp.asarray(_rng.normal(size=(3,)), jnp.float32),
        }
        pack, unpack, enc = es._snapshot_codec(w0)
        assert pack is not None
        flat = pack(w0)
        assert flat.dtype == jnp.float32  # bf16 + fp32 promotes to fp32
        back = unpack(flat)
        assert back["w"].dtype == jnp.bfloat16
        assert back["norm"].dtype == jnp.float32
        # bf16 -> fp32 -> bf16 is lossless, fp32 passes through untouched
        assert (_bits(back) == _bits(w0)).all()

    def test_uniform_bf16_tree_roundtrips(self):
        w0 = {"a": jnp.asarray(_rng.normal(size=(5,)), jnp.bfloat16)}
        pack, unpack, _ = es._snapshot_codec(w0)
        flat = pack(w0)
        assert flat.dtype == jnp.bfloat16
        assert (_bits(unpack(flat)) == _bits(w0)).all()

    def test_int_leaf_falls_back_to_per_leaf(self):
        w0 = {"a": jnp.zeros((3,), jnp.float32), "steps": jnp.zeros((), jnp.int32)}
        assert es._snapshot_codec(w0) == (None, None, None)

    def test_snapshot_dtype_rejects_non_float_tree(self):
        w0 = {"a": jnp.zeros((3,), jnp.float32), "steps": jnp.zeros((), jnp.int32)}
        with pytest.raises(ValueError, match="all-float"):
            es._snapshot_codec(w0, snapshot_dtype="bfloat16")

    def test_snapshot_dtype_on_mixed_float_tree(self):
        w0 = {
            "w": jnp.asarray(_rng.normal(size=(4,)), jnp.bfloat16),
            "norm": jnp.asarray(_rng.normal(size=(2,)), jnp.float32),
        }
        pack, unpack, enc = es._snapshot_codec(w0, snapshot_dtype="bfloat16")
        stored = enc(pack(w0))
        assert stored.dtype == jnp.bfloat16
        back = unpack(stored.astype(jnp.float32))
        assert back["w"].dtype == jnp.bfloat16
        assert back["norm"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# setup-cache memoization (regression: key must include the task)
# ---------------------------------------------------------------------------


class TestCachedSetup:
    def test_two_tasks_same_data_get_distinct_models(self):
        data = FederatedClassification(n_clients=6, seed=0)
        s16 = _cached_fl_setup(data, 0, task=ClassificationTask(hidden=16))
        s32 = _cached_fl_setup(data, 0, task=ClassificationTask(hidden=32))
        shapes16 = [x.shape for x in jax.tree_util.tree_leaves(s16.params)]
        shapes32 = [x.shape for x in jax.tree_util.tree_leaves(s32.params)]
        assert shapes16 != shapes32  # pre-fix: seed-only key returned s16 twice

    def test_equal_task_config_hits_cache(self):
        data = FederatedClassification(n_clients=6, seed=0)
        s1 = _cached_fl_setup(data, 0, task=ClassificationTask(hidden=16))
        s2 = _cached_fl_setup(data, 0, task=ClassificationTask(hidden=16))
        assert s1 is s2

    def test_dataset_free_task_caches_on_task(self):
        task = LMTask(cfg=_tiny_cfg(), batch_size=1, seq_len=8, shard_size=16)
        s1 = _cached_fl_setup(None, 0, task=task, n_clients=4)
        s2 = _cached_fl_setup(None, 0, task=task, n_clients=4)
        assert s1 is s2
        assert "_fl_setup_cache" in task.__dict__


# ---------------------------------------------------------------------------
# LMTask through the engines
# ---------------------------------------------------------------------------


def _lm_run(task, engine, *, T=16, block_size=1, n=6, C=3, seed=0, **kw):
    flc = FLConfig(n_clients=n, concurrency=C, server_steps=T,
                   sampling="uniform", seed=seed, block_size=block_size)
    return run_experiment(flc, "gen_async", eta=0.05, eval_every=T // 2,
                          engine=engine, task=task, **kw)


class TestLMEngineParity:
    def test_scan_matches_python_tiny(self):
        task = LMTask(cfg=_tiny_cfg(), batch_size=1, seq_len=8, shard_size=16)
        r_py = _lm_run(task, "python")
        r_sc = _lm_run(task, "scan")
        np.testing.assert_allclose(r_sc.eval_acc, r_py.eval_acc, atol=1e-4)
        for a, b in zip(jax.tree_util.tree_leaves(r_sc.final_params),
                        jax.tree_util.tree_leaves(r_py.final_params)):
            np.testing.assert_allclose(a, b, atol=1e-4)

    def test_scan_matches_python_smoke_config(self):
        # the acceptance row: the real smoke transformer, not a toy
        task = LMTask(cfg=smoke_config("granite-3-2b"), batch_size=2,
                      seq_len=16, shard_size=32)
        r_py = _lm_run(task, "python", T=8, n=4, C=2)
        r_sc = _lm_run(task, "scan", T=8, n=4, C=2)
        np.testing.assert_allclose(r_sc.eval_acc, r_py.eval_acc, atol=1e-4)

    def test_blocked_matches_python_tiny(self):
        task = LMTask(cfg=_tiny_cfg(), batch_size=1, seq_len=8, shard_size=16)
        r_py = _lm_run(task, "python")
        r_bl = _lm_run(task, "scan", block_size=4)
        np.testing.assert_allclose(r_bl.eval_acc, r_py.eval_acc, atol=1e-4)

    def test_training_reduces_loss(self):
        task = LMTask(cfg=_tiny_cfg(), batch_size=2, seq_len=8, shard_size=32)
        r = _lm_run(task, "scan", T=64)
        assert np.isfinite(r.eval_acc).all()
        assert r.eval_acc[-1] < r.eval_acc[0]  # eval metric is the LM loss


# ---------------------------------------------------------------------------
# mixed-dtype tree through the blocked flat-packed ring
# ---------------------------------------------------------------------------


class _MixedLinearTask:
    """Duck-typed task: linear regression with a bf16 weight + fp32 bias."""

    def cache_key(self):
        return ("mixed-linear-test", id(self))

    def build(self, data, seed, n_clients):
        rng = np.random.default_rng(seed)
        xs = rng.normal(size=(n_clients, 32, 3)).astype(np.float32)
        ys = rng.normal(size=(n_clients, 32)).astype(np.float32)

        def loss(params, batch):
            pred = batch["x"] @ params["w"].astype(jnp.float32) + params["b"]
            return jnp.mean((pred - batch["y"]) ** 2)

        clients = DeviceTaskClients(loss, {"x": xs, "y": ys}, batch_size=4, seed=seed)
        params = {"w": jnp.ones((3,), jnp.bfloat16), "b": jnp.zeros((), jnp.float32)}
        ev = {"x": jnp.asarray(xs[0]), "y": jnp.asarray(ys[0])}
        return TaskSetup(params=params, clients=clients,
                         eval_fn=jax.jit(lambda p: loss(p, ev)))


class TestMixedDtypeEngine:
    def test_blocked_matches_per_event_exactly(self):
        # both flat-packed paths carry the same fp32 master vector, so
        # blocked vs per-event must agree to float tolerance
        task = _MixedLinearTask()
        r1 = _lm_run(task, "scan", block_size=1)
        r4 = _lm_run(task, "scan", block_size=4)
        np.testing.assert_allclose(r4.eval_acc, r1.eval_acc, atol=1e-6)
        assert r1.final_params["w"].dtype == jnp.bfloat16
        assert r1.final_params["b"].dtype == jnp.float32

    def test_tracks_python_loop(self):
        # the python loop updates the bf16 leaf in bf16, the flat-packed
        # engine in the fp32 master vector — agreement is loose by design
        task = _MixedLinearTask()
        r_py = _lm_run(task, "python")
        r_sc = _lm_run(task, "scan")
        np.testing.assert_allclose(r_sc.eval_acc, r_py.eval_acc, atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# LM checkpoint / resume
# ---------------------------------------------------------------------------


class TestLMResume:
    def test_truncate_and_resume_bitwise(self, tmp_path):
        task = LMTask(cfg=_tiny_cfg(), batch_size=1, seq_len=8, shard_size=16)
        d = str(tmp_path / "lm")
        r1 = _lm_run(task, "scan", T=32, ckpt_dir=d, ckpt_every=16)
        for s in ck.available_steps(d):
            if s > 16:
                shutil.rmtree(os.path.join(d, f"step_{s:010d}"))
        r2 = _lm_run(task, "scan", T=32, ckpt_dir=d, ckpt_every=16, resume=True)
        assert (_bits(r1.final_params) == _bits(r2.final_params)).all()
