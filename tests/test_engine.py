"""Compiled scan engine: parity vs the Python reference loop, exported
event-stream properties, and weighted_update kernel parity.

The hypothesis-based property tests are optional (pip install .[dev]); the
deterministic checks below them cover the same invariants dependency-free.
"""
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    ServerConfig,
    SimConfig,
    export_stream,
    make_runner,
    run_fedbuff,
    run_generalized_async_sgd,
    step_scales,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency
    HAVE_HYPOTHESIS = False


class Quadratic:
    """Clients hold quadratics f_i(w) = 0.5 ||w - c_i||^2, in both host
    (GradientSource) and device (DeviceGradientSource) form — the parity
    oracle: identical event stream => identical iterates up to float assoc."""

    def __init__(self, n, d=4, seed=0):
        rng = np.random.default_rng(seed)
        self.c = rng.normal(size=(n, d)).astype(np.float32)
        self.c_dev = jnp.asarray(self.c)
        self.d = d

    def grad(self, i, w, k):
        return w - self.c[i]

    def device_grad(self, j, w, k):
        return w - self.c_dev[j]


def _nonuniform_p(n, seed=1):
    p = np.random.default_rng(seed).uniform(0.5, 1.5, n)
    return p / p.sum()


# ------------------------------------------------------------------ #
# parity: scan engine vs Python reference on identical event streams
# ------------------------------------------------------------------ #
class TestScanParity:
    N, T = 8, 1200

    @pytest.mark.parametrize("C", [1, 4, 8])  # C == n at 8
    @pytest.mark.parametrize("weighting", ["importance", "plain"])
    def test_gen_async_parity(self, C, weighting):
        prob = Quadratic(self.N)
        cfg = ServerConfig(
            n=self.N, C=C, T=self.T, eta=0.02, p=_nonuniform_p(self.N),
            seed=3, weighting=weighting,
        )
        w_py, _ = run_generalized_async_sgd(np.zeros(prob.d, np.float32), prob, cfg)
        cfg_scan = replace(cfg, engine="scan")
        w_sc, _ = run_generalized_async_sgd(np.zeros(prob.d, np.float32), prob, cfg_scan)
        np.testing.assert_allclose(np.asarray(w_sc), w_py, atol=1e-5)

    @pytest.mark.parametrize("Z", [1, 5])
    def test_fedbuff_parity(self, Z):
        prob = Quadratic(self.N)
        cfg = ServerConfig(n=self.N, C=4, T=self.T, eta=0.05, seed=0, weighting="plain")
        w_py, _ = run_fedbuff(np.zeros(prob.d, np.float32), prob, cfg, Z=Z)
        cfg_scan = replace(cfg, engine="scan")
        w_sc, _ = run_fedbuff(np.zeros(prob.d, np.float32), prob, cfg_scan, Z=Z)
        np.testing.assert_allclose(np.asarray(w_sc), w_py, atol=1e-5)

    def test_eval_curve_parity(self):
        """The chunked outer scan evaluates at the same steps as the Python
        loop and sees the same iterates."""
        prob = Quadratic(self.N)
        cfg = ServerConfig(n=self.N, C=4, T=500, eta=0.02, seed=7, eval_every=100)
        cfg_scan = replace(cfg, engine="scan")
        _, tr_py = run_generalized_async_sgd(
            np.zeros(prob.d, np.float32), prob, cfg, eval_fn=lambda w: float(np.sum(np.asarray(w) ** 2))
        )
        _, tr_sc = run_generalized_async_sgd(
            np.zeros(prob.d, np.float32), prob, cfg_scan, eval_fn=lambda w: jnp.sum(w**2)
        )
        assert tr_sc.eval_steps == tr_py.eval_steps
        np.testing.assert_allclose(tr_sc.eval_values, tr_py.eval_values, atol=1e-5)

    def test_trace_metadata_parity(self):
        """times / delays / mean queue lengths come from the same stream."""
        prob = Quadratic(self.N)
        cfg = ServerConfig(n=self.N, C=4, T=400, eta=0.02, seed=5)
        _, tr_py = run_generalized_async_sgd(np.zeros(prob.d, np.float32), prob, cfg)
        cfg_scan = replace(cfg, engine="scan")
        _, tr_sc = run_generalized_async_sgd(np.zeros(prob.d, np.float32), prob, cfg_scan)
        np.testing.assert_allclose(tr_sc.times, tr_py.times)
        np.testing.assert_allclose(tr_sc.mean_queue_lengths, tr_py.mean_queue_lengths)
        assert tr_sc.delays == tr_py.delays

    def test_pallas_update_path_matches_jnp(self):
        prob = Quadratic(self.N, d=37)  # non-tile-aligned parameter
        cfg = ServerConfig(n=self.N, C=4, T=200, eta=0.02, seed=2, engine="scan")
        w_jnp, _ = run_generalized_async_sgd(np.zeros(prob.d, np.float32), prob, cfg)
        cfg_pl = replace(cfg, update="pallas")
        w_pl, _ = run_generalized_async_sgd(np.zeros(prob.d, np.float32), prob, cfg_pl)
        np.testing.assert_allclose(np.asarray(w_pl), np.asarray(w_jnp), atol=1e-6)

    def test_vmap_over_seeds(self):
        """One vmapped call over stacked streams == per-seed scan runs."""
        n, C, T, eta = 6, 3, 300, 0.03
        prob = Quadratic(n)
        p = _nonuniform_p(n)
        streams = [
            export_stream(SimConfig(mu=np.ones(n), p=p, C=C, T=T, seed=s))
            for s in (0, 1, 2)
        ]
        J = jnp.asarray(np.stack([st_.J for st_ in streams]))
        slot = jnp.asarray(np.stack([st_.slot for st_ in streams]))
        scale = jnp.asarray(
            np.stack([step_scales(st_, eta, p, "importance") for st_ in streams])
        )
        run = make_runner(prob.device_grad, C=C)
        w0 = jnp.zeros(prob.d, jnp.float32)
        w_batch, _ = jax.jit(jax.vmap(run, in_axes=(None, 0, 0, 0)))(w0, J, slot, scale)
        for b in range(3):
            w_one, _ = jax.jit(run)(w0, J[b], slot[b], scale[b])
            np.testing.assert_allclose(np.asarray(w_batch[b]), np.asarray(w_one), atol=1e-6)

    def test_bf16_params_keep_dtype(self):
        """Carry dtypes must stay stable across the scan (no fp32 promotion)."""
        prob = Quadratic(self.N)
        w0 = jnp.zeros(prob.d, jnp.bfloat16)

        class Bf16Quad:
            def device_grad(self, j, w, k):
                return w - prob.c_dev[j].astype(jnp.bfloat16)

        cfg = ServerConfig(n=self.N, C=4, T=100, eta=0.05, seed=1, engine="scan")
        w, _ = run_generalized_async_sgd(w0, Bf16Quad(), cfg)
        assert w.dtype == jnp.bfloat16
        assert np.all(np.isfinite(np.asarray(w, np.float32)))
        w_fb, _ = run_fedbuff(w0, Bf16Quad(), cfg, Z=5)
        assert w_fb.dtype == jnp.bfloat16

    def test_scan_rejects_host_only_source(self):
        class HostOnly:
            def grad(self, i, w, k):
                return w

        cfg = ServerConfig(n=4, C=2, T=10, eta=0.1, engine="scan")
        with pytest.raises(TypeError):
            run_generalized_async_sgd(np.zeros(2, np.float32), HostOnly(), cfg)


# ------------------------------------------------------------------ #
# FL wiring: device clients + scenario matrix
# ------------------------------------------------------------------ #
class TestFLScanEngine:
    def test_run_experiment_engines_agree_on_quality(self):
        from repro.configs.base import FLConfig
        from repro.fl import run_experiment

        flc = FLConfig(n_clients=12, concurrency=4, server_steps=300, speed_ratio=4.0)
        accs = {}
        for eng in ("python", "scan"):
            r = run_experiment(flc, "gen_async", eta=0.08, eval_every=300, engine=eng)
            accs[eng] = r.eval_acc[-1]
            assert r.extras["engine"] == eng
        # different minibatch RNG streams, same law: final accuracy comparable
        assert abs(accs["python"] - accs["scan"]) < 0.15

    def test_jit_runner_memo_survives_eval_cadence_sweep(self):
        """The memo key excludes eval_every: a cadence sweep reuses ONE
        runner object (jit's own cache handles per-cadence tracing)."""
        from repro.core import jit_runner

        prob = Quadratic(self.N if hasattr(self, "N") else 6)
        r1 = jit_runner(prob.device_grad, 3, eval_fn=None, eval_every=0)
        r2 = jit_runner(prob.device_grad, 3, eval_fn=None, eval_every=50)
        assert r1.func is r2.func  # same underlying jitted callable
        assert len(prob.__dict__["_scan_runner_cache"]) == 1
        # different algorithm shape -> different entry
        jit_runner(prob.device_grad, 3, fedbuff_Z=5)
        assert len(prob.__dict__["_scan_runner_cache"]) == 2

    def test_run_matrix_reuses_setup_across_calls(self):
        """Sweeping eval cadence over one dataset keeps one cached gradient
        source (and with it the memoized compiled runner)."""
        from repro.configs.base import FLConfig
        from repro.data.pipeline import FederatedClassification
        from repro.fl import run_matrix

        flc = FLConfig(n_clients=8, concurrency=3, server_steps=60)
        data = FederatedClassification(n_clients=8, seed=0)
        for ev in (30, 20):
            run_matrix(flc, seeds=(0,), policies=("uniform",),
                       speed_ratios=(1.0,), eval_every=ev, data=data)
        (setup,) = data.__dict__["_fl_setup_cache"].values()
        clients = setup.clients
        host_keys = [k for k in clients.__dict__["_scan_runner_cache"]
                     if k[0] == "host"]
        assert len(host_keys) == 1  # one runner across both cadences

    def test_run_matrix_shapes(self):
        from repro.configs.base import FLConfig
        from repro.fl import run_matrix

        flc = FLConfig(n_clients=10, concurrency=4, server_steps=120)
        m = run_matrix(
            flc, seeds=(0, 1), policies=("uniform", "optimal"),
            speed_ratios=(1.0, 8.0), eval_every=60,
        )
        assert m.final_acc.shape == (2, 2, 2)
        assert m.eval_acc.shape == (2, 2, 2, 2)
        assert m.eval_times.shape == (2, 2, 2, 2)
        assert list(m.eval_steps) == [60, 120]
        assert m.p_vectors.shape == (2, 2, 10)
        # physical time is monotone within every scenario
        assert np.all(np.diff(m.eval_times, axis=-1) >= 0)
        # uniform policy rows really are uniform
        np.testing.assert_allclose(m.p_vectors[0], 0.1, atol=1e-12)


# ------------------------------------------------------------------ #
# exported event-stream invariants
# ------------------------------------------------------------------ #
def _check_stream(stream):
    """FIFO conservation, Lemma-9 in-flight count, slot-uniqueness."""
    C, n, T = stream.C, stream.n, stream.T
    # replay: per-client FIFO of (dispatch_step, slot)
    fifo = [list() for _ in range(n)]
    for s, node in enumerate(stream.init_nodes):
        fifo[node].append((0, int(s)))
    outstanding = {int(s) for s in range(C)}
    recomputed_delays = [[] for _ in range(n)]
    for k in range(T):
        j, k_new, s = int(stream.J[k]), int(stream.K[k]), int(stream.slot[k])
        assert fifo[j], "completion at a client with no outstanding task"
        disp_step, disp_slot = fifo[j].pop(0)   # FIFO: oldest dispatch completes
        assert disp_slot == s, "slot must belong to the oldest in-flight task"
        recomputed_delays[j].append(k - disp_step)
        outstanding.discard(s)
        assert len(outstanding) == C - 1        # Lemma 9: C-1 tasks in flight
        fifo[k_new].append((k + 1, s))
        outstanding.add(s)
        assert len(outstanding) == C            # freed slot reused exactly once
    assert sum(len(q) for q in fifo) == C
    # FIFO order reproduces the simulator's recorded per-node delays exactly
    assert recomputed_delays == stream.delays


class TestStreamProperties:
    @pytest.mark.parametrize("seed", [0, 1, 7])
    @pytest.mark.parametrize("C", [1, 3, 12])
    def test_invariants_deterministic(self, seed, C):
        n = 5
        p = _nonuniform_p(n, seed=seed + 1)
        mu = np.random.default_rng(seed).uniform(0.3, 4.0, n)
        _check_stream(
            export_stream(SimConfig(mu=mu, p=p, C=C, T=400, seed=seed, record_delays=True))
        )

    def test_K_frequencies_match_p_chi_square(self):
        from stat_utils import assert_frequencies

        n, T = 6, 40_000
        p = np.array([0.3, 0.25, 0.2, 0.1, 0.1, 0.05])
        stream = export_stream(SimConfig(mu=np.ones(n), p=p, C=4, T=T, seed=0))
        assert_frequencies(stream.K, p, label="host dispatch")

    def test_matches_simulate_trace(self):
        """export_stream replays the exact (J, K, t) of ClosedNetworkSim."""
        from repro.core import simulate

        cfg = SimConfig(mu=np.array([1.0, 2.0, 0.5]), p=np.full(3, 1 / 3), C=4, T=600, seed=9)
        stream, res = export_stream(cfg), simulate(cfg)
        np.testing.assert_array_equal(stream.J, res.J)
        np.testing.assert_array_equal(stream.K, res.K)
        np.testing.assert_allclose(stream.t, res.t)


if HAVE_HYPOTHESIS:

    @st.composite
    def stream_configs(draw):
        n = draw(st.integers(2, 8))
        C = draw(st.integers(1, 12))
        T = draw(st.integers(10, 300))
        seed = draw(st.integers(0, 2**16))
        service = draw(st.sampled_from(["exp", "det"]))
        mu = np.array([draw(st.floats(0.2, 8.0)) for _ in range(n)])
        praw = np.array([draw(st.floats(0.05, 1.0)) for _ in range(n)])
        return SimConfig(mu=mu, p=praw / praw.sum(), C=C, T=T, service=service, seed=seed,
                         record_delays=True)

    class TestStreamPropertiesHypothesis:
        @given(cfg=stream_configs())
        @settings(max_examples=30, deadline=None)
        def test_invariants(self, cfg):
            _check_stream(export_stream(cfg))


# ------------------------------------------------------------------ #
# weighted_update kernel parity (interpret mode; the engine's update path)
# ------------------------------------------------------------------ #
class TestWeightedUpdateKernelParity:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [(3, 1000), (127,), (64, 128), (5, 3, 11)])
    @pytest.mark.parametrize("momentum", [0.0, 0.9])
    def test_kernel_vs_jnp_reference(self, dtype, shape, momentum):
        from repro.kernels.ref import weighted_update_ref
        from repro.kernels.weighted_update import weighted_update

        rng = np.random.default_rng(17)
        w = jnp.asarray(rng.normal(size=shape), dtype)
        g = jnp.asarray(rng.normal(size=shape), dtype)
        m = jnp.asarray(rng.normal(size=shape), jnp.float32) if momentum else None
        scale = jnp.float32(0.123)
        ow, om = weighted_update(w, g, scale, m=m, momentum=momentum, interpret=True)
        ew, em = weighted_update_ref(w, g, scale, m=m, momentum=momentum)
        tol = dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-6, rtol=2e-6)
        np.testing.assert_allclose(
            ow.astype(jnp.float32), ew.astype(jnp.float32), **tol
        )
        assert ow.dtype == w.dtype
        if momentum:
            np.testing.assert_allclose(om, em, atol=1e-5)

    def test_tree_weighted_update_matches_leafwise(self):
        from repro.kernels.weighted_update import tree_weighted_update

        rng = np.random.default_rng(3)
        w = {"a": jnp.asarray(rng.normal(size=(3, 1000)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(17,)), jnp.float32)}
        g = {"a": jnp.asarray(rng.normal(size=(3, 1000)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(17,)), jnp.float32)}
        out = tree_weighted_update(w, g, 0.25)
        for key in w:
            np.testing.assert_allclose(
                out[key], w[key] - 0.25 * g[key], atol=1e-6
            )
