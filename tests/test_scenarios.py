"""Scenario library: statistical law tests, host==device parity, defaults.

Every registry entry gets (a) a law test through the shared harness
(`stat_utils`) — service-law moments, modulated-availability stationarity,
Little's law — and (b) a host-oracle==device-stream parity test.  The
disabled default (`exponential` + always-on) must stay *bitwise* identical
to the engine path with no scenario at all.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from stat_utils import (
    assert_chi_square,
    assert_ks,
    assert_little,
    assert_mean,
    assert_occupancy_conserved,
    assert_onoff_stationary,
    assert_scv,
)

from repro.core import ServerConfig, run_generalized_async_sgd
from repro.core import stream_device as sd
from repro.core.engine_scan import make_fused_runner
from repro.core.queue_sim import (
    KIND_COMPLETE,
    ClosedNetworkSim,
    SimConfig,
    export_stream,
)
from repro.core.scenario import (
    SCENARIOS,
    ModulationConfig,
    ScenarioConfig,
    ServiceLaw,
    chain_moments,
    get_scenario,
    list_scenarios,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False

ENABLED = [n for n in list_scenarios() if SCENARIOS[n].enabled]
SERVICE_ONLY = [n for n in ENABLED if SCENARIOS[n].modulation is None]
MODULATED = [n for n in ENABLED if SCENARIOS[n].modulation is not None]


def _nonuniform_p(n, seed=0):
    rng = np.random.default_rng(seed)
    p = rng.uniform(0.5, 2.0, n)
    return p / p.sum()


class _QuadSource:
    def __init__(self, n):
        self.targ = np.arange(n, dtype=np.float32)

    def grad(self, j, w, k):
        return {"a": np.asarray(w["a"]) - self.targ[j]}

    def device_grad(self, j, w, k):
        return {"a": w["a"] - jnp.asarray(self.targ)[j]}


# ------------------------------------------------------------------ #
# registry sanity + serialization
# ------------------------------------------------------------------ #
def test_registry_contents():
    for name in ("exponential", "erlang2", "erlang4", "hyperexp2",
                 "onoff", "onoff_slow", "erlang2_onoff"):
        assert name in SCENARIOS
    assert not SCENARIOS["exponential"].enabled
    assert get_scenario(None) is None
    assert get_scenario("erlang2") is SCENARIOS["erlang2"]
    sc = ScenarioConfig(name="adhoc", service=ServiceLaw.erlang(3))
    assert get_scenario(sc) is sc
    with pytest.raises(KeyError):
        get_scenario("no-such-scenario")
    with pytest.raises(TypeError):
        get_scenario(3.14)


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_registry_json_round_trip(name):
    sc = SCENARIOS[name]
    back = ScenarioConfig.from_json(sc.to_json())
    assert back == sc
    assert back.cache_key() == sc.cache_key()


# ------------------------------------------------------------------ #
# service-law moments: n=1, C=1 makes inter-completion times iid draws
# ------------------------------------------------------------------ #
def _service_samples_host(sc, mu, T, seed):
    stream = export_stream(
        SimConfig(mu=np.array([mu]), p=np.ones(1), C=1, T=T, seed=seed,
                  scenario=sc)
    )
    return np.diff(stream.t[stream.kind == KIND_COMPLETE])


def _service_samples_device(sc, mu, T, seed):
    stream = sd.generate_stream(np.array([mu]), np.ones(1), C=1, T=T,
                                seed=seed, scenario=sc)
    return np.diff(stream.t[stream.kind == KIND_COMPLETE])


@pytest.mark.parametrize("name", SERVICE_ONLY)
@pytest.mark.parametrize("side", ["host", "device"])
def test_service_law_moments(name, side):
    """Service times have mean 1/mu and the law's squared CV, both sides."""
    sc = SCENARIOS[name]
    mu, T = 1.7, 40_000
    draw = _service_samples_host if side == "host" else _service_samples_device
    x = draw(sc, mu, T, seed=11) * mu
    assert_mean(x, 1.0)
    assert_scv(x, sc.service.scv())


@pytest.mark.parametrize("side", ["host", "device"])
def test_service_law_ks(side):
    """Full-distribution KS check: Erlang-2 against the exact gamma CDF."""
    from scipy.stats import gamma

    sc = SCENARIOS["erlang2"]
    draw = _service_samples_host if side == "host" else _service_samples_device
    x = draw(sc, 1.0, 20_000, seed=3)
    assert_ks(x, gamma(a=2, scale=0.5).cdf)


# ------------------------------------------------------------------ #
# modulated availability: stationary on-share, host and device
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("name", MODULATED)
def test_availability_stationarity_host(name):
    sc = SCENARIOS[name]
    n, C, T = 5, 3, 40_000
    sim = ClosedNetworkSim(
        SimConfig(mu=np.full(n, 1.0), p=np.full(n, 1 / n), C=C, T=T,
                  seed=9, scenario=sc)
    )
    sim.run(T)
    assert sim.avail_tw is not None
    q_off, q_on = sc.modulation.resolve(n)
    assert_onoff_stationary(sim.avail_tw / sim.now, q_off[0], q_on[0], sim.now)


@pytest.mark.parametrize("name", MODULATED)
def test_availability_stationarity_device(name):
    sc = SCENARIOS[name]
    n, C, T = 5, 3, 40_000
    src = _QuadSource(n)
    runner = make_fused_runner(src.device_grad, n, C, T, scenario=sc)
    _, _, extras = runner(
        {"a": jnp.zeros(4, jnp.float32)}, jnp.full(n, 1.0),
        jnp.full(n, 1 / n), jax.random.PRNGKey(4), 0.0,
    )
    horizon = float(np.asarray(extras["t"])[-1])
    frac = np.asarray(extras["avail_time"], np.float64) / horizon
    q_off, q_on = sc.modulation.resolve(n)
    assert_onoff_stationary(frac, q_off[0], q_on[0], horizon)


# ------------------------------------------------------------------ #
# Little's law + conservation under modulation
# ------------------------------------------------------------------ #
def _completion_counted_delays(stream):
    """Per-completion delay in *completion* counts (stage/flip rows skipped).

    Replays the slot bookkeeping: a completing slot's delay is the number
    of completions since its task was dispatched — the quantity Little's
    law pins at C-1 in the closed network regardless of the service law.
    """
    C = stream.C
    disp = np.zeros(C + 1, np.int64)  # completion count at dispatch per slot
    comp = 0
    out = []
    for k in range(stream.T):
        if stream.kind is not None and stream.kind[k] != KIND_COMPLETE:
            continue
        s = stream.slot[k]
        out.append(comp - disp[s])
        comp += 1
        disp[s] = comp  # re-dispatch into the freed slot
    return np.asarray(out)


@pytest.mark.parametrize("name", ENABLED)
@pytest.mark.parametrize("side", ["host", "device"])
def test_little_and_conservation(name, side):
    """Time-avg total occupancy == C and completion-counted delay == C-1."""
    sc = SCENARIOS[name]
    n, C, T = 5, 4, 30_000
    mu = np.random.default_rng(1).uniform(0.6, 2.5, n)
    p = _nonuniform_p(n, seed=2)
    if side == "host":
        stream = export_stream(SimConfig(mu=mu, p=p, C=C, T=T, seed=6,
                                         scenario=sc))
    else:
        stream = sd.generate_stream(mu, p, C, T=T, seed=6, scenario=sc)
    assert_occupancy_conserved(stream.queue_len_sum, C, T)
    horizon = stream.t[-1]
    assert np.sum(stream.queue_len_tw) / horizon == pytest.approx(C, rel=1e-6)
    assert_little(_completion_counted_delays(stream), C, rel=0.03)


# ------------------------------------------------------------------ #
# host == device law parity, every registry entry
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_host_device_parity(name):
    """Same laws on both sides: kind mix, occupancy, throughput, delays."""
    sc = SCENARIOS[name]
    n, C, T = 5, 3, 30_000
    mu = np.random.default_rng(0).uniform(0.5, 3.0, n)
    p = _nonuniform_p(n, seed=4)
    host = export_stream(SimConfig(mu=mu, p=p, C=C, T=T, seed=1, scenario=sc))
    dev = sd.generate_stream(mu, p, C, T=T, seed=1, scenario=sc)
    if not sc.enabled:
        assert host.kind is None and dev.kind is None
    else:
        # merged-event mix: device histogram against host shares
        ch = np.bincount(host.kind, minlength=6)
        cd = np.bincount(dev.kind, minlength=6)
        np.testing.assert_allclose(cd / T, ch / T, atol=0.02)
        assert set(np.nonzero(cd)[0]) == set(np.nonzero(ch)[0])
    # physical horizon (throughput) and time-averaged occupancy
    assert dev.t[-1] == pytest.approx(host.t[-1], rel=0.06)
    np.testing.assert_allclose(
        dev.queue_len_tw / dev.t[-1], host.queue_len_tw / host.t[-1],
        rtol=0.2, atol=0.08,
    )
    # completion-counted delay distribution agrees in mean
    dh = _completion_counted_delays(host)
    dd = _completion_counted_delays(dev)
    assert np.mean(dd) == pytest.approx(np.mean(dh), rel=0.03)
    # completion shares match the sampling law on both sides
    comp_h = host.J if host.kind is None else host.J[host.kind == KIND_COMPLETE]
    comp_d = dev.J if dev.kind is None else dev.J[dev.kind == KIND_COMPLETE]
    assert_chi_square(np.bincount(comp_d, minlength=n),
                      comp_d.size * p, label=f"{name} device J")
    assert_chi_square(np.bincount(comp_h, minlength=n),
                      comp_h.size * p, label=f"{name} host J")


# ------------------------------------------------------------------ #
# bitwise default: exponential + always-on == no scenario at all
# ------------------------------------------------------------------ #
def test_default_scenario_bitwise_streams():
    mu = np.array([2.0, 1.0, 0.5])
    p = np.full(3, 1 / 3)
    off = SCENARIOS["exponential"]
    for gen in (
        lambda s: sd.generate_stream(mu, p, C=2, T=500, seed=3, scenario=s),
        lambda s: export_stream(SimConfig(mu=mu, p=p, C=2, T=500, seed=3,
                                          scenario=s)),
    ):
        a, b = gen(None), gen(off)
        np.testing.assert_array_equal(a.J, b.J)
        np.testing.assert_array_equal(a.K, b.K)
        np.testing.assert_array_equal(a.slot, b.slot)
        np.testing.assert_array_equal(a.t, b.t)
        assert a.kind is None and b.kind is None


@pytest.mark.parametrize("engine,stream", [("python", "host"),
                                           ("scan", "host"),
                                           ("scan", "device")])
def test_default_scenario_bitwise_engines(engine, stream):
    n = 4
    src = _QuadSource(n)
    w0 = {"a": jnp.zeros(3, jnp.float32)}
    outs = []
    for scenario in (None, "exponential"):
        cfg = ServerConfig(n=n, C=3, T=300, eta=0.05, p=np.full(n, 1 / n),
                           mu=np.linspace(0.5, 2.0, n), seed=7,
                           engine=engine, stream=stream, scenario=scenario,
                           sparse=False)
        w, _ = run_generalized_async_sgd(w0, src, cfg)
        outs.append(np.asarray(w["a"]))
    np.testing.assert_array_equal(outs[0], outs[1])


# ------------------------------------------------------------------ #
# scenario engines: python == scan/host on the same exported stream
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("name", ["erlang2", "hyperexp2", "erlang2_onoff"])
def test_python_scan_scenario_parity(name):
    n = 5
    src = _QuadSource(n)
    w0 = {"a": jnp.zeros(4, jnp.float32)}
    base = dict(n=n, C=3, T=400, eta=0.05, p=np.full(n, 1 / n),
                mu=np.linspace(0.5, 2.0, n), seed=2, scenario=name,
                sparse=False)
    w_py, tr_py = run_generalized_async_sgd(
        w0, src, ServerConfig(**base, engine="python"))
    w_sc, tr_sc = run_generalized_async_sgd(
        w0, src, ServerConfig(**base, engine="scan"))
    np.testing.assert_allclose(np.asarray(w_py["a"]), np.asarray(w_sc["a"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(tr_py.extras["kind_count"],
                                  tr_sc.extras["kind_count"])


def test_scenario_fault_mutually_exclusive():
    from repro.core.queue_sim import FaultConfig

    n = 4
    cfg = ServerConfig(n=n, C=2, T=50, eta=0.1, scenario="erlang2",
                       faults=FaultConfig(off_rate=0.5, on_rate=1.0),
                       engine="scan", stream="device")
    with pytest.raises(ValueError, match="separate injection paths"):
        run_generalized_async_sgd({"a": jnp.zeros(2)}, _QuadSource(n), cfg)


# ------------------------------------------------------------------ #
# block-size probe honors the configured scenario (regression)
# ------------------------------------------------------------------ #
def test_probe_stream_matches_configuration():
    """`block_size="auto"` must probe the *configured* stream.

    A faultless/exponential probe never emits trash-slot rows, so it
    understates block conflicts and overstates E under faults or a
    scenario; the probe now draws from the configured law.
    """
    from repro.core.async_sgd import _auto_block_size, _probe_stream_slots
    from repro.core.queue_sim import FaultConfig

    n, C, T = 6, 3, 2000
    mu, p = np.ones(n), np.full(n, 1 / n)
    plain = _probe_stream_slots(mu, p, C, T, 0)
    assert not (plain == C).any()
    heavy = FaultConfig(off_rate=6.0, on_rate=6.0)
    flipped = _probe_stream_slots(mu, p, C, T, 0, fault=heavy)
    assert (flipped == C).any()
    staged = _probe_stream_slots(mu, p, C, T, 0,
                                 scenario=SCENARIOS["erlang4"])
    assert (staged == C).any()
    # trash-slot repeats close blocks immediately: the configured probe
    # must pick a strictly smaller E than the faultless one did
    assert _auto_block_size(flipped) < _auto_block_size(plain)
    assert _auto_block_size(staged) < _auto_block_size(plain)


# ------------------------------------------------------------------ #
# hypothesis property tests (optional dependency)
# ------------------------------------------------------------------ #
if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(k=st.integers(min_value=1, max_value=16))
    def test_prop_erlang_chain(k):
        law = ServiceLaw.erlang(k)
        alpha, rates, absorb, nxt = law.chain()  # _validate_chain inside
        m1, m2 = chain_moments(alpha, rates, absorb, nxt)
        assert m1 == pytest.approx(1.0, rel=1e-9)
        assert law.scv() == pytest.approx(1.0 / k, rel=1e-9)

    @settings(max_examples=40, deadline=None)
    @given(scv=st.floats(min_value=1.01, max_value=50.0,
                         allow_nan=False, allow_infinity=False))
    def test_prop_hyperexp_chain(scv):
        law = ServiceLaw.hyperexp_scv(scv)
        alpha, rates, absorb, nxt = law.chain()
        m1, m2 = chain_moments(alpha, rates, absorb, nxt)
        assert m1 == pytest.approx(1.0, rel=1e-9)
        assert law.scv() == pytest.approx(scv, rel=1e-6)

    _rate = st.floats(min_value=0.0, max_value=10.0,
                      allow_nan=False, allow_infinity=False)

    @settings(max_examples=40, deadline=None)
    @given(
        kind=st.sampled_from(["exp", "erlang", "hyperexp"]),
        k=st.integers(min_value=1, max_value=8),
        scv=st.floats(min_value=1.1, max_value=20.0,
                      allow_nan=False, allow_infinity=False),
        off=_rate, on=_rate,
        scale=st.floats(min_value=0.0, max_value=1.0,
                        allow_nan=False, allow_infinity=False),
        with_mod=st.booleans(),
    )
    def test_prop_scenario_round_trip(kind, k, scv, off, on, scale, with_mod):
        service = {
            "exp": ServiceLaw.exponential(),
            "erlang": ServiceLaw.erlang(k),
            "hyperexp": ServiceLaw.hyperexp_scv(scv),
        }[kind]
        mod = ModulationConfig(off_rate=off, on_rate=on,
                               rate_scale=scale) if with_mod else None
        sc = ScenarioConfig(name="prop", service=service, modulation=mod)
        back = ScenarioConfig.from_json(sc.to_json())
        assert back == sc
        assert back.cache_key() == sc.cache_key()
        assert back.enabled == sc.enabled
