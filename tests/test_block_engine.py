"""Blocked (event micro-batched) engine: segmentation invariants, blocked-vs-
per-event parity on both stream paths, the fused Pallas block kernel, the
bf16 snapshot codec and the extras-pruning / donation knobs.

The per-event scan engine is itself parity-locked against the Python
reference loop (tests/test_engine.py), so blocked == per-event == oracle.
The hypothesis-based property test is optional (pip install .[dev]).
"""
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    EventBlocks,
    ServerConfig,
    SimConfig,
    blocked_inputs,
    export_blocks,
    export_stream,
    jit_runner,
    run_fedbuff,
    run_generalized_async_sgd,
    segment_blocks,
    step_scales,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency
    HAVE_HYPOTHESIS = False


class Quadratic:
    """Clients hold quadratics f_i(w) = 0.5 ||w - c_i||^2 — the blocked /
    per-event parity oracle (contractive dynamics: float-associativity
    differences stay bounded instead of amplifying)."""

    def __init__(self, n, d=4, seed=0):
        rng = np.random.default_rng(seed)
        self.c = rng.normal(size=(n, d)).astype(np.float32)
        self.c_dev = jnp.asarray(self.c)
        self.d = d

    def grad(self, i, w, k):
        return w - self.c[i]

    def device_grad(self, j, w, k):
        return w - self.c_dev[j]


def _nonuniform_p(n, seed=1):
    p = np.random.default_rng(seed).uniform(0.5, 1.5, n)
    return p / p.sum()


def _check_blocks(blocks: EventBlocks):
    """Every event in exactly one block, in order; no intra-block slot
    repeats; padding only at block tails; pad lanes neutralized."""
    idx, mask = blocks.idx, blocks.mask
    # masks are a prefix of each row (padding only at the tail)
    assert np.all(mask[:, :1]), "every block holds at least one event"
    assert np.all(mask[:, 1:] <= mask[:, :-1]), "padding must be a row suffix"
    # events partition 0..T-1 in stream order
    flat = idx[mask]
    np.testing.assert_array_equal(flat, np.arange(blocks.T))
    # no slot repeats within a block (padding rows sit on the trash row C)
    for b in range(blocks.B):
        real = blocks.slot[b][mask[b]]
        assert len(set(real.tolist())) == real.size
        assert np.all(real < blocks.C)
        assert np.all(blocks.slot[b][~mask[b]] == blocks.C)
    assert np.all(blocks.J[~mask] == 0)
    # blocked columns match the stream on real lanes
    st_ = blocks.stream
    np.testing.assert_array_equal(blocks.J[mask], st_.J[flat])
    np.testing.assert_array_equal(blocks.slot[mask], st_.slot[flat])


class TestSegmentation:
    @pytest.mark.parametrize("C", [1, 4, 12])
    @pytest.mark.parametrize("E", [2, 4, 8])
    def test_invariants(self, C, E):
        n = 6
        cfg = SimConfig(
            mu=np.random.default_rng(C).uniform(0.3, 4.0, n),
            p=_nonuniform_p(n, seed=C + E), C=C, T=400, seed=C + 3 * E,
        )
        _check_blocks(export_blocks(cfg, E))

    def test_forced_cuts_land_on_eval_boundaries(self):
        cfg = SimConfig(mu=np.ones(5), p=np.full(5, 0.2), C=3, T=330, seed=1)
        blocks = export_blocks(cfg, 4, cut_every=50)
        _check_blocks(blocks)
        firsts = blocks.idx[:, 0][blocks.mask[:, 0]]
        lasts = np.array([blocks.idx[b][blocks.mask[b]][-1]
                          for b in range(blocks.B)])
        # no block spans a multiple of 50
        assert np.all(firsts // 50 == lasts // 50)

    def test_block_size_one_is_identity(self):
        idx, mask = segment_blocks(np.array([0, 1, 0, 2]), 1)
        np.testing.assert_array_equal(idx[:, 0], np.arange(4))
        assert mask.all() and idx.shape == (4, 1)

    def test_device_generated_blocks(self):
        from repro.core import generate_blocks

        blocks = generate_blocks(np.ones(6), np.full(6, 1 / 6), C=4, T=300,
                                 block_size=4, seed=0)
        _check_blocks(blocks)

    def test_blocked_scales_zero_on_padding(self):
        cfg = SimConfig(mu=np.ones(4), p=np.full(4, 0.25), C=3, T=100, seed=0)
        blocks = export_blocks(cfg, 4)
        sc = blocks.blocked_scales(np.full(100, 0.5))
        assert np.all(sc[~blocks.mask] == 0.0)
        assert np.all(sc[blocks.mask] == 0.5)


class TestDPSegmentation:
    """DP cut placement: same invariants, never more padded lanes than
    greedy (the DP is the exact optimum; greedy matches it because block
    validity is hereditary — the test locks both directions)."""

    def _cfg(self, C, E, T=400):
        n = 6
        return SimConfig(
            mu=np.random.default_rng(C).uniform(0.3, 4.0, n),
            p=_nonuniform_p(n, seed=C + E), C=C, T=T, seed=C + 3 * E,
        )

    @pytest.mark.parametrize("C", [1, 4, 12])
    @pytest.mark.parametrize("E", [2, 4, 8])
    def test_invariants(self, C, E):
        _check_blocks(export_blocks(self._cfg(C, E), E, method="dp"))

    @pytest.mark.parametrize("C,E,cut", [(1, 4, 0), (4, 4, 50), (8, 6, 25)])
    def test_no_more_padded_lanes_than_greedy(self, C, E, cut):
        cfg = self._cfg(C, E)
        greedy = export_blocks(cfg, E, cut_every=cut)
        dp = export_blocks(cfg, E, cut_every=cut, method="dp")
        assert dp.padded_lanes <= greedy.padded_lanes
        assert dp.utilization >= greedy.utilization
        # hereditary validity makes greedy count-optimal too: exact tie
        assert dp.B == greedy.B

    def test_forced_cuts_respected(self):
        cfg = SimConfig(mu=np.ones(5), p=np.full(5, 0.2), C=3, T=330, seed=1)
        blocks = export_blocks(cfg, 4, cut_every=50, method="dp")
        _check_blocks(blocks)
        firsts = blocks.idx[:, 0][blocks.mask[:, 0]]
        lasts = np.array([blocks.idx[b][blocks.mask[b]][-1]
                          for b in range(blocks.B)])
        assert np.all(firsts // 50 == lasts // 50)

    def test_identical_replay(self):
        """Greedy and DP cuts of the same stream replay to the same final
        iterate (different block boundaries, same sequential semantics)."""
        n, C, T, E = 8, 4, 600, 4
        prob = Quadratic(n)
        cfg = ServerConfig(n=n, C=C, T=T, eta=0.02, p=_nonuniform_p(n),
                           seed=3, engine="scan", block_size=E)
        w_g, _ = run_generalized_async_sgd(np.zeros(prob.d, np.float32),
                                           prob, cfg)
        w_d, _ = run_generalized_async_sgd(
            np.zeros(prob.d, np.float32), prob,
            replace(cfg, segmentation="dp"))
        np.testing.assert_allclose(np.asarray(w_d), np.asarray(w_g),
                                   atol=1e-5)

    def test_unknown_method_rejected(self):
        with pytest.raises(ValueError, match="bogus"):
            segment_blocks(np.zeros(10, np.int32), 4, method="bogus")


class TestSelectBlockSize:
    def test_candidates_are_device_multiples(self):
        from repro.core import select_block_size

        cfg = SimConfig(mu=np.ones(8), p=np.full(8, 1 / 8), C=4, T=500,
                        seed=0)
        st = export_stream(cfg)
        E, utils = select_block_size(st.slot, block_size_max=12, devices=3)
        assert E % 3 == 0
        assert set(utils) == {3, 6, 9, 12}

    def test_largest_E_above_floor(self):
        from repro.core import select_block_size

        cfg = SimConfig(mu=np.ones(8), p=np.full(8, 1 / 8), C=4, T=800,
                        seed=1)
        st = export_stream(cfg)
        E, utils = select_block_size(st.slot, block_size_max=16,
                                     min_utilization=0.5)
        above = [e for e, u in utils.items() if u >= 0.5]
        assert E == max(above) if above else utils[E] == max(utils.values())

    def test_aggregates_multiple_streams(self):
        from repro.core import select_block_size

        streams = [
            export_stream(SimConfig(mu=np.ones(6), p=np.full(6, 1 / 6), C=3,
                                    T=300, seed=s))
            for s in (0, 1)
        ]
        E, utils = select_block_size([s.slot for s in streams])
        assert E in utils and 0.0 < utils[E] <= 1.0

    def test_run_matrix_auto_matches_explicit(self):
        from repro.configs.base import FLConfig
        from repro.core import select_block_size
        from repro.data.pipeline import FederatedClassification
        from repro.fl import run_matrix

        flc = FLConfig(n_clients=10, concurrency=4, server_steps=120)
        data = FederatedClassification(n_clients=10, seed=0)
        kw = dict(seeds=(0,), policies=("uniform",), speed_ratios=(1.0,),
                  eval_every=60, data=data)
        m_auto = run_matrix(flc, block_size="auto", **kw)
        m_ref = run_matrix(flc, **kw)  # per-event reference
        np.testing.assert_allclose(m_auto.final_acc, m_ref.final_acc,
                                   atol=1e-5)


if HAVE_HYPOTHESIS:

    @st.composite
    def block_cases(draw):
        n = draw(st.integers(2, 8))
        C = draw(st.integers(1, 10))
        T = draw(st.integers(10, 200))
        E = draw(st.integers(2, 8))
        seed = draw(st.integers(0, 2**16))
        cut = draw(st.sampled_from([0, 25, 50]))
        mu = np.array([draw(st.floats(0.2, 8.0)) for _ in range(n)])
        praw = np.array([draw(st.floats(0.05, 1.0)) for _ in range(n)])
        return SimConfig(mu=mu, p=praw / praw.sum(), C=C, T=T, seed=seed), E, cut

    class TestSegmentationHypothesis:
        @given(case=block_cases())
        @settings(max_examples=25, deadline=None)
        def test_invariants(self, case):
            cfg, E, cut = case
            _check_blocks(export_blocks(cfg, E, cut_every=cut))


# ------------------------------------------------------------------ #
# blocked replay vs the per-event scan engine (itself oracle-locked)
# ------------------------------------------------------------------ #
class TestBlockedParity:
    N, T = 8, 1200

    def _run(self, cfg, prob, method="gen_async", Z=5):
        w0 = np.zeros(prob.d, np.float32)
        if method == "fedbuff":
            return run_fedbuff(w0, prob, cfg, Z=Z)
        return run_generalized_async_sgd(w0, prob, cfg)

    @pytest.mark.parametrize("C", [1, 4, 8])  # C == n at 8
    @pytest.mark.parametrize("E", [4, 7])
    def test_gen_async_blocked_matches_per_event(self, C, E):
        prob = Quadratic(self.N)
        cfg = ServerConfig(
            n=self.N, C=C, T=self.T, eta=0.02, p=_nonuniform_p(self.N),
            seed=3, weighting="importance", engine="scan",
        )
        w1, _ = self._run(cfg, prob)
        wb, _ = self._run(replace(cfg, block_size=E), prob)
        np.testing.assert_allclose(np.asarray(wb), np.asarray(w1), atol=1e-5)

    @pytest.mark.parametrize("Z", [1, 5])
    def test_fedbuff_blocked_matches_per_event(self, Z):
        prob = Quadratic(self.N)
        cfg = ServerConfig(n=self.N, C=4, T=self.T, eta=0.05, seed=0,
                           weighting="plain", engine="scan")
        w1, _ = self._run(cfg, prob, "fedbuff", Z)
        wb, _ = self._run(replace(cfg, block_size=6), prob, "fedbuff", Z)
        np.testing.assert_allclose(np.asarray(wb), np.asarray(w1), atol=1e-5)

    def test_eval_curve_and_delays_match(self):
        """Forced cuts put eval points on block boundaries: identical eval
        steps, iterates and queueing metadata (same underlying stream)."""
        prob = Quadratic(self.N)
        cfg = ServerConfig(n=self.N, C=4, T=570, eta=0.02, seed=7,
                           eval_every=100, engine="scan")
        ev = lambda w: jnp.sum(w**2)
        w1, tr1 = run_generalized_async_sgd(
            np.zeros(prob.d, np.float32), prob, cfg, eval_fn=ev)
        wb, trb = run_generalized_async_sgd(
            np.zeros(prob.d, np.float32), prob, replace(cfg, block_size=4),
            eval_fn=ev)
        assert trb.eval_steps == tr1.eval_steps
        np.testing.assert_allclose(trb.eval_values, tr1.eval_values, atol=1e-5)
        np.testing.assert_allclose(np.asarray(wb), np.asarray(w1), atol=1e-5)
        np.testing.assert_allclose(trb.times, tr1.times)
        assert trb.delays == tr1.delays
        np.testing.assert_allclose(
            trb.mean_queue_lengths, tr1.mean_queue_lengths
        )

    @pytest.mark.parametrize("E", [2, 5])
    def test_fused_device_blocked_matches_per_event(self, E):
        """Device stream: E CS steps per scan iteration, sequential fixup
        for in-window dispatches — same PRNG key => same trajectory.
        Non-uniform p exercises the dispatch-time slot-scale bookkeeping
        inside the window scan."""
        prob = Quadratic(self.N)
        cfg = ServerConfig(n=self.N, C=4, T=800, eta=0.02, seed=5,
                           p=_nonuniform_p(self.N), engine="scan",
                           stream="device", mu=np.ones(self.N))
        w1, tr1 = self._run(cfg, prob)
        wb, trb = self._run(replace(cfg, block_size=E), prob)
        np.testing.assert_allclose(np.asarray(wb), np.asarray(w1), atol=1e-5)
        np.testing.assert_allclose(trb.times, tr1.times, atol=1e-4)

    def test_fused_device_blocked_fedbuff(self):
        prob = Quadratic(self.N)
        cfg = ServerConfig(n=self.N, C=4, T=600, eta=0.05, seed=2,
                           weighting="plain", engine="scan", stream="device",
                           mu=np.ones(self.N))
        w1, _ = self._run(cfg, prob, "fedbuff")
        wb, _ = self._run(replace(cfg, block_size=4), prob, "fedbuff")
        np.testing.assert_allclose(np.asarray(wb), np.asarray(w1), atol=1e-5)

    def test_fused_device_blocked_with_eval_and_nonaligned_chunk(self):
        """Chunk length not a multiple of E: windows + per-event remainder."""
        prob = Quadratic(self.N)
        cfg = ServerConfig(n=self.N, C=4, T=500, eta=0.02, seed=9,
                           eval_every=110, engine="scan", stream="device",
                           mu=np.ones(self.N))
        ev = lambda w: jnp.sum(w**2)
        _, tr1 = run_generalized_async_sgd(
            np.zeros(prob.d, np.float32), prob, cfg, eval_fn=ev)
        _, trb = run_generalized_async_sgd(
            np.zeros(prob.d, np.float32), prob, replace(cfg, block_size=4),
            eval_fn=ev)
        assert trb.eval_steps == tr1.eval_steps
        np.testing.assert_allclose(trb.eval_values, tr1.eval_values, atol=1e-5)

    def test_pallas_kernel_path_matches_jnp(self):
        prob = Quadratic(self.N, d=37)  # non-tile-aligned parameter count
        cfg = ServerConfig(n=self.N, C=4, T=300, eta=0.02, seed=2,
                           engine="scan", block_size=4)
        wj, _ = self._run(cfg, prob)
        wp, _ = self._run(replace(cfg, update="pallas"), prob)
        np.testing.assert_allclose(np.asarray(wp), np.asarray(wj), atol=1e-6)

    def test_vmapped_blocked_streams_match_single(self):
        """One vmapped call over stacked blocked scenarios == per-scenario."""
        n, C, T, eta, E = 6, 3, 300, 0.03, 4
        prob = Quadratic(n)
        p = _nonuniform_p(n)
        streams = [
            export_stream(SimConfig(mu=np.ones(n), p=p, C=C, T=T, seed=s))
            for s in (0, 1, 2)
        ]
        from repro.core import blocked_inputs_batch

        blocks = [EventBlocks.from_stream(s, E) for s in streams]
        scales = [step_scales(s, eta, p, "importance") for s in streams]
        Jb, sb, scb, kb, mb, G, nch = blocked_inputs_batch(blocks, scales)
        runner = jit_runner(prob.device_grad, C, block_size=E,
                            vmap_streams=True)
        w0 = jnp.zeros(prob.d, jnp.float32)
        wB, _ = runner(w0, *map(jnp.asarray, (Jb, sb, scb, kb, mb)),
                       chunk_blocks=G, n_chunks=nch)
        single = jit_runner(prob.device_grad, C, block_size=E)
        for i in range(3):
            args = blocked_inputs(blocks[i], scales[i])
            w1, _ = single(w0, *map(jnp.asarray, args[:5]),
                           chunk_blocks=args[5], n_chunks=args[6])
            np.testing.assert_allclose(np.asarray(wB[i]), np.asarray(w1),
                                       atol=1e-6)

    def test_run_matrix_blocked_matches_per_event(self):
        from repro.configs.base import FLConfig
        from repro.data.pipeline import FederatedClassification
        from repro.fl import run_matrix

        flc = FLConfig(n_clients=10, concurrency=4, server_steps=120)
        data = FederatedClassification(n_clients=10, seed=0)
        kw = dict(seeds=(0, 1), policies=("uniform", "optimal"),
                  speed_ratios=(1.0, 8.0), eval_every=60, data=data)
        m1 = run_matrix(flc, **kw)
        mb = run_matrix(flc, block_size=4, **kw)
        assert mb.eval_acc.shape == m1.eval_acc.shape
        np.testing.assert_allclose(mb.eval_acc, m1.eval_acc, atol=1e-5)
        np.testing.assert_allclose(mb.final_acc, m1.final_acc, atol=1e-5)
        np.testing.assert_allclose(mb.eval_times, m1.eval_times)

    def test_run_matrix_device_blocked_matches_per_event(self):
        """Device stream + blocked: same PRNG keys => same scenario grid."""
        from repro.configs.base import FLConfig
        from repro.data.pipeline import FederatedClassification
        from repro.fl import run_matrix

        flc = FLConfig(n_clients=10, concurrency=4, server_steps=120,
                       stream="device")
        data = FederatedClassification(n_clients=10, seed=0)
        kw = dict(seeds=(0, 1), policies=("uniform",),
                  speed_ratios=(1.0, 8.0), eval_every=60, data=data)
        m1 = run_matrix(flc, **kw)
        mb = run_matrix(flc, block_size=4, **kw)
        np.testing.assert_allclose(mb.eval_acc, m1.eval_acc, atol=1e-5)
        np.testing.assert_allclose(mb.final_acc, m1.final_acc, atol=1e-5)


# ------------------------------------------------------------------ #
# codec / extras / guard rails
# ------------------------------------------------------------------ #
class TestBlockedKnobs:
    N = 8

    def test_bf16_snapshot_codec(self):
        """bf16 ring storage runs on both engines and stays near fp32."""
        prob = Quadratic(self.N)
        cfg = ServerConfig(n=self.N, C=4, T=400, eta=0.02, seed=1,
                           engine="scan", block_size=4)
        w32, _ = run_generalized_async_sgd(np.zeros(prob.d, np.float32), prob, cfg)
        wbf, _ = run_generalized_async_sgd(
            np.zeros(prob.d, np.float32), prob,
            replace(cfg, snapshot_dtype="bfloat16"))
        assert np.asarray(wbf).dtype == np.float32  # params stay fp32
        np.testing.assert_allclose(np.asarray(wbf), np.asarray(w32),
                                   atol=5e-2)  # bf16 snapshot quantization
        wpe, _ = run_generalized_async_sgd(
            np.zeros(prob.d, np.float32), prob,
            replace(cfg, block_size=1, snapshot_dtype="bfloat16"))
        np.testing.assert_allclose(np.asarray(wpe), np.asarray(w32), atol=5e-2)

    def test_collect_extras_off_prunes_stats(self):
        prob = Quadratic(self.N)
        cfg = ServerConfig(n=self.N, C=4, T=400, eta=0.02, seed=5,
                           engine="scan", stream="device", mu=np.ones(self.N))
        w_full, tr_full = run_generalized_async_sgd(
            np.zeros(prob.d, np.float32), prob, cfg)
        w_lite, tr_lite = run_generalized_async_sgd(
            np.zeros(prob.d, np.float32), prob,
            replace(cfg, collect_extras=False))
        # identical trajectory, pruned observables
        np.testing.assert_allclose(np.asarray(w_lite), np.asarray(w_full),
                                   atol=1e-6)
        assert "mean_delays" in tr_full.extras and "p_traj" in tr_full.extras
        assert set(tr_lite.extras) == {"p_final"}

    def test_blocked_rejects_custom_update(self):
        prob = Quadratic(self.N)
        cfg = ServerConfig(
            n=self.N, C=4, T=50, eta=0.1, engine="scan", block_size=4,
            apply_update=lambda w, g, s: w - s * g,
        )
        with pytest.raises(ValueError, match="block_size"):
            run_generalized_async_sgd(np.zeros(prob.d, np.float32), prob, cfg)

    def test_auto_blocked_rejects_custom_update(self):
        """block_size="auto" must re-check the custom-update guard after
        resolving E — a silently dropped apply_update is a wrong result."""
        prob = Quadratic(self.N)
        cfg = ServerConfig(
            n=self.N, C=4, T=400, eta=0.1, engine="scan", block_size="auto",
            apply_update=lambda w, g, s: w - 2 * s * g,
        )
        with pytest.raises(ValueError, match="default update"):
            run_generalized_async_sgd(np.zeros(prob.d, np.float32), prob, cfg)

    def test_blocked_rejects_unknown_update(self):
        """The blocked branch validates cfg.update like the per-event one."""
        prob = Quadratic(self.N)
        cfg = ServerConfig(n=self.N, C=2, T=20, eta=0.1, engine="scan",
                           block_size=2, update="bogus")
        with pytest.raises(ValueError, match="bogus"):
            run_generalized_async_sgd(np.zeros(prob.d, np.float32), prob, cfg)

    def test_blocked_accepts_mixed_float_rejects_int_params(self):
        # mixed *float* trees flat-pack per leaf (fp32 master vector) and
        # run the blocked path; non-float leaves still cannot
        class Ident:
            def device_grad(self, j, w, k):
                return jax.tree_util.tree_map(
                    lambda x: jnp.zeros_like(x) if jnp.issubdtype(x.dtype, jnp.inexact) else x,
                    w,
                )

        cfg = ServerConfig(n=4, C=2, T=20, eta=0.1, engine="scan", block_size=2)
        mixed = {"a": jnp.ones(3, jnp.float32), "b": jnp.ones(3, jnp.bfloat16)}
        out = run_generalized_async_sgd(mixed, Ident(), cfg)
        w_fin = out[0] if isinstance(out, tuple) else out
        assert w_fin["a"].dtype == jnp.float32
        assert w_fin["b"].dtype == jnp.bfloat16

        w_int = {"a": jnp.zeros(3, jnp.float32), "steps": jnp.zeros(3, jnp.int32)}
        with pytest.raises(ValueError, match="all-float"):
            run_generalized_async_sgd(w_int, Ident(), cfg)


# ------------------------------------------------------------------ #
# fused block kernel vs jnp oracle (interpret mode — the engine's path)
# ------------------------------------------------------------------ #
class TestBlockPrefixKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("E,R", [(4, 5), (8, 9), (1, 3)])
    def test_kernel_vs_ref(self, dtype, E, R):
        from repro.kernels.ref import block_prefix_update_ref
        from repro.kernels.weighted_update import BLOCK_TILE, block_prefix_update

        rng = np.random.default_rng(11)
        P = 2 * BLOCK_TILE
        snaps = jnp.asarray(rng.normal(size=(R, P)), dtype)
        w = jnp.asarray(rng.normal(size=(P,)), jnp.float32)
        D = jnp.asarray(rng.normal(size=(E, P)) * 0.1, jnp.float32)
        slots = jnp.asarray(rng.choice(R - 1, size=E, replace=False), jnp.int32)
        ks, kw = block_prefix_update(snaps, w, D, slots, interpret=True)
        rs, rw = block_prefix_update_ref(snaps, w, D, slots)
        tol = dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=1e-6)
        np.testing.assert_allclose(np.asarray(ks, np.float32),
                                   np.asarray(rs, np.float32), **tol)
        np.testing.assert_allclose(np.asarray(kw), np.asarray(rw), atol=1e-6)
        assert ks.dtype == snaps.dtype and kw.dtype == w.dtype

    def test_kernel_duplicate_trash_slots_last_wins(self):
        """Padded lanes all target the trash row; kernel resolves them in
        event order (last-writer-wins), and real rows are untouched."""
        from repro.kernels.weighted_update import BLOCK_TILE, block_prefix_update

        P, R, E = BLOCK_TILE, 4, 3
        snaps = jnp.full((R, P), 7.0)
        w = jnp.zeros((P,))
        D = jnp.stack([jnp.full((P,), float(i + 1)) for i in range(E)])
        slots = jnp.asarray([R - 1, 1, R - 1], jnp.int32)
        ks, kw = block_prefix_update(snaps, w, D, slots, interpret=True)
        np.testing.assert_allclose(np.asarray(ks[R - 1]), -6.0)  # 0-(1+2+3)
        np.testing.assert_allclose(np.asarray(ks[1]), -3.0)
        np.testing.assert_allclose(np.asarray(ks[0]), 7.0)
        np.testing.assert_allclose(np.asarray(kw), -6.0)

    def test_kernel_requires_tile_aligned_P(self):
        from repro.kernels.weighted_update import block_prefix_update

        with pytest.raises(ValueError, match="BLOCK_TILE"):
            block_prefix_update(
                jnp.zeros((3, 100)), jnp.zeros(100), jnp.zeros((2, 100)),
                jnp.zeros(2, jnp.int32),
            )

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_scatter_kernel_vs_ref(self, dtype):
        """Lane-partitioned variant: precomputed rows, scatter-only pass."""
        from repro.kernels.ref import block_scatter_rows_ref
        from repro.kernels.weighted_update import BLOCK_TILE, block_scatter_rows

        rng = np.random.default_rng(13)
        P, R, E = 2 * BLOCK_TILE, 5, 4
        snaps = jnp.asarray(rng.normal(size=(R, P)), dtype)
        w = jnp.asarray(rng.normal(size=(P,)), jnp.float32)
        W = jnp.asarray(rng.normal(size=(E, P)), jnp.float32)
        slots = jnp.asarray([R - 1, 1, R - 1, 2], jnp.int32)  # dup trash rows
        ks, kw = block_scatter_rows(snaps, w, W, slots, interpret=True)
        rs, rw = block_scatter_rows_ref(snaps, w, W, slots)
        tol = (dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16
               else dict(atol=1e-6))
        np.testing.assert_allclose(np.asarray(ks, np.float32),
                                   np.asarray(rs, np.float32), **tol)
        np.testing.assert_allclose(np.asarray(kw), np.asarray(rw), atol=1e-6)
        # last-writer-wins on the duplicated trash row, real rows untouched
        np.testing.assert_allclose(np.asarray(rs[R - 1], np.float32),
                                   np.asarray(W[2], np.float32), **tol)
        assert ks.dtype == snaps.dtype and kw.dtype == w.dtype
