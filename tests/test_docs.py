"""Docs stay true: every file:symbol reference and relative link in docs/
and README.md must resolve (the same check the CI docs job runs)."""
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_docs  # noqa: E402


def test_doc_tree_exists():
    for name in ("architecture.md", "theory.md", "benchmarks.md"):
        assert (REPO / "docs" / name).exists(), f"docs/{name} missing"


def test_all_references_resolve():
    errors = []
    for md in check_docs.doc_files(REPO):
        assert md.exists(), md
        errors.extend(check_docs.check_file(md, REPO))
    assert not errors, "\n".join(errors)


def test_checker_catches_bad_symbol(tmp_path):
    """The checker itself must fail on a dead reference (no false greens)."""
    bad = tmp_path / "bad.md"
    bad.write_text(
        "see `src/repro/core/queue_sim.py:no_such_symbol_xyz` and "
        "[gone](missing_file.md)\n"
    )
    errors = check_docs.check_file(bad, REPO)
    assert len(errors) == 2
    assert any("no_such_symbol_xyz" in e for e in errors)
    assert any("missing_file.md" in e for e in errors)
