"""Optimizers, data pipeline, checkpointing, FL engine integration."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt import latest_step, restore, save
from repro.configs.base import FLConfig, OptimConfig
from repro.data.pipeline import FederatedClassification, SyntheticLMStream, make_client_speeds
from repro.fl import MLPClassifier, run_experiment
from repro.optim import make_optimizer


class TestOptimizers:
    def _quad_min(self, name, **kw):
        opt = make_optimizer(OptimConfig(name=name, lr=0.1, **kw))
        params = {"w": jnp.array([3.0, -2.0])}
        state = opt.init(params)
        grad_fn = jax.grad(lambda p: jnp.sum(p["w"] ** 2))
        for _ in range(300):
            g = grad_fn(params)
            params, state = opt.update(g, state, params)
        return float(jnp.max(jnp.abs(params["w"])))

    @pytest.mark.parametrize("name", ["sgd", "momentum", "adamw"])
    def test_minimizes_quadratic(self, name):
        assert self._quad_min(name) < 1e-2

    def test_scale_is_importance_weight(self):
        opt = make_optimizer(OptimConfig(name="sgd", lr=0.1))
        params = {"w": jnp.array([1.0])}
        st = opt.init(params)
        g = {"w": jnp.array([1.0])}
        p1, _ = opt.update(g, st, params, scale=1.0)
        p2, _ = opt.update(g, st, params, scale=2.0)
        assert float(params["w"][0] - p2["w"][0]) == pytest.approx(
            2 * float(params["w"][0] - p1["w"][0])
        )

    def test_bf16_state_dtype(self):
        opt = make_optimizer(OptimConfig(name="adamw", state_dtype="bfloat16"))
        params = {"w": jnp.zeros((4,), jnp.bfloat16)}
        st = opt.init(params)
        assert st["m"]["w"].dtype == jnp.bfloat16


class TestData:
    def test_lm_stream_learnable_structure(self):
        s = SyntheticLMStream(vocab_size=64, seq_len=32, seed=0)
        b = s.batch(16)
        assert b["tokens"].shape == (16, 32)
        assert b["labels"].shape == (16, 32)
        # markov structure: successor sets are small
        assert (b["tokens"] >= 0).all() and (b["tokens"] < 64).all()

    def test_federated_split_heterogeneous(self):
        d = FederatedClassification(n_clients=10, num_classes=10, classes_per_client=7, seed=0)
        for i in range(10):
            ys = d.client_batch(i, 512)["y"]
            assert len(np.unique(ys)) <= 7
        ys_eval = d.eval_batch(2048)["y"]
        assert len(np.unique(ys_eval)) == 10  # server eval sees all classes

    def test_client_speeds(self):
        mu = make_client_speeds(100, 0.5, 10.0, seed=0)
        assert (mu == 10.0).sum() == 50
        assert (mu == 1.0).sum() == 50


class TestInitDeterminism:
    @pytest.mark.slow  # two cold subprocesses, each compiling a model init
    def test_init_params_stable_across_processes(self):
        """crc32 path hashing: same seed -> same params in any process
        (PYTHONHASHSEED-proof) — checkpoint reproducibility depends on it."""
        import subprocess, sys

        code = (
            "import jax, numpy as np;"
            "from repro.configs import smoke_config;"
            "from repro.models import api;"
            "from repro.models.module import init_params;"
            "cfg = smoke_config('yi_6b');"
            "p = init_params(api.model_meta(cfg), jax.random.PRNGKey(0));"
            "leaves = jax.tree_util.tree_leaves(p);"
            "print(float(sum(np.abs(np.asarray(l)).sum() for l in leaves)))"
        )
        outs = set()
        for _ in range(2):
            import pathlib
            repo_root = str(pathlib.Path(__file__).resolve().parent.parent)
            r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                               text=True, cwd=repo_root, timeout=600,
                               env={"PYTHONPATH": "src", "PYTHONHASHSEED": "random",
                                    "PATH": "/usr/bin:/bin",
                                    "HOME": os.environ.get("HOME", "/root")})
            assert r.returncode == 0, r.stderr[-1000:]
            outs.add(r.stdout.strip())
        assert len(outs) == 1, f"init not process-deterministic: {outs}"


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": np.arange(6).reshape(2, 3).astype(np.float32),
                "b": {"c": np.ones((4,), np.int32)}}
        save(str(tmp_path), 7, tree, metadata={"note": "x"})
        assert latest_step(str(tmp_path)) == 7
        like = jax.tree_util.tree_map(np.zeros_like, tree)
        out = restore(str(tmp_path), 7, like)
        np.testing.assert_array_equal(out["a"], tree["a"])
        np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])

    def test_rotation(self, tmp_path):
        tree = {"w": np.zeros(3)}
        for s in range(6):
            save(str(tmp_path), s, tree, keep=3)
        from repro.ckpt import available_steps

        assert available_steps(str(tmp_path)) == [3, 4, 5]

    def test_shape_mismatch_raises(self, tmp_path):
        save(str(tmp_path), 0, {"w": np.zeros(3)})
        with pytest.raises(ValueError):
            restore(str(tmp_path), 0, {"w": np.zeros(4)})


class TestFLEngine:
    def test_methods_run_and_genasync_wins(self):
        """The paper's §5 ordering at equal CS steps under speed heterogeneity."""
        flc = FLConfig(n_clients=16, concurrency=8, server_steps=250,
                       speed_ratio=10.0, seed=0)
        accs = {}
        for m in ("gen_async", "async_sgd", "fedbuff"):
            r = run_experiment(flc, m, eta=0.08, eval_every=250)
            accs[m] = r.eval_acc[-1]
        assert accs["gen_async"] > accs["fedbuff"]
        assert accs["async_sgd"] > accs["fedbuff"]

    def test_mlp_trains(self):
        d = FederatedClassification(n_clients=4, seed=1)
        model = MLPClassifier(d.dim, d.num_classes, seed=1)
        opt_grad = jax.jit(jax.grad(model.loss))
        params = model.init_params
        b = d.eval_batch(512)
        batch = {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}
        l0 = float(model.loss(params, batch))
        for _ in range(100):
            g = opt_grad(params, batch)
            params = jax.tree_util.tree_map(lambda p, gg: p - 0.1 * gg, params, g)
        assert float(model.loss(params, batch)) < l0 * 0.7
