"""Reproduce the paper's sampling-optimization study (Figs. 2, 3, 5, 9).

Sweeps the fast-client speed mu_f, computes the bound-optimal sampling
probability p (CS-step and physical-time objectives), and validates the
delay predictions against event-driven simulation of the closed network.

    PYTHONPATH=src python examples/optimal_sampling.py
"""
import numpy as np

from repro.core import (
    BoundConstants,
    JacksonNetwork,
    SimConfig,
    optimize_general,
    optimize_physical_time,
    optimize_two_cluster,
    simulate,
    two_cluster_delay_bounds,
)


def main() -> None:
    n, n_f = 100, 90
    k = BoundConstants(A=100.0, L=1.0, B=20.0, C=10, T=10_000)

    print("== Fig. 2/3: optimal p_fast and bound improvement vs speed ==")
    print(f"{'mu_f':>6s} {'p_fast*':>10s} {'p_slow*':>10s} {'improvement':>12s}")
    for mu_f in (2.0, 4.0, 8.0, 16.0):
        res = optimize_two_cluster(mu_f, 1.0, n, n_f, k)
        print(f"{mu_f:6.1f} {res.p[0]:10.2e} {res.p[-1]:10.2e} "
              f"{100*res.relative_improvement:11.1f}%")

    print("\n== App. E.2 (Fig. 9): physical-time objective ==")
    for mu_f in (2.0, 8.0):
        res = optimize_physical_time(mu_f, 1.0, n, n_f, k, U=1000.0)
        print(f"mu_f={mu_f:4.1f} p_fast*={res.p[0]:.2e} "
              f"improvement={100*res.relative_improvement:.1f}%")

    print("\n== Fig. 5: saturated delays, theory vs simulation ==")
    n2, nf2, C2 = 10, 5, 1000
    mu = np.array([1.2] * nf2 + [1.0] * (n2 - nf2))
    p = np.full(n2, 1 / n2)
    bounds = two_cluster_delay_bounds(n2, nf2, 1.2, 1.0, C2)
    net = JacksonNetwork(mu=mu, p=p, C=C2)
    est = net.expected_delays()
    sim = simulate(SimConfig(mu=mu, p=p, C=C2, T=300_000, seed=0, record_delays=True))
    sd = sim.mean_delay_per_node()
    print(f"fast: closed-form<= {bounds[0]:7.1f}  jackson-est {est[0]:7.1f}  sim {np.mean(sd[:nf2]):7.1f}")
    print(f"slow: closed-form<= {bounds[1]:7.1f}  jackson-est {est[-1]:7.1f}  sim {np.mean(sd[nf2:]):7.1f}")
    print("(paper reports ~50 fast / ~1950 slow for this configuration)")

    print("\n== Beyond-paper: general heterogeneous speeds at n=256, C=64 ==")
    # analytic simplex gradients make this size interactive (the seed
    # finite-difference optimizer needed O(n^2 C) per step)
    import time

    rng = np.random.default_rng(0)
    mu_het = rng.uniform(0.5, 8.0, 256)
    k_big = BoundConstants(A=100.0, L=1.0, B=20.0, C=64, T=10_000)
    t0 = time.perf_counter()
    res = optimize_general(mu_het, k_big, iters=60)
    dt = time.perf_counter() - t0
    corr = np.corrcoef(mu_het, res.p)[0, 1]
    print(f"optimized 256 clients in {dt:.2f}s: improvement="
          f"{100*res.relative_improvement:.1f}%  corr(mu, p*)={corr:+.2f}")
    print("(negative correlation: slower clients are sampled more, as in Fig. 4)")


if __name__ == "__main__":
    main()
