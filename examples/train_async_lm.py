"""End-to-end driver: asynchronous LM pre-training with Generalized AsyncSGD.

Trains a transformer (reduced Granite-family config by default; pass
--preset 100m for a ~100M-parameter model) for a few hundred server steps on
synthetic non-iid LM streams with heterogeneous client speeds, using the
paper's importance-weighted asynchronous updates and Jackson-optimal
sampling.  Prints eval loss vs CS steps and the realized queueing delays.

    PYTHONPATH=src python examples/train_async_lm.py --steps 300
    PYTHONPATH=src python examples/train_async_lm.py --preset 100m --steps 200
"""
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--mode", "lm", *sys.argv[1:]]
    train_main()
