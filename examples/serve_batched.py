"""Batched serving demo: prefill + decode with the unified serve_step.

Works for every assigned architecture family (dense KV cache, MoE routing,
Mamba2 SSM state, Zamba2 hybrid, audio/VLM stubs):

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-130m
    PYTHONPATH=src python examples/serve_batched.py --arch qwen2-moe-a2.7b --steps 16
"""
import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main()
