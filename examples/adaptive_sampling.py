"""Adaptive sampling on the fused device stream: static-optimal vs adaptive.

The paper optimizes the sampling vector p *offline* from known client
speeds.  The fused engine (`stream="device"`) keeps the closed network
inside the compiled program, so p can instead be re-optimized every
``refresh_every`` CS steps from the *observed* queue dynamics — no prior
knowledge of the speeds.  This demo runs, on a two-cluster network:

  1. uniform sampling                  (the baseline),
  2. static bound-optimal sampling     (oracle speeds, `optimize_general`),
  3. adaptive sampling from uniform    (control loop, measured speeds),

and prints the bound trajectory of the adaptive run against the static
optimum, plus the realized per-node delays of all three.

    PYTHONPATH=src python examples/adaptive_sampling.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import BoundConstants, make_runner, optimize_general
from repro.core.sampling import bound_for_p


def main() -> None:
    n, C, T = 32, 8, 20_000
    refresh = 500
    mu = np.array([8.0] * (n // 2) + [1.0] * (n // 2))
    k = BoundConstants(C=C, T=T)
    uniform = np.full(n, 1.0 / n)

    # oracle: static optimum from the true speeds (generous iteration budget
    # — the adaptive loop's accumulated mirror steps are a strong opponent)
    opt = optimize_general(mu, k, iters=800)
    print("== two-cluster network: n=%d, C=%d, T=%d ==" % (n, C, T))
    print(f"uniform bound        : {opt.uniform_bound:8.4f}")
    print(f"static-optimal bound : {opt.bound:8.4f}  "
          f"(p_fast={opt.p[0]:.4f}, p_slow={opt.p[-1]:.4f})")

    # adaptive: control loop over the fused device stream (no model — the
    # stream/controller runs standalone by passing a zero gradient source)
    run = make_runner(
        lambda j, w, kk: w * 0.0, C=C, stream="device", n=n, T=T,
        adaptive=True, refresh_every=refresh, bound=k,
    )
    runs = {}
    for name, p0, adaptive_run in (("uniform", uniform, False),
                                   ("static-opt", opt.p, False),
                                   ("adaptive", uniform, True)):
        r = run if adaptive_run else make_runner(
            lambda j, w, kk: w * 0.0, C=C, stream="device", n=n, T=T)
        _, _, ex = jax.jit(r)(jnp.zeros(2), jnp.asarray(mu), jnp.asarray(p0),
                              jax.random.PRNGKey(0), 0.0)
        runs[name] = {key: np.asarray(v, np.float64) for key, v in ex.items()}

    print("\n== adaptive bound trajectory (per control refresh) ==")
    traj = runs["adaptive"]["p_traj"]
    print(f"{'step':>7s} {'bound':>9s} {'vs static-opt':>14s}")
    for i in range(0, traj.shape[0], max(traj.shape[0] // 10, 1)):
        p_i = np.maximum(traj[i], 1e-12)
        p_i /= p_i.sum()
        b_i = bound_for_p(mu, p_i, k)[0]
        print(f"{(i + 1) * refresh:7d} {b_i:9.4f} {100 * (b_i / opt.bound - 1):+13.2f}%")
    p_fin = np.maximum(runs["adaptive"]["p_final"], 1e-12)
    p_fin /= p_fin.sum()
    b_fin = bound_for_p(mu, p_fin, k)[0]
    print(f"final adaptive bound : {b_fin:8.4f}  "
          f"({100 * (b_fin / opt.bound - 1):+.2f}% vs static optimum)")

    print("\n== realized delays (CS steps, fast / slow cluster means) ==")
    for name, ex in runs.items():
        m_node = ex["delay_sum"] / np.maximum(ex["comp"], 1.0)
        print(f"{name:>11s}: fast {m_node[: n // 2].mean():7.2f}   "
              f"slow {m_node[n // 2 :].mean():7.2f}")
    print("\n(optimal sampling under-samples fast clients: their queues — and "
          "the slow\n clients' — drain, cutting the stale-gradient delays the "
          "bound penalizes.)")


if __name__ == "__main__":
    main()
