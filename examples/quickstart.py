"""Quickstart: the paper's pipeline in 60 seconds.

1. model client speeds as a closed Jackson network,
2. compute delay-aware optimal sampling probabilities (Generalized AsyncSGD),
3. train a small federated model and compare against uniform AsyncSGD.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    BoundConstants,
    JacksonNetwork,
    SimConfig,
    optimize_two_cluster,
    simulate,
)
from repro.configs.base import FLConfig
from repro.fl import run_experiment


def main() -> None:
    # --- 1. queueing analysis ------------------------------------------- #
    n, n_f, C = 10, 5, 10
    mu = np.array([10.0] * n_f + [1.0] * (n - n_f))   # 5 fast, 5 slow
    p = np.full(n, 1 / n)
    net = JacksonNetwork(mu=mu, p=p, C=C)
    m_hat = net.expected_delays()
    sim = simulate(SimConfig(mu=mu, p=p, C=C, T=50_000, seed=0, record_delays=True))
    print("expected delays (steps)  theory:", np.round(m_hat, 1))
    print("                        simulated:", np.round(sim.mean_delay_per_node(), 1))

    # --- 2. optimal sampling --------------------------------------------- #
    k = BoundConstants(C=C, T=10_000)
    res = optimize_two_cluster(mu_f=10.0, mu_s=1.0, n=n, n_f=n_f, k=k)
    print(f"\noptimal p_fast={res.p[0]:.4f} p_slow={res.p[-1]:.4f} "
          f"(uniform would be {1/n:.4f})")
    print(f"bound improvement vs uniform: {100*res.relative_improvement:.1f}%")

    # --- 3. train --------------------------------------------------------- #
    # engine="scan" replays the pre-simulated event stream on device as one
    # compiled lax.scan (engine="python" is the per-event reference loop)
    flc = FLConfig(n_clients=20, concurrency=8, server_steps=200,
                   speed_ratio=10.0, engine="scan")
    print("\ntraining (200 server steps, 20 clients, 10x speed gap, scan engine):")
    for method in ("gen_async", "async_sgd", "fedbuff"):
        r = run_experiment(flc, method, eta=0.08, eval_every=100)
        print(f"  {method:10s} final accuracy {r.eval_acc[-1]:.3f}")


if __name__ == "__main__":
    main()
