"""Preemptible training: SIGKILL a faulted run mid-flight, resume bitwise.

Long asynchronous-FL runs die — preempted VMs, OOM kills, node drains —
and the injected failure modes (client churn, crashes, stragglers) make
re-running from scratch both expensive and irreproducible.  The engine
therefore checkpoints its *full* state every ``ckpt_every`` events
(iterate, snapshot ring, queue/statistics state, sampling distribution,
eval buffer, event cursor) and `resume=True` continues from the latest
checkpoint with a bitwise-identical trajectory.

This demo runs the §5 federated experiment with churn + crashes +
timeouts and a divergence guard, in three acts:

  1. a child process starts the run and is SIGKILLed mid-flight (a real
     kill -9 — no cleanup, no atexit),
  2. the parent resumes from the surviving checkpoints to completion,
  3. an uninterrupted reference run (fresh directory) confirms the
     resumed final parameters are bitwise identical.

    PYTHONPATH=src python examples/preemptible_run.py
"""
import os
import shutil
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
sys.path.insert(0, SRC)

N, C, T = 32, 8, 2000
CKPT_EVERY = 250
SEED = 7


def _experiment(ckpt_dir: str, resume: bool):
    import jax

    from repro.configs.base import FLConfig
    from repro.core import FaultConfig, GuardConfig
    from repro.fl.engine import run_experiment

    flc = FLConfig(n_clients=N, concurrency=C, server_steps=T, seed=SEED,
                   engine="scan")
    fault = FaultConfig(off_rate=0.2, on_rate=1.0,   # Markov on/off churn
                        crash_rate=0.05,             # crash-with-task-loss
                        timeout_rate=0.1)            # straggler timeouts
    guard = GuardConfig(max_grad_norm=1e3, stale_cutoff=25 * C)
    run = run_experiment(flc, "gen_async", eval_every=T // 4, faults=fault,
                         guard=guard, ckpt_dir=ckpt_dir,
                         ckpt_every=CKPT_EVERY, resume=resume)
    flat = np.concatenate(
        [np.asarray(x).ravel() for x in jax.tree_util.tree_leaves(run.final_params)]
    )
    return run, flat


def main() -> None:
    from repro.ckpt import checkpoint as ck

    root = tempfile.mkdtemp(prefix="preemptible_")
    d_run, d_ref = os.path.join(root, "run"), os.path.join(root, "reference")
    try:
        # -- act 1: child starts the run, parent SIGKILLs it mid-flight --- #
        child_src = (
            f"import sys; sys.path.insert(0, {SRC!r}); "
            f"sys.path.insert(0, {os.path.dirname(os.path.abspath(__file__))!r}); "
            f"from preemptible_run import _experiment; "
            f"_experiment({d_run!r}, False); print('UNREACHED', flush=True)"
        )
        print(f"[1] starting run (T={T}, ckpt every {CKPT_EVERY} events) "
              "in a child process ...")
        child = subprocess.Popen([sys.executable, "-c", child_src],
                                 stdout=subprocess.PIPE, stderr=subprocess.PIPE)
        # wait for the second checkpoint to land, then kill -9
        deadline = time.time() + 300
        while time.time() < deadline:
            steps = ck.available_steps(d_run) if os.path.isdir(d_run) else []
            if len(steps) >= 2:
                break
            if child.poll() is not None:
                out, err = child.communicate()
                raise SystemExit(
                    f"child exited early (rc={child.returncode}):\n"
                    f"{err.decode()[-2000:]}"
                )
            time.sleep(0.1)
        child.kill()  # SIGKILL: no cleanup, exactly like a preemption
        child.wait()
        steps = ck.available_steps(d_run)
        print(f"    SIGKILLed at checkpoints {steps} "
              f"(event cursor {max(steps)}/{T})")
        assert steps and max(steps) < T, "child finished before the kill"

        # -- act 2: resume from the surviving checkpoints ----------------- #
        print("[2] resuming from the latest checkpoint ...")
        run_res, flat_res = _experiment(d_run, resume=True)
        kinds = np.asarray(run_res.extras["kind_count"])
        print(f"    resumed to T={T}: kind counts "
              f"complete={kinds[0]} flip={kinds[1]} crash={kinds[2]} "
              f"timeout={kinds[3]}, guard_rejects="
              f"{int(np.asarray(run_res.extras['guard_rejects']))}, "
              f"stale_drops={int(np.asarray(run_res.extras['stale_drops']))}")

        # -- act 3: uninterrupted reference run, bitwise comparison ------- #
        print("[3] uninterrupted reference run ...")
        _, flat_ref = _experiment(d_ref, resume=False)
        bitwise = (flat_res.view(np.uint8) == flat_ref.view(np.uint8)).all()
        print(f"    resumed == uninterrupted, bitwise: {bool(bitwise)} "
              f"({flat_ref.size} parameters)")
        if not bitwise:
            raise SystemExit("kill-and-resume diverged from the reference run")
        print("\nA preempted run and its resume are the SAME run: the "
              "checkpoint carries the\nfull engine state, and per-chunk "
              "randomness re-derives from the base key, so\nnothing about "
              "the interruption is visible in the final iterate.")
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
