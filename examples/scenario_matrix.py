"""Scenario matrix on the compiled engine: one XLA program, many runs.

The queuing structure of asynchronous FL makes the event stream independent
of the gradients, so whole training runs compile into a single `lax.scan` —
and a grid of them (seeds x sampling policies x heterogeneity levels) into a
single `vmap`-ed call.  This sweeps the paper's §5 comparison across
heterogeneity in seconds:

    PYTHONPATH=src python examples/scenario_matrix.py
"""
import numpy as np

from repro.configs.base import FLConfig
from repro.fl import run_matrix


def main() -> None:
    flc = FLConfig(n_clients=40, concurrency=16, server_steps=2000)
    seeds = (0, 1, 2)
    policies = ("uniform", "optimal", "physical_time")
    ratios = (1.0, 4.0, 16.0)
    print(f"{len(seeds)} seeds x {policies} x speed ratios {ratios} "
          f"= {len(seeds) * len(policies) * len(ratios)} runs, one compiled call\n")
    m = run_matrix(flc, seeds=seeds, policies=policies, speed_ratios=ratios,
                   eta=0.08, eval_every=200)

    acc = m.final_acc.mean(axis=0)          # average over seeds -> (P, H)
    print(f"final accuracy (mean over {len(seeds)} seeds):")
    print(f"{'policy':>14s} " + " ".join(f"ratio={r:<5g}" for r in ratios))
    for pi, pol in enumerate(policies):
        print(f"{pol:>14s} " + " ".join(f"{acc[pi, hi]:.3f}    " for hi in range(len(ratios))))

    # physical time to finish T steps: optimal sampling trades a slightly
    # slower clock for unbiased, lower-variance progress per step
    t_end = m.eval_times[..., -1].mean(axis=0)
    print("\nphysical time at final eval (mean over seeds):")
    for pi, pol in enumerate(policies):
        print(f"{pol:>14s} " + " ".join(f"{t_end[pi, hi]:8.1f} " for hi in range(len(ratios))))


if __name__ == "__main__":
    main()
