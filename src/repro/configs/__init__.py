from .base import SHAPES, FLConfig, MeshConfig, ModelConfig, OptimConfig, ShapeConfig
from .registry import ALIASES, ARCH_IDS, all_pairs, batch_logical_axes, for_shape, get_config, input_specs, smoke_config
