"""Qwen1.5/2-MoE-A2.7B: fine-grained MoE. [hf:Qwen/Qwen1.5-MoE-A2.7B]

24L, d_model 2048, 16 heads (MHA kv=16), 60 routed experts top-4 (d_ff 1408)
plus 4 shared experts, vocab 151936.
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2_moe_a2_7b",
        family="moe",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=151936,
        num_experts=60,
        num_experts_per_tok=4,
        num_shared_experts=4,
        d_ff_expert=1408,
        qkv_bias=True,
        moe_group_size=1024,
    )
