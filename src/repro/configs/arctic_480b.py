"""Snowflake Arctic (480B): dense-MoE hybrid. [hf:Snowflake/snowflake-arctic-base]

35L, d_model 7168, 56 heads (GQA kv=8), 128 experts top-2 (d_ff 4864 each)
with a parallel dense residual FFN, vocab 32000.
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="arctic_480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=4864,            # dense residual branch width
        vocab_size=32000,
        num_experts=128,
        num_experts_per_tok=2,
        d_ff_expert=4864,
        moe_dense_residual=True,
        moe_group_size=512,
    )
