"""Granite-3.0-2B: dense GQA. [hf:ibm-granite/granite-3.0-2b-base]

40L, d_model 2048, 32 heads (GQA kv=8), d_ff 8192, vocab 49155.
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="granite_3_2b",
        family="dense",
        num_layers=40,
        d_model=2048,
        num_heads=32,
        num_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=49155,
        tie_embeddings=True,
    )
