"""StarCoder2-7B: dense code LM, GQA, RoPE. [arXiv:2402.19173]

32L, d_model 4608, 36 heads (GQA kv=4), d_ff 18432, vocab 49152.
(StarCoder2 uses a 4k sliding window in alternating layers; we expose the
window only for the long_500k variant per the assignment's shape policy.)
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2_7b",
        family="dense",
        num_layers=32,
        d_model=4608,
        num_heads=36,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18432,
        vocab_size=49152,
        rope_theta=100_000.0,
        qkv_bias=True,
        ffn_gated=False,
    )
