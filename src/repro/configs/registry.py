"""Architecture registry + input_specs for the dry-run.

Each `src/repro/configs/<id>.py` exports `get_config() -> ModelConfig` with
the exact assigned architecture; this module maps ids to configs, builds
reduced smoke variants, and produces ShapeDtypeStruct input stand-ins for
every (arch × input shape) pair.
"""
from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from .base import SHAPES, ModelConfig, ShapeConfig

ARCH_IDS = [
    "internvl2_26b",
    "starcoder2_7b",
    "musicgen_medium",
    "arctic_480b",
    "qwen2_5_32b",
    "mamba2_130m",
    "qwen2_moe_a2_7b",
    "yi_6b",
    "granite_3_2b",
    "zamba2_2_7b",
]

# CLI aliases with dashes/dots as given in the assignment
ALIASES = {
    "internvl2-26b": "internvl2_26b",
    "starcoder2-7b": "starcoder2_7b",
    "musicgen-medium": "musicgen_medium",
    "arctic-480b": "arctic_480b",
    "qwen2.5-32b": "qwen2_5_32b",
    "mamba2-130m": "mamba2_130m",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "yi-6b": "yi_6b",
    "granite-3-2b": "granite_3_2b",
    "zamba2-2.7b": "zamba2_2_7b",
}


def get_config(arch: str) -> ModelConfig:
    key = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.get_config()


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
    cfg = get_config(arch)
    upd: dict = dict(
        num_layers=2,
        d_model=256,
        d_ff=512,
        vocab_size=512,
        dtype="float32",
        remat="none",
        moe_group_size=128,
    )
    if cfg.num_heads:
        upd.update(num_heads=4, num_kv_heads=2, head_dim=64)
    if cfg.family == "moe":
        upd.update(
            num_experts=4,
            num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
            num_shared_experts=min(cfg.num_shared_experts, 1),
            d_ff_expert=128,
        )
    if cfg.family in ("ssm", "hybrid"):
        upd.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
    if cfg.family == "hybrid":
        upd.update(attn_every=1, num_layers=2)
    if cfg.family == "vlm":
        upd.update(num_patches=16)
    if cfg.sliding_window:
        upd.update(sliding_window=64)
    return cfg.replace(**upd)


def for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Shape-dependent config tweaks (the long-context sliding-window variant)."""
    if shape.name == "long_500k":
        if cfg.family in ("ssm",):
            return cfg
        # dense/moe/vlm/audio (and the hybrid's shared attention) switch to
        # the sliding-window variant for sub-quadratic long-context decode.
        return cfg.replace(sliding_window=4096 if cfg.sliding_window == 0 else cfg.sliding_window)
    return cfg


def input_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio_stub":
            batch = {
                "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        elif cfg.frontend == "vision_stub":
            s_text = S - cfg.num_patches
            batch = {
                "tokens": jax.ShapeDtypeStruct((B, s_text), i32),
                "patch_embeds": jax.ShapeDtypeStruct((B, cfg.num_patches, cfg.d_model), dtype),
                "labels": jax.ShapeDtypeStruct((B, s_text), i32),
            }
        else:
            batch = {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        if shape.kind == "prefill":
            batch.pop("labels")
        return batch
    # decode: ONE new token against a seq_len KV cache
    if cfg.frontend == "audio_stub":
        return {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model), dtype)}
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}


def batch_logical_axes(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    spec = input_specs(cfg, shape)
    out = {}
    for k, v in spec.items():
        if k in ("tokens", "labels", "loss_mask"):
            out[k] = ("batch", "seq") if v.ndim == 2 else ("batch",)
        elif k in ("embeds", "patch_embeds"):
            out[k] = ("batch", "seq", "embed")
        else:
            out[k] = tuple([None] * v.ndim)
    return out


def all_pairs() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in SHAPES]
