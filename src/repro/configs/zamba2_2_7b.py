"""Zamba2-2.7B: Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]

54 Mamba2 layers, d_model 2560 (d_inner 5120, ssm_state 64, head_dim 64),
one weight-shared attention+MLP block (32 heads MHA, d_ff 10240) applied
every 6 layers, vocab 32000.
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2_2_7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab_size=32000,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=64,
        attn_every=6,
    )
