"""Config system: frozen dataclasses, hashable (usable as jit static args)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    qkv_bias: bool = False
    ffn_gated: bool = True             # False = plain 2-matrix GELU MLP
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_dense_residual: bool = False   # arctic: parallel dense FFN residual
    d_ff_expert: int = 0
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    moe_group_size: int = 1024         # tokens per dispatch group (chunked MoE)
    moe_dispatch: str = "einsum"       # einsum (GShard one-hot) | sort (beyond-paper)
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    ssm_groups: int = 1
    # --- hybrid (zamba2) ---
    attn_every: int = 0                # shared attn+mlp block every k layers
    # --- attention windowing ---
    sliding_window: int = 0            # 0 = full causal
    # --- modality frontends (stubs per spec carve-out) ---
    num_patches: int = 0               # vlm: prefix patch embeds per sample
    frontend: str = "none"             # none | vision_stub | audio_stub
    # --- numerics / training ---
    dtype: str = "bfloat16"
    remat: str = "full"                # none | dots | full
    scan_layers: bool = True
    force_bf16_grads: bool = False     # cast residual-stream cotangents to bf16
                                       # before TP all-reduces (beyond-paper)
    use_pallas: bool = False           # TPU kernels (CPU dry-run uses jnp path)

    @property
    def d_inner(self) -> int:          # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"

    def replace(self, **kw) -> "ShapeConfig":
        return dataclasses.replace(self, **kw)


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class OptimConfig:
    name: str = "adamw"        # sgd | momentum | adamw
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.9
    state_dtype: str = "float32"   # adam moments dtype (bf16 for huge models)


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pods: int = 1

    @property
    def n_devices(self) -> int:
        return self.data * self.model * self.pods


@dataclass(frozen=True)
class FLConfig:
    """Generalized AsyncSGD scheduling config (the paper's knobs).

    The queueing knobs (``n_clients`` .. ``speed_ratio``) parameterize the
    closed Jackson network of §2 and the client-speed heterogeneity of the
    §5 experiment; the engine knobs (``engine`` .. ``devices``) pick how the
    server loop executes — see ``docs/architecture.md`` for the full
    host/device/blocked runner decision matrix, and
    `repro.core.async_sgd.ServerConfig` for the per-run equivalent these
    fields translate into (`repro.fl.engine.run_experiment`).
    """

    n_clients: int = 100           # n — number of federated clients
    concurrency: int = 10          # C — tasks in flight (closed-network pop.)
    server_steps: int = 200        # T — CS steps (one completion+dispatch each)
    sampling: str = "optimal"      # client-sampling policy for p:
                                   # uniform | optimal (Theorem-1 bound
                                   # minimizer) | physical_time
    service: str = "exp"           # service law: "exp" | "det" (host-only)
    frac_fast: float = 0.5         # fraction of fast clients (two clusters)
    speed_ratio: float = 10.0      # mu_fast / mu_slow
    weighting: str = "importance"  # importance (Alg. 1) | plain
    fedbuff_Z: int = 10            # FedBuff buffer size (flush every Z-th)
    seed: int = 0
    engine: str = "python"         # python (reference loop) | scan (compiled)
    stream: str = "host"           # scan event source: host (pre-simulated
                                   # replay) | device (fused on-device
                                   # generator — zero host pre-simulation)
    sparse: bool | str = "auto"    # device stream: sparse O(C) stream state
                                   # for large n (see ServerConfig.sparse) —
                                   # "auto" switches on above SPARSE_AUTO_N
                                   # when the speed profile collapses to few
                                   # classes; True forces, False keeps dense
    adaptive: bool = False         # device stream: adaptive sampling control
                                   # loop (re-optimize p from observed queues)
    refresh_every: int = 250       # control-loop cadence in CS steps
    block_size: int | str = 1      # scan engine: events per micro-block
                                   # (E > 1 = blocked replay; exact — see
                                   # engine_scan / docs); "auto" picks E from
                                   # measured conflict rates
                                   # (queue_sim.select_block_size)
    devices: int = 1               # blocked engine: lane-shard device count —
                                   # each micro-block's E gradient lanes are
                                   # split across this many devices (requires
                                   # block_size a >1 multiple of it)
    segmentation: str = "greedy"   # blocked cut placement: greedy | dp
                                   # (queue_sim.segment_blocks)
    scenario: str | None = None    # scenario-registry name (core.scenario.
                                   # SCENARIOS): phase-type service + Markov-
                                   # modulated availability; None or
                                   # "exponential" keeps the paper's exp/
                                   # always-on law (bitwise-identical engine
                                   # path)

    def replace(self, **kw) -> "FLConfig":
        return dataclasses.replace(self, **kw)
