"""Mamba2-130M: attention-free SSD. [arXiv:2405.21060]

24L, d_model 768, ssm_state 128, expand 2 (d_inner 1536, 24 heads of dim 64),
vocab 50280, tied embeddings.
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2_130m",
        family="ssm",
        num_layers=24,
        d_model=768,
        vocab_size=50280,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=64,
        tie_embeddings=True,
    )
