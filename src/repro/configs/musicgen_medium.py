"""MusicGen-medium backbone: decoder-only over EnCodec tokens. [arXiv:2306.05284]

48L, d_model 1536, 24 heads (MHA: kv=24), d_ff 6144, vocab 2048.
The EnCodec codec frontend is stubbed: input_specs provides precomputed
frame embeddings (B, S, d_model); the decoder predicts code ids.
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen_medium",
        family="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        frontend="audio_stub",
        ffn_gated=False,
    )
