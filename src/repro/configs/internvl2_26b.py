"""InternVL2-26B backbone: InternViT frontend (stub) + InternLM2-20B decoder.

[arXiv:2404.16821] — 48L, d_model 6144, 48 heads (GQA kv=8), d_ff 16384,
vocab 92553.  The ViT + MLP projector frontend is stubbed per the spec
carve-out: input_specs provides precomputed patch embeddings (256 patches).
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2_26b",
        family="vlm",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92553,
        rope_theta=1_000_000.0,
        frontend="vision_stub",
        num_patches=256,
    )
