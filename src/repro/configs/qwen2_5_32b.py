"""Qwen2.5-32B: dense, GQA, QKV bias. [hf:Qwen/Qwen2.5-0.5B model-card family]

64L, d_model 5120, 40 heads (GQA kv=8), d_ff 27648, vocab 152064.
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2_5_32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=27648,
        vocab_size=152064,
        rope_theta=1_000_000.0,
        qkv_bias=True,
    )
