"""Yi-6B: llama-architecture dense GQA. [arXiv:2403.04652]

32L, d_model 4096, 32 heads (GQA kv=4), d_ff 11008, vocab 64000.
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="yi_6b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab_size=64000,
        rope_theta=5_000_000.0,
    )
