"""Optimizers in plain JAX (no optax dependency): sgd, momentum, adamw.

API mirrors optax: `opt.init(params) -> state`, `opt.update(grads, state,
params) -> (updates, state)`; updates are *subtracted* by the caller.  State
dtype is configurable (bf16 moments for huge models — see OptimConfig).
All optimizers support a per-step scale (the Generalized-AsyncSGD importance
weight eta/(n p_j) divides out the base lr: we pass scale and the optimizer
multiplies its step by it).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import OptimConfig

Pytree = Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], Pytree]
    update: Callable[..., tuple[Pytree, Pytree]]
    # update(grads, state, params, scale=1.0) -> (new_params, new_state)


def _cast(tree: Pytree, dtype) -> Pytree:
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)


def make_optimizer(cfg: OptimConfig) -> Optimizer:
    sdt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32

    if cfg.name == "sgd":

        def init(params):
            return {"count": jnp.zeros((), jnp.int32)}

        def update(grads, state, params, scale=1.0):
            new = jax.tree_util.tree_map(
                lambda p, g: (p.astype(jnp.float32) - cfg.lr * scale * g.astype(jnp.float32)).astype(p.dtype),
                params,
                grads,
            )
            return new, {"count": state["count"] + 1}

        return Optimizer(init, update)

    if cfg.name == "momentum":

        def init(params):
            return {
                "count": jnp.zeros((), jnp.int32),
                "m": _cast(jax.tree_util.tree_map(jnp.zeros_like, params), sdt),
            }

        def update(grads, state, params, scale=1.0):
            m = jax.tree_util.tree_map(
                lambda m, g: (cfg.momentum * m.astype(jnp.float32) + g.astype(jnp.float32)).astype(sdt),
                state["m"],
                grads,
            )
            new = jax.tree_util.tree_map(
                lambda p, mm: (p.astype(jnp.float32) - cfg.lr * scale * mm.astype(jnp.float32)).astype(p.dtype),
                params,
                m,
            )
            return new, {"count": state["count"] + 1, "m": m}

        return Optimizer(init, update)

    if cfg.name == "adamw":

        def init(params):
            z = jax.tree_util.tree_map(jnp.zeros_like, params)
            return {
                "count": jnp.zeros((), jnp.int32),
                "m": _cast(z, sdt),
                "v": _cast(z, sdt),
            }

        def update(grads, state, params, scale=1.0):
            c = state["count"] + 1
            b1, b2 = cfg.beta1, cfg.beta2
            m = jax.tree_util.tree_map(
                lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(sdt),
                state["m"],
                grads,
            )
            v = jax.tree_util.tree_map(
                lambda v, g: (b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(sdt),
                state["v"],
                grads,
            )
            bc1 = 1.0 - b1 ** c.astype(jnp.float32)
            bc2 = 1.0 - b2 ** c.astype(jnp.float32)

            def step(p, mm, vv):
                mhat = mm.astype(jnp.float32) / bc1
                vhat = vv.astype(jnp.float32) / bc2
                upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
                if cfg.weight_decay:
                    upd = upd + cfg.weight_decay * p.astype(jnp.float32)
                return (p.astype(jnp.float32) - cfg.lr * scale * upd).astype(p.dtype)

            new = jax.tree_util.tree_map(step, params, m, v)
            return new, {"count": c, "m": m, "v": v}

        return Optimizer(init, update)

    raise ValueError(f"unknown optimizer {cfg.name}")
