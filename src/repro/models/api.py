"""Unified model API: every architecture family behind the same four calls.

    meta      = model_meta(cfg)                      # ParamMeta tree
    logits, _ = forward(params, batch, cfg)          # train / prefill
    cache     = init_cache(cfg, batch, seq_len)      # abstract cache spec
    logits, c = decode_step(params, cache, batch, cfg)

plus the training-facing:

    loss, aux           = loss_fn(params, batch, cfg)
    params, opt, metric = train_step(params, opt_state, batch, cfg, opt,
                                     sampling_weight)   # Alg.1 line 10 weight

`sampling_weight` is the Generalized-AsyncSGD importance factor 1/(n p_j)
(1.0 recovers plain synchronous SGD) — the paper's technique as a first-class
feature of the training step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import hybrid, mamba2, transformer
from repro.optim import Optimizer

__all__ = [
    "family_module",
    "model_meta",
    "forward",
    "init_cache",
    "cache_logical_axes",
    "decode_step",
    "loss_fn",
    "train_step",
    "serve_step",
]


def family_module(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        return transformer
    if cfg.family == "ssm":
        return mamba2
    if cfg.family == "hybrid":
        return hybrid
    raise ValueError(f"unknown family {cfg.family}")


def model_meta(cfg: ModelConfig) -> dict:
    return family_module(cfg).model_meta(cfg)


def forward(params, batch, cfg: ModelConfig):
    return family_module(cfg).forward(params, batch, cfg)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    return family_module(cfg).init_cache(cfg, batch, seq_len)


def cache_logical_axes(cfg: ModelConfig) -> dict:
    return family_module(cfg).cache_logical_axes(cfg)


def decode_step(params, cache, batch, cfg: ModelConfig):
    return family_module(cfg).decode_step(params, cache, batch, cfg)


# ------------------------------------------------------------------ #
# loss & steps
# ------------------------------------------------------------------ #
def loss_fn(params, batch, cfg: ModelConfig):
    """Next-token cross entropy (fp32), masked, + MoE aux loss."""
    logits, aux = forward(params, batch, cfg)
    labels = batch["labels"]                       # (B, S_lab)
    S_lab = labels.shape[1]
    if cfg.frontend == "vision_stub":
        # text logits start after the patch prefix; position P-1+i predicts
        # text token i (the last patch slot predicts the first text token).
        start = cfg.num_patches - 1
        logits = jax.lax.dynamic_slice_in_dim(logits, start, S_lab, axis=1)
    else:
        logits = logits[:, :S_lab]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        loss = jnp.mean(nll)
    else:
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    if cfg.family == "moe":
        loss = loss + cfg.router_aux_coef * aux
    return loss, aux


def train_step(params, opt_state, batch, cfg: ModelConfig, opt: Optimizer,
               sampling_weight: jax.Array | float = 1.0):
    """One Generalized-AsyncSGD server step on a (possibly sharded) batch.

    sampling_weight = 1/(n p_j) for the contributing client j (Alg. 1);
    it scales the whole update, keeping the gradient estimator unbiased.
    """
    (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch, cfg)
    new_params, new_opt = opt.update(grads, opt_state, params, scale=sampling_weight)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree_util.tree_leaves(grads))
    )
    metrics = {"loss": loss, "moe_aux": aux, "grad_norm": gnorm}
    return new_params, new_opt, metrics


def serve_step(params, cache, batch, cfg: ModelConfig):
    """One batched decode step; greedy next-token ids alongside raw logits."""
    logits, new_cache = decode_step(params, cache, batch, cfg)
    next_ids = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return {"logits": logits, "next_ids": next_ids}, new_cache
