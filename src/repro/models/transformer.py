"""Decoder-only transformer family: dense, MoE, VLM-backbone, audio-backbone.

One parameter tree + three entry points:
  * `forward`      — full-sequence logits (train / prefill),
  * `init_cache`   — ring-buffer KV cache metadata,
  * `decode_step`  — one-token serve step against the cache.

Layers are stacked along a leading 'layers' axis and executed with
jax.lax.scan (small HLO, fast SPMD compile) with configurable remat.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.module import ParamMeta

__all__ = ["model_meta", "forward", "init_cache", "decode_step"]


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def model_meta(cfg: ModelConfig) -> dict:
    D, V, nL = cfg.d_model, cfg.vocab_size, cfg.num_layers
    dt = _dt(cfg)
    tree: dict[str, Any] = {}
    if cfg.frontend != "audio_stub":
        tree["embed"] = ParamMeta((V, D), ("vocab", "embed"), dtype=dt, init="embed")
    block = {"attn": L.attention_meta(cfg, stacked=nL)}
    if cfg.family in ("moe",):
        block["moe"] = L.moe_meta(cfg, stacked=nL)
    else:
        block["ffn"] = L.ffn_meta(cfg, stacked=nL)
    tree["blocks"] = block
    tree["final_norm"] = ParamMeta((D,), ("embed",), dtype=dt, init="ones")
    if not cfg.tie_embeddings:
        tree["lm_head"] = ParamMeta((D, V), ("embed", "vocab"), dtype=dt, fan_in_axes=(0,))
    return tree


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


def _block_apply(cfg: ModelConfig, params_l: dict, x: jax.Array, positions: jax.Array):
    x = L.attention_block(params_l["attn"], x, cfg, positions)
    if "moe" in params_l:
        x, aux = L.moe_block(params_l["moe"], x, cfg)
    else:
        x = L.ffn_block(params_l["ffn"], x, cfg)
        aux = jnp.zeros((), jnp.float32)
    return x, aux


def _embed_inputs(params: dict, batch: dict, cfg: ModelConfig) -> jax.Array:
    """Token / stub-frontend embedding.  Returns (B, S_total, D)."""
    if cfg.frontend == "audio_stub":
        # EnCodec frame embeddings arrive precomputed (spec carve-out).
        return batch["embeds"].astype(_dt(cfg))
    x = params["embed"][batch["tokens"]]  # (B, S_text, D) gather
    if cfg.frontend == "vision_stub":
        patches = batch["patch_embeds"].astype(x.dtype)  # (B, P, D)
        x = jnp.concatenate([patches, x], axis=1)
    return x


def forward(params: dict, batch: dict, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits (B,S,V), moe_aux_loss)."""
    x = _embed_inputs(params, batch, cfg)
    B, S, D = x.shape
    x = L._shard(x, ("batch", "seq", "embed"))
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, axis=0)

    blk = _remat(functools.partial(_block_apply, cfg), cfg)

    if cfg.scan_layers:
        def body(carry, params_l):
            x, aux = carry
            x, a = blk(params_l, x, positions)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
        aux = aux / cfg.num_layers
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.num_layers):
            params_l = jax.tree_util.tree_map(lambda p: p[i], params["blocks"])
            x, a = blk(params_l, x, positions)
            aux = aux + a / cfg.num_layers

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = L._shard(logits, ("batch", "seq", "vocab"))
    return logits, aux


# ------------------------------------------------------------------ #
# decode
# ------------------------------------------------------------------ #
def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """Abstract cache spec (ShapeDtypeStructs); materialize with jnp.zeros."""
    W = cache_len_for(cfg, seq_len)
    nL, K, Dh = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    dt = _dt(cfg)
    return {
        "k": jax.ShapeDtypeStruct((nL, batch, W, K, Dh), dt),
        "v": jax.ShapeDtypeStruct((nL, batch, W, K, Dh), dt),
        "positions": jax.ShapeDtypeStruct((W,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_logical_axes(cfg: ModelConfig) -> dict:
    return {
        "k": ("layers", "batch", "cache_seq", "cache_kv_heads", "cache_head_dim"),
        "v": ("layers", "batch", "cache_seq", "cache_kv_heads", "cache_head_dim"),
        "positions": (None,),
        "pos": (),
    }


def decode_step(
    params: dict, cache: dict, batch: dict, cfg: ModelConfig
) -> tuple[jax.Array, dict]:
    """One-token decode.  batch: {"tokens": (B,1)} or {"embeds": (B,1,D)}.

    Returns (logits (B, V), new_cache).
    """
    if cfg.frontend == "audio_stub":
        x = batch["embeds"].astype(_dt(cfg))
    else:
        x = params["embed"][batch["tokens"]]
    B = x.shape[0]
    x = L._shard(x, ("batch", None, "embed"))
    pos = cache["pos"]

    def body(carry, xs):
        x, positions = carry
        params_l, ck, cv = xs
        x, (ck, cv), positions = L.decode_attention_block(
            params_l["attn"], x, cfg, (ck, cv), positions, pos
        )
        if "moe" in params_l:
            x, _ = L.moe_block(params_l["moe"], x, cfg)
        else:
            x = L.ffn_block(params_l["ffn"], x, cfg)
        return (x, positions), (ck, cv)

    # NOTE: cache positions are identical across layers; carry one copy.
    (x, new_positions), (ks, vs) = jax.lax.scan(
        body, (x, cache["positions"]), (params["blocks"], cache["k"], cache["v"])
    )
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0]
    new_cache = {"k": ks, "v": vs, "positions": new_positions, "pos": pos + 1}
    return logits, new_cache
