"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention+MLP block
applied every `cfg.attn_every` layers (arXiv:2411.15242).

The shared block has a single parameter set reused at each application
(Zamba2's weight-shared global block); the backbone layers scan as usual.
Decode carries per-layer SSM/conv states plus one KV cache per shared-block
application site.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.module import ParamMeta

__all__ = ["model_meta", "forward", "init_cache", "decode_step", "num_shared_sites"]


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def num_shared_sites(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.attn_every


def model_meta(cfg: ModelConfig) -> dict:
    D, V, nL = cfg.d_model, cfg.vocab_size, cfg.num_layers
    dt = _dt(cfg)
    tree: dict[str, Any] = {
        "embed": ParamMeta((V, D), ("vocab", "embed"), dtype=dt, init="embed"),
        "blocks": M.mamba_block_meta(cfg, stacked=nL),
        "shared": {
            "attn": L.attention_meta(cfg),
            "ffn": L.ffn_meta(cfg),
        },
        "final_norm": ParamMeta((D,), ("embed",), dtype=dt, init="ones"),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = ParamMeta((D, V), ("embed", "vocab"), dtype=dt, fan_in_axes=(0,))
    return tree


def _seg_slice(tree, lo: int, hi: int):
    return jax.tree_util.tree_map(lambda p: p[lo:hi], tree)


def forward(params: dict, batch: dict, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    x = params["embed"][batch["tokens"]]
    B, S, D = x.shape
    x = L._shard(x, ("batch", "seq", "embed"))
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, axis=0)
    ae = cfg.attn_every
    n_seg = num_shared_sites(cfg)

    def mamba_body(x, params_l):
        x, _ = M.mamba_block(params_l, x, cfg)
        return x, None

    mamba_body = (
        jax.checkpoint(mamba_body) if cfg.remat != "none" else mamba_body
    )

    for seg in range(n_seg):
        # shared attention + MLP block at the segment head (weight-shared)
        x = L.attention_block(params["shared"]["attn"], x, cfg, positions)
        x = L.ffn_block(params["shared"]["ffn"], x, cfg)
        x, _ = jax.lax.scan(mamba_body, x, _seg_slice(params["blocks"], seg * ae, (seg + 1) * ae))
    # trailing backbone layers if L % attn_every != 0
    if cfg.num_layers % ae:
        x, _ = jax.lax.scan(mamba_body, x, _seg_slice(params["blocks"], n_seg * ae, cfg.num_layers))

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    from repro.models.transformer import cache_len_for

    d_inner = cfg.d_inner
    H, N = cfg.ssm_heads, cfg.ssm_state
    conv_ch = d_inner + 2 * cfg.ssm_groups * N
    nL, nseg = cfg.num_layers, num_shared_sites(cfg)
    W = cache_len_for(cfg, seq_len)
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    dt = _dt(cfg)
    return {
        "ssm": jax.ShapeDtypeStruct((nL, batch, H, N, cfg.ssm_head_dim), jnp.float32),
        "conv": jax.ShapeDtypeStruct((nL, batch, cfg.ssm_conv - 1, conv_ch), dt),
        "k": jax.ShapeDtypeStruct((nseg, batch, W, K, Dh), dt),
        "v": jax.ShapeDtypeStruct((nseg, batch, W, K, Dh), dt),
        "positions": jax.ShapeDtypeStruct((W,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_logical_axes(cfg: ModelConfig) -> dict:
    return {
        "ssm": ("layers", "batch", "heads", "state", None),
        "conv": ("layers", "batch", None, "mlp"),
        "k": ("layers", "batch", "cache_seq", "cache_kv_heads", "cache_head_dim"),
        "v": ("layers", "batch", "cache_seq", "cache_kv_heads", "cache_head_dim"),
        "positions": (None,),
        "pos": (),
    }


def decode_step(params: dict, cache: dict, batch: dict, cfg: ModelConfig):
    x = params["embed"][batch["tokens"]]
    pos = cache["pos"]
    ae = cfg.attn_every
    n_seg = num_shared_sites(cfg)
    new_ssm, new_conv, new_k, new_v = [], [], [], []
    positions = cache["positions"]

    def mamba_seg(x, lo, hi):
        def body(x, xs):
            params_l, ssm, conv = xs
            x, ssm, conv = M.mamba_decode_block(params_l, x, cfg, ssm, conv)
            return x, (ssm, conv)

        xs = (
            _seg_slice(params["blocks"], lo, hi),
            cache["ssm"][lo:hi],
            cache["conv"][lo:hi],
        )
        return jax.lax.scan(body, x, xs)

    for seg in range(n_seg):
        x, (kc, vc), positions = L.decode_attention_block(
            params["shared"]["attn"], x, cfg,
            (cache["k"][seg], cache["v"][seg]), cache["positions"], pos,
        )
        x = L.ffn_block(params["shared"]["ffn"], x, cfg)
        new_k.append(kc)
        new_v.append(vc)
        x, (ssm, conv) = mamba_seg(x, seg * ae, (seg + 1) * ae)
        new_ssm.append(ssm)
        new_conv.append(conv)
    if cfg.num_layers % ae:
        x, (ssm, conv) = mamba_seg(x, n_seg * ae, cfg.num_layers)
        new_ssm.append(ssm)
        new_conv.append(conv)

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0]
    new_cache = {
        "ssm": jnp.concatenate(new_ssm, axis=0),
        "conv": jnp.concatenate(new_conv, axis=0),
        "k": jnp.stack(new_k, axis=0),
        "v": jnp.stack(new_v, axis=0),
        "positions": positions,
        "pos": pos + 1,
    }
    return logits, new_cache
