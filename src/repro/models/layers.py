"""Transformer building blocks: norms, RoPE, GQA attention, dense & MoE FFN.

Pure functions over explicit parameter dicts.  Every block has two data paths:
  * the jnp reference path (always available; used by the CPU dry-run), and
  * the Pallas TPU kernel path (cfg.use_pallas) from repro.kernels.

Activation sharding hints are applied through `repro.launch.shardings.shard`,
a no-op outside a mesh context.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.module import ParamMeta

__all__ = [
    "rms_norm",
    "layer_norm",
    "make_rope",
    "apply_rope",
    "attention_meta",
    "attention_block",
    "decode_attention_block",
    "ffn_meta",
    "ffn_block",
    "moe_meta",
    "moe_block",
]


def _shard(x, axes):
    from repro.launch.shardings import shard_activation

    return shard_activation(x, axes)


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _grad_cast(x, dtype_name: str):
    """Identity that casts the cotangent to the primal dtype (bf16 barrier).

    Keeps SPMD-inserted tensor-parallel backward all-reduces in bf16 instead
    of letting XLA hoist f32 converts above them (2x collective traffic)."""
    return x


def _grad_cast_fwd(x, dtype_name):
    return x, None


def _grad_cast_bwd(dtype_name, _res, g):
    return (g.astype(dtype_name),)


_grad_cast.defvjp(_grad_cast_fwd, _grad_cast_bwd)


def _maybe_grad_cast(x, cfg):
    return _grad_cast(x, x.dtype.name) if cfg.force_bf16_grads else x


# --------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------- #
def rms_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layer_norm(scale: jax.Array, bias: jax.Array, x: jax.Array, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    m = jnp.mean(x, axis=-1, keepdims=True)
    v = jnp.mean((x - m) ** 2, axis=-1, keepdims=True)
    y = (x - m) * jax.lax.rsqrt(v + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------- #
def make_rope(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions: (..., S) int -> (cos, sin) of shape (..., S, head_dim//2)."""
    freqs = 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D).  cos/sin: (S, D/2) or (B, S, D/2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


# --------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------- #
def attention_meta(cfg: ModelConfig, stacked: int | None = None) -> dict:
    """ParamMeta tree for one attention block (optionally layer-stacked)."""
    H, K, Dh, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def st(shape, axes):
        if stacked is not None:
            return (stacked, *shape), ("layers", *axes)
        return shape, axes

    def P(shape, axes, **kw):
        s, a = st(shape, axes)
        return ParamMeta(s, a, dtype=dt, **kw)

    tree = {
        "wq": P((D, H * Dh), ("embed", "heads_x_dim"), fan_in_axes=(-2,)),
        "wk": P((D, K * Dh), ("embed", "kv_x_dim"), fan_in_axes=(-2,)),
        "wv": P((D, K * Dh), ("embed", "kv_x_dim"), fan_in_axes=(-2,)),
        "wo": P((H * Dh, D), ("heads_x_dim", "embed"), fan_in_axes=(-2,)),
        "pre_norm": P((D,), ("embed",), init="ones"),
    }
    if cfg.qkv_bias:
        tree["bq"] = P((H * Dh,), ("heads_x_dim",), init="zeros")
        tree["bk"] = P((K * Dh,), ("kv_x_dim",), init="zeros")
        tree["bv"] = P((K * Dh,), ("kv_x_dim",), init="zeros")
    return tree


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """Reference grouped-query attention.

    q: (B, S, H, Dh); k, v: (B, T, K, Dh); mask: (B or 1, 1, S, T) bool.
    """
    B, S, H, Dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    q = q.reshape(B, S, K, G, Dh)
    scale = 1.0 / np.sqrt(Dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[:, :, None], scores, -1e30)  # mask (B,1,S,T)->(B,1,1,S,T)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, Dh)


def _flash_or_ref(q, k, v, mask, cfg: ModelConfig, causal_offset: int):
    if cfg.use_pallas:
        from repro.kernels import ops as kops

        return kops.flash_attention(
            q, k, v, causal=True, window=cfg.sliding_window, q_offset=causal_offset
        )
    return _sdpa(q, k, v, mask, cfg)


def attention_block(
    params: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array
) -> jax.Array:
    """Full-sequence (train / prefill) attention with residual."""
    B, S, D = x.shape
    H, K, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    h = _maybe_grad_cast(rms_norm(params["pre_norm"], x, cfg.norm_eps), cfg)
    q = jnp.einsum("bsd,dh->bsh", h, params["wq"])
    k = jnp.einsum("bsd,dh->bsh", h, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", h, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, K, Dh)
    v = v.reshape(B, S, K, Dh)
    q = _shard(q, ("batch", "seq", "heads", None))
    k = _shard(k, ("batch", "seq", "kv_heads", None))
    cos, sin = make_rope(positions, Dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    # causal (+ sliding window) mask
    pq = positions if positions.ndim == 2 else positions[None, :]
    rel = pq[:, :, None] - pq[:, None, :]          # (B?, S, S) q_pos - k_pos
    mask = rel >= 0
    if cfg.sliding_window:
        mask = mask & (rel < cfg.sliding_window)
    mask = mask[:, None]                           # (B?, 1, S, S)
    out = _flash_or_ref(q, k, v, mask, cfg, 0)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * Dh), params["wo"])
    out = _shard(out, ("batch", "seq", "embed"))
    return x + out


def decode_attention_block(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    kv_cache: tuple[jax.Array, jax.Array],
    cache_positions: jax.Array,
    pos: jax.Array,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Single-token decode against a ring-buffer KV cache.

    x: (B, 1, D).  kv_cache: (k, v) each (B, W, K, Dh) holding RoPE'd keys.
    cache_positions: (W,) int32 — the absolute position stored in each slot
    (-1 = empty).  pos: scalar int32, the position of the current token.
    The new token is written at slot pos % W (ring eviction).
    """
    B, _, D = x.shape
    H, K, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    W = kv_cache[0].shape[1]
    h = rms_norm(params["pre_norm"], x, cfg.norm_eps)
    q = jnp.einsum("bsd,dh->bsh", h, params["wq"])
    k = jnp.einsum("bsd,dh->bsh", h, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", h, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, 1, H, Dh)
    k = k.reshape(B, 1, K, Dh)
    v = v.reshape(B, 1, K, Dh)
    posv = jnp.reshape(pos, (1,))
    cos, sin = make_rope(posv, Dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    slot = jnp.mod(pos, W)
    ck = jax.lax.dynamic_update_slice(kv_cache[0], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(kv_cache[1], v, (0, slot, 0, 0))
    new_positions = cache_positions.at[slot].set(pos)
    # attend over the whole ring buffer; mask invalid/out-of-window slots
    valid = (new_positions >= 0) & (new_positions <= pos)
    if cfg.sliding_window:
        valid = valid & (new_positions > pos - cfg.sliding_window)
    mask = valid[None, None, None, :]              # (1,1,1,W)
    G = H // K
    qg = q.reshape(B, 1, K, G, Dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, ck).astype(jnp.float32) / np.sqrt(Dh)
    scores = jnp.where(mask[:, :, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, cv).reshape(B, 1, H * Dh)
    out = jnp.einsum("bsh,hd->bsd", out, params["wo"])
    return x + out, (ck, cv), new_positions


# --------------------------------------------------------------------- #
# dense FFN
# --------------------------------------------------------------------- #
def ffn_meta(cfg: ModelConfig, d_ff: int | None = None, stacked: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def P(shape, axes, **kw):
        if stacked is not None:
            shape, axes = (stacked, *shape), ("layers", *axes)
        return ParamMeta(shape, axes, dtype=dt, **kw)

    tree = {
        "w_up": P((D, F), ("embed", "mlp"), fan_in_axes=(-2,)),
        "w_down": P((F, D), ("mlp", "embed"), fan_in_axes=(-2,)),
        "pre_norm": P((D,), ("embed",), init="ones"),
    }
    if cfg.ffn_gated:
        tree["w_gate"] = P((D, F), ("embed", "mlp"), fan_in_axes=(-2,))
    return tree


def ffn_block(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = _maybe_grad_cast(rms_norm(params["pre_norm"], x, cfg.norm_eps), cfg)
    u = jnp.einsum("bsd,df->bsf", h, params["w_up"])
    if cfg.ffn_gated:
        g = jnp.einsum("bsd,df->bsf", h, params["w_gate"])
        a = jax.nn.silu(g) * u
    else:
        a = jax.nn.gelu(u)
    a = _shard(a, ("batch", "seq", "mlp"))
    out = jnp.einsum("bsf,fd->bsd", a, params["w_down"])
    out = _shard(out, ("batch", "seq", "embed"))
    return x + out


# --------------------------------------------------------------------- #
# MoE FFN (top-k routed experts + optional shared experts / dense residual)
# --------------------------------------------------------------------- #
def moe_meta(cfg: ModelConfig, stacked: int | None = None) -> dict:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.d_ff_expert
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def P(shape, axes, **kw):
        if stacked is not None:
            shape, axes = (stacked, *shape), ("layers", *axes)
        return ParamMeta(shape, axes, dtype=dt, **kw)

    tree = {
        "router": P((D, E), ("embed", "experts"), init="small"),
        "we_gate": P((E, D, F), ("experts", "embed", "mlp_expert"), fan_in_axes=(-2,)),
        "we_up": P((E, D, F), ("experts", "embed", "mlp_expert"), fan_in_axes=(-2,)),
        "we_down": P((E, F, D), ("experts", "mlp_expert", "embed"), fan_in_axes=(-2,)),
        "pre_norm": P((D,), ("embed",), init="ones"),
    }
    if cfg.num_shared_experts:
        Fs = cfg.num_shared_experts * F
        tree["ws_gate"] = P((D, Fs), ("embed", "mlp"), fan_in_axes=(-2,))
        tree["ws_up"] = P((D, Fs), ("embed", "mlp"), fan_in_axes=(-2,))
        tree["ws_down"] = P((Fs, D), ("mlp", "embed"), fan_in_axes=(-2,))
    if cfg.moe_dense_residual:
        Fd = cfg.d_ff
        tree["wd_gate"] = P((D, Fd), ("embed", "mlp"), fan_in_axes=(-2,))
        tree["wd_up"] = P((D, Fd), ("embed", "mlp"), fan_in_axes=(-2,))
        tree["wd_down"] = P((Fd, D), ("mlp", "embed"), fan_in_axes=(-2,))
    return tree


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    cap = int(np.ceil(cfg.num_experts_per_tok * n_tokens / cfg.num_experts * cfg.capacity_factor))
    return max(4, int(np.ceil(cap / 4) * 4))


def _route_group(params, xg: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Dispatch/FFN/combine for one token group.  xg: (N, D) -> (N, D), aux."""
    N, D = xg.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = _capacity(N, cfg)
    logits = jnp.einsum("nd,de->ne", xg.astype(jnp.float32), params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                     # (N, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    sel = jax.nn.one_hot(idx, E, dtype=jnp.float32)              # (N, k, E)
    # priority: expert slot 0 of every token first, then slot 1, ... (GShard)
    sel_f = sel.transpose(1, 0, 2).reshape(N * k, E)             # (k*N, E)
    pos_in_e = jnp.cumsum(sel_f, axis=0) * sel_f - 1.0           # (k*N, E)
    keep = (pos_in_e >= 0) & (pos_in_e < cap)
    disp = (sel_f * keep)[..., None] * jax.nn.one_hot(
        pos_in_e.astype(jnp.int32), cap, dtype=jnp.float32
    )
    disp = disp.reshape(k, N, E, cap).transpose(1, 0, 2, 3)      # (N, k, E, cap)
    disp_tok = disp.sum(axis=1)                                  # (N, E, cap) 0/1
    xin = jnp.einsum("nec,nd->ecd", disp_tok.astype(xg.dtype), xg)   # (E, cap, D)
    h = jnp.einsum("ecd,edf->ecf", xin, params["we_gate"])
    u = jnp.einsum("ecd,edf->ecf", xin, params["we_up"])
    a = jax.nn.silu(h) * u
    a = _shard(a, ("experts", None, "mlp_expert"))
    y = jnp.einsum("ecf,efd->ecd", a, params["we_down"])         # (E, cap, D)
    comb = jnp.einsum("nkec,nk->nec", disp, gate_vals).astype(y.dtype)
    out = jnp.einsum("nec,ecd->nd", comb, y)                     # (N, D)
    # aux load-balance loss (Switch): E * sum_e f_e * P_e
    frac_tokens = jnp.mean(sel.sum(axis=1), axis=0)              # (E,)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return out, aux


def _route_group_sorted(params, xg: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Sort-based dispatch (beyond-paper §Perf optimization).

    Replaces the O(N·E·cap·D) one-hot dispatch einsums with an argsort +
    scatter/gather: FLOPs drop to the expert FFN itself; cross-device
    movement lowers to all-to-all instead of data-axis all-reduce.
    Token->slot assignment (k-major priority, capacity drop) is identical
    to `_route_group`, so outputs match exactly.
    """
    N, D = xg.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = _capacity(N, cfg)
    logits = jnp.einsum("nd,de->ne", xg.astype(jnp.float32), params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                     # (N, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    # k-major flattening (same priority order as the einsum path)
    idx_f = idx.T.reshape(N * k)                                 # (k*N,)
    gates_f = gate_vals.T.reshape(N * k)
    tok_f = jnp.tile(jnp.arange(N, dtype=jnp.int32), k)
    # position within expert via stable sort over expert ids
    order = jnp.argsort(idx_f, stable=True)                    # (k*N,)
    sorted_e = idx_f[order]
    pos_sorted = jnp.arange(N * k) - jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.zeros(N * k, jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < cap
    slot = jnp.where(keep, idx_f * cap + pos, E * cap)           # overflow -> dropped row
    # scatter tokens into the dispatch buffer (E*cap+1, D); last row = trash
    buf = jnp.zeros((E * cap + 1, D), xg.dtype).at[slot].add(xg[tok_f] * keep[:, None])
    xin = buf[: E * cap].reshape(E, cap, D)
    if cfg.use_pallas:
        from repro.kernels import ops as kops

        h = kops.moe_gmm(xin, params["we_gate"])
        u = kops.moe_gmm(xin, params["we_up"])
        a = jax.nn.silu(h) * u
        y = kops.moe_gmm(a, params["we_down"])
    else:
        h = jnp.einsum("ecd,edf->ecf", xin, params["we_gate"])
        u = jnp.einsum("ecd,edf->ecf", xin, params["we_up"])
        a = jax.nn.silu(h) * u
        a = _shard(a, ("experts", None, "mlp_expert"))
        y = jnp.einsum("ecf,efd->ecd", a, params["we_down"])     # (E, cap, D)
    y_flat = jnp.concatenate([y.reshape(E * cap, D), jnp.zeros((1, D), y.dtype)], axis=0)
    per_slot = y_flat[slot] * (gates_f * keep).astype(y.dtype)[:, None]   # (k*N, D)
    out = jnp.zeros((N, D), y.dtype).at[tok_f].add(per_slot)
    sel = jax.nn.one_hot(idx, E, dtype=jnp.float32)
    frac_tokens = jnp.mean(sel.sum(axis=1), axis=0)
    aux = E * jnp.sum(frac_tokens * jnp.mean(probs, axis=0))
    return out, aux


def moe_block(params: dict, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).  Tokens dispatched in groups along S."""
    B, S, D = x.shape
    h = _maybe_grad_cast(rms_norm(params["pre_norm"], x, cfg.norm_eps), cfg)
    Sg = min(cfg.moe_group_size, S)
    n_groups = max(S // Sg, 1)
    if S % Sg:
        raise ValueError(f"seq {S} not divisible by moe group {Sg}")
    hg = h.reshape(B, n_groups, Sg, D).transpose(1, 0, 2, 3).reshape(n_groups, B * Sg, D)

    route = _route_group_sorted if cfg.moe_dispatch == "sort" else _route_group

    if n_groups == 1:
        out_flat, aux = route(params, hg[0], cfg)
        out = out_flat.reshape(1, B, Sg, D)
        aux_total = aux
    else:
        def body(carry, xg):
            y, aux = route(params, xg, cfg)
            return carry + aux, y

        aux_total, out = jax.lax.scan(body, jnp.zeros((), jnp.float32), hg)
        aux_total = aux_total / n_groups
        out = out.reshape(n_groups, B, Sg, D)
    out = out.transpose(1, 0, 2, 3).reshape(B, S, D)

    if cfg.num_shared_experts:
        g = jnp.einsum("bsd,df->bsf", h, params["ws_gate"])
        u = jnp.einsum("bsd,df->bsf", h, params["ws_up"])
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, params["ws_down"])
    if cfg.moe_dense_residual:
        g = jnp.einsum("bsd,df->bsf", h, params["wd_gate"])
        u = jnp.einsum("bsd,df->bsf", h, params["wd_up"])
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, params["wd_down"])
    out = _shard(out, ("batch", "seq", "embed"))
    return x + out, aux_total
