"""Minimal pure-JAX module system: parameter metadata as the single source of truth.

A model definition is a nested dict of `ParamMeta` leaves.  From that one
tree we derive:
  * `init_params`      — materialized arrays (deterministic per-leaf RNG),
  * `abstract_params`  — jax.ShapeDtypeStruct tree (dry-run, no allocation),
  * `logical_specs`    — PartitionSpec-of-logical-axis-names tree, later
                         translated to mesh axes by `repro.launch.shardings`.

This is the same "logical axis annotations at init" design MaxText/Flaxformer
use, without pulling in a framework dependency.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ParamMeta", "init_params", "abstract_params", "logical_specs", "param_count"]


@dataclasses.dataclass(frozen=True)
class ParamMeta:
    """Declares one parameter: shape, dtype, logical axes, initializer.

    axes entries are logical names ('embed', 'mlp', 'heads', 'kv_heads',
    'head_dim', 'vocab', 'experts', 'layers', 'state', None...) — one per dim.
    init: 'normal' (fan-in scaled), 'zeros', 'ones', 'embed' (unit normal
    scaled by 1/sqrt(d)), 'small' (0.006 std, router-style).
    """

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"
    fan_in_axes: tuple[int, ...] | None = None  # dims reduced by the matmul

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def _is_meta(x) -> bool:
    return isinstance(x, ParamMeta)


def _leaf_rng(rng: jax.Array, path: str) -> jax.Array:
    """Deterministic per-leaf key derived from the tree path.

    Uses crc32 (not Python's salted hash()) so initialization is identical
    across processes — checkpoint/restart reproducibility depends on this."""
    import zlib

    h = zlib.crc32(path.encode()) & 0x7FFFFFFF
    return jax.random.fold_in(rng, int(h))


def _path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", p))) for p in path
    )


def init_params(meta_tree: Any, rng: jax.Array, dtype_override: Any = None) -> Any:
    """Materialize parameters.  Deterministic given rng."""

    def make(path, meta: ParamMeta):
        dt = dtype_override or meta.dtype
        key = _leaf_rng(rng, _path_str(path))
        if meta.init == "zeros":
            return jnp.zeros(meta.shape, dt)
        if meta.init == "ones":
            return jnp.ones(meta.shape, dt)
        if meta.init == "small":
            return (0.006 * jax.random.normal(key, meta.shape, jnp.float32)).astype(dt)
        if meta.init == "embed":
            d = meta.shape[-1]
            return (jax.random.normal(key, meta.shape, jnp.float32) / np.sqrt(d)).astype(dt)
        if meta.init == "normal":
            fan_axes = meta.fan_in_axes if meta.fan_in_axes is not None else (0,)
            fan_in = int(np.prod([meta.shape[a] for a in fan_axes]))
            std = 1.0 / np.sqrt(max(fan_in, 1))
            return (std * jax.random.normal(key, meta.shape, jnp.float32)).astype(dt)
        if meta.init == "ssm_a":  # mamba2 A_log init: log(uniform[1,16])
            u = jax.random.uniform(key, meta.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(jnp.float32)  # A kept fp32 for stability
        if meta.init == "ssm_dt":  # dt_bias: softplus^-1 of uniform[1e-3, 1e-1]
            u = jax.random.uniform(key, meta.shape, jnp.float32, 1e-3, 1e-1)
            return jnp.log(jnp.expm1(u)).astype(jnp.float32)
        raise ValueError(f"unknown init {meta.init}")

    return jax.tree_util.tree_map_with_path(make, meta_tree, is_leaf=_is_meta)


def abstract_params(meta_tree: Any, dtype_override: Any = None) -> Any:
    """ShapeDtypeStruct tree — used by the dry-run (no device allocation)."""

    def make(meta: ParamMeta):
        dt = meta.dtype if dtype_override is None else dtype_override
        if meta.init in ("ssm_a", "ssm_dt"):
            dt = jnp.float32  # stability-critical params stay fp32
        return jax.ShapeDtypeStruct(meta.shape, dt)

    return jax.tree_util.tree_map(make, meta_tree, is_leaf=_is_meta)


def logical_specs(meta_tree: Any) -> Any:
    """Tree of logical-axis tuples, mirroring the parameter tree."""
    return jax.tree_util.tree_map(lambda m: m.axes, meta_tree, is_leaf=_is_meta)


def param_count(meta_tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(meta_tree, is_leaf=_is_meta)
    return int(sum(np.prod(m.shape) for m in leaves))
