"""Mamba2 (state-space duality / SSD) blocks — chunked parallel form + decode.

Follows "Transformers are SSDs" (arXiv:2405.21060):
  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t,      y_t = C_t h_t + D x_t
with per-head scalar A, shared B/C across heads (ssm_groups=1).  Training and
prefill use the chunked dual form (O(S Q) with chunk Q); decode is the O(1)
recurrence.  fp32 for all decay/exp math.

The chunked scan is also implemented as a Pallas kernel
(repro.kernels.ssd_scan) selected by cfg.use_pallas.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.module import ParamMeta

__all__ = [
    "mamba_block_meta",
    "model_meta",
    "ssd_chunked",
    "ssd_recurrent_step",
    "mamba_block",
    "mamba_decode_block",
    "forward",
    "init_cache",
    "decode_step",
]


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _dims(cfg: ModelConfig):
    d_inner = cfg.d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    G = cfg.ssm_groups
    conv_ch = d_inner + 2 * G * N
    return d_inner, H, N, G, conv_ch


def mamba_block_meta(cfg: ModelConfig, stacked: int | None = None) -> dict:
    D = cfg.d_model
    d_inner, H, N, G, conv_ch = _dims(cfg)
    d_in_proj = 2 * d_inner + 2 * G * N + H
    dt = _dt(cfg)

    def P(shape, axes, **kw):
        if stacked is not None:
            shape, axes = (stacked, *shape), ("layers", *axes)
        return ParamMeta(shape, axes, dtype=dt, **kw)

    return {
        "in_proj": P((D, d_in_proj), ("embed", "mlp"), fan_in_axes=(-2,)),
        "conv_w": P((cfg.ssm_conv, conv_ch), ("conv", "mlp"), init="normal", fan_in_axes=(0,)),
        "conv_b": P((conv_ch,), ("mlp",), init="zeros"),
        "A_log": P((H,), ("state",), init="ssm_a"),
        "D": P((H,), ("state",), init="ones"),
        "dt_bias": P((H,), ("state",), init="ssm_dt"),
        "norm": P((d_inner,), ("mlp",), init="ones"),
        "out_proj": P((d_inner, D), ("mlp", "embed"), fan_in_axes=(-2,)),
        "pre_norm": P((D,), ("embed",), init="ones"),
    }


def model_meta(cfg: ModelConfig) -> dict:
    D, V, nL = cfg.d_model, cfg.vocab_size, cfg.num_layers
    dt = _dt(cfg)
    tree: dict[str, Any] = {
        "embed": ParamMeta((V, D), ("vocab", "embed"), dtype=dt, init="embed"),
        "blocks": mamba_block_meta(cfg, stacked=nL),
        "final_norm": ParamMeta((D,), ("embed",), dtype=dt, init="ones"),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = ParamMeta((D, V), ("embed", "vocab"), dtype=dt, fan_in_axes=(0,))
    return tree


# ------------------------------------------------------------------ #
# SSD math
# ------------------------------------------------------------------ #
def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., Q) -> (..., Q, Q) with out[i,j] = sum_{j < l <= i} a_l (i>=j), -inf else."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = np.tril(np.ones((Q, Q), dtype=bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)  fp32, post-softplus
    A: jax.Array,   # (H,)       fp32, negative
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    chunk: int,
    init_state: jax.Array | None = None,  # (B, H, N, P)
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD.  Returns (y (B,S,H,P), final_state (B,H,N,P))."""
    Bsz, S, H, Pd = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    if S % Q:
        raise ValueError(f"S={S} not divisible by chunk={Q}")
    nc = S // Q
    xc = x.reshape(Bsz, nc, Q, H, Pd)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    a = dtc * A[None, None, None, :]                     # (B,nc,Q,H)
    cs = jnp.cumsum(a, axis=2)                           # within-chunk cumsum

    # --- intra-chunk (diagonal) term --------------------------------- #
    Lmat = jnp.exp(_segsum(a.transpose(0, 1, 3, 2)))     # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)       # (B,nc,Q,Q)
    xw = xc.astype(jnp.float32) * dtc[..., None]         # dt_j * x_j
    y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp", scores, Lmat, xw)

    # --- chunk states -------------------------------------------------- #
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)        # (B,nc,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, dtc * decay_to_end, xc.astype(jnp.float32))

    # --- inter-chunk recurrence (scan over chunks) -------------------- #
    chunk_decay = jnp.exp(cs[:, :, -1, :])               # (B,nc,H)
    h0 = (
        jnp.zeros((Bsz, H, N, Pd), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def body(h, xs):
        st, dec = xs                                     # (B,H,N,P), (B,H)
        h_prev = h
        h = h * dec[:, :, None, None] + st
        return h, h_prev

    hT, h_prevs = jax.lax.scan(
        body,
        h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)           # (B,nc,H,N,P)

    # --- inter-chunk output term -------------------------------------- #
    decay_from_start = jnp.exp(cs)                       # (B,nc,Q,H)
    y_off = jnp.einsum("bcin,bchnp,bcih->bcihp", Cc, h_prevs, decay_from_start)

    y = (y_diag + y_off).reshape(Bsz, S, H, Pd).astype(x.dtype)
    return y, hT


def ssd_recurrent_step(
    h: jax.Array,   # (B, H, N, P) fp32 state
    x: jax.Array,   # (B, H, P)
    dt: jax.Array,  # (B, H) fp32
    A: jax.Array,   # (H,)
    Bm: jax.Array,  # (B, N)
    Cm: jax.Array,  # (B, N)
) -> tuple[jax.Array, jax.Array]:
    """One decode step.  Returns (y (B,H,P), new_state)."""
    dA = jnp.exp(dt * A[None, :])                        # (B,H)
    dBx = jnp.einsum("bn,bh,bhp->bhnp", Bm.astype(jnp.float32), dt, x.astype(jnp.float32))
    h = h * dA[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), h)
    return y.astype(x.dtype), h


# ------------------------------------------------------------------ #
# blocks
# ------------------------------------------------------------------ #
def _split_proj(proj: jax.Array, cfg: ModelConfig):
    d_inner, H, N, G, conv_ch = _dims(cfg)
    z, xBC, dt = jnp.split(proj, [d_inner, d_inner + conv_ch], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv.  xBC: (B,S,Ch), w: (W,Ch)."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for i in range(W):  # W is 4: unrolled taps beat conv_general on TPU for depthwise
        out = out + pad[:, i : i + xBC.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(xBC.dtype)


def mamba_block(params: dict, x: jax.Array, cfg: ModelConfig,
                init_state: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Full-sequence Mamba2 block with residual.  Returns (y, final_ssm_state)."""
    B, S, D = x.shape
    d_inner, H, N, G, conv_ch = _dims(cfg)
    Pd = cfg.ssm_head_dim
    h = L._maybe_grad_cast(L.rms_norm(params["pre_norm"], x, cfg.norm_eps), cfg)
    proj = jnp.einsum("bsd,de->bse", h, params["in_proj"])
    z, xBC, dt_raw = _split_proj(proj, cfg)
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"], params["conv_b"]))
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + G * N], axis=-1)
    xs = xs.reshape(B, S, H, Pd)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    if cfg.use_pallas:
        from repro.kernels import ops as kops

        y, hT = kops.ssd_scan(xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk, init_state=init_state)
    else:
        y, hT = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk, init_state)
    y = y + xs * params["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_inner) * jax.nn.silu(z)
    y = L.rms_norm(params["norm"], y, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    out = L._shard(out, ("batch", "seq", "embed"))
    return x + out, hT


def mamba_decode_block(
    params: dict,
    x: jax.Array,                       # (B, 1, D)
    cfg: ModelConfig,
    ssm_state: jax.Array,               # (B, H, N, P) fp32
    conv_state: jax.Array,              # (B, W-1, conv_ch)
) -> tuple[jax.Array, jax.Array, jax.Array]:
    B, _, D = x.shape
    d_inner, H, N, G, conv_ch = _dims(cfg)
    Pd = cfg.ssm_head_dim
    h = L.rms_norm(params["pre_norm"], x, cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", h, params["in_proj"])
    z, xBC, dt_raw = _split_proj(proj, cfg)
    xBC = xBC[:, 0]                                       # (B, conv_ch)
    # conv ring: taps = [conv_state, new]
    full = jnp.concatenate([conv_state, xBC[:, None, :]], axis=1)  # (B, W, ch)
    w = params["conv_w"]
    conv_out = jnp.einsum("bwc,wc->bc", full.astype(jnp.float32), w.astype(jnp.float32))
    conv_out = conv_out + params["conv_b"].astype(jnp.float32)
    xBC_c = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv_state = full[:, 1:, :]
    xs, Bm, Cm = jnp.split(xBC_c, [d_inner, d_inner + G * N], axis=-1)
    xs = xs.reshape(B, H, Pd)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, new_state = ssd_recurrent_step(ssm_state, xs, dt, A, Bm, Cm)
    y = y + xs * params["D"].astype(y.dtype)[None, :, None]
    y = y.reshape(B, 1, d_inner) * jax.nn.silu(z)
    y = L.rms_norm(params["norm"], y, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return x + out, new_state, new_conv_state


# ------------------------------------------------------------------ #
# whole-model entry points
# ------------------------------------------------------------------ #
def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


def forward(params: dict, batch: dict, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    x = params["embed"][batch["tokens"]]
    x = L._shard(x, ("batch", "seq", "embed"))

    blk = _remat(functools.partial(_call_block, cfg), cfg)

    def body(x, params_l):
        x, _ = blk(params_l, x)
        return x, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, jnp.zeros((), jnp.float32)


def _call_block(cfg, params_l, x):
    return mamba_block(params_l, x, cfg)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    d_inner, H, N, G, conv_ch = _dims(cfg)
    nL = cfg.num_layers
    return {
        "ssm": jax.ShapeDtypeStruct((nL, batch, H, N, cfg.ssm_head_dim), jnp.float32),
        "conv": jax.ShapeDtypeStruct((nL, batch, cfg.ssm_conv - 1, conv_ch), _dt(cfg)),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_logical_axes(cfg: ModelConfig) -> dict:
    return {
        "ssm": ("layers", "batch", "heads", "state", None),
        "conv": ("layers", "batch", None, "mlp"),
        "pos": (),
    }


def decode_step(params: dict, cache: dict, batch: dict, cfg: ModelConfig):
    x = params["embed"][batch["tokens"]]
    B = x.shape[0]

    def body(x, xs):
        params_l, ssm, conv = xs
        x, ssm, conv = mamba_decode_block(params_l, x, cfg, ssm, conv)
        return x, (ssm, conv)

    x, (ssm, conv) = jax.lax.scan(body, x, (params["blocks"], cache["ssm"], cache["conv"]))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0]
    return logits, {"ssm": ssm, "conv": conv, "pos": cache["pos"] + 1}
