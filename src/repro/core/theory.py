"""Non-convex convergence bounds (paper §3, Table 1).

Implements the Theorem 1 bound for Generalized AsyncSGD and the comparison
bounds of FedBuff (Nguyen et al. 2022) and uniform AsyncSGD (Koloskova et
al. 2022), plus the step-size rules eta_max(p).

All bounds are evaluated from *expected* delays m_i (from
`repro.core.jackson.JacksonNetwork.expected_delays` or from simulation),
never from tau_max — that is the paper's point.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = [
    "BoundConstants",
    "eta_max",
    "eta_max_components",
    "generalized_bound",
    "optimal_eta",
    "fedbuff_bound",
    "asyncsgd_bound",
]


@dataclass
class BoundConstants:
    """Problem constants of Theorem 1.

    A = E[f(mu_0) - f(mu_{T+1})] (initialization gap),
    L = smoothness, B = 2 G^2 + sigma^2 (heterogeneity + gradient noise),
    C = concurrency, T = number of CS steps.
    """

    A: float = 100.0
    L: float = 1.0
    B: float = 20.0
    C: int = 10
    T: int = 10_000
    rho: float = 0.0  # strong-growth constant (App. C.2); 0 = plain A3


def eta_max_components(
    p: np.ndarray, m: np.ndarray, k: BoundConstants
) -> tuple[float, float]:
    """The two branches (a, b) of the Theorem 1 cap, eta_max = min(a, b).

    a = (16 L^2 C m_k growth)^{-1/2} with m_k = sum_i m_i/(n^2 p_i^2),
    b = n^2 / (8 L growth sum_i 1/p_i).

    Exposed separately so the analytic gradient (sampling.bound_value_and_grad)
    can differentiate the *active* branch from the same formulas the objective
    uses — keep both call sites in sync through this single definition.
    """
    p = np.asarray(p, dtype=np.float64)
    m = np.asarray(m, dtype=np.float64)
    n = p.size
    m_k = float(np.sum(m / (n**2 * p**2)))
    growth = 1.0 + k.rho**2
    a = 1.0 / np.sqrt(16.0 * k.L**2 * k.C * m_k * growth)
    b = n**2 / (8.0 * k.L * growth * np.sum(1.0 / p))
    return float(a), float(b)


def eta_max(p: np.ndarray, m: np.ndarray, k: BoundConstants) -> float:
    """Theorem 1 step-size cap.

    eta_max = 1/(4L) * min( C^{-1/2} (max_k m_k^T)^{-1/2},
                            2 / sum_i 1/(n^2 p_i) )
    with m_k^T ~ stationary  m_k = sum_i m_i / (n^2 p_i^2).
    """
    return min(eta_max_components(p, m, k))


def generalized_bound(
    eta: float, p: np.ndarray, m: np.ndarray, k: BoundConstants
) -> float:
    """G(p, eta) of Eq. (3) — the Theorem 1 RHS in stationary regime.

        A/(eta (T+1)) + eta L B sum_i 1/(n^2 p_i)
                      + eta^2 L^2 B C sum_i m_i/(n^2 p_i^2)

    (stationarity: sum_k m_{i,k}^T/(T+1) -> m_i, Prop. 3.)
    """
    p = np.asarray(p, dtype=np.float64)
    m = np.asarray(m, dtype=np.float64)
    n = p.size
    t1 = k.A / (eta * (k.T + 1))
    t2 = eta * k.L * k.B * np.sum(1.0 / (n**2 * p))
    t3 = eta**2 * k.L**2 * k.B * k.C * np.sum(m / (n**2 * p**2))
    return float(t1 + t2 + t3)


def optimal_eta(p: np.ndarray, m: np.ndarray, k: BoundConstants) -> float:
    """argmin_eta G(p, eta) s.t. eta <= eta_max — exact via the cubic root.

    dG/deta = -A/(eta^2 (T+1)) + b + 2 c eta = 0
    with b = L B sum 1/(n^2 p_i), c = L^2 B C sum m_i/(n^2 p_i^2)
    <=>  2 c eta^3 + b eta^2 - A/(T+1) = 0.
    """
    p = np.asarray(p, dtype=np.float64)
    m = np.asarray(m, dtype=np.float64)
    n = p.size
    b = k.L * k.B * np.sum(1.0 / (n**2 * p))
    c = k.L**2 * k.B * k.C * np.sum(m / (n**2 * p**2))
    cap = eta_max(p, m, k)
    roots = np.roots([2.0 * c, b, 0.0, -k.A / (k.T + 1)])
    real = [float(r.real) for r in roots if abs(r.imag) < 1e-12 and r.real > 0]
    eta = min(min(real) if real else cap, cap)
    return float(eta)


# -------------------------------------------------------------------- #
# Baseline bounds (Table 1)
# -------------------------------------------------------------------- #
def fedbuff_bound(eta: float, tau_max: float, n: int, k: BoundConstants) -> float:
    """FedBuff: A/(eta(T+1)) + eta L B + eta^2 tau_max^2 L^2 B n.

    Note: with exponential service times tau_max is unbounded over T -> inf
    and the bound is vacuous — we surface that by returning inf when the
    caller passes tau_max = inf (the honest value).
    """
    if not np.isfinite(tau_max):
        return float("inf")
    return float(
        k.A / (eta * (k.T + 1))
        + eta * k.L * k.B
        + eta**2 * tau_max**2 * k.L**2 * k.B * n
    )


def fedbuff_eta_max(tau_max: float, k: BoundConstants) -> float:
    if not np.isfinite(tau_max):
        return 0.0
    return float(1.0 / (k.L * np.sqrt(tau_max**3)))


def asyncsgd_bound(
    eta: float, tau_c: float, tau_sum: np.ndarray, k: BoundConstants
) -> float:
    """Koloskova et al. AsyncSGD: A/(eta(T+1)) + eta L B + eta^2 tau_c L^2 B sum_i tau_sum_i/(T+1)."""
    if not np.all(np.isfinite(tau_sum)) or not np.isfinite(tau_c):
        return float("inf")
    return float(
        k.A / (eta * (k.T + 1))
        + eta * k.L * k.B
        + eta**2 * tau_c * k.L**2 * k.B * np.sum(tau_sum) / (k.T + 1)
    )


def asyncsgd_eta_max(tau_c: float, tau_max: float, k: BoundConstants) -> float:
    if not (np.isfinite(tau_c) and np.isfinite(tau_max)):
        return 0.0
    return float(1.0 / (k.L * np.sqrt(tau_c * tau_max)))
