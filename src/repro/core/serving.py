"""Resilient serving plane: an open inference queue coupled to the closed
training network.

The paper models *training* as a closed Jackson network; the production
system must also *serve* the model it trains.  This module models inference
requests as an **open** Poisson stream merged into the engine's CTMC event
race (`stream_device.merged_stream_step`): every competing clock — client
completions, faults, request arrivals, service completions, per-request
deadline timeouts, retry-backoff releases — is exponential, so the merged
system stays a CTMC and the engine's one-uniform-pair-per-event machinery
survives unchanged in law.

Robustness envelope (`ServingConfig`):

  * **token-bucket admission control** — ``bucket_rate``/``bucket_cap``
    tokens refill lazily at arrival epochs; an arrival without a token is
    shed.  Composes with **load shedding above a queue-depth threshold**
    (``queue_cap``): arrivals beyond it are shed, so queue depth — and
    therefore memory — is bounded no matter the overload factor.
  * **deadline timeouts with capped, jittered exponential backoff** — each
    queued request carries an ``Exp(1/deadline)`` timeout clock (the
    memoryless deadline keeps the CTMC exact); on firing, the request
    either retries (attempt < ``max_retries``) after an
    ``Exp(1/delay)`` backoff with ``delay = min(backoff_base *
    2**attempt, backoff_cap)`` — the exponential holding time *is* the
    jitter — or is evicted and counted ``timed_out``.
  * **degraded-mode snapshot serving** — requests are answered from the
    engine's ``(C, P)`` snapshot ring at the **known-good pointer**: the
    ring row written by the most recent *accepted* update.  Divergence-
    guard-rejected and fault-masked updates never advance the pointer (and
    never enter ``w`` at all), so a guard trip degrades the served model to
    the last known-good iterate instead of serving poison; the per-serve
    staleness ``k - kg_step`` is histogrammed so the degradation is
    observable.

Everything is fixed-shape and O(R) per serve event (R = ``table_cap``), so
the serve plane rides inside `lax.scan` and checkpoints bitwise as part of
the engine carry.  `simulate_serving_host` is the host-side oracle: the
serving *marginal* of the merged CTMC is independent of the training state
(superposition of independent exponential clocks), so a standalone
event-driven simulation of the same law locks parity for the device plane.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Any, NamedTuple

import numpy as np

__all__ = [
    "ServingConfig",
    "ServeState",
    "ServeStats",
    "serve_init",
    "serve_stats_init",
    "serve_total_rate",
    "serve_depth",
    "serve_time_step",
    "serve_apply",
    "backoff_delay",
    "hist_bucket",
    "hist_quantile",
    "serve_extras",
    "drain_counters",
    "simulate_serving_host",
]

#: number of log2 buckets in the sojourn / staleness histograms
HIST_BUCKETS = 24
#: sojourn histogram: bucket i covers [2**(i + HIST_LO), 2**(i + 1 + HIST_LO))
HIST_LO = -10


@dataclass(frozen=True)
class ServingConfig:
    """The serving plane's traffic and robustness envelope.

    ``arrival_rate`` (lambda) and ``serve_rate`` (nu) are in the same time
    unit as the training network's ``mu``; ``deadline`` is the *mean* of
    the exponential per-request deadline; ``backoff_base``/``backoff_cap``
    bound the mean retry delay ``min(base * 2**attempt, cap)``.
    ``queue_cap`` is the admission threshold on in-system depth;
    ``table_cap`` (>= queue_cap; 0 = auto ``queue_cap + max_retries + 1``)
    sizes the static request table, which also holds backoff parkers.
    ``bucket_rate <= 0`` disables the token bucket (depth-only admission).
    """

    arrival_rate: float = 0.0
    serve_rate: float = 1.0
    queue_cap: int = 8
    bucket_rate: float = 0.0
    bucket_cap: float = 8.0
    deadline: float = 0.0
    max_retries: int = 2
    backoff_base: float = 0.25
    backoff_cap: float = 2.0
    table_cap: int = 0

    @property
    def enabled(self) -> bool:
        return float(self.arrival_rate) > 0.0

    @property
    def R(self) -> int:
        """Static request-table capacity."""
        if int(self.table_cap) > 0:
            return int(self.table_cap)
        return int(self.queue_cap) + int(self.max_retries) + 1

    def validate(self) -> "ServingConfig":
        if self.enabled:
            if float(self.serve_rate) <= 0:
                raise ValueError("serve_rate must be > 0")
            if int(self.queue_cap) < 1:
                raise ValueError("queue_cap must be >= 1")
            if self.R < int(self.queue_cap):
                raise ValueError("table_cap must be >= queue_cap")
            if int(self.max_retries) and float(self.deadline) <= 0:
                # retries only fire off deadline timeouts
                pass
            if float(self.backoff_base) <= 0 or float(self.backoff_cap) <= 0:
                raise ValueError("backoff_base/backoff_cap must be > 0")
        return self

    def cache_key(self):
        return (
            float(self.arrival_rate), float(self.serve_rate),
            int(self.queue_cap), float(self.bucket_rate),
            float(self.bucket_cap), float(self.deadline),
            int(self.max_retries), float(self.backoff_base),
            float(self.backoff_cap), int(self.R),
        )


def backoff_delay(cfg: ServingConfig, attempt):
    """Mean backoff delay before retry number ``attempt`` (1-based):
    ``min(backoff_base * 2**(attempt - 1), backoff_cap)`` — capped
    exponential backoff.  Works on numpy or jnp operands."""
    import jax.numpy as jnp

    a = jnp.maximum(jnp.asarray(attempt, jnp.float32) - 1.0, 0.0)
    return jnp.minimum(
        jnp.float32(cfg.backoff_base) * jnp.exp2(a),
        jnp.float32(cfg.backoff_cap),
    )


# request states in ServeState.stt
_FREE, _QUEUED, _BACKOFF = 0, 1, 2
_SEQ_MAX = np.int32(2**31 - 1)


class ServeState(NamedTuple):
    """Device state of the open serving queue (one scenario).

    ``stt`` is the per-slot request state (0 free / 1 queued / 2 in
    backoff); ``seq`` the FIFO order stamp (service pops the minimum);
    ``kg_slot``/``kg_step`` the known-good snapshot pointer — the ring row
    and server step of the most recent *accepted* training update.

    ``depth`` and ``cdf`` are derived caches of the table — the in-system
    count and the cumulative sum of the (2R + 2,) competing-clock rate
    vector (`_rates`), so ``cdf[-1] == serve_total_rate``.  The table
    mutates only inside `serve_apply`, so both are recomputed once at its
    commit and nowhere else; the engine reads them every merged step (the
    race needs the total rate, the queue-depth time integral needs
    ``depth``) without rebuilding the rate vector on train events.
    """

    t_arr: Any     # (R,) float32 — first-arrival time of the request
    attempt: Any   # (R,) int32 — retries consumed (0 on first attempt)
    stt: Any       # (R,) int32 — _FREE / _QUEUED / _BACKOFF
    seq: Any       # (R,) int32 — FIFO stamp (re-stamped on retry release)
    next_seq: Any  # () int32
    tokens: Any    # () float32 — token bucket level
    t_tok: Any     # () float32 — last lazy bucket refill time
    depth: Any     # () int32 — cached in-system count (== serve_depth)
    cdf: Any       # (2R+2,) float32 — cached cumsum of `_rates`
    kg_slot: Any   # () int32 — snapshot-ring row of the known-good iterate
    kg_step: Any   # () int32 — server step that wrote it


class ServeStats(NamedTuple):
    """Serving observables; float accumulators are Kahan pairs."""

    arrivals: Any    # () int32 — every Poisson arrival, admitted or not
    served: Any      # () int32
    shed: Any        # () int32 — rejected at admission (bucket or depth)
    timed_out: Any   # () int32 — evicted after exhausting the retry budget
    retried: Any     # () int32 — deadline hits that re-entered via backoff
    sojourn: Any     # () float32 — Kahan sum of served sojourn times
    sojourn_c: Any
    qdepth_tw: Any   # () float32 — time integral of in-system depth
    qdepth_tw_c: Any
    qdepth_max: Any  # () int32 — max in-system depth ever observed
    sojourn_hist: Any  # (HIST_BUCKETS,) int32 — log2 sojourn buckets
    stale_hist: Any    # (HIST_BUCKETS,) int32 — log2 served-staleness buckets
    checksum: Any    # () float32 — Kahan sum over serves of the served
    checksum_c: Any  # snapshot row's mean — the serving *read path*


def serve_init(cfg: ServingConfig) -> ServeState:
    import jax.numpy as jnp

    R = cfg.R
    return ServeState(
        t_arr=jnp.zeros(R, jnp.float32),
        attempt=jnp.zeros(R, jnp.int32),
        stt=jnp.zeros(R, jnp.int32),
        seq=jnp.zeros(R, jnp.int32),
        next_seq=jnp.int32(0),
        tokens=jnp.float32(cfg.bucket_cap),
        t_tok=jnp.float32(0.0),
        depth=jnp.int32(0),
        # empty table: only the arrival clock runs, so the cumulative rate
        # vector is flat at lambda — bitwise what cumsum(_rates) gives
        cdf=jnp.full(2 * R + 2, cfg.arrival_rate, jnp.float32),
        kg_slot=jnp.int32(0),
        kg_step=jnp.int32(0),
    )


def serve_stats_init() -> ServeStats:
    import jax.numpy as jnp

    z32 = jnp.int32(0)
    zf = jnp.float32(0.0)
    zh = jnp.zeros(HIST_BUCKETS, jnp.int32)
    return ServeStats(
        arrivals=z32, served=z32, shed=z32, timed_out=z32, retried=z32,
        sojourn=zf, sojourn_c=zf, qdepth_tw=zf, qdepth_tw_c=zf,
        qdepth_max=z32, sojourn_hist=zh, stale_hist=zh,
        checksum=zf, checksum_c=zf,
    )


def _rates(cfg: ServingConfig, sv: ServeState):
    """The serving side's (2R + 2,) competing-clock rate vector:
    ``[arrival | service | R deadline clocks | R backoff releases]``."""
    import jax.numpy as jnp

    queued = sv.stt == _QUEUED
    r_arr = jnp.float32(cfg.arrival_rate)[None]
    r_srv = jnp.where(jnp.any(queued), jnp.float32(cfg.serve_rate), 0.0)[None]
    if float(cfg.deadline) > 0:
        r_tmo = jnp.where(queued, jnp.float32(1.0 / cfg.deadline), 0.0)
    else:
        r_tmo = jnp.zeros(cfg.R, jnp.float32)
    r_rel = jnp.where(
        sv.stt == _BACKOFF, 1.0 / backoff_delay(cfg, sv.attempt), 0.0
    )
    return jnp.concatenate([r_arr, r_srv, r_tmo, r_rel])


def serve_total_rate(cfg: ServingConfig, sv: ServeState):
    """Total serving-side event rate — the open stream's share of the
    merged race (`stream_device.merged_stream_step`'s ``ext_rate``)."""
    return _rates(cfg, sv).sum()


def serve_depth(sv: ServeState):
    """In-system request count (queued + backoff)."""
    import jax.numpy as jnp

    return jnp.sum((sv.stt != _FREE).astype(jnp.int32))


def serve_time_step(stats: ServeStats, sv: ServeState, dt) -> ServeStats:
    """Time-integral accumulation over one merged event of duration ``dt``
    (training *and* serving events both advance the clock, so this runs
    unconditionally — outside the serve/train branch).  Uses the cached
    ``sv.depth`` so train events pay only scalar ops, no O(R) reduction."""
    import jax.numpy as jnp

    from .stream_device import kahan_add

    d = sv.depth
    qtw, qtw_c = kahan_add(
        stats.qdepth_tw, stats.qdepth_tw_c, d.astype(jnp.float32) * dt
    )
    return stats._replace(
        qdepth_tw=qtw, qdepth_tw_c=qtw_c,
        qdepth_max=jnp.maximum(stats.qdepth_max, d),
    )


def hist_bucket(x, lo: int = HIST_LO):
    """log2 bucket index of a positive float32 (clipped into range)."""
    import jax.numpy as jnp

    xb = jnp.maximum(jnp.asarray(x, jnp.float32), 1e-30)
    b = jnp.floor(jnp.log2(xb)).astype(jnp.int32) - lo
    return jnp.clip(b, 0, HIST_BUCKETS - 1)


def serve_apply(cfg: ServingConfig, sv: ServeState, stats: ServeStats,
                u, t, k, snaps, live=True):
    """Resolve one serving event: which clock fired, and its transition.

    ``u`` is the conditional uniform from the merged race (exact in law),
    ``t`` the post-event clock, ``k`` the server step, ``snaps`` the
    ``(C, P)`` snapshot ring.  Everything is masked scatters — no data-
    dependent control flow — so the engine runs it *unconditionally* every
    merged step with ``live = is_ext``: when ``live`` is False every
    transition flag masks off and the call is an exact no-op on ``sv`` /
    ``stats`` (a `lax.cond` would skip the ~30 small ops on train events,
    but marshaling the ~25 serve-state buffers through the conditional
    costs more than the ops themselves at hot-loop event rates).
    Returns ``(sv', stats')``.

    The clock draw is an inverse-CDF ``searchsorted`` over the (2R + 2,)
    rate vector: ``side='right'`` skips zero-rate categories (their cdf
    step is flat), and the one rounding hazard — ``u * total`` landing
    past the last positive entry — is caught by the per-category state
    guards below, which turn any impossible draw into a no-op self-loop.
    Exactly one transition fires per event, so the request table commits
    through a single fused scatter per column instead of one per clock
    class (at the hot-loop event rate, scatter thunks are the cost).
    """
    import jax
    import jax.numpy as jnp

    from .stream_device import kahan_add

    R = cfg.R
    cdf = sv.cdf  # cached cumsum of `_rates` over the pre-event table
    idx = jnp.searchsorted(cdf, u * cdf[-1], side="right").astype(jnp.int32)

    lv = jnp.asarray(live, bool)
    stt0 = sv.stt
    queued = stt0 == _QUEUED
    it_raw = jnp.clip(idx - 2, 0, R - 1)
    ir_raw = jnp.clip(idx - (R + 2), 0, R - 1)
    is_arr = lv & (idx == 0)
    is_srv = lv & (idx == 1) & jnp.any(queued)
    is_tmo = lv & (idx >= 2) & (idx < R + 2) & (stt0[it_raw] == _QUEUED)
    is_rel = (lv & (idx >= R + 2) & (idx < 2 * R + 2)
              & (stt0[ir_raw] == _BACKOFF))

    # ---------------- arrival: token bucket + depth admission ----------
    # lazy refill at arrival epochs only (the bucket is read nowhere else)
    tok_ref = jnp.minimum(
        jnp.float32(cfg.bucket_cap),
        sv.tokens + jnp.float32(cfg.bucket_rate) * (t - sv.t_tok),
    )
    has_free = jnp.any(stt0 == _FREE)
    has_token = (tok_ref >= 1.0) | (float(cfg.bucket_rate) <= 0.0)
    admit = is_arr & has_free & (sv.depth < cfg.queue_cap) & has_token
    i_free = jnp.argmax(stt0 == _FREE).astype(jnp.int32)
    tokens = jnp.where(
        is_arr, tok_ref - jnp.where(admit, 1.0, 0.0), sv.tokens
    )
    t_tok = jnp.where(is_arr, t, sv.t_tok)

    # ---------------- service completion: FIFO head, known-good read ---
    i_head = jnp.argmin(
        jnp.where(queued, sv.seq, _SEQ_MAX)
    ).astype(jnp.int32)
    sj = t - sv.t_arr[i_head]
    # the read path: answer from the known-good snapshot row.  The row
    # mean is the servable observable — a guard-rejected (non-finite /
    # exploded) update reaching it would poison this checksum, which the
    # never-served property test asserts stays finite.  Scalar-output
    # cond: the O(P) row reduction runs only when a serve completes, and
    # `snaps` stays a read-only closure capture (no buffer marshaling).
    row_mean = jax.lax.cond(
        is_srv,
        lambda: jnp.mean(snaps[sv.kg_slot].astype(jnp.float32)),
        lambda: jnp.float32(0.0),
    )
    staleness = (k - sv.kg_step).astype(jnp.float32)

    # ---------------- deadline timeout: retry via backoff, or evict ----
    exhausted = sv.attempt[it_raw] >= cfg.max_retries
    evict = is_tmo & exhausted
    retry = is_tmo & ~exhausted

    # ---------------- commit: one fused scatter per table column -------
    # index R is out of bounds -> drop (shed arrivals and guarded
    # impossible draws leave the table untouched)
    i_upd = jnp.where(
        admit, i_free,
        jnp.where(is_srv, i_head,
                  jnp.where(is_tmo, it_raw,
                            jnp.where(is_rel, ir_raw, R))),
    )
    new_stt = jnp.where(
        admit | is_rel, jnp.int32(_QUEUED),
        jnp.where(retry, jnp.int32(_BACKOFF), jnp.int32(_FREE)),
    )
    stt = stt0.at[i_upd].set(new_stt, mode="drop")
    i_seq = jnp.where(admit | is_rel, i_upd, R)
    seq = sv.seq.at[i_seq].set(sv.next_seq, mode="drop")
    i_att = jnp.where(admit | retry, i_upd, R)
    att_val = jnp.where(admit, jnp.int32(0), sv.attempt[it_raw] + 1)
    attempt = sv.attempt.at[i_att].set(att_val, mode="drop")
    ia = jnp.where(admit, i_free, R)
    t_arr = sv.t_arr.at[ia].set(t, mode="drop")

    next_seq = sv.next_seq + (admit | is_rel).astype(jnp.int32)
    depth = (sv.depth + admit.astype(jnp.int32)
             - (is_srv | evict).astype(jnp.int32))
    sv = ServeState(
        t_arr=t_arr, attempt=attempt, stt=stt, seq=seq, next_seq=next_seq,
        tokens=tokens, t_tok=t_tok, depth=depth, cdf=sv.cdf,
        kg_slot=sv.kg_slot, kg_step=sv.kg_step,
    )
    # refresh the rate cache from the committed table — the one place the
    # table mutates.  On a masked (``~live``) call the table is unchanged
    # and the recompute reproduces the cache bitwise (same op sequence).
    sv = sv._replace(cdf=jnp.cumsum(_rates(cfg, sv)))

    srv_i = is_srv.astype(jnp.int32)
    sojourn, sojourn_c = kahan_add(
        stats.sojourn, stats.sojourn_c, jnp.where(is_srv, sj, 0.0)
    )
    checksum, checksum_c = kahan_add(
        stats.checksum, stats.checksum_c, jnp.where(is_srv, row_mean, 0.0)
    )
    hb = jnp.where(is_srv, hist_bucket(sj), HIST_BUCKETS)
    sb = jnp.where(is_srv, hist_bucket(jnp.maximum(staleness, 1.0), lo=0),
                   HIST_BUCKETS)
    stats = stats._replace(
        arrivals=stats.arrivals + is_arr.astype(jnp.int32),
        served=stats.served + srv_i,
        shed=stats.shed + (is_arr & ~admit).astype(jnp.int32),
        timed_out=stats.timed_out + (is_tmo & exhausted).astype(jnp.int32),
        retried=stats.retried + (is_tmo & ~exhausted).astype(jnp.int32),
        sojourn=sojourn, sojourn_c=sojourn_c,
        sojourn_hist=stats.sojourn_hist.at[hb].add(1, mode="drop"),
        stale_hist=stats.stale_hist.at[sb].add(1, mode="drop"),
        checksum=checksum, checksum_c=checksum_c,
    )
    return sv, stats


# ------------------------------------------------------------------ #
# host-side readout
# ------------------------------------------------------------------ #
def hist_quantile(hist, q: float, lo: int = HIST_LO) -> float:
    """Approximate quantile from a log2-bucket histogram (geometric
    midpoint of the bucket where the cumulative mass crosses ``q``)."""
    h = np.asarray(hist, np.float64)
    total = h.sum()
    if total <= 0:
        return float("nan")
    cum = np.cumsum(h)
    b = int(np.searchsorted(cum, q * total))
    b = min(b, len(h) - 1)
    return float(2.0 ** (b + lo + 0.5))


def drain_counters(sv: ServeState, stats: ServeStats) -> dict:
    """End-of-run drain: requests still in flight when the run stops are
    flushed into ``timed_out`` (server-shutdown semantics) and reported
    separately as ``pending``.  Conservation then holds *exactly*:
    ``served + shed + timed_out == arrivals``."""
    pending = int(np.sum(np.asarray(sv.stt) != _FREE))
    out = {
        "arrivals": int(stats.arrivals),
        "served": int(stats.served),
        "shed": int(stats.shed),
        "timed_out": int(stats.timed_out) + pending,
        "retried": int(stats.retried),
        "pending_drained": pending,
    }
    return out


def serve_extras(cfg: ServingConfig, sv: ServeState, stats: ServeStats,
                 t_final) -> dict:
    """Host-readable serving extras dict (counters, quantiles, SLO view)."""
    from .stream_device import kahan_value

    out = drain_counters(sv, stats)
    served = max(out["served"], 1)
    t = float(np.asarray(t_final, np.float64))
    out.update(
        sojourn_mean=float(kahan_value(stats.sojourn, stats.sojourn_c))
        / served,
        sojourn_p50=hist_quantile(stats.sojourn_hist, 0.50),
        sojourn_p99=hist_quantile(stats.sojourn_hist, 0.99),
        staleness_p50=hist_quantile(stats.stale_hist, 0.50, lo=0),
        staleness_p99=hist_quantile(stats.stale_hist, 0.99, lo=0),
        qdepth_mean=float(kahan_value(stats.qdepth_tw, stats.qdepth_tw_c))
        / max(t, 1e-30),
        qdepth_max=int(stats.qdepth_max),
        checksum=float(kahan_value(stats.checksum, stats.checksum_c)),
        kg_step=int(sv.kg_step),
        shed_frac=out["shed"] / max(out["arrivals"], 1),
    )
    return out


# ------------------------------------------------------------------ #
# host oracle: the serving marginal as a standalone event-driven sim
# ------------------------------------------------------------------ #
def simulate_serving_host(cfg: ServingConfig, horizon: float,
                          seed: int = 0) -> dict:
    """Exact event-driven simulation of the serving plane's marginal law.

    The merged CTMC's serving marginal is independent of the training
    state (independent exponential clocks superpose), so this standalone
    heap simulation follows the *same law* as the device plane inside the
    engine — the parity oracle for tests/test_serving.py.  Returns the
    same counters as `drain_counters` plus the served sojourn list.
    """
    cfg.validate()
    rng = np.random.default_rng(seed)
    R = cfg.R
    lam, nu = float(cfg.arrival_rate), float(cfg.serve_rate)
    arrivals = served = shed = timed_out = retried = 0
    sojourns: list[float] = []
    # request table mirrors ServeState; events live on one heap.  Each
    # queued request re-arms its own Exp(1/deadline) clock; stale heap
    # entries are invalidated by an epoch stamp per slot.
    stt = np.zeros(R, np.int64)
    t_arr = np.zeros(R)
    attempt = np.zeros(R, np.int64)
    seq = np.zeros(R, np.int64)
    epoch = np.zeros(R, np.int64)
    next_seq = 0
    heap: list[tuple[float, int, int, int]] = []  # (t, kind, slot, epoch)
    A_ARR, A_SRV, A_TMO, A_REL = 0, 1, 2, 3
    heapq.heappush(heap, (rng.exponential(1.0 / lam), A_ARR, -1, 0))
    srv_busy_since: float | None = None
    srv_slot = -1
    t = 0.0

    def arm_service():
        nonlocal srv_slot
        q = [i for i in range(R) if stt[i] == _QUEUED]
        if not q:
            srv_slot = -1
            return
        i = min(q, key=lambda i: seq[i])
        if srv_slot != i:
            srv_slot = i
            # memoryless: re-arming on a new head is law-preserving
            heapq.heappush(
                heap, (t + rng.exponential(1.0 / nu), A_SRV, i, epoch[i])
            )

    tokens = float(cfg.bucket_cap)
    t_tok = 0.0
    while heap:
        te, kind, i, ep = heapq.heappop(heap)
        if te > horizon:
            break
        t = te
        if kind == A_ARR:
            heapq.heappush(heap, (t + rng.exponential(1.0 / lam), A_ARR, -1, 0))
            arrivals += 1
            if cfg.bucket_rate > 0:
                tokens = min(cfg.bucket_cap, tokens
                             + cfg.bucket_rate * (t - t_tok))
                t_tok = t
            depth = int(np.sum(stt != _FREE))
            free = np.flatnonzero(stt == _FREE)
            ok = (len(free) > 0 and depth < cfg.queue_cap
                  and (cfg.bucket_rate <= 0 or tokens >= 1.0))
            if not ok:
                shed += 1
                continue
            if cfg.bucket_rate > 0:
                tokens -= 1.0
            s = int(free[0])
            stt[s], t_arr[s], attempt[s] = _QUEUED, t, 0
            seq[s] = next_seq
            next_seq += 1
            epoch[s] += 1
            if cfg.deadline > 0:
                heapq.heappush(
                    heap,
                    (t + rng.exponential(cfg.deadline), A_TMO, s, epoch[s]),
                )
            arm_service()
        elif kind == A_SRV:
            if i != srv_slot or stt[i] != _QUEUED or ep != epoch[i]:
                continue  # stale clock (head changed / request left)
            served += 1
            sojourns.append(t - t_arr[i])
            stt[i] = _FREE
            epoch[i] += 1
            srv_slot = -1
            arm_service()
        elif kind == A_TMO:
            if stt[i] != _QUEUED or ep != epoch[i]:
                continue
            epoch[i] += 1
            if attempt[i] >= cfg.max_retries:
                stt[i] = _FREE
                timed_out += 1
            else:
                retried += 1
                attempt[i] += 1
                stt[i] = _BACKOFF
                d = min(cfg.backoff_base * 2.0 ** (attempt[i] - 1),
                        cfg.backoff_cap)
                heapq.heappush(
                    heap, (t + rng.exponential(d), A_REL, i, epoch[i])
                )
            if i == srv_slot:
                srv_slot = -1
            arm_service()
        else:  # A_REL
            if stt[i] != _BACKOFF or ep != epoch[i]:
                continue
            epoch[i] += 1
            stt[i] = _QUEUED
            seq[i] = next_seq
            next_seq += 1
            if cfg.deadline > 0:
                heapq.heappush(
                    heap,
                    (t + rng.exponential(cfg.deadline), A_TMO, i, epoch[i]),
                )
            arm_service()
    pending = int(np.sum(stt != _FREE))
    return {
        "arrivals": arrivals,
        "served": served,
        "shed": shed,
        "timed_out": timed_out + pending,
        "retried": retried,
        "pending_drained": pending,
        "sojourns": sojourns,
    }
