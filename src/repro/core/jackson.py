"""Closed Jackson network analysis for Generalized AsyncSGD (paper §4).

The asynchronous FL computational graph is a closed Jackson network on the
complete graph with ``n`` single-server FIFO nodes (clients) and ``C``
circulating tasks (Prop. 2 of the paper).  Node ``i`` serves at rate ``mu_i``
(exponential) and the dispatcher routes a completed task to node ``i`` with
probability ``p_i``.  The stationary distribution is product-form

    pi_C(x) = H_C^{-1} * prod_i theta_i^{x_i},     theta_i = p_i / mu_i.

Everything here is exact, host-side math (numpy): the control plane of the
training system.  All quantities are computed with Buzen's convolution
algorithm, in a numerically-stable normalized form (thetas are rescaled by
max(theta) which leaves pi_C invariant, paper §4 'Scaling regime').

Performance notes
-----------------
``buzen_normalizing_constants`` accepts a batch of theta vectors (B, n) and
convolves all of them at once; the 1-D path runs each node's geometric-series
convolution as an O(C) C-level linear filter instead of a Python loop.
``buzen_remove_node`` / ``buzen_add_node`` give O(C) single-node
unconvolution / reconvolution, so perturbing one coordinate of ``p`` does not
cost a full O(n*C) pass.  ``mean_queue_lengths`` is one (n, N) matrix
operation (memoized per N), and ``expected_delays_vjp`` provides the exact
vector-Jacobian product of the delay vector w.r.t. theta via the product-form
identity  d log H_C / d theta_i = E_C[X_i] / theta_i,  which is what makes
analytic simplex gradients in `repro.core.sampling` O(n*C) per step.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "JacksonNetwork",
    "MixedServingResult",
    "mixed_serving_analysis",
    "serving_slo",
    "buzen_normalizing_constants",
    "buzen_add_node",
    "buzen_remove_node",
    "buzen_replace_node",
    "buzen_log_normalizing_constants",
    "buzen_log_add_node",
    "buzen_log_remove_node",
    "batched_expected_delays",
    "two_cluster_delay_bounds",
    "three_cluster_delay_bounds",
    "gamma_ratio",
]

_lfilter = None


def _get_lfilter():
    global _lfilter
    if _lfilter is None:
        from scipy.signal import lfilter

        _lfilter = lfilter
    return _lfilter


def _buzen_reference(theta: np.ndarray, C: int) -> np.ndarray:
    """Seed implementation (pure-Python double loop) — kept as the oracle for
    tests and before/after benchmarks."""
    theta = np.asarray(theta, dtype=np.float64)
    G = np.zeros(C + 1, dtype=np.float64)
    G[0] = 1.0
    for th in theta:
        for c in range(1, C + 1):
            G[c] = G[c] + th * G[c - 1]
    return G


def buzen_normalizing_constants(theta: np.ndarray, C: int) -> np.ndarray:
    """Buzen's convolution algorithm, scalar or batched.

    For ``theta`` of shape (n,), returns ``G`` with
    ``G[c] = H_c = sum_{x : sum x_i = c} prod theta_i^{x_i}`` for ``c = 0..C``
    (complexity O(n*C), executed as n O(C) linear filters at C speed).

    For ``theta`` of shape (B, n) — a batch of B independent theta vectors —
    returns (B, C+1), convolving the whole batch in vectorized sweeps.  This
    is what lets `optimize_two_cluster` evaluate its entire coarse grid in
    one call.

    For numerical stability the caller should pass *rescaled* thetas
    (``theta / theta.max()``); all ratios H_{c-1}/H_c etc. are invariant.
    """
    theta = np.asarray(theta, dtype=np.float64)
    if theta.ndim not in (1, 2) or theta.size == 0:
        raise ValueError("theta must be a non-empty 1-D or 2-D array")
    if np.any(theta <= 0):
        raise ValueError("theta must be strictly positive")
    if C < 0:
        raise ValueError("C must be >= 0")
    if theta.ndim == 1:
        lfilter = _get_lfilter()
        G = np.zeros(C + 1, dtype=np.float64)
        G[0] = 1.0
        b = np.ones(1)
        for th in theta:
            # G_new[c] = G_old[c] + th * G_new[c-1]: an IIR filter along c.
            G = lfilter(b, np.array([1.0, -th]), G)
        return G
    B, n = theta.shape
    G = np.zeros((B, C + 1), dtype=np.float64)
    G[:, 0] = 1.0
    for i in range(n):
        th = theta[:, i]
        for c in range(1, C + 1):
            G[:, c] += th * G[:, c - 1]
    return G


def buzen_remove_node(G: np.ndarray, th: float | np.ndarray) -> np.ndarray:
    """O(C) unconvolution: normalizing constants of the network with one node
    (traffic intensity ``th``) removed.

    Inverts the Buzen recurrence ``G[c] = G_minus[c] + th * G[c-1]``; note the
    right-hand side uses the *full* G, so this is a fully vectorized first
    difference, not a sequential recurrence.  Works on (C+1,) or batched
    (B, C+1) arrays (``th`` scalar or (B,)).

    Numerical caveat: the subtraction cancels catastrophically when the
    removed node *dominates* the network (H_c ≈ th * H_{c-1}, i.e. ``th``
    near the rescaling maximum with everyone else far below).  That regime is
    detectable — the true constants are strictly positive, cancellation
    drives entries to ~0 or below — so we raise instead of returning garbage;
    fall back to a full `buzen_normalizing_constants` pass in that case.
    """
    G = np.asarray(G, dtype=np.float64)
    th_arr = np.asarray(th, dtype=np.float64)
    if G.ndim == 2:
        th_arr = th_arr.reshape(-1, 1)
    out = np.empty_like(G)
    out[..., 0] = G[..., 0]
    out[..., 1:] = G[..., 1:] - th_arr * G[..., :-1]
    if np.any(out <= 0):
        raise FloatingPointError(
            "buzen_remove_node lost all precision (removed node dominates the "
            "network); recompute with buzen_normalizing_constants instead"
        )
    return out


def buzen_add_node(G: np.ndarray, th: float | np.ndarray) -> np.ndarray:
    """O(C) reconvolution: add a node with traffic intensity ``th``.

    ``buzen_add_node(buzen_remove_node(G, t), t) == G`` up to roundoff.
    """
    G = np.asarray(G, dtype=np.float64)
    if G.ndim == 1:
        lfilter = _get_lfilter()
        return lfilter(np.ones(1), np.array([1.0, -float(th)]), G)
    th_arr = np.asarray(th, dtype=np.float64)
    out = G.copy()
    C = G.shape[-1] - 1
    for c in range(1, C + 1):
        out[..., c] += th_arr * out[..., c - 1]
    return out


def buzen_replace_node(
    G: np.ndarray, th_old: float | np.ndarray, th_new: float | np.ndarray
) -> np.ndarray:
    """O(C) update of G after perturbing a single node's theta — the
    incremental alternative to a full O(n*C) reconvolution."""
    return buzen_add_node(buzen_remove_node(G, th_old), th_new)


# ---------------------------------------------------------------------- #
# log-space Buzen: large n / C / skewed theta without float64 over- or
# underflow.  Even after the theta/theta.max rescaling, G(c) ~ binom(n, c)
# exceeds float64 range once n*C is large (n = 10^4, C = 10^3 already
# overflows), and strongly skewed thetas underflow the tail entries — the
# linear-space path then returns inf/0 and every downstream ratio is
# garbage.  These variants carry log G(c) throughout.
# ---------------------------------------------------------------------- #
def _log_nb_series(lth: float, count: float, C: int) -> np.ndarray:
    """log coefficients of (1 - e^lth x)^(-count), orders 0..C.

    The generating function of a speed class with ``count`` identical
    nodes: its order-k coefficient is the negative-binomial weight
    binom(count+k-1, k) th^k, built stably via the log-ratio recurrence
    ``lnb_k = lnb_{k-1} + lth + log((count+k-1)/k)``.
    """
    if C == 0:
        return np.zeros(1)
    k = np.arange(1.0, C + 1.0)
    return np.concatenate([[0.0], np.cumsum(lth + np.log((count + k - 1.0) / k))])


def _log_conv(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Truncated log-space polynomial product: out[c] = logsumexp_j a[j]+b[c-j]."""
    from scipy.special import logsumexp

    L = a.shape[0]
    M = np.full((L, L), -np.inf)
    for c in range(L):
        M[c, : c + 1] = a[: c + 1] + b[c::-1]
    return logsumexp(M, axis=1)


def buzen_log_normalizing_constants(
    theta: np.ndarray, C: int, counts: np.ndarray | None = None
) -> np.ndarray:
    """log G(c), c = 0..C, overflow/underflow-free.

    Without ``counts``: one exact log-space convolution per node.  Adding
    a node is ``G'[c] = sum_j G[j] th^(c-j)``, i.e. with the tilted vector
    ``h[j] = log G[j] - j log th`` simply ``log G'[c] = c log th +
    logcumsumexp(h)[c]`` — a vectorized `np.logaddexp.accumulate`, O(C)
    per node with no renormalization step and no within-vector underflow
    (a plain running-renormalization sweep keeps the *scale* in range but
    still zeroes entries >~300 decades below the vector max, destroying
    the low-c constants that tail probabilities need).

    With ``counts`` (m,): ``theta`` holds one entry per *speed class* and
    ``counts`` its multiplicities — the class-collapsed control plane.
    Each class contributes a negative-binomial series (`_log_nb_series`)
    and the m series are convolved fully in log space: O(m*C^2)
    independent of n, which is what makes n = 10^6 exact analysis cheap.

    Same rescaling contract as `buzen_normalizing_constants`: pass
    ``theta / theta.max()``; all ratios of G entries are invariant.
    """
    theta = np.asarray(theta, dtype=np.float64)
    if theta.ndim != 1 or theta.size == 0:
        raise ValueError("theta must be a non-empty 1-D array")
    if np.any(theta <= 0):
        raise ValueError("theta must be strictly positive")
    if C < 0:
        raise ValueError("C must be >= 0")
    if counts is not None:
        counts = np.asarray(counts, dtype=np.float64)
        if counts.shape != theta.shape or np.any(counts < 1):
            raise ValueError("counts must match theta with entries >= 1")
        lg = _log_nb_series(float(np.log(theta[0])), float(counts[0]), C)
        for lth, cnt in zip(np.log(theta[1:]), counts[1:]):
            lg = _log_conv(lg, _log_nb_series(float(lth), float(cnt), C))
        return lg
    tilt = np.arange(C + 1, dtype=np.float64)
    lg = np.full(C + 1, -np.inf)
    lg[0] = 0.0
    for lth in np.log(theta):
        ct = tilt * lth
        lg = ct + np.logaddexp.accumulate(lg - ct)
    return lg


def buzen_log_add_node(lG: np.ndarray, lth: float) -> np.ndarray:
    """O(C) log-space reconvolution: add one node with log-theta ``lth``.

    ``lG'[c] = logaddexp(lG[c], lth + lG'[c-1])`` — the IIR recurrence of
    `buzen_add_node` carried in logs.
    """
    lG = np.asarray(lG, dtype=np.float64)
    out = np.empty_like(lG)
    out[0] = lG[0]
    for c in range(1, lG.shape[0]):
        out[c] = np.logaddexp(lG[c], lth + out[c - 1])
    return out


def buzen_log_remove_node(lG: np.ndarray, lth: float) -> np.ndarray:
    """O(C) log-space unconvolution, inverse of `buzen_log_add_node`.

    Inverts ``lG[c] = logaddexp(lG'[c], lth + lG[c-1])`` — like the
    linear-space first difference, the subtracted term uses the *full*
    network's constants, so this is vectorized:
    ``lG'[c] = lG[c] + log1p(-exp(lth + lG[c-1] - lG[c]))``.  Like
    `buzen_remove_node` it cancels catastrophically when the removed node
    dominates; that regime surfaces as the log1p argument reaching -1 and
    raises instead of returning NaN/-inf.
    """
    lG = np.asarray(lG, dtype=np.float64)
    d = lth + lG[:-1] - lG[1:]
    if np.any(d >= 0.0):
        raise FloatingPointError(
            "buzen_log_remove_node lost all precision (removed node "
            "dominates); recompute with buzen_log_normalizing_constants"
        )
    out = np.empty_like(lG)
    out[0] = lG[0]
    out[1:] = lG[1:] + np.log1p(-np.exp(d))
    if not np.all(np.isfinite(out)):
        raise FloatingPointError(
            "buzen_log_remove_node lost all precision (removed node "
            "dominates); recompute with buzen_log_normalizing_constants"
        )
    return out


def gamma_ratio(F: int, c: float) -> float:
    """The paper's Gamma(c) = P(sum_{j<=F+2} E_j <= c) / P(sum_{j<=F+1} E_j <= c).

    Erlang CDF ratio (App. D.3).  ``P(k, x) = 1 - sum_{i<k} e^-x x^i/i!``.
    """
    from scipy.stats import gamma as _gamma

    num = _gamma.cdf(c, a=F + 2)
    den = _gamma.cdf(c, a=F + 1)
    if den == 0.0:
        return 1.0
    return float(num / den)


def _tail_matrix(theta: np.ndarray, G: np.ndarray, N: int) -> np.ndarray:
    """P[i, c-1] = P(X_i >= c) = theta_i^c H_{N-c} / H_N, c = 1..N  (n, N).

    Scale-invariant: pass the rescaled thetas with their matching G.
    """
    n = theta.shape[0]
    if N == 0:
        return np.zeros((n, 0))
    pows = np.cumprod(np.tile(theta[:, None], (1, N)), axis=1)
    return pows * (G[N - 1 :: -1][:N] / G[N])


def batched_expected_delays(
    mu: np.ndarray, P: np.ndarray, C: int, normalized: bool = True
) -> tuple[np.ndarray, np.ndarray]:
    """Delay vectors m and throughputs for a batch of sampling vectors.

    ``mu`` (n,) shared service rates; ``P`` (B, n) rows on the simplex.
    Returns ``(m, lam)`` with shapes (B, n) and (B,).  One batched Buzen pass
    plus one einsum — the whole coarse grid of `optimize_two_cluster` in a
    single call.  Memory O(B*n*C).
    """
    mu = np.asarray(mu, dtype=np.float64)
    P = np.asarray(P, dtype=np.float64)
    theta = P / mu
    s = theta.max(axis=1, keepdims=True)
    th = theta / s
    G = buzen_normalizing_constants(th, C)  # (B, C+1)
    N = C - 1
    B, n = th.shape
    if N == 0:
        q = np.zeros((B, n))
    else:
        pows = np.cumprod(np.repeat(th[:, :, None], N, axis=2), axis=2)
        ratios = G[:, N - 1 :: -1][:, :N] / G[:, N][:, None]  # (B, N)
        q = np.einsum("inc,ic->in", pows, ratios)
    lam = G[:, C - 1] / G[:, C] / s[:, 0]
    m = lam[:, None] * (q + 1.0) / mu
    if normalized:
        m = m * (C - 1.0) / C
    return m, lam


@dataclass
class JacksonNetwork:
    """Exact stationary analysis of the paper's closed network.

    Parameters
    ----------
    mu : (n,) service rates (tasks/unit-time) per client.
    p  : (n,) dispatcher sampling probabilities (sum to 1).
    C  : number of circulating tasks (concurrency).
    """

    mu: np.ndarray
    p: np.ndarray
    C: int
    _G: np.ndarray = field(init=False, repr=False)
    _theta: np.ndarray = field(init=False, repr=False)
    _ql_cache: dict = field(init=False, repr=False, default_factory=dict)
    _E: np.ndarray | None = field(init=False, repr=False, default=None)

    def __post_init__(self) -> None:
        self.mu = np.asarray(self.mu, dtype=np.float64)
        self.p = np.asarray(self.p, dtype=np.float64)
        if self.mu.shape != self.p.shape:
            raise ValueError("mu and p must have the same shape")
        if abs(float(self.p.sum()) - 1.0) > 1e-8:
            raise ValueError(f"p must sum to 1, got {self.p.sum()}")
        if self.C < 1:
            raise ValueError("C must be >= 1")
        theta = self.p / self.mu
        self._theta = theta / theta.max()  # rescale: pi_C invariant
        self._G = buzen_normalizing_constants(self._theta, self.C)

    # ------------------------------------------------------------------ #
    # product-form basics
    # ------------------------------------------------------------------ #
    @property
    def n(self) -> int:
        return int(self.mu.size)

    @property
    def theta(self) -> np.ndarray:
        """Rescaled traffic intensities theta_i/max_j theta_j."""
        return self._theta

    def normalizing_constant(self, c: int | None = None) -> float:
        """H_c for the *rescaled* thetas (c defaults to C)."""
        c = self.C if c is None else c
        return float(self._G[c])

    def stationary_prob(self, x: np.ndarray) -> float:
        """pi_C(x) for a full state vector x (sum x_i must equal C)."""
        x = np.asarray(x)
        if x.sum() != self.C:
            return 0.0
        return float(np.prod(self._theta**x) / self._G[self.C])

    def queue_tail_prob(self, i: int, c: int, ntasks: int | None = None) -> float:
        """P(X_i >= c) = theta_i^c * H_{C-c} / H_C (standard closed-network identity)."""
        N = self.C if ntasks is None else ntasks
        if c <= 0:
            return 1.0
        if c > N:
            return 0.0
        return float(self._theta[i] ** c * self._G[N - c] / self._G[N])

    def mean_queue_lengths(self, ntasks: int | None = None) -> np.ndarray:
        """E[X_i] = sum_{c=1..N} P(X_i >= c), for a network with N tasks.

        ``ntasks=C-1`` gives the arrival-theorem view (Theorem 11 / MUSTA).
        One (n, N) matrix-vector product, memoized per N.
        """
        N = self.C if ntasks is None else ntasks
        cached = self._ql_cache.get(N)
        if cached is not None:
            return cached.copy()
        if N == 0:
            out = np.zeros(self.n)
        else:
            pows = np.cumprod(np.tile(self._theta[:, None], (1, N)), axis=1)
            out = pows @ (self._G[N - 1 :: -1][:N] / self._G[N])
        self._ql_cache[N] = out
        return out.copy()

    def occupancy_matrix(self) -> np.ndarray:
        """E[i, M] = E_M[X_i] for all populations M = 0..C, shape (n, C+1).

        Built in O(n*C) by the MVA-style recurrence
        ``E_M[X_i] = theta_i (H_{M-1}/H_M) (1 + E_{M-1}[X_i])`` and cached;
        the gradient machinery reads every column.
        """
        if self._E is None:
            E = np.zeros((self.n, self.C + 1))
            ratio = self._G[:-1] / self._G[1:]  # H_{M-1}/H_M, M = 1..C
            for M in range(1, self.C + 1):
                E[:, M] = self._theta * ratio[M - 1] * (1.0 + E[:, M - 1])
            E.setflags(write=False)  # shared cache: callers get a frozen view
            self._E = E
        return self._E

    def utilization(self, ntasks: int | None = None) -> np.ndarray:
        """rho_i = P(X_i > 0) = theta_i * H_{N-1}/H_N."""
        N = self.C if ntasks is None else ntasks
        return self._theta * self._G[N - 1] / self._G[N]

    def throughput(self, ntasks: int | None = None) -> float:
        """Total CS step rate Lambda(N) in *unrescaled* units.

        Lambda(N) = sum_i mu_i P(X_i>0) = sum_i mu_i theta_i H_{N-1}/H_N.
        With unrescaled theta_i = p_i/mu_i this is H_{N-1}/H_N; rescaling by
        theta_max divides theta by theta_max hence multiplies H_{N-1}/H_N
        ratio by theta_max... careful: H_c(theta/s) = H_c(theta)/s^c, so
        H_{N-1}/H_N in rescaled units equals s * (H_{N-1}/H_N) unrescaled.
        We correct for that here to return physical tasks/unit-time.
        """
        N = self.C if ntasks is None else ntasks
        s = float((self.p / self.mu).max())
        return float(self._G[N - 1] / self._G[N] / s)

    def node_throughputs(self, ntasks: int | None = None) -> np.ndarray:
        """lambda_i = p_i * Lambda(N) (flow balance on the complete graph)."""
        return self.p * self.throughput(ntasks)

    # ------------------------------------------------------------------ #
    # the paper's key quantity: m_i, expected delay in CS steps (Prop. 3)
    # ------------------------------------------------------------------ #
    def expected_sojourn_time(self, i: int) -> float:
        """Palm expectation E^{C-1}[S_i] = (E^{C-1}[X_i] + 1)/mu_i (FIFO, App. D.4)."""
        ql = self.mean_queue_lengths(ntasks=self.C - 1)
        return float((ql[i] + 1.0) / self.mu[i])

    def expected_delay_steps(self, i: int) -> float:
        """Arrival-theorem estimate of m_i (CS steps between dispatch & completion).

        Prop. 3: m_i = E^{C-1}[ int_0^{S_i} sum_j mu_j 1(X_j(s)>0) ds ].
        The integrand is the instantaneous CS step rate; replacing it by its
        stationary mean Lambda(C) gives   m̂_i = Lambda(C) * E^{C-1}[S_i].
        Matches event-driven simulation within a few % (see tests/benchmarks).
        """
        return self.throughput() * self.expected_sojourn_time(i)

    def delay_upper_bound_steps(self, i: int) -> float:
        """Prop. 5 style bound: m_i <= lambda_tot * E^{C-1}[S_i], lambda_tot=sum mu_j."""
        return float(self.mu.sum()) * self.expected_sojourn_time(i)

    def expected_delays(self, normalized: bool = True) -> np.ndarray:
        """Vector of m̂_i = Lambda(C) * E^{C-1}[S_i] for all nodes.

        With ``normalized=True`` (default) the vector is rescaled by
        (C-1)/C so that the exact Little's-law identity
        ``sum_i p_i m_i = C - 1`` holds (each completed task saw exactly
        C-1 *other* completions on average while in flight).  The raw
        estimate satisfies sum_i p_i * Lambda * E[S_i] = C by Little's law
        in physical time; the normalization removes the known +1 bias and
        is exact in the saturated regime (all nodes busy).
        """
        ql = self.mean_queue_lengths(ntasks=self.C - 1)
        m = self.throughput() * (ql + 1.0) / self.mu
        if normalized:
            m = m * (self.C - 1.0) / self.C
        return m

    def expected_delays_vjp(self, v: np.ndarray, normalized: bool = True) -> np.ndarray:
        """Exact w_j = sum_i v_i * dm_i/dtheta_j, theta_j = p_j/mu_j unrescaled.

        The full Jacobian dm/dtheta is n x n; the bound optimizer only ever
        needs its action on a cotangent v, which this computes in O(n*C) from
        the product-form identity  dH_N/dtheta_j = E_N[X_j] H_N / theta_j:

            dLambda/dtheta_j = Lambda (E_{C-1}[X_j] - E_C[X_j]) / theta_j
            dq_i/dtheta_j    = (1/theta_j) [ sum_c P_c(i)(E_{N-c}[X_j]
                               - E_N[X_j]) + delta_ij sum_c c P_c(i) ]

        with N = C-1, q_i = E^{C-1}[X_i], P_c(i) the tail probabilities, and
        m_i = kappa * Lambda (q_i + 1)/mu_i.  All sums reduce to tail/
        occupancy matrices that are invariant under the theta rescaling.
        """
        v = np.asarray(v, dtype=np.float64)
        C = self.C
        N = C - 1
        theta_unres = self.p / self.mu
        E = self.occupancy_matrix()
        q = E[:, N]
        lam = self.throughput()
        u = v / self.mu
        kappa = (C - 1.0) / C if normalized else 1.0
        if N > 0:
            Pm = _tail_matrix(self._theta, self._G, N)  # (n, N)
            a = Pm.T @ u  # a_c = sum_i u_i P_c(i), c = 1..N
            # term1_j = sum_c a_c E_{N-c}[X_j]: columns N-1..0 of E
            term1 = E[:, N - 1 :: -1][:, :N] @ a
            S = Pm @ np.arange(1, N + 1, dtype=np.float64)
            vjp_q = (term1 - E[:, N] * float(u @ q) + u * S) / theta_unres
        else:
            vjp_q = np.zeros_like(u)
        dlam = lam * (E[:, C - 1] - E[:, C]) / theta_unres
        return kappa * (dlam * float(u @ (q + 1.0)) + lam * vjp_q)

    def delay_upper_bounds(self) -> np.ndarray:
        ql = self.mean_queue_lengths(ntasks=self.C - 1)
        return float(self.mu.sum()) * (ql + 1.0) / self.mu

    # ------------------------------------------------------------------ #
    # brute-force oracle (small n, C) — used by tests
    # ------------------------------------------------------------------ #
    def brute_force_distribution(self) -> dict[tuple[int, ...], float]:
        """Enumerate all states (only for tiny n, C): exact pi_C."""
        if math.comb(self.C + self.n - 1, self.n - 1) > 200_000:
            raise ValueError("state space too large for brute force")
        states = _compositions(self.C, self.n)
        w = np.array([np.prod(self._theta**np.array(s)) for s in states])
        w = w / w.sum()
        return {tuple(s): float(v) for s, v in zip(states, w)}


def _compositions(total: int, parts: int) -> list[tuple[int, ...]]:
    """All non-negative integer vectors of length `parts` summing to `total`."""
    if parts == 1:
        return [(total,)]
    out = []
    for head in range(total + 1):
        for tail in _compositions(total - head, parts - 1):
            out.append((head,) + tail)
    return out


# ---------------------------------------------------------------------- #
# Saturated-regime closed forms (paper §4 & App. F/G)
# ---------------------------------------------------------------------- #
def two_cluster_delay_bounds(
    n: int, n_f: int, mu_f: float, mu_s: float, C: int
) -> tuple[float, float]:
    """Closed-form delay bounds for the 2-cluster saturated regime (App. F.1).

    Uniform sampling p_i=1/n, n_f fast nodes at rate mu_f, n-n_f slow at mu_s
    (mu_f > mu_s).  Returns (m_fast_bound, m_slow_bound) in CS steps:

        m_f <= lambda/mu_f * 1/(mu_f/mu_s - 1)
        m_s <= lambda/mu_s * (C/(n-n_f) - n_f/(n-n_f) * 1/(mu_f/mu_s - 1))

    (The paper specializes to n_f = n/2 giving its 5n / 195n example.)
    """
    if mu_f <= mu_s:
        raise ValueError("mu_f must exceed mu_s in the 2-cluster regime")
    lam = n_f * mu_f + (n - n_f) * mu_s
    ratio = mu_f / mu_s - 1.0
    x_f = 1.0 / ratio  # limiting scaled queue length of a fast node
    m_fast = lam / mu_f * x_f
    m_slow = lam / mu_s * (C / (n - n_f) - n_f / (n - n_f) * x_f)
    return float(m_fast), float(m_slow)


def three_cluster_delay_bounds(
    n: int,
    n_f: int,
    n_m: int,
    mu_f: float,
    mu_m: float,
    mu_s: float,
    C: int,
    p_fast_busy: float = 1.0,
) -> tuple[float, float, float]:
    """App. G closed forms for fast/medium/slow clusters (fast queues degenerate).

    lambda = n_f*P(X_f>0)*mu_f + (n_m-n_f)*mu_m + (n-n_m)*mu_s.
    Returns (m_fast, m_medium, m_slow) upper bounds in CS steps.
    """
    if not (mu_f > mu_m > mu_s):
        raise ValueError("need mu_f > mu_m > mu_s")
    lam = n_f * p_fast_busy * mu_f + (n_m - n_f) * mu_m + (n - n_m) * mu_s
    ratio_m = mu_m / mu_s - 1.0
    m_fast = lam / mu_f
    m_med = lam / mu_m / ratio_m
    m_slow = lam / mu_s * (C * (n / (n - n_m)) / n - 1.0 / ratio_m)
    # note: with equal thirds (n-n_m)=n/3 the paper writes 3C/n - 1/ratio.
    return float(m_fast), float(m_med), float(m_slow)


# --------------------------------------------------------------------------- #
# mixed open/closed analysis: serving plane coupled to the training network
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class MixedServingResult:
    """Stationary quantities of the open serving queue beside the closed network.

    All rates are in the physical (unrescaled) units of ``mu``.
    """

    lambda_train: float      # closed-network CS step throughput Lambda(C)
    serve_rate_eff: float    # nu_eff: serve rate after training interference
    rho: float               # offered load lambda_arr / nu_eff
    block_prob: float        # P(shed): arrival finds the M/M/1/K queue full
    admit_rate: float        # lambda_arr * (1 - block_prob)
    mean_queue: float        # E[Q] including the request in service
    mean_sojourn: float      # W = E[Q] / admit_rate (Little's law)
    p99_sojourn: float       # FCFS tail estimate ln(100) * W
    utilization: float       # P(server busy) = 1 - pi_0


def serving_slo(
    lambda_train: float,
    *,
    arrival_rate: float,
    serve_rate: float,
    queue_cap: int,
    update_capacity: float | None = None,
) -> MixedServingResult:
    """M/M/1/K serving-plane factor at a given training throughput.

    ``update_capacity`` models the *host* coupling that the merged CTMC
    abstracts away: the serve loop shares one host with the update scan, so
    each training step at throughput ``lambda_train`` steals
    1/update_capacity of the wall clock and the effective serve rate shrinks
    to

        nu_eff = serve_rate * max(1 - lambda_train/update_capacity, 0.05).

    With ``update_capacity=None`` the planes are independent and
    ``nu_eff = serve_rate`` — the exact law of the simulated merged chain.
    The p99 estimate is the FCFS exponential-tail approximation
    ``ln(100) * W``.
    """
    if arrival_rate <= 0 or serve_rate <= 0:
        raise ValueError("arrival_rate and serve_rate must be positive")
    if queue_cap < 1:
        raise ValueError("queue_cap must be >= 1")
    if update_capacity is not None:
        frac = max(1.0 - float(lambda_train) / float(update_capacity), 0.05)
        nu_eff = serve_rate * frac
    else:
        nu_eff = float(serve_rate)
    K = int(queue_cap)
    rho = arrival_rate / nu_eff
    if abs(rho - 1.0) < 1e-12:
        block = 1.0 / (K + 1)
        mean_q = K / 2.0
        pi0 = 1.0 / (K + 1)
    else:
        block = (1.0 - rho) * rho**K / (1.0 - rho ** (K + 1))
        mean_q = rho / (1.0 - rho) - (K + 1) * rho ** (K + 1) / (
            1.0 - rho ** (K + 1)
        )
        pi0 = (1.0 - rho) / (1.0 - rho ** (K + 1))
    admit = arrival_rate * (1.0 - block)
    W = mean_q / admit if admit > 0 else math.inf
    return MixedServingResult(
        lambda_train=float(lambda_train),
        serve_rate_eff=float(nu_eff),
        rho=float(rho),
        block_prob=float(block),
        admit_rate=float(admit),
        mean_queue=float(mean_q),
        mean_sojourn=float(W),
        p99_sojourn=float(math.log(100.0) * W),
        utilization=float(1.0 - pi0),
    )


def mixed_serving_analysis(
    mu: np.ndarray,
    p: np.ndarray,
    C: int,
    *,
    arrival_rate: float,
    serve_rate: float,
    queue_cap: int,
    update_capacity: float | None = None,
) -> MixedServingResult:
    """Product-form analysis of the merged open/closed network.

    The engine merges an open Poisson(``arrival_rate``) inference stream into
    the closed Jackson network's event race (`repro.core.serving`).  In the
    merged CTMC the serving clocks are independent of the training state, so
    the stationary law factorizes: closed product-form marginal (Prop. 2)
    x an M/M/1/K marginal with K = ``queue_cap`` for the serve queue.  This
    evaluates the closed factor with Buzen's algorithm and composes it with
    the open factor via `serving_slo` (which also carries the optional
    ``update_capacity`` host-interference model).
    `repro.core.sampling.optimize_tradeoff` drives this to trade training
    throughput against the serving SLO.
    """
    net = JacksonNetwork(mu=np.asarray(mu, float), p=np.asarray(p, float), C=C)
    return serving_slo(
        net.throughput(),
        arrival_rate=arrival_rate,
        serve_rate=serve_rate,
        queue_cap=queue_cap,
        update_capacity=update_capacity,
    )
