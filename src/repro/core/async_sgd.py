"""Generalized AsyncSGD (Algorithm 1) and asynchronous/synchronous baselines.

The server algorithms are written against an abstract `GradientSource`
(anything that can produce a stochastic gradient for client i at parameters
w), so they run identically over:
  * toy quadratic problems (tests),
  * jitted JAX models on federated data shards (repro.fl),
  * sharded multi-pod train steps (repro.launch.train).

Faithfulness notes
------------------
* Line 10 of Algorithm 1:  w_{k+1} = w_k - eta/(n p_{J_k}) * g_{J_k}(w_{I_k})
  — the gradient is computed *at the dispatch-time parameters* w_{I_k}.  We
  snapshot parameters per in-flight task (C snapshots live at any time).
* Event timing follows the closed Jackson network (repro.core.queue_sim):
  completions J_k, sampling K_{k+1} ~ p, FIFO queues per client.
* The virtual-iterate sequence mu_k (Eq. 4) is tracked on demand to expose
  the Lemma-9 invariant |G_k| = C - 1 in tests.

Engines
-------
Each async server loop runs through one of two interchangeable engines
(`ServerConfig.engine`):
  * "python" — the per-event reference loop below (the parity oracle);
  * "scan"   — the compiled device-resident engine (repro.core.engine_scan):
    the event stream is pre-simulated on the host and Algorithm 1 replays
    as a single `jax.lax.scan` over a stacked snapshot ring buffer.
Identical (seed, block) => identical event stream => iterates agree to
float-associativity tolerance (locked in tests/test_engine.py).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Any, Callable, Protocol

import numpy as np

from .queue_sim import (
    KIND_COMPLETE,
    KIND_FLIP,
    KIND_STAGE,
    ClosedNetworkSim,
    FaultConfig,
    SimConfig,
    export_stream,
)

__all__ = [
    "GradientSource",
    "ServerConfig",
    "TraceRecord",
    "run_generalized_async_sgd",
    "run_fedbuff",
    "run_fedavg",
    "run_favano",
]

Pytree = Any


class GradientSource(Protocol):
    def grad(self, client_id: int, params: Pytree, server_step: int) -> Pytree:
        """Stochastic gradient of client `client_id`'s local loss at `params`."""
        ...


def _tree_map(f, *trees):
    import jax

    return jax.tree_util.tree_map(f, *trees)


def _axpy(w: Pytree, g: Pytree, a: float) -> Pytree:
    """w + a*g elementwise over the pytree."""
    return _tree_map(lambda x, y: x + a * y, w, g)


@dataclass
class ServerConfig:
    """One server-loop run of {Generalized AsyncSGD, AsyncSGD, FedBuff, ...}.

    The queueing knobs (``n``, ``C``, ``T``, ``p``, ``mu``, ``service``)
    define the closed Jackson network of §2; the engine knobs pick how
    Algorithm 1 executes (reference Python loop vs one compiled scan, host
    replay vs fused on-device stream, per-event vs micro-blocked, single- vs
    multi-device).  See ``docs/architecture.md`` for the decision matrix.
    """

    n: int                      # number of clients
    C: int                      # concurrency (in-flight tasks)
    T: int                      # CS steps
    eta: float                  # learning rate
    p: np.ndarray | None = None  # sampling probabilities (None = uniform)
    mu: np.ndarray | None = None  # client speeds for the event clock (None = 1)
    service: str = "exp"
    seed: int = 0
    weighting: str = "importance"  # "importance" (Alg. 1) | "plain" (AsyncSGD)
    eval_every: int = 0
    track_virtual: bool = False
    apply_update: Callable[[Pytree, Pytree, float], Pytree] | None = None
    # apply_update(w, g, scale) -> new w.  Defaults to w - scale*g; override to
    # route through an optimizer or the Pallas weighted_update kernel.
    engine: str = "python"      # "python" (reference loop) | "scan" (compiled)
    update: str = "jnp"         # scan engine update path: "jnp" | "pallas"
    stream: str = "host"        # scan engine event source: "host" (pre-simulated
                                # EventStream replay, the parity oracle) |
                                # "device" (fused on-device generator, exp only)
    sparse: bool | str = "auto"  # device stream: sparse O(C) per-event state
                                 # keyed by the in-flight slots (flat in n).
                                 # "auto" switches on when n >= SPARSE_AUTO_N,
                                 # the speed profile collapses to few classes
                                 # and the run is per-event/unsharded/un-
                                 # checkpointed; True forces it (errors if
                                 # unsupported); False keeps the dense oracle
    adaptive: bool = False      # device stream: re-optimize p from running
                                # occupancy estimates every refresh_every steps
    refresh_every: int = 0      # control-loop cadence (CS steps)
    ctrl_lr: float = 0.3        # control-loop mirror-descent step size
    ctrl_iters: int = 4         # mirror-descent steps per refresh
    block_size: int | str = 1   # scan engine: events per micro-block (1 =
                                # per-event replay; E > 1 batches gathers /
                                # gradients / scatters over conflict-free
                                # blocks — exact, see engine_scan; "auto"
                                # selects E from the measured conflict rates
                                # via queue_sim.select_block_size)
    devices: int = 1            # blocked scan engine: lane-shard device count
                                # — each micro-block's E gradient lanes split
                                # across this many devices (requires
                                # block_size a >1 multiple of it; 1 = off)
    segmentation: str = "greedy"  # blocked cut placement: "greedy" (maximal
                                  # extension) | "dp" (exact minimum-padding
                                  # DP) — queue_sim.segment_blocks
    snapshot_dtype: str | None = None  # scan engine: ring-buffer storage dtype
                                       # (e.g. "bfloat16"; None = param dtype)
    pallas_interpret: bool = True  # update="pallas": run the kernels in
                                   # interpret mode (True = CPU/debug; set
                                   # False on real TPU/GPU backends)
    collect_extras: bool = True  # scan engine: accumulate queue statistics /
                                 # p-trajectory extras (False prunes them from
                                 # the compiled program — benchmark runs)
    faults: "FaultConfig | None" = None  # client churn / crash / straggler
                                 # injection (queue_sim.FaultConfig); both
                                 # engines and both streams honor it — non-
                                 # completion events apply no update and
                                 # re-dispatch with the current weights
    guard: Any | None = None     # engine_scan.GuardConfig: reject non-finite /
                                 # norm-exploding gradients and (optionally)
                                 # updates staler than stale_cutoff CS steps
    ckpt_dir: str | None = None  # scan engine: checkpoint directory — routes
                                 # the run through repro.core.engine_ckpt,
                                 # snapshotting the full engine carry every
                                 # ckpt_every CS steps (kill-and-resume safe)
    ckpt_every: int = 0          # checkpoint cadence in CS steps
    resume: bool = False         # resume from the latest checkpoint in
                                 # ckpt_dir (config-fingerprint validated)
    serving: Any | None = None   # serving.ServingConfig: merge an open Poisson
                                 # inference stream into the device-stream
                                 # event race — requests are served from the
                                 # snapshot ring (last known-good iterate)
                                 # without blocking the update scan; serve_*
                                 # counters land in TraceRecord.extras
    scenario: Any | None = None  # scenario.ScenarioConfig (or a registry
                                 # name): phase-type service + Markov-
                                 # modulated availability.  Mutually
                                 # exclusive with `faults`; both engines
                                 # honor it — stage advances / flips apply
                                 # no update, exactly like fault events


@dataclass
class TraceRecord:
    steps: np.ndarray
    times: np.ndarray
    eval_steps: list[int] = field(default_factory=list)
    eval_values: list[float] = field(default_factory=list)
    delays: list[list[int]] | None = None
    mean_queue_lengths: np.ndarray | None = None
    virtual_gap_sq: list[float] = field(default_factory=list)
    inflight_cardinality: list[int] = field(default_factory=list)
    extras: dict = field(default_factory=dict)  # device stream: p_final,
                                                # p_traj, delay_sum, comp, ...


def _resolve(cfg: ServerConfig) -> tuple[np.ndarray, np.ndarray]:
    p = np.full(cfg.n, 1.0 / cfg.n) if cfg.p is None else np.asarray(cfg.p, float)
    mu = np.ones(cfg.n) if cfg.mu is None else np.asarray(cfg.mu, float)
    return p, mu


def _resolve_scenario_cfg(cfg: ServerConfig):
    """``cfg.scenario`` (name | ScenarioConfig | None) -> enabled config or
    None.  A disabled scenario (exponential + always-on) resolves to None so
    every engine takes its unmodified — bitwise-identical — path."""
    if cfg.scenario is None:
        return None
    from .scenario import get_scenario

    sc = get_scenario(cfg.scenario)
    return sc if sc.enabled else None


# sparse="auto" switches the device stream to the O(C) class-collapsed
# representation at and above this population size (below it the dense
# (n, C) path is already fast and stays the oracle)
SPARSE_AUTO_N = 50_000


def _resolve_sparse(cfg: ServerConfig, mu, p, block_size, ckpt_on):
    """Decide whether the device stream runs sparse; collapse to classes.

    Returns ``(ClassSpec, mu_m, p_m)`` — class-level rates and per-node
    sampling probabilities — or ``(None, None, None)`` to keep the dense
    path.  ``sparse=True`` raises on any unsupported combination;
    ``sparse="auto"`` silently falls back to dense (small n, too many
    distinct speed classes, blocked/sharded/checkpointed runs, or fault
    rates that vary within a class).
    """
    forced = cfg.sparse is True
    blockers = []
    if block_size != "auto" and int(block_size) > 1:
        blockers.append("block_size > 1")
    if cfg.devices > 1:
        blockers.append("devices > 1")
    if ckpt_on:
        blockers.append("checkpointing")
    if blockers:
        if forced:
            raise ValueError(
                "sparse=True does not compose with " + ", ".join(blockers)
            )
        return None, None, None
    if not forced and cfg.n < SPARSE_AUTO_N:
        return None, None, None
    from .stream_device import build_class_spec, resolve_fault_rates_classes

    try:
        spec, mu_m, p_m = build_class_spec(mu, p)
        if cfg.faults is not None and cfg.faults.enabled:
            resolve_fault_rates_classes(cfg.faults, spec)  # class-constant?
    except ValueError:
        if forced:
            raise
        return None, None, None
    return spec, np.asarray(mu_m, np.float64), np.asarray(p_m, np.float64)


def _expand_class_extras(extras: dict, classes) -> dict:
    """Expand (m,) class-level runner extras back to per-client (n,) arrays.

    Per-node quantities (`p_final`, `p_traj`) gather through ``inv_cls``;
    class totals (`occ_mean`, `busy_time`, `comp`, ...) divide by the class
    size first — within a class clients are exchangeable, so the per-client
    expectation is the class total over the class count.  `mean_delays` is
    computed at class level (class totals keep the ratio exact) and
    gathered.
    """
    inv = np.asarray(classes.inv_cls)
    cnt = np.asarray(classes.counts, np.float64)
    out = {k: np.asarray(v) for k, v in extras.items()}
    if "p_final" in out:
        out["p_final"] = out["p_final"][inv]
    if "p_traj" in out:
        out["p_traj"] = out["p_traj"][..., inv]
    if "comp" in out and "delay_sum" in out:
        out["mean_delays"] = (
            np.asarray(out["delay_sum"], np.float64)
            / np.maximum(np.asarray(out["comp"], np.float64), 1.0)
        )[inv]
    for k in ("occ_mean", "occ_time_avg", "busy_time", "delay_sum", "comp",
              "avail_time"):
        if k in out:
            out[k] = (np.asarray(out[k], np.float64) / cnt)[inv]
    return out


def _device_grad_fn(source) -> Callable:
    """Resolve the traceable gradient fn for the scan engine."""
    fn = getattr(source, "device_grad", None)
    if fn is None and callable(source):
        fn = source
    if fn is None:
        raise TypeError(
            "engine='scan' needs a DeviceGradientSource (a `device_grad(j, w, "
            "k)` method traceable by JAX); got "
            f"{type(source).__name__} — use engine='python' for host sources."
        )
    return fn


@lru_cache(maxsize=4)
def _pallas_update_fn(interpret: bool):
    """Memoized per interpret flag: the jitted-runner memo keys on the
    update_fn *object*, so the same flag must return the same callable."""
    from functools import partial

    from ..kernels.weighted_update import tree_weighted_update

    return partial(tree_weighted_update, interpret=interpret)


#: cap for block_size="auto" selection (multiples of `devices` are tried)
DEFAULT_BLOCK_SIZE_MAX = 16
#: probe length for "auto" when no event stream is materialized (device path)
AUTO_PROBE_STEPS = 4000


def _probe_stream_slots(mu, p, C: int, T: int, seed, fault=None,
                        scenario=None) -> np.ndarray:
    """Short device-generated probe stream for block-size auto-selection.

    The fused engine never materializes its event stream, so ``"auto"`` on
    the device path measures conflict rates on a (law-identical) probe of at
    most `AUTO_PROBE_STEPS` CS steps from `stream_device.generate_stream`.
    Shared by `_run_scan` and `fl.run_matrix` so both resolve "auto"
    identically.  The probe must draw from the *configured* stream — a
    faultless/exponential probe under faults or a scenario would understate
    slot-conflict rates (stage/flip events carry the trash slot C, which
    never conflicts) and bias ``block_size="auto"``.
    """
    from .stream_device import generate_stream

    return generate_stream(
        mu, p, C, min(T, AUTO_PROBE_STEPS), seed=seed, fault=fault,
        scenario=scenario,
    ).slot


def _auto_block_size(slots, devices: int = 1, cut_every: int = 0) -> int:
    """Resolve ``block_size="auto"`` from measured conflict-free run lengths
    (`queue_sim.select_block_size`), scaled to multiples of ``devices``.
    ``slots`` is one measured (T,) slot array or a list of them."""
    from .queue_sim import select_block_size

    E, _ = select_block_size(
        slots,
        block_size_max=max(DEFAULT_BLOCK_SIZE_MAX, devices),
        devices=max(devices, 1),
        cut_every=cut_every,
        # greedy and DP cuts have identical block counts (hereditary
        # validity — locked by tests), so the cheaper single pass suffices
        # for the utilization measurements
        method="greedy",
    )
    return E


def _scan_update_fn(cfg: ServerConfig):
    if cfg.apply_update is not None:
        return cfg.apply_update
    if cfg.update == "pallas":
        return _pallas_update_fn(cfg.pallas_interpret)
    if cfg.update != "jnp":
        raise ValueError(cfg.update)
    return None  # engine default: w - scale*g


def _run_scan(
    w0: Pytree,
    source,
    cfg: ServerConfig,
    eval_fn,
    p: np.ndarray,
    mu: np.ndarray,
    *,
    fedbuff_Z: int = 0,
) -> tuple[Pytree, TraceRecord]:
    """Shared scan-engine driver for Generalized AsyncSGD and FedBuff.

    ``cfg.stream`` picks the event source: "host" pre-simulates the event
    stream with `queue_sim.export_stream` and replays it (the parity
    oracle); "device" generates it inside the compiled program
    (`engine_scan.make_fused_runner`) — zero host pre-simulation, and the
    only mode supporting ``cfg.adaptive`` sampling.
    """
    import jax
    import jax.numpy as jnp

    from .engine_scan import (
        blocked_inputs,
        jit_fused_runner,
        jit_runner,
        step_scales,
        stream_arrays,
    )
    from .queue_sim import EventBlocks

    if cfg.track_virtual:
        raise NotImplementedError("track_virtual requires engine='python'")
    weighting = "plain" if fedbuff_Z else cfg.weighting
    faults = cfg.faults if (cfg.faults is not None and cfg.faults.enabled) else None
    scenario = _resolve_scenario_cfg(cfg)
    if scenario is not None:
        if faults is not None:
            raise ValueError(
                "scenario= and faults= are separate injection paths; model "
                "suspension via ScenarioConfig modulation (rate_scale)"
            )
        if fedbuff_Z:
            raise ValueError(
                "scenario= composes with Algorithm 1, not FedBuff"
            )
        if cfg.service != "exp":
            raise ValueError(
                "scenario= replaces the service law; leave service='exp'"
            )
    guard = cfg.guard
    guard_stale = guard is not None and int(guard.stale_cutoff) > 0
    ckpt_on = cfg.ckpt_dir is not None
    if ckpt_on and cfg.ckpt_every <= 0:
        raise ValueError("ckpt_dir requires ckpt_every > 0")
    serving = cfg.serving if (cfg.serving is not None and cfg.serving.enabled) else None
    if serving is not None and cfg.stream != "device":
        raise ValueError(
            "serving requires stream='device' (the open arrival stream is "
            "merged into the on-device event race)"
        )
    if fedbuff_Z and (faults is not None or guard_stale):
        raise ValueError(
            "fault injection / staleness cutoff compose with Algorithm 1, "
            "not FedBuff (the buffer flush has no per-event masking)"
        )
    w0_dev = _tree_map(jnp.asarray, w0)
    eval_every = cfg.eval_every if eval_fn is not None else 0
    # the event-stream arrays are freshly built per run, so hand their
    # buffers to the compiled program; CPU cannot donate them (warns), so
    # keep donation to accelerator backends
    donate = jax.default_backend() != "cpu"
    block_size = cfg.block_size
    if block_size != "auto" and int(block_size) > 1 and cfg.apply_update is not None:
        raise ValueError(
            "block_size > 1 requires the default update w - scale*g"
        )

    if cfg.stream == "device":
        if cfg.service != "exp":
            raise ValueError(
                "stream='device' supports exponential service only "
                "(the on-device race relies on memorylessness)"
            )
        classes = class_mu = class_p = None
        if cfg.sparse is True and serving is not None:
            raise ValueError(
                "serving composes with the dense stream only (the serve "
                "read path indexes the dense snapshot ring)"
            )
        if scenario is not None:
            if cfg.sparse is True:
                raise ValueError(
                    "the fused engine's scenario path is dense-only; use "
                    "sparse_stats_stream_fn(scenario=True) for class-level "
                    "laws"
                )
            if serving is not None:
                raise ValueError("scenario= does not compose with serving=")
            if ckpt_on:
                raise ValueError(
                    "scenario= does not compose with checkpointing yet"
                )
            if block_size == "auto":
                block_size = 1  # scenario stream is per-event
            elif int(block_size) > 1:
                raise ValueError("scenario= requires block_size=1")
        elif (cfg.sparse is True or cfg.sparse == "auto") and serving is None:
            classes, class_mu, class_p = _resolve_sparse(
                cfg, mu, p, block_size, ckpt_on
            )
        elif cfg.sparse not in (False, "auto"):
            raise ValueError(f"sparse={cfg.sparse!r} (expected bool or 'auto')")
        if classes is not None:
            block_size = 1  # sparse stream is per-event; skip the auto probe
        if block_size == "auto":
            block_size = _auto_block_size(
                _probe_stream_slots(mu, p, cfg.C, cfg.T, cfg.seed,
                                    fault=faults),
                cfg.devices,
            )
        if ckpt_on:
            from .engine_ckpt import run_checkpointed

            if cfg.devices > 1:
                raise ValueError(
                    "checkpointing does not compose with lane sharding — "
                    "checkpoint the unsharded run"
                )
            if fedbuff_Z or _scan_update_fn(cfg) is not None:
                raise ValueError(
                    "the checkpointed fused engine supports the default "
                    "update w - scale*g with fedbuff_Z=0"
                )
            w, evals, ck_extras = run_checkpointed(
                _device_grad_fn(source), cfg.n, cfg.C, cfg.T,
                w0=w0_dev, mu=mu, p0=p, key=jax.random.PRNGKey(cfg.seed),
                eta=cfg.eta, ckpt_dir=cfg.ckpt_dir, ckpt_every=cfg.ckpt_every,
                weighting=weighting, eval_fn=eval_fn, eval_every=eval_every,
                adaptive=cfg.adaptive, refresh_every=cfg.refresh_every,
                ctrl_lr=cfg.ctrl_lr, ctrl_iters=cfg.ctrl_iters,
                block_size=int(block_size), snapshot_dtype=cfg.snapshot_dtype,
                fault=faults, guard=guard, serving=serving, resume=cfg.resume,
            )
            w = jax.block_until_ready(w)
            # the chunked driver keeps no per-step clock (only the final t)
            trace = TraceRecord(
                steps=np.arange(cfg.T), times=np.full(cfg.T, np.nan)
            )
            trace.extras = {
                k: np.asarray(v) for k, v in ck_extras.items()
            }
            if eval_fn is not None and cfg.eval_every:
                n_evals = np.asarray(evals).shape[0]
                trace.eval_steps = [
                    (i + 1) * cfg.eval_every for i in range(n_evals)
                ]
                trace.eval_values = [float(v) for v in np.asarray(evals)]
            return w, trace
        runner = jit_fused_runner(
            _device_grad_fn(source),
            cfg.n,
            cfg.C,
            cfg.T,
            weighting=weighting,
            fedbuff_Z=fedbuff_Z,
            eval_fn=eval_fn,
            eval_every=eval_every,
            adaptive=cfg.adaptive,
            refresh_every=cfg.refresh_every,
            ctrl_lr=cfg.ctrl_lr,
            ctrl_iters=cfg.ctrl_iters,
            update_fn=_scan_update_fn(cfg),
            block_size=block_size,
            snapshot_dtype=cfg.snapshot_dtype,
            collect_extras=cfg.collect_extras,
            lane_devices=cfg.devices,
            fault=faults,
            guard=guard,
            classes=classes,
            serving=serving,
            scenario=scenario,
        )
        run_mu = mu if classes is None else class_mu
        run_p = p if classes is None else class_p
        w, evals, extras = runner(
            w0_dev, jnp.asarray(run_mu), jnp.asarray(run_p),
            jax.random.PRNGKey(cfg.seed), cfg.eta,
        )
        w = jax.block_until_ready(w)
        if classes is not None:
            extras = _expand_class_extras(extras, classes)
        times = (
            np.asarray(extras["t"], np.float64)
            if "t" in extras
            # collect_extras=False prunes the per-step clock: make misuse
            # loud (NaN propagates) instead of fabricating t=0 timestamps
            else np.full(cfg.T, np.nan)
        )
        trace = TraceRecord(steps=np.arange(cfg.T), times=times)
        trace.extras = {"p_final": np.asarray(extras["p_final"], np.float64)}
        for name in ("guard_rejects", "stale_drops", "kind_count", "avail_time"):
            if name in extras:
                trace.extras[name] = np.asarray(extras[name])
        for name in extras:
            if name.startswith("serve_"):
                trace.extras[name] = np.asarray(extras[name])
        if "occ_mean" in extras:
            trace.mean_queue_lengths = np.asarray(extras["occ_mean"], np.float64)
            comp = np.asarray(extras["comp"], np.float64)
            mean_delays = (
                np.asarray(extras["mean_delays"], np.float64)
                if "mean_delays" in extras  # sparse: exact class-level ratio
                else np.asarray(extras["delay_sum"], np.float64)
                / np.maximum(comp, 1.0)
            )
            trace.extras.update(
                p_traj=np.asarray(extras["p_traj"], np.float64),
                mean_delays=mean_delays,
                comp=comp,
                busy_time=np.asarray(extras["busy_time"], np.float64),
            )
    else:
        if cfg.stream != "host":
            raise ValueError(cfg.stream)
        if cfg.adaptive:
            raise ValueError("adaptive sampling requires stream='device'")
        stream = export_stream(
            SimConfig(mu=mu, p=p, C=cfg.C, T=cfg.T, service=cfg.service,
                      seed=cfg.seed,
                      record_delays=cfg.collect_extras or guard_stale,
                      fault=faults, scenario=scenario)
        )
        scale = step_scales(stream, cfg.eta, p, weighting)
        host_stale_drops = 0
        if guard_stale:
            # the host replay enforces the staleness cutoff here, where the
            # exported per-event delays live — the in-scan counter (gcnt[1])
            # stays 0 on host paths and the drop count rides trace.extras
            stale = (stream.delay_steps > int(guard.stale_cutoff)) & (scale != 0)
            host_stale_drops = int(stale.sum())
            scale = np.where(stale, 0.0, scale).astype(scale.dtype)
        if cfg.update not in ("jnp", "pallas"):
            raise ValueError(cfg.update)
        kernel = cfg.update
        if block_size == "auto":
            block_size = _auto_block_size(
                stream.slot, cfg.devices, cut_every=eval_every
            )
        if block_size > 1 and cfg.apply_update is not None:
            # re-check after "auto" resolution: the blocked replay only
            # reconstructs iterates for the default update w - scale*g
            raise ValueError(
                "block_size > 1 requires the default update w - scale*g"
            )
        gcnt = None
        if block_size > 1:
            group_events = eval_every
            if ckpt_on:
                # checkpoint cursors need exact event counts per row group,
                # which only the grouped (cut_every) layout provides
                group_events = eval_every if eval_every else min(
                    cfg.ckpt_every, cfg.T
                )
            blocks = EventBlocks.from_stream(
                stream, block_size, cut_every=group_events,
                method=cfg.segmentation,
            )
            J, slot, sc, kb, mask, chunk_blocks, n_chunks = blocked_inputs(
                blocks, scale, group_events
            )
            if ckpt_on:
                from .engine_ckpt import run_checkpointed_host_blocked

                if cfg.devices > 1:
                    raise ValueError(
                        "checkpointing does not compose with lane sharding"
                    )
                out = run_checkpointed_host_blocked(
                    _device_grad_fn(source), cfg.C, int(block_size),
                    w0_dev, J, slot, sc, kb, mask,
                    group_events=group_events, chunk_blocks=chunk_blocks,
                    n_chunks=n_chunks, ckpt_dir=cfg.ckpt_dir,
                    ckpt_every=cfg.ckpt_every, eval_fn=eval_fn,
                    kernel=kernel, interpret=cfg.pallas_interpret,
                    snapshot_dtype=cfg.snapshot_dtype, fedbuff_Z=fedbuff_Z,
                    guard=guard, resume=cfg.resume,
                )
            else:
                runner = jit_runner(
                    _device_grad_fn(source),
                    cfg.C,
                    fedbuff_Z=fedbuff_Z,
                    eval_fn=eval_fn,
                    block_size=block_size,
                    kernel=kernel,
                    snapshot_dtype=cfg.snapshot_dtype,
                    donate=donate,
                    interpret=cfg.pallas_interpret,
                    lane_devices=cfg.devices,
                    guard=guard,
                )
                out = runner(
                    w0_dev, jnp.asarray(J), jnp.asarray(slot), jnp.asarray(sc),
                    jnp.asarray(kb), jnp.asarray(mask),
                    chunk_blocks=chunk_blocks, n_chunks=n_chunks,
                )
        else:
            if cfg.devices > 1:
                raise ValueError(
                    "devices > 1 lane-shards micro-blocks and requires the "
                    "blocked engine (block_size > 1)"
                )
            if ckpt_on:
                from .engine_ckpt import run_checkpointed_host

                out = run_checkpointed_host(
                    _device_grad_fn(source), cfg.C, w0_dev,
                    stream.J, stream.slot, scale,
                    ckpt_dir=cfg.ckpt_dir, ckpt_every=cfg.ckpt_every,
                    eval_fn=eval_fn, eval_every=eval_every,
                    fedbuff_Z=fedbuff_Z, update_fn=_scan_update_fn(cfg),
                    snapshot_dtype=cfg.snapshot_dtype, guard=guard,
                    resume=cfg.resume,
                )
            else:
                runner = jit_runner(
                    _device_grad_fn(source),
                    cfg.C,
                    fedbuff_Z=fedbuff_Z,
                    eval_fn=eval_fn,
                    eval_every=eval_every,
                    update_fn=_scan_update_fn(cfg),
                    donate=donate,
                    guard=guard,
                )
                J_dev, slot_dev = stream_arrays(stream)
                out = runner(w0_dev, J_dev, slot_dev, jnp.asarray(scale))
        if guard is not None:
            w, evals, gcnt = out
        else:
            w, evals = out
        w = jax.block_until_ready(w)
        trace = TraceRecord(steps=np.arange(cfg.T), times=np.asarray(stream.t))
        trace.delays = stream.delays
        trace.mean_queue_lengths = (
            stream.queue_len_sum / cfg.T
            if stream.queue_len_sum is not None else None
        )
        if guard is not None:
            gcnt = np.asarray(gcnt)
            trace.extras["guard_rejects"] = int(gcnt[0])
            trace.extras["stale_drops"] = int(gcnt[1]) + host_stale_drops
        if stream.kind is not None and (faults is not None or scenario is not None):
            trace.extras["kind_count"] = np.bincount(
                stream.kind, minlength=6 if scenario is not None else 4
            )

    if eval_fn is not None and cfg.eval_every:
        n_evals = np.asarray(evals).shape[0]
        trace.eval_steps = [(i + 1) * cfg.eval_every for i in range(n_evals)]
        trace.eval_values = [float(v) for v in np.asarray(evals)]
    return w, trace


def run_generalized_async_sgd(
    w0: Pytree,
    source: GradientSource,
    cfg: ServerConfig,
    eval_fn: Callable[[Pytree], float] | None = None,
) -> tuple[Pytree, TraceRecord]:
    """Algorithm 1.  Returns final parameters and the execution trace.

    With ``cfg.engine == "scan"``, `source` must be a DeviceGradientSource
    and `eval_fn` (if given) must be traceable — pure JAX ops returning a
    device scalar, since it runs inside the compiled program.  The "python"
    engine accepts any host callable returning a float.
    """
    p, mu = _resolve(cfg)
    if cfg.engine == "scan":
        return _run_scan(w0, source, cfg, eval_fn, p, mu)
    if cfg.engine != "python":
        raise ValueError(cfg.engine)
    if cfg.stream == "device" or cfg.adaptive:
        raise ValueError("stream='device' / adaptive require engine='scan'")
    if cfg.ckpt_dir is not None:
        raise ValueError("checkpointing requires engine='scan'")
    scenario = _resolve_scenario_cfg(cfg)
    sim = ClosedNetworkSim(
        SimConfig(mu=mu, p=p, C=cfg.C, T=cfg.T, service=cfg.service,
                  seed=cfg.seed, record_delays=True, fault=cfg.faults,
                  scenario=scenario)
    )
    apply_update = cfg.apply_update or (lambda w, g, s: _axpy(w, g, -s))
    faults_on = cfg.faults is not None and cfg.faults.enabled
    if faults_on or cfg.guard is not None or scenario is not None:
        if cfg.track_virtual:
            raise NotImplementedError(
                "track_virtual does not compose with faults/guards/scenarios"
            )
        return _python_fault_loop(w0, source, cfg, eval_fn, p, sim,
                                  apply_update)

    w = w0
    mu_virtual = w0 if cfg.track_virtual else None
    # dispatch-time parameter snapshot per client FIFO queue (mirrors sim.queues)
    snaps: list[deque] = [deque(w0 for _ in q) for q in sim.queues]

    times = np.zeros(cfg.T)
    steps = np.arange(cfg.T)
    trace = TraceRecord(steps=steps, times=times)
    if cfg.track_virtual:
        import jax

    for k in range(cfg.T):
        j, k_new = sim.step()          # J_k completes; K_{k+1} sampled; task enqueued
        w_disp = snaps[j].popleft()    # FIFO: the completed task's dispatch params
        g = source.grad(j, w_disp, k)
        if cfg.weighting == "importance":
            scale = cfg.eta / (cfg.n * p[j])
        elif cfg.weighting == "plain":
            scale = cfg.eta
        else:
            raise ValueError(cfg.weighting)
        w = apply_update(w, g, scale)
        snaps[k_new].append(w)    # the new task departs with the *updated* model
        times[k] = sim.now

        if cfg.track_virtual:
            # mu_{k+1} = mu_k - eta/(n p_{K_k}) g_{K_k}(w_k): instantaneous
            # contribution of the *newly sampled* client at the current w.
            g_virt = source.grad(k_new, w, k)
            mu_virtual = _axpy(mu_virtual, g_virt, -cfg.eta / (cfg.n * p[k_new]))
            gap = _tree_map(lambda a, b: float(np.sum((np.asarray(a) - np.asarray(b)) ** 2)), w, mu_virtual)
            trace.virtual_gap_sq.append(sum(jax.tree_util.tree_leaves(gap)))
            trace.inflight_cardinality.append(sim.total_tasks())

        if eval_fn is not None and cfg.eval_every and (k + 1) % cfg.eval_every == 0:
            trace.eval_steps.append(k + 1)
            trace.eval_values.append(float(eval_fn(w)))

    trace.delays = sim.delays
    trace.mean_queue_lengths = sim.queue_len_sum / cfg.T
    return w, trace


def _python_fault_loop(
    w0: Pytree,
    source: GradientSource,
    cfg: ServerConfig,
    eval_fn,
    p: np.ndarray,
    sim: ClosedNetworkSim,
    apply_update,
) -> tuple[Pytree, TraceRecord]:
    """Fault/guard-aware reference loop — the parity oracle of the scan
    engines' fault semantics.

    One iteration consumes one *merged* CTMC event (`ClosedNetworkSim.
    step_event`): completions compute the gradient at the dispatch-time
    snapshot and (guards permitting) apply it; crashes and straggler
    timeouts discard the in-flight work and re-dispatch the freed slot with
    the *current* server weights; availability flips touch no task.  The
    guard ordering (staleness before divergence, rejects counted once)
    matches `engine_scan._make_flat_guard` exactly, with the staleness clock
    being merged-event steps — the same counter the device stream scans
    over.
    """
    import jax

    guard = cfg.guard
    max_sq = float(guard.max_grad_norm) ** 2 if guard is not None else 0.0
    cutoff = int(guard.stale_cutoff) if guard is not None else 0
    gcnt = [0, 0]  # [guard_rejects, stale_drops]
    w = w0
    # per-node FIFO of (dispatch-time snapshot, dispatch step + 1)
    snaps: list[deque] = [deque((w0, 0) for _ in q) for q in sim.queues]
    times = np.zeros(cfg.T)
    trace = TraceRecord(steps=np.arange(cfg.T), times=times)
    for k in range(cfg.T):
        kind, j, k_new = sim.step_event()
        times[k] = sim.now
        if kind == KIND_FLIP or kind == KIND_STAGE:
            # no task moved: flips touch no queue, stage advances keep the
            # head task in service — no snapshot pop, no update
            continue
        w_disp, disp_k = snaps[j].popleft()
        if kind == KIND_COMPLETE:
            live = True
            if cutoff and (k - disp_k) > cutoff:
                gcnt[1] += 1
                live = False
            if live:
                g = source.grad(j, w_disp, k)
                if guard is not None:
                    sq = sum(
                        float(np.sum(np.square(np.asarray(x, np.float32))))
                        for x in jax.tree_util.tree_leaves(g)
                    )
                    if not np.isfinite(sq) or (max_sq > 0.0 and sq > max_sq):
                        gcnt[0] += 1
                        live = False
            if live:
                if cfg.weighting == "importance":
                    scale = cfg.eta / (cfg.n * p[j])
                elif cfg.weighting == "plain":
                    scale = cfg.eta
                else:
                    raise ValueError(cfg.weighting)
                w = apply_update(w, g, scale)
        # crash/timeout: work discarded; slot re-dispatched at current w
        snaps[k_new].append((w, k + 1))
        if eval_fn is not None and cfg.eval_every and (k + 1) % cfg.eval_every == 0:
            trace.eval_steps.append(k + 1)
            trace.eval_values.append(float(eval_fn(w)))
    trace.delays = sim.delays
    trace.mean_queue_lengths = sim.queue_len_sum / cfg.T
    trace.extras = {
        "guard_rejects": gcnt[0],
        "stale_drops": gcnt[1],
        "kind_count": np.asarray(sim.kind_counts)
        if getattr(sim, "_fault", False) or getattr(sim, "_scenario", False)
        else None,
    }
    return w, trace


def run_fedbuff(
    w0: Pytree,
    source: GradientSource,
    cfg: ServerConfig,
    Z: int = 10,
    eval_fn: Callable[[Pytree], float] | None = None,
) -> tuple[Pytree, TraceRecord]:
    """FedBuff (Nguyen et al. 2022): uniform sampling, server applies the
    *average* of a buffer of Z received gradients.  The buffer fill shares the
    same queueing clock; the CS performs T//Z buffered updates over T
    completions."""
    p, mu = _resolve(cfg)
    pu = np.full(cfg.n, 1.0 / cfg.n)  # FedBuff samples uniformly
    if cfg.engine == "scan":
        return _run_scan(w0, source, cfg, eval_fn, pu, mu, fedbuff_Z=Z)
    if cfg.engine != "python":
        raise ValueError(cfg.engine)
    if cfg.stream == "device" or cfg.adaptive:
        raise ValueError("stream='device' / adaptive require engine='scan'")
    if ((cfg.faults is not None and cfg.faults.enabled) or cfg.guard is not None
            or _resolve_scenario_cfg(cfg) is not None):
        raise ValueError(
            "faults/guards/scenarios compose with Algorithm 1 "
            "(run_generalized_async_sgd), not the FedBuff reference loop"
        )
    if cfg.ckpt_dir is not None:
        raise ValueError("checkpointing requires engine='scan'")
    sim = ClosedNetworkSim(
        SimConfig(mu=mu, p=pu, C=cfg.C, T=cfg.T, service=cfg.service,
                  seed=cfg.seed, record_delays=True)
    )
    apply_update = cfg.apply_update or (lambda w, g, s: _axpy(w, g, -s))
    w = w0
    snaps: list[deque] = [deque(w0 for _ in q) for q in sim.queues]
    buffer: list[Pytree] = []
    times = np.zeros(cfg.T)
    trace = TraceRecord(steps=np.arange(cfg.T), times=times)
    updates = 0
    for k in range(cfg.T):
        j, k_new = sim.step()
        w_disp = snaps[j].popleft()
        buffer.append(source.grad(j, w_disp, k))
        if len(buffer) >= Z:
            g_mean = buffer[0]
            for g in buffer[1:]:
                g_mean = _axpy(g_mean, g, 1.0)
            g_mean = _tree_map(lambda x: x / len(buffer), g_mean)
            w = apply_update(w, g_mean, cfg.eta)
            buffer = []
            updates += 1
        snaps[k_new].append(w)
        times[k] = sim.now
        if eval_fn is not None and cfg.eval_every and (k + 1) % cfg.eval_every == 0:
            trace.eval_steps.append(k + 1)
            trace.eval_values.append(float(eval_fn(w)))
    trace.delays = sim.delays
    trace.mean_queue_lengths = sim.queue_len_sum / cfg.T
    return w, trace


def run_fedavg(
    w0: Pytree,
    source: GradientSource,
    cfg: ServerConfig,
    clients_per_round: int = 10,
    local_steps: int = 1,
    eval_fn: Callable[[Pytree], float] | None = None,
) -> tuple[Pytree, TraceRecord]:
    """Synchronous FedAvg baseline.  Each round waits for the slowest sampled
    client (round time = max of their service draws); `cfg.T` counts rounds."""
    _, mu = _resolve(cfg)
    rng = np.random.default_rng(cfg.seed)
    apply_update = cfg.apply_update or (lambda w, g, s: _axpy(w, g, -s))
    w = w0
    now = 0.0
    times = np.zeros(cfg.T)
    trace = TraceRecord(steps=np.arange(cfg.T), times=times)
    for r in range(cfg.T):
        sel = rng.choice(cfg.n, size=clients_per_round, replace=False)
        # round wall time = slowest client's total local work
        if cfg.service == "exp":
            durs = rng.exponential(1.0 / mu[sel], size=sel.size) * local_steps
        else:
            durs = local_steps / mu[sel]
        now += float(np.max(durs))
        g_mean = None
        for i in sel:
            g = source.grad(int(i), w, r)
            for _ in range(local_steps - 1):
                g = _axpy(g, source.grad(int(i), _axpy(w, g, -cfg.eta), r), 1.0)
            g_mean = g if g_mean is None else _axpy(g_mean, g, 1.0)
        g_mean = _tree_map(lambda x: x / sel.size, g_mean)
        w = apply_update(w, g_mean, cfg.eta)
        times[r] = now
        if eval_fn is not None and cfg.eval_every and (r + 1) % cfg.eval_every == 0:
            trace.eval_steps.append(r + 1)
            trace.eval_values.append(float(eval_fn(w)))
    return w, trace


def run_favano(
    w0: Pytree,
    source: GradientSource,
    cfg: ServerConfig,
    period: float = 1.0,
    max_local_steps: int = 8,
    eval_fn: Callable[[Pytree], float] | None = None,
) -> tuple[Pytree, TraceRecord]:
    """FAVANO/QuAFL-style baseline (Leconte et al. 2023; Zakerinia et al. 2022).

    No queues: the CS ticks at a fixed cadence `period`; between ticks each
    client performs as many local SGD steps as its speed allows (capped at
    `max_local_steps`, interruptible), and the CS averages the client models.
    The CS step rate is bounded by the cadence — the contrast the paper draws
    against queue-driven AsyncSGD (§5).  `cfg.T` counts CS rounds.
    """
    p, mu = _resolve(cfg)
    rng = np.random.default_rng(cfg.seed)
    apply_update = cfg.apply_update or (lambda w, g, s: _axpy(w, g, -s))
    w = w0
    locals_ = [w0 for _ in range(cfg.n)]
    now = 0.0
    times = np.zeros(cfg.T)
    trace = TraceRecord(steps=np.arange(cfg.T), times=times)
    for r in range(cfg.T):
        now += period
        for i in range(cfg.n):
            # local steps completed within the window (speed-proportional)
            n_i = min(int(rng.poisson(mu[i] * period)), max_local_steps)
            wi = locals_[i]
            for _ in range(n_i):
                wi = apply_update(wi, source.grad(i, wi, r), cfg.eta)
            locals_[i] = wi
        # CS averages client models and broadcasts
        w = _tree_mean(locals_)
        locals_ = [w for _ in range(cfg.n)]
        times[r] = now
        if eval_fn is not None and cfg.eval_every and (r + 1) % cfg.eval_every == 0:
            trace.eval_steps.append(r + 1)
            trace.eval_values.append(float(eval_fn(w)))
    return w, trace


def _tree_mean(trees: list) -> Pytree:
    out = trees[0]
    for t in trees[1:]:
        out = _axpy(out, t, 1.0)
    return _tree_map(lambda x: x / len(trees), out)
