"""Checkpointed engine drivers: kill-and-resume with a bitwise guarantee.

The scan engines of `engine_scan` run as one compiled call — a SIGKILL loses
everything.  This module drives the same per-event / blocked machinery
chunk-at-a-time from the host and snapshots the COMPLETE carry at chunk
boundaries through `repro.ckpt.checkpoint`:

  * the parameter vector, the snapshot ring buffer (fp32 or the bf16 codec —
    stored bit-exactly via the checkpoint layer's uint view), the FedBuff
    accumulator and the guard counters (``ucarry``),
  * the closed-network `StreamState` (occupancy, FIFO ring, head/tail
    cursors, clock, availability) and `StatsState` (rate accumulators the
    adaptive controller feeds on),
  * the controller state: sampling vector p, its dispatch CDF and the
    per-slot dispatch-time importance scales,
  * the eval curve so far and the event cursor.

Determinism contract: the fused driver derives each chunk's uniforms from
the run key via ``jax.random.fold_in(key_part, chunk_index)`` — nothing
depends on *when* a chunk executes, so restoring the latest checkpoint and
continuing reproduces the uninterrupted checkpointed run bit for bit (the
host drivers replay pre-simulated event arrays, which are deterministic by
construction).  Note the fold-in chunking is a different (equally valid)
draw order than `make_fused_runner`'s single upfront ``(T,)`` draw, so the
checkpointed fused engine is its own deterministic trajectory; its law is
identical.

A config fingerprint is stored in every checkpoint's metadata and validated
on ``resume=True`` — resuming under a different engine configuration is an
error, not a silent divergence.
"""
from __future__ import annotations

import hashlib
import json
from typing import Any, Callable

import numpy as np

from .engine_scan import (
    GuardConfig,
    _default_update,
    _init_update_carry,
    _make_block_step,
    _make_fused_advance,
    _make_update_step,
    _runner_cache,
    _snapshot_codec,
)
from .queue_sim import FaultConfig
from .theory import BoundConstants

__all__ = [
    "run_checkpointed",
    "run_checkpointed_host",
    "run_checkpointed_host_blocked",
]


# ------------------------------------------------------------------ #
# shared checkpoint plumbing
# ------------------------------------------------------------------ #
def _fingerprint(kind: str, fields: dict) -> str:
    """Stable config fingerprint for resume validation."""
    blob = json.dumps({"kind": kind, **fields}, sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()


def _array_digest(*arrays) -> str:
    h = hashlib.sha1()
    for a in arrays:
        a = np.ascontiguousarray(np.asarray(a))
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def _key_fingerprint(key) -> list:
    import jax

    try:
        kd = np.asarray(jax.random.key_data(key))
    except Exception:
        kd = np.asarray(key)
    return [int(x) for x in np.ravel(kd)]


def _resume_state(ckpt_dir: str, like, fingerprint: str):
    """Latest checkpoint tree (validated against ``fingerprint``) or None."""
    from ..ckpt import checkpoint as ck

    step = ck.latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(
            f"resume=True but no checkpoint found under {ckpt_dir!r}"
        )
    meta = ck.load_metadata(ckpt_dir, step)
    if meta.get("fingerprint") != fingerprint:
        raise ValueError(
            "checkpoint/config mismatch: the run under "
            f"{ckpt_dir!r} was written by a different engine configuration "
            "(refusing to resume into a divergent trajectory)"
        )
    return ck.restore(ckpt_dir, step, like), step


def _save_state(ckpt_dir: str, step: int, tree, fingerprint: str, keep: int):
    import jax

    from ..ckpt import checkpoint as ck

    tree = jax.tree_util.tree_map(np.asarray, tree)
    ck.save(
        ckpt_dir, step, tree,
        metadata={"fingerprint": fingerprint, "events_done": step},
        keep=keep,
    )


class _AsyncSaver:
    """Background checkpoint writer for the chunked drivers.

    Intermediate saves overlap the next chunk's compute: jax arrays are
    immutable, so the worker thread can run the device->host transfer and
    the atomic `checkpoint.save` (tmp dir + rename) while the main thread
    dispatches ahead.  Saves stay strictly ordered (single worker, FIFO
    queue); a SIGKILL mid-write leaves only an ignored ``.tmp_ckpt_*``
    directory, so resume falls back to the last *completed* step — the
    same guarantee as synchronous saving, minus the wall-clock stall.
    ``close()`` drains the queue and re-raises the first worker failure;
    the drivers call it before returning, so the final checkpoint is
    always on disk when the run completes.
    """

    def __init__(self, ckpt_dir: str, fingerprint: str, keep: int):
        import queue
        import threading

        self._dir, self._fp, self._keep = ckpt_dir, fingerprint, keep
        self._q: Any = queue.Queue(maxsize=2)
        self._err: BaseException | None = None
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                if self._err is None:
                    _save_state(self._dir, step, tree, self._fp, self._keep)
            except BaseException as exc:  # surfaced at put()/close()
                self._err = exc

    def put(self, step: int, carry, evals_buf: np.ndarray) -> None:
        if self._err is not None:
            # fail loudly at the *next* save after a write error (full
            # disk, permissions): reap the worker first so the failure
            # doesn't leak a thread blocked on the queue
            self.abort()
            raise self._err
        # snapshot the (host-mutable) eval buffer; the carry's jax arrays
        # are immutable and safe to hand across threads as-is
        self._q.put((step, {"carry": carry, "evals": evals_buf.copy(),
                            "cursor": np.int64(step)}))

    def abort(self) -> None:
        """Reap the worker without raising — error-path cleanup.  Safe to
        call repeatedly and after `close` (no-op once the worker exited);
        the drivers call it in a ``finally`` so an exception anywhere in
        the chunk loop never leaks the writer thread."""
        if self._worker.is_alive():
            self._q.put(None)
            self._worker.join()

    def close(self) -> None:
        self.abort()
        if self._err is not None:
            raise self._err


def _chunk_layout(T: int, ckpt_every: int, eval_every: int,
                  refresh_every: int = 0) -> int:
    """Chunk length L: refresh, eval and checkpoint all land on chunk
    boundaries, so L divides all the active cadences."""
    if ckpt_every <= 0:
        raise ValueError("ckpt_every > 0 required")
    L = min(ckpt_every, T)
    if refresh_every:
        L = min(L, refresh_every)
    if eval_every:
        L = min(L, eval_every)
    for name, every in (("refresh_every", refresh_every),
                        ("eval_every", eval_every),
                        ("ckpt_every", ckpt_every)):
        if every and every % L:
            raise ValueError(
                f"{name}={every} must be a multiple of the chunk length {L} "
                "(refresh/eval/checkpoint cadences must nest)"
            )
    return L


class _EvalBuffer:
    """NaN-padded fixed-size eval curve that rides inside the checkpoint."""

    def __init__(self, n_evals: int, restored: np.ndarray | None = None):
        if restored is not None:
            self.buf = np.array(restored, np.float32)
        else:
            self.buf = np.full(n_evals, np.nan, np.float32)
        self.n = n_evals

    def put(self, idx: int, value) -> None:
        if 0 <= idx < self.n:
            self.buf[idx] = np.float32(value)

    def curve(self) -> np.ndarray:
        return self.buf[~np.isnan(self.buf)]


# ------------------------------------------------------------------ #
# fused (device-stream) checkpointed driver
# ------------------------------------------------------------------ #
def run_checkpointed(
    grad_fn: Callable[[Any, Any, Any], Any],
    n: int,
    C: int,
    T: int,
    *,
    w0,
    mu,
    p0,
    key,
    eta,
    ckpt_dir: str,
    ckpt_every: int,
    weighting: str = "importance",
    eval_fn=None,
    eval_every: int = 0,
    adaptive: bool = False,
    refresh_every: int = 0,
    bound: BoundConstants | None = None,
    ctrl_lr: float = 0.3,
    ctrl_iters: int = 4,
    init: str = "distinct",
    unroll: int = 1,
    block_size: int = 1,
    snapshot_dtype=None,
    fault: FaultConfig | None = None,
    guard: GuardConfig | None = None,
    serving=None,
    resume: bool = False,
    keep: int = 3,
):
    """Checkpointed fused engine (host-driven chunks of the device stream).

    Same event semantics as `engine_scan.make_fused_runner` (via the shared
    `_make_fused_advance` core — faults, guards, adaptive control and the
    blocked window all compose); the outer scan is replaced by a host loop
    over L-event chunks whose uniforms derive from ``fold_in(key, chunk)``,
    with a full-carry checkpoint every ``ckpt_every`` events.  Returns
    ``(w_final, evals, extras)``.  ``resume=True`` restores the latest
    checkpoint under ``ckpt_dir`` (config-fingerprint validated) and
    continues; kill-and-resume is bitwise-identical to the uninterrupted
    call.  Lanes/scenario meshes are not supported here — checkpoint the
    per-scenario runs individually instead.
    """
    import jax
    import jax.numpy as jnp

    from . import stream_device as sd

    if weighting not in ("importance", "plain"):
        raise ValueError(weighting)
    faulty = fault is not None and fault.enabled
    guard_stale = guard is not None and int(guard.stale_cutoff) > 0
    if adaptive and refresh_every <= 0:
        raise ValueError("adaptive=True requires refresh_every > 0")
    E = max(int(block_size), 1)
    serving_on = serving is not None and serving.enabled
    if serving_on:
        from . import serving as sp

        serving.validate()
        if E > 1:
            raise ValueError("serving= requires block_size=1")
    importance = weighting == "importance"
    need_stats = True  # stats ride in the checkpoint either way
    L = _chunk_layout(T, ckpt_every, eval_every if eval_fn else 0,
                      refresh_every if adaptive else 0)
    n_chunks, tail = T // L, T % L
    eval_on = eval_fn is not None and eval_every > 0
    eval_stride = max(eval_every // L, 1) if eval_on else 0
    bound = bound if bound is not None else BoundConstants(C=C, T=T)

    update_fn, default_update = _default_update(None)
    pack, unpack, enc = _snapshot_codec(w0, snapshot_dtype)
    flat_mode = default_update and unpack is not None
    if E > 1 and not flat_mode:
        raise ValueError(
            "block_size > 1 requires all-float parameters "
            "(flat-packed snapshot storage)"
        )
    update_step = _make_update_step(
        grad_fn, 0, update_fn, pack, unpack, flat_mode, enc, guard
    )
    rows = C + 1 if E > 1 else C
    ucarry0, to_tree = _init_update_carry(
        w0, rows, pack, unpack, flat_mode, 0, enc
    )

    mu = jnp.asarray(mu, jnp.float32)
    p0 = jnp.asarray(p0, jnp.float32)
    eta = jnp.asarray(eta, jnp.float32)
    fr = sd.resolve_fault_rates(fault, n) if faulty else None
    k_init, k_race, k_exp, k_disp = jax.random.split(key, 4)
    sstate0, init_nodes = sd.stream_init(k_init, n, C, p0, init=init,
                                         fault=faulty)
    stats0 = sd.stats_init(n, C, fault=faulty)
    if importance:
        slot_scale0 = eta / (n * p0[init_nodes])
    else:
        slot_scale0 = jnp.broadcast_to(eta, (C,))
    carry0 = (ucarry0, sstate0, stats0, slot_scale0, p0, jnp.cumsum(p0))
    if serving_on:
        # serving state/counters ride inside the checkpointed carry, so
        # kill-and-resume of the serve plane is bitwise for free
        carry0 = carry0 + (sp.serve_init(serving), sp.serve_stats_init())

    # the jitted chunk is memoized on the gradient source (same idiom as
    # jit_runner/jit_fused_runner): mu/eta/fault-rates are call-time
    # arguments, so repeated runs — and the warm calls of a benchmark —
    # reuse one compiled executable instead of re-tracing the closure
    cache, func = _runner_cache(grad_fn)
    w0_sig = (
        jax.tree_util.tree_structure(w0),
        tuple((tuple(x.shape), jnp.asarray(x).dtype.name)
              for x in jax.tree_util.tree_leaves(w0)),
    )
    memo_key = (
        "ckpt_fused", func, n, C, E, importance, adaptive,
        (bound.A, bound.L, bound.B, bound.C, bound.T, bound.rho)
        if adaptive else None,
        float(ctrl_lr), int(ctrl_iters), eval_fn, unroll,
        str(snapshot_dtype), faulty,
        None if guard is None else guard.cache_key(),
        None if not serving_on else serving.cache_key(), w0_sig,
    )
    if memo_key in cache:
        jchunk = cache[memo_key]
    else:
        make_adv = _make_fused_advance(
            grad_fn, n, C, E, update_step, pack, unpack, enc, 0, guard,
            importance=importance, faulty=faulty, guard_stale=guard_stale,
            need_stats=need_stats, axis=None, lane_devices=1, unroll=unroll,
            serving=serving,
        )

        def chunk(carry, mu_, eta_, fr_, kr, ke, kd, c, k0, Lc, do_eval):
            if serving_on:
                ucarry, sstate, stats, slot_scale, p, cdf, sv, svs = carry
            else:
                ucarry, sstate, stats, slot_scale, p, cdf = carry
            advance = make_adv(mu_, eta_, fr_)
            ur = jax.random.uniform(jax.random.fold_in(kr, c), (Lc,))
            ue = jax.random.uniform(jax.random.fold_in(ke, c), (Lc,))
            ud = jax.random.uniform(jax.random.fold_in(kd, c), (Lc,))
            Kc = jnp.minimum(
                jnp.searchsorted(cdf, ud, side="right"), n - 1
            ).astype(jnp.int32)
            if serving_on:
                ucarry, sstate, stats, slot_scale, _, sv, svs = advance(
                    ucarry, sstate, stats, slot_scale, p, ur, ue, Kc, k0,
                    None, sv, svs,
                )
            else:
                ucarry, sstate, stats, slot_scale, _ = advance(
                    ucarry, sstate, stats, slot_scale, p, ur, ue, Kc, k0
                )
            if adaptive:
                p = sd.ctrl_refresh(
                    p, stats.comp, stats.busy_t, bound, lr=ctrl_lr,
                    iters=ctrl_iters,
                )
                cdf = jnp.cumsum(p)
            ev = (
                jnp.asarray(eval_fn(to_tree(ucarry[0])), jnp.float32)
                if do_eval else jnp.float32(0.0)
            )
            out = (ucarry, sstate, stats, slot_scale, p, cdf)
            if serving_on:
                out = out + (sv, svs)
            return out, ev

        jchunk = jax.jit(chunk, static_argnames=("Lc", "do_eval"))
        cache[memo_key] = jchunk

    fingerprint = _fingerprint("fused", dict(
        n=n, C=C, T=T, L=L, ckpt_every=ckpt_every, weighting=weighting,
        eval_every=eval_every if eval_on else 0, adaptive=adaptive,
        refresh_every=refresh_every, init=init, block_size=E,
        snapshot_dtype=str(snapshot_dtype),
        fault=None if fault is None else fault.cache_key(),
        guard=None if guard is None else guard.cache_key(),
        serving=None if not serving_on else serving.cache_key(),
        key=_key_fingerprint(key), eta=float(np.asarray(eta)),
        mu=_array_digest(mu), p0=_array_digest(p0),
        ctrl=(float(ctrl_lr), int(ctrl_iters)),
    ))

    n_evals = T // eval_every if eval_on else 0
    like = {"carry": carry0, "evals": np.full(n_evals, np.nan, np.float32),
            "cursor": np.int64(0)}
    carry, evals, cursor0 = carry0, _EvalBuffer(n_evals), 0
    if resume:
        state, _ = _resume_state(ckpt_dir, like, fingerprint)
        carry = jax.tree_util.tree_map(jnp.asarray, state["carry"])
        evals = _EvalBuffer(n_evals, restored=state["evals"])
        cursor0 = int(state["cursor"])

    saver = _AsyncSaver(ckpt_dir, fingerprint, keep)
    try:
        for c in range(cursor0 // L, n_chunks):
            do_eval = eval_on and ((c + 1) % eval_stride == 0)
            carry, ev = jchunk(
                carry, mu, eta, fr, k_race, k_exp, k_disp, jnp.int32(c),
                jnp.int32(c * L), Lc=L, do_eval=do_eval,
            )
            if do_eval:
                evals.put((c + 1) // eval_stride - 1, ev)
            events_done = (c + 1) * L
            if events_done % ckpt_every == 0 and events_done < T:
                saver.put(events_done, carry, evals.buf)
        if tail and cursor0 < T:  # cursor0 == T: resumed post-tail final
            carry, _ = jchunk(
                carry, mu, eta, fr, k_race, k_exp, k_disp,
                jnp.int32(n_chunks), jnp.int32(n_chunks * L), Lc=tail,
                do_eval=False,
            )
        # final checkpoint: a later resume returns instantly from here
        saver.put(T, carry, evals.buf)
        saver.close()
    finally:
        saver.abort()

    if serving_on:
        ucarry, sstate, stats, slot_scale, p, cdf, sv, svs = carry
    else:
        ucarry, sstate, stats, slot_scale, p, cdf = carry
    extras = {
        "p_final": p,
        "comp": stats.comp,
        "busy_time": stats.busy_t,
        "delay_sum": stats.delay_sum,
        "t_final": sstate.t,
    }
    if guard is not None:
        extras["guard_rejects"] = ucarry[3][0]
        extras["stale_drops"] = ucarry[3][1]
    if faulty:
        extras["kind_count"] = stats.kind_count
        extras["avail_time"] = stats.avail_tw
    if serving_on:
        extras.update({
            "serve_arrivals": svs.arrivals,
            "serve_served": svs.served,
            "serve_shed": svs.shed,
            "serve_timed_out": svs.timed_out,
            "serve_retried": svs.retried,
            "serve_pending": jnp.sum((sv.stt != 0).astype(jnp.int32)),
            "serve_sojourn_sum": svs.sojourn - svs.sojourn_c,
            "serve_sojourn_hist": svs.sojourn_hist,
            "serve_stale_hist": svs.stale_hist,
            "serve_qdepth_time": svs.qdepth_tw - svs.qdepth_tw_c,
            "serve_qdepth_max": svs.qdepth_max,
            "serve_checksum": svs.checksum - svs.checksum_c,
            "serve_kg_step": sv.kg_step,
            "serve_kg_slot": sv.kg_slot,
            "serve_tokens": sv.tokens,
            "serve_t_final": sstate.t,
        })
    return to_tree(ucarry[0]), jnp.asarray(evals.curve()), extras


# ------------------------------------------------------------------ #
# host-replay checkpointed drivers
# ------------------------------------------------------------------ #
def run_checkpointed_host(
    grad_fn,
    C: int,
    w0,
    J,
    slot,
    scale,
    *,
    ckpt_dir: str,
    ckpt_every: int,
    eval_fn=None,
    eval_every: int = 0,
    fedbuff_Z: int = 0,
    update_fn=None,
    unroll: int = 1,
    snapshot_dtype=None,
    guard: GuardConfig | None = None,
    resume: bool = False,
    keep: int = 3,
):
    """Checkpointed per-event host replay (`_make_host_runner` semantics).

    Replays the pre-simulated ``(J, slot, scale)`` event arrays in L-event
    jitted chunks with a full-carry checkpoint every ``ckpt_every`` events.
    The event arrays themselves are deterministic host data (re-exported
    from the same `SimConfig` on resume), so only the carry + cursor need
    saving.  Returns ``(w_final, evals)`` (+ the guard counter when
    ``guard``), matching the un-checkpointed runner.
    """
    import jax
    import jax.numpy as jnp

    J = np.asarray(J, np.int32)
    slot_h = np.asarray(slot, np.int32)
    scale_h = np.asarray(scale, np.float32)
    T = int(J.shape[0])
    eval_on = eval_fn is not None and eval_every > 0
    L = _chunk_layout(T, ckpt_every, eval_every if eval_on else 0)
    n_chunks, tail = T // L, T % L
    eval_stride = max(eval_every // L, 1) if eval_on else 0

    update_fn, default_update = _default_update(update_fn)
    pack, unpack, enc = _snapshot_codec(w0, snapshot_dtype)
    flat_mode = default_update and unpack is not None
    update_step = _make_update_step(
        grad_fn, fedbuff_Z, update_fn, pack, unpack, flat_mode, enc, guard
    )
    carry0, to_tree = _init_update_carry(
        w0, C, pack, unpack, flat_mode, fedbuff_Z, enc
    )

    def chunk(carry, Jc, sc_, scc, k0, do_eval):
        def body(c, xs):
            j, s, sc, k = xs
            return update_step(c, j, s, sc, k), ()

        ks = k0 + jnp.arange(Jc.shape[0], dtype=jnp.int32)
        carry = jax.lax.scan(body, carry, (Jc, sc_, scc, ks), unroll=unroll)[0]
        ev = (
            jnp.asarray(eval_fn(to_tree(carry[0])), jnp.float32)
            if do_eval else jnp.float32(0.0)
        )
        return carry, ev

    jchunk = jax.jit(chunk, static_argnames=("do_eval",))

    fingerprint = _fingerprint("host", dict(
        C=C, T=T, L=L, ckpt_every=ckpt_every, fedbuff_Z=fedbuff_Z,
        eval_every=eval_every if eval_on else 0,
        snapshot_dtype=str(snapshot_dtype),
        guard=None if guard is None else guard.cache_key(),
        stream=_array_digest(J, slot_h, scale_h),
    ))
    n_evals = T // eval_every if eval_on else 0
    like = {"carry": carry0, "evals": np.full(n_evals, np.nan, np.float32),
            "cursor": np.int64(0)}
    carry, evals, cursor0 = carry0, _EvalBuffer(n_evals), 0
    if resume:
        state, _ = _resume_state(ckpt_dir, like, fingerprint)
        carry = jax.tree_util.tree_map(jnp.asarray, state["carry"])
        evals = _EvalBuffer(n_evals, restored=state["evals"])
        cursor0 = int(state["cursor"])

    saver = _AsyncSaver(ckpt_dir, fingerprint, keep)
    try:
        for c in range(cursor0 // L, n_chunks):
            lo, hi = c * L, (c + 1) * L
            do_eval = eval_on and ((c + 1) % eval_stride == 0)
            carry, ev = jchunk(
                carry, jnp.asarray(J[lo:hi]), jnp.asarray(slot_h[lo:hi]),
                jnp.asarray(scale_h[lo:hi]), jnp.int32(lo), do_eval=do_eval,
            )
            if do_eval:
                evals.put((c + 1) // eval_stride - 1, ev)
            if hi % ckpt_every == 0 and hi < T:
                saver.put(hi, carry, evals.buf)
        if tail and cursor0 < T:  # cursor0 == T: resumed post-tail final
            lo = n_chunks * L
            carry, _ = jchunk(
                carry, jnp.asarray(J[lo:]), jnp.asarray(slot_h[lo:]),
                jnp.asarray(scale_h[lo:]), jnp.int32(lo), do_eval=False,
            )
        saver.put(T, carry, evals.buf)
        saver.close()
    finally:
        saver.abort()
    w = to_tree(carry[0])
    ev_curve = jnp.asarray(evals.curve())
    if guard is not None:
        return w, ev_curve, carry[3]
    return w, ev_curve


def run_checkpointed_host_blocked(
    grad_fn,
    C: int,
    block_size: int,
    w0,
    J,
    slot,
    scale,
    k,
    mask,
    *,
    group_events: int,
    chunk_blocks: int,
    n_chunks: int,
    ckpt_dir: str,
    ckpt_every: int,
    eval_fn=None,
    kernel: str = "jnp",
    interpret: bool = True,
    unroll: int = 1,
    snapshot_dtype=None,
    fedbuff_Z: int = 0,
    guard: GuardConfig | None = None,
    resume: bool = False,
    keep: int = 3,
):
    """Checkpointed blocked host replay (`_make_host_block_runner` semantics).

    Consumes the grouped blocked layout of `engine_scan.blocked_inputs`
    (``eval_every=group_events``): each group of ``chunk_blocks`` rows covers
    exactly ``group_events`` events (the conflict-free cut guarantees it),
    giving exact event cursors for the checkpoint cadence —
    ``ckpt_every`` must be a multiple of ``group_events``.  Trailing rows
    past the last group replay after the loop, un-checkpointed.  When
    ``eval_fn`` is set, the eval fires at every group boundary (cadence
    ``group_events``), matching the grouped layout's contract.  Returns
    ``(w_final, evals)`` (+ the guard counter when ``guard``).
    """
    import jax
    import jax.numpy as jnp

    if block_size < 2:
        raise ValueError("use run_checkpointed_host for block_size <= 1")
    if n_chunks < 1 or chunk_blocks < 1:
        raise ValueError(
            "the blocked checkpoint driver needs the grouped layout: pass "
            "blocked_inputs(blocks, scale, eval_every=group_events) arrays"
        )
    if ckpt_every <= 0 or ckpt_every % group_events:
        raise ValueError("ckpt_every must be a positive multiple of "
                         "group_events")
    pad_to = 1
    if kernel == "pallas":
        from ..kernels.weighted_update import BLOCK_TILE

        pad_to = BLOCK_TILE

    J = np.asarray(J, np.int32)
    slot_h = np.asarray(slot, np.int32)
    scale_h = np.asarray(scale, np.float32)
    k_h = np.asarray(k, np.int32)
    mask_h = np.asarray(mask, bool)

    pack, unpack, enc = _snapshot_codec(w0, snapshot_dtype, pad_to=pad_to)
    if unpack is None:
        raise ValueError(
            "block_size > 1 requires all-float parameters "
            "(flat-packed snapshot storage)"
        )
    block_step = _make_block_step(
        grad_fn, fedbuff_Z, pack, unpack, kernel, interpret, None, guard
    )
    carry0, to_tree = _init_update_carry(
        w0, C + 1, pack, unpack, True, fedbuff_Z, enc
    )

    def chunk(carry, Jc, sc_, scc, kc, mc, do_eval):
        def body(c, xs):
            return block_step(c, *xs), ()

        carry = jax.lax.scan(
            body, carry, (Jc, sc_, scc, kc, mc), unroll=unroll
        )[0]
        ev = (
            jnp.asarray(eval_fn(to_tree(carry[0])), jnp.float32)
            if do_eval else jnp.float32(0.0)
        )
        return carry, ev

    jchunk = jax.jit(chunk, static_argnames=("do_eval",))

    fingerprint = _fingerprint("host_blocked", dict(
        C=C, E=block_size, group_events=group_events, ckpt_every=ckpt_every,
        chunk_blocks=chunk_blocks, n_chunks=n_chunks, kernel=kernel,
        fedbuff_Z=fedbuff_Z, snapshot_dtype=str(snapshot_dtype),
        guard=None if guard is None else guard.cache_key(),
        stream=_array_digest(J, slot_h, scale_h, k_h, mask_h),
    ))
    eval_on = eval_fn is not None
    n_evals = n_chunks if eval_on else 0
    # the grouped layout's tail rows sit past the last exact event cursor;
    # count their real (unmasked) events so the final cursor is unambiguous
    Bm = n_chunks * chunk_blocks
    total = n_chunks * group_events
    tail_events = (
        int(mask_h[Bm:].sum()) if Bm < int(J.shape[0]) else 0
    )
    total_all = total + tail_events
    like = {"carry": carry0, "evals": np.full(n_evals, np.nan, np.float32),
            "cursor": np.int64(0)}
    carry, evals, cursor0 = carry0, _EvalBuffer(n_evals), 0
    if resume:
        state, _ = _resume_state(ckpt_dir, like, fingerprint)
        carry = jax.tree_util.tree_map(jnp.asarray, state["carry"])
        evals = _EvalBuffer(n_evals, restored=state["evals"])
        cursor0 = int(state["cursor"])

    saver = _AsyncSaver(ckpt_dir, fingerprint, keep)
    try:
        for g in range(min(cursor0, total) // group_events, n_chunks):
            lo, hi = g * chunk_blocks, (g + 1) * chunk_blocks
            carry, ev = jchunk(
                carry, jnp.asarray(J[lo:hi]), jnp.asarray(slot_h[lo:hi]),
                jnp.asarray(scale_h[lo:hi]), jnp.asarray(k_h[lo:hi]),
                jnp.asarray(mask_h[lo:hi]), do_eval=eval_on,
            )
            if eval_on:
                evals.put(g, ev)
            events_done = (g + 1) * group_events
            if events_done % ckpt_every == 0 and events_done < total_all:
                saver.put(events_done, carry, evals.buf)
        if Bm < int(J.shape[0]) and cursor0 < total_all:  # tail rows
            carry, _ = jchunk(
                carry, jnp.asarray(J[Bm:]), jnp.asarray(slot_h[Bm:]),
                jnp.asarray(scale_h[Bm:]), jnp.asarray(k_h[Bm:]),
                jnp.asarray(mask_h[Bm:]), do_eval=False,
            )
        saver.put(total_all, carry, evals.buf)
        saver.close()
    finally:
        saver.abort()
    w = to_tree(carry[0])
    ev_curve = jnp.asarray(evals.curve())
    if guard is not None:
        return w, ev_curve, carry[3]
    return w, ev_curve
