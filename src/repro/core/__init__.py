"""Core library: the paper's contribution (queueing analysis + Generalized AsyncSGD)."""
from .jackson import (
    JacksonNetwork,
    buzen_normalizing_constants,
    gamma_ratio,
    three_cluster_delay_bounds,
    two_cluster_delay_bounds,
)
from .queue_sim import ClosedNetworkSim, SimConfig, SimResult, simulate
from .sampling import (
    SamplingResult,
    bound_for_p,
    optimize_general,
    optimize_physical_time,
    optimize_two_cluster,
    two_cluster_p_vector,
)
from .theory import (
    BoundConstants,
    asyncsgd_bound,
    asyncsgd_eta_max,
    eta_max,
    fedbuff_bound,
    fedbuff_eta_max,
    generalized_bound,
    optimal_eta,
)
from .async_sgd import (
    ServerConfig,
    TraceRecord,
    run_favano,
    run_fedavg,
    run_fedbuff,
    run_generalized_async_sgd,
)
