"""Core library: the paper's contribution (queueing analysis + Generalized AsyncSGD)."""
from .jackson import (
    JacksonNetwork,
    batched_expected_delays,
    buzen_add_node,
    buzen_normalizing_constants,
    buzen_remove_node,
    buzen_replace_node,
    gamma_ratio,
    three_cluster_delay_bounds,
    two_cluster_delay_bounds,
)
from .engine_scan import (
    DeviceGradientSource,
    GuardConfig,
    blocked_inputs,
    blocked_inputs_batch,
    jit_fused_runner,
    jit_runner,
    make_fused_runner,
    make_runner,
    step_scales,
    stream_arrays,
)
from .engine_ckpt import (
    run_checkpointed,
    run_checkpointed_host,
    run_checkpointed_host_blocked,
)
from .stream_device import (
    ctrl_refresh,
    estimate_mu,
    generate_blocks,
    generate_stream,
    make_bound_value_and_grad,
    mva_throughput_delays,
)
from .queue_sim import (
    KIND_COMPLETE,
    KIND_CRASH,
    KIND_FLIP,
    KIND_TIMEOUT,
    ClosedNetworkSim,
    EventBlocks,
    EventStream,
    FaultConfig,
    SimConfig,
    SimResult,
    export_blocks,
    export_stream,
    segment_blocks,
    select_block_size,
    simulate,
    simulate_batch,
)
from .sampling import (
    SamplingResult,
    bound_for_p,
    bound_for_p_batch,
    bound_value_and_grad,
    optimize_general,
    optimize_physical_time,
    optimize_two_cluster,
    two_cluster_p_vector,
)
from .theory import (
    BoundConstants,
    asyncsgd_bound,
    asyncsgd_eta_max,
    eta_max,
    fedbuff_bound,
    fedbuff_eta_max,
    generalized_bound,
    optimal_eta,
)
from .async_sgd import (
    ServerConfig,
    TraceRecord,
    run_favano,
    run_fedavg,
    run_fedbuff,
    run_generalized_async_sgd,
)
