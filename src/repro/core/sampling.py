"""Optimal client-sampling schemes for Generalized AsyncSGD (paper §2 & App. E/F).

Given client speeds mu and the bound constants, pick (p, eta) minimizing the
Theorem-1 bound G(p, eta) where the delays m_i(p) come from the *exact*
Jackson-network analysis (repro.core.jackson), closing the loop the paper
opens: the bound depends on p both directly and through the queueing delays.

Three optimizers:
  * `optimize_two_cluster`  — scalar golden-section over the fast-node
    probability p (the paper's Figs. 2/3/9 setting).  The coarse grid is
    evaluated as ONE batched Buzen pass and the golden-section refinement
    reuses/memoizes objective evaluations.
  * `optimize_general`      — projected mirror-descent on the simplex for
    arbitrary heterogeneous mu (beyond-paper: the paper only treats clusters).
    Gradients are *analytic* (product-form identity, O(n*C) per step) by
    default; the seed finite-difference path (O(n^2*C) per step) is kept as
    ``method="fd"`` for benchmarking.
  * `optimize_physical_time`— App. E.2: fixed wall-clock budget U, T = λ(p)·U,
    grid evaluated in one batched pass.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from .jackson import (
    JacksonNetwork,
    MixedServingResult,
    batched_expected_delays,
    serving_slo,
)
from .theory import BoundConstants, eta_max_components, generalized_bound, optimal_eta

__all__ = [
    "SamplingResult",
    "TradeoffResult",
    "bound_for_p",
    "bound_for_p_batch",
    "bound_value_and_grad",
    "optimize_two_cluster",
    "optimize_general",
    "optimize_physical_time",
    "optimize_tradeoff",
    "two_cluster_p_vector",
]


@dataclass
class SamplingResult:
    p: np.ndarray
    eta: float
    bound: float
    uniform_bound: float
    m: np.ndarray  # expected delays at the optimum

    @property
    def relative_improvement(self) -> float:
        """(uniform - optimal)/uniform, the quantity plotted in Figs. 3/4/9."""
        if not np.isfinite(self.uniform_bound) or self.uniform_bound == 0:
            return 0.0
        return float((self.uniform_bound - self.bound) / self.uniform_bound)


def _delays(mu: np.ndarray, p: np.ndarray, C: int) -> np.ndarray:
    return JacksonNetwork(mu=mu, p=p, C=C).expected_delays()


def bound_for_p(
    mu: np.ndarray, p: np.ndarray, k: BoundConstants
) -> tuple[float, float, np.ndarray]:
    """(G(p, eta*(p)), eta*, m(p)) with delays from the Jackson analysis."""
    m = _delays(mu, p, k.C)
    eta = optimal_eta(p, m, k)
    return generalized_bound(eta, p, m, k), eta, m


def bound_for_p_batch(
    mu: np.ndarray, P: np.ndarray, k: BoundConstants
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized `bound_for_p` over rows of P (B, n).

    Delays come from one batched Buzen pass; only the cubic root for eta* is
    per-row.  Returns (bounds (B,), etas (B,), delays (B, n)).
    """
    P = np.asarray(P, dtype=np.float64)
    m, _ = batched_expected_delays(mu, P, k.C)
    B = P.shape[0]
    vals = np.empty(B)
    etas = np.empty(B)
    for b in range(B):
        etas[b] = optimal_eta(P[b], m[b], k)
        vals[b] = generalized_bound(etas[b], P[b], m[b], k)
    return vals, etas, m


def bound_value_and_grad(
    mu: np.ndarray, p: np.ndarray, k: BoundConstants
) -> tuple[float, float, np.ndarray, np.ndarray]:
    """Exact (value, eta*, m, dvalue/dp) of f(p) = G(p, eta*(p)) in O(n*C).

    The chain rule runs through three channels:
      * the explicit 1/p terms of the Theorem-1 bound,
      * the delays m(p) via the Jackson-network VJP
        (`JacksonNetwork.expected_delays_vjp`),
      * eta*(p): zero by the envelope theorem when the cubic stationary point
        is interior, plus the exact d eta_max/dp term when the step-size cap
        is the active minimizer.
    """
    mu = np.asarray(mu, dtype=np.float64)
    p = np.asarray(p, dtype=np.float64)
    n = p.size
    net = JacksonNetwork(mu=mu, p=p, C=k.C)
    m = net.expected_delays()
    eta = optimal_eta(p, m, k)
    val = generalized_bound(eta, p, m, k)

    L, Bc, C = k.L, k.B, k.C
    n2 = float(n) ** 2
    Sp1 = float(np.sum(1.0 / (n2 * p)))
    Sp2 = float(np.sum(m / (n2 * p**2)))
    # explicit p-dependence (m, eta held fixed)
    grad = -eta * L * Bc / (n2 * p**2) - 2.0 * eta**2 * L**2 * Bc * C * m / (n2 * p**3)
    # delay channel: dG/dm_i = eta^2 L^2 B C / (n^2 p_i^2), pulled back to p
    v = eta**2 * L**2 * Bc * C / (n2 * p**2)
    grad = grad + net.expected_delays_vjp(v) / mu

    # eta channel: only active when eta* sits on the eta_max cap
    a_val, b_val = eta_max_components(p, m, k)
    cap = min(a_val, b_val)
    if eta >= cap * (1.0 - 1e-12):
        dG_deta = (
            -k.A / (eta**2 * (k.T + 1)) + L * Bc * Sp1 + 2.0 * eta * L**2 * Bc * C * Sp2
        )
        if a_val <= b_val:
            # a = (16 L^2 C growth * Sp2)^{-1/2}; Sp2 depends on p and m(p)
            w = net.expected_delays_vjp(1.0 / (n2 * p**2)) / mu
            dSp2 = w - 2.0 * m / (n2 * p**3)
            deta_dp = -(a_val / 2.0) * dSp2 / Sp2
        else:
            # b = n^2/(8 L growth sum 1/p): db/dp_j = b / (sum 1/p) / p_j^2
            s1 = float(np.sum(1.0 / p))
            deta_dp = b_val / (s1 * p**2)
        grad = grad + dG_deta * deta_dp
    return val, eta, m, grad


def two_cluster_p_vector(n: int, n_f: int, p_fast: float) -> np.ndarray:
    """Full p vector from the scalar fast-node probability (paper §2).

    q = (1 - n_f * p_fast) / (n - n_f) for slow nodes.
    """
    if not (0.0 < p_fast < 1.0 / n_f):
        raise ValueError(f"p_fast must lie in (0, 1/n_f)=(0,{1.0/n_f:.4g})")
    q = (1.0 - n_f * p_fast) / (n - n_f)
    p = np.full(n, q)
    p[:n_f] = p_fast
    return p


def _two_cluster_p_batch(n: int, n_f: int, ps: np.ndarray) -> np.ndarray:
    """(B, n) matrix of two-cluster p vectors for fast-node probabilities ps."""
    q = (1.0 - n_f * ps) / (n - n_f)
    P = np.empty((ps.size, n))
    P[:, :n_f] = ps[:, None]
    P[:, n_f:] = q[:, None]
    return P


def optimize_two_cluster(
    mu_f: float,
    mu_s: float,
    n: int,
    n_f: int,
    k: BoundConstants,
    grid: int = 60,
) -> SamplingResult:
    """Golden-section (after a batched coarse grid) over the fast-node probability."""
    mu = np.full(n, mu_s)
    mu[:n_f] = mu_f

    def objective(p_fast: float) -> float:
        p = two_cluster_p_vector(n, n_f, p_fast)
        return bound_for_p(mu, p, k)[0]

    lo, hi = 1e-4 / n, (1.0 - 1e-6) / n_f
    # log-spaced coarse grid (optimum can sit orders of magnitude below 1/n),
    # evaluated with a single batched Buzen pass
    ps = np.geomspace(lo, hi, grid)
    vals, _, _ = bound_for_p_batch(mu, _two_cluster_p_batch(n, n_f, ps), k)
    i = int(np.argmin(vals))
    a = float(ps[max(i - 1, 0)])
    b = float(ps[min(i + 1, grid - 1)])
    # golden-section refine on [a, b]: one fresh evaluation per iteration
    # (the surviving interior point's value is carried over)
    gr = (np.sqrt(5.0) - 1.0) / 2.0
    c, d = b - gr * (b - a), a + gr * (b - a)
    fc, fd = objective(c), objective(d)
    for _ in range(40):
        if fc < fd:
            b, d, fd = d, c, fc
            c = b - gr * (b - a)
            fc = objective(c)
        else:
            a, c, fc = c, d, fd
            d = a + gr * (b - a)
            fd = objective(d)
    p_star = float(0.5 * (a + b))
    p_vec = two_cluster_p_vector(n, n_f, p_star)
    bound, eta, m = bound_for_p(mu, p_vec, k)
    u = np.full(n, 1.0 / n)
    ub, _, _ = bound_for_p(mu, u, k)
    return SamplingResult(p=p_vec, eta=eta, bound=bound, uniform_bound=ub, m=m)


# class-collapse threshold for optimize_general: below this population the
# dense analytic path is fast and stays the default (and the oracle)
_COLLAPSE_MIN_N = 1024


def _mva_delays_f64(
    mu_m: np.ndarray, p_m: np.ndarray, counts: np.ndarray, C: int
) -> tuple[np.ndarray, float]:
    """Class-collapsed exact MVA in float64: (delays m (m,), throughput).

    ``mu_m``/``p_m`` are per-*node* class values, ``counts`` multiplicities.
    The MVA recurrence W = (1+Q)/mu, lam = M / sum_i p_i W_i is a sum over
    nodes; within a class every node is identical, so the sum collapses to
    counts-weighted class terms — O(m*C) independent of n.  Delays follow
    the arrival theorem (queue seen at C-1) with the (C-1)/C Little's-law
    normalization of `JacksonNetwork.expected_delays`.
    """
    w = counts.astype(np.float64)
    Q = np.zeros_like(mu_m)
    Q_prev, lam = Q, 0.0
    for M in range(1, C + 1):
        W = (1.0 + Q) / mu_m
        lam = M / float((w * p_m) @ W)
        Q_prev = Q
        Q = lam * p_m * W
    m = lam * (Q_prev + 1.0) / mu_m * (C - 1.0) / C
    return m, lam


def _optimize_general_classes(
    mu: np.ndarray, k: BoundConstants, iters: int, lr: float
) -> SamplingResult | None:
    """Class-collapsed mirror descent: O(m*C) per iteration, any n.

    Collapses mu to its m distinct speed classes (the optimum is
    class-symmetric by exchangeability), runs exponentiated gradient on
    the m-dimensional class-mass simplex with exact f64 MVA delays and a
    finite-difference class gradient (m+1 cheap evaluations per step —
    the dense analytic VJP is O(n*C) and moot here), then expands the
    optimal per-node p back to (n,).  Returns None when the profile does
    not collapse (too many classes) — caller falls back to the dense path.
    """
    from .stream_device import build_class_spec

    n = mu.size
    try:
        spec, mu_m, p_u = build_class_spec(mu)
    except ValueError:
        return None
    m_cls = spec.m
    if m_cls > max(n // 4, 1):
        return None  # no real collapse: dense path is as good
    counts = np.asarray(spec.counts, np.float64)
    mu_m = np.asarray(mu_m, np.float64)
    inv = np.asarray(spec.inv_cls)

    def objective(z: np.ndarray) -> tuple[float, float, np.ndarray]:
        """Bound at class masses z (sum 1): (value, eta*, class delays)."""
        p_m = z / counts
        md, _ = _mva_delays_f64(mu_m, p_m, counts, k.C)
        p_full, m_full = p_m[inv], md[inv]
        eta = optimal_eta(p_full, m_full, k)
        return generalized_bound(eta, p_full, m_full, k), eta, md

    z = counts / n  # uniform start
    floor = 1e-5 * counts / n
    best_z, best_v = z.copy(), np.inf
    for _ in range(iters):
        val, _, _ = objective(z)
        if val < best_v:
            best_z, best_v = z.copy(), val
        h = 1e-7
        g = np.empty(m_cls)
        for j in range(m_cls):
            zq = z.copy()
            zq[j] += h
            g[j] = (objective(zq / zq.sum())[0] - val) / h
        g = g - float(g @ z)
        z = z * np.exp(-lr * g / (np.abs(g).max() + 1e-12))
        z = np.maximum(z, floor)
        z /= z.sum()
    val = objective(z)[0]
    if val < best_v:
        best_z, best_v = z.copy(), val
    bound, eta, md = objective(best_z)
    p_m = best_z / counts
    mdu, _ = _mva_delays_f64(mu_m, np.full(m_cls, 1.0 / n), counts, k.C)
    pu_full, mu_full = np.full(n, 1.0 / n), mdu[inv]
    ub = generalized_bound(optimal_eta(pu_full, mu_full, k), pu_full, mu_full, k)
    return SamplingResult(
        p=p_m[inv], eta=eta, bound=bound, uniform_bound=ub, m=md[inv]
    )


def optimize_general(
    mu: np.ndarray,
    k: BoundConstants,
    iters: int = 200,
    lr: float = 0.3,
    seed: int = 0,
    method: str = "analytic",
    collapse: bool | str = "auto",
) -> SamplingResult:
    """Mirror descent (exponentiated gradient) on the simplex.

    Beyond-paper: handles arbitrary mu without cluster structure.  The
    objective is smooth in p away from the boundary; we keep a floor on p.

    ``method="analytic"`` (default) uses the exact O(n*C) gradient from the
    product-form identity; ``method="fd"`` is the seed finite-difference
    path (O(n^2*C) per step), kept for regression benchmarks.

    ``collapse="auto"`` switches to the class-collapsed optimizer
    (`_optimize_general_classes`, O(m*C) per step — runs at n = 10^6)
    when n >= 1024 and mu has few distinct values; ``True`` forces it,
    ``False`` keeps the dense path regardless.
    """
    mu = np.asarray(mu, dtype=np.float64)
    if collapse is True or (collapse == "auto" and mu.size >= _COLLAPSE_MIN_N):
        res = _optimize_general_classes(mu, k, iters=iters, lr=lr)
        if res is not None:
            return res
        if collapse is True:
            raise ValueError(
                "collapse=True: mu does not reduce to a small number of "
                "speed classes"
            )
    if method == "fd":
        return _optimize_general_fd(mu, k, iters=iters, lr=lr)
    if method != "analytic":
        raise ValueError(f"unknown method {method!r}")
    n = mu.size
    p = np.full(n, 1.0 / n)
    floor = 1e-5 / n

    best_p, best_v = p.copy(), np.inf
    for _ in range(iters):
        val, _, _, g = bound_value_and_grad(mu, p, k)
        if val < best_v:
            best_p, best_v = p.copy(), val
        # project onto the simplex tangent (exponentiated-gradient update is
        # invariant to the shift after renormalization; centering keeps the
        # step size interpretable) and normalize by the largest component
        g = g - float(g @ p)
        p = p * np.exp(-lr * g / (np.abs(g).max() + 1e-12))
        p = np.maximum(p, floor)
        p /= p.sum()
    val = bound_for_p(mu, p, k)[0]
    if val < best_v:
        best_p, best_v = p.copy(), val
    bound, eta, m = bound_for_p(mu, best_p, k)
    u = np.full(n, 1.0 / n)
    ub, _, _ = bound_for_p(mu, u, k)
    return SamplingResult(p=best_p, eta=eta, bound=bound, uniform_bound=ub, m=m)


def _optimize_general_fd(
    mu: np.ndarray, k: BoundConstants, iters: int, lr: float
) -> SamplingResult:
    """Seed finite-difference mirror descent (before-state of the perf work)."""
    n = mu.size
    p = np.full(n, 1.0 / n)
    floor = 1e-5 / n

    def f(pv: np.ndarray) -> float:
        return bound_for_p(mu, pv, k)[0]

    best_p, best_v = p.copy(), f(p)
    for _ in range(iters):
        g = np.zeros(n)
        v0 = f(p)
        h = 1e-4 / n
        for i in range(n):
            q = p.copy()
            q[i] += h
            q /= q.sum()
            g[i] = (f(q) - v0) / h
        p = p * np.exp(-lr * (g - g.mean()) / (np.abs(g).max() + 1e-12))
        p = np.maximum(p, floor)
        p /= p.sum()
        v = f(p)
        if v < best_v:
            best_p, best_v = p.copy(), v
    bound, eta, m = bound_for_p(mu, best_p, k)
    u = np.full(n, 1.0 / n)
    ub, _, _ = bound_for_p(mu, u, k)
    return SamplingResult(p=best_p, eta=eta, bound=bound, uniform_bound=ub, m=m)


def optimize_physical_time(
    mu_f: float,
    mu_s: float,
    n: int,
    n_f: int,
    k: BoundConstants,
    U: float = 1000.0,
    grid: int = 60,
) -> SamplingResult:
    """App. E.2: fixed time budget U; T(p) = lambda(p) * U server steps.

    lambda(p) is the network throughput of the Jackson network — sampling
    slow nodes more reduces delays *in steps* but slows the CS step clock.
    The grid is evaluated with one batched Buzen pass.
    """
    mu = np.full(n, mu_s)
    mu[:n_f] = mu_f

    lo, hi = 1e-4 / n, (1.0 - 1e-6) / n_f
    ps = np.geomspace(lo, hi, grid)
    P = _two_cluster_p_batch(n, n_f, ps)
    ms, lams = batched_expected_delays(mu, P, k.C)
    vals = np.empty(grid)
    for b in range(grid):
        kk = replace(k, T=max(int(lams[b] * U), 1))
        eta = optimal_eta(P[b], ms[b], kk)
        vals[b] = generalized_bound(eta, P[b], ms[b], kk)
    p_star = float(ps[int(np.argmin(vals))])
    p_vec = two_cluster_p_vector(n, n_f, p_star)
    net = JacksonNetwork(mu=mu, p=p_vec, C=k.C)
    kk = replace(k, T=max(int(net.throughput() * U), 1))
    m = net.expected_delays()
    eta = optimal_eta(p_vec, m, kk)
    bound = generalized_bound(eta, p_vec, m, kk)
    u = np.full(n, 1.0 / n)
    net_u = JacksonNetwork(mu=mu, p=u, C=k.C)
    ku = replace(k, T=max(int(net_u.throughput() * U), 1))
    mu_del = net_u.expected_delays()
    ub = generalized_bound(optimal_eta(u, mu_del, ku), u, mu_del, ku)
    return SamplingResult(p=p_vec, eta=eta, bound=bound, uniform_bound=ub, m=m)


@dataclass
class TradeoffResult:
    """Optimum of the training-bound / serving-SLO tradeoff."""

    p: np.ndarray
    eta: float
    bound: float                 # training bound G at the optimum
    serving: MixedServingResult  # serving-plane factors at the optimum
    objective: float             # G + weight * mean_sojourn
    uniform_objective: float     # same objective at p = 1/n

    @property
    def relative_improvement(self) -> float:
        if not np.isfinite(self.uniform_objective) or self.uniform_objective == 0:
            return 0.0
        return float(
            (self.uniform_objective - self.objective) / self.uniform_objective
        )


def _throughput_and_grad(
    mu: np.ndarray, p: np.ndarray, C: int
) -> tuple[float, np.ndarray]:
    """(Lambda(p), dLambda/dp) from the product-form identity.

    Lambda = H_{C-1}/H_C in unrescaled theta units, and
    d log H_N / d theta_i = E_N[X_i] / theta_i, so with theta_i = p_i/mu_i:

        dLambda/dp_i = Lambda * (E_{C-1}[X_i] - E_C[X_i]) / p_i.
    """
    net = JacksonNetwork(mu=mu, p=p, C=C)
    lam = net.throughput()
    qC = net.mean_queue_lengths()
    qCm1 = net.mean_queue_lengths(ntasks=C - 1)
    return lam, lam * (qCm1 - qC) / p


def optimize_tradeoff(
    mu: np.ndarray,
    k: BoundConstants,
    serving,
    *,
    weight: float = 1.0,
    update_capacity: float | None = None,
    iters: int = 100,
    lr: float = 0.3,
) -> TradeoffResult:
    """Mirror descent on  G(p) + weight * W_serve(Lambda(p)).

    ``serving`` is duck-typed as `repro.core.serving.ServingConfig` — only
    ``arrival_rate``, ``serve_rate`` and ``queue_cap`` are read.  The serving
    mean sojourn W couples to p through the training throughput Lambda(p)
    via the ``update_capacity`` host-interference model (`jackson.serving_slo`):
    sampling fast clients more raises Lambda, which squeezes the effective
    serve rate and inflates W.  With ``update_capacity=None`` the penalty is
    constant in p and the optimum coincides with `optimize_general`.

    The gradient composes the analytic bound gradient
    (`bound_value_and_grad`) with the exact product-form throughput gradient
    (`_throughput_and_grad`) and a scalar central difference for dW/dLambda
    (W is a smooth scalar map; one FD evaluation costs two M/M/1/K
    closed-form evaluations, no Buzen pass).
    """
    mu = np.asarray(mu, dtype=np.float64)
    n = mu.size
    lam_arr = float(serving.arrival_rate)
    nu_s = float(serving.serve_rate)
    K = int(serving.queue_cap)

    def slo(lam_train: float) -> MixedServingResult:
        return serving_slo(
            lam_train, arrival_rate=lam_arr, serve_rate=nu_s,
            queue_cap=K, update_capacity=update_capacity,
        )

    def penalty_and_dlam(lam_train: float) -> tuple[float, float]:
        W = slo(lam_train).mean_sojourn
        h = max(1e-6 * abs(lam_train), 1e-9)
        dW = (slo(lam_train + h).mean_sojourn
              - slo(lam_train - h).mean_sojourn) / (2 * h)
        return weight * W, weight * dW

    def objective(pv: np.ndarray) -> float:
        val = bound_for_p(mu, pv, k)[0]
        lam, _ = _throughput_and_grad(mu, pv, k.C)
        return val + penalty_and_dlam(lam)[0]

    p = np.full(n, 1.0 / n)
    floor = 1e-5 / n
    best_p, best_v = p.copy(), objective(p)
    for _ in range(iters):
        val, _, _, g = bound_value_and_grad(mu, p, k)
        lam, dlam = _throughput_and_grad(mu, p, k.C)
        pen, dpen = penalty_and_dlam(lam)
        total = val + pen
        if total < best_v:
            best_p, best_v = p.copy(), total
        g = g + dpen * dlam
        g = g - float(g @ p)
        p = p * np.exp(-lr * g / (np.abs(g).max() + 1e-12))
        p = np.maximum(p, floor)
        p /= p.sum()
    v = objective(p)
    if v < best_v:
        best_p, best_v = p.copy(), v
    bound, eta, _ = bound_for_p(mu, best_p, k)
    lam, _ = _throughput_and_grad(mu, best_p, k.C)
    u = np.full(n, 1.0 / n)
    return TradeoffResult(
        p=best_p, eta=eta, bound=bound, serving=slo(lam),
        objective=best_v, uniform_objective=objective(u),
    )
