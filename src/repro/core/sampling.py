"""Optimal client-sampling schemes for Generalized AsyncSGD (paper §2 & App. E/F).

Given client speeds mu and the bound constants, pick (p, eta) minimizing the
Theorem-1 bound G(p, eta) where the delays m_i(p) come from the *exact*
Jackson-network analysis (repro.core.jackson), closing the loop the paper
opens: the bound depends on p both directly and through the queueing delays.

Three optimizers:
  * `optimize_two_cluster`  — scalar golden-section over the fast-node
    probability p (the paper's Figs. 2/3/9 setting).
  * `optimize_general`      — projected mirror-descent on the simplex for
    arbitrary heterogeneous mu (beyond-paper: the paper only treats clusters).
  * `optimize_physical_time`— App. E.2: fixed wall-clock budget U, T = λ(p)·U.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from .jackson import JacksonNetwork
from .theory import BoundConstants, generalized_bound, optimal_eta

__all__ = [
    "SamplingResult",
    "bound_for_p",
    "optimize_two_cluster",
    "optimize_general",
    "optimize_physical_time",
    "two_cluster_p_vector",
]


@dataclass
class SamplingResult:
    p: np.ndarray
    eta: float
    bound: float
    uniform_bound: float
    m: np.ndarray  # expected delays at the optimum

    @property
    def relative_improvement(self) -> float:
        """(uniform - optimal)/uniform, the quantity plotted in Figs. 3/4/9."""
        if not np.isfinite(self.uniform_bound) or self.uniform_bound == 0:
            return 0.0
        return float((self.uniform_bound - self.bound) / self.uniform_bound)


def _delays(mu: np.ndarray, p: np.ndarray, C: int) -> np.ndarray:
    return JacksonNetwork(mu=mu, p=p, C=C).expected_delays()


def bound_for_p(
    mu: np.ndarray, p: np.ndarray, k: BoundConstants
) -> tuple[float, float, np.ndarray]:
    """(G(p, eta*(p)), eta*, m(p)) with delays from the Jackson analysis."""
    m = _delays(mu, p, k.C)
    eta = optimal_eta(p, m, k)
    return generalized_bound(eta, p, m, k), eta, m


def two_cluster_p_vector(n: int, n_f: int, p_fast: float) -> np.ndarray:
    """Full p vector from the scalar fast-node probability (paper §2).

    q = (1 - n_f * p_fast) / (n - n_f) for slow nodes.
    """
    if not (0.0 < p_fast < 1.0 / n_f):
        raise ValueError(f"p_fast must lie in (0, 1/n_f)=(0,{1.0/n_f:.4g})")
    q = (1.0 - n_f * p_fast) / (n - n_f)
    p = np.full(n, q)
    p[:n_f] = p_fast
    return p


def optimize_two_cluster(
    mu_f: float,
    mu_s: float,
    n: int,
    n_f: int,
    k: BoundConstants,
    grid: int = 60,
) -> SamplingResult:
    """Golden-section (after a coarse grid) over the fast-node probability."""
    mu = np.full(n, mu_s)
    mu[:n_f] = mu_f

    def objective(p_fast: float) -> float:
        p = two_cluster_p_vector(n, n_f, p_fast)
        b, _, _ = bound_for_p(mu, p, k)
        return b

    lo, hi = 1e-4 / n, (1.0 - 1e-6) / n_f
    # log-spaced coarse grid (optimum can sit orders of magnitude below 1/n)
    ps = np.geomspace(lo, hi, grid)
    vals = np.array([objective(x) for x in ps])
    i = int(np.argmin(vals))
    a = ps[max(i - 1, 0)]
    b = ps[min(i + 1, grid - 1)]
    # golden-section refine on [a, b]
    gr = (np.sqrt(5.0) - 1.0) / 2.0
    c, d = b - gr * (b - a), a + gr * (b - a)
    for _ in range(40):
        if objective(c) < objective(d):
            b, d = d, c
            c = b - gr * (b - a)
        else:
            a, c = c, d
            d = a + gr * (b - a)
    p_star = float(0.5 * (a + b))
    p_vec = two_cluster_p_vector(n, n_f, p_star)
    bound, eta, m = bound_for_p(mu, p_vec, k)
    u = np.full(n, 1.0 / n)
    ub, _, _ = bound_for_p(mu, u, k)
    return SamplingResult(p=p_vec, eta=eta, bound=bound, uniform_bound=ub, m=m)


def optimize_general(
    mu: np.ndarray,
    k: BoundConstants,
    iters: int = 200,
    lr: float = 0.3,
    seed: int = 0,
) -> SamplingResult:
    """Mirror descent (exponentiated gradient) on the simplex, finite-diff grads.

    Beyond-paper: handles arbitrary mu without cluster structure.  The
    objective is smooth in p away from the boundary; we keep a floor on p.
    """
    mu = np.asarray(mu, dtype=np.float64)
    n = mu.size
    p = np.full(n, 1.0 / n)
    floor = 1e-5 / n

    def f(pv: np.ndarray) -> float:
        return bound_for_p(mu, pv, k)[0]

    best_p, best_v = p.copy(), f(p)
    for _ in range(iters):
        g = np.zeros(n)
        v0 = f(p)
        h = 1e-4 / n
        for i in range(n):
            q = p.copy()
            q[i] += h
            q /= q.sum()
            g[i] = (f(q) - v0) / h
        p = p * np.exp(-lr * (g - g.mean()) / (np.abs(g).max() + 1e-12))
        p = np.maximum(p, floor)
        p /= p.sum()
        v = f(p)
        if v < best_v:
            best_p, best_v = p.copy(), v
    bound, eta, m = bound_for_p(mu, best_p, k)
    u = np.full(n, 1.0 / n)
    ub, _, _ = bound_for_p(mu, u, k)
    return SamplingResult(p=best_p, eta=eta, bound=bound, uniform_bound=ub, m=m)


def optimize_physical_time(
    mu_f: float,
    mu_s: float,
    n: int,
    n_f: int,
    k: BoundConstants,
    U: float = 1000.0,
    grid: int = 60,
) -> SamplingResult:
    """App. E.2: fixed time budget U; T(p) = lambda(p) * U server steps.

    lambda(p) is the network throughput of the Jackson network — sampling
    slow nodes more reduces delays *in steps* but slows the CS step clock.
    """
    mu = np.full(n, mu_s)
    mu[:n_f] = mu_f

    def objective(p_fast: float) -> float:
        p = two_cluster_p_vector(n, n_f, p_fast)
        net = JacksonNetwork(mu=mu, p=p, C=k.C)
        T_eff = max(int(net.throughput() * U), 1)
        kk = replace(k, T=T_eff)
        m = net.expected_delays()
        eta = optimal_eta(p, m, kk)
        return generalized_bound(eta, p, m, kk)

    lo, hi = 1e-4 / n, (1.0 - 1e-6) / n_f
    ps = np.geomspace(lo, hi, grid)
    vals = np.array([objective(x) for x in ps])
    p_star = float(ps[int(np.argmin(vals))])
    p_vec = two_cluster_p_vector(n, n_f, p_star)
    net = JacksonNetwork(mu=mu, p=p_vec, C=k.C)
    kk = replace(k, T=max(int(net.throughput() * U), 1))
    m = net.expected_delays()
    eta = optimal_eta(p_vec, m, kk)
    bound = generalized_bound(eta, p_vec, m, kk)
    u = np.full(n, 1.0 / n)
    net_u = JacksonNetwork(mu=mu, p=u, C=k.C)
    ku = replace(k, T=max(int(net_u.throughput() * U), 1))
    mu_del = net_u.expected_delays()
    ub = generalized_bound(optimal_eta(u, mu_del, ku), u, mu_del, ku)
    return SamplingResult(p=p_vec, eta=eta, bound=bound, uniform_bound=ub, m=m)
