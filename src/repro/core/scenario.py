"""Scenario library: phase-type service laws + Markov-modulated availability.

The paper's closed-Jackson analysis assumes exponential service at
always-on clients.  Real deployments (FLGo's system simulator, the
staleness/frequency analysis of arXiv:2502.08206) add two effects on
top of that:

* **non-exponential responsiveness** — client round times with squared
  coefficient of variation (SCV) below 1 (Erlang-like, deterministic-ish
  compute) or above 1 (hyperexponential, heavy-tailed stragglers);
* **time-varying availability** — clients cycle through on/off (or
  degraded) states independently of the training process.

Both stay *memoryless at every instant* when expressed the right way,
which is what lets the device engine keep its single inverse-CDF race:

* A phase-type service law is a k-stage chain of exponential clocks.
  We restrict to **deterministic-exit chains**: stage ``i`` fires at
  rate ``rates[i]``; on firing it either absorbs (service completes,
  ``absorb[i]``) or moves to a fixed next stage ``nxt[i]``.  This covers
  exponential (1 stage), Erlang-k (k stages in series) and
  hyperexponential (a mixture over single absorbing stages via the
  initial distribution ``alpha``) exactly, and keeps the device decode
  branch-free: the race winner's event is "stage advance" or
  "completion" by a single table lookup.
* Markov-modulated availability is a per-node 2-state chain
  (on -> off at ``off_rate``, off -> on at ``on_rate``).  While "off" a
  node serves at ``rate_scale`` times its nominal rate — ``0.0``
  recovers PR 6's hard on/off suspension, ``0 < rate_scale < 1`` models
  degraded (throttled / contended) service.

Chains are normalized to **unit mean** so that node ``i`` keeps mean
service time ``1/mu_i`` when fully available; the scenario reshapes the
distribution around that mean, never the mean itself.  This is what
keeps ``estimate_mu`` comparable across scenarios.

This module is numpy-only (no jax) so config/registry code stays
importable everywhere; `stream_device.resolve_scenario` lifts a
``ScenarioConfig`` onto the device.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any

import numpy as np

__all__ = [
    "ServiceLaw",
    "ModulationConfig",
    "ScenarioConfig",
    "SCENARIOS",
    "register_scenario",
    "get_scenario",
    "list_scenarios",
]


# ---------------------------------------------------------------------------
# Service laws (phase-type, deterministic-exit chains)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ServiceLaw:
    """A unit-mean phase-type service law as a deterministic-exit chain.

    ``kind`` selects the family:

    * ``"exp"`` — a single absorbing stage (the engine's default law).
    * ``"erlang"`` — ``shape`` stages in series, each at rate ``shape``
      (unit mean, SCV = 1/shape).
    * ``"hyperexp"`` — a mixture of single absorbing stages: branch ``i``
      is taken with probability ``branch_probs[i]`` and absorbs at rate
      ``branch_rates[i]``; rates are rescaled to unit mean in
      :meth:`chain`.
    """

    kind: str = "exp"
    shape: int = 1
    branch_probs: tuple[float, ...] = ()
    branch_rates: tuple[float, ...] = ()

    def __post_init__(self) -> None:
        if self.kind not in ("exp", "erlang", "hyperexp"):
            raise ValueError(f"unknown service law kind {self.kind!r}")
        if self.kind == "erlang" and self.shape < 1:
            raise ValueError("erlang shape must be >= 1")
        if self.kind == "hyperexp":
            p = np.asarray(self.branch_probs, dtype=np.float64)
            r = np.asarray(self.branch_rates, dtype=np.float64)
            if p.ndim != 1 or p.shape != r.shape or p.size == 0:
                raise ValueError("hyperexp needs matching non-empty branch_probs/branch_rates")
            if np.any(p < 0) or not np.isclose(p.sum(), 1.0, atol=1e-9):
                raise ValueError("branch_probs must be a probability vector")
            if np.any(r <= 0):
                raise ValueError("branch_rates must be positive")
        # Normalize tuple fields so equality / hashing is value-based.
        object.__setattr__(self, "branch_probs", tuple(float(x) for x in self.branch_probs))
        object.__setattr__(self, "branch_rates", tuple(float(x) for x in self.branch_rates))

    # -- constructors -------------------------------------------------------

    @classmethod
    def exponential(cls) -> "ServiceLaw":
        return cls(kind="exp")

    @classmethod
    def erlang(cls, k: int) -> "ServiceLaw":
        return cls(kind="erlang", shape=int(k))

    @classmethod
    def hyperexp(cls, probs, rates) -> "ServiceLaw":
        return cls(kind="hyperexp", branch_probs=tuple(probs), branch_rates=tuple(rates))

    @classmethod
    def hyperexp_scv(cls, scv: float) -> "ServiceLaw":
        """Balanced-means 2-phase hyperexponential with the given SCV > 1.

        The standard construction: ``p1 = (1 + sqrt((scv-1)/(scv+1)))/2``,
        branch rates ``2*p_i`` (each branch contributes half the mean).
        """
        if scv <= 1.0:
            raise ValueError("hyperexp_scv needs scv > 1 (use erlang for scv < 1)")
        p1 = 0.5 * (1.0 + np.sqrt((scv - 1.0) / (scv + 1.0)))
        p2 = 1.0 - p1
        return cls.hyperexp((p1, p2), (2.0 * p1, 2.0 * p2))

    # -- chain construction --------------------------------------------------

    def chain(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Return ``(alpha, rates, absorb, nxt)`` arrays, unit-mean normalized.

        ``alpha`` is the initial-stage distribution, ``rates[i] > 0`` the
        stage-``i`` clock rate, ``absorb[i]`` whether firing stage ``i``
        completes service, ``nxt[i]`` the successor stage otherwise.
        """
        if self.kind == "exp":
            alpha = np.array([1.0])
            rates = np.array([1.0])
            absorb = np.array([1], dtype=np.int32)
            nxt = np.array([0], dtype=np.int32)
        elif self.kind == "erlang":
            k = self.shape
            alpha = np.zeros(k)
            alpha[0] = 1.0
            rates = np.full(k, float(k))
            absorb = np.zeros(k, dtype=np.int32)
            absorb[-1] = 1
            nxt = np.arange(1, k + 1, dtype=np.int32) % k
        else:  # hyperexp: each branch is a single absorbing stage
            alpha = np.asarray(self.branch_probs, dtype=np.float64)
            rates = np.asarray(self.branch_rates, dtype=np.float64)
            absorb = np.ones(alpha.size, dtype=np.int32)
            nxt = np.zeros(alpha.size, dtype=np.int32)
            # Rescale to unit mean: E[T] = sum_i alpha_i / rates_i.
            rates = rates * float(np.sum(alpha / rates))
        _validate_chain(alpha, rates, absorb, nxt)
        return alpha, rates, absorb, nxt

    # -- moments -------------------------------------------------------------

    def moments(self) -> tuple[float, float]:
        """Return ``(E[T], E[T^2])`` of the (unit-mean) law via path following."""
        return chain_moments(*self.chain())

    def scv(self) -> float:
        """Squared coefficient of variation ``Var[T]/E[T]^2``."""
        m1, m2 = self.moments()
        return (m2 - m1 * m1) / (m1 * m1)

    def to_dict(self) -> dict[str, Any]:
        return {
            "kind": self.kind,
            "shape": self.shape,
            "branch_probs": list(self.branch_probs),
            "branch_rates": list(self.branch_rates),
        }

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "ServiceLaw":
        return cls(
            kind=d["kind"],
            shape=int(d.get("shape", 1)),
            branch_probs=tuple(d.get("branch_probs", ())),
            branch_rates=tuple(d.get("branch_rates", ())),
        )


def _validate_chain(alpha, rates, absorb, nxt) -> None:
    alpha = np.asarray(alpha, dtype=np.float64)
    rates = np.asarray(rates, dtype=np.float64)
    absorb = np.asarray(absorb)
    nxt = np.asarray(nxt)
    S = rates.size
    if not (alpha.shape == rates.shape == absorb.shape == nxt.shape):
        raise ValueError("chain arrays must share one shape (S,)")
    if np.any(alpha < 0) or not np.isclose(alpha.sum(), 1.0, atol=1e-9):
        raise ValueError("alpha must be a probability vector")
    if np.any(rates <= 0) or not np.all(np.isfinite(rates)):
        raise ValueError("stage rates must be positive and finite")
    if np.any((nxt < 0) | (nxt >= S)):
        raise ValueError("nxt indices out of range")
    # Every stage with alpha mass must reach absorption within S hops
    # (deterministic-exit chains cannot cycle before absorbing).
    for s0 in range(S):
        if alpha[s0] <= 0:
            continue
        s = int(s0)
        for _ in range(S):
            if absorb[s]:
                break
            s = int(nxt[s])
        else:
            raise ValueError(f"stage {s0} never absorbs (chain cycles)")


def chain_moments(alpha, rates, absorb, nxt) -> tuple[float, float]:
    """``(E[T], E[T^2])`` of a deterministic-exit phase chain.

    A path from start stage ``s0`` is a fixed sequence of independent
    exponential stages, so conditionally ``E[T] = sum 1/r`` and
    ``Var[T] = sum 1/r^2``; mix over ``alpha``.
    """
    alpha = np.asarray(alpha, dtype=np.float64)
    rates = np.asarray(rates, dtype=np.float64)
    absorb = np.asarray(absorb)
    nxt = np.asarray(nxt)
    S = rates.size
    mean = 0.0
    m2 = 0.0
    for s0 in range(S):
        if alpha[s0] <= 0:
            continue
        m1p = 0.0
        varp = 0.0
        s = int(s0)
        for _ in range(S):
            m1p += 1.0 / rates[s]
            varp += 1.0 / rates[s] ** 2
            if absorb[s]:
                break
            s = int(nxt[s])
        else:
            raise ValueError(f"stage {s0} never absorbs")
        mean += alpha[s0] * m1p
        m2 += alpha[s0] * (varp + m1p * m1p)
    return float(mean), float(m2)


# ---------------------------------------------------------------------------
# Markov-modulated availability
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModulationConfig:
    """Per-node 2-state availability chain with a degraded-service scale.

    ``off_rate`` (on -> off) and ``on_rate`` (off -> on) are scalars or
    per-node tuples; ``rate_scale`` multiplies the service rate while a
    node is off (``0.0`` = hard suspension, the `FaultConfig` on/off
    semantics; ``0 < rate_scale < 1`` = throttled).
    """

    off_rate: float | tuple[float, ...] = 0.0
    on_rate: float | tuple[float, ...] = 0.0
    rate_scale: float = 0.0

    def __post_init__(self) -> None:
        for name in ("off_rate", "on_rate"):
            v = getattr(self, name)
            if isinstance(v, (list, np.ndarray)):
                object.__setattr__(self, name, tuple(float(x) for x in np.asarray(v).ravel()))
            elif not isinstance(v, tuple):
                object.__setattr__(self, name, float(v))
        if not (0.0 <= float(self.rate_scale) <= 1.0):
            raise ValueError("rate_scale must be in [0, 1]")
        object.__setattr__(self, "rate_scale", float(self.rate_scale))

    def resolve(self, n: int) -> tuple[np.ndarray, np.ndarray]:
        """Broadcast ``(off_rate, on_rate)`` to validated float64 ``(n,)``."""
        out = []
        for name in ("off_rate", "on_rate"):
            r = np.broadcast_to(np.asarray(getattr(self, name), dtype=np.float64), (n,)).copy()
            if np.any(~np.isfinite(r)) or np.any(r < 0):
                raise ValueError(f"{name} must be finite and >= 0")
            out.append(r)
        return out[0], out[1]

    @property
    def enabled(self) -> bool:
        off = np.asarray(self.off_rate, dtype=np.float64)
        return bool(np.any(off > 0))

    def stationary_on(self, n: int = 1) -> np.ndarray:
        """Stationary probability of the on state, ``q_on / (q_on + q_off)``."""
        q_off, q_on = self.resolve(n)
        tot = q_on + q_off
        return np.where(tot > 0, q_on / np.maximum(tot, 1e-300), 1.0)

    def to_dict(self) -> dict[str, Any]:
        def _val(v):
            return list(v) if isinstance(v, tuple) else v

        return {
            "off_rate": _val(self.off_rate),
            "on_rate": _val(self.on_rate),
            "rate_scale": self.rate_scale,
        }

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "ModulationConfig":
        def _val(v):
            return tuple(v) if isinstance(v, list) else v

        return cls(
            off_rate=_val(d.get("off_rate", 0.0)),
            on_rate=_val(d.get("on_rate", 0.0)),
            rate_scale=float(d.get("rate_scale", 0.0)),
        )


# ---------------------------------------------------------------------------
# ScenarioConfig + registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ScenarioConfig:
    """A named (service law, availability modulation) pair.

    ``enabled`` is False for the exponential + always-on scenario: every
    entry point routes that case through the unmodified engine path, so
    the default scenario is bitwise-identical to not passing one at all.
    """

    name: str = "exponential"
    service: ServiceLaw = field(default_factory=ServiceLaw)
    modulation: ModulationConfig | None = None

    @property
    def enabled(self) -> bool:
        mod_on = self.modulation is not None and self.modulation.enabled
        return self.service.kind != "exp" or mod_on

    def cache_key(self) -> tuple:
        """Hashable identity for jit-cache memoization."""
        sl = self.service
        mod = self.modulation
        mod_key = None if mod is None else (mod.off_rate, mod.on_rate, mod.rate_scale)
        return (self.name, sl.kind, sl.shape, sl.branch_probs, sl.branch_rates, mod_key)

    def to_dict(self) -> dict[str, Any]:
        return {
            "name": self.name,
            "service": self.service.to_dict(),
            "modulation": None if self.modulation is None else self.modulation.to_dict(),
        }

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "ScenarioConfig":
        mod = d.get("modulation")
        return cls(
            name=d["name"],
            service=ServiceLaw.from_dict(d["service"]),
            modulation=None if mod is None else ModulationConfig.from_dict(mod),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ScenarioConfig":
        return cls.from_dict(json.loads(s))


SCENARIOS: dict[str, ScenarioConfig] = {}


def register_scenario(cfg: ScenarioConfig, overwrite: bool = False) -> ScenarioConfig:
    if cfg.name in SCENARIOS and not overwrite:
        raise ValueError(f"scenario {cfg.name!r} already registered")
    SCENARIOS[cfg.name] = cfg
    return cfg


def get_scenario(s: "str | ScenarioConfig | None") -> ScenarioConfig | None:
    """Resolve a registry name / config / None to a ScenarioConfig (or None)."""
    if s is None:
        return None
    if isinstance(s, ScenarioConfig):
        return s
    if isinstance(s, str):
        if s not in SCENARIOS:
            raise KeyError(f"unknown scenario {s!r}; known: {sorted(SCENARIOS)}")
        return SCENARIOS[s]
    raise TypeError(f"scenario must be a name, ScenarioConfig, or None, got {type(s)}")


def list_scenarios() -> list[str]:
    return sorted(SCENARIOS)


# Built-in registry.  Modulation rates are in units of the nominal service
# rate (mu ~ 1): the on/off entries hold ~75% stationary availability with
# O(1) sojourns, so the chain mixes well inside typical T ~ 1e4 runs.
register_scenario(ScenarioConfig(name="exponential"))
register_scenario(ScenarioConfig(name="erlang2", service=ServiceLaw.erlang(2)))
register_scenario(ScenarioConfig(name="erlang4", service=ServiceLaw.erlang(4)))
register_scenario(ScenarioConfig(name="hyperexp2", service=ServiceLaw.hyperexp_scv(4.0)))
register_scenario(
    ScenarioConfig(
        name="onoff",
        modulation=ModulationConfig(off_rate=0.5, on_rate=1.5, rate_scale=0.0),
    )
)
register_scenario(
    ScenarioConfig(
        name="onoff_slow",
        modulation=ModulationConfig(off_rate=0.5, on_rate=1.5, rate_scale=0.25),
    )
)
register_scenario(
    ScenarioConfig(
        name="erlang2_onoff",
        service=ServiceLaw.erlang(2),
        modulation=ModulationConfig(off_rate=0.5, on_rate=1.5, rate_scale=0.0),
    )
)
