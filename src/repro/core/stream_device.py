"""JAX-native closed-Jackson-network simulator + adaptive sampling control.

`queue_sim.ClosedNetworkSim` is the host-side oracle: exact, per-event
Python.  This module is the same closed network as a *device-resident* scan
step, so the event stream can be generated inside the compiled training
program (`engine_scan.make_runner(stream="device")`) instead of being
pre-simulated on the host and replayed:

  * `StreamState` carries per-node queue occupancy, fixed-shape ``(n, C)``
    FIFO ring buffers of slot ids and per-node head/tail counters — the
    device analogue of the host simulator's deques;
  * `stream_step` advances one CS step: the exponential completion race is
    sampled by inverse-CDF over the busy-rate vector (the same distribution
    as ``categorical(mu * busy_mask)``, but driven by pre-drawn uniform
    blocks — per-step Gumbel/threefry sampling costs ~10x more on CPU), the
    dispatch draw comes from ``p`` the same way, and the step emits the same
    ``(J, K, t, slot)`` tuple `queue_sim.EventStream` carries;
  * `StatsState`/`stats_step` accumulate running occupancy, busy time,
    completion counts and FIFO delays on device — the observables the
    adaptive control loop (and the parity tests) consume;
  * the control-plane section ports the exact Jackson analysis to ``jnp``:
    `mva_throughput_delays` (Mean Value Analysis — mathematically identical
    to Buzen-based `jackson.JacksonNetwork.expected_delays`, but a C-length
    scan of O(n) vectorized ops), `optimal_eta_jnp` (cubic stationary point
    by guarded Newton + the Theorem-1 cap) and `make_bound_value_and_grad`
    (the Theorem-1 objective G(p, eta*(p)) with exact simplex gradients via
    AD through the MVA recurrence — the `jnp` port of
    `sampling.bound_value_and_grad`);
  * `ctrl_refresh` is one control-loop update: re-estimate per-node service
    rates from observed (completions, busy time), then take a few
    exponentiated-gradient steps on the bound — `sampling.optimize_general`
    running *inside* the compiled program on *measured* rates.

Only exponential service is supported on device (the race relies on
memorylessness); ``service="det"`` stays host-only.  The stream is
deterministic given the PRNG key but does **not** reproduce the host
simulator's realization — law-level parity is locked in
tests/test_stream_device.py (chi-square, Little's law, delay means).
"""
from __future__ import annotations

from functools import lru_cache
from typing import Any, NamedTuple

import numpy as np

from .queue_sim import (
    KIND_COMPLETE,
    KIND_CRASH,
    KIND_FLIP,
    KIND_TIMEOUT,
    EventBlocks,
    EventStream,
    FaultConfig,
)
from .theory import BoundConstants

__all__ = [
    "StreamState",
    "StatsState",
    "Event",
    "stream_init",
    "stream_step",
    "fault_stream_step",
    "resolve_fault_rates",
    "stats_init",
    "stats_step",
    "fault_stats_step",
    "stats_stream_fn",
    "generate_stream",
    "generate_blocks",
    "mva_throughput_delays",
    "optimal_eta_jnp",
    "generalized_bound_jnp",
    "make_bound_value_and_grad",
    "ctrl_refresh",
    "estimate_mu",
]


class StreamState(NamedTuple):
    """Device state of the closed network (one scenario)."""

    occ: Any    # (n,) int32 — queue length per node (X_i)
    ring: Any   # (n, C) int32 — FIFO ring buffer of slot ids per node
    head: Any   # (n,) int32 — pop counter per node (ring index = head % C)
    tail: Any   # (n,) int32 — push counter per node
    t: Any      # () float32 — physical time
    avail: Any = None  # (n,) float32 0/1 availability (fault mode; else None)


class Event(NamedTuple):
    """One CS step, as emitted by `stream_step` (matches EventStream columns)."""

    j: Any      # completing client J_k
    k: Any      # newly sampled client K_{k+1}
    t: Any      # physical completion time
    slot: Any   # ring-buffer slot of the completing task (freed & reused)
    dt: Any     # time since the previous CS step
    kind: Any = KIND_COMPLETE  # KIND_* tag; constant 0 on fault-free streams


class StatsState(NamedTuple):
    """Running observables accumulated on device (one scenario)."""

    occ_sum: Any    # (n,) int32 — sum over steps of post-step X_{i,k} (Palm)
    occ_tw: Any     # (n,) float32 — time-weighted integral of X_i(t)
    busy_t: Any     # (n,) float32 — integral of 1{X_i > 0} dt (fault mode:
                    # gated on availability, so mu MLEs stay unbiased)
    comp: Any       # (n,) int32 — completions per node
    delay_sum: Any  # (n,) float32 — sum of CS-step delays per node
    slot_step: Any  # (C,) int32 — dispatch step of the task in each slot
    avail_tw: Any = None    # (n,) float32 — integral of availability (faults)
    kind_count: Any = None  # (4,) int32 — events per KIND_* tag (faults)


def stream_init(key, n: int, C: int, p, init: str = "distinct",
                fault: bool = False):
    """Initial placement of the C tasks.  Returns (state, init_nodes).

    ``"distinct"`` places the tasks on C distinct clients (uniform random
    subset; round-robin when C > n), ``"sampled"`` draws C iid clients from
    ``p`` — the same two conventions as `queue_sim.SimConfig.initial`.
    """
    import jax
    import jax.numpy as jnp

    if init == "distinct":
        if C <= n:
            nodes = jax.random.permutation(key, n)[:C].astype(jnp.int32)
        else:
            nodes = (jnp.arange(C, dtype=jnp.int32) % n)
    elif init == "sampled":
        cdf = jnp.cumsum(p)
        u = jax.random.uniform(key, (C,))
        nodes = jnp.minimum(
            jnp.searchsorted(cdf, u, side="right"), n - 1
        ).astype(jnp.int32)
    else:
        raise ValueError(init)
    # FIFO position of task s at its node = number of earlier tasks there
    eq = nodes[None, :] == nodes[:, None]
    pos = jnp.sum(jnp.tril(eq, -1), axis=1).astype(jnp.int32)
    occ = jnp.zeros(n, jnp.int32).at[nodes].add(1)
    ring = jnp.zeros((n, C), jnp.int32).at[nodes, pos].set(
        jnp.arange(C, dtype=jnp.int32)
    )
    state = StreamState(
        occ=occ,
        ring=ring,
        head=jnp.zeros(n, jnp.int32),
        tail=occ,
        t=jnp.float32(0.0),
        avail=jnp.ones(n, jnp.float32) if fault else None,
    )
    return state, nodes


def stream_step(state: StreamState, mu, xs) -> tuple[StreamState, Event]:
    """One CS step of the closed network.

    ``xs = (u_race, u_exp, k_new)``: two pre-drawn uniforms (completion race
    and holding time) plus the pre-sampled dispatch target K_{k+1} ~ p.  With
    exponential service the network is a CTMC, so given the occupancy the
    next completion is at node j w.p. mu_j 1{X_j>0} / sum(...) after an
    Exp(sum) holding time — no per-node residual clocks needed.
    """
    import jax.numpy as jnp

    u_race, u_exp, k_new = xs
    occ, ring, head, tail, t = (
        state.occ, state.ring, state.head, state.tail, state.t,
    )
    n, C = ring.shape
    rates = jnp.where(occ > 0, mu, 0.0)
    cr = jnp.cumsum(rates)
    tot = cr[-1]
    dt = -jnp.log1p(-u_exp) / tot
    t = t + dt
    j = jnp.minimum(
        jnp.searchsorted(cr, u_race * tot, side="right"), n - 1
    ).astype(jnp.int32)
    # pop the oldest in-flight task at j; its freed slot hosts the dispatch
    s = ring[j, head[j] % C]
    head = head.at[j].add(1)
    occ = occ.at[j].add(-1)
    ring = ring.at[k_new, tail[k_new] % C].set(s)
    tail = tail.at[k_new].add(1)
    occ = occ.at[k_new].add(1)
    return (
        StreamState(occ=occ, ring=ring, head=head, tail=tail, t=t),
        Event(j=j, k=k_new, t=t, slot=s, dt=dt),
    )


def resolve_fault_rates(fault: FaultConfig, n: int):
    """`FaultConfig` -> jnp ``(kappa, theta, q_off, q_on)`` float32 arrays,
    the operand order `fault_stream_step` races over."""
    import jax.numpy as jnp

    q_off, q_on, kappa, theta = fault.resolve(n)
    return (jnp.asarray(kappa, jnp.float32), jnp.asarray(theta, jnp.float32),
            jnp.asarray(q_off, jnp.float32), jnp.asarray(q_on, jnp.float32))


def fault_stream_step(state: StreamState, mu, fr, xs):
    """One merged-CTMC event of the faulty closed network.

    Identical machinery to `stream_step`, but the inverse-CDF race runs over
    ``4n`` competing exponential clocks instead of ``n``:

      ``[ mu_i a_i 1{X_i>0} | kappa_i a_i 1{X_i>0} | theta_i 1{X_i>0} |
         q_off_i a_i + q_on_i (1 - a_i) ]``

    — completions, crashes, straggler timeouts (deadlines are server-side,
    so they fire even while the node is off) and availability flips, with
    ``a`` the 0/1 availability vector.  Everything stays memoryless, so one
    pre-drawn uniform pair per step still suffices.  The winning index
    decodes as ``kind = idx // n``, ``node = idx % n``.

    Task movements (kind < 3) pop the head-of-line slot at ``node`` and
    re-dispatch it at the pre-sampled ``k_new``; flips toggle availability,
    emit ``slot = C`` (the trash row — every consumer scatter drops it) and
    leave the queues untouched.  ``fr = resolve_fault_rates(...)``.
    """
    import jax.numpy as jnp

    kappa, theta, q_off, q_on = fr
    u_race, u_exp, k_new = xs
    occ, ring, head, tail, t, avail = (
        state.occ, state.ring, state.head, state.tail, state.t, state.avail,
    )
    n, C = ring.shape
    busy = occ > 0
    r_comp = jnp.where(busy, mu * avail, 0.0)
    r_crash = jnp.where(busy, kappa * avail, 0.0)
    r_tmo = jnp.where(busy, theta, 0.0)
    r_flip = jnp.where(avail > 0, q_off, q_on)
    rates = jnp.concatenate([r_comp, r_crash, r_tmo, r_flip])
    cr = jnp.cumsum(rates)
    tot = jnp.maximum(cr[-1], 1e-30)  # all-off + no clocks: time still moves
    dt = -jnp.log1p(-u_exp) / tot
    t = t + dt
    idx = jnp.minimum(
        jnp.searchsorted(cr, u_race * tot, side="right"), 4 * n - 1
    ).astype(jnp.int32)
    kind = idx // n
    j = idx % n
    move = kind < KIND_FLIP
    # pop the oldest in-flight task at j (no-op on flips: slot -> trash C,
    # head/occ increments masked, push target row n is dropped out-of-bounds)
    s = jnp.where(move, ring[j, head[j] % C], C).astype(jnp.int32)
    mv = move.astype(jnp.int32)
    head = head.at[j].add(mv)
    occ = occ.at[j].add(-mv)
    push_row = jnp.where(move, k_new, n)
    ring = ring.at[push_row, tail[k_new] % C].set(s, mode="drop")
    tail = tail.at[k_new].add(mv)
    occ = occ.at[k_new].add(mv)
    flip = (kind == KIND_FLIP).astype(jnp.float32)
    avail = avail.at[j].add(flip * (1.0 - 2.0 * avail[j]))
    return (
        StreamState(occ=occ, ring=ring, head=head, tail=tail, t=t, avail=avail),
        Event(j=j, k=k_new, t=t, slot=s, dt=dt, kind=kind),
    )


def stats_init(n: int, C: int, fault: bool = False) -> StatsState:
    import jax.numpy as jnp

    return StatsState(
        occ_sum=jnp.zeros(n, jnp.int32),
        occ_tw=jnp.zeros(n, jnp.float32),
        busy_t=jnp.zeros(n, jnp.float32),
        comp=jnp.zeros(n, jnp.int32),
        delay_sum=jnp.zeros(n, jnp.float32),
        slot_step=jnp.zeros(C, jnp.int32),
        avail_tw=jnp.zeros(n, jnp.float32) if fault else None,
        kind_count=jnp.zeros(4, jnp.int32) if fault else None,
    )


def stats_step(stats: StatsState, ev: Event, occ_pre, occ_post, k) -> StatsState:
    """Accumulate observables for step k (0-based).

    ``occ_pre`` is the pre-step occupancy (the state that persisted over
    ``ev.dt`` — its time integral is the quantity product form predicts),
    ``occ_post`` the post-step occupancy (the X_{i,k} the Palm accumulators
    of the host simulator count).
    """
    import jax.numpy as jnp

    delay = (k - stats.slot_step[ev.slot]).astype(jnp.float32)
    return StatsState(
        occ_sum=stats.occ_sum + occ_post,
        occ_tw=stats.occ_tw + occ_pre.astype(jnp.float32) * ev.dt,
        busy_t=stats.busy_t + jnp.where(occ_pre > 0, ev.dt, 0.0),
        comp=stats.comp.at[ev.j].add(1),
        delay_sum=stats.delay_sum.at[ev.j].add(delay),
        slot_step=stats.slot_step.at[ev.slot].set(k + 1),
    )


def fault_stats_step(
    stats: StatsState, ev: Event, occ_pre, avail_pre, occ_post, k
) -> StatsState:
    """Fault-aware `stats_step`.

    Differences: completions / delays only count ``KIND_COMPLETE`` events,
    ``busy_t`` integrates ``1{X_i > 0 and available}`` (the time a node was
    actually serving — dividing completions by it keeps `estimate_mu`
    unbiased under churn), and the availability integral plus per-kind event
    counts accumulate.  Slot bookkeeping is shared: any task movement
    refreshes ``slot_step`` (crash/timeout re-dispatches reset staleness);
    flips carry ``slot == C`` so their scatters drop out of bounds.
    """
    import jax.numpy as jnp

    comp = (ev.kind == KIND_COMPLETE).astype(jnp.int32)
    delay = (k - stats.slot_step[ev.slot]).astype(jnp.float32)
    return StatsState(
        occ_sum=stats.occ_sum + occ_post,
        occ_tw=stats.occ_tw + occ_pre.astype(jnp.float32) * ev.dt,
        busy_t=stats.busy_t
        + jnp.where((occ_pre > 0) & (avail_pre > 0), ev.dt, 0.0),
        comp=stats.comp.at[ev.j].add(comp),
        delay_sum=stats.delay_sum.at[ev.j].add(delay * comp),
        slot_step=stats.slot_step.at[ev.slot].set(k + 1, mode="drop"),
        avail_tw=stats.avail_tw + avail_pre * ev.dt,
        kind_count=stats.kind_count.at[ev.kind].add(1),
    )


def _network_scan(n: int, C: int, T: int, init: str, emit_events: bool,
                  fault: bool = False):
    """Shared scan harness: T fused CS steps of stream_step + stats_step.

    Returns ``gen(key, mu, p) -> (init_nodes, events | None, stats)`` where
    ``events = (J, K, t, slot, delay)`` arrays when ``emit_events`` (the
    exportable stream) and None otherwise (the cheaper stats-only pass the
    adaptive control loop and the stream benchmarks consume).  With
    ``fault``, the generator signature grows a trailing
    ``fr = resolve_fault_rates(...)`` operand, the per-step machinery swaps
    to `fault_stream_step` / `fault_stats_step`, and the emitted events gain
    a trailing kind column.
    """
    import jax
    import jax.numpy as jnp

    def gen(key, mu, p, fr=None):
        k_init, k_race, k_exp, k_disp = jax.random.split(key, 4)
        state, init_nodes = stream_init(k_init, n, C, p, init=init, fault=fault)
        u_race = jax.random.uniform(k_race, (T,))
        u_exp = jax.random.uniform(k_exp, (T,))
        # all T dispatch draws in one vectorized inverse-CDF op
        K = jnp.minimum(
            jnp.searchsorted(jnp.cumsum(p), jax.random.uniform(k_disp, (T,)),
                             side="right"),
            n - 1,
        ).astype(jnp.int32)
        stats = stats_init(n, C, fault=fault)

        def body(carry, xs):
            state, stats, k = carry
            occ_pre = state.occ
            if fault:
                avail_pre = state.avail
                state, ev = fault_stream_step(state, mu, fr, xs)
                delay = k - stats.slot_step[ev.slot]
                stats = fault_stats_step(
                    stats, ev, occ_pre, avail_pre, state.occ, k
                )
                ys = (
                    (ev.j, ev.k, ev.t, ev.slot, delay, ev.kind)
                    if emit_events else None
                )
            else:
                state, ev = stream_step(state, mu, xs)
                delay = k - stats.slot_step[ev.slot]  # before stats_step moves it
                stats = stats_step(stats, ev, occ_pre, state.occ, k)
                ys = (ev.j, ev.k, ev.t, ev.slot, delay) if emit_events else None
            return (state, stats, k + 1), ys

        carry = (state, stats, jnp.int32(0))
        (state, stats, _), events = jax.lax.scan(body, carry, (u_race, u_exp, K))
        return init_nodes, events, stats

    return gen


@lru_cache(maxsize=32)
def _stream_generator(n: int, C: int, T: int, init: str, fault: bool = False):
    import jax

    return jax.jit(_network_scan(n, C, T, init, emit_events=True, fault=fault))


@lru_cache(maxsize=32)
def stats_stream_fn(n: int, C: int, T: int, init: str = "distinct",
                    fault: bool = False):
    """Stats-only fused network scan: ``gen(key, mu, p[, fr]) -> StatsState``.

    No per-event outputs — just the running occupancy / busy-time /
    completion / delay accumulators.  Returned un-jitted so callers compose
    it with vmap/pmap over scenarios before compiling.
    """
    base = _network_scan(n, C, T, init, emit_events=False, fault=fault)
    if fault:
        return lambda key, mu, p, fr: base(key, mu, p, fr)[2]
    return lambda key, mu, p: base(key, mu, p)[2]


def generate_stream(
    mu,
    p,
    C: int,
    T: int,
    seed: int | Any = 0,
    init: str = "distinct",
    fault: FaultConfig | None = None,
) -> EventStream:
    """Simulate T CS steps on device and export a host `EventStream`.

    Drop-in replacement for `queue_sim.export_stream` (exponential service
    only): same arrays, same invariants, different — but law-identical —
    realization.  ``seed`` may be an int or a PRNG key.  The jitted
    generator is cached per (n, C, T, init, faults-on), so sweeps over
    (mu, p, seed) and fault rates reuse one compiled program.  With
    ``fault`` the stream carries a kind column and T counts merged events
    (flips included) — same convention as `queue_sim.export_stream`.
    """
    import jax
    import jax.numpy as jnp

    mu = np.asarray(mu, dtype=np.float64)
    p = np.asarray(p, dtype=np.float64)
    n = mu.size
    if abs(p.sum() - 1.0) > 1e-8:
        raise ValueError("p must sum to 1")
    key = jax.random.PRNGKey(seed) if np.ndim(seed) == 0 else seed
    faulty = fault is not None and fault.enabled
    gen = _stream_generator(n, int(C), int(T), init, faulty)
    if faulty:
        init_nodes, (J, K, t, slot, delays, kind), stats = gen(
            key, jnp.asarray(mu, jnp.float32), jnp.asarray(p, jnp.float32),
            resolve_fault_rates(fault, n),
        )
        kind_np = np.asarray(kind, np.int8)
    else:
        init_nodes, (J, K, t, slot, delays), stats = gen(
            key, jnp.asarray(mu, jnp.float32), jnp.asarray(p, jnp.float32)
        )
        kind_np = None
    return EventStream(
        J=np.asarray(J, np.int32),
        K=np.asarray(K, np.int32),
        t=np.asarray(t, np.float64),
        slot=np.asarray(slot, np.int32),
        init_nodes=np.asarray(init_nodes, np.int32),
        n=n,
        C=int(C),
        p=p.copy(),
        delay_steps=np.asarray(delays, np.int32),
        queue_len_sum=np.asarray(stats.occ_sum, np.float64),
        queue_len_tw=np.asarray(stats.occ_tw, np.float64),
        kind=kind_np,
    )


def generate_blocks(
    mu,
    p,
    C: int,
    T: int,
    block_size: int,
    seed: int | Any = 0,
    init: str = "distinct",
    cut_every: int = 0,
    method: str = "greedy",
    fault: FaultConfig | None = None,
) -> EventBlocks:
    """Device-generated event stream, segmented into conflict-free blocks.

    The blocked analogue of `generate_stream`: the closed network is
    simulated on device (one compiled scan, cached per shape) and the
    resulting stream is cut into fixed-shape ``(B, E)`` index+mask
    micro-blocks by `queue_sim.segment_blocks` — the feed of the blocked
    scan engine when the events should come from the device generator
    rather than the host simulator.  ``method`` picks the cut placement
    ("greedy" | "dp" — see `queue_sim.segment_blocks`).
    """
    return EventBlocks.from_stream(
        generate_stream(mu, p, C, T, seed=seed, init=init, fault=fault),
        block_size,
        cut_every,
        method,
    )


# ---------------------------------------------------------------------- #
# jnp control plane: exact Jackson analysis + Theorem-1 bound, traceable
# ---------------------------------------------------------------------- #
def mva_throughput_delays(mu, p, C: int, normalized: bool = True):
    """Exact (m, lam) of the closed network via Mean Value Analysis.

    The MVA recurrence over populations M = 1..C

        W = (1 + Q_{M-1}) / mu,   lam_M = M / (p . W),   Q_M = lam_M p W

    computes the arrival-theorem queue lengths Q_{C-1} and throughput
    Lambda(C) without normalizing constants — identical values to the
    Buzen pipeline in `jackson.JacksonNetwork` (locked in tests), but a
    C-step scan of O(n) vectorized ops that AD flows through cheaply.
    Returns ``(m, lam)``: delays in CS steps (Prop. 3 estimate, with the
    (C-1)/C Little's-law normalization by default) and the throughput.
    """
    import jax
    import jax.numpy as jnp

    mu = jnp.asarray(mu)
    p = jnp.asarray(p)
    n = p.shape[0]

    def body(Q, M):
        W = (1.0 + Q) / mu
        lam = M / jnp.dot(p, W)
        return lam * p * W, lam

    Ms = jnp.arange(1, C + 1, dtype=p.dtype)
    Q_C, lams = jax.lax.scan(body, jnp.zeros(n, p.dtype), Ms)
    lam_C = lams[-1]
    if C == 1:
        Q_prev = jnp.zeros(n, p.dtype)
    else:
        # invert the last MVA step: Q_C = lam_C p (1 + Q_{C-1}) / mu
        Q_prev = mu * Q_C / (lam_C * p) - 1.0
    m = lam_C * (Q_prev + 1.0) / mu
    if normalized:
        m = m * (C - 1.0) / C
    return m, lam_C


def generalized_bound_jnp(eta, p, m, k: BoundConstants):
    """G(p, eta) of Eq. (3) — jnp port of `theory.generalized_bound`."""
    import jax.numpy as jnp

    n = p.shape[0]
    n2 = float(n) ** 2
    t1 = k.A / (eta * (k.T + 1))
    t2 = eta * k.L * k.B * jnp.sum(1.0 / (n2 * p))
    t3 = eta**2 * k.L**2 * k.B * k.C * jnp.sum(m / (n2 * p**2))
    return t1 + t2 + t3


def optimal_eta_jnp(p, m, k: BoundConstants, newton_iters: int = 20):
    """argmin_eta G(p, eta) s.t. eta <= eta_max, traceable.

    The stationary point solves 2c eta^3 + b eta^2 = D (unique positive
    root); Newton from eta0 = cbrt(D / 2c) >= root converges monotonically
    (f is convex increasing on eta > 0).  The Theorem-1 cap
    min(a, b) mirrors `theory.eta_max_components`.
    """
    import jax
    import jax.numpy as jnp

    n = p.shape[0]
    n2 = float(n) ** 2
    D = k.A / (k.T + 1)
    b = k.L * k.B * jnp.sum(1.0 / (n2 * p))
    c = k.L**2 * k.B * k.C * jnp.sum(m / (n2 * p**2))

    eta0 = jnp.cbrt(D / (2.0 * c))

    def newton(eta, _):
        f = 2.0 * c * eta**3 + b * eta**2 - D
        fp = 6.0 * c * eta**2 + 2.0 * b * eta
        return eta - f / fp, None

    eta, _ = jax.lax.scan(newton, eta0, None, length=newton_iters)
    growth = 1.0 + k.rho**2
    m_k = jnp.sum(m / (n2 * p**2))
    a_cap = 1.0 / jnp.sqrt(16.0 * k.L**2 * k.C * m_k * growth)
    b_cap = n2 / (8.0 * k.L * growth * jnp.sum(1.0 / p))
    return jnp.minimum(eta, jnp.minimum(a_cap, b_cap))


@lru_cache(maxsize=32)
def _bound_value_and_grad(k_tuple):
    import jax

    k = BoundConstants(*k_tuple)

    def objective(p, mu):
        m, _ = mva_throughput_delays(mu, p, k.C)
        eta = optimal_eta_jnp(p, m, k)
        return generalized_bound_jnp(eta, p, m, k)

    return jax.value_and_grad(objective)


def make_bound_value_and_grad(k: BoundConstants):
    """(value, grad) of f(p) = G(p, eta*(p)) with delays from MVA — the jnp
    port of `sampling.bound_value_and_grad`.

    The gradient is exact AD through the MVA recurrence (the delay channel),
    the explicit 1/p terms, and eta*(p): when the cubic stationary point is
    interior the eta channel vanishes by the envelope theorem (dG/deta = 0
    there, so the Newton iterates' sensitivity is multiplied by ~0); when
    the cap is active, ``jnp.minimum`` routes the chain rule through the
    active branch — the same case split `sampling.bound_value_and_grad`
    does by hand.  Cached per BoundConstants.
    """
    return _bound_value_and_grad(
        (k.A, k.L, k.B, int(k.C), int(k.T), k.rho)
    )


def estimate_mu(comp, busy_t, prior_weight: float = 1.0,
                floor_frac: float = 1e-3):
    """Per-node service-rate MLE from observed (completions, busy time).

    While a node is busy its completions are Poisson(mu_i), so
    mu_i ~ comp_i / busy_i.  Nodes with little observed busy time shrink
    toward the busy-time-weighted global mean rate (``prior_weight``
    pseudo-completions at the global rate).

    Dead-node safety: a node that recorded zero completions *and* zero busy
    time (unavailable the whole window — or, with ``prior_weight = 0``, any
    idle node) used to produce ``0/0`` or a hard zero, which the MVA delay
    recurrence turns into inf/NaN delays and the control loop into NaN
    sampling weights.  The estimate is therefore floored at ``floor_frac``
    of the global mean rate (itself floored away from zero), so a dark node
    reports as "very slow but finite" and mirror descent pushes its p
    toward the floor instead of diverging.
    """
    import jax.numpy as jnp

    comp = comp.astype(jnp.float32)
    mu_bar = jnp.sum(comp) / jnp.maximum(jnp.sum(busy_t), 1e-20)
    mu_bar = jnp.maximum(mu_bar, 1e-8)  # zero completions everywhere
    est = (comp + prior_weight) / jnp.maximum(
        busy_t + prior_weight / mu_bar, 1e-20
    )
    return jnp.maximum(est, floor_frac * mu_bar)


def ctrl_refresh(
    p,
    comp,
    busy_t,
    k: BoundConstants,
    lr: float = 0.3,
    iters: int = 4,
    floor_scale: float = 1e-5,
):
    """One adaptive-sampling refresh: re-optimize p from running estimates.

    Estimates per-node rates from the observed stream, then takes ``iters``
    exponentiated-gradient steps on the Theorem-1 bound (the same projected
    mirror-descent update as `sampling.optimize_general`, with the analytic
    jnp gradient).  Pure function of device values — traceable, vmappable.

    Control-plane safeguards (faults): `estimate_mu` floors dead-node rate
    estimates, non-finite gradient components are scrubbed to 0 (a single
    poisoned coordinate must not NaN the whole simplex), and each iterate is
    re-floored/renormalized — so nodes that went dark keep a small positive
    sampling weight (bounded importance scales) instead of p collapsing to
    NaN or exact zeros.
    """
    import jax
    import jax.numpy as jnp

    vg = make_bound_value_and_grad(k)
    mu_hat = estimate_mu(comp, busy_t)
    n = p.shape[0]
    floor = floor_scale / n

    def one(p, _):
        _, g = vg(p, mu_hat)
        g = jnp.where(jnp.isfinite(g), g, 0.0)
        g = g - jnp.dot(g, p)
        p = p * jnp.exp(-lr * g / (jnp.max(jnp.abs(g)) + 1e-12))
        p = jnp.where(jnp.isfinite(p), p, floor)
        p = jnp.maximum(p, floor)
        return p / jnp.sum(p), None

    p, _ = jax.lax.scan(one, p, None, length=iters)
    return p
