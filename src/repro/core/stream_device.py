"""JAX-native closed-Jackson-network simulator + adaptive sampling control.

`queue_sim.ClosedNetworkSim` is the host-side oracle: exact, per-event
Python.  This module is the same closed network as a *device-resident* scan
step, so the event stream can be generated inside the compiled training
program (`engine_scan.make_runner(stream="device")`) instead of being
pre-simulated on the host and replayed:

  * `StreamState` carries per-node queue occupancy, fixed-shape ``(n, C)``
    FIFO ring buffers of slot ids and per-node head/tail counters — the
    device analogue of the host simulator's deques;
  * `stream_step` advances one CS step: the exponential completion race is
    sampled by inverse-CDF over the busy-rate vector (the same distribution
    as ``categorical(mu * busy_mask)``, but driven by pre-drawn uniform
    blocks — per-step Gumbel/threefry sampling costs ~10x more on CPU), the
    dispatch draw comes from ``p`` the same way, and the step emits the same
    ``(J, K, t, slot)`` tuple `queue_sim.EventStream` carries;
  * `StatsState`/`stats_step` accumulate running occupancy, busy time,
    completion counts and FIFO delays on device — the observables the
    adaptive control loop (and the parity tests) consume;
  * the control-plane section ports the exact Jackson analysis to ``jnp``:
    `mva_throughput_delays` (Mean Value Analysis — mathematically identical
    to Buzen-based `jackson.JacksonNetwork.expected_delays`, but a C-length
    scan of O(n) vectorized ops), `optimal_eta_jnp` (cubic stationary point
    by guarded Newton + the Theorem-1 cap) and `make_bound_value_and_grad`
    (the Theorem-1 objective G(p, eta*(p)) with exact simplex gradients via
    AD through the MVA recurrence — the `jnp` port of
    `sampling.bound_value_and_grad`);
  * `ctrl_refresh` is one control-loop update: re-estimate per-node service
    rates from observed (completions, busy time), then take a few
    exponentiated-gradient steps on the bound — `sampling.optimize_general`
    running *inside* the compiled program on *measured* rates.

The race relies on memorylessness, which is less restrictive than it
sounds: phase-type service (Erlang-k / hyperexponential chains of
exponential stages) and Markov-modulated on/off availability are both
memoryless at every instant, and `scenario_stream_step` /
`sparse_scenario_stream_step` fold them into the same inverse-CDF race
(see `core.scenario` for the law definitions).  Only ``service="det"``
stays host-only.  The stream is deterministic given the PRNG key but
does **not** reproduce the host simulator's realization — law-level
parity is locked in tests/test_stream_device.py and
tests/test_scenarios.py (chi-square, Little's law, delay means).
"""
from __future__ import annotations

from functools import lru_cache
from typing import Any, NamedTuple

import numpy as np

from .queue_sim import (
    KIND_COMPLETE,
    KIND_CRASH,
    KIND_FLIP,
    KIND_SERVE,
    KIND_STAGE,
    KIND_TIMEOUT,
    N_KINDS,
    EventBlocks,
    EventStream,
    FaultConfig,
)
from .scenario import ModulationConfig, ScenarioConfig
from .theory import BoundConstants

__all__ = [
    "StreamState",
    "StatsState",
    "Event",
    "stream_init",
    "stream_step",
    "fault_stream_step",
    "merged_stream_step",
    "resolve_fault_rates",
    "ScenarioRates",
    "resolve_scenario",
    "resolve_scenario_classes",
    "scenario_stream_init",
    "scenario_stream_step",
    "scenario_stats_step",
    "stats_init",
    "stats_step",
    "fault_stats_step",
    "stats_stream_fn",
    "generate_stream",
    "generate_blocks",
    "tree_build",
    "tree_sample",
    "tree_update",
    "kahan_add",
    "kahan_value",
    "ClassSpec",
    "build_class_spec",
    "resolve_fault_rates_classes",
    "SparseStreamState",
    "sparse_stream_init",
    "sparse_stream_step",
    "sparse_fault_stream_step",
    "sparse_scenario_stream_init",
    "sparse_scenario_stream_step",
    "sparse_scenario_class_stats",
    "sparse_stats_init",
    "sparse_stats_step",
    "sparse_fault_stats_step",
    "sparse_stats_stream_fn",
    "class_occupancy",
    "sample_dispatch_classes",
    "mva_throughput_delays",
    "optimal_eta_jnp",
    "generalized_bound_jnp",
    "make_bound_value_and_grad",
    "ctrl_refresh",
    "estimate_mu",
]


# ---------------------------------------------------------------------- #
# segment-tree CDF sampler: hierarchical sums, descent never lands on a
# zero-weight leaf — the unbiased replacement for cumsum+searchsorted
# ---------------------------------------------------------------------- #
def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


def tree_build(w):
    """Flattened-heap sum tree over the weight vector ``w``.

    Returns a ``(2N,)`` array (N = next power of two >= len(w)) with
    ``tree[1]`` the root total, children of node i at ``2i`` / ``2i+1``
    and the (zero-padded) leaves at ``tree[N:]``.  The root is a pairwise
    (hierarchical) sum, so its rounding error is O(log n) ulps — the
    float32 ``cumsum`` it replaces accumulates O(n) ulps, which visibly
    biases the inverse-CDF race at n >= 1e5.
    """
    import jax.numpy as jnp

    w = jnp.asarray(w)
    n = w.shape[0]
    N = _next_pow2(n)
    level = jnp.zeros(N, w.dtype).at[:n].set(w) if N != n else w
    levels = [level]
    while levels[-1].shape[0] > 1:
        lv = levels[-1]
        levels.append(lv.reshape(-1, 2).sum(axis=1))
    return jnp.concatenate([jnp.zeros(1, w.dtype)] + levels[::-1])


def tree_sample(tree, u):
    """Inverse-CDF draw from a `tree_build` tree; returns a leaf index.

    Descends ``x = u * total`` through the heap: go right iff the left
    mass is exhausted *and* the right subtree has positive mass.  A leaf
    with zero weight is therefore never selected — the clamped
    ``searchsorted`` this replaces rounds ``u * total`` past the last
    cumsum entry and over-selects the final (possibly idle, rate-0)
    index; the descent re-routes that mass to the last positive leaf,
    which is exactly the conditional law given the rounding event.
    """
    import jax.numpy as jnp

    N = tree.shape[0] // 2
    depth = max(N.bit_length() - 1, 0)
    x = u * tree[1]
    idx = jnp.int32(1)
    for _ in range(depth):
        left = tree[2 * idx]
        right = tree[2 * idx + 1]
        go_right = (x >= left) & (right > 0)
        x = jnp.where(go_right, x - left, x)
        idx = 2 * idx + go_right.astype(jnp.int32)
    return idx - N


def tree_update(tree, idx, value):
    """Set leaf ``idx`` to ``value`` and refresh its root path (O(log n))."""
    import jax.numpy as jnp

    N = tree.shape[0] // 2
    depth = max(N.bit_length() - 1, 0)
    pos = jnp.asarray(idx, jnp.int32) + N
    delta = value - tree[pos]
    path = jnp.stack([pos >> d for d in range(depth + 1)])
    return tree.at[path].add(delta)


# ---------------------------------------------------------------------- #
# Kahan-compensated accumulation: float32 time integrals stall once the
# accumulator passes ~2^24 (increments round to zero); a compensated
# (sum, c) pair keeps relative O(eps) accuracy on any backend, x64 or not
# ---------------------------------------------------------------------- #
def kahan_add(s, c, x):
    """One compensated add: returns the new ``(s, c)`` pair.

    The represented total is ``s - c``; ``s`` alone is the correctly
    rounded float32 running sum (safe to read in traced code).
    """
    y = x - c
    t = s + y
    return t, (t - s) - y


def kahan_value(s, c):
    """Host-side exact readout of a compensated pair (float64)."""
    return np.asarray(s, np.float64) - np.asarray(c, np.float64)


def _kahan_scatter_add(s, c, idx, x):
    """Compensated ``s.at[idx].add(x)`` for a single dynamic index."""
    sj, cj = s[idx], c[idx]
    y = x - cj
    t = sj + y
    return s.at[idx].set(t), c.at[idx].set((t - sj) - y)


class StreamState(NamedTuple):
    """Device state of the closed network (one scenario)."""

    occ: Any    # (n,) int32 — queue length per node (X_i)
    ring: Any   # (n, C) int32 — FIFO ring buffer of slot ids per node
    head: Any   # (n,) int32 — pop counter per node (ring index = head % C)
    tail: Any   # (n,) int32 — push counter per node
    t: Any      # () float32 — physical time (Kahan sum; see t_c)
    avail: Any = None  # (n,) float32 0/1 availability (fault mode; else None)
    t_c: Any = 0.0     # () float32 — Kahan compensation of t
    phase: Any = None  # (C,) int32 — service stage of each slot's task
                       # (scenario mode; else None)


class Event(NamedTuple):
    """One CS step, as emitted by `stream_step` (matches EventStream columns)."""

    j: Any      # completing client J_k
    k: Any      # newly sampled client K_{k+1}
    t: Any      # physical completion time
    slot: Any   # ring-buffer slot of the completing task (freed & reused)
    dt: Any     # time since the previous CS step
    kind: Any = KIND_COMPLETE  # KIND_* tag; constant 0 on fault-free streams


class StatsState(NamedTuple):
    """Running observables accumulated on device (one scenario).

    All float accumulators are Kahan pairs (``x`` plus compensation
    ``x_c``; host readout via `kahan_value`) — plain float32 integrals
    stall once they pass ~2^24, corrupting `estimate_mu`/`ctrl_refresh`
    on T ~ 1e8 runs.  In sparse (class-collapsed) mode the per-node
    vectors become per-class ``(m,)`` vectors; field names are shared so
    every consumer reads both layouts.
    """

    occ_sum: Any    # (n,) int32 — sum over steps of post-step X_{i,k} (Palm)
    occ_tw: Any     # (n,) float32 — time-weighted integral of X_i(t)
    busy_t: Any     # (n,) float32 — integral of 1{X_i > 0} dt (fault mode:
                    # gated on availability, so mu MLEs stay unbiased)
    comp: Any       # (n,) int32 — completions per node
    delay_sum: Any  # (n,) float32 — sum of CS-step delays per node
    slot_step: Any  # (C,) int32 — dispatch step of the task in each slot
    avail_tw: Any = None    # (n,) float32 — integral of availability (faults)
    kind_count: Any = None  # (4,) int32 — events per KIND_* tag (faults)
    occ_tw_c: Any = 0.0     # Kahan compensations of the float integrals
    busy_t_c: Any = 0.0
    delay_sum_c: Any = 0.0
    avail_tw_c: Any = None


def stream_init(key, n: int, C: int, p, init: str = "distinct",
                fault: bool = False):
    """Initial placement of the C tasks.  Returns (state, init_nodes).

    ``"distinct"`` places the tasks on C distinct clients (uniform random
    subset; round-robin when C > n), ``"sampled"`` draws C iid clients from
    ``p`` — the same two conventions as `queue_sim.SimConfig.initial`.
    """
    import jax
    import jax.numpy as jnp

    if init == "distinct":
        if C <= n:
            nodes = jax.random.permutation(key, n)[:C].astype(jnp.int32)
        else:
            nodes = (jnp.arange(C, dtype=jnp.int32) % n)
    elif init == "sampled":
        ptree = tree_build(jnp.asarray(p, jnp.float32))
        u = jax.random.uniform(key, (C,))
        nodes = jax.vmap(lambda uu: tree_sample(ptree, uu))(u).astype(jnp.int32)
    else:
        raise ValueError(init)
    # FIFO position of task s at its node = number of earlier tasks there
    eq = nodes[None, :] == nodes[:, None]
    pos = jnp.sum(jnp.tril(eq, -1), axis=1).astype(jnp.int32)
    occ = jnp.zeros(n, jnp.int32).at[nodes].add(1)
    ring = jnp.zeros((n, C), jnp.int32).at[nodes, pos].set(
        jnp.arange(C, dtype=jnp.int32)
    )
    state = StreamState(
        occ=occ,
        ring=ring,
        head=jnp.zeros(n, jnp.int32),
        tail=occ,
        t=jnp.float32(0.0),
        avail=jnp.ones(n, jnp.float32) if fault else None,
    )
    return state, nodes


def stream_step(state: StreamState, mu, xs) -> tuple[StreamState, Event]:
    """One CS step of the closed network.

    ``xs = (u_race, u_exp, k_new)``: two pre-drawn uniforms (completion race
    and holding time) plus the pre-sampled dispatch target K_{k+1} ~ p.  With
    exponential service the network is a CTMC, so given the occupancy the
    next completion is at node j w.p. mu_j 1{X_j>0} / sum(...) after an
    Exp(sum) holding time — no per-node residual clocks needed.  The race is
    sampled by segment-tree descent (`tree_build`/`tree_sample`): pairwise
    sums keep the CDF unbiased at large n and the descent never selects an
    idle (rate-0) node, unlike the clamped float32 cumsum+searchsorted.
    """
    import jax.numpy as jnp

    u_race, u_exp, k_new = xs
    occ, ring, head, tail, t = (
        state.occ, state.ring, state.head, state.tail, state.t,
    )
    n, C = ring.shape
    rates = jnp.where(occ > 0, mu, 0.0)
    rtree = tree_build(rates)
    tot = rtree[1]
    dt = -jnp.log1p(-u_exp) / tot
    t, t_c = kahan_add(t, state.t_c, dt)
    j = tree_sample(rtree, u_race).astype(jnp.int32)
    # pop the oldest in-flight task at j; its freed slot hosts the dispatch
    s = ring[j, head[j] % C]
    head = head.at[j].add(1)
    occ = occ.at[j].add(-1)
    ring = ring.at[k_new, tail[k_new] % C].set(s)
    tail = tail.at[k_new].add(1)
    occ = occ.at[k_new].add(1)
    return (
        StreamState(occ=occ, ring=ring, head=head, tail=tail, t=t, t_c=t_c),
        Event(j=j, k=k_new, t=t, slot=s, dt=dt),
    )


def resolve_fault_rates(fault: FaultConfig, n: int):
    """`FaultConfig` -> jnp ``(kappa, theta, q_off, q_on)`` float32 arrays,
    the operand order `fault_stream_step` races over."""
    import jax.numpy as jnp

    q_off, q_on, kappa, theta = fault.resolve(n)
    return (jnp.asarray(kappa, jnp.float32), jnp.asarray(theta, jnp.float32),
            jnp.asarray(q_off, jnp.float32), jnp.asarray(q_on, jnp.float32))


def fault_stream_step(state: StreamState, mu, fr, xs):
    """One merged-CTMC event of the faulty closed network.

    Identical machinery to `stream_step`, but the inverse-CDF race runs over
    ``4n`` competing exponential clocks instead of ``n``:

      ``[ mu_i a_i 1{X_i>0} | kappa_i a_i 1{X_i>0} | theta_i 1{X_i>0} |
         q_off_i a_i + q_on_i (1 - a_i) ]``

    — completions, crashes, straggler timeouts (deadlines are server-side,
    so they fire even while the node is off) and availability flips, with
    ``a`` the 0/1 availability vector.  Everything stays memoryless, so one
    pre-drawn uniform pair per step still suffices.  The winning index
    decodes as ``kind = idx // n``, ``node = idx % n``.

    Task movements (kind < 3) pop the head-of-line slot at ``node`` and
    re-dispatch it at the pre-sampled ``k_new``; flips toggle availability,
    emit ``slot = C`` (the trash row — every consumer scatter drops it) and
    leave the queues untouched.  ``fr = resolve_fault_rates(...)``.
    """
    import jax.numpy as jnp

    kappa, theta, q_off, q_on = fr
    u_race, u_exp, k_new = xs
    occ, ring, head, tail, t, avail = (
        state.occ, state.ring, state.head, state.tail, state.t, state.avail,
    )
    n, C = ring.shape
    busy = occ > 0
    r_comp = jnp.where(busy, mu * avail, 0.0)
    r_crash = jnp.where(busy, kappa * avail, 0.0)
    r_tmo = jnp.where(busy, theta, 0.0)
    r_flip = jnp.where(avail > 0, q_off, q_on)
    rates = jnp.concatenate([r_comp, r_crash, r_tmo, r_flip])
    rtree = tree_build(rates)
    tot = jnp.maximum(rtree[1], 1e-30)  # all-off + no clocks: time still moves
    dt = -jnp.log1p(-u_exp) / tot
    t, t_c = kahan_add(t, state.t_c, dt)
    idx = tree_sample(rtree, u_race).astype(jnp.int32)
    kind = idx // n
    j = idx % n
    move = kind < KIND_FLIP
    # pop the oldest in-flight task at j (no-op on flips: slot -> trash C,
    # head/occ increments masked, push target row n is dropped out-of-bounds)
    s = jnp.where(move, ring[j, head[j] % C], C).astype(jnp.int32)
    mv = move.astype(jnp.int32)
    head = head.at[j].add(mv)
    occ = occ.at[j].add(-mv)
    push_row = jnp.where(move, k_new, n)
    ring = ring.at[push_row, tail[k_new] % C].set(s, mode="drop")
    tail = tail.at[k_new].add(mv)
    occ = occ.at[k_new].add(mv)
    flip = (kind == KIND_FLIP).astype(jnp.float32)
    avail = avail.at[j].add(flip * (1.0 - 2.0 * avail[j]))
    return (
        StreamState(occ=occ, ring=ring, head=head, tail=tail, t=t,
                    avail=avail, t_c=t_c),
        Event(j=j, k=k_new, t=t, slot=s, dt=dt, kind=kind),
    )


def merged_stream_step(state: StreamState, mu, ext_rate, xs, fr=None):
    """One event of the closed network merged with an external event class.

    ``ext_rate`` is the total rate of an independent open stream (the
    serving plane, `serving.serve_total_rate`) racing against the closed
    network's clocks.  With all clocks exponential the merged system is a
    CTMC: the holding time is ``Exp(r_train + ext_rate)`` and the winner is
    external w.p. ``ext_rate / (r_train + ext_rate)`` — one pre-drawn
    uniform pair still suffices, and the *conditional* uniforms handed to
    each side (``x / r_train`` resp. ``(x - r_train) / ext_rate`` with
    ``x = u_race * tot``) are again uniform, so both sub-races stay exact
    in law.

    External wins leave the queues untouched and emit ``Event(j=n,
    slot=C, kind=KIND_SERVE)`` — the KIND_FLIP masking pattern: training-
    side gathers clamp, scatters drop.  ``fr`` switches the closed side to
    the faulty 4n-clock race of `fault_stream_step`.  Returns
    ``(state', ev, is_ext, u_ext)`` where ``u_ext`` is the external side's
    conditional uniform (garbage unless ``is_ext``).
    """
    import jax.numpy as jnp

    u_race, u_exp, k_new = xs
    occ, ring, head, tail, t, avail = (
        state.occ, state.ring, state.head, state.tail, state.t, state.avail,
    )
    n, C = ring.shape
    faulty = fr is not None
    if faulty:
        kappa, theta, q_off, q_on = fr
        busy = occ > 0
        rates = jnp.concatenate([
            jnp.where(busy, mu * avail, 0.0),
            jnp.where(busy, kappa * avail, 0.0),
            jnp.where(busy, theta, 0.0),
            jnp.where(avail > 0, q_off, q_on),
        ])
    else:
        rates = jnp.where(occ > 0, mu, 0.0)
    rtree = tree_build(rates)
    r_train = jnp.maximum(rtree[1], 1e-30)
    ext = jnp.asarray(ext_rate, jnp.float32)
    tot = r_train + ext
    dt = -jnp.log1p(-u_exp) / tot
    t, t_c = kahan_add(t, state.t_c, dt)
    x = u_race * tot
    is_ext = x >= r_train
    # conditional uniforms: exact given the branch (clipped only against
    # the open boundary so the tree descent stays in range)
    u_train = jnp.clip(x / r_train, 0.0, 1.0 - 1e-7)
    u_ext = jnp.clip((x - r_train) / jnp.maximum(ext, 1e-30),
                     0.0, 1.0 - 1e-7)
    idx = tree_sample(rtree, u_train).astype(jnp.int32)
    if faulty:
        kind = jnp.where(is_ext, KIND_SERVE, idx // n)
        j = idx % n
    else:
        kind = jnp.where(is_ext, KIND_SERVE, KIND_COMPLETE)
        j = idx
    j = jnp.where(is_ext, n, j).astype(jnp.int32)
    move = kind < KIND_FLIP  # excludes flips AND external events
    s = jnp.where(move, ring[j % n, head[j % n] % C], C).astype(jnp.int32)
    mv = move.astype(jnp.int32)
    head = head.at[j].add(mv, mode="drop")
    occ = occ.at[j].add(-mv, mode="drop")
    push_row = jnp.where(move, k_new, n)
    ring = ring.at[push_row, tail[k_new] % C].set(s, mode="drop")
    tail = tail.at[k_new].add(mv)
    occ = occ.at[k_new].add(mv)
    if faulty:
        flip = ((kind == KIND_FLIP) & ~is_ext).astype(jnp.float32)
        avail = avail.at[j].add(flip * (1.0 - 2.0 * avail[j % n]),
                                mode="drop")
    return (
        StreamState(occ=occ, ring=ring, head=head, tail=tail, t=t,
                    avail=avail, t_c=t_c),
        Event(j=j, k=k_new, t=t, slot=s, dt=dt, kind=kind),
        is_ext,
        u_ext,
    )


class ScenarioRates(NamedTuple):
    """Device-resident tables of a resolved `ScenarioConfig`.

    ``acdf`` is the cumulative initial-stage distribution (inverse-CDF
    phase draws), ``srate``/``absorb``/``nxt`` the unit-mean stage chain
    of `ServiceLaw.chain`, ``q_off``/``q_on`` the per-node (dense) or
    per-class (sparse) modulation intensities and ``rate_scale`` the
    degraded-service multiplier while off.
    """

    acdf: Any        # (S,) float32 — cumsum of alpha, tail pinned to 1
    srate: Any       # (S,) float32 — stage clock rates (unit-mean chain)
    absorb: Any      # (S,) float32 — 1.0 where firing completes service
    nxt: Any         # (S,) int32 — successor stage otherwise
    q_off: Any       # (n,)/(m,) float32 — on->off flip intensity
    q_on: Any        # (n,)/(m,) float32 — off->on flip intensity
    rate_scale: Any  # () float32 — service-speed multiplier while off


def _scenario_tables(scenario: ScenarioConfig):
    alpha, srate, absorb, nxt = scenario.service.chain()
    acdf = np.cumsum(alpha)
    acdf[-1] = max(acdf[-1], 1.0)  # guard fp undershoot at the tail
    mod = scenario.modulation if scenario.modulation is not None else ModulationConfig()
    return acdf, srate, absorb, nxt, mod


def resolve_scenario(scenario: ScenarioConfig, n: int) -> ScenarioRates:
    """`ScenarioConfig` -> dense device `ScenarioRates` ((n,) modulation)."""
    import jax.numpy as jnp

    acdf, srate, absorb, nxt, mod = _scenario_tables(scenario)
    q_off, q_on = mod.resolve(n)
    return ScenarioRates(
        acdf=jnp.asarray(acdf, jnp.float32),
        srate=jnp.asarray(srate, jnp.float32),
        absorb=jnp.asarray(absorb, jnp.float32),
        nxt=jnp.asarray(nxt, jnp.int32),
        q_off=jnp.asarray(q_off, jnp.float32),
        q_on=jnp.asarray(q_on, jnp.float32),
        rate_scale=jnp.float32(mod.rate_scale),
    )


def resolve_scenario_classes(scenario: ScenarioConfig, spec: "ClassSpec") -> ScenarioRates:
    """Class-level `resolve_scenario`: modulation rates as ``(m,)`` arrays.

    Modulation must be constant within each speed class — the
    exchangeability the sparse idle pools rely on (same constraint as
    `resolve_fault_rates_classes`)."""
    import jax.numpy as jnp

    acdf, srate, absorb, nxt, mod = _scenario_tables(scenario)
    q_off, q_on = mod.resolve(spec.n)
    perm = np.asarray(spec.perm)
    offsets = np.asarray(spec.offsets)
    counts = np.asarray(spec.counts)
    out = []
    for name, r in (("off_rate", q_off), ("on_rate", q_on)):
        rc = np.asarray(r, np.float64)[perm]
        vals = rc[offsets]
        for c in range(counts.size):
            seg = rc[offsets[c]: offsets[c] + counts[c]]
            if not np.allclose(seg, vals[c]):
                raise ValueError(
                    f"ModulationConfig.{name} varies within speed class {c}; "
                    "the sparse stream requires class-constant modulation"
                )
        out.append(jnp.asarray(vals, jnp.float32))
    return ScenarioRates(
        acdf=jnp.asarray(acdf, jnp.float32),
        srate=jnp.asarray(srate, jnp.float32),
        absorb=jnp.asarray(absorb, jnp.float32),
        nxt=jnp.asarray(nxt, jnp.int32),
        q_off=out[0],
        q_on=out[1],
        rate_scale=jnp.float32(mod.rate_scale),
    )


def _phase_draw(acdf, u):
    """Inverse-CDF initial-stage draw from the (S,) cumulative alpha."""
    import jax.numpy as jnp

    S = acdf.shape[0]
    return jnp.minimum(
        jnp.searchsorted(acdf, u, side="right"), S - 1
    ).astype(jnp.int32)


def scenario_stream_init(key, n: int, C: int, p, sr: ScenarioRates,
                         init: str = "distinct"):
    """`stream_init` + per-slot initial phase draws.  Returns (state, nodes).

    The phase of each task is drawn at dispatch (here: at initial
    placement) — independence of the stage sequence from the queue
    process makes this law-identical to drawing at service start, and it
    matches the host oracle's convention.
    """
    import jax

    k_place, k_ph = jax.random.split(key)
    state, nodes = stream_init(k_place, n, C, p, init=init, fault=True)
    phase = _phase_draw(sr.acdf, jax.random.uniform(k_ph, (C,)))
    return state._replace(phase=phase), nodes


def scenario_stream_step(state: StreamState, mu, sr: ScenarioRates, xs):
    """One merged-CTMC event of the scenario closed network.

    The race runs over ``2n`` clocks:

      ``[ mu_i srate[phase_i] speed_i 1{X_i>0} | q_off_i a_i + q_on_i (1-a_i) ]``

    where ``phase_i`` is the stage of node i's head-of-line task and
    ``speed_i = a_i + (1-a_i) rate_scale`` the modulated service speed.
    A serve-clock win is decoded by ``absorb[phase]`` into either a task
    completion (KIND_COMPLETE — pop + re-dispatch exactly as in
    `fault_stream_step`) or a stage advance (KIND_STAGE — the head task
    steps to ``nxt[phase]``; no queue change, ``slot = C``).  Flips
    toggle availability like fault flips.  ``xs = (u_race, u_exp, k_new,
    u_ph)`` — one extra pre-drawn uniform feeds the dispatch-time phase
    draw of the re-dispatched task.
    """
    import jax.numpy as jnp

    u_race, u_exp, k_new, u_ph = xs
    occ, ring, head, tail, t, avail, phase = (
        state.occ, state.ring, state.head, state.tail, state.t,
        state.avail, state.phase,
    )
    n, C = ring.shape
    busy = occ > 0
    speed = avail + (1.0 - avail) * sr.rate_scale
    head_slots = ring[jnp.arange(n), head % C]
    r_serve = jnp.where(busy, mu * sr.srate[phase[head_slots]] * speed, 0.0)
    r_flip = jnp.where(avail > 0, sr.q_off, sr.q_on)
    rates = jnp.concatenate([r_serve, r_flip])
    rtree = tree_build(rates)
    tot = jnp.maximum(rtree[1], 1e-30)  # all-suspended: time still moves
    dt = -jnp.log1p(-u_exp) / tot
    t, t_c = kahan_add(t, state.t_c, dt)
    idx = tree_sample(rtree, u_race).astype(jnp.int32)
    is_serve = idx < n
    j = idx % n
    s_head = ring[j, head[j] % C]
    ph_j = phase[s_head]
    complete = is_serve & (sr.absorb[ph_j] > 0)
    kind = jnp.where(
        complete, KIND_COMPLETE, jnp.where(is_serve, KIND_STAGE, KIND_FLIP)
    ).astype(jnp.int32)
    # completions pop + re-dispatch; stages and flips emit slot = C (trash)
    move = complete
    s = jnp.where(move, s_head, C).astype(jnp.int32)
    mv = move.astype(jnp.int32)
    head = head.at[j].add(mv)
    occ = occ.at[j].add(-mv)
    push_row = jnp.where(move, k_new, n)
    ring = ring.at[push_row, tail[k_new] % C].set(s, mode="drop")
    tail = tail.at[k_new].add(mv)
    occ = occ.at[k_new].add(mv)
    # phase update: a completion's freed slot hosts the dispatched task
    # (fresh alpha draw); a stage advance steps the head task to nxt;
    # flips write to the trash index C (dropped)
    ph_w = jnp.where(complete, _phase_draw(sr.acdf, u_ph), sr.nxt[ph_j])
    phase = phase.at[jnp.where(is_serve, s_head, C)].set(
        ph_w.astype(jnp.int32), mode="drop"
    )
    flip = (kind == KIND_FLIP).astype(jnp.float32)
    avail = avail.at[j].add(flip * (1.0 - 2.0 * avail[j]))
    return (
        StreamState(occ=occ, ring=ring, head=head, tail=tail, t=t,
                    avail=avail, t_c=t_c, phase=phase),
        Event(j=j, k=k_new, t=t, slot=s, dt=dt, kind=kind),
    )


def stats_init(n: int, C: int, fault: bool = False,
               scenario: bool = False) -> StatsState:
    import jax.numpy as jnp

    tagged = fault or scenario
    return StatsState(
        occ_sum=jnp.zeros(n, jnp.int32),
        occ_tw=jnp.zeros(n, jnp.float32),
        busy_t=jnp.zeros(n, jnp.float32),
        comp=jnp.zeros(n, jnp.int32),
        delay_sum=jnp.zeros(n, jnp.float32),
        slot_step=jnp.zeros(C, jnp.int32),
        avail_tw=jnp.zeros(n, jnp.float32) if tagged else None,
        kind_count=(
            jnp.zeros(N_KINDS if scenario else 4, jnp.int32) if tagged else None
        ),
        occ_tw_c=jnp.zeros(n, jnp.float32),
        busy_t_c=jnp.zeros(n, jnp.float32),
        delay_sum_c=jnp.zeros(n, jnp.float32),
        avail_tw_c=jnp.zeros(n, jnp.float32) if tagged else None,
    )


def stats_step(stats: StatsState, ev: Event, occ_pre, occ_post, k) -> StatsState:
    """Accumulate observables for step k (0-based).

    ``occ_pre`` is the pre-step occupancy (the state that persisted over
    ``ev.dt`` — its time integral is the quantity product form predicts),
    ``occ_post`` the post-step occupancy (the X_{i,k} the Palm accumulators
    of the host simulator count).
    """
    import jax.numpy as jnp

    delay = (k - stats.slot_step[ev.slot]).astype(jnp.float32)
    occ_tw, occ_tw_c = kahan_add(
        stats.occ_tw, stats.occ_tw_c, occ_pre.astype(jnp.float32) * ev.dt
    )
    busy_t, busy_t_c = kahan_add(
        stats.busy_t, stats.busy_t_c, jnp.where(occ_pre > 0, ev.dt, 0.0)
    )
    delay_sum, delay_sum_c = _kahan_scatter_add(
        stats.delay_sum, stats.delay_sum_c, ev.j, delay
    )
    return StatsState(
        occ_sum=stats.occ_sum + occ_post,
        occ_tw=occ_tw,
        busy_t=busy_t,
        comp=stats.comp.at[ev.j].add(1),
        delay_sum=delay_sum,
        slot_step=stats.slot_step.at[ev.slot].set(k + 1),
        occ_tw_c=occ_tw_c,
        busy_t_c=busy_t_c,
        delay_sum_c=delay_sum_c,
    )


def fault_stats_step(
    stats: StatsState, ev: Event, occ_pre, avail_pre, occ_post, k
) -> StatsState:
    """Fault-aware `stats_step`.

    Differences: completions / delays only count ``KIND_COMPLETE`` events,
    ``busy_t`` integrates ``1{X_i > 0 and available}`` (the time a node was
    actually serving — dividing completions by it keeps `estimate_mu`
    unbiased under churn), and the availability integral plus per-kind event
    counts accumulate.  Slot bookkeeping is shared: any task movement
    refreshes ``slot_step`` (crash/timeout re-dispatches reset staleness);
    flips carry ``slot == C`` so their scatters drop out of bounds.
    """
    import jax.numpy as jnp

    comp = (ev.kind == KIND_COMPLETE).astype(jnp.int32)
    delay = (k - stats.slot_step[ev.slot]).astype(jnp.float32)
    occ_tw, occ_tw_c = kahan_add(
        stats.occ_tw, stats.occ_tw_c, occ_pre.astype(jnp.float32) * ev.dt
    )
    busy_t, busy_t_c = kahan_add(
        stats.busy_t, stats.busy_t_c,
        jnp.where((occ_pre > 0) & (avail_pre > 0), ev.dt, 0.0),
    )
    delay_sum, delay_sum_c = _kahan_scatter_add(
        stats.delay_sum, stats.delay_sum_c, ev.j,
        delay * comp.astype(jnp.float32),
    )
    avail_tw, avail_tw_c = kahan_add(
        stats.avail_tw, stats.avail_tw_c, avail_pre * ev.dt
    )
    return StatsState(
        occ_sum=stats.occ_sum + occ_post,
        occ_tw=occ_tw,
        busy_t=busy_t,
        comp=stats.comp.at[ev.j].add(comp),
        delay_sum=delay_sum,
        slot_step=stats.slot_step.at[ev.slot].set(k + 1, mode="drop"),
        avail_tw=avail_tw,
        kind_count=stats.kind_count.at[ev.kind].add(1),
        occ_tw_c=occ_tw_c,
        busy_t_c=busy_t_c,
        delay_sum_c=delay_sum_c,
        avail_tw_c=avail_tw_c,
    )


def scenario_stats_step(
    stats: StatsState, ev: Event, occ_pre, avail_pre, speed_pre, occ_post, k
) -> StatsState:
    """Scenario-aware `stats_step`.

    Like `fault_stats_step`, but ``busy_t`` integrates the *modulated*
    exposure ``speed_i 1{X_i > 0}`` instead of the 0/1 gate: in the
    time-change ``dtau = speed dt`` the head task's stage sequence is the
    unmodulated unit-mean phase chain at rate ``mu_i``, so long-run
    ``comp / busy_t -> mu`` exactly and `estimate_mu` / `ctrl_refresh`
    stay unbiased under modulation and non-exponential service alike.
    ``kind_count`` is the full (N_KINDS,) histogram (stage advances are
    tag 5).
    """
    import jax.numpy as jnp

    comp = (ev.kind == KIND_COMPLETE).astype(jnp.int32)
    delay = (k - stats.slot_step[ev.slot]).astype(jnp.float32)
    occ_tw, occ_tw_c = kahan_add(
        stats.occ_tw, stats.occ_tw_c, occ_pre.astype(jnp.float32) * ev.dt
    )
    busy_t, busy_t_c = kahan_add(
        stats.busy_t, stats.busy_t_c,
        jnp.where(occ_pre > 0, speed_pre, 0.0) * ev.dt,
    )
    delay_sum, delay_sum_c = _kahan_scatter_add(
        stats.delay_sum, stats.delay_sum_c, ev.j,
        delay * comp.astype(jnp.float32),
    )
    avail_tw, avail_tw_c = kahan_add(
        stats.avail_tw, stats.avail_tw_c, avail_pre * ev.dt
    )
    return StatsState(
        occ_sum=stats.occ_sum + occ_post,
        occ_tw=occ_tw,
        busy_t=busy_t,
        comp=stats.comp.at[ev.j].add(comp),
        delay_sum=delay_sum,
        slot_step=stats.slot_step.at[ev.slot].set(k + 1, mode="drop"),
        avail_tw=avail_tw,
        kind_count=stats.kind_count.at[ev.kind].add(1),
        occ_tw_c=occ_tw_c,
        busy_t_c=busy_t_c,
        delay_sum_c=delay_sum_c,
        avail_tw_c=avail_tw_c,
    )


def _network_scan(n: int, C: int, T: int, init: str, emit_events: bool,
                  fault: bool = False, scenario: bool = False):
    """Shared scan harness: T fused CS steps of stream_step + stats_step.

    Returns ``gen(key, mu, p) -> (init_nodes, events | None, stats)`` where
    ``events = (J, K, t, slot, delay)`` arrays when ``emit_events`` (the
    exportable stream) and None otherwise (the cheaper stats-only pass the
    adaptive control loop and the stream benchmarks consume).  With
    ``fault``, the generator signature grows a trailing
    ``fr = resolve_fault_rates(...)`` operand, the per-step machinery swaps
    to `fault_stream_step` / `fault_stats_step`, and the emitted events gain
    a trailing kind column.  With ``scenario``, ``fr`` is instead a
    `ScenarioRates` and the machinery swaps to `scenario_stream_step` /
    `scenario_stats_step` (events also gain the kind column).
    """
    import jax
    import jax.numpy as jnp

    if fault and scenario:
        raise ValueError("fault and scenario streams are mutually exclusive")

    def gen(key, mu, p, fr=None):
        if scenario:
            k_init, k_race, k_exp, k_disp, k_ph = jax.random.split(key, 5)
            state, init_nodes = scenario_stream_init(
                k_init, n, C, p, fr, init=init
            )
            u_ph = jax.random.uniform(k_ph, (T,))
        else:
            k_init, k_race, k_exp, k_disp = jax.random.split(key, 4)
            state, init_nodes = stream_init(
                k_init, n, C, p, init=init, fault=fault
            )
            u_ph = None
        u_race = jax.random.uniform(k_race, (T,))
        u_exp = jax.random.uniform(k_exp, (T,))
        # all T dispatch draws through one shared segment tree (hierarchical
        # CDF — O(log n) per draw, unbiased at large n, zero-p never drawn)
        ptree = tree_build(jnp.asarray(p, jnp.float32))
        K = jax.vmap(lambda u: tree_sample(ptree, u))(
            jax.random.uniform(k_disp, (T,))
        ).astype(jnp.int32)
        stats = stats_init(n, C, fault=fault, scenario=scenario)

        def body(carry, xs):
            state, stats, k = carry
            occ_pre = state.occ
            if scenario:
                avail_pre = state.avail
                speed_pre = avail_pre + (1.0 - avail_pre) * fr.rate_scale
                state, ev = scenario_stream_step(state, mu, fr, xs)
                delay = k - stats.slot_step[ev.slot]
                stats = scenario_stats_step(
                    stats, ev, occ_pre, avail_pre, speed_pre, state.occ, k
                )
                ys = (
                    (ev.j, ev.k, ev.t, ev.slot, delay, ev.kind)
                    if emit_events else None
                )
            elif fault:
                avail_pre = state.avail
                state, ev = fault_stream_step(state, mu, fr, xs)
                delay = k - stats.slot_step[ev.slot]
                stats = fault_stats_step(
                    stats, ev, occ_pre, avail_pre, state.occ, k
                )
                ys = (
                    (ev.j, ev.k, ev.t, ev.slot, delay, ev.kind)
                    if emit_events else None
                )
            else:
                state, ev = stream_step(state, mu, xs)
                delay = k - stats.slot_step[ev.slot]  # before stats_step moves it
                stats = stats_step(stats, ev, occ_pre, state.occ, k)
                ys = (ev.j, ev.k, ev.t, ev.slot, delay) if emit_events else None
            return (state, stats, k + 1), ys

        carry = (state, stats, jnp.int32(0))
        xs = (u_race, u_exp, K, u_ph) if scenario else (u_race, u_exp, K)
        (state, stats, _), events = jax.lax.scan(body, carry, xs)
        return init_nodes, events, stats

    return gen


@lru_cache(maxsize=32)
def _stream_generator(n: int, C: int, T: int, init: str, fault: bool = False,
                      scenario: bool = False):
    import jax

    return jax.jit(
        _network_scan(n, C, T, init, emit_events=True, fault=fault,
                      scenario=scenario)
    )


@lru_cache(maxsize=32)
def stats_stream_fn(n: int, C: int, T: int, init: str = "distinct",
                    fault: bool = False, scenario: bool = False):
    """Stats-only fused network scan: ``gen(key, mu, p[, fr]) -> StatsState``.

    No per-event outputs — just the running occupancy / busy-time /
    completion / delay accumulators.  Returned un-jitted so callers compose
    it with vmap/pmap over scenarios before compiling.  With ``fault`` the
    trailing operand is `resolve_fault_rates(...)`; with ``scenario`` it is
    a `ScenarioRates`.
    """
    base = _network_scan(n, C, T, init, emit_events=False, fault=fault,
                         scenario=scenario)
    if fault or scenario:
        return lambda key, mu, p, fr: base(key, mu, p, fr)[2]
    return lambda key, mu, p: base(key, mu, p)[2]


def generate_stream(
    mu,
    p,
    C: int,
    T: int,
    seed: int | Any = 0,
    init: str = "distinct",
    fault: FaultConfig | None = None,
    scenario: ScenarioConfig | None = None,
) -> EventStream:
    """Simulate T CS steps on device and export a host `EventStream`.

    Drop-in replacement for `queue_sim.export_stream` (exponential service
    only): same arrays, same invariants, different — but law-identical —
    realization.  ``seed`` may be an int or a PRNG key.  The jitted
    generator is cached per (n, C, T, init, faults-on), so sweeps over
    (mu, p, seed) and fault rates reuse one compiled program.  With
    ``fault`` the stream carries a kind column and T counts merged events
    (flips included) — same convention as `queue_sim.export_stream`.
    ``scenario`` (mutually exclusive with ``fault``) swaps the service law
    and availability process to a `ScenarioConfig`; T then counts merged
    events including stage advances and flips.
    """
    import jax
    import jax.numpy as jnp

    mu = np.asarray(mu, dtype=np.float64)
    p = np.asarray(p, dtype=np.float64)
    n = mu.size
    if abs(p.sum() - 1.0) > 1e-8:
        raise ValueError("p must sum to 1")
    key = jax.random.PRNGKey(seed) if np.ndim(seed) == 0 else seed
    faulty = fault is not None and fault.enabled
    scen = scenario is not None and scenario.enabled
    if faulty and scen:
        raise ValueError("fault= and scenario= are mutually exclusive")
    gen = _stream_generator(n, int(C), int(T), init, faulty, scen)
    if scen:
        init_nodes, (J, K, t, slot, delays, kind), stats = gen(
            key, jnp.asarray(mu, jnp.float32), jnp.asarray(p, jnp.float32),
            resolve_scenario(scenario, n),
        )
        kind_np = np.asarray(kind, np.int8)
    elif faulty:
        init_nodes, (J, K, t, slot, delays, kind), stats = gen(
            key, jnp.asarray(mu, jnp.float32), jnp.asarray(p, jnp.float32),
            resolve_fault_rates(fault, n),
        )
        kind_np = np.asarray(kind, np.int8)
    else:
        init_nodes, (J, K, t, slot, delays), stats = gen(
            key, jnp.asarray(mu, jnp.float32), jnp.asarray(p, jnp.float32)
        )
        kind_np = None
    return EventStream(
        J=np.asarray(J, np.int32),
        K=np.asarray(K, np.int32),
        t=np.asarray(t, np.float64),
        slot=np.asarray(slot, np.int32),
        init_nodes=np.asarray(init_nodes, np.int32),
        n=n,
        C=int(C),
        p=p.copy(),
        delay_steps=np.asarray(delays, np.int64),
        queue_len_sum=np.asarray(stats.occ_sum, np.float64),
        queue_len_tw=kahan_value(stats.occ_tw, stats.occ_tw_c),
        kind=kind_np,
    )


def generate_blocks(
    mu,
    p,
    C: int,
    T: int,
    block_size: int,
    seed: int | Any = 0,
    init: str = "distinct",
    cut_every: int = 0,
    method: str = "greedy",
    fault: FaultConfig | None = None,
    scenario: ScenarioConfig | None = None,
) -> EventBlocks:
    """Device-generated event stream, segmented into conflict-free blocks.

    The blocked analogue of `generate_stream`: the closed network is
    simulated on device (one compiled scan, cached per shape) and the
    resulting stream is cut into fixed-shape ``(B, E)`` index+mask
    micro-blocks by `queue_sim.segment_blocks` — the feed of the blocked
    scan engine when the events should come from the device generator
    rather than the host simulator.  ``method`` picks the cut placement
    ("greedy" | "dp" — see `queue_sim.segment_blocks`).
    """
    return EventBlocks.from_stream(
        generate_stream(mu, p, C, T, seed=seed, init=init, fault=fault,
                        scenario=scenario),
        block_size,
        cut_every,
        method,
    )


# ---------------------------------------------------------------------- #
# sparse O(C) closed network: state keyed by the C in-flight tasks
# ---------------------------------------------------------------------- #
class ClassSpec(NamedTuple):
    """Static speed-class structure of the client population.

    Clients with identical ``(mu, p)`` are exchangeable in the closed
    Jackson network (the paper's two-cluster structure, generalized to m
    classes), so the sparse stream only tracks *which class* each idle
    node belongs to and keeps per-node identity for the C in-flight
    tasks.  ``perm`` maps compact (class-sorted) positions to global
    client ids — clients of class c occupy ``perm[offsets[c] :
    offsets[c] + counts[c]]`` — and ``inv_cls`` inverts it per global id.
    Both tables are O(n) *memory* but only touched by O(1) gathers per
    event, so per-event cost stays flat in n.
    """

    counts: Any   # (m,) int32 — class sizes
    offsets: Any  # (m,) int32 — exclusive prefix sums of counts
    perm: Any     # (n,) int32 — compact position -> global client id
    inv_cls: Any  # (n,) int32 — global client id -> class index

    @property
    def n(self) -> int:
        return int(self.perm.shape[0])

    @property
    def m(self) -> int:
        return int(self.counts.shape[0])

    def device(self) -> "ClassSpec":
        import jax.numpy as jnp

        return ClassSpec(*(jnp.asarray(a, jnp.int32) for a in self))

    def cache_key(self) -> tuple:
        return (
            self.n,
            tuple(np.asarray(self.counts).tolist()),
            hash(np.asarray(self.perm, np.int32).tobytes()),
        )


def build_class_spec(mu, p=None, max_classes: int = 64):
    """Detect speed classes from per-node ``(mu, p)``.

    Returns ``(spec, mu_m, p_m)`` with class-level service rates and
    per-node dispatch probabilities.  Raises if more than ``max_classes``
    distinct ``(mu, p)`` pairs exist — the sparse path is for populations
    with cluster structure, not fully heterogeneous rates.
    """
    mu = np.asarray(mu, np.float64)
    n = mu.size
    p = np.full(n, 1.0 / n) if p is None else np.asarray(p, np.float64)
    vals, inv = np.unique(np.stack([mu, p], axis=1), axis=0, return_inverse=True)
    m = vals.shape[0]
    if m > max_classes:
        raise ValueError(
            f"{m} distinct (mu, p) classes exceed max_classes={max_classes}; "
            "the sparse stream needs cluster structure"
        )
    inv = inv.reshape(n)
    counts = np.bincount(inv, minlength=m)
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    spec = ClassSpec(
        counts=counts.astype(np.int32),
        offsets=offsets.astype(np.int32),
        perm=np.argsort(inv, kind="stable").astype(np.int32),
        inv_cls=inv.astype(np.int32),
    )
    return spec, vals[:, 0].copy(), vals[:, 1].copy()


def resolve_fault_rates_classes(fault: FaultConfig, spec: ClassSpec):
    """Class-level `resolve_fault_rates`: ``(kappa, theta, q_off, q_on)``
    as ``(m,)`` arrays.  Fault rates must be constant within each speed
    class — the exchangeability the sparse idle pools rely on."""
    import jax.numpy as jnp

    q_off, q_on, kappa, theta = fault.resolve(spec.n)
    perm = np.asarray(spec.perm)
    offsets = np.asarray(spec.offsets)
    counts = np.asarray(spec.counts)
    out = []
    for name, r in (("crash_rate", kappa), ("timeout_rate", theta),
                    ("off_rate", q_off), ("on_rate", q_on)):
        rc = np.asarray(r, np.float64)[perm]
        vals = rc[offsets]
        for c in range(counts.size):
            seg = rc[offsets[c]: offsets[c] + counts[c]]
            if not np.allclose(seg, vals[c]):
                raise ValueError(
                    f"FaultConfig.{name} varies within speed class {c}; "
                    "the sparse stream requires class-constant fault rates"
                )
        out.append(jnp.asarray(vals, jnp.float32))
    return tuple(out)


class SparseStreamState(NamedTuple):
    """Sparse device state of the closed network: O(C + m), not O(n·C).

    Each of the C circulating tasks is one slot; FIFO order within a node
    is the monotone ``seq`` stamp and ``head`` marks the head-of-line
    task (exactly one per busy node).  Under faults, availability is
    carried per slot (consistent across slots of the same node) and idle
    nodes are tracked as per-class ``(idle_on, idle_off)`` counts —
    within-class identities are exchangeable, so the collapse is exact in
    law for every per-class observable.
    """

    node: Any   # (C,) int32 — global client id of each in-flight task
    cls: Any    # (C,) int32 — speed class of that node
    seq: Any    # (C,) int32 — dispatch stamp (FIFO: head = min seq per node)
    head: Any   # (C,) bool — head-of-line flag
    t: Any      # () float32 — physical time (Kahan; see t_c)
    t_c: Any    # () float32
    next_seq: Any  # () int32 — next dispatch stamp
    avail: Any = None     # (C,) float32 — availability bit of the slot's node
    idle_on: Any = None   # (m,) int32 — idle & available nodes per class
    idle_off: Any = None  # (m,) int32 — idle & unavailable nodes per class
    phase: Any = None     # (C,) int32 — service stage of each slot's task
                          # (scenario mode; else None)


def sample_dispatch_classes(p, spec: ClassSpec, u_cls, u_mem):
    """Vectorized dispatch draws K ~ p for a class-structured population.

    ``p`` is the (m,) *per-node* probability by class; a draw picks the
    class from the (m,) mass vector ``counts * p`` by segment-tree
    descent, then a uniform member — identical in law to the dense (n,)
    inverse-CDF draw, at O(log m) per event.  ``u_cls``/``u_mem`` are
    1-D uniform blocks; returns global client ids.
    """
    import jax
    import jax.numpy as jnp

    counts = jnp.asarray(spec.counts, jnp.int32)
    offsets = jnp.asarray(spec.offsets, jnp.int32)
    perm = jnp.asarray(spec.perm, jnp.int32)
    mass = counts.astype(jnp.float32) * jnp.asarray(p, jnp.float32)
    mtree = tree_build(mass)
    c = jax.vmap(lambda u: tree_sample(mtree, u))(u_cls).astype(jnp.int32)
    member = jnp.minimum(
        (u_mem * counts[c].astype(jnp.float32)).astype(jnp.int32),
        counts[c] - 1,
    )
    return perm[offsets[c] + member]


def sparse_stream_init(key, spec: ClassSpec, C: int, p=None,
                       init: str = "distinct", fault: bool = False):
    """Initial sparse placement of the C tasks.  Returns (state, nodes).

    Same two conventions as `stream_init`.  "distinct" draws a uniform
    C-subset by sequential rank-adjusted draws (O(C^2), exact — the r-th
    smallest unchosen id is recovered by bumping r past every chosen id
    at or below it) rather than an O(n) permutation, so even the one-time
    init stays flat in n; every event is O(C + m + log n).
    """
    import jax
    import jax.numpy as jnp

    n, m = spec.n, spec.m
    if init == "distinct":
        if C <= n:
            ranks = jax.vmap(
                lambda k, hi: jax.random.randint(k, (), 0, hi)
            )(jax.random.split(key, C), jnp.arange(n, n - C, -1))

            def draw(i, chosen):
                sort = jnp.sort(chosen)  # sentinels n sort last

                def bump(x, s):
                    return jnp.where(s <= x, x + 1, x), None

                r, _ = jax.lax.scan(bump, ranks[i], sort)
                return chosen.at[i].set(r)

            nodes = jax.lax.fori_loop(
                0, C, draw, jnp.full(C, n, jnp.int32)).astype(jnp.int32)
        else:
            nodes = jnp.arange(C, dtype=jnp.int32) % n
    elif init == "sampled":
        k1, k2 = jax.random.split(key)
        nodes = sample_dispatch_classes(
            p, spec, jax.random.uniform(k1, (C,)), jax.random.uniform(k2, (C,))
        )
    else:
        raise ValueError(init)
    # head-of-line = first occurrence of each node among the C slots
    eq = nodes[None, :] == nodes[:, None]
    head = jnp.sum(jnp.tril(eq, -1), axis=1) == 0
    cls = jnp.asarray(spec.inv_cls, jnp.int32)[nodes]
    if fault:
        busy_m = jnp.zeros(m, jnp.int32).at[cls].add(head.astype(jnp.int32))
        idle_on = jnp.asarray(spec.counts, jnp.int32) - busy_m
        avail = jnp.ones(C, jnp.float32)
        idle_off = jnp.zeros(m, jnp.int32)
    else:
        avail = idle_on = idle_off = None
    state = SparseStreamState(
        node=nodes,
        cls=cls,
        seq=jnp.arange(C, dtype=jnp.int32),
        head=head,
        t=jnp.float32(0.0),
        t_c=jnp.float32(0.0),
        next_seq=jnp.int32(C),
        avail=avail,
        idle_on=idle_on,
        idle_off=idle_off,
    )
    return state, nodes


def class_occupancy(cls, m: int):
    """(m,) int32 per-class task counts from the (C,) slot classes."""
    import jax.numpy as jnp

    return jnp.zeros(m, jnp.int32).at[cls].add(1)


def sparse_class_stats(state: SparseStreamState, m: int, fault: bool = False):
    """Per-class (occupancy, busy-node, available-node) counts.

    ``busy`` counts distinct busy nodes (one head per busy node); in
    fault mode it is gated on availability (the `estimate_mu` exposure)
    and ``avail`` counts available nodes (busy-available heads plus the
    idle-on pool).  Returns ``(occ, busy, avail-or-None)``.
    """
    import jax.numpy as jnp

    occ = class_occupancy(state.cls, m)
    h = state.head.astype(jnp.int32)
    if not fault:
        busy = jnp.zeros(m, jnp.int32).at[state.cls].add(h)
        return occ, busy, None
    ha = h * (state.avail > 0).astype(jnp.int32)
    busy = jnp.zeros(m, jnp.int32).at[state.cls].add(ha)
    avail = busy + state.idle_on
    return occ, busy, avail


def sparse_stream_step(state: SparseStreamState, mu, spec, xs):
    """One CS step of the sparse closed network — O(C + log n) work.

    ``xs = (u_race, u_exp, k_new)`` as in `stream_step`; ``mu`` is the
    (m,) class rate vector and ``spec`` a device `ClassSpec`.  The
    completion race runs over the <= C head-of-line tasks only (each busy
    node contributes exactly one head), so nothing scales with n.
    """
    import jax.numpy as jnp

    u_race, u_exp, k_new = xs
    node, cls, seq, head = state.node, state.cls, state.seq, state.head
    C = node.shape[0]
    ar = jnp.arange(C, dtype=jnp.int32)
    rates = jnp.where(head, mu[cls], 0.0)
    rtree = tree_build(rates)
    dt = -jnp.log1p(-u_exp) / rtree[1]
    t, t_c = kahan_add(state.t, state.t_c, dt)
    s = tree_sample(rtree, u_race).astype(jnp.int32)
    j = node[s]
    # promote j's next-oldest task (if any) to head-of-line
    others_j = (node == j) & (ar != s)
    has_succ = jnp.any(others_j)
    succ = jnp.argmin(jnp.where(others_j, seq, jnp.int32(2**31 - 1)))
    head = head & (ar != s)
    head = head | ((ar == succ) & has_succ)
    # the freed slot hosts the dispatch; join-or-fresh by O(C) membership
    exists_k = jnp.any((node == k_new) & (ar != s))
    node = node.at[s].set(k_new)
    cls = cls.at[s].set(jnp.asarray(spec.inv_cls, jnp.int32)[k_new])
    seq = seq.at[s].set(state.next_seq)
    head = jnp.where(ar == s, ~exists_k, head)
    return (
        SparseStreamState(node=node, cls=cls, seq=seq, head=head, t=t,
                          t_c=t_c, next_seq=state.next_seq + 1),
        Event(j=j, k=k_new, t=t, slot=s, dt=dt),
    )


def sparse_fault_stream_step(state: SparseStreamState, mu, spec, fr, xs):
    """One merged-CTMC event of the faulty sparse network — O(C + m).

    The race runs over ``4C + 2m`` clocks: per head slot [completion |
    crash | timeout | availability flip] plus per class [idle on->off |
    idle off->on] — the exact class-collapse of the dense ``4n`` race
    (each busy node has one head; idle nodes are exchangeable within a
    class).  ``xs = (u_race, u_exp, k_new, u_bit)``: ``u_bit`` resolves
    the availability bit when the dispatch lands on an idle node (drawn
    from the class's (idle_on, idle_off) composition — exact in law by
    exchangeability; within-class *identities* of idle nodes are not
    tracked).  ``fr = resolve_fault_rates_classes(...)``.  Idle-pool
    flips emit a representative id of the class with ``slot = C`` (the
    trash row), like dense flips.
    """
    import jax.numpy as jnp

    kappa, theta, q_off, q_on = fr
    u_race, u_exp, k_new, u_bit = xs
    node, cls, seq, head, a = (
        state.node, state.cls, state.seq, state.head, state.avail,
    )
    ion, ioff = state.idle_on, state.idle_off
    C = node.shape[0]
    m = ion.shape[0]
    ar = jnp.arange(C, dtype=jnp.int32)
    inv_cls = jnp.asarray(spec.inv_cls, jnp.int32)
    hf = head.astype(jnp.float32)
    rates = jnp.concatenate([
        mu[cls] * a * hf,
        kappa[cls] * a * hf,
        theta[cls] * hf,
        (q_off[cls] * a + q_on[cls] * (1.0 - a)) * hf,
        ion.astype(jnp.float32) * q_off,
        ioff.astype(jnp.float32) * q_on,
    ])
    rtree = tree_build(rates)
    tot = jnp.maximum(rtree[1], 1e-30)  # all-off + no clocks: time still moves
    dt = -jnp.log1p(-u_exp) / tot
    t, t_c = kahan_add(state.t, state.t_c, dt)
    idx = tree_sample(rtree, u_race).astype(jnp.int32)

    move = idx < 3 * C
    kind = jnp.where(move, idx // C, KIND_FLIP).astype(jnp.int32)
    s_mv = jnp.where(move, idx % C, 0)
    is_bf = (idx >= 3 * C) & (idx < 4 * C)
    s_bf = jnp.where(is_bf, idx - 3 * C, 0)
    is_if = idx >= 4 * C
    if_on2off = is_if & (idx < 4 * C + m)
    if_c = jnp.where(
        is_if, jnp.where(if_on2off, idx - 4 * C, idx - 4 * C - m), 0
    )

    j_mv = node[s_mv]
    cls_j = cls[s_mv]
    a_j = a[s_mv]
    j_bf = node[s_bf]
    perm = jnp.asarray(spec.perm, jnp.int32)
    offsets = jnp.asarray(spec.offsets, jnp.int32)
    j = jnp.where(move, j_mv, jnp.where(is_bf, j_bf, perm[offsets[if_c]]))
    s = jnp.where(move, s_mv, C).astype(jnp.int32)

    # movement (complete / crash / timeout): pop the head at j, redispatch
    others_j = move & (node == j_mv) & (ar != s_mv)
    has_succ = jnp.any(others_j)
    succ = jnp.argmin(jnp.where(others_j, seq, jnp.int32(2**31 - 1)))
    head = head & ~(move & (ar == s_mv))
    head = head | ((ar == succ) & has_succ)

    cls_k = inv_cls[k_new]
    k_is_j = move & (k_new == j_mv)
    exists_k = move & jnp.any((node == k_new) & (ar != s_mv))
    j_idles = move & ~has_succ & ~k_is_j
    # pool state the fresh draw sees: after j (possibly) went idle
    ion1 = ion.at[cls_j].add((j_idles & (a_j > 0)).astype(jnp.int32))
    ioff1 = ioff.at[cls_j].add((j_idles & (a_j == 0)).astype(jnp.int32))
    pool_on = ion1[cls_k].astype(jnp.float32)
    pool = pool_on + ioff1[cls_k].astype(jnp.float32)
    bit_pool = (u_bit * jnp.maximum(pool, 1.0) < pool_on).astype(jnp.float32)
    bit_join = jnp.max(jnp.where((node == k_new) & (ar != s_mv), a, 0.0))
    bit_new = jnp.where(exists_k, bit_join, jnp.where(k_is_j, a_j, bit_pool))
    fresh = move & ~exists_k & ~k_is_j
    ion2 = ion1.at[cls_k].add(-(fresh & (bit_new > 0)).astype(jnp.int32))
    ioff2 = ioff1.at[cls_k].add(-(fresh & (bit_new == 0)).astype(jnp.int32))

    # busy-node availability flip: toggle every slot of that node
    a = jnp.where(is_bf & (node == j_bf), 1.0 - a, a)
    # idle-pool flips: move one node between the (on, off) counts
    ion3 = ion2.at[if_c].add(jnp.where(is_if, jnp.where(if_on2off, -1, 1), 0))
    ioff3 = ioff2.at[if_c].add(jnp.where(is_if, jnp.where(if_on2off, 1, -1), 0))

    at_s = move & (ar == s_mv)
    node = jnp.where(at_s, k_new, node)
    cls = jnp.where(at_s, cls_k, cls)
    seq = jnp.where(at_s, state.next_seq, seq)
    head = jnp.where(at_s, ~exists_k, head)
    a = jnp.where(at_s, bit_new, a)
    return (
        SparseStreamState(
            node=node, cls=cls, seq=seq, head=head, t=t, t_c=t_c,
            next_seq=state.next_seq + move.astype(jnp.int32),
            avail=a, idle_on=ion3, idle_off=ioff3,
        ),
        Event(j=j, k=k_new, t=t, slot=s, dt=dt, kind=kind),
    )


def sparse_scenario_stream_init(key, spec: ClassSpec, C: int, p,
                                sr: ScenarioRates, init: str = "distinct"):
    """`sparse_stream_init` + per-slot initial phase draws."""
    import jax

    k_place, k_ph = jax.random.split(key)
    state, nodes = sparse_stream_init(k_place, spec, C, p, init=init,
                                      fault=True)
    phase = _phase_draw(sr.acdf, jax.random.uniform(k_ph, (C,)))
    return state._replace(phase=phase), nodes


def sparse_scenario_class_stats(state: SparseStreamState, m: int, rate_scale):
    """Per-class (occupancy, modulated busy exposure, available nodes).

    ``busy`` is the *speed-weighted* float exposure ``sum_heads speed`` —
    the denominator that keeps `estimate_mu` unbiased under modulation
    (see `scenario_stats_step`); ``avail`` counts available nodes
    (available heads + the idle-on pool), as in fault mode.
    """
    import jax.numpy as jnp

    occ = class_occupancy(state.cls, m)
    hf = state.head.astype(jnp.float32)
    speed = state.avail + (1.0 - state.avail) * rate_scale
    busy = jnp.zeros(m, jnp.float32).at[state.cls].add(hf * speed)
    ha = state.head.astype(jnp.int32) * (state.avail > 0).astype(jnp.int32)
    avail = jnp.zeros(m, jnp.int32).at[state.cls].add(ha) + state.idle_on
    return occ, busy, avail


def sparse_scenario_stream_step(state: SparseStreamState, mu, spec,
                                sr: ScenarioRates, xs):
    """One merged-CTMC event of the scenario sparse network — O(C + m).

    The race runs over ``2C + 2m`` clocks: per head slot [serve-or-stage |
    availability flip] plus per class [idle on->off | idle off->on] — the
    class-collapse of the dense ``2n`` race of `scenario_stream_step`.
    A serve win decodes into completion vs stage advance by
    ``absorb[phase]``; only completions move a task (the idle-pool /
    join-bit machinery is shared with `sparse_fault_stream_step`).
    ``xs = (u_race, u_exp, k_new, u_bit, u_ph)``;
    ``sr = resolve_scenario_classes(...)``.
    """
    import jax.numpy as jnp

    u_race, u_exp, k_new, u_bit, u_ph = xs
    node, cls, seq, head, a, phase = (
        state.node, state.cls, state.seq, state.head, state.avail, state.phase,
    )
    ion, ioff = state.idle_on, state.idle_off
    C = node.shape[0]
    m = ion.shape[0]
    ar = jnp.arange(C, dtype=jnp.int32)
    inv_cls = jnp.asarray(spec.inv_cls, jnp.int32)
    hf = head.astype(jnp.float32)
    speed = a + (1.0 - a) * sr.rate_scale
    rates = jnp.concatenate([
        mu[cls] * sr.srate[phase] * speed * hf,
        (sr.q_off[cls] * a + sr.q_on[cls] * (1.0 - a)) * hf,
        ion.astype(jnp.float32) * sr.q_off,
        ioff.astype(jnp.float32) * sr.q_on,
    ])
    rtree = tree_build(rates)
    tot = jnp.maximum(rtree[1], 1e-30)  # all-suspended: time still moves
    dt = -jnp.log1p(-u_exp) / tot
    t, t_c = kahan_add(state.t, state.t_c, dt)
    idx = tree_sample(rtree, u_race).astype(jnp.int32)

    is_sv = idx < C
    s_sv = jnp.where(is_sv, idx, 0)
    ph_sv = phase[s_sv]
    complete = is_sv & (sr.absorb[ph_sv] > 0)
    move = complete
    s_mv = s_sv
    is_bf = (idx >= C) & (idx < 2 * C)
    s_bf = jnp.where(is_bf, idx - C, 0)
    is_if = idx >= 2 * C
    if_on2off = is_if & (idx < 2 * C + m)
    if_c = jnp.where(
        is_if, jnp.where(if_on2off, idx - 2 * C, idx - 2 * C - m), 0
    )
    kind = jnp.where(
        complete, KIND_COMPLETE, jnp.where(is_sv, KIND_STAGE, KIND_FLIP)
    ).astype(jnp.int32)

    j_mv = node[s_mv]
    cls_j = cls[s_mv]
    a_j = a[s_mv]
    j_bf = node[s_bf]
    perm = jnp.asarray(spec.perm, jnp.int32)
    offsets = jnp.asarray(spec.offsets, jnp.int32)
    j = jnp.where(is_sv, j_mv, jnp.where(is_bf, j_bf, perm[offsets[if_c]]))
    s = jnp.where(move, s_mv, C).astype(jnp.int32)

    # completion: pop the head at j, redispatch (shared with the fault step)
    others_j = move & (node == j_mv) & (ar != s_mv)
    has_succ = jnp.any(others_j)
    succ = jnp.argmin(jnp.where(others_j, seq, jnp.int32(2**31 - 1)))
    head = head & ~(move & (ar == s_mv))
    head = head | ((ar == succ) & has_succ)

    cls_k = inv_cls[k_new]
    k_is_j = move & (k_new == j_mv)
    exists_k = move & jnp.any((node == k_new) & (ar != s_mv))
    j_idles = move & ~has_succ & ~k_is_j
    # pool state the fresh draw sees: after j (possibly) went idle
    ion1 = ion.at[cls_j].add((j_idles & (a_j > 0)).astype(jnp.int32))
    ioff1 = ioff.at[cls_j].add((j_idles & (a_j == 0)).astype(jnp.int32))
    pool_on = ion1[cls_k].astype(jnp.float32)
    pool = pool_on + ioff1[cls_k].astype(jnp.float32)
    bit_pool = (u_bit * jnp.maximum(pool, 1.0) < pool_on).astype(jnp.float32)
    bit_join = jnp.max(jnp.where((node == k_new) & (ar != s_mv), a, 0.0))
    bit_new = jnp.where(exists_k, bit_join, jnp.where(k_is_j, a_j, bit_pool))
    fresh = move & ~exists_k & ~k_is_j
    ion2 = ion1.at[cls_k].add(-(fresh & (bit_new > 0)).astype(jnp.int32))
    ioff2 = ioff1.at[cls_k].add(-(fresh & (bit_new == 0)).astype(jnp.int32))

    # busy-node availability flip: toggle every slot of that node
    a = jnp.where(is_bf & (node == j_bf), 1.0 - a, a)
    # idle-pool flips: move one node between the (on, off) counts
    ion3 = ion2.at[if_c].add(jnp.where(is_if, jnp.where(if_on2off, -1, 1), 0))
    ioff3 = ioff2.at[if_c].add(jnp.where(is_if, jnp.where(if_on2off, 1, -1), 0))

    at_s = move & (ar == s_mv)
    node = jnp.where(at_s, k_new, node)
    cls = jnp.where(at_s, cls_k, cls)
    seq = jnp.where(at_s, state.next_seq, seq)
    head = jnp.where(at_s, ~exists_k, head)
    a = jnp.where(at_s, bit_new, a)
    # phase update: completion -> fresh alpha draw for the dispatched task;
    # stage advance -> nxt; flips write to the trash index C (dropped)
    ph_w = jnp.where(complete, _phase_draw(sr.acdf, u_ph), sr.nxt[ph_sv])
    phase = phase.at[jnp.where(is_sv, s_sv, C)].set(
        ph_w.astype(jnp.int32), mode="drop"
    )
    return (
        SparseStreamState(
            node=node, cls=cls, seq=seq, head=head, t=t, t_c=t_c,
            next_seq=state.next_seq + move.astype(jnp.int32),
            avail=a, idle_on=ion3, idle_off=ioff3, phase=phase,
        ),
        Event(j=j, k=k_new, t=t, slot=s, dt=dt, kind=kind),
    )


def sparse_stats_init(m: int, C: int, fault: bool = False,
                      scenario: bool = False) -> StatsState:
    """Per-class `StatsState`: same fields, (m,) instead of (n,)."""
    import jax.numpy as jnp

    tagged = fault or scenario
    return StatsState(
        occ_sum=jnp.zeros(m, jnp.int32),
        occ_tw=jnp.zeros(m, jnp.float32),
        busy_t=jnp.zeros(m, jnp.float32),
        comp=jnp.zeros(m, jnp.int32),
        delay_sum=jnp.zeros(m, jnp.float32),
        slot_step=jnp.zeros(C, jnp.int32),
        avail_tw=jnp.zeros(m, jnp.float32) if tagged else None,
        kind_count=(
            jnp.zeros(N_KINDS if scenario else 4, jnp.int32) if tagged else None
        ),
        occ_tw_c=jnp.zeros(m, jnp.float32),
        busy_t_c=jnp.zeros(m, jnp.float32),
        delay_sum_c=jnp.zeros(m, jnp.float32),
        avail_tw_c=jnp.zeros(m, jnp.float32) if tagged else None,
    )


def sparse_stats_step(stats: StatsState, ev: Event, cls_j, occ_pre, busy_pre,
                      occ_post, k) -> StatsState:
    """Per-class `stats_step`: ``occ_pre``/``busy_pre``/``occ_post`` are the
    (m,) counts from `sparse_class_stats` and ``cls_j`` the class of the
    completing node."""
    import jax.numpy as jnp

    delay = (k - stats.slot_step[ev.slot]).astype(jnp.float32)
    occ_tw, occ_tw_c = kahan_add(
        stats.occ_tw, stats.occ_tw_c, occ_pre.astype(jnp.float32) * ev.dt
    )
    busy_t, busy_t_c = kahan_add(
        stats.busy_t, stats.busy_t_c, busy_pre.astype(jnp.float32) * ev.dt
    )
    delay_sum, delay_sum_c = _kahan_scatter_add(
        stats.delay_sum, stats.delay_sum_c, cls_j, delay
    )
    return StatsState(
        occ_sum=stats.occ_sum + occ_post,
        occ_tw=occ_tw,
        busy_t=busy_t,
        comp=stats.comp.at[cls_j].add(1),
        delay_sum=delay_sum,
        slot_step=stats.slot_step.at[ev.slot].set(k + 1),
        occ_tw_c=occ_tw_c,
        busy_t_c=busy_t_c,
        delay_sum_c=delay_sum_c,
    )


def sparse_fault_stats_step(stats: StatsState, ev: Event, cls_j, occ_pre,
                            busy_pre, avail_pre, occ_post, k) -> StatsState:
    """Fault-aware per-class stats: mirrors `fault_stats_step` with (m,)
    vectors (``avail_pre`` = available nodes per class, busy + idle-on)."""
    import jax.numpy as jnp

    comp = (ev.kind == KIND_COMPLETE).astype(jnp.int32)
    delay = (k - stats.slot_step[ev.slot]).astype(jnp.float32)
    occ_tw, occ_tw_c = kahan_add(
        stats.occ_tw, stats.occ_tw_c, occ_pre.astype(jnp.float32) * ev.dt
    )
    busy_t, busy_t_c = kahan_add(
        stats.busy_t, stats.busy_t_c, busy_pre.astype(jnp.float32) * ev.dt
    )
    delay_sum, delay_sum_c = _kahan_scatter_add(
        stats.delay_sum, stats.delay_sum_c, cls_j,
        delay * comp.astype(jnp.float32),
    )
    avail_tw, avail_tw_c = kahan_add(
        stats.avail_tw, stats.avail_tw_c, avail_pre.astype(jnp.float32) * ev.dt
    )
    return StatsState(
        occ_sum=stats.occ_sum + occ_post,
        occ_tw=occ_tw,
        busy_t=busy_t,
        comp=stats.comp.at[cls_j].add(comp),
        delay_sum=delay_sum,
        slot_step=stats.slot_step.at[ev.slot].set(k + 1, mode="drop"),
        avail_tw=avail_tw,
        kind_count=stats.kind_count.at[ev.kind].add(1),
        occ_tw_c=occ_tw_c,
        busy_t_c=busy_t_c,
        delay_sum_c=delay_sum_c,
        avail_tw_c=avail_tw_c,
    )


def _sparse_network_scan(m: int, C: int, T: int, init: str,
                         fault: bool = False, scenario: bool = False):
    """Sparse analogue of `_network_scan`: T fused sparse CS steps.

    Returns ``gen(key, mu, p, spec[, fr]) -> (init_nodes, stats, state)``
    with (m,) class-level ``mu``/``p`` and a device `ClassSpec`.
    """
    import jax
    import jax.numpy as jnp

    if fault and scenario:
        raise ValueError("fault and scenario streams are mutually exclusive")

    def gen(key, mu, p, spec, fr=None):
        keys = jax.random.split(key, 7 if scenario else 6)
        if scenario:
            state, init_nodes = sparse_scenario_stream_init(
                keys[0], spec, C, p, fr, init=init
            )
        else:
            state, init_nodes = sparse_stream_init(
                keys[0], spec, C, p, init=init, fault=fault
            )
        u_race = jax.random.uniform(keys[1], (T,))
        u_exp = jax.random.uniform(keys[2], (T,))
        K = sample_dispatch_classes(
            p, spec,
            jax.random.uniform(keys[3], (T,)),
            jax.random.uniform(keys[4], (T,)),
        )
        tagged = fault or scenario
        u_bit = jax.random.uniform(keys[5], (T,)) if tagged else None
        u_ph = jax.random.uniform(keys[6], (T,)) if scenario else None
        stats = sparse_stats_init(m, C, fault=fault, scenario=scenario)

        def body(carry, xs):
            state, stats, k = carry
            if scenario:
                occ_pre, busy_pre, avail_pre = sparse_scenario_class_stats(
                    state, m, fr.rate_scale
                )
                ur, ue, kn, ub, uph = xs
                state, ev = sparse_scenario_stream_step(
                    state, mu, spec, fr, (ur, ue, kn, ub, uph)
                )
                cls_j = jnp.asarray(spec.inv_cls, jnp.int32)[ev.j]
                stats = sparse_fault_stats_step(
                    stats, ev, cls_j, occ_pre, busy_pre, avail_pre,
                    class_occupancy(state.cls, m), k,
                )
            elif fault:
                occ_pre, busy_pre, avail_pre = sparse_class_stats(
                    state, m, fault=True
                )
                ur, ue, kn, ub = xs
                state, ev = sparse_fault_stream_step(
                    state, mu, spec, fr, (ur, ue, kn, ub)
                )
                cls_j = jnp.asarray(spec.inv_cls, jnp.int32)[ev.j]
                stats = sparse_fault_stats_step(
                    stats, ev, cls_j, occ_pre, busy_pre, avail_pre,
                    class_occupancy(state.cls, m), k,
                )
            else:
                occ_pre, busy_pre, avail_pre = sparse_class_stats(
                    state, m, fault=False
                )
                ur, ue, kn = xs
                state, ev = sparse_stream_step(state, mu, spec, (ur, ue, kn))
                cls_j = jnp.asarray(spec.inv_cls, jnp.int32)[ev.j]
                stats = sparse_stats_step(
                    stats, ev, cls_j, occ_pre, busy_pre,
                    class_occupancy(state.cls, m), k,
                )
            return (state, stats, k + 1), None

        if scenario:
            xs = (u_race, u_exp, K, u_bit, u_ph)
        elif fault:
            xs = (u_race, u_exp, K, u_bit)
        else:
            xs = (u_race, u_exp, K)
        (state, stats, _), _ = jax.lax.scan(
            body, (state, stats, jnp.int32(0)), xs
        )
        return init_nodes, stats, state

    return gen


@lru_cache(maxsize=32)
def sparse_stats_stream_fn(m: int, C: int, T: int, init: str = "distinct",
                           fault: bool = False, scenario: bool = False):
    """Stats-only sparse network scan, cached per shape.

    ``gen(key, mu, p, spec[, fr]) -> (StatsState, SparseStreamState)``
    with (m,) class-level inputs; per-event cost is flat in n (the
    benchmark surface of ``benchmarks/engine.py --scale``).  Un-jitted so
    callers compose with vmap before compiling; ``spec`` must be a
    device `ClassSpec` (``spec.device()``).  With ``scenario=True``,
    ``fr`` must be the `ScenarioRates` from `resolve_scenario_classes`.
    """
    base = _sparse_network_scan(m, C, T, init, fault=fault, scenario=scenario)
    if fault or scenario:
        return lambda key, mu, p, spec, fr: base(key, mu, p, spec, fr)[1:]
    return lambda key, mu, p, spec: base(key, mu, p, spec)[1:]


# ---------------------------------------------------------------------- #
# jnp control plane: exact Jackson analysis + Theorem-1 bound, traceable
# ---------------------------------------------------------------------- #
def mva_throughput_delays(mu, p, C: int, normalized: bool = True,
                          counts=None):
    """Exact (m, lam) of the closed network via Mean Value Analysis.

    The MVA recurrence over populations M = 1..C

        W = (1 + Q_{M-1}) / mu,   lam_M = M / (p . W),   Q_M = lam_M p W

    computes the arrival-theorem queue lengths Q_{C-1} and throughput
    Lambda(C) without normalizing constants — identical values to the
    Buzen pipeline in `jackson.JacksonNetwork` (locked in tests), but a
    C-step scan of O(n) vectorized ops that AD flows through cheaply.
    Returns ``(m, lam)``: delays in CS steps (Prop. 3 estimate, with the
    (C-1)/C Little's-law normalization by default) and the throughput.

    ``counts`` collapses identical nodes to speed classes: ``mu``/``p``
    are then (m,) class-level values (per-*node* probabilities) with
    ``counts[c]`` nodes per class, and the recurrence runs in O(m·C) —
    numerically identical to the dense recurrence on the expanded
    vectors, so n = 1e6 costs the same as n = m.
    """
    import jax
    import jax.numpy as jnp

    mu = jnp.asarray(mu)
    p = jnp.asarray(p)
    n = p.shape[0]
    w = jnp.ones(n, p.dtype) if counts is None else jnp.asarray(counts, p.dtype)

    def body(Q, M):
        W = (1.0 + Q) / mu
        lam = M / jnp.dot(w * p, W)
        return lam * p * W, lam

    Ms = jnp.arange(1, C + 1, dtype=p.dtype)
    Q_C, lams = jax.lax.scan(body, jnp.zeros(n, p.dtype), Ms)
    lam_C = lams[-1]
    if C == 1:
        Q_prev = jnp.zeros(n, p.dtype)
    else:
        # invert the last MVA step: Q_C = lam_C p (1 + Q_{C-1}) / mu
        Q_prev = mu * Q_C / (lam_C * p) - 1.0
    m = lam_C * (Q_prev + 1.0) / mu
    if normalized:
        m = m * (C - 1.0) / C
    return m, lam_C


def _class_weights(p, counts):
    """(weights, total n) for dense (counts=None) or class-collapsed sums."""
    import jax.numpy as jnp

    if counts is None:
        return jnp.ones_like(p), float(p.shape[0])
    w = jnp.asarray(counts, p.dtype)
    return w, float(int(np.sum(np.asarray(counts))))


def generalized_bound_jnp(eta, p, m, k: BoundConstants, counts=None):
    """G(p, eta) of Eq. (3) — jnp port of `theory.generalized_bound`.

    With ``counts``, p/m are (m,) class-level values and every sum over
    nodes becomes a counts-weighted sum over classes (O(m))."""
    import jax.numpy as jnp

    w, n = _class_weights(p, counts)
    n2 = n**2
    t1 = k.A / (eta * (k.T + 1))
    t2 = eta * k.L * k.B * jnp.sum(w / (n2 * p))
    t3 = eta**2 * k.L**2 * k.B * k.C * jnp.sum(w * m / (n2 * p**2))
    return t1 + t2 + t3


def optimal_eta_jnp(p, m, k: BoundConstants, newton_iters: int = 20,
                    counts=None):
    """argmin_eta G(p, eta) s.t. eta <= eta_max, traceable.

    The stationary point solves 2c eta^3 + b eta^2 = D (unique positive
    root); Newton from eta0 = cbrt(D / 2c) >= root converges monotonically
    (f is convex increasing on eta > 0).  The Theorem-1 cap
    min(a, b) mirrors `theory.eta_max_components`.  ``counts`` collapses
    the node sums to counts-weighted class sums.
    """
    import jax
    import jax.numpy as jnp

    w, n = _class_weights(p, counts)
    n2 = n**2
    D = k.A / (k.T + 1)
    b = k.L * k.B * jnp.sum(w / (n2 * p))
    c = k.L**2 * k.B * k.C * jnp.sum(w * m / (n2 * p**2))

    eta0 = jnp.cbrt(D / (2.0 * c))

    def newton(eta, _):
        f = 2.0 * c * eta**3 + b * eta**2 - D
        fp = 6.0 * c * eta**2 + 2.0 * b * eta
        return eta - f / fp, None

    eta, _ = jax.lax.scan(newton, eta0, None, length=newton_iters)
    growth = 1.0 + k.rho**2
    m_k = jnp.sum(w * m / (n2 * p**2))
    a_cap = 1.0 / jnp.sqrt(16.0 * k.L**2 * k.C * m_k * growth)
    b_cap = n2 / (8.0 * k.L * growth * jnp.sum(w / p))
    return jnp.minimum(eta, jnp.minimum(a_cap, b_cap))


@lru_cache(maxsize=32)
def _bound_value_and_grad(k_tuple, counts=None):
    import jax

    k = BoundConstants(*k_tuple)

    def objective(p, mu):
        m, _ = mva_throughput_delays(mu, p, k.C, counts=counts)
        eta = optimal_eta_jnp(p, m, k, counts=counts)
        return generalized_bound_jnp(eta, p, m, k, counts=counts)

    return jax.value_and_grad(objective)


def make_bound_value_and_grad(k: BoundConstants, counts=None):
    """(value, grad) of f(p) = G(p, eta*(p)) with delays from MVA — the jnp
    port of `sampling.bound_value_and_grad`.

    The gradient is exact AD through the MVA recurrence (the delay channel),
    the explicit 1/p terms, and eta*(p): when the cubic stationary point is
    interior the eta channel vanishes by the envelope theorem (dG/deta = 0
    there, so the Newton iterates' sensitivity is multiplied by ~0); when
    the cap is active, ``jnp.minimum`` routes the chain rule through the
    active branch — the same case split `sampling.bound_value_and_grad`
    does by hand.  Cached per (BoundConstants, counts); ``counts`` (a
    tuple of class sizes) switches everything to the O(m·C)
    class-collapsed form with (m,) per-node class probabilities.
    """
    if counts is not None:
        counts = tuple(int(c) for c in counts)
    return _bound_value_and_grad(
        (k.A, k.L, k.B, int(k.C), int(k.T), k.rho), counts
    )


def estimate_mu(comp, busy_t, prior_weight: float = 1.0,
                floor_frac: float = 1e-3):
    """Per-node service-rate MLE from observed (completions, busy time).

    While a node is busy its completions are Poisson(mu_i), so
    mu_i ~ comp_i / busy_i.  Nodes with little observed busy time shrink
    toward the busy-time-weighted global mean rate (``prior_weight``
    pseudo-completions at the global rate).

    Dead-node safety: a node that recorded zero completions *and* zero busy
    time (unavailable the whole window — or, with ``prior_weight = 0``, any
    idle node) used to produce ``0/0`` or a hard zero, which the MVA delay
    recurrence turns into inf/NaN delays and the control loop into NaN
    sampling weights.  The estimate is therefore floored at ``floor_frac``
    of the global mean rate (itself floored away from zero), so a dark node
    reports as "very slow but finite" and mirror descent pushes its p
    toward the floor instead of diverging.
    """
    import jax.numpy as jnp

    comp = comp.astype(jnp.float32)
    mu_bar = jnp.sum(comp) / jnp.maximum(jnp.sum(busy_t), 1e-20)
    mu_bar = jnp.maximum(mu_bar, 1e-8)  # zero completions everywhere
    est = (comp + prior_weight) / jnp.maximum(
        busy_t + prior_weight / mu_bar, 1e-20
    )
    return jnp.maximum(est, floor_frac * mu_bar)


def ctrl_refresh(
    p,
    comp,
    busy_t,
    k: BoundConstants,
    lr: float = 0.3,
    iters: int = 4,
    floor_scale: float = 1e-5,
    counts=None,
):
    """One adaptive-sampling refresh: re-optimize p from running estimates.

    Estimates per-node rates from the observed stream, then takes ``iters``
    exponentiated-gradient steps on the Theorem-1 bound (the same projected
    mirror-descent update as `sampling.optimize_general`, with the analytic
    jnp gradient).  Pure function of device values — traceable, vmappable.

    Control-plane safeguards (faults): `estimate_mu` floors dead-node rate
    estimates, non-finite gradient components are scrubbed to 0 (a single
    poisoned coordinate must not NaN the whole simplex), and each iterate is
    re-floored/renormalized — so nodes that went dark keep a small positive
    sampling weight (bounded importance scales) instead of p collapsing to
    NaN or exact zeros.

    With ``counts`` (a static tuple of class sizes), everything runs in
    the O(m·C) class-collapsed form: ``p`` is the (m,) per-node
    probability by class, ``comp``/``busy_t`` the per-class aggregates
    (the pooled MLE is strictly better-conditioned than per-node), and
    the exponentiated-gradient step operates on the class *masses*
    ``z = counts · p`` (the simplex the collapsed problem lives on).
    Within a class the dense optimum is symmetric, so the collapsed
    optimum is exact — the adaptive loop at n = 1e6 costs the same as
    n = m.
    """
    import jax
    import jax.numpy as jnp

    vg = make_bound_value_and_grad(k, counts=counts)
    mu_hat = estimate_mu(comp, busy_t)
    if counts is None:
        w = jnp.ones_like(p)
        n = p.shape[0]
    else:
        w = jnp.asarray(counts, p.dtype)
        n = int(np.sum(np.asarray(counts)))
    floor = floor_scale / n

    def one(p, _):
        _, g = vg(p, mu_hat)
        g = jnp.where(jnp.isfinite(g), g, 0.0)
        z = w * p
        gz = g / w
        gz = gz - jnp.dot(gz, z)
        z = z * jnp.exp(-lr * gz / (jnp.max(jnp.abs(gz)) + 1e-12))
        z = jnp.where(jnp.isfinite(z), z, w * floor)
        z = jnp.maximum(z, w * floor)
        return (z / jnp.sum(z)) / w, None

    p, _ = jax.lax.scan(one, p, None, length=iters)
    return p
