"""Event-driven simulator of the paper's closed queueing network.

Simulates exactly the process of §2: C tasks circulate among n FIFO clients;
when client J_k completes a task (k-th CS step), the dispatcher samples a new
client K_{k+1} ~ p and enqueues a fresh task there.  Produces the exact traces
(J_k, K_k, X_{i,k}, M_{i,k}) that the theory reasons about, for exponential or
deterministic service times.

This is the control-plane companion of `repro.fl.engine` (which attaches real
gradient computations to these events) and the oracle used to validate
`repro.core.jackson` closed forms.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field

import numpy as np

__all__ = ["SimConfig", "SimResult", "ClosedNetworkSim", "simulate"]


@dataclass
class SimConfig:
    mu: np.ndarray              # (n,) service rates
    p: np.ndarray               # (n,) dispatch probabilities
    C: int                      # concurrency (number of circulating tasks)
    T: int                      # number of CS steps to simulate
    service: str = "exp"        # "exp" | "det"
    seed: int = 0
    initial: str = "distinct"   # "distinct": C tasks on C distinct clients (S_0)
                                # "sampled": C iid draws from p


@dataclass
class SimResult:
    J: np.ndarray               # (T,) completing client per CS step
    K: np.ndarray               # (T,) newly-sampled client per CS step
    t: np.ndarray               # (T,) physical time of each CS step
    delays: list[list[int]]     # per-node list of delays in CS steps (M_{i,k})
    time_delays: list[list[float]]  # per-node physical-time sojourns
    queue_len_sum: np.ndarray   # (n,) event-sampled sum over steps of X_{i,k}
    queue_len_tw: np.ndarray    # (n,) time-weighted integral of X_i(t)
    queue_len_last: np.ndarray  # (n,) final queue lengths
    steps: int

    def mean_delay_per_node(self) -> np.ndarray:
        return np.array([np.mean(d) if d else np.nan for d in self.delays])

    def max_delay_per_node(self) -> np.ndarray:
        return np.array([np.max(d) if d else np.nan for d in self.delays])

    def mean_queue_lengths(self) -> np.ndarray:
        """Event-sampled means (Palm view at CS steps)."""
        return self.queue_len_sum / self.steps

    def time_avg_queue_lengths(self) -> np.ndarray:
        """Time-stationary means — comparable to JacksonNetwork.mean_queue_lengths."""
        return self.queue_len_tw / float(self.t[-1])

    def throughput(self) -> float:
        """CS steps per unit physical time."""
        return self.steps / float(self.t[-1]) if self.steps else 0.0


class ClosedNetworkSim:
    """Stepable simulator (used by repro.fl.engine to drive real training)."""

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.n = int(np.asarray(cfg.mu).size)
        self.mu = np.asarray(cfg.mu, dtype=np.float64)
        self.p = np.asarray(cfg.p, dtype=np.float64)
        if abs(self.p.sum() - 1.0) > 1e-8:
            raise ValueError("p must sum to 1")
        if cfg.C < 1:
            raise ValueError("C >= 1 required")
        self.rng = np.random.default_rng(cfg.seed)
        self.now = 0.0
        self.step_idx = 0
        # FIFO queue per node: deque of (task_id, dispatch_step, dispatch_time)
        self.queues: list[deque] = [deque() for _ in range(self.n)]
        # Event heap of (completion_time, seq, node).  Only the head-of-line
        # task of each node is in service; lazy invalidation via seq check.
        self.heap: list[tuple[float, int, int]] = []
        self._seq = 0
        self._inservice_seq = [-1] * self.n
        self.delays: list[list[int]] = [[] for _ in range(self.n)]
        self.time_delays: list[list[float]] = [[] for _ in range(self.n)]
        self.queue_len_sum = np.zeros(self.n)
        self.queue_len_tw = np.zeros(self.n)
        self._task_counter = 0
        self._init_tasks()

    # -------------------------------------------------------------- #
    def _service_time(self, node: int) -> float:
        if self.cfg.service == "exp":
            return float(self.rng.exponential(1.0 / self.mu[node]))
        if self.cfg.service == "det":
            return float(1.0 / self.mu[node])
        raise ValueError(f"unknown service kind {self.cfg.service}")

    def _start_service(self, node: int) -> None:
        self._seq += 1
        self._inservice_seq[node] = self._seq
        heapq.heappush(self.heap, (self.now + self._service_time(node), self._seq, node))

    def _enqueue(self, node: int, dispatch_step: int) -> int:
        tid = self._task_counter
        self._task_counter += 1
        self.queues[node].append((tid, dispatch_step, self.now))
        if len(self.queues[node]) == 1:
            self._start_service(node)
        return tid

    def _init_tasks(self) -> None:
        if self.cfg.initial == "distinct":
            if self.cfg.C > self.n:
                # spread round-robin when C > n (paper uses C <= n for S_0,
                # but saturated-regime experiments need C >> n)
                nodes = [i % self.n for i in range(self.cfg.C)]
            else:
                nodes = list(
                    self.rng.choice(self.n, size=self.cfg.C, replace=False, p=None)
                )
        elif self.cfg.initial == "sampled":
            nodes = list(self.rng.choice(self.n, size=self.cfg.C, p=self.p))
        else:
            raise ValueError(self.cfg.initial)
        for nd in nodes:
            self._enqueue(int(nd), dispatch_step=0)

    # -------------------------------------------------------------- #
    def total_tasks(self) -> int:
        return sum(len(q) for q in self.queues)

    def queue_lengths(self) -> np.ndarray:
        return np.array([len(q) for q in self.queues])

    def step(self) -> tuple[int, int]:
        """Advance one CS step.  Returns (J_k, K_{k+1})."""
        # pop next *valid* completion event
        while True:
            t_done, seq, node = heapq.heappop(self.heap)
            if self._inservice_seq[node] == seq:
                break
        # time-weighted occupancy over (self.now, t_done] — state unchanged there
        self.queue_len_tw += self.queue_lengths() * (t_done - self.now)
        self.now = t_done
        tid, disp_step, disp_time = self.queues[node].popleft()
        # delay in CS steps: completions strictly between dispatch and this one
        self.delays[node].append(self.step_idx - disp_step)
        self.time_delays[node].append(self.now - disp_time)
        if self.queues[node]:
            self._start_service(node)
        # dispatcher samples the next client
        k_new = int(self.rng.choice(self.n, p=self.p))
        self._enqueue(k_new, dispatch_step=self.step_idx + 1)
        self.queue_len_sum += self.queue_lengths()
        self.step_idx += 1
        return node, k_new


def simulate(cfg: SimConfig) -> SimResult:
    sim = ClosedNetworkSim(cfg)
    J = np.zeros(cfg.T, dtype=np.int32)
    K = np.zeros(cfg.T, dtype=np.int32)
    t = np.zeros(cfg.T, dtype=np.float64)
    for k in range(cfg.T):
        j, knew = sim.step()
        J[k], K[k], t[k] = j, knew, sim.now
    return SimResult(
        J=J,
        K=K,
        t=t,
        delays=sim.delays,
        time_delays=sim.time_delays,
        queue_len_sum=sim.queue_len_sum,
        queue_len_tw=sim.queue_len_tw,
        queue_len_last=sim.queue_lengths(),
        steps=cfg.T,
    )
