"""Event-driven simulator of the paper's closed queueing network.

Simulates exactly the process of §2: C tasks circulate among n FIFO clients;
when client J_k completes a task (k-th CS step), the dispatcher samples a new
client K_{k+1} ~ p and enqueues a fresh task there.  Produces the exact traces
(J_k, K_k, X_{i,k}, M_{i,k}) that the theory reasons about, for exponential or
deterministic service times.

This is the control-plane companion of `repro.fl.engine` (which attaches real
gradient computations to these events) and the oracle used to validate
`repro.core.jackson` closed forms.

Performance notes
-----------------
A CS step is O(log n) amortized, independent of the number of clients:

  * queue lengths are incremental counters, never recomputed from the deques;
  * the occupancy accumulators (event-sampled sum and time-weighted integral
    of X_i) use per-node "last changed at step/time" bookkeeping, so each
    event touches only the two affected nodes; reads flush lazily via the
    `queue_len_sum` / `queue_len_tw` properties;
  * dispatch samples and exponential service variates are pre-drawn in
    vectorized blocks (inverse-CDF via one `searchsorted` per block), so the
    per-event RNG cost is O(1) instead of `rng.choice`'s O(n);
  * per-event delay recording is opt-in (``SimConfig.record_delays``) and
    stored as flat numpy arrays — the old always-on list-of-lists cost
    hundreds of MB of Python objects at T=1e6.  The per-node views
    (``delays`` / ``time_delays``) are derived lazily.

The event stream is deterministic given (seed, block size); it differs from
the seed implementation's stream (which drew variates one at a time) but has
identical law.

Delay recording semantics
-------------------------
Delay recording is opt-in (``SimConfig.record_delays=True``) and **flat**:

  * `ClosedNetworkSim.delay_steps` is a ``(k,)`` int64 array in *completion
    order* — entry ``i`` is the CS-step delay of the i-th completion, i.e.
    the number of CS steps strictly between that task's dispatch and its
    completion (``M_{i,k}`` of §2).  The completing node of record ``i`` is
    ``J[i]``, so the pair ``(J, delay_steps)`` fully determines every
    per-node view and nothing per-node is ever materialized eagerly.
  * `EventStream.delay_steps` aligns 1:1 with the ``(J, K, t)`` trace:
    ``delay_steps[k]`` is the delay of the task completing at CS step ``k``
    (at node ``J[k]``).  ``None`` unless the stream was exported with
    ``record_delays=True`` (host) — device-generated streams
    (`stream_device.generate_stream`) always carry it.
  * The per-node list-of-lists views (``delays`` / ``time_delays``) are
    lazy, derived via `_split_delays`, and preserve event order within each
    node.  The flat invariant — regrouping the per-node view by ``J`` in
    event order reproduces ``delay_steps`` exactly — is locked by
    ``tests/test_queue_sim.py``.

Block segmentation
------------------
`segment_blocks` cuts a ``(T,)`` slot sequence into conflict-free
micro-blocks for the blocked scan engine.  Two cut policies are available
(``method=``): ``"greedy"`` extends each block until the next event's slot
repeats (or the length/eval caps hit) — provably minimal in block count for
this hereditary validity structure; ``"dp"`` is an exact O(T) dynamic
program over admissible cut points that certifies that minimum and
tie-breaks toward longer trailing blocks (never more padded lanes than
greedy — locked by tests).  `select_block_size` picks the lane count E from
the *measured* conflict structure of a stream: the delay distribution
governs conflict-free run lengths, so E is chosen as the largest candidate
whose measured lane utilization ``T / (B(E) * E)`` stays above a floor
(rounded to a multiple of the lane-shard device count).
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass

import numpy as np

__all__ = [
    "SimConfig",
    "SimResult",
    "EventStream",
    "EventBlocks",
    "FaultConfig",
    "ClosedNetworkSim",
    "simulate",
    "simulate_batch",
    "export_stream",
    "export_blocks",
    "segment_blocks",
    "select_block_size",
    "KIND_COMPLETE",
    "KIND_CRASH",
    "KIND_TIMEOUT",
    "KIND_FLIP",
    "KIND_SERVE",
    "KIND_STAGE",
    "N_KINDS",
]

#: event kind tags shared by the host simulator, the device stream and the
#: scan engine.  Only KIND_COMPLETE events apply a gradient; crash/timeout
#: events move the task (re-dispatch with the *current* server weights) and
#: KIND_FLIP events toggle availability without touching any queue.
KIND_COMPLETE = 0
KIND_CRASH = 1
KIND_TIMEOUT = 2
KIND_FLIP = 3
#: open-queue serving event (core.serving): an inference-plane arrival /
#: completion / deadline / retry-release interleaved into the merged race.
#: Serve events carry ``j = n`` and ``slot = C`` so every training-side
#: gather clamps harmlessly and every scatter drops out of bounds — the
#: same masking pattern as KIND_FLIP.  The serving sub-kind (arrival vs
#: completion vs timeout vs release) is resolved inside
#: `serving.serve_apply`, not in the event tag.
KIND_SERVE = 4
#: phase-type stage advance (scenario mode, `core.scenario`): the head-of-line
#: task at ``j`` moves to its next service stage — no queue changes, no
#: gradient.  Stage rows carry ``slot = C`` and ``K = -1`` (host) exactly like
#: KIND_FLIP, so every downstream gather/scatter masks them for free.
KIND_STAGE = 5
#: size of a kind-count histogram covering every tag above
N_KINDS = 6

#: shared RNG pre-draw block size — every entry point uses the same default so
#: `simulate(cfg)`, `simulate_batch(cfg)` and `ClosedNetworkSim(cfg).run(T)`
#: produce the identical event stream for the same seed
DEFAULT_BLOCK = 4096


@dataclass(frozen=True, eq=False)
class FaultConfig:
    """Memoryless fault processes layered on the closed network.

    Every rate is a per-node exponential intensity (scalar broadcast or an
    ``(n,)`` array), so the network + faults remain a CTMC and both the
    host event heap and the device inverse-CDF race survive unchanged in
    law.  Semantics:

      * availability is a 2-state Markov chain per node: ``off_rate`` is
        the on->off flip intensity, ``on_rate`` off->on.  An unavailable
        node serves nothing (completion and crash clocks are suspended;
        memorylessness means service simply redraws on resume).
      * ``crash_rate`` races the in-service completion while the node is
        available; on a crash the in-flight task's work is discarded and
        the task re-enters dispatch (K ~ p) with the current server
        weights.
      * ``timeout_rate`` is a per-task straggler deadline on the
        head-of-line task.  It fires *regardless of availability* (the
        deadline is enforced server-side), and the expired task is
        re-dispatched exactly like a crash.

    All four default to 0 (process disabled).
    """

    off_rate: float | tuple | np.ndarray = 0.0
    on_rate: float | tuple | np.ndarray = 0.0
    crash_rate: float | tuple | np.ndarray = 0.0
    timeout_rate: float | tuple | np.ndarray = 0.0

    def resolve(self, n: int) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Broadcast all four rates to float64 ``(n,)`` arrays (validated)."""
        out = []
        for name in ("off_rate", "on_rate", "crash_rate", "timeout_rate"):
            a = np.broadcast_to(
                np.asarray(getattr(self, name), np.float64), (n,)
            ).copy()
            if not np.all(np.isfinite(a)) or np.any(a < 0):
                raise ValueError(f"FaultConfig.{name} must be finite and >= 0")
            out.append(a)
        return tuple(out)

    @property
    def enabled(self) -> bool:
        return any(
            np.any(np.asarray(r, np.float64) > 0)
            for r in (self.off_rate, self.on_rate, self.crash_rate, self.timeout_rate)
        )

    def cache_key(self) -> tuple:
        """Hashable fingerprint (rates flattened) for jit/runner caches."""
        def t(x):
            return tuple(np.asarray(x, np.float64).ravel().tolist())

        return (t(self.off_rate), t(self.on_rate), t(self.crash_rate),
                t(self.timeout_rate))


@dataclass
class SimConfig:
    mu: np.ndarray              # (n,) service rates
    p: np.ndarray               # (n,) dispatch probabilities
    C: int                      # concurrency (number of circulating tasks)
    T: int                      # number of CS steps to simulate
    service: str = "exp"        # "exp" | "det"
    seed: int = 0
    initial: str = "distinct"   # "distinct": C tasks on C distinct clients (S_0)
                                # "sampled": C iid draws from p
    record_delays: bool = False  # opt-in per-event delay recording (flat arrays;
                                 # off by default — the queue-length accumulators
                                 # and the (J, K, t) trace are always available)
    fault: FaultConfig | None = None  # optional churn/crash/straggler injection;
                                      # with faults, T counts *merged* CTMC
                                      # events (flips included), not only CS
                                      # steps — filter by `kind` to recover the
                                      # task-movement subsequence
    scenario: "object | None" = None  # optional core.scenario.ScenarioConfig:
                                      # phase-type service + Markov-modulated
                                      # availability.  Mutually exclusive with
                                      # `fault`; like fault mode, T counts
                                      # merged events (stages + flips included)


@dataclass
class SimResult:
    J: np.ndarray               # (T,) completing client per CS step
    K: np.ndarray               # (T,) newly-sampled client per CS step
    t: np.ndarray               # (T,) physical time of each CS step
    delays: list[list[int]] | None       # per-node delays in CS steps (M_{i,k});
                                         # None unless cfg.record_delays
    time_delays: list[list[float]] | None  # per-node physical-time sojourns
    queue_len_sum: np.ndarray   # (n,) event-sampled sum over steps of X_{i,k}
    queue_len_tw: np.ndarray    # (n,) time-weighted integral of X_i(t)
    queue_len_last: np.ndarray  # (n,) final queue lengths
    steps: int

    def _need_delays(self) -> list[list[int]]:
        if self.delays is None:
            raise ValueError(
                "delays were not recorded; simulate with "
                "SimConfig(record_delays=True)"
            )
        return self.delays

    def mean_delay_per_node(self) -> np.ndarray:
        return np.array([np.mean(d) if d else np.nan for d in self._need_delays()])

    def max_delay_per_node(self) -> np.ndarray:
        return np.array([np.max(d) if d else np.nan for d in self._need_delays()])

    def mean_queue_lengths(self) -> np.ndarray:
        """Event-sampled means (Palm view at CS steps)."""
        return self.queue_len_sum / self.steps

    def time_avg_queue_lengths(self) -> np.ndarray:
        """Time-stationary means — comparable to JacksonNetwork.mean_queue_lengths."""
        return self.queue_len_tw / float(self.t[-1])

    def throughput(self) -> float:
        """CS steps per unit physical time."""
        return self.steps / float(self.t[-1]) if self.steps else 0.0


@dataclass
class EventStream:
    """Pre-computed event stream of a closed-network run, in array form.

    This is the bridge between the host-side event simulator and the compiled
    (scan-based) training engine: the queuing structure makes every CS step's
    control decisions — who completes (``J``), who is sampled next (``K``),
    when (``t``) — independent of the gradient values, so they can be
    simulated once on the host and the whole training run replayed on device
    as a single XLA program.

    ``slot`` encodes the FIFO snapshot bookkeeping: the engine keeps C
    dispatch-time parameter snapshots in a ring buffer; at step k the
    completing task's snapshot lives in ``slot[k]``, and — because exactly one
    task completes and one is dispatched per step — the newly dispatched
    task reuses the same slot.
    """

    J: np.ndarray            # (T,) completing client per CS step
    K: np.ndarray            # (T,) newly-sampled client per CS step
    t: np.ndarray            # (T,) physical time of each CS step
    slot: np.ndarray         # (T,) ring-buffer slot of the completing task
    init_nodes: np.ndarray   # (C,) client of the initial task in each slot
    n: int                   # number of clients
    C: int                   # concurrency
    p: np.ndarray            # (n,) dispatch probabilities the stream was drawn from
    delay_steps: np.ndarray | None = None       # (T,) CS-step delay of the task
                                                # completing at step k (node J[k]);
                                                # None unless record_delays
    queue_len_sum: np.ndarray | None = None     # (n,) event-sampled occupancy sum
    queue_len_tw: np.ndarray | None = None      # (n,) time-weighted occupancy
                                                # integral (device streams)
    kind: np.ndarray | None = None              # (T,) event kind (KIND_*); None
                                                # on fault-free streams (all
                                                # events are completions).  On
                                                # KIND_FLIP rows slot == C (the
                                                # trash row) and K == -1 (host)
                                                # / unused (device).

    @property
    def T(self) -> int:
        return int(self.J.size)

    @property
    def delays(self) -> list[list[int]] | None:
        """Per-node CS-step delays, derived lazily from the flat arrays.

        The flat ``(J, delay_steps)`` pair is the stored form (O(T) ints —
        list-of-lists over 1e6 events used to cost hundreds of MB of Python
        objects); the per-node view is materialized on demand.
        """
        if self.delay_steps is None:
            return None
        return _split_delays(self.J, self.delay_steps, self.n)


def _greedy_starts(slot: np.ndarray, E: int, cut_every: int) -> list[int]:
    """Block start indices of the greedy maximal-extension cut."""
    T = slot.size
    starts = [0]
    seen: set[int] = set()
    length = 0
    for k in range(T):
        s = int(slot[k])
        cut = length >= E or s in seen or (cut_every and k and k % cut_every == 0)
        if cut:
            starts.append(k)
            seen = set()
            length = 0
        seen.add(s)
        length += 1
    return starts


def _window_starts(slot: np.ndarray, E: int, cut_every: int) -> np.ndarray:
    """``s_lim[k]``: leftmost admissible start of a block ending at event k
    (inclusive) — slots in ``[s_lim[k], k]`` are distinct, the span stays
    inside one ``cut_every`` interval, and the length is capped at E.  All
    three lower bounds are non-decreasing in k, so ``s_lim`` is monotone
    (which the DP's sliding-window minimum relies on)."""
    T = slot.size
    s_lim = np.empty(T, np.int64)
    last: dict[int, int] = {}
    s = 0
    for k in range(T):
        v = int(slot[k])
        p = last.get(v, -1)
        if p >= s:
            s = p + 1
        if cut_every:
            b = (k // cut_every) * cut_every
            if b > s:
                s = b
        lo = k - E + 1
        if lo > s:
            s = lo
        s_lim[k] = s
        last[v] = k
    return s_lim


def _dp_starts(slot: np.ndarray, E: int, cut_every: int) -> list[int]:
    """Exact minimum-block-count cut via an O(T) DP.

    ``f[k]`` = fewest blocks covering events ``[0, k)``; the transition
    minimizes over admissible last-block starts ``i in [s_lim[k-1], k)``
    (conflict-free, length <= E, no ``cut_every`` boundary inside).  The
    admissible window's left edge is monotone, so the minimum is maintained
    with a monotonic deque — one push/pop per event.  Ties prefer the
    smallest start (the longest trailing block), making the reconstruction
    deterministic.  Block validity is hereditary (any subinterval of a
    conflict-free block is conflict-free), so this matches the greedy
    count exactly — the DP is the optimality certificate the tests hold
    the greedy cut to, and the base `select_block_size` measures on.
    """
    T = slot.size
    if T == 0:
        return [0]
    s_lim = _window_starts(slot, E, cut_every)
    f = np.empty(T + 1, np.int64)
    f[0] = 0
    back = np.empty(T + 1, np.int64)
    dq: deque[tuple[int, int]] = deque()  # (f[i], i), f increasing
    pushed = 0
    for k in range(1, T + 1):
        while pushed < k:  # starts up to k-1 become available
            while dq and dq[-1][0] > f[pushed]:
                dq.pop()
            dq.append((int(f[pushed]), pushed))
            pushed += 1
        lo = s_lim[k - 1]
        while dq and dq[0][1] < lo:
            dq.popleft()
        fb, i = dq[0]
        f[k] = fb + 1
        back[k] = i
    starts = []
    k = T
    while k > 0:
        k = int(back[k])
        starts.append(k)
    starts.reverse()
    return starts


def segment_blocks(
    slot: np.ndarray, block_size: int, cut_every: int = 0, method: str = "greedy"
) -> tuple[np.ndarray, np.ndarray]:
    """Conflict-free cut of an event stream into micro-blocks.

    Walks the (T,) ``slot`` sequence and closes a block whenever the next
    event's ring-buffer slot already appears in it (its dispatch-time
    snapshot was *written inside the block*, so its gradient depends on an
    in-block update), the block holds ``block_size`` events, or — when
    ``cut_every > 0`` — the event index crosses a multiple of ``cut_every``
    (so evaluation points land exactly on block boundaries).

    ``method`` picks the cut placement: ``"greedy"`` extends each block
    maximally (provably minimal in block count — validity is hereditary);
    ``"dp"`` computes the same minimum by exact dynamic programming over all
    admissible cut points (`_dp_starts`), guaranteeing no more padded lanes
    than greedy and a deterministic longest-trailing-block tie-break.

    Returns ``(idx, mask)`` with fixed shape ``(B, E)``: ``idx[b, i]`` is the
    event index of the i-th event of block b (0 on padding), ``mask[b, i]``
    marks real events.  Within a block all slots are distinct, so the blocked
    replay — batch-gather, batched gradients, prefix-sum of the scaled
    updates — reproduces the sequential Algorithm 1 exactly.
    """
    E = int(block_size)
    if E < 1:
        raise ValueError("block_size >= 1 required")
    slot = np.asarray(slot)
    T = slot.size
    if method == "greedy":
        starts = _greedy_starts(slot, E, cut_every)
    elif method == "dp":
        starts = _dp_starts(slot, E, cut_every)
    else:
        raise ValueError(f"unknown segmentation method {method!r}")
    B = len(starts)
    bounds = np.asarray(starts + [T])
    idx = np.zeros((B, E), np.int32)
    mask = np.zeros((B, E), bool)
    for b in range(B):
        lo, hi = bounds[b], bounds[b + 1]
        idx[b, : hi - lo] = np.arange(lo, hi)
        mask[b, : hi - lo] = True
    return idx, mask


def select_block_size(
    slots: np.ndarray | list[np.ndarray],
    block_size_max: int = 16,
    devices: int = 1,
    cut_every: int = 0,
    min_utilization: float = 0.5,
    method: str = "dp",
) -> tuple[int, dict[int, float]]:
    """Pick the lane count E from the *measured* conflict structure.

    Cut placement is governed by the delay distribution: an intra-block
    conflict means a task completed with a delay shorter than its in-block
    offset, so the measured conflict-free run lengths of a stream bound how
    full E lanes can get.  For each candidate E (multiples of ``devices``,
    so every block splits evenly across lane-shard devices) this segments
    the measured ``slots`` (one ``(T,)`` array or a list of them, aggregated)
    and computes the mean lane utilization ``sum(T) / sum(B(E) * E)``.

    Returns ``(E, utilizations)`` where E is the **largest** candidate whose
    utilization stays at or above ``min_utilization`` — the biggest batch
    whose lanes actually fill — falling back to the highest-utilization
    candidate when none clears the floor.
    """
    if isinstance(slots, np.ndarray):
        slots = [slots]
    step = max(int(devices), 1)
    if block_size_max < step:
        raise ValueError("block_size_max must be >= devices")
    utils: dict[int, float] = {}
    for E in range(step, block_size_max + 1, step):
        total_T = total_lanes = 0
        for s in slots:
            _, mask = segment_blocks(np.asarray(s), E, cut_every, method=method)
            total_T += int(np.asarray(s).size)
            total_lanes += int(mask.size)
        utils[E] = total_T / max(total_lanes, 1)
    above = [E for E, u in utils.items() if u >= min_utilization]
    best = max(above) if above else max(utils, key=lambda E: (utils[E], E))
    return best, utils


@dataclass
class EventBlocks:
    """Conflict-free micro-blocks of an `EventStream`, in fixed-shape form.

    ``idx``/``mask`` come from `segment_blocks`; ``J``/``slot``/``k`` are the
    blocked event columns with padding already neutralized: padded lanes get
    client 0, the trash ring-buffer row ``C`` (the blocked engine allocates
    C+1 snapshot rows so padded scatters land in a scratch row) and event
    index 0 — their update scale is forced to 0 by `blocked_scales`.
    """

    idx: np.ndarray          # (B, E) event index per block lane
    mask: np.ndarray         # (B, E) True on real events, False on padding
    J: np.ndarray            # (B, E) completing client (0 on padding)
    slot: np.ndarray         # (B, E) ring slot; == C (trash row) on padding
    n: int
    C: int
    T: int
    block_size: int
    cut_every: int = 0
    method: str = "greedy"   # cut placement: "greedy" | "dp" (segment_blocks)
    stream: EventStream | None = None

    @property
    def B(self) -> int:
        return int(self.idx.shape[0])

    @property
    def utilization(self) -> float:
        """Mean lane utilization T / (B * E) — 1.0 means no padded lanes."""
        return self.T / max(self.mask.size, 1)

    @property
    def padded_lanes(self) -> int:
        """Number of no-op lanes (mask False) across all blocks."""
        return int(self.mask.size - self.T)

    @classmethod
    def from_stream(
        cls,
        stream: EventStream,
        block_size: int,
        cut_every: int = 0,
        method: str = "greedy",
    ) -> "EventBlocks":
        idx, mask = segment_blocks(stream.slot, block_size, cut_every, method)
        return cls(
            idx=idx,
            mask=mask,
            J=np.where(mask, stream.J[idx], 0).astype(np.int32),
            slot=np.where(mask, stream.slot[idx], stream.C).astype(np.int32),
            n=stream.n,
            C=stream.C,
            T=stream.T,
            block_size=int(block_size),
            cut_every=int(cut_every),
            method=method,
            stream=stream,
        )

    def blocked_scales(self, scale: np.ndarray) -> np.ndarray:
        """Blocked view of a per-step (T,) scale array; 0 on padding."""
        return np.where(self.mask, np.asarray(scale)[self.idx], 0.0)


def export_blocks(
    cfg: SimConfig,
    block_size: int,
    cut_every: int = 0,
    block: int = DEFAULT_BLOCK,
    method: str = "greedy",
) -> EventBlocks:
    """Simulate ``cfg`` and export conflict-free event micro-blocks.

    `export_stream` followed by `segment_blocks` — the host-side feed of the
    blocked scan engine (``engine_scan.make_runner(block_size=...)``).
    ``method`` picks the cut placement ("greedy" | "dp").
    """
    return EventBlocks.from_stream(
        export_stream(cfg, block=block), block_size, cut_every, method
    )


def _split_delays(node: np.ndarray, value: np.ndarray, n: int) -> list:
    """Per-node lists from flat (node, value) event records, in event order."""
    out: list[list] = [[] for _ in range(n)]
    for j, v in zip(node.tolist(), value.tolist()):
        out[j].append(v)
    return out


def export_stream(cfg: SimConfig, block: int = DEFAULT_BLOCK) -> EventStream:
    """Simulate ``cfg`` and export the event stream as replayable arrays.

    The (J, K, t) trace is identical to what ``ClosedNetworkSim(cfg).run(T)``
    produces for the same seed/block.  On top of it we compute the FIFO slot
    assignment by replaying per-client queues of slot ids — an O(T) host pass.
    """
    sim = ClosedNetworkSim(cfg, block=block)
    C = cfg.C
    # initial placement: task ids 0..C-1 were enqueued in order, one per slot
    init_nodes = np.empty(C, dtype=np.int32)
    for node, q in enumerate(sim.queues):
        for tid, _, _ in q:
            init_nodes[tid] = node
    J, K, t = sim.run(cfg.T)
    kinds = sim.kind_trace
    slot = np.empty(cfg.T, dtype=np.int32)
    slot_queues: list[deque] = [deque() for _ in range(sim.n)]
    for s, node in enumerate(init_nodes):
        slot_queues[node].append(s)
    if kinds is None:
        for k in range(cfg.T):
            s = slot_queues[J[k]].popleft()  # FIFO: oldest in-flight completes
            slot[k] = s
            slot_queues[K[k]].append(s)      # freed slot hosts the new dispatch
        delay_steps = sim.delay_steps
    else:
        # fault mode: delays recomputed per trace row (the sim records only
        # completion delays, which no longer align 1:1 with the merged trace)
        slot_disp = np.zeros(C, dtype=np.int64)  # dispatch step + 1, per slot
        delay_steps = np.zeros(cfg.T, dtype=np.int64)
        for k in range(cfg.T):
            if kinds[k] == KIND_FLIP or kinds[k] == KIND_STAGE:
                slot[k] = C        # trash row: flips/stages touch no queue
                continue
            s = slot_queues[J[k]].popleft()
            slot[k] = s
            delay_steps[k] = k - slot_disp[s]
            slot_queues[K[k]].append(s)   # freed slot hosts the (re-)dispatch
            slot_disp[s] = k + 1
    return EventStream(
        J=J,
        K=K,
        t=t,
        slot=slot,
        init_nodes=init_nodes,
        n=sim.n,
        C=C,
        p=sim.p.copy(),
        delay_steps=delay_steps,
        queue_len_sum=sim.queue_len_sum,
        queue_len_tw=sim.queue_len_tw,
        kind=kinds,
    )


class ClosedNetworkSim:
    """Stepable simulator (used by repro.fl.engine to drive real training).

    ``block`` sets the RNG pre-draw block size; it changes the (deterministic)
    event stream but not its law.
    """

    def __init__(self, cfg: SimConfig, block: int = DEFAULT_BLOCK):
        self.cfg = cfg
        self.n = int(np.asarray(cfg.mu).size)
        self.mu = np.asarray(cfg.mu, dtype=np.float64)
        self.p = np.asarray(cfg.p, dtype=np.float64)
        if abs(self.p.sum() - 1.0) > 1e-8:
            raise ValueError("p must sum to 1")
        if cfg.C < 1:
            raise ValueError("C >= 1 required")
        if cfg.service not in ("exp", "det"):
            raise ValueError(f"unknown service kind {cfg.service}")
        self.rng = np.random.default_rng(cfg.seed)
        self.now = 0.0
        self.step_idx = 0
        # FIFO queue per node: deque of (task_id, dispatch_step, dispatch_time)
        self.queues: list[deque] = [deque() for _ in range(self.n)]
        # Event heap of (time, seq, node, kind).  Only the head-of-line task
        # of each node is in service; lazy invalidation via seq check.  The
        # kind column is constant KIND_COMPLETE without faults, so ordering
        # (by time, seq) — and hence the fault-free stream — is unchanged.
        self.heap: list[tuple[float, int, int, int]] = []
        self._seq = 0
        self._inservice_seq = [-1] * self.n
        # fault injection (churn / crash / straggler timeout)
        fc = cfg.fault
        self._fault = fc is not None and fc.enabled
        if self._fault:
            if cfg.service != "exp":
                raise ValueError("fault injection requires service='exp'")
            qoff, qon, kap, theta = fc.resolve(self.n)
            self._qoff, self._qon = qoff.tolist(), qon.tolist()
            self._kap, self._theta = kap.tolist(), theta.tolist()
            # separate RNG sub-stream: fault clocks never perturb the main
            # service/dispatch draw sequence
            self._frng = np.random.default_rng((cfg.seed, 0xFA17))
            self._avail = [True] * self.n
            self._timeout_seq = [-1] * self.n
            self._avail_tw = [0.0] * self.n   # integral of 1{available}
            self._avail_last_t = [0.0] * self.n
            self.kind_counts = np.zeros(4, np.int64)
        # scenario injection (phase-type service + modulated availability)
        sc = getattr(cfg, "scenario", None)
        self._scenario = sc is not None and sc.enabled
        if self._scenario:
            if self._fault:
                raise ValueError(
                    "scenario= and fault= are separate injection paths; "
                    "fold churn rates into the scenario's modulation instead"
                )
            if cfg.service != "exp":
                raise ValueError("scenario= requires service='exp' "
                                 "(the phase chain replaces the service law)")
            alpha, srates, absorb, nxt = sc.service.chain()
            self._sc_cdf = np.cumsum(alpha)
            self._sc_cdf[-1] = max(self._sc_cdf[-1], 1.0)
            self._sc_rates = srates.tolist()
            self._sc_absorb = [bool(b) for b in absorb]
            self._sc_nxt = [int(x) for x in nxt]
            self._sc_S = len(self._sc_rates)
            mod = sc.modulation
            if mod is None:
                from .scenario import ModulationConfig

                mod = ModulationConfig()
            qoff, qon = mod.resolve(self.n)
            self._qoff, self._qon = qoff.tolist(), qon.tolist()
            self._rate_scale = float(mod.rate_scale)
            # scenario clocks (flips + phase draws) live on their own RNG
            # sub-stream, mirroring the fault path's isolation guarantee
            self._frng = np.random.default_rng((cfg.seed, 0x5CE9))
            self._avail = [True] * self.n
            self._avail_tw = [0.0] * self.n
            self._avail_last_t = [0.0] * self.n
            self.kind_counts = np.zeros(N_KINDS, np.int64)
            self._task_phase: dict[int, int] = {}
        self.kind_trace: np.ndarray | None = None  # filled by run() (fault mode)
        # delay recording (opt-in): flat per-event arrays with doubling growth
        # — the completing node of record k is the k-th completion, so the
        # per-node view is derivable and never materialized here.
        self._record = bool(cfg.record_delays)
        self._dcap = 0
        self._dlen = 0
        self._d_node: np.ndarray | None = None
        self._d_steps: np.ndarray | None = None
        self._d_time: np.ndarray | None = None
        if self._record:
            self._dcap = max(int(cfg.T), 1024)
            self._d_node = np.empty(self._dcap, np.int32)
            # int64: a CS-step delay is bounded by T, which exceeds int32
            # range on T > 2^31 runs — int32 here silently wrapped
            self._d_steps = np.empty(self._dcap, np.int64)
            self._d_time = np.empty(self._dcap, np.float64)
        # incremental queue-length counters + lazily-flushed accumulators
        # (python lists: O(1) scalar access is much faster than numpy indexing)
        self._qlen = [0] * self.n
        self._qsum = [0] * self.n          # flushed part of sum_k X_{i,k}
        self._last_snap = [1] * self.n     # 1-indexed step of last change
        self._tw = [0.0] * self.n          # flushed part of int X_i(t) dt
        self._last_t = [0.0] * self.n      # time of last change
        self._inv_mu = (1.0 / self.mu).tolist()
        self._is_exp = cfg.service == "exp"
        # block-buffered variates
        self._block = int(block)
        cdf = np.cumsum(self.p)
        cdf[-1] = max(cdf[-1], 1.0)  # guard fp undershoot at the tail
        self._cdf = cdf
        self._disp_buf: list[int] = []
        self._disp_ptr = 0
        self._exp_buf: list[float] = []
        self._exp_ptr = 0
        self._task_counter = 0
        self._init_tasks()
        if self._fault or self._scenario:
            # all nodes start available; arm the first on->off flip clocks
            for node in range(self.n):
                if self._qoff[node] > 0:
                    self._push_flip(node, self._qoff[node])

    # -------------------------------------------------------------- #
    def _refill_disp(self) -> None:
        u = self.rng.random(self._block)
        self._disp_buf = np.minimum(
            np.searchsorted(self._cdf, u, side="right"), self.n - 1
        ).tolist()
        self._disp_ptr = 0

    def _refill_exp(self) -> None:
        self._exp_buf = self.rng.standard_exponential(self._block).tolist()
        self._exp_ptr = 0

    def _std_exp(self) -> float:
        """Next pre-drawn standard-exponential variate from the main stream."""
        i = self._exp_ptr
        if i >= len(self._exp_buf):
            self._refill_exp()
            i = 0
        self._exp_ptr = i + 1
        return self._exp_buf[i]

    def _service_time(self, node: int) -> float:
        if self._is_exp:
            return self._std_exp() * self._inv_mu[node]
        return self._inv_mu[node]

    def _change(self, node: int, delta: int) -> None:
        """Update node's queue length; settle its accumulators up to now.

        The post-step states X_{i,k} are counted once per step k=1..T and the
        time integral carries the pre-change state over (last_t, now]; both
        only need attention at the (two) nodes an event touches.
        """
        k = self.step_idx + 1
        ql = self._qlen[node]
        self._qsum[node] += ql * (k - self._last_snap[node])
        self._last_snap[node] = k
        self._tw[node] += ql * (self.now - self._last_t[node])
        self._last_t[node] = self.now
        self._qlen[node] = ql + delta

    def _start_service(self, node: int) -> None:
        if self._scenario:
            # stage clock of the head-of-line task: rate = mu * stage-rate *
            # modulation speed.  A zero rate (node off, rate_scale=0) suspends
            # service until the next flip re-arms it — memorylessness makes
            # the fresh redraw on resume exact in law.
            tid = self.queues[node][0][0]
            ph = self._task_phase[tid]
            speed = 1.0 if self._avail[node] else self._rate_scale
            rate = self.mu[node] * self._sc_rates[ph] * speed
            if rate <= 0.0:
                self._inservice_seq[node] = -2
                return
            self._seq += 1
            self._inservice_seq[node] = self._seq
            heapq.heappush(
                self.heap,
                (self.now + self._std_exp() / rate, self._seq, node, KIND_COMPLETE),
            )
            return
        self._seq += 1
        self._inservice_seq[node] = self._seq
        heapq.heappush(
            self.heap,
            (self.now + self._service_time(node), self._seq, node, KIND_COMPLETE),
        )
        if self._fault and self._kap[node] > 0:
            # crash races the completion; same seq — both die together when
            # the head task changes or the node flips off
            heapq.heappush(
                self.heap,
                (
                    self.now + self._frng.standard_exponential() / self._kap[node],
                    self._seq,
                    node,
                    KIND_CRASH,
                ),
            )

    def _push_flip(self, node: int, rate: float) -> None:
        self._seq += 1
        heapq.heappush(
            self.heap,
            (self.now + self._frng.standard_exponential() / rate, self._seq,
             node, KIND_FLIP),
        )

    def _schedule_head(self, node: int) -> None:
        """Arm the clocks of a new head-of-line task.

        Service (completion + crash) only runs while the node is available;
        the straggler timeout is a server-side deadline and fires regardless.
        In scenario mode `_start_service` itself handles modulated speeds
        (including suspension at rate 0) and there are no timeout clocks.
        """
        if not self._fault:
            self._start_service(node)
            return
        if self._avail[node]:
            self._start_service(node)
        if self._theta[node] > 0:
            self._seq += 1
            self._timeout_seq[node] = self._seq
            heapq.heappush(
                self.heap,
                (self.now + self._frng.standard_exponential() / self._theta[node],
                 self._seq, node, KIND_TIMEOUT),
            )

    def _settle_avail(self, node: int) -> None:
        if self._avail[node]:
            self._avail_tw[node] += self.now - self._avail_last_t[node]
        self._avail_last_t[node] = self.now

    @property
    def avail_tw(self) -> np.ndarray | None:
        """(n,) time integral of availability, flushed to `now` (fault mode)."""
        if not (self._fault or self._scenario):
            return None
        out = np.array(self._avail_tw, np.float64)
        pending = np.array(self._avail, np.float64) * (
            self.now - np.array(self._avail_last_t)
        )
        return out + pending

    def availability(self) -> np.ndarray | None:
        if not (self._fault or self._scenario):
            return None
        return np.array(self._avail, bool)

    def _enqueue(self, node: int, dispatch_step: int) -> int:
        tid = self._task_counter
        self._task_counter += 1
        if self._scenario:
            # the task's initial service stage is drawn at dispatch — by
            # independence of the stage sequence from the queue process this
            # is law-identical to drawing it at service start, and it is what
            # the device stream does (one phase draw per dispatch)
            u = self._frng.random()
            self._task_phase[tid] = min(
                int(np.searchsorted(self._sc_cdf, u, side="right")), self._sc_S - 1
            )
        self.queues[node].append((tid, dispatch_step, self.now))
        self._change(node, +1)
        if len(self.queues[node]) == 1:
            self._schedule_head(node)
        return tid

    def _init_tasks(self) -> None:
        if self.cfg.initial == "distinct":
            if self.cfg.C > self.n:
                # spread round-robin when C > n (paper uses C <= n for S_0,
                # but saturated-regime experiments need C >> n)
                nodes = [i % self.n for i in range(self.cfg.C)]
            else:
                nodes = list(
                    self.rng.choice(self.n, size=self.cfg.C, replace=False, p=None)
                )
        elif self.cfg.initial == "sampled":
            nodes = list(self.rng.choice(self.n, size=self.cfg.C, p=self.p))
        else:
            raise ValueError(self.cfg.initial)
        for nd in nodes:
            self._enqueue(int(nd), dispatch_step=0)

    # -------------------------------------------------------------- #
    def _grow_delay_buffers(self) -> None:
        self._dcap *= 2
        for name in ("_d_node", "_d_steps", "_d_time"):
            buf = getattr(self, name)
            new = np.empty(self._dcap, buf.dtype)
            new[: self._dlen] = buf[: self._dlen]
            setattr(self, name, new)

    @property
    def delay_steps(self) -> np.ndarray | None:
        """(k,) flat CS-step delays in completion order (node k is J_k)."""
        if not self._record:
            return None
        return self._d_steps[: self._dlen].copy()

    @property
    def delays(self) -> list[list[int]] | None:
        """Per-node CS-step delays (derived view; None unless record_delays)."""
        if not self._record:
            return None
        return _split_delays(self._d_node[: self._dlen], self._d_steps[: self._dlen], self.n)

    @property
    def time_delays(self) -> list[list[float]] | None:
        """Per-node physical-time sojourns (derived view)."""
        if not self._record:
            return None
        return _split_delays(self._d_node[: self._dlen], self._d_time[: self._dlen], self.n)

    def total_tasks(self) -> int:
        return sum(self._qlen)

    def queue_lengths(self) -> np.ndarray:
        return np.array(self._qlen)

    @property
    def queue_len_sum(self) -> np.ndarray:
        """sum_{k=1..step_idx} X_{i,k} (post-step states), flushed on read."""
        q = np.array(self._qlen, dtype=np.float64)
        pending = q * (self.step_idx + 1 - np.array(self._last_snap))
        return np.array(self._qsum, dtype=np.float64) + pending

    @property
    def queue_len_tw(self) -> np.ndarray:
        """int_0^now X_i(t) dt, flushed on read."""
        q = np.array(self._qlen, dtype=np.float64)
        pending = q * (self.now - np.array(self._last_t))
        return np.array(self._tw, dtype=np.float64) + pending

    def step_event(self) -> tuple[int, int, int]:
        """Advance one merged-CTMC event.  Returns ``(kind, node, k_new)``.

        Without faults every event is a completion, so this is exactly one CS
        step.  With faults ``kind`` is a ``KIND_*`` tag: task movements
        (complete / crash / timeout) pop the head-of-line task at ``node`` and
        re-dispatch it at ``k_new ~ p``; availability flips toggle ``node``
        and return ``k_new = -1``.  ``step_idx`` counts merged events —
        exactly the scan-step counter of the device fault stream, so delays
        measured in steps agree between the two paths.
        """
        heap = self.heap
        inservice = self._inservice_seq
        fault = self._fault
        while True:
            t_ev, seq, node, kind = heapq.heappop(heap)
            if kind == KIND_FLIP:
                break  # exactly one outstanding flip per node — always valid
            if kind == KIND_TIMEOUT:
                if self._timeout_seq[node] == seq:
                    break
            elif inservice[node] == seq:
                break
        self.now = t_ev
        if kind == KIND_FLIP:
            self._settle_avail(node)
            up = not self._avail[node]
            self._avail[node] = up
            if self._scenario:
                # modulated speed changed: invalidate and re-arm the stage
                # clock at the new rate (exact by memorylessness; the task's
                # phase is preserved).  rate_scale=0 leaves it suspended.
                self._inservice_seq[node] = -2
                if self._qlen[node] > 0:
                    self._start_service(node)
                rate = self._qoff[node] if up else self._qon[node]
                if rate > 0:
                    self._push_flip(node, rate)
            elif up:
                if self._qlen[node] > 0:
                    self._start_service(node)  # memoryless: fresh service draw
                if self._qoff[node] > 0:
                    self._push_flip(node, self._qoff[node])
            else:
                self._inservice_seq[node] = -2  # suspend completion + crash
                if self._qon[node] > 0:
                    self._push_flip(node, self._qon[node])
            self.step_idx += 1
            self.kind_counts[KIND_FLIP] += 1
            return KIND_FLIP, node, -1
        if self._scenario:
            # a stage clock fired: absorb (fall through to the completion
            # path below) or advance the head task to its next stage
            tid = self.queues[node][0][0]
            ph = self._task_phase[tid]
            if not self._sc_absorb[ph]:
                self._task_phase[tid] = self._sc_nxt[ph]
                self._start_service(node)
                self.step_idx += 1
                self.kind_counts[KIND_STAGE] += 1
                return KIND_STAGE, node, -1
            del self._task_phase[tid]
            self.kind_counts[KIND_COMPLETE] += 1
            self._inservice_seq[node] = -2
        # task movement: complete / crash / timeout pops the head-of-line task
        q = self.queues[node]
        tid, disp_step, disp_time = q.popleft()
        if kind == KIND_COMPLETE and self._record:
            # delay in CS steps: completions strictly between dispatch and this
            i = self._dlen
            if i >= self._dcap:
                self._grow_delay_buffers()
            self._d_node[i] = node
            self._d_steps[i] = self.step_idx - disp_step
            self._d_time[i] = t_ev - disp_time
            self._dlen = i + 1
        self._change(node, -1)
        if fault:
            self._inservice_seq[node] = -2  # kill the crash/completion sibling
            self._timeout_seq[node] = -2
            self.kind_counts[kind] += 1
        if q:
            self._schedule_head(node)
        # dispatcher samples the next client from the pre-drawn block
        i = self._disp_ptr
        if i >= len(self._disp_buf):
            self._refill_disp()
            i = 0
        self._disp_ptr = i + 1
        k_new = self._disp_buf[i]
        self._enqueue(k_new, dispatch_step=self.step_idx + 1)
        self.step_idx += 1
        return kind, node, k_new

    def step(self) -> tuple[int, int]:
        """Advance one CS step.  Returns (J_k, K_{k+1}).

        With faults enabled this advances one *merged* event (which may be a
        flip, returning K = -1) — fault-aware callers should use `step_event`
        to see the kind tag.
        """
        _, node, k_new = self.step_event()
        return node, k_new

    def run(self, T: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Advance T steps, returning the (J, K, t) trace arrays.

        In fault mode the per-event kind tags of this run are kept in
        ``self.kind_trace`` (int8, aligned with the returned arrays).
        """
        step_event = self.step_event
        Jl: list[int] = []
        Kl: list[int] = []
        tl: list[float] = []
        kl: list[int] | None = [] if (self._fault or self._scenario) else None
        append_J, append_K, append_t = Jl.append, Kl.append, tl.append
        for _ in range(T):
            kind, j, k_new = step_event()
            append_J(j)
            append_K(k_new)
            append_t(self.now)
            if kl is not None:
                kl.append(kind)
        if kl is not None:
            self.kind_trace = np.array(kl, dtype=np.int8)
        return (
            np.array(Jl, dtype=np.int32),
            np.array(Kl, dtype=np.int32),
            np.array(tl, dtype=np.float64),
        )


def simulate_batch(cfg: SimConfig, block: int = DEFAULT_BLOCK) -> SimResult:
    """Fast-path simulation: pre-drawn RNG blocks + the O(1)-per-event core."""
    sim = ClosedNetworkSim(cfg, block=block)
    J, K, t = sim.run(cfg.T)
    return SimResult(
        J=J,
        K=K,
        t=t,
        delays=sim.delays,
        time_delays=sim.time_delays,
        queue_len_sum=sim.queue_len_sum,
        queue_len_tw=sim.queue_len_tw,
        queue_len_last=sim.queue_lengths(),
        steps=cfg.T,
    )


def simulate(cfg: SimConfig) -> SimResult:
    return simulate_batch(cfg)
