"""Compiled device-resident Generalized-AsyncSGD engine (one `lax.scan`).

The Python reference loop in `async_sgd.py` pays a host<->device round trip
per CS step, which caps the §5 experiment at toy sizes.  The queuing
structure removes the need for that: the event stream (J_k, K_{k+1}, t_k) of
the closed Jackson network is independent of the gradient values, so
Algorithm 1 runs on device as a single XLA program:

  * the C in-flight dispatch snapshots live in a stacked ring buffer
    (a (C, ...) leading axis on every parameter leaf);
  * `update_step` — the algorithm half — gathers the completing task's
    snapshot from its slot, computes the client gradient with a traceable
    `grad_fn(j, w, k)`, applies the importance-weighted update, and scatters
    the updated parameters back into the same slot (the freed slot hosts the
    new dispatch — exactly one task completes and one departs per step,
    Lemma 9);
  * the event half comes from one of two *streams* (`make_runner(stream=)`):

      "host"    replay a pre-simulated `queue_sim.EventStream` — the parity
                oracle.  `run(w0, J, slot, scale[, eval_every])`.
      "device"  fuse `stream_device.stream_step` with `update_step` behind a
                single scan carry: the closed network advances one CS step
                per iteration *inside* the compiled program — zero host
                pre-simulation, and the sampling vector p becomes state.
                `run(w0, mu, p0, key, eta) -> (w, evals, extras)`.

  * on the fused path an optional control loop (``adaptive=True``)
    re-optimizes p every `refresh_every` steps from the running occupancy /
    rate estimates (`stream_device.ctrl_refresh` — projected analytic
    simplex gradient steps on the Theorem-1 bound), giving an adaptive
    variant of the paper's sampling scheme.  Importance weights stay
    unbiased under time-varying p because each in-flight slot remembers the
    scale computed from its *dispatch-time* p.
  * evaluation runs as an outer scan over chunks, so the whole run —
    updates and metric curve — is one compiled call.

`make_runner` returns a pure function: jit it for a single run, `jax.vmap`
it over stacked streams / (mu, p, key) triples for the scenario matrix
(seeds x sampling policies x heterogeneity levels in one compiled call).

FedBuff rides the same scan: gradients accumulate into a buffer pytree and
the (masked, branch-free) server update fires every Z-th step.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Protocol

import numpy as np

from .queue_sim import EventStream
from .theory import BoundConstants

__all__ = [
    "DeviceGradientSource",
    "jit_runner",
    "jit_fused_runner",
    "make_runner",
    "make_fused_runner",
    "step_scales",
    "stream_arrays",
]

Pytree = Any


class DeviceGradientSource(Protocol):
    """A gradient source the compiled engine can trace.

    Unlike `GradientSource.grad` (host Python, one call per step),
    `device_grad` is called once under tracing with abstract scalar
    `client_id` / `server_step`; it must be expressible as pure JAX ops over
    device-resident data (e.g. a gather from stacked per-client shards).
    """

    def device_grad(self, client_id, params: Pytree, server_step) -> Pytree:
        ...


def step_scales(
    stream: EventStream, eta: float, p: np.ndarray, weighting: str
) -> np.ndarray:
    """Per-step update scale as a (T,) array: eta/(n p_{J_k}) or plain eta."""
    if weighting == "importance":
        return (eta / (stream.n * np.asarray(p, float)))[stream.J]
    if weighting == "plain":
        return np.full(stream.T, eta)
    raise ValueError(weighting)


def stream_arrays(stream: EventStream):
    """Device copies of the scan inputs (J, slot) for one stream."""
    import jax.numpy as jnp

    return jnp.asarray(stream.J), jnp.asarray(stream.slot)


# ------------------------------------------------------------------ #
# shared pieces: snapshot codec + the algorithm step
# ------------------------------------------------------------------ #
def _snapshot_codec(w0):
    """Flat-packed snapshot storage when all leaves share a dtype.

    The ring buffer then is ONE (C, P) array — a single gather/scatter
    per step instead of two per leaf, which matters for small models
    where per-op overhead inside the scan dominates.  Mixed-dtype trees
    fall back to per-leaf (C, ...) buffers.
    """
    import jax
    import jax.numpy as jnp

    leaves, treedef = jax.tree_util.tree_flatten(w0)
    dtypes = {jnp.asarray(l).dtype for l in leaves}
    if len(dtypes) != 1:
        return None, None  # per-leaf buffers
    shapes = [jnp.shape(l) for l in leaves]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    offs = np.concatenate([[0], np.cumsum(sizes)]).tolist()

    def pack(w):
        ls = jax.tree_util.tree_leaves(w)
        return jnp.concatenate([jnp.ravel(x) for x in ls])

    def unpack(flat):
        ls = [
            flat[offs[i] : offs[i + 1]].reshape(shapes[i])
            for i in range(len(shapes))
        ]
        return jax.tree_util.tree_unflatten(treedef, ls)

    return pack, unpack


def _make_update_step(grad_fn, fedbuff_Z, update_fn, pack, unpack, flat_mode):
    """The algorithm half of a CS step, independent of the event source.

    ``update_step(ucarry, j, s, scale, k) -> ucarry`` consumes one event
    (completing client j, ring slot s, update scale, server step k) exactly
    as Algorithm 1 lines 9-11 — both the host-replay scan body and the fused
    device-stream body compose it with their event producer.
    """
    import jax
    import jax.numpy as jnp

    tree_map = jax.tree_util.tree_map

    def update_step(ucarry, j, s, scale, k):
        w, snaps, acc = ucarry  # w (and acc) are flat vectors in flat_mode
        # gather the completing task's dispatch-time snapshot (Alg. 1 line 9)
        if unpack is None:
            w_disp = tree_map(lambda b: b[s], snaps)
        else:
            w_disp = unpack(snaps[s])
        g = grad_fn(j, w_disp, k)
        if flat_mode:
            # default update on the packed vector: one axpy, one scatter
            g = pack(g)
            if fedbuff_Z > 0:
                acc = acc + g
                fire = ((k + 1) % fedbuff_Z) == 0
                eff = jnp.where(fire, scale / fedbuff_Z, 0.0)
                w = (w - eff * acc).astype(w.dtype)
                acc = acc * (~fire).astype(acc.dtype)
            else:
                w = (w - scale * g).astype(w.dtype)
            snaps = snaps.at[s].set(w)
            return (w, snaps, acc)
        if fedbuff_Z > 0:
            acc = tree_map(lambda a, y: a + y, acc, g)
            fire = ((k + 1) % fedbuff_Z) == 0
            eff = jnp.where(fire, scale / fedbuff_Z, 0.0)
            w = update_fn(w, acc, eff)
            acc = tree_map(lambda a: a * (~fire).astype(a.dtype), acc)
        else:
            w = update_fn(w, g, scale)
        # the freed slot hosts the new dispatch with the updated params
        if unpack is None:
            snaps = tree_map(lambda b, x: b.at[s].set(x), snaps, w)
        else:
            snaps = snaps.at[s].set(pack(w))
        return (w, snaps, acc)

    return update_step


def _init_update_carry(w0, C, pack, unpack, flat_mode, fedbuff_Z):
    """(w, snaps, acc) initial carry + the carry->pytree decoder."""
    import jax
    import jax.numpy as jnp

    tree_map = jax.tree_util.tree_map
    if unpack is None:
        snaps0 = tree_map(
            lambda x: jnp.broadcast_to(x[None], (C,) + jnp.shape(x)), w0
        )
        w_init = w0
    else:
        flat0 = pack(w0)
        snaps0 = jnp.broadcast_to(flat0[None], (C, flat0.shape[0]))
        w_init = flat0 if flat_mode else w0
    acc0 = tree_map(jnp.zeros_like, w_init) if fedbuff_Z > 0 else ()
    to_tree = (lambda w: unpack(w)) if flat_mode else (lambda w: w)
    return (w_init, snaps0, acc0), to_tree


def _default_update(update_fn):
    """Resolve update_fn; the default casts back per leaf so the scan carry
    dtype stays stable (bf16 params with an fp32 scale would otherwise
    promote)."""
    import jax

    if update_fn is not None:
        return update_fn, False
    tree_map = jax.tree_util.tree_map
    return (
        lambda w, g, s: tree_map(lambda x, y: (x - s * y).astype(x.dtype), w, g),
        True,
    )


# ------------------------------------------------------------------ #
# host stream: replay a pre-simulated EventStream
# ------------------------------------------------------------------ #
def _make_host_runner(
    grad_fn: Callable[[Any, Pytree, Any], Pytree],
    C: int,
    *,
    fedbuff_Z: int = 0,
    eval_fn: Callable[[Pytree], Any] | None = None,
    eval_every: int = 0,
    update_fn: Callable[[Pytree, Pytree, Any], Pytree] | None = None,
    unroll: int = 1,
):
    """Build the replay engine for a fixed algorithm shape.

    Returns ``run(w0, J, slot, scale, eval_every=...) -> (w_final, evals)``
    — a pure function: `jax.jit` it directly (``eval_every`` is a Python
    int, pass it via ``static_argnames``), or `jax.vmap(run, in_axes=(None,
    0, 0, 0))` to execute a whole scenario matrix in one compiled call.
    ``evals`` is the eval_fn curve sampled every `eval_every` steps (empty
    array when evaluation is off).  The factory-level ``eval_every`` only
    sets the run-time default, so one runner serves any eval cadence.

    grad_fn(j, w, k): traceable stochastic gradient of client j at params w,
    server step k.  update_fn(w, g, scale) defaults to w - scale*g.
    """
    import jax
    import jax.numpy as jnp

    update_fn, default_update = _default_update(update_fn)
    eval_every_default = eval_every

    def run(w0, J, slot, scale, eval_every=eval_every_default):
        pack, unpack = _snapshot_codec(w0)
        flat_mode = default_update and unpack is not None
        update_step = _make_update_step(
            grad_fn, fedbuff_Z, update_fn, pack, unpack, flat_mode
        )

        def body(carry, xs):
            j, s, sc, k = xs
            return update_step(carry, j, s, sc, k), ()

        def scan(carry, Jc, slotc, scalec, k0):
            ks = k0 + jnp.arange(Jc.shape[0], dtype=Jc.dtype)
            return jax.lax.scan(body, carry, (Jc, slotc, scalec, ks), unroll=unroll)[0]

        carry, to_tree = _init_update_carry(w0, C, pack, unpack, flat_mode, fedbuff_Z)
        T = int(J.shape[0])
        if eval_fn is not None and eval_every and T >= eval_every:
            n_chunks = T // eval_every
            Tc = n_chunks * eval_every

            # per-chunk absolute step offsets ride along as a scan input
            def chunk_body(c, xs):
                Jc, sc, scc, k0 = xs
                c = scan(c, Jc, sc, scc, k0)
                return c, eval_fn(to_tree(c[0]))

            xs = (
                J[:Tc].reshape(n_chunks, eval_every),
                slot[:Tc].reshape(n_chunks, eval_every),
                scale[:Tc].reshape(n_chunks, eval_every),
                jnp.arange(n_chunks, dtype=J.dtype) * eval_every,
            )
            carry, evals = jax.lax.scan(chunk_body, carry, xs)
            if Tc < T:  # tail events past the last eval point
                carry = scan(carry, J[Tc:], slot[Tc:], scale[Tc:], k0=Tc)
            return to_tree(carry[0]), evals
        carry = scan(carry, J, slot, scale, k0=0)
        return to_tree(carry[0]), jnp.zeros((0,))

    return run


# ------------------------------------------------------------------ #
# device stream: fused generator + control loop
# ------------------------------------------------------------------ #
def make_fused_runner(
    grad_fn: Callable[[Any, Pytree, Any], Pytree],
    n: int,
    C: int,
    T: int,
    *,
    weighting: str = "importance",
    fedbuff_Z: int = 0,
    eval_fn: Callable[[Pytree], Any] | None = None,
    eval_every: int = 0,
    adaptive: bool = False,
    refresh_every: int = 0,
    bound: BoundConstants | None = None,
    ctrl_lr: float = 0.3,
    ctrl_iters: int = 4,
    update_fn: Callable[[Pytree, Pytree, Any], Pytree] | None = None,
    init: str = "distinct",
    unroll: int = 1,
):
    """Build the fused engine: `stream_device.stream_step` ∘ `update_step`.

    Returns ``run(w0, mu, p0, key, eta) -> (w_final, evals, extras)``.  The
    closed network advances inside the scan (exponential service only), so
    nothing is pre-simulated on the host; `jax.vmap(run, in_axes=(None, 0,
    0, 0, None))` executes a scenario matrix in one compiled call.

    With ``adaptive=True`` the sampling vector is re-optimized from the
    running occupancy/rate estimates every `refresh_every` steps
    (`stream_device.ctrl_refresh`); each in-flight task keeps the
    importance scale of its dispatch-time p, so the weighted update stays
    unbiased under the time-varying policy.  ``extras`` carries the
    per-step event times plus the final/trajectory sampling vectors and
    the on-device occupancy, busy-time, delay and completion statistics.
    """
    import jax
    import jax.numpy as jnp

    from . import stream_device as sd

    if weighting not in ("importance", "plain"):
        raise ValueError(weighting)
    if adaptive:
        if fedbuff_Z:
            raise ValueError("adaptive sampling applies to Algorithm 1, not FedBuff")
        if refresh_every <= 0:
            raise ValueError("adaptive=True requires refresh_every > 0")
        if eval_fn is not None and eval_every and eval_every % refresh_every:
            raise ValueError("eval_every must be a multiple of refresh_every")
    bound = bound if bound is not None else BoundConstants(C=C, T=T)
    importance = weighting == "importance"

    # chunk length: refresh and eval both happen at chunk boundaries
    if adaptive:
        L = min(refresh_every, T)
    elif eval_fn is not None and eval_every:
        L = min(eval_every, T)
    else:
        L = T
    n_chunks, Tc = T // L, (T // L) * L
    eval_on = eval_fn is not None and eval_every > 0
    eval_stride = max(eval_every // L, 1) if eval_on else 0

    update_fn, default_update = _default_update(update_fn)

    def run(w0, mu, p0, key, eta):
        pack, unpack = _snapshot_codec(w0)
        flat_mode = default_update and unpack is not None
        update_step = _make_update_step(
            grad_fn, fedbuff_Z, update_fn, pack, unpack, flat_mode
        )
        ucarry, to_tree = _init_update_carry(w0, C, pack, unpack, flat_mode, fedbuff_Z)

        mu = jnp.asarray(mu, jnp.float32)
        p0 = jnp.asarray(p0, jnp.float32)
        eta = jnp.asarray(eta, jnp.float32)
        k_init, k_race, k_exp, k_disp = jax.random.split(key, 4)
        u_race = jax.random.uniform(k_race, (T,))
        u_exp = jax.random.uniform(k_exp, (T,))
        u_disp = jax.random.uniform(k_disp, (T,))
        sstate, init_nodes = sd.stream_init(k_init, n, C, p0, init=init)
        stats = sd.stats_init(n, C)
        # dispatch-time importance scale per in-flight slot (Alg. 1 line 10)
        if importance:
            slot_scale0 = eta / (n * p0[init_nodes])
        else:
            slot_scale0 = jnp.broadcast_to(eta, (C,))

        def inner(ucarry, sstate, stats, slot_scale, p, ur, ue, Kc, k0):
            """One chunk of fused CS steps (p constant within the chunk)."""

            def body(c, x):
                ucarry, sstate, stats, slot_scale = c
                urk, uek, kn, k = x
                occ_pre = sstate.occ
                sstate, ev = sd.stream_step(sstate, mu, (urk, uek, kn))
                scale = slot_scale[ev.slot] if importance else eta
                ucarry = update_step(ucarry, ev.j, ev.slot, scale, k)
                stats = sd.stats_step(stats, ev, occ_pre, sstate.occ, k)
                if importance:
                    slot_scale = slot_scale.at[ev.slot].set(eta / (n * p[ev.k]))
                return (ucarry, sstate, stats, slot_scale), ev.t

            ks = k0 + jnp.arange(Kc.shape[0], dtype=jnp.int32)
            (ucarry, sstate, stats, slot_scale), ts = jax.lax.scan(
                body, (ucarry, sstate, stats, slot_scale), (ur, ue, Kc, ks),
                unroll=unroll,
            )
            return ucarry, sstate, stats, slot_scale, ts

        def sample_dispatch(cdf, u):
            return jnp.minimum(
                jnp.searchsorted(cdf, u, side="right"), n - 1
            ).astype(jnp.int32)

        def chunk_step(carry, xs):
            ucarry, sstate, stats, slot_scale, p, cdf = carry
            ur, ue, ud, k0 = xs
            Kc = sample_dispatch(cdf, ud)
            ucarry, sstate, stats, slot_scale, ts = inner(
                ucarry, sstate, stats, slot_scale, p, ur, ue, Kc, k0
            )
            if adaptive:
                p = sd.ctrl_refresh(
                    p, stats.comp, stats.busy_t, bound, lr=ctrl_lr, iters=ctrl_iters
                )
                cdf = jnp.cumsum(p)
            if not eval_on:
                ev_val = jnp.float32(0.0)
            elif eval_stride == 1:
                ev_val = eval_fn(to_tree(ucarry[0]))
            else:
                # eval fires every eval_stride-th refresh chunk; the predicate
                # is unbatched under vmap, so the cond stays a real branch and
                # off-cadence chunks skip the eval work entirely
                fire = ((k0 // L + 1) % eval_stride) == 0
                ev_val = jax.lax.cond(
                    fire,
                    lambda u: jnp.asarray(eval_fn(to_tree(u)), jnp.float32),
                    lambda u: jnp.float32(0.0),
                    ucarry[0],
                )
            return (ucarry, sstate, stats, slot_scale, p, cdf), (ts, ev_val, p)

        carry = (ucarry, sstate, stats, slot_scale0, p0, jnp.cumsum(p0))
        xs = (
            u_race[:Tc].reshape(n_chunks, L),
            u_exp[:Tc].reshape(n_chunks, L),
            u_disp[:Tc].reshape(n_chunks, L),
            jnp.arange(n_chunks, dtype=jnp.int32) * L,
        )
        carry, (ts, evals, p_traj) = jax.lax.scan(chunk_step, carry, xs)
        ucarry, sstate, stats, slot_scale, p, cdf = carry
        ts = ts.reshape(Tc)
        if Tc < T:  # tail events past the last chunk boundary
            Kc = sample_dispatch(cdf, u_disp[Tc:])
            ucarry, sstate, stats, slot_scale, ts_tail = inner(
                ucarry, sstate, stats, slot_scale, p,
                u_race[Tc:], u_exp[Tc:], Kc, Tc,
            )
            ts = jnp.concatenate([ts, ts_tail])
        if eval_on:
            evals = evals[eval_stride - 1 :: eval_stride]
        else:
            evals = jnp.zeros((0,))
        extras = {
            "t": ts,
            "p_final": p,
            "p_traj": p_traj,
            "occ_mean": stats.occ_sum.astype(jnp.float32) / T,
            "occ_time_avg": stats.occ_tw / ts[-1],
            "busy_time": stats.busy_t,
            "delay_sum": stats.delay_sum,
            "comp": stats.comp,
        }
        return to_tree(ucarry[0]), evals, extras

    return run


def make_runner(
    grad_fn: Callable[[Any, Pytree, Any], Pytree],
    C: int,
    *,
    stream: str = "host",
    fedbuff_Z: int = 0,
    eval_fn: Callable[[Pytree], Any] | None = None,
    eval_every: int = 0,
    update_fn: Callable[[Pytree, Pytree, Any], Pytree] | None = None,
    unroll: int = 1,
    **device_kw,
):
    """Build the scan engine; ``stream`` selects the event source.

    ``stream="host"`` (default) replays a pre-simulated `EventStream` — the
    parity oracle: ``run(w0, J, slot, scale[, eval_every])``.

    ``stream="device"`` fuses the on-device closed-network generator with
    the update step (zero host pre-simulation): ``run(w0, mu, p0, key, eta)``.
    Requires ``n=`` and ``T=`` (and accepts `make_fused_runner`'s
    ``weighting / adaptive / refresh_every / bound / ctrl_lr / ctrl_iters /
    init`` knobs).
    """
    if stream == "host":
        if device_kw:
            raise TypeError(f"host stream does not accept {sorted(device_kw)}")
        return _make_host_runner(
            grad_fn, C, fedbuff_Z=fedbuff_Z, eval_fn=eval_fn,
            eval_every=eval_every, update_fn=update_fn, unroll=unroll,
        )
    if stream == "device":
        try:
            n, T = device_kw.pop("n"), device_kw.pop("T")
        except KeyError as e:
            raise TypeError(f"stream='device' requires {e.args[0]}=") from None
        return make_fused_runner(
            grad_fn, n, C, T, fedbuff_Z=fedbuff_Z, eval_fn=eval_fn,
            eval_every=eval_every, update_fn=update_fn, unroll=unroll,
            **device_kw,
        )
    raise ValueError(stream)


# ------------------------------------------------------------------ #
# memoized jitted runners
# ------------------------------------------------------------------ #
def _runner_cache(grad_fn):
    """Per-owner memo for jitted runners.

    `make_runner` builds a fresh closure per call, which would defeat
    `jax.jit`'s compilation cache, so the jitted runner is memoized on the
    object owning `grad_fn` (its `__self__` for bound methods like
    `source.device_grad`, else the function's own `__dict__`): repeated runs
    with the same source reuse the compiled executable, and the memo — a
    plain attribute forming an internal reference cycle — is garbage
    collected together with the source instead of pinning device shards and
    executables in a process-global cache.
    """
    owner = getattr(grad_fn, "__self__", grad_fn)
    func = getattr(grad_fn, "__func__", grad_fn)
    try:
        cache = owner.__dict__.setdefault("_scan_runner_cache", {})
    except AttributeError:  # no instance dict (slots/builtin): skip memoization
        cache = {}
    return cache, func


def jit_runner(
    grad_fn,
    C: int,
    fedbuff_Z: int = 0,
    eval_fn=None,
    eval_every: int = 0,
    update_fn=None,
    unroll: int = 1,
    vmap_streams: bool = False,
):
    """Jitted, memoized host-replay runner.

    The memo key deliberately excludes ``eval_every``: the cadence is a
    static *call-time* argument of the jitted function (``static_argnames``),
    so sweeps over eval cadence reuse one runner object and share
    `jax.jit`'s compilation cache instead of rebuilding the closure (and
    with it the whole trace) per cadence.  ``vmap_streams=True`` returns the
    batched variant mapping over stacked (J, slot, scale).
    """
    import jax

    cache, func = _runner_cache(grad_fn)
    key = ("host", func, C, fedbuff_Z, eval_fn, update_fn, unroll, vmap_streams)
    if key not in cache:
        run = _make_host_runner(
            grad_fn, C, fedbuff_Z=fedbuff_Z, eval_fn=eval_fn, eval_every=0,
            update_fn=update_fn, unroll=unroll,
        )
        if vmap_streams:
            def vrun(w0, J, slot, scale, eval_every=0):
                return jax.vmap(
                    lambda w, a, b, c: run(w, a, b, c, eval_every),
                    in_axes=(None, 0, 0, 0),
                )(w0, J, slot, scale)

            cache[key] = jax.jit(vrun, static_argnames=("eval_every",))
        else:
            cache[key] = jax.jit(run, static_argnames=("eval_every",))
    return partial(cache[key], eval_every=eval_every)


def jit_fused_runner(
    grad_fn,
    n: int,
    C: int,
    T: int,
    *,
    vmap_scenarios: bool = False,
    shard_devices: int = 1,
    **kw,
):
    """Jitted, memoized fused (device-stream) runner.

    Memoized on the gradient source like `jit_runner`; ``vmap_scenarios``
    maps over stacked (mu, p0, key) with shared (w0, eta) — the zero-host-
    presimulation scenario matrix.  ``shard_devices > 1`` additionally
    `pmap`s the batched runner over that many devices (inputs carry an extra
    leading device axis) — the scenario matrix then runs data-parallel
    across the host platform's cores/accelerators, which the serial
    host-export path cannot.
    """
    import jax

    cache, func = _runner_cache(grad_fn)
    kw_key = tuple(
        (k, v) if k != "bound" else
        (k, None if v is None else (v.A, v.L, v.B, v.C, v.T, v.rho))
        for k, v in sorted(kw.items())
    )
    key = ("device", func, n, C, T, vmap_scenarios, shard_devices, kw_key)
    if key not in cache:
        run = make_fused_runner(grad_fn, n, C, T, **kw)
        if vmap_scenarios:
            batched = jax.vmap(run, in_axes=(None, 0, 0, 0, None))
            if shard_devices > 1:
                cache[key] = jax.pmap(batched, in_axes=(None, 0, 0, 0, None))
            else:
                cache[key] = jax.jit(batched)
        else:
            cache[key] = jax.jit(run)
    return cache[key]
