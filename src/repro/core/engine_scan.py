"""Compiled device-resident Generalized-AsyncSGD engine (one `lax.scan`).

The Python reference loop in `async_sgd.py` pays a host<->device round trip
per CS step, which caps the §5 experiment at toy sizes.  The queuing
structure removes the need for that: the event stream (J_k, K_{k+1}, t_k) of
the closed Jackson network is independent of the gradient values, so
Algorithm 1 runs on device as a single XLA program:

  * the C in-flight dispatch snapshots live in a stacked ring buffer
    (a (C, ...) leading axis on every parameter leaf; flat-packed into ONE
    (C, P) array when dtypes allow, optionally stored in a narrower dtype —
    ``snapshot_dtype="bfloat16"`` halves the ring buffer's footprint and
    bandwidth at an O(2^-8) snapshot quantization);
  * `update_step` — the algorithm half — gathers the completing task's
    snapshot from its slot, computes the client gradient with a traceable
    `grad_fn(j, w, k)`, applies the importance-weighted update, and scatters
    the updated parameters back into the same slot (the freed slot hosts the
    new dispatch — exactly one task completes and one departs per step,
    Lemma 9);
  * with ``block_size=E > 1`` the engine replays *event micro-blocks*
    instead of single events: gradients read dispatch-time snapshots, not
    the live server weight, so a block of events whose ring slots don't
    collide is data-parallel — `block_step` batch-gathers the E snapshots,
    computes all E gradients in one vmapped call, reconstructs the exact
    sequential iterates via a prefix sum over the scaled updates
    (w_i = w_0 - sum_{j<=i} eta_j g_j), and scatters the E intermediate
    weights back in one pass (optionally through the fused Pallas kernel
    `kernels.weighted_update.block_prefix_update`, which streams tiles once
    and updates the ring buffer in place).  On the host path the blocks come
    from `queue_sim.export_blocks` (greedy conflict-free cut, padded lanes
    target a trash row C); on the device path `stream_step` advances E CS
    steps per scan iteration and a sequential fixup pass recomputes the
    (rare) gradients whose dispatch landed inside the same window, so both
    paths stay law- and trajectory-equivalent to the per-event oracle;
  * the event half comes from one of two *streams* (`make_runner(stream=)`):

      "host"    replay a pre-simulated `queue_sim.EventStream` — the parity
                oracle.  `run(w0, J, slot, scale[, eval_every])`, or the
                blocked `run(w0, J, slot, scale, k, mask[, chunk_blocks,
                n_chunks])` over `queue_sim.EventBlocks` arrays.
      "device"  fuse `stream_device.stream_step` with `update_step` behind a
                single scan carry: the closed network advances one CS step
                (or one E-event micro-block) per iteration *inside* the
                compiled program — zero host pre-simulation, and the
                sampling vector p becomes state.
                `run(w0, mu, p0, key, eta) -> (w, evals, extras)`.

  * on the fused path an optional control loop (``adaptive=True``)
    re-optimizes p every `refresh_every` steps from the running occupancy /
    rate estimates (`stream_device.ctrl_refresh` — projected analytic
    simplex gradient steps on the Theorem-1 bound), giving an adaptive
    variant of the paper's sampling scheme.  Importance weights stay
    unbiased under time-varying p because each in-flight slot remembers the
    scale computed from its *dispatch-time* p.
  * evaluation runs as an outer scan over chunks, so the whole run —
    updates and metric curve — is one compiled call.  Eval points land on
    micro-block boundaries by construction (`segment_blocks(cut_every=)`),
    so the blocked curve samples the identical iterates.

`make_runner` returns a pure function: jit it for a single run, `jax.vmap`
it over stacked streams / (mu, p, key) triples for the scenario matrix
(seeds x sampling policies x heterogeneity levels in one compiled call).

FedBuff rides the same scan: gradients accumulate into a buffer pytree and
the (masked, branch-free) server update fires every Z-th step; the blocked
replay reproduces it exactly through a closed-form per-event delta
decomposition (each gradient is applied once, at the first buffer flush at
or after its arrival).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Protocol

import numpy as np

from .queue_sim import KIND_COMPLETE, EventBlocks, EventStream, FaultConfig
from .theory import BoundConstants

__all__ = [
    "DeviceGradientSource",
    "GuardConfig",
    "blocked_inputs",
    "blocked_inputs_batch",
    "jit_runner",
    "jit_fused_runner",
    "make_runner",
    "make_fused_runner",
    "step_scales",
    "stream_arrays",
]

Pytree = Any


class DeviceGradientSource(Protocol):
    """A gradient source the compiled engine can trace.

    Unlike `GradientSource.grad` (host Python, one call per step),
    `device_grad` is called once under tracing with abstract scalar
    `client_id` / `server_step`; it must be expressible as pure JAX ops over
    device-resident data (e.g. a gather from stacked per-client shards).
    """

    def device_grad(self, client_id, params: Pytree, server_step) -> Pytree:
        ...


def step_scales(
    stream: EventStream, eta: float, p: np.ndarray, weighting: str
) -> np.ndarray:
    """Per-step update scale as a (T,) array: eta/(n p_{J_k}) or plain eta.

    On fault-injected streams (``stream.kind`` set) non-completion events —
    crashes, timeouts, availability flips — carry scale 0: the replay engine
    then applies them exactly as the paper's update does nothing, while the
    re-dispatch side effect (the freed slot re-hosting the task with the
    current server weights) still happens through the normal scatter.
    """
    if weighting == "importance":
        sc = (eta / (stream.n * np.asarray(p, float)))[stream.J]
    elif weighting == "plain":
        sc = np.full(stream.T, eta)
    else:
        raise ValueError(weighting)
    if stream.kind is not None:
        sc = np.where(stream.kind == KIND_COMPLETE, sc, 0.0)
    return sc


def stream_arrays(stream: EventStream):
    """Device copies of the scan inputs (J, slot) for one stream."""
    import jax.numpy as jnp

    return jnp.asarray(stream.J), jnp.asarray(stream.slot)


# ------------------------------------------------------------------ #
# blocked input layout (host-side numpy prep)
# ------------------------------------------------------------------ #
def _blocked_layout(
    blocks: EventBlocks,
    scale: np.ndarray,
    eval_every: int,
    chunk_blocks: int | None = None,
    tail_blocks: int | None = None,
) -> tuple:
    """(J, slot, scale, k, mask) rows + (chunk_blocks, n_chunks) layout.

    With ``eval_every`` the blocks are grouped per eval interval and each
    group is padded with all-masked rows to a common ``chunk_blocks`` width,
    so the chunked outer scan evaluates after exactly `eval_every` events;
    trailing blocks past the last eval point are appended flat.  Padded rows
    are no-ops: mask False, trash slot C, zero scale.
    """
    E = blocks.block_size
    sc_all = blocks.blocked_scales(scale).astype(np.float32)
    if not eval_every:
        n_tail = blocks.B if tail_blocks is None else tail_blocks
        if n_tail < blocks.B:
            raise ValueError("tail_blocks smaller than block count")
        pad = n_tail - blocks.B
        def padded(a, fill):
            return np.concatenate(
                [a, np.full((pad, E), fill, a.dtype)]) if pad else a
        return (
            padded(blocks.J, 0),
            padded(blocks.slot, blocks.C),
            padded(sc_all, 0.0),
            padded(blocks.idx, 0),
            padded(blocks.mask, False),
            0,
            0,
        )
    if blocks.cut_every != eval_every:
        raise ValueError(
            f"blocks were cut every {blocks.cut_every} events; eval_every="
            f"{eval_every} requires segment_blocks(cut_every={eval_every})"
        )
    n_chunks = blocks.T // eval_every
    group = np.minimum(blocks.idx[:, 0] // eval_every, n_chunks)
    counts = np.bincount(group, minlength=n_chunks + 1)
    G = int(counts[:n_chunks].max()) if n_chunks else 0
    if chunk_blocks is not None:
        if chunk_blocks < G:
            raise ValueError("chunk_blocks smaller than densest eval interval")
        G = chunk_blocks
    n_tail = int(counts[n_chunks])
    if tail_blocks is not None:
        if tail_blocks < n_tail:
            raise ValueError("tail_blocks smaller than tail block count")
        n_tail = tail_blocks
    rows = n_chunks * G + n_tail
    J = np.zeros((rows, E), np.int32)
    slot = np.full((rows, E), blocks.C, np.int32)
    sc = np.zeros((rows, E), np.float32)
    kb = np.zeros((rows, E), np.int32)
    mask = np.zeros((rows, E), bool)
    pos = 0
    for g in range(n_chunks + 1):
        cnt = int(counts[g])
        r0 = g * G if g < n_chunks else n_chunks * G
        src = slice(pos, pos + cnt)
        dst = slice(r0, r0 + cnt)
        J[dst], slot[dst], sc[dst] = blocks.J[src], blocks.slot[src], sc_all[src]
        kb[dst], mask[dst] = blocks.idx[src], blocks.mask[src]
        pos += cnt
    return J, slot, sc, kb, mask, G, n_chunks


def blocked_inputs(blocks: EventBlocks, scale: np.ndarray, eval_every: int = 0):
    """Device-ready blocked scan inputs for one stream.

    Returns ``(J, slot, scale, k, mask, chunk_blocks, n_chunks)`` — the
    array arguments plus the two static layout ints of the blocked runner.
    """
    return _blocked_layout(blocks, scale, eval_every)


def blocked_inputs_batch(
    blocks_list: list[EventBlocks],
    scales_list: list[np.ndarray],
    eval_every: int = 0,
):
    """Stacked blocked inputs over scenarios, padded to one common layout.

    The per-scenario greedy cuts produce different block counts; every
    scenario is padded (all-masked no-op rows) to the batch-wide maximum, so
    the result vmaps as one (S, B, E) program with shared static
    ``(chunk_blocks, n_chunks)``.
    """
    layouts = [
        _blocked_layout(b, s, eval_every)
        for b, s in zip(blocks_list, scales_list)
    ]
    if eval_every:
        G = max(l[5] for l in layouts)
        n_chunks = layouts[0][6]
        tails = [l[0].shape[0] - n_chunks * l[5] for l in layouts]
        tail = max(tails)
        layouts = [
            _blocked_layout(b, s, eval_every, chunk_blocks=G, tail_blocks=tail)
            for b, s in zip(blocks_list, scales_list)
        ]
    else:
        B = max(l[0].shape[0] for l in layouts)
        layouts = [
            _blocked_layout(b, s, 0, tail_blocks=B)
            for b, s in zip(blocks_list, scales_list)
        ]
    stacked = tuple(np.stack([l[i] for l in layouts]) for i in range(5))
    return stacked + (layouts[0][5], layouts[0][6])


# ------------------------------------------------------------------ #
# shared pieces: snapshot codec + the algorithm step
# ------------------------------------------------------------------ #
def _snapshot_codec(w0, snapshot_dtype=None, pad_to: int = 1):
    """Flat-packed snapshot storage for any all-float parameter pytree.

    The ring buffer then is ONE (C, P) array — a single gather/scatter
    per step instead of two per leaf, which matters for small models
    where per-op overhead inside the scan dominates.  Uniform-dtype trees
    pack losslessly; mixed *float* trees (e.g. bf16 matmul weights with
    fp32 norms — the real-model presets) pack per-leaf into the common
    promoted dtype (``jnp.result_type`` over the leaves, so bf16+fp32
    packs to an fp32 master vector) and ``unpack`` casts each leaf back
    to its original dtype.  Only trees with non-float leaves fall back to
    per-leaf (C, ...) buffers.

    Returns ``(pack, unpack, enc)``: ``pack`` flattens a pytree to the
    padded compute-dtype vector, ``unpack`` restores the pytree from a
    stored row (casting back to the leaf dtype), and ``enc`` casts a
    compute-dtype vector to the snapshot *storage* dtype — the optional
    ``snapshot_dtype`` codec (e.g. ``"bfloat16"`` ring storage for fp32
    params).  ``pad_to`` rounds the packed length up (once, at init) so the
    fused block kernel's column tiling never re-pads inside the scan.
    """
    import jax
    import jax.numpy as jnp

    leaves, treedef = jax.tree_util.tree_flatten(w0)
    leaf_dtypes = [jnp.asarray(l).dtype for l in leaves]
    dtypes = set(leaf_dtypes)
    if not all(jnp.issubdtype(d, jnp.inexact) for d in dtypes):
        if snapshot_dtype is not None:
            raise ValueError(
                "snapshot_dtype requires all-float parameters "
                "(flat-packed snapshot storage)"
            )
        return None, None, None  # non-float leaves: per-leaf buffers
    compute_dtype = jnp.result_type(*dtypes) if len(dtypes) > 1 else dtypes.pop()
    store_dtype = (
        jnp.dtype(snapshot_dtype) if snapshot_dtype is not None else compute_dtype
    )
    shapes = [jnp.shape(l) for l in leaves]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    offs = np.concatenate([[0], np.cumsum(sizes)]).tolist()
    P = offs[-1]
    P_pad = ((P + pad_to - 1) // pad_to) * pad_to

    def pack(w):
        ls = jax.tree_util.tree_leaves(w)
        flat = jnp.concatenate(
            [jnp.ravel(x).astype(compute_dtype) for x in ls]
        )
        if P_pad != P:
            flat = jnp.pad(flat, (0, P_pad - P))
        return flat

    def unpack(flat):
        ls = [
            flat[offs[i] : offs[i + 1]].reshape(shapes[i]).astype(leaf_dtypes[i])
            for i in range(len(shapes))
        ]
        return jax.tree_util.tree_unflatten(treedef, ls)

    if store_dtype == compute_dtype:
        enc = lambda x: x
    else:
        enc = lambda x: x.astype(store_dtype)
    return pack, unpack, enc


@dataclass(frozen=True)
class GuardConfig:
    """Divergence guard on the server update (graceful degradation).

    Guard order per event, applied after fault-kind masking (a crash /
    timeout / flip already carries scale 0 and is never counted):

      1. staleness cutoff — an update whose task has been in flight for more
         than ``stale_cutoff`` server steps is dropped (scale -> 0) and
         counted in ``stale_drops``;
      2. divergence — a gradient that is non-finite, or whose l2 norm
         exceeds ``max_grad_norm`` (0 disables the norm cap; non-finite
         rejection is always on), is zeroed and counted in
         ``guard_rejects``; the re-dispatch scatter still happens, so the
         event stream's queue dynamics are untouched.

    Guarded runners return the (2,) int32 counter ``[guard_rejects,
    stale_drops]`` alongside the final iterate.  Requires the flat-packed
    snapshot codec (uniform parameter dtype, default linear update).
    """

    max_grad_norm: float = 0.0
    stale_cutoff: int = 0

    @property
    def enabled(self) -> bool:
        return True  # non-finite rejection is unconditional

    def cache_key(self):
        return (float(self.max_grad_norm), int(self.stale_cutoff))


def _make_flat_guard(guard: GuardConfig):
    """``check(g, scale, gcnt, stale) -> (bad, scale, gcnt)`` on one packed
    gradient — the single place the guard semantics (ordering, counting)
    live; every engine path composes it around its update axpy.

    The divergence verdict ``bad`` is returned instead of a pre-zeroed
    gradient so the caller can compute the candidate update concurrently
    with the norm reduction and suppress it afterwards
    (``where(bad, w, w_new)``): zeroing ``g`` or ``scale`` up front would
    serialize the axpy behind the full reduction over ``g``, an extra
    sweep of stall per event.
    """
    import jax.numpy as jnp

    max_sq = float(guard.max_grad_norm) ** 2
    cutoff = int(guard.stale_cutoff)

    def check(g, scale, gcnt, stale=None):
        live = scale != 0
        if cutoff > 0 and stale is not None:
            st = live & (stale > cutoff)
            gcnt = gcnt.at[1].add(st.astype(jnp.int32))
            scale = jnp.where(st, 0.0, scale)
            live = live & ~st
        sq = jnp.sum(jnp.square(g.astype(jnp.float32)))
        bad = ~jnp.isfinite(sq)
        if max_sq > 0.0:
            bad = bad | (sq > max_sq)
        gcnt = gcnt.at[0].add((bad & live).astype(jnp.int32))
        return bad, scale, gcnt

    return check


def _make_apply_event(fedbuff_Z, enc, guard=None):
    """Flat-mode server update for one event, given its (packed) gradient.

    ``apply_event((w, snaps, acc, gcnt), g, s, scale, k[, stale])`` is
    Algorithm 1 lines 10-11 on the packed vector — one axpy, one scatter
    (plus the masked FedBuff buffer flush every Z-th step).  Shared by the
    per-event `update_step` and the device-blocked fixup pass so the update
    semantics exist exactly once.  With ``guard`` the gradient passes the
    divergence/staleness check first and the carry's (2,) reject counter
    accumulates.
    """
    import jax.numpy as jnp

    check = _make_flat_guard(guard) if guard is not None else None

    def apply_event(ucarry, g, s, scale, k, stale=None):
        w, snaps, acc, gcnt = ucarry
        bad = None
        if check is not None:
            bad, scale, gcnt = check(g, scale, gcnt, stale)
        if fedbuff_Z > 0:
            if bad is not None:  # the accumulator consumes g beyond the axpy
                g = jnp.where(bad, jnp.zeros_like(g), g)
            acc = acc + g
            fire = ((k + 1) % fedbuff_Z) == 0
            eff = jnp.where(fire, scale / fedbuff_Z, 0.0)
            w = (w - eff * acc).astype(w.dtype)
            acc = acc * (~fire).astype(acc.dtype)
        else:
            w_new = (w - scale * g).astype(w.dtype)
            # candidate axpy and norm reduction run concurrently; rejection
            # is a select on the result, not a stall before it
            w = jnp.where(bad, w, w_new) if bad is not None else w_new
        snaps = snaps.at[s].set(enc(w))
        return (w, snaps, acc, gcnt)

    return apply_event


def _make_update_step(
    grad_fn, fedbuff_Z, update_fn, pack, unpack, flat_mode, enc, guard=None
):
    """The algorithm half of a CS step, independent of the event source.

    ``update_step(ucarry, j, s, scale, k[, stale]) -> ucarry`` consumes one
    event (completing client j, ring slot s, update scale, server step k)
    exactly as Algorithm 1 lines 9-11 — both the host-replay scan body and
    the fused device-stream body compose it with their event producer.
    ``stale`` (server steps the task spent in flight) feeds the optional
    staleness cutoff of ``guard``.
    """
    import jax
    import jax.numpy as jnp

    if guard is not None and not flat_mode:
        raise ValueError(
            "the divergence guard requires the flat-packed snapshot codec "
            "(uniform-dtype parameters, default linear update)"
        )
    tree_map = jax.tree_util.tree_map
    apply_event = _make_apply_event(fedbuff_Z, enc, guard) if flat_mode else None

    def update_step(ucarry, j, s, scale, k, stale=None):
        w, snaps, acc, gcnt = ucarry  # w (and acc) are flat in flat_mode
        # gather the completing task's dispatch-time snapshot (Alg. 1 line 9)
        if unpack is None:
            w_disp = tree_map(lambda b: b[s], snaps)
        else:
            w_disp = unpack(snaps[s])
        g = grad_fn(j, w_disp, k)
        if flat_mode:
            return apply_event(ucarry, pack(g), s, scale, k, stale)
        if fedbuff_Z > 0:
            acc = tree_map(lambda a, y: a + y, acc, g)
            fire = ((k + 1) % fedbuff_Z) == 0
            eff = jnp.where(fire, scale / fedbuff_Z, 0.0)
            w = update_fn(w, acc, eff)
            acc = tree_map(lambda a: a * (~fire).astype(a.dtype), acc)
        else:
            w = update_fn(w, g, scale)
        # the freed slot hosts the new dispatch with the updated params
        if unpack is None:
            snaps = tree_map(lambda b, x: b.at[s].set(x), snaps, w)
        else:
            snaps = snaps.at[s].set(enc(pack(w)))
        return (w, snaps, acc, gcnt)

    return update_step


def _make_batched_grads(grad_fn, pack, unpack):
    """Batched gradient call over one micro-block: (E,) client ids, (E, P)
    stored snapshot rows, (E,) server steps -> (E, P) packed gradients.
    Shared by the host block step and the fused device window."""
    import jax

    return jax.vmap(
        lambda j, wi, k: pack(grad_fn(j, unpack(wi), k))
    )


def _fedbuff_block_deltas(Gm, scm, k, m, acc, Z):
    """Closed-form FedBuff per-event deltas over one (full) micro-block.

    Gradient g_j is applied exactly once — at the first buffer flush at or
    after its arrival — so D_i = 1{flush at i} * (scale_i/Z) * (carried
    buffer + gradients since the previous flush), computed from the in-block
    flush positions.  Returns ``(D, acc')``: the (E, P) scaled update deltas
    (prefix-summable like the gen_async path) and the buffer carried out of
    the block.
    """
    import jax
    import jax.numpy as jnp

    cum = jnp.cumsum(Gm, axis=0)
    fire = m & (((k + 1) % Z) == 0)
    E = m.shape[0]
    fi = jnp.where(fire, jnp.arange(E, dtype=jnp.int32), -1)
    last_incl = jax.lax.cummax(fi)  # last flush at or before i
    prev = jnp.concatenate([jnp.full((1,), -1, jnp.int32), last_incl[:-1]])
    prevcum = jnp.where((prev >= 0)[:, None], cum[jnp.maximum(prev, 0)], 0.0)
    first = jnp.where(prev < 0, 1.0, 0.0)[:, None]
    acc_at = cum - prevcum + first * acc.astype(jnp.float32)
    D = jnp.where(fire, scm / Z, 0.0)[:, None] * acc_at
    lastf = last_incl[-1]
    flushed = jnp.where(lastf >= 0, cum[jnp.maximum(lastf, 0)], 0.0)
    acc = (
        jnp.where(lastf >= 0, 0.0, 1.0) * acc.astype(jnp.float32)
        + (cum[-1] - flushed)
    ).astype(acc.dtype)
    return D, acc


def _make_block_step(
    grad_fn, fedbuff_Z, pack, unpack, kernel, interpret, lane_axis=None,
    guard=None,
):
    """One event micro-block of the blocked engine (flat-packed mode only).

    ``block_step(ucarry, j, s, scale, k, mask) -> ucarry`` consumes up to E
    conflict-free events: one batched snapshot gather, one vmapped gradient
    call, then the exact sequential iterates via a prefix sum over the
    per-event update deltas D_i (w_i = w_0 - sum_{j<=i} D_j), scattered back
    in one pass.  Padded lanes (mask False) carry zero scale and the trash
    ring row, so they are arithmetic no-ops.

    FedBuff decomposes into the same prefix form via
    `_fedbuff_block_deltas`.

    With ``lane_axis`` set, the step is the per-device body of a `shard_map`
    whose E lanes are partitioned over that mesh axis: each device gathers
    the snapshots of — and differentiates — only its E/D lanes, the local
    per-lane prefix sums are combined with ONE cross-device ``all_gather``
    (lane prefixes and slot ids ride in the same collective; the exclusive
    per-device offsets fall out of the gathered lane totals), and the
    scatter into the replicated ring buffer is applied identically on every
    device (`kernels.ref.block_scatter_rows_ref` / the Pallas
    `kernels.weighted_update.block_scatter_rows`).  FedBuff gathers the
    masked lane *gradients* instead — its flush positions couple all lanes —
    then applies the same closed-form decomposition; either way the
    gradient FLOPs, the gather bandwidth and the pack/unpack work are all
    divided by the lane-device count.
    """
    import jax
    import jax.numpy as jnp

    if kernel == "pallas":
        # the ops wrappers consult the cached autotune table (backend, P, E)
        # for the column tile; a miss is the plain full-BLOCK_TILE kernel
        from ..kernels.ops import block_prefix_update, block_scatter_rows

        apply_block = partial(block_prefix_update, interpret=interpret)
        scatter_rows = partial(block_scatter_rows, interpret=interpret)
    elif kernel == "jnp":
        from ..kernels.ref import block_prefix_update_ref, block_scatter_rows_ref

        apply_block = block_prefix_update_ref
        scatter_rows = block_scatter_rows_ref
    else:
        raise ValueError(kernel)

    grads = _make_batched_grads(grad_fn, pack, unpack)
    max_sq = float(guard.max_grad_norm) ** 2 if guard is not None else 0.0

    def guard_rows(G, scm, gcnt):
        # row-wise divergence check over the (local) lane batch; the zeroed
        # rows flow through the prefix sum as exact no-ops.  Staleness is a
        # host-side concern on the blocked replay path (the event arrays are
        # pre-simulated, so stale scales arrive already zeroed).
        sq = jnp.sum(jnp.square(G.astype(jnp.float32)), axis=1)
        bad = ~jnp.isfinite(sq)
        if max_sq > 0.0:
            bad = bad | (sq > max_sq)
        cnt = jnp.sum((bad & (scm != 0)).astype(jnp.int32))
        if lane_axis is not None:
            cnt = jax.lax.psum(cnt, lane_axis)
        gcnt = gcnt.at[0].add(cnt)
        return jnp.where(bad[:, None], jnp.zeros_like(G), G), gcnt

    def block_step(ucarry, j, s, sc, k, m):
        w, snaps, acc, gcnt = ucarry
        G = grads(j, snaps[s], k)  # (E_local, P) batched over (local) lanes
        scm = jnp.where(m, sc, 0.0).astype(jnp.float32)
        if guard is not None:
            G, gcnt = guard_rows(G, scm, gcnt)
        if lane_axis is None:
            if fedbuff_Z > 0:
                Gm = jnp.where(m[:, None], G, 0).astype(jnp.float32)
                D, acc = _fedbuff_block_deltas(Gm, scm, k, m, acc, fedbuff_Z)
            else:
                D = scm[:, None] * G.astype(jnp.float32)
            snaps, w = apply_block(snaps, w, D, s)
            return (w, snaps, acc, gcnt)
        if fedbuff_Z > 0:
            # flush positions couple all lanes: gather the masked lane
            # gradients (+ metadata) in one collective, then run the same
            # closed form on the full block, replicated
            Gm = jnp.where(m[:, None], G, 0).astype(jnp.float32)
            Gm, s_all, scm_all, k_all, m_all = jax.lax.all_gather(
                (Gm, s, scm, k, m), lane_axis, tiled=True
            )
            D, acc = _fedbuff_block_deltas(
                Gm, scm_all, k_all, m_all, acc, fedbuff_Z
            )
            snaps, w = apply_block(snaps, w, D, s_all)
            return (w, snaps, acc, gcnt)
        # gen_async: local lane prefix + one collective, then the global
        # iterates W_i = w - (S_all + exclusive device offset), replicated
        Dl = scm[:, None] * G.astype(jnp.float32)
        S = jnp.cumsum(Dl, axis=0)  # local inclusive lane prefix
        S_all, s_all = jax.lax.all_gather((S, s), lane_axis)  # (D, El, P/[El])
        totals = S_all[:, -1, :]
        off = jnp.cumsum(totals, axis=0) - totals  # exclusive device offsets
        E = s_all.size
        W = w.astype(jnp.float32)[None] - (S_all + off[:, None, :]).reshape(
            E, -1
        )
        snaps, w = scatter_rows(snaps, w, W, s_all.reshape(E))
        return (w, snaps, acc, gcnt)

    return block_step


def _init_update_carry(w0, rows, pack, unpack, flat_mode, fedbuff_Z, enc):
    """(w, snaps, acc, gcnt) initial carry + the carry->pytree decoder.

    ``rows`` is the snapshot ring height — C for the per-event engine, C+1
    for the blocked engine (the extra trash row absorbs padded scatters).
    ``gcnt`` is the (2,) int32 ``[guard_rejects, stale_drops]`` counter —
    carried unconditionally (two scalars) so the carry structure does not
    depend on whether a guard is active.
    """
    import jax
    import jax.numpy as jnp

    tree_map = jax.tree_util.tree_map
    if unpack is None:
        snaps0 = tree_map(
            lambda x: jnp.broadcast_to(x[None], (rows,) + jnp.shape(x)), w0
        )
        w_init = w0
    else:
        flat0 = pack(w0)
        stored0 = enc(flat0)
        snaps0 = jnp.broadcast_to(stored0[None], (rows, stored0.shape[0]))
        w_init = flat0 if flat_mode else w0
    acc0 = tree_map(jnp.zeros_like, w_init) if fedbuff_Z > 0 else ()
    to_tree = (lambda w: unpack(w)) if flat_mode else (lambda w: w)
    return (w_init, snaps0, acc0, jnp.zeros((2,), jnp.int32)), to_tree


def _default_update(update_fn):
    """Resolve update_fn; the default casts back per leaf so the scan carry
    dtype stays stable (bf16 params with an fp32 scale would otherwise
    promote)."""
    import jax

    if update_fn is not None:
        return update_fn, False
    tree_map = jax.tree_util.tree_map
    return (
        lambda w, g, s: tree_map(lambda x, y: (x - s * y).astype(x.dtype), w, g),
        True,
    )


# ------------------------------------------------------------------ #
# host stream: replay a pre-simulated EventStream
# ------------------------------------------------------------------ #
def _make_host_runner(
    grad_fn: Callable[[Any, Pytree, Any], Pytree],
    C: int,
    *,
    fedbuff_Z: int = 0,
    eval_fn: Callable[[Pytree], Any] | None = None,
    eval_every: int = 0,
    update_fn: Callable[[Pytree, Pytree, Any], Pytree] | None = None,
    unroll: int = 1,
    snapshot_dtype=None,
    guard: GuardConfig | None = None,
):
    """Build the replay engine for a fixed algorithm shape.

    Returns ``run(w0, J, slot, scale, eval_every=...) -> (w_final, evals)``
    — with ``guard`` set, ``(w_final, evals, gcnt)`` where ``gcnt`` is the
    (2,) ``[guard_rejects, stale_drops]`` counter (staleness on the replay
    path is enforced host-side by zeroing scales before the call, so only
    the divergence slot accumulates here) —
    a pure function: `jax.jit` it directly (``eval_every`` is a Python
    int, pass it via ``static_argnames``), or `jax.vmap(run, in_axes=(None,
    0, 0, 0))` to execute a whole scenario matrix in one compiled call.
    ``evals`` is the eval_fn curve sampled every `eval_every` steps (empty
    array when evaluation is off).  The factory-level ``eval_every`` only
    sets the run-time default, so one runner serves any eval cadence.

    grad_fn(j, w, k): traceable stochastic gradient of client j at params w,
    server step k.  update_fn(w, g, scale) defaults to w - scale*g.
    """
    import jax
    import jax.numpy as jnp

    update_fn, default_update = _default_update(update_fn)
    eval_every_default = eval_every

    def run(w0, J, slot, scale, eval_every=eval_every_default):
        pack, unpack, enc = _snapshot_codec(w0, snapshot_dtype)
        flat_mode = default_update and unpack is not None
        update_step = _make_update_step(
            grad_fn, fedbuff_Z, update_fn, pack, unpack, flat_mode, enc, guard
        )

        def body(carry, xs):
            j, s, sc, k = xs
            return update_step(carry, j, s, sc, k), ()

        def scan(carry, Jc, slotc, scalec, k0):
            ks = k0 + jnp.arange(Jc.shape[0], dtype=Jc.dtype)
            return jax.lax.scan(body, carry, (Jc, slotc, scalec, ks), unroll=unroll)[0]

        carry, to_tree = _init_update_carry(
            w0, C, pack, unpack, flat_mode, fedbuff_Z, enc
        )
        T = int(J.shape[0])
        if eval_fn is not None and eval_every and T >= eval_every:
            n_chunks = T // eval_every
            Tc = n_chunks * eval_every

            # per-chunk absolute step offsets ride along as a scan input
            def chunk_body(c, xs):
                Jc, sc, scc, k0 = xs
                c = scan(c, Jc, sc, scc, k0)
                return c, eval_fn(to_tree(c[0]))

            xs = (
                J[:Tc].reshape(n_chunks, eval_every),
                slot[:Tc].reshape(n_chunks, eval_every),
                scale[:Tc].reshape(n_chunks, eval_every),
                jnp.arange(n_chunks, dtype=J.dtype) * eval_every,
            )
            carry, evals = jax.lax.scan(chunk_body, carry, xs)
            if Tc < T:  # tail events past the last eval point
                carry = scan(carry, J[Tc:], slot[Tc:], scale[Tc:], k0=Tc)
            if guard is not None:
                return to_tree(carry[0]), evals, carry[3]
            return to_tree(carry[0]), evals
        carry = scan(carry, J, slot, scale, k0=0)
        if guard is not None:
            return to_tree(carry[0]), jnp.zeros((0,)), carry[3]
        return to_tree(carry[0]), jnp.zeros((0,))

    return run


def _check_lane_devices(lane_devices: int, block_size: int) -> None:
    """Validate the lane-shard request against the block shape and platform."""
    import jax

    if lane_devices < 1:
        raise ValueError("lane_devices >= 1 required")
    if lane_devices == 1:
        return
    if block_size < 2:
        raise ValueError(
            "lane_devices > 1 shards the E-lane micro-block gradient batch "
            "across devices and requires block_size > 1"
        )
    if block_size % lane_devices:
        raise ValueError(
            f"block_size={block_size} must be a multiple of "
            f"lane_devices={lane_devices} (each device owns E/D lanes)"
        )
    avail = jax.device_count()
    if lane_devices > avail:
        raise ValueError(
            f"lane_devices={lane_devices} but only {avail} device(s) are "
            "visible (on CPU, set XLA_FLAGS="
            "--xla_force_host_platform_device_count=N)"
        )


def _lane_mesh(lane_devices: int):
    """1-D ("lanes",) mesh over the first lane_devices visible devices."""
    import jax
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()[:lane_devices]), ("lanes",))


def _make_host_block_runner(
    grad_fn: Callable[[Any, Pytree, Any], Pytree],
    C: int,
    block_size: int,
    *,
    fedbuff_Z: int = 0,
    eval_fn: Callable[[Pytree], Any] | None = None,
    update_fn: Callable[[Pytree, Pytree, Any], Pytree] | None = None,
    unroll: int = 1,
    kernel: str = "jnp",
    snapshot_dtype=None,
    interpret: bool = True,
    lane_devices: int = 1,
    vmap_streams: bool = False,
    guard: GuardConfig | None = None,
):
    """Build the blocked replay engine over `queue_sim.EventBlocks` arrays.

    Returns ``run(w0, J, slot, scale, k, mask, chunk_blocks=0, n_chunks=0)
    -> (w_final, evals)`` consuming (B, E) blocked arrays (see
    `blocked_inputs`).  ``chunk_blocks``/``n_chunks`` are the static eval
    layout: the first ``n_chunks * chunk_blocks`` rows are eval-interval
    groups (eval fires after each group — the conflict-free cut guarantees
    group boundaries land on exact event multiples), trailing rows replay
    flat.

    The blocked engine requires the flat-packed snapshot codec (uniform
    parameter dtype) and the default linear update; ``kernel`` picks the
    jnp fallback ("jnp", the CPU/parity path) or the fused Pallas kernel
    ("pallas") for the prefix-scan + scatter.

    ``lane_devices=D > 1`` shards the E gradient lanes of every micro-block
    across D devices via `shard_map`: the (B, E) event arrays are
    partitioned over their lane axis, each device gathers/differentiates
    its E/D lanes, and one all-gather per block recombines the lane
    prefixes (see `_make_block_step`).  The scan structure, eval chunking
    and results are identical to the unsharded runner (≤1e-5 in fp32 —
    fp32 lane prefixes are re-associated per device).  ``vmap_streams``
    additionally maps the per-device body over a leading scenario axis —
    the scenario × lane 2-D layout `fl.run_matrix` uses.
    """
    import jax
    import jax.numpy as jnp

    if update_fn is not None:
        raise ValueError(
            "block_size > 1 requires the default update w - scale*g "
            "(the blocked replay reconstructs iterates via a prefix sum)"
        )
    if block_size < 2:
        raise ValueError("use _make_host_runner for block_size <= 1")
    _check_lane_devices(lane_devices, block_size)
    lane_axis = "lanes" if lane_devices > 1 else None
    pad_to = 1
    if kernel == "pallas":
        from ..kernels.weighted_update import BLOCK_TILE

        pad_to = BLOCK_TILE

    def run_local(w0, J, slot, scale, k, mask, chunk_blocks=0, n_chunks=0):
        pack, unpack, enc = _snapshot_codec(w0, snapshot_dtype, pad_to=pad_to)
        if unpack is None:
            raise ValueError(
                "block_size > 1 requires all-float parameters "
                "(flat-packed snapshot storage)"
            )
        block_step = _make_block_step(
            grad_fn, fedbuff_Z, pack, unpack, kernel, interpret, lane_axis,
            guard,
        )
        carry, to_tree = _init_update_carry(
            w0, C + 1, pack, unpack, True, fedbuff_Z, enc
        )

        def body(c, xs):
            return block_step(c, *xs), ()

        def scan(c, *arrs):
            return jax.lax.scan(body, c, arrs, unroll=unroll)[0]

        B = int(J.shape[0])
        Bm = n_chunks * chunk_blocks
        if eval_fn is not None and n_chunks and chunk_blocks:
            resh = lambda a: a[:Bm].reshape(
                (n_chunks, chunk_blocks) + a.shape[1:]
            )

            def chunk_body(c, xs):
                c = scan(c, *xs)
                return c, eval_fn(to_tree(c[0]))

            carry, evals = jax.lax.scan(
                chunk_body, carry, tuple(resh(a) for a in (J, slot, scale, k, mask))
            )
            if Bm < B:  # tail blocks past the last eval point
                carry = scan(
                    carry, J[Bm:], slot[Bm:], scale[Bm:], k[Bm:], mask[Bm:]
                )
            if guard is not None:
                return to_tree(carry[0]), evals, carry[3]
            return to_tree(carry[0]), evals
        carry = scan(carry, J, slot, scale, k, mask)
        if guard is not None:
            return to_tree(carry[0]), jnp.zeros((0,)), carry[3]
        return to_tree(carry[0]), jnp.zeros((0,))

    if lane_devices == 1:
        if not vmap_streams:
            return run_local

        def run_batched(w0, J, slot, scale, k, mask, chunk_blocks=0,
                        n_chunks=0):
            return jax.vmap(
                lambda w, a, b, c, d, e: run_local(
                    w, a, b, c, d, e, chunk_blocks, n_chunks
                ),
                in_axes=(None, 0, 0, 0, 0, 0),
            )(w0, J, slot, scale, k, mask)

        return run_batched

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = _lane_mesh(lane_devices)
    # blocked arrays are (B, E) — or (S, B, E) under vmap_streams — and are
    # partitioned over their trailing lane axis; w0 and outputs replicate
    lane_spec = (
        P(None, None, "lanes") if vmap_streams else P(None, "lanes")
    )

    def run(w0, J, slot, scale, k, mask, chunk_blocks=0, n_chunks=0):
        if vmap_streams:
            base = jax.vmap(
                lambda w, a, b, c, d, e: run_local(
                    w, a, b, c, d, e, chunk_blocks, n_chunks
                ),
                in_axes=(None, 0, 0, 0, 0, 0),
            )
        else:
            base = lambda w, a, b, c, d, e: run_local(
                w, a, b, c, d, e, chunk_blocks, n_chunks
            )
        f = shard_map(
            base,
            mesh=mesh,
            in_specs=(P(),) + (lane_spec,) * 5,
            out_specs=(P(), P(), P()) if guard is not None else (P(), P()),
            check_rep=False,
        )
        return f(w0, J, slot, scale, k, mask)

    return run


# ------------------------------------------------------------------ #
# device stream: fused generator + control loop
# ------------------------------------------------------------------ #
def _make_fused_advance(
    grad_fn, n, C, E, update_step, pack, unpack, enc, fedbuff_Z, guard, *,
    importance, faulty, guard_stale, need_stats, axis, lane_devices, unroll,
    classes=None, serving=None, scenario=False,
):
    """The chunk-advance core of the fused engine, shared with `engine_ckpt`.

    ``build(mu, eta, fr)`` closes over the traced scalars and returns
    ``advance(ucarry, sstate, stats, slot_scale, p, ur, ue, Kc, k0[, ub])
    -> (ucarry, sstate, stats, slot_scale, ts)`` — fused CS steps over one
    chunk of pre-drawn uniforms: E-event windows plus a per-event remainder.
    Factoring it out of `make_fused_runner` keeps exactly one copy of the
    event semantics for the monolithic runner and the checkpointed
    chunk-at-a-time driver (`core.engine_ckpt`).

    ``classes`` (a `stream_device.ClassSpec`) switches the event source to
    the sparse O(C) stream: ``mu``/``p`` become (m,) class-level values,
    stats accumulate per class, and per-event cost is flat in n.  The
    algorithm side is untouched — events still carry global client ids and
    slot indices, so the snapshot ring, guards and update math are shared.
    Sparse is per-event only (E == 1); ``ub`` is the extra per-event
    uniform resolving idle-pool availability bits under faults.
    """
    import jax
    import jax.numpy as jnp

    from . import stream_device as sd

    sparse = classes is not None
    if sparse and E > 1:
        raise ValueError("the sparse stream supports block_size=1 only")
    scen_on = bool(scenario)
    if scen_on:
        # scenario streams reuse the fault-mode masking idiom (stage/flip
        # events carry slot C and scale 0); `fr` carries the ScenarioRates
        if faulty:
            raise ValueError("scenario= and fault= are separate injection "
                             "paths; compose via ScenarioConfig modulation")
        if sparse:
            raise ValueError(
                "the fused engine's scenario path is dense-only; use "
                "sparse_stats_stream_fn for class-level scenario laws"
            )
        if E > 1:
            raise ValueError("scenario= requires block_size=1")
    spec = classes.device() if sparse else None
    m_cls = classes.m if sparse else 0
    serving_on = serving is not None and serving.enabled
    if serving_on:
        from . import serving as sp

        if sparse:
            raise ValueError("serving= requires the dense stream (classes=None)")
        if E > 1:
            raise ValueError("serving= requires block_size=1")
        if fedbuff_Z:
            raise ValueError("serving= composes with Algorithm 1, not FedBuff")
        serving.validate()

    def build(mu, eta, fr):
        def event_body(c, x):
            """One fused CS step (stream advance + algorithm update).

            With ``serving`` the step first races the closed network
            against the open serving stream (`merged_stream_step`), then
            runs BOTH halves unconditionally in the masked style: a serve
            event carries ``j = n``, ``slot = C`` and a zeroed scale, so
            the gradient it computes is discarded by the masked axpy and
            every scatter drops — exactly the fault-masking idiom
            (`KIND_CRASH` etc.) — while on train events `serve_apply`
            masks to a no-op via ``live=is_ext``.  No `lax.cond`: routing
            the (C, P) snapshot ring through a cond output forces XLA to
            re-materialize the ring every step instead of aliasing it
            through the scan carry (measured ~1.6x wall), and even a
            small-state cond pays per-step buffer marshaling that exceeds
            the masked ops it skips.  The known-good snapshot pointer
            advances only on *accepted* training updates (nonzero scale
            and no guard counter movement), so the serving read path can
            never observe a guard-rejected iterate.
            """
            if serving_on:
                ucarry, sstate, stats, slot_scale, p, sv, sstats = c
            else:
                ucarry, sstate, stats, slot_scale, p = c
            if serving_on:
                urk, uek, kn, k = x
                occ_pre = sstate.occ
                if faulty:
                    avail_pre = sstate.avail
                r_ext = sv.cdf[-1]  # rate cache refreshed in serve_apply
                sstate, ev, is_ext, u_ext = sd.merged_stream_step(
                    sstate, mu, r_ext, (urk, uek, kn), fr
                )
            elif sparse:
                if faulty:
                    urk, uek, kn, ubk, k = x
                else:
                    urk, uek, kn, k = x
                if need_stats:
                    occ_pre, busy_pre, avail_pre = sd.sparse_class_stats(
                        sstate, m_cls, fault=faulty
                    )
                if faulty:
                    sstate, ev = sd.sparse_fault_stream_step(
                        sstate, mu, spec, fr, (urk, uek, kn, ubk)
                    )
                else:
                    sstate, ev = sd.sparse_stream_step(
                        sstate, mu, spec, (urk, uek, kn)
                    )
            elif scen_on:
                urk, uek, kn, uphk, k = x
                occ_pre = sstate.occ
                avail_pre = sstate.avail
                speed_pre = avail_pre + (1.0 - avail_pre) * fr.rate_scale
                sstate, ev = sd.scenario_stream_step(
                    sstate, mu, fr, (urk, uek, kn, uphk)
                )
            else:
                urk, uek, kn, k = x
                occ_pre = sstate.occ
                if faulty:
                    avail_pre = sstate.avail
                    sstate, ev = sd.fault_stream_step(
                        sstate, mu, fr, (urk, uek, kn)
                    )
                else:
                    sstate, ev = sd.stream_step(sstate, mu, (urk, uek, kn))
            # flips carry slot C: the (C,) gather clamps but the scale is
            # masked to 0, and every scatter below drops out of bounds
            scale = slot_scale[ev.slot] if importance else eta
            if faulty or serving_on or scen_on:
                scale = jnp.where(ev.kind == KIND_COMPLETE, scale, 0.0)
            stale = (k - stats.slot_step[ev.slot]) if guard_stale else None
            if serving_on:
                # pre-event depth persists over dt: integrate it before
                # the transition, on every (train or serve) event
                sstats = sp.serve_time_step(sstats, sv, ev.dt)
                gcnt_pre = ucarry[3]
                ucarry = update_step(ucarry, ev.j, ev.slot, scale, k, stale)
                # unguarded runs never move gcnt: skip the per-step compare
                accepted = scale != 0.0
                if guard is not None:
                    accepted &= jnp.all(ucarry[3] == gcnt_pre)
                sv = sv._replace(
                    kg_slot=jnp.where(accepted, ev.slot, sv.kg_slot),
                    kg_step=jnp.where(accepted, jnp.int32(k) + 1, sv.kg_step),
                )
                # unconditional masked call (live=is_ext): a lax.cond
                # here would marshal the ~25 serve-state buffers through
                # the conditional every step, which costs more than the
                # masked small ops themselves
                sv, sstats = sp.serve_apply(
                    serving, sv, sstats, u_ext, ev.t, k, ucarry[1],
                    live=is_ext,
                )
            else:
                ucarry = update_step(ucarry, ev.j, ev.slot, scale, k, stale)
            if need_stats:
                if sparse:
                    cls_j = spec.inv_cls[ev.j]
                    occ_post = sd.class_occupancy(sstate.cls, m_cls)
                    if faulty:
                        stats = sd.sparse_fault_stats_step(
                            stats, ev, cls_j, occ_pre, busy_pre, avail_pre,
                            occ_post, k,
                        )
                    else:
                        stats = sd.sparse_stats_step(
                            stats, ev, cls_j, occ_pre, busy_pre, occ_post, k
                        )
                elif scen_on:
                    stats = sd.scenario_stats_step(
                        stats, ev, occ_pre, avail_pre, speed_pre,
                        sstate.occ, k,
                    )
                elif faulty:
                    stats = sd.fault_stats_step(
                        stats, ev, occ_pre, avail_pre, sstate.occ, k
                    )
                else:
                    stats = sd.stats_step(stats, ev, occ_pre, sstate.occ, k)
            if importance:
                pk = p[spec.inv_cls[ev.k]] if sparse else p[ev.k]
                if serving_on:
                    # serve events carry slot C: no re-dispatch, the
                    # importance-scale scatter must drop, not overwrite
                    slot_scale = slot_scale.at[ev.slot].set(
                        eta / (n * pk), mode="drop"
                    )
                else:
                    slot_scale = slot_scale.at[ev.slot].set(eta / (n * pk))
            if serving_on:
                return (ucarry, sstate, stats, slot_scale, p, sv, sstats), ev.t
            return (ucarry, sstate, stats, slot_scale, p), ev.t

        def window_body(c, x):
            """One E-event micro-block of fused CS steps.

            Phase 1 advances the closed network E steps (cheap integer /
            scalar ops, one inner scan); phase 2 batch-gathers the E
            window-entry snapshots and computes all gradients in one vmapped
            call; phase 3 replays the exact sequential updates, recomputing
            a gradient only when its task was dispatched *inside* this
            window (``conf >= 0`` — its snapshot was written after the batch
            gather).
            """
            ucarry, sstate, stats, slot_scale, p = c
            urw, uew, knw, kw = x

            def sbody(cc, xx):
                sstate, stats, slot_scale, lastw, i = cc
                urk, uek, kn, k = xx
                occ_pre = sstate.occ
                if faulty:
                    avail_pre = sstate.avail
                    sstate, ev = sd.fault_stream_step(
                        sstate, mu, fr, (urk, uek, kn)
                    )
                else:
                    sstate, ev = sd.stream_step(sstate, mu, (urk, uek, kn))
                sc = slot_scale[ev.slot] if importance else eta
                if faulty:
                    sc = jnp.where(ev.kind == KIND_COMPLETE, sc, 0.0)
                conf = lastw[ev.slot]
                lastw = lastw.at[ev.slot].set(i)
                dl = (k - stats.slot_step[ev.slot]) if guard_stale else None
                if need_stats:
                    if faulty:
                        stats = sd.fault_stats_step(
                            stats, ev, occ_pre, avail_pre, sstate.occ, k
                        )
                    else:
                        stats = sd.stats_step(stats, ev, occ_pre, sstate.occ, k)
                if importance:
                    slot_scale = slot_scale.at[ev.slot].set(eta / (n * p[ev.k]))
                ys = (ev.j, ev.slot, sc, conf, ev.t)
                if guard_stale:
                    ys = ys + (dl,)
                return (sstate, stats, slot_scale, lastw, i + 1), ys

            lastw0 = jnp.full((C,), -1, jnp.int32)
            (sstate, stats, slot_scale, _, _), sys_ = jax.lax.scan(
                sbody,
                (sstate, stats, slot_scale, lastw0, jnp.int32(0)),
                (urw, uew, knw, kw),
            )
            if guard_stale:
                jv, sv, scv, confv, tv, dlv = sys_
            else:
                jv, sv, scv, confv, tv = sys_
            snaps = ucarry[1]
            batched_grads = _make_batched_grads(grad_fn, pack, unpack)
            if axis is None:
                G0 = batched_grads(jv, snaps[sv], kw)
            else:
                # lane-sharded: this device differentiates E/D of the
                # window's lanes; one all-gather recombines the batch
                El = E // lane_devices
                d = jax.lax.axis_index(axis)
                sl = lambda a: jax.lax.dynamic_slice_in_dim(a, d * El, El, 0)
                jl, svl, kl = sl(jv), sl(sv), sl(kw)
                Gl = batched_grads(jl, snaps[svl], kl)
                G0 = jax.lax.all_gather(Gl, axis, tiled=True)

            apply_event = _make_apply_event(fedbuff_Z, enc, guard)

            def fbody(cc, xx):
                if guard_stale:
                    j, s, sc, conf, g0, k, dl = xx
                else:
                    j, s, sc, conf, g0, k = xx
                    dl = None
                row = cc[1][s]
                g = jax.lax.cond(
                    conf >= 0,
                    lambda r: pack(grad_fn(j, unpack(r), k)),
                    lambda r: g0,
                    row,
                )
                return apply_event(cc, g, s, sc, k, dl), ()

            xs_f = (jv, sv, scv, confv, G0, kw)
            if guard_stale:
                xs_f = xs_f + (dlv,)
            ucarry, _ = jax.lax.scan(fbody, ucarry, xs_f)
            return (ucarry, sstate, stats, slot_scale, p), tv

        def advance(ucarry, sstate, stats, slot_scale, p, ur, ue, Kc, k0,
                    ub=None, sv=None, sstats=None, uph=None):
            """Fused CS steps over one chunk: E-event windows + remainder.

            With serving the carry widens by ``(sv, sstats)`` — the serve
            table + counters ride the scan (and the checkpoint) like any
            other state — and the return gains the updated pair.
            """
            if serving_on:
                c = (ucarry, sstate, stats, slot_scale, p, sv, sstats)
            else:
                c = (ucarry, sstate, stats, slot_scale, p)
            Lc = Kc.shape[0]
            ks = k0 + jnp.arange(Lc, dtype=jnp.int32)
            nW = Lc // E if E > 1 else 0
            Wc = nW * E
            ts_parts = []
            if nW:
                resh = lambda a: a[:Wc].reshape(nW, E)
                c, tsw = jax.lax.scan(
                    window_body, c, (resh(ur), resh(ue), resh(Kc), resh(ks)),
                    unroll=unroll,
                )
                ts_parts.append(tsw.reshape(Wc))
            if Wc < Lc:
                if sparse and faulty:
                    xse = (ur[Wc:], ue[Wc:], Kc[Wc:], ub[Wc:], ks[Wc:])
                elif scen_on:
                    xse = (ur[Wc:], ue[Wc:], Kc[Wc:], uph[Wc:], ks[Wc:])
                else:
                    xse = (ur[Wc:], ue[Wc:], Kc[Wc:], ks[Wc:])
                c, tse = jax.lax.scan(event_body, c, xse, unroll=unroll)
                ts_parts.append(tse)
            ts = ts_parts[0] if len(ts_parts) == 1 else jnp.concatenate(ts_parts)
            if serving_on:
                ucarry, sstate, stats, slot_scale, p, sv, sstats = c
                return ucarry, sstate, stats, slot_scale, ts, sv, sstats
            ucarry, sstate, stats, slot_scale, p = c
            return ucarry, sstate, stats, slot_scale, ts

        return advance

    return build


def make_fused_runner(
    grad_fn: Callable[[Any, Pytree, Any], Pytree],
    n: int,
    C: int,
    T: int,
    *,
    weighting: str = "importance",
    fedbuff_Z: int = 0,
    eval_fn: Callable[[Pytree], Any] | None = None,
    eval_every: int = 0,
    adaptive: bool = False,
    refresh_every: int = 0,
    bound: BoundConstants | None = None,
    ctrl_lr: float = 0.3,
    ctrl_iters: int = 4,
    update_fn: Callable[[Pytree, Pytree, Any], Pytree] | None = None,
    init: str = "distinct",
    unroll: int = 1,
    block_size: int = 1,
    collect_extras: bool = True,
    snapshot_dtype=None,
    lane_devices: int = 1,
    lane_axis: str | None = None,
    fault: FaultConfig | None = None,
    guard: GuardConfig | None = None,
    classes=None,
    serving=None,
    scenario=None,
):
    """Build the fused engine: `stream_device.stream_step` ∘ `update_step`.

    Returns ``run(w0, mu, p0, key, eta) -> (w_final, evals, extras)``.  The
    closed network advances inside the scan (exponential service only), so
    nothing is pre-simulated on the host; `jax.vmap(run, in_axes=(None, 0,
    0, 0, None))` executes a scenario matrix in one compiled call.

    With ``adaptive=True`` the sampling vector is re-optimized from the
    running occupancy/rate estimates every `refresh_every` steps
    (`stream_device.ctrl_refresh`); each in-flight task keeps the
    importance scale of its dispatch-time p, so the weighted update stays
    unbiased under the time-varying policy.  ``extras`` carries the
    per-step event times plus the final/trajectory sampling vectors and
    the on-device occupancy, busy-time, delay and completion statistics;
    ``collect_extras=False`` prunes all of that dead weight (benchmark /
    fire-and-forget runs) — only ``p_final`` survives.

    With ``block_size=E > 1`` the stream advances E CS steps per scan
    iteration and the E gradients are computed in one vmapped call from the
    window-entry snapshots; a sequential fixup pass re-derives the exact
    iterates, recomputing (under `lax.cond`) only the gradients whose task
    was dispatched inside the same window — the device-side analogue of the
    host path's conflict-free cut, trajectory-identical to ``block_size=1``
    with the same PRNG key.  Caveat: under `jax.vmap` (`vmap_scenarios` /
    `run_matrix(stream="device")`) the conflict predicate is batched, so the
    cond lowers to a both-branches select and every gradient is computed
    twice — blocked fused runs are only a win un-vmapped; the vmapped
    scenario matrix should prefer the host blocked path or ``block_size=1``.

    ``lane_devices=D > 1`` shards each window's E-lane batched gradient
    call across D devices (`shard_map` over a "lanes" mesh axis; one
    all-gather per window recombines the lane gradients).  The stream
    advance and the sequential fixup replay replicated — they are cheap
    integer/axpy work — so results match the unsharded fused runner.
    ``lane_axis`` is for callers that already run inside a `shard_map`
    (the scenario × lane 2-D mesh of `jit_fused_runner`): it names the
    existing lane axis instead of self-wrapping.

    ``fault`` injects client churn / crashes / straggler timeouts into the
    on-device generator (`stream_device.fault_stream_step`): non-completion
    events carry scale 0 through the shared `_make_apply_event`, so a
    crashed or timed-out task's work is discarded and its slot re-dispatched
    with the *current* server weights — exactly FedBuff's non-fire masking.
    ``guard`` adds the divergence/staleness checks of `GuardConfig`;
    ``extras`` then reports ``guard_rejects`` / ``stale_drops`` (and, under
    faults, the per-kind event counts and availability integrals).  Both
    compose with blocks, lanes and the scenario mesh; neither composes with
    FedBuff (the buffer flush has no per-event masking semantics).

    ``classes`` (a `stream_device.ClassSpec`, from `build_class_spec`)
    switches the event source to the sparse O(C) per-event stream for
    large n: the closed network is represented by its C in-flight slots
    and per-event cost is flat in n.  ``run`` then takes **class-level**
    ``mu``/``p0`` of shape (m,) — per-*node* rates/probabilities, one per
    speed class — and the occupancy/busy/delay/completion extras come back
    with shape (m,) (per class) instead of (n,).  The adaptive control
    loop runs on the class simplex (`ctrl_refresh(..., counts=...)`).
    Requires ``block_size=1`` and ``lane_devices=1``; dispatch draws use
    the O(log m) class tree + within-class uniform member draw, exact in
    law versus the dense path by exchangeability within a class.

    ``serving`` (a `serving.ServingConfig`) merges an **open** Poisson
    inference stream into the event race: requests are admitted through a
    token bucket + queue-depth threshold, served FIFO from the snapshot
    ring at the known-good (last-accepted-update) row, deadline-timed-out
    with capped jittered exponential-backoff retries, and shed under
    overload — the resilient serving plane of `core.serving`.  Requires
    ``block_size=1``, the dense stream, flat-packed parameters and no
    FedBuff; composes with faults, guards and the adaptive controller.
    Serve events skip the gradient entirely (a `lax.cond` branch,
    un-vmapped) and are invisible to the training-side scatters (slot C /
    client n, the flip masking pattern); the Palm accumulator ``occ_sum``
    does sample at serve epochs too, which is still unbiased for the
    time-average by PASTA.  ``extras`` gains the ``serve_*`` counters,
    histograms and the final serve state.

    ``scenario`` (a `scenario.ScenarioConfig`) swaps the event source to
    `stream_device.scenario_stream_step`: phase-type service (Erlang-k /
    hyperexponential stage chains) + Markov-modulated on/off availability.
    Stage advances and flips carry scale 0 / slot C exactly like fault
    events, so the algorithm half is untouched; ``busy_t`` integrates the
    modulated exposure, keeping the adaptive controller's `ctrl_refresh`
    unbiased per scenario.  Requires ``block_size=1``, the dense stream
    (use `sparse_stats_stream_fn(scenario=True)` for class-level laws),
    and excludes ``fault`` / ``serving`` / FedBuff.  A disabled scenario
    (``exponential`` + always-on) routes through the unmodified engine —
    bitwise-identical to ``scenario=None``.
    """
    import jax
    import jax.numpy as jnp

    from . import stream_device as sd

    if weighting not in ("importance", "plain"):
        raise ValueError(weighting)
    if adaptive:
        if fedbuff_Z:
            raise ValueError("adaptive sampling applies to Algorithm 1, not FedBuff")
        if refresh_every <= 0:
            raise ValueError("adaptive=True requires refresh_every > 0")
        if eval_fn is not None and eval_every and eval_every % refresh_every:
            raise ValueError("eval_every must be a multiple of refresh_every")
    if block_size > 1 and update_fn is not None:
        raise ValueError(
            "block_size > 1 requires the default update w - scale*g"
        )
    bound = bound if bound is not None else BoundConstants(C=C, T=T)
    importance = weighting == "importance"
    E = max(int(block_size), 1)
    if lane_axis is not None and lane_devices <= 1:
        raise ValueError("lane_axis requires lane_devices > 1")
    if lane_devices > 1 and lane_axis is None:
        _check_lane_devices(lane_devices, E)  # incl. device availability
    elif lane_devices > 1 and (E < 2 or E % lane_devices):
        raise ValueError(
            f"block_size={E} must be a >1 multiple of "
            f"lane_devices={lane_devices}"
        )
    wrap_lanes = lane_devices > 1 and lane_axis is None
    axis = lane_axis if lane_axis is not None else (
        "lanes" if lane_devices > 1 else None
    )
    faulty = fault is not None and fault.enabled
    guard_stale = guard is not None and int(guard.stale_cutoff) > 0
    sparse = classes is not None
    scen_on = scenario is not None and scenario.enabled
    if scen_on:
        if faulty:
            raise ValueError(
                "scenario= and fault= are separate injection paths; model "
                "suspension via ScenarioConfig modulation (rate_scale)"
            )
        if sparse:
            raise ValueError(
                "the fused engine's scenario path is dense-only; use "
                "sparse_stats_stream_fn(scenario=True) for class-level laws"
            )
        if E > 1:
            raise ValueError("scenario= requires block_size=1")
        if fedbuff_Z:
            raise ValueError("scenario= composes with Algorithm 1, not FedBuff")
        if serving is not None and serving.enabled:
            raise ValueError("scenario= does not compose with serving=")
    if sparse:
        if E > 1:
            raise ValueError("classes= (sparse stream) requires block_size=1")
        if lane_devices > 1:
            raise ValueError("classes= (sparse stream) requires lane_devices=1")
        if classes.n != n:
            raise ValueError(
                f"ClassSpec covers n={classes.n} clients, runner built "
                f"for n={n}"
            )
    if faulty and fedbuff_Z:
        raise ValueError(
            "fault injection composes with Algorithm 1, not FedBuff "
            "(a crash/timeout at a flush step has no masking semantics)"
        )
    if guard_stale and fedbuff_Z:
        raise ValueError(
            "the staleness cutoff requires the per-event update (fedbuff_Z=0)"
        )
    serving_on = serving is not None and serving.enabled
    if serving_on:
        from . import serving as sp

        serving.validate()
        if E > 1:
            raise ValueError("serving= requires block_size=1")
        if fedbuff_Z:
            raise ValueError("serving= composes with Algorithm 1, not FedBuff")
        if sparse:
            raise ValueError(
                "serving= requires the dense stream (classes=None)"
            )
        if lane_devices > 1:
            raise ValueError("serving= requires lane_devices=1")
        if update_fn is not None:
            raise ValueError("serving= requires the default update w - scale*g")
    # the staleness cutoff reads StatsState.slot_step, so stats must run
    need_stats = collect_extras or adaptive or guard_stale

    # chunk length: refresh and eval both happen at chunk boundaries
    if adaptive:
        L = min(refresh_every, T)
    elif eval_fn is not None and eval_every:
        L = min(eval_every, T)
    else:
        L = T
    n_chunks, Tc = T // L, (T // L) * L
    eval_on = eval_fn is not None and eval_every > 0
    eval_stride = max(eval_every // L, 1) if eval_on else 0

    update_fn, default_update = _default_update(update_fn)

    def run(w0, mu, p0, key, eta):
        pack, unpack, enc = _snapshot_codec(w0, snapshot_dtype)
        flat_mode = default_update and unpack is not None
        if E > 1 and not flat_mode:
            raise ValueError(
                "block_size > 1 requires all-float parameters "
                "(flat-packed snapshot storage)"
            )
        if serving_on and not flat_mode:
            raise ValueError(
                "serving= requires all-float parameters (the serving read "
                "path gathers flat-packed snapshot rows)"
            )
        update_step = _make_update_step(
            grad_fn, fedbuff_Z, update_fn, pack, unpack, flat_mode, enc, guard
        )
        rows = C + 1 if E > 1 else C
        ucarry, to_tree = _init_update_carry(
            w0, rows, pack, unpack, flat_mode, fedbuff_Z, enc
        )

        mu = jnp.asarray(mu, jnp.float32)
        p0 = jnp.asarray(p0, jnp.float32)
        eta = jnp.asarray(eta, jnp.float32)
        spec = classes.device() if sparse else None
        if sparse:
            fr = sd.resolve_fault_rates_classes(fault, classes) if faulty \
                else None
            k_init, k_race, k_exp, k_disp, k_mem, k_bit = jax.random.split(
                key, 6
            )
            u_mem = jax.random.uniform(k_mem, (T,))
            u_bit = jax.random.uniform(k_bit, (T,)) if faulty else None
            u_ph = None
            sstate, init_nodes = sd.sparse_stream_init(
                k_init, spec, C, p0, init=init, fault=faulty
            )
            stats = sd.sparse_stats_init(classes.m, C, fault=faulty)
        elif scen_on:
            fr = sd.resolve_scenario(scenario, n)
            k_init, k_race, k_exp, k_disp, k_ph = jax.random.split(key, 5)
            u_mem = u_bit = None
            u_ph = jax.random.uniform(k_ph, (T,))
            sstate, init_nodes = sd.scenario_stream_init(
                k_init, n, C, p0, fr, init=init
            )
            stats = sd.stats_init(n, C, scenario=True)
        else:
            fr = sd.resolve_fault_rates(fault, n) if faulty else None
            k_init, k_race, k_exp, k_disp = jax.random.split(key, 4)
            u_mem = u_bit = u_ph = None
            sstate, init_nodes = sd.stream_init(
                k_init, n, C, p0, init=init, fault=faulty
            )
            stats = sd.stats_init(n, C, fault=faulty)
        u_race = jax.random.uniform(k_race, (T,))
        u_exp = jax.random.uniform(k_exp, (T,))
        u_disp = jax.random.uniform(k_disp, (T,))
        # dispatch-time importance scale per in-flight slot (Alg. 1 line 10)
        if importance:
            p_nodes0 = p0[spec.inv_cls[init_nodes]] if sparse \
                else p0[init_nodes]
            slot_scale0 = eta / (n * p_nodes0)
        else:
            slot_scale0 = jnp.broadcast_to(eta, (C,))

        advance = _make_fused_advance(
            grad_fn, n, C, E, update_step, pack, unpack, enc, fedbuff_Z, guard,
            importance=importance, faulty=faulty, guard_stale=guard_stale,
            need_stats=need_stats, axis=axis, lane_devices=lane_devices,
            unroll=unroll, classes=classes, serving=serving,
            scenario=scen_on,
        )(mu, eta, fr)
        sv0 = sp.serve_init(serving) if serving_on else None
        sstats0 = sp.serve_stats_init() if serving_on else None

        if sparse:
            # O(log m) class draw + uniform member — flat in n
            def sample_dispatch(p, u, um):
                return sd.sample_dispatch_classes(p, spec, u, um)
        else:
            # segment-tree inverse-CDF: O(log n) ulp error and never lands
            # on a zero-probability client (the clamped fp32
            # cumsum+searchsorted over-selected index n-1 at large n)
            def sample_dispatch(p, u, um=None):
                ptree = sd.tree_build(p)
                return jax.vmap(
                    lambda uu: sd.tree_sample(ptree, uu)
                )(u).astype(jnp.int32)

        def chunk_step(carry, xs):
            if serving_on:
                ucarry, sstate, stats, slot_scale, p, sv, sstats = carry
            else:
                ucarry, sstate, stats, slot_scale, p = carry
                sv = sstats = None
            if sparse and faulty:
                ur, ue, ud, um, ub, k0 = xs
                uph = None
            elif sparse:
                ur, ue, ud, um, k0 = xs
                ub = uph = None
            elif scen_on:
                ur, ue, ud, uph, k0 = xs
                um = ub = None
            else:
                ur, ue, ud, k0 = xs
                um = ub = uph = None
            Kc = sample_dispatch(p, ud, um)
            if serving_on:
                ucarry, sstate, stats, slot_scale, ts, sv, sstats = advance(
                    ucarry, sstate, stats, slot_scale, p, ur, ue, Kc, k0, ub,
                    sv, sstats,
                )
            else:
                ucarry, sstate, stats, slot_scale, ts = advance(
                    ucarry, sstate, stats, slot_scale, p, ur, ue, Kc, k0, ub,
                    uph=uph,
                )
            if adaptive:
                p = sd.ctrl_refresh(
                    p, stats.comp, stats.busy_t, bound, lr=ctrl_lr,
                    iters=ctrl_iters,
                    counts=tuple(int(c) for c in classes.counts) if sparse
                    else None,
                )
            if not eval_on:
                ev_val = jnp.float32(0.0)
            elif eval_stride == 1:
                ev_val = eval_fn(to_tree(ucarry[0]))
            else:
                # eval fires every eval_stride-th refresh chunk; the predicate
                # is unbatched under vmap, so the cond stays a real branch and
                # off-cadence chunks skip the eval work entirely
                fire = ((k0 // L + 1) % eval_stride) == 0
                ev_val = jax.lax.cond(
                    fire,
                    lambda u: jnp.asarray(eval_fn(to_tree(u)), jnp.float32),
                    lambda u: jnp.float32(0.0),
                    ucarry[0],
                )
            ys = (ts, ev_val, p) if collect_extras else (ev_val,)
            if serving_on:
                return (ucarry, sstate, stats, slot_scale, p, sv, sstats), ys
            return (ucarry, sstate, stats, slot_scale, p), ys

        carry = (ucarry, sstate, stats, slot_scale0, p0)
        if serving_on:
            carry = carry + (sv0, sstats0)
        resh = lambda a: a[:Tc].reshape(n_chunks, L)
        xs = (resh(u_race), resh(u_exp), resh(u_disp))
        if sparse:
            xs = xs + (resh(u_mem),)
            if faulty:
                xs = xs + (resh(u_bit),)
        elif scen_on:
            xs = xs + (resh(u_ph),)
        xs = xs + (jnp.arange(n_chunks, dtype=jnp.int32) * L,)
        carry, ys = jax.lax.scan(chunk_step, carry, xs)
        if collect_extras:
            ts, evals, p_traj = ys
            ts = ts.reshape(Tc)
        else:
            (evals,) = ys
        if serving_on:
            ucarry, sstate, stats, slot_scale, p, sv, sstats = carry
        else:
            ucarry, sstate, stats, slot_scale, p = carry
            sv = sstats = None
        if Tc < T:  # tail events past the last chunk boundary
            Kc = sample_dispatch(
                p, u_disp[Tc:], u_mem[Tc:] if sparse else None
            )
            if serving_on:
                (ucarry, sstate, stats, slot_scale, ts_tail, sv,
                 sstats) = advance(
                    ucarry, sstate, stats, slot_scale, p,
                    u_race[Tc:], u_exp[Tc:], Kc, Tc, None, sv, sstats,
                )
            else:
                ucarry, sstate, stats, slot_scale, ts_tail = advance(
                    ucarry, sstate, stats, slot_scale, p,
                    u_race[Tc:], u_exp[Tc:], Kc, Tc,
                    u_bit[Tc:] if sparse and faulty else None,
                    uph=u_ph[Tc:] if scen_on else None,
                )
            if collect_extras:
                ts = jnp.concatenate([ts, ts_tail])
        if eval_on:
            evals = evals[eval_stride - 1 :: eval_stride]
        else:
            evals = jnp.zeros((0,))
        def _serve_extras():
            return {
                "serve_arrivals": sstats.arrivals,
                "serve_served": sstats.served,
                "serve_shed": sstats.shed,
                "serve_timed_out": sstats.timed_out,
                "serve_retried": sstats.retried,
                "serve_pending": jnp.sum((sv.stt != 0).astype(jnp.int32)),
                "serve_sojourn_sum": sstats.sojourn - sstats.sojourn_c,
                "serve_sojourn_hist": sstats.sojourn_hist,
                "serve_stale_hist": sstats.stale_hist,
                "serve_qdepth_time": sstats.qdepth_tw - sstats.qdepth_tw_c,
                "serve_qdepth_max": sstats.qdepth_max,
                "serve_checksum": sstats.checksum - sstats.checksum_c,
                "serve_kg_step": sv.kg_step,
                "serve_kg_slot": sv.kg_slot,
                "serve_tokens": sv.tokens,
                "serve_t_final": sstate.t,
            }

        if not collect_extras:
            extras = {"p_final": p}
            if guard is not None:
                extras["guard_rejects"] = ucarry[3][0]
                extras["stale_drops"] = ucarry[3][1]
            if serving_on:
                extras.update(_serve_extras())
            return to_tree(ucarry[0]), evals, extras
        extras = {
            "t": ts,
            "p_final": p,
            "p_traj": p_traj,
            "occ_mean": stats.occ_sum.astype(jnp.float32) / T,
            "occ_time_avg": stats.occ_tw / ts[-1],
            "busy_time": stats.busy_t,
            "delay_sum": stats.delay_sum,
            "comp": stats.comp,
        }
        if guard is not None:
            extras["guard_rejects"] = ucarry[3][0]
            extras["stale_drops"] = ucarry[3][1]
        if faulty or scen_on:
            extras["kind_count"] = stats.kind_count
            extras["avail_time"] = stats.avail_tw
        if sparse:
            # class-level extras: consumers expand per class via the counts
            extras["class_counts"] = jnp.asarray(classes.counts, jnp.int32)
        if serving_on:
            extras.update(_serve_extras())
        return to_tree(ucarry[0]), evals, extras

    if not wrap_lanes:
        return run

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = _lane_mesh(lane_devices)

    def run_sharded(w0, mu, p0, key, eta):
        # every operand replicates; only the window gradient batch (inside
        # `run`, via the "lanes" axis) is partitioned across the mesh
        f = shard_map(
            run, mesh=mesh, in_specs=(P(),) * 5, out_specs=P(),
            check_rep=False,
        )
        return f(w0, mu, p0, key, eta)

    return run_sharded


def make_runner(
    grad_fn: Callable[[Any, Pytree, Any], Pytree],
    C: int,
    *,
    stream: str = "host",
    fedbuff_Z: int = 0,
    eval_fn: Callable[[Pytree], Any] | None = None,
    eval_every: int = 0,
    update_fn: Callable[[Pytree, Pytree, Any], Pytree] | None = None,
    unroll: int = 1,
    block_size: int = 1,
    kernel: str = "jnp",
    snapshot_dtype=None,
    interpret: bool = True,
    lane_devices: int = 1,
    guard: GuardConfig | None = None,
    **device_kw,
):
    """Build the scan engine; ``stream`` selects the event source.

    ``stream="host"`` (default) replays a pre-simulated `EventStream` — the
    parity oracle: ``run(w0, J, slot, scale[, eval_every])``.  With
    ``block_size=E > 1`` it replays `EventBlocks` micro-blocks instead:
    ``run(w0, J, slot, scale, k, mask[, chunk_blocks, n_chunks])`` (see
    `blocked_inputs`); ``kernel`` picks the jnp fallback or the fused
    Pallas prefix-scan kernel, ``snapshot_dtype`` an optional narrower ring
    storage dtype.

    ``stream="device"`` fuses the on-device closed-network generator with
    the update step (zero host pre-simulation): ``run(w0, mu, p0, key, eta)``.
    Requires ``n=`` and ``T=`` (and accepts `make_fused_runner`'s
    ``weighting / adaptive / refresh_every / bound / ctrl_lr / ctrl_iters /
    init / collect_extras`` knobs); ``block_size`` advances E CS steps per
    scan iteration.

    ``lane_devices=D > 1`` (either stream; requires ``block_size`` a >1
    multiple of D) shards each micro-block's E-lane gradient batch across D
    devices — the block axis stays sequential, the lane axis becomes the
    unit of distribution (see `_make_block_step` / `make_fused_runner`).
    """
    if stream == "host":
        if device_kw:
            raise TypeError(f"host stream does not accept {sorted(device_kw)}")
        if block_size > 1:
            if eval_every:
                raise ValueError(
                    "block_size > 1: the eval cadence is encoded in the "
                    "blocked layout — pass chunk_blocks/n_chunks from "
                    "blocked_inputs(..., eval_every=...) at call time "
                    "instead of eval_every"
                )
            return _make_host_block_runner(
                grad_fn, C, block_size, fedbuff_Z=fedbuff_Z, eval_fn=eval_fn,
                update_fn=update_fn, unroll=unroll, kernel=kernel,
                snapshot_dtype=snapshot_dtype, interpret=interpret,
                lane_devices=lane_devices, guard=guard,
            )
        _check_lane_devices(lane_devices, block_size)  # rejects D>1 at E=1
        return _make_host_runner(
            grad_fn, C, fedbuff_Z=fedbuff_Z, eval_fn=eval_fn,
            eval_every=eval_every, update_fn=update_fn, unroll=unroll,
            snapshot_dtype=snapshot_dtype, guard=guard,
        )
    if stream == "device":
        try:
            n, T = device_kw.pop("n"), device_kw.pop("T")
        except KeyError as e:
            raise TypeError(f"stream='device' requires {e.args[0]}=") from None
        return make_fused_runner(
            grad_fn, n, C, T, fedbuff_Z=fedbuff_Z, eval_fn=eval_fn,
            eval_every=eval_every, update_fn=update_fn, unroll=unroll,
            block_size=block_size, snapshot_dtype=snapshot_dtype,
            lane_devices=lane_devices, guard=guard,
            **device_kw,
        )
    raise ValueError(stream)


# ------------------------------------------------------------------ #
# memoized jitted runners
# ------------------------------------------------------------------ #
def _runner_cache(grad_fn):
    """Per-owner memo for jitted runners.

    `make_runner` builds a fresh closure per call, which would defeat
    `jax.jit`'s compilation cache, so the jitted runner is memoized on the
    object owning `grad_fn` (its `__self__` for bound methods like
    `source.device_grad`, else the function's own `__dict__`): repeated runs
    with the same source reuse the compiled executable, and the memo — a
    plain attribute forming an internal reference cycle — is garbage
    collected together with the source instead of pinning device shards and
    executables in a process-global cache.
    """
    owner = getattr(grad_fn, "__self__", grad_fn)
    func = getattr(grad_fn, "__func__", grad_fn)
    try:
        cache = owner.__dict__.setdefault("_scan_runner_cache", {})
    except AttributeError:  # no instance dict (slots/builtin): skip memoization
        cache = {}
    return cache, func


def jit_runner(
    grad_fn,
    C: int,
    fedbuff_Z: int = 0,
    eval_fn=None,
    eval_every: int = 0,
    update_fn=None,
    unroll: int = 1,
    vmap_streams: bool = False,
    block_size: int = 1,
    kernel: str = "jnp",
    snapshot_dtype=None,
    donate: bool = False,
    interpret: bool = True,
    lane_devices: int = 1,
    guard: GuardConfig | None = None,
):
    """Jitted, memoized host-replay runner.

    The memo key deliberately excludes ``eval_every``: the cadence is a
    static *call-time* argument of the jitted function (``static_argnames``),
    so sweeps over eval cadence reuse one runner object and share
    `jax.jit`'s compilation cache instead of rebuilding the closure (and
    with it the whole trace) per cadence.  ``vmap_streams=True`` returns the
    batched variant mapping over stacked (J, slot, scale).

    ``block_size=E > 1`` returns the blocked runner (`blocked_inputs`
    arrays; the eval layout ``chunk_blocks``/``n_chunks`` are its call-time
    statics).  ``lane_devices=D > 1`` lane-shards the blocked runner's E
    gradient lanes across D devices (requires E a multiple of D; composes
    with ``vmap_streams`` into the scenario × lane layout).  ``donate=True``
    donates the per-run event-stream buffers to the compiled program
    (callers passing freshly built arrays — the `_run_scan` / `run_matrix`
    drivers — save one device-side copy of the stream; don't enable it when
    re-calling with the same arrays).
    """
    import jax

    cache, func = _runner_cache(grad_fn)
    key = (
        "host", func, C, fedbuff_Z, eval_fn, update_fn, unroll, vmap_streams,
        block_size, kernel, snapshot_dtype, donate, interpret, lane_devices,
        None if guard is None else guard.cache_key(),
    )
    if block_size > 1 and eval_every:
        raise ValueError(
            "block_size > 1: the eval cadence is encoded in the blocked "
            "layout — pass chunk_blocks/n_chunks from blocked_inputs(..., "
            "eval_every=...) at call time instead of eval_every"
        )
    _check_lane_devices(lane_devices, block_size)
    if key in cache:
        jitted = cache[key]
        return jitted if block_size > 1 else partial(jitted, eval_every=eval_every)
    if block_size > 1:
        # the factory owns the scenario vmap and (if any) the lane shard_map
        run = _make_host_block_runner(
            grad_fn, C, block_size, fedbuff_Z=fedbuff_Z, eval_fn=eval_fn,
            update_fn=update_fn, unroll=unroll, kernel=kernel,
            snapshot_dtype=snapshot_dtype, interpret=interpret,
            lane_devices=lane_devices, vmap_streams=vmap_streams, guard=guard,
        )
        cache[key] = jax.jit(
            run,
            static_argnames=("chunk_blocks", "n_chunks"),
            donate_argnums=(1, 2, 3, 4, 5) if donate else (),
        )
        return cache[key]
    base = _make_host_runner(
        grad_fn, C, fedbuff_Z=fedbuff_Z, eval_fn=eval_fn, eval_every=0,
        update_fn=update_fn, unroll=unroll, snapshot_dtype=snapshot_dtype,
        guard=guard,
    )
    if vmap_streams:
        def run(w0, J, slot, scale, eval_every=0):
            return jax.vmap(
                lambda w, a, b, c: base(w, a, b, c, eval_every),
                in_axes=(None, 0, 0, 0),
            )(w0, J, slot, scale)
    else:
        run = base
    cache[key] = jax.jit(
        run,
        static_argnames=("eval_every",),
        donate_argnums=(1, 2, 3) if donate else (),
    )
    return partial(cache[key], eval_every=eval_every)


def jit_fused_runner(
    grad_fn,
    n: int,
    C: int,
    T: int,
    *,
    vmap_scenarios: bool = False,
    shard_devices: int = 1,
    lane_devices: int = 1,
    **kw,
):
    """Jitted, memoized fused (device-stream) runner.

    Memoized on the gradient source like `jit_runner`; ``vmap_scenarios``
    maps over stacked (mu, p0, key) with shared (w0, eta) — the zero-host-
    presimulation scenario matrix.  ``shard_devices > 1`` additionally
    `pmap`s the batched runner over that many devices (inputs carry an extra
    leading device axis) — the scenario matrix then runs data-parallel
    across the host platform's cores/accelerators, which the serial
    host-export path cannot.

    ``lane_devices > 1`` lane-shards each micro-block's gradient batch
    (requires ``block_size`` a >1 multiple of it).  Combined with
    ``vmap_scenarios`` it builds one `shard_map` over a scenario × lane 2-D
    mesh — ``shard_devices`` scenario shards on the first axis,
    ``lane_devices`` lane shards on the second — and unlike the pmap path
    the caller passes flat ``(B, ...)`` scenario batches (B divisible by
    ``shard_devices``); no extra leading device axis.

    Extra keywords (``block_size``, ``collect_extras``, ``snapshot_dtype``,
    ...) forward to `make_fused_runner` and participate in the memo key.
    """
    import jax

    cache, func = _runner_cache(grad_fn)

    def _kw_entry(k, v):
        if k == "bound":
            return (k, None if v is None else (v.A, v.L, v.B, v.C, v.T, v.rho))
        if k in ("fault", "guard", "serving", "scenario"):
            return (k, None if v is None else v.cache_key())
        if k == "classes":
            return (k, None if v is None else v.cache_key())
        return (k, v)

    kw_key = tuple(_kw_entry(k, v) for k, v in sorted(kw.items()))
    key = (
        "device", func, n, C, T, vmap_scenarios, shard_devices, lane_devices,
        kw_key,
    )
    if key not in cache:
        if lane_devices > 1 and vmap_scenarios:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import Mesh, PartitionSpec as P

            n_dev = shard_devices * lane_devices
            avail = jax.device_count()
            if n_dev > avail:
                raise ValueError(
                    f"scenario × lane mesh needs {n_dev} devices, "
                    f"{avail} visible"
                )
            run = make_fused_runner(
                grad_fn, n, C, T, lane_devices=lane_devices,
                lane_axis="lanes", **kw,
            )
            batched = jax.vmap(run, in_axes=(None, 0, 0, 0, None))
            mesh = Mesh(
                np.array(jax.devices()[:n_dev]).reshape(
                    shard_devices, lane_devices
                ),
                ("scen", "lanes"),
            )
            sharded = shard_map(
                batched,
                mesh=mesh,
                in_specs=(P(), P("scen"), P("scen"), P("scen"), P()),
                out_specs=P("scen"),
                check_rep=False,
            )
            cache[key] = jax.jit(sharded)
        elif lane_devices > 1:
            run = make_fused_runner(
                grad_fn, n, C, T, lane_devices=lane_devices, **kw
            )
            cache[key] = jax.jit(run)
        else:
            run = make_fused_runner(grad_fn, n, C, T, **kw)
            if vmap_scenarios:
                batched = jax.vmap(run, in_axes=(None, 0, 0, 0, None))
                if shard_devices > 1:
                    cache[key] = jax.pmap(batched, in_axes=(None, 0, 0, 0, None))
                else:
                    cache[key] = jax.jit(batched)
            else:
                cache[key] = jax.jit(run)
    return cache[key]
