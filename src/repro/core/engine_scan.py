"""Compiled device-resident Generalized-AsyncSGD engine (one `lax.scan`).

The Python reference loop in `async_sgd.py` pays a host<->device round trip
per CS step, which caps the §5 experiment at toy sizes.  The queuing
structure removes the need for that: the event stream (J_k, K_{k+1}, t_k) of
the closed Jackson network is independent of the gradient values, so it can
be pre-simulated on the host (`queue_sim.export_stream`) and Algorithm 1
replayed on device as a single XLA program:

  * the C in-flight dispatch snapshots live in a stacked ring buffer
    (a (C, ...) leading axis on every parameter leaf);
  * step k gathers the completing task's snapshot from `slot[k]`, computes
    the client gradient with a traceable `grad_fn(j, w, k)`, applies the
    importance-weighted update, and scatters the updated parameters back
    into the same slot (the freed slot hosts the new dispatch — exactly one
    task completes and one departs per step, Lemma 9);
  * evaluation runs as an outer scan over chunks of `eval_every` events, so
    the whole run — updates and metric curve — is one compiled call.

`make_runner` returns a pure function of (w0, J, slot, scale): jit it for a
single run, `jax.vmap` it over stacked streams for the scenario matrix
(seeds x sampling policies x heterogeneity levels in one compiled call).

FedBuff rides the same scan: gradients accumulate into a buffer pytree and
the (masked, branch-free) server update fires every Z-th step.
"""
from __future__ import annotations

from typing import Any, Callable, Protocol

import numpy as np

from .queue_sim import EventStream

__all__ = [
    "DeviceGradientSource",
    "jit_runner",
    "make_runner",
    "step_scales",
    "stream_arrays",
]

Pytree = Any


class DeviceGradientSource(Protocol):
    """A gradient source the compiled engine can trace.

    Unlike `GradientSource.grad` (host Python, one call per step),
    `device_grad` is called once under tracing with abstract scalar
    `client_id` / `server_step`; it must be expressible as pure JAX ops over
    device-resident data (e.g. a gather from stacked per-client shards).
    """

    def device_grad(self, client_id, params: Pytree, server_step) -> Pytree:
        ...


def step_scales(
    stream: EventStream, eta: float, p: np.ndarray, weighting: str
) -> np.ndarray:
    """Per-step update scale as a (T,) array: eta/(n p_{J_k}) or plain eta."""
    if weighting == "importance":
        return (eta / (stream.n * np.asarray(p, float)))[stream.J]
    if weighting == "plain":
        return np.full(stream.T, eta)
    raise ValueError(weighting)


def stream_arrays(stream: EventStream):
    """Device copies of the scan inputs (J, slot) for one stream."""
    import jax.numpy as jnp

    return jnp.asarray(stream.J), jnp.asarray(stream.slot)


def make_runner(
    grad_fn: Callable[[Any, Pytree, Any], Pytree],
    C: int,
    *,
    fedbuff_Z: int = 0,
    eval_fn: Callable[[Pytree], Any] | None = None,
    eval_every: int = 0,
    update_fn: Callable[[Pytree, Pytree, Any], Pytree] | None = None,
    unroll: int = 1,
):
    """Build the scan engine for a fixed algorithm shape.

    Returns ``run(w0, J, slot, scale) -> (w_final, evals)`` — a pure
    function: `jax.jit` it directly, or `jax.vmap(run, in_axes=(None, 0, 0,
    0))` to execute a whole scenario matrix in one compiled call.  ``evals``
    is the eval_fn curve sampled every `eval_every` steps (empty array when
    evaluation is off).

    grad_fn(j, w, k): traceable stochastic gradient of client j at params w,
    server step k.  update_fn(w, g, scale) defaults to w - scale*g.
    """
    import jax
    import jax.numpy as jnp

    tree_map = jax.tree_util.tree_map
    default_update = update_fn is None
    if update_fn is None:
        # cast back per leaf so the scan carry dtype stays stable (bf16
        # params with an fp32 scale would otherwise promote)
        update_fn = lambda w, g, s: tree_map(
            lambda x, y: (x - s * y).astype(x.dtype), w, g
        )

    def _snapshot_codec(w0):
        """Flat-packed snapshot storage when all leaves share a dtype.

        The ring buffer then is ONE (C, P) array — a single gather/scatter
        per step instead of two per leaf, which matters for small models
        where per-op overhead inside the scan dominates.  Mixed-dtype trees
        fall back to per-leaf (C, ...) buffers.
        """
        leaves, treedef = jax.tree_util.tree_flatten(w0)
        dtypes = {jnp.asarray(l).dtype for l in leaves}
        if len(dtypes) != 1:
            return None, None  # per-leaf buffers
        shapes = [jnp.shape(l) for l in leaves]
        sizes = [int(np.prod(s)) if s else 1 for s in shapes]
        offs = np.concatenate([[0], np.cumsum(sizes)]).tolist()

        def pack(w):
            ls = jax.tree_util.tree_leaves(w)
            return jnp.concatenate([jnp.ravel(x) for x in ls])

        def unpack(flat):
            ls = [
                flat[offs[i] : offs[i + 1]].reshape(shapes[i])
                for i in range(len(shapes))
            ]
            return jax.tree_util.tree_unflatten(treedef, ls)

        return pack, unpack

    def make_body(pack, unpack, flat_mode):
        def body(carry, xs):
            w, snaps, acc = carry  # w (and acc) are flat vectors in flat_mode
            j, s, scale, k = xs
            # gather the completing task's dispatch-time snapshot (Alg. 1 line 9)
            if unpack is None:
                w_disp = tree_map(lambda b: b[s], snaps)
            else:
                w_disp = unpack(snaps[s])
            g = grad_fn(j, w_disp, k)
            if flat_mode:
                # default update on the packed vector: one axpy, one scatter
                g = pack(g)
                if fedbuff_Z > 0:
                    acc = acc + g
                    fire = ((k + 1) % fedbuff_Z) == 0
                    eff = jnp.where(fire, scale / fedbuff_Z, 0.0)
                    w = (w - eff * acc).astype(w.dtype)
                    acc = acc * (~fire).astype(acc.dtype)
                else:
                    w = (w - scale * g).astype(w.dtype)
                snaps = snaps.at[s].set(w)
                return (w, snaps, acc), ()
            if fedbuff_Z > 0:
                acc = tree_map(lambda a, y: a + y, acc, g)
                fire = ((k + 1) % fedbuff_Z) == 0
                eff = jnp.where(fire, scale / fedbuff_Z, 0.0)
                w = update_fn(w, acc, eff)
                acc = tree_map(lambda a: a * (~fire).astype(a.dtype), acc)
            else:
                w = update_fn(w, g, scale)
            # the freed slot hosts the new dispatch with the updated params
            if unpack is None:
                snaps = tree_map(lambda b, x: b.at[s].set(x), snaps, w)
            else:
                snaps = snaps.at[s].set(pack(w))
            return (w, snaps, acc), ()

        return body

    def run(w0, J, slot, scale):
        pack, unpack = _snapshot_codec(w0)
        flat_mode = default_update and unpack is not None
        body = make_body(pack, unpack, flat_mode)
        to_tree = (lambda w: unpack(w)) if flat_mode else (lambda w: w)

        def scan(carry, Jc, slotc, scalec, k0):
            ks = k0 + jnp.arange(Jc.shape[0], dtype=Jc.dtype)
            return jax.lax.scan(body, carry, (Jc, slotc, scalec, ks), unroll=unroll)[0]

        if unpack is None:
            snaps0 = tree_map(
                lambda x: jnp.broadcast_to(x[None], (C,) + jnp.shape(x)), w0
            )
            w_init = w0
        else:
            flat0 = pack(w0)
            snaps0 = jnp.broadcast_to(flat0[None], (C, flat0.shape[0]))
            w_init = flat0 if flat_mode else w0
        acc0 = tree_map(jnp.zeros_like, w_init) if fedbuff_Z > 0 else ()
        carry = (w_init, snaps0, acc0)
        T = int(J.shape[0])
        if eval_fn is not None and eval_every and T >= eval_every:
            n_chunks = T // eval_every
            Tc = n_chunks * eval_every

            # per-chunk absolute step offsets ride along as a scan input
            def chunk_body(c, xs):
                Jc, sc, scc, k0 = xs
                c = scan(c, Jc, sc, scc, k0)
                return c, eval_fn(to_tree(c[0]))

            xs = (
                J[:Tc].reshape(n_chunks, eval_every),
                slot[:Tc].reshape(n_chunks, eval_every),
                scale[:Tc].reshape(n_chunks, eval_every),
                jnp.arange(n_chunks, dtype=J.dtype) * eval_every,
            )
            carry, evals = jax.lax.scan(chunk_body, carry, xs)
            if Tc < T:  # tail events past the last eval point
                carry = scan(carry, J[Tc:], slot[Tc:], scale[Tc:], k0=Tc)
            return to_tree(carry[0]), evals
        carry = scan(carry, J, slot, scale, k0=0)
        return to_tree(carry[0]), jnp.zeros((0,))

    return run


def jit_runner(
    grad_fn,
    C: int,
    fedbuff_Z: int = 0,
    eval_fn=None,
    eval_every: int = 0,
    update_fn=None,
    unroll: int = 1,
):
    """Jitted, memoized `make_runner`.

    `make_runner` builds a fresh closure per call, which would defeat
    `jax.jit`'s compilation cache, so the jitted runner is memoized on the
    object owning `grad_fn` (its `__self__` for bound methods like
    `source.device_grad`, else the function's own `__dict__`): repeated runs
    with the same source reuse the compiled executable, and the memo — a
    plain attribute forming an internal reference cycle — is garbage
    collected together with the source instead of pinning device shards and
    executables in a process-global cache.
    """
    import jax

    owner = getattr(grad_fn, "__self__", grad_fn)
    key = (getattr(grad_fn, "__func__", grad_fn), C, fedbuff_Z, eval_fn,
           eval_every, update_fn, unroll)
    try:
        cache = owner.__dict__.setdefault("_scan_runner_cache", {})
    except AttributeError:  # no instance dict (slots/builtin): skip memoization
        cache = {}
    if key not in cache:
        cache[key] = jax.jit(
            make_runner(
                grad_fn,
                C,
                fedbuff_Z=fedbuff_Z,
                eval_fn=eval_fn,
                eval_every=eval_every,
                update_fn=update_fn,
                unroll=unroll,
            )
        )
    return cache[key]
