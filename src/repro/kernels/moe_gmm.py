"""Pallas kernel: grouped (per-expert) matmul on dispatch-form MoE tensors.

Computes y[e] = x[e] @ w[e] for E experts with fp32 MXU accumulation:
  grid = (E, C/bc, F/bf, D/bd) — the contraction axis is innermost
  ("arbitrary"); a fp32 VMEM scratch accumulates partial products, written
  out on the last D step.  Block sizes default to MXU-aligned 128.

This is the expert-FFN hot loop of the MoE architectures (arctic-480b,
qwen2-moe); the capacity-dispatch form means every expert block is dense —
the TPU-native adaptation of GPU "megablocks"-style ragged GMM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc, *, nd: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(
        x_ref[0].astype(jnp.float32),
        w_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(di == nd - 1)
    def _emit():
        o_ref[0, ...] = acc[...].astype(o_ref.dtype)


def _gmm_impl(
    x: jax.Array,  # (E, C, D)
    w: jax.Array,  # (E, D, F)
    bc: int,
    bf: int,
    bd: int,
    interpret: bool,
) -> jax.Array:
    E, C, D = x.shape
    F = w.shape[-1]
    bc_, bf_, bd_ = min(bc, C), min(bf, F), min(bd, D)
    if C % bc_ or F % bf_ or D % bd_:
        raise ValueError(f"dims ({C},{D},{F}) not divisible by blocks ({bc_},{bd_},{bf_})")
    nd = D // bd_
    out = pl.pallas_call(
        functools.partial(_gmm_kernel, nd=nd),
        grid=(E, C // bc_, F // bf_, nd),
        in_specs=[
            pl.BlockSpec((1, bc_, bd_), lambda e, c, f, d: (e, c, d)),
            pl.BlockSpec((1, bd_, bf_), lambda e, c, f, d: (e, d, f)),
        ],
        out_specs=pl.BlockSpec((1, bc_, bf_), lambda e, c, f, d: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc_, bf_), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _gmm_vjp(x, w, bc, bf, bd, interpret):
    return _gmm_impl(x, w, bc, bf, bd, interpret)


def _gmm_fwd(x, w, bc, bf, bd, interpret):
    return _gmm_impl(x, w, bc, bf, bd, interpret), (x, w)


def _gmm_bwd(bc, bf, bd, interpret, res, g):
    from .ref import moe_gmm_ref

    x, w = res
    _, pullback = jax.vjp(moe_gmm_ref, x, w)
    return pullback(g)


_gmm_vjp.defvjp(_gmm_fwd, _gmm_bwd)


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bd", "interpret"))
def moe_gmm(
    x: jax.Array,  # (E, C, D)
    w: jax.Array,  # (E, D, F)
    bc: int = 128,
    bf: int = 128,
    bd: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Differentiable wrapper: Pallas kernel forward, jnp-reference VJP.

    The backward differentiates `moe_gmm_ref` (the fp32-accumulating
    einsum) under `jax.vjp` from the saved (x, w) residuals — the two
    transposed GEMMs of the grouped-matmul backward, so the kernel sits
    directly on the expert-FFN training hot path.
    """
    return _gmm_vjp(x, w, bc, bf, bd, interpret)
