"""Cached tile autotuning for the blocked-update Pallas kernels.

`block_prefix_update` / `block_scatter_rows` stream (R, P) ring-buffer
column tiles; the best tile width depends on the backend, the packed
parameter length P and the micro-block size E (wider tiles amortize grid
overhead, narrower tiles fit more snapshot rows per VMEM residency).  The
sweep lives in ``benchmarks/kernel_micro.py`` (`--sweep-tiles`); this
module only stores and serves its results:

    table[(kernel, backend, P, E)] = best tile width

The table persists as JSON next to the benchmark outputs
(``benchmarks/autotune_kernels.json``, override with the
``REPRO_AUTOTUNE_TABLE`` env var) and is consulted by the
`repro.kernels.ops` wrappers — and therefore by the blocked scan engine,
whose ``update="pallas"`` path calls the kernels through ops.  A miss
returns ``None``, which the kernels map to the full ``BLOCK_TILE`` —
exactly the pre-autotune behaviour, so shipping no table changes nothing.

Tiles must divide ``BLOCK_TILE`` (1024): the engine pads the packed vector
to a BLOCK_TILE multiple once at init, so every divisor tiles it evenly.
"""
from __future__ import annotations

import json
import os
import threading

TILE_CANDIDATES = (128, 256, 512, 1024)

_DEFAULT_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))),
    "benchmarks",
    "autotune_kernels.json",
)

_lock = threading.Lock()
_cache: dict | None = None
_cache_path: str | None = None


def table_path() -> str:
    return os.environ.get("REPRO_AUTOTUNE_TABLE", _DEFAULT_PATH)


def _key(kernel: str, backend: str, P: int, E: int) -> str:
    return f"{kernel}|{backend}|P={int(P)}|E={int(E)}"


def load_table(path: str | None = None) -> dict:
    """Load (and memoize) the autotune table; {} when absent/unreadable."""
    global _cache, _cache_path
    path = path or table_path()
    with _lock:
        if _cache is not None and _cache_path == path:
            return _cache
        try:
            with open(path) as f:
                table = json.load(f)
        except (OSError, ValueError):
            table = {}
        _cache, _cache_path = table, path
        return table


def save_table(table: dict, path: str | None = None) -> str:
    """Persist the table and refresh the in-process cache."""
    global _cache, _cache_path
    path = path or table_path()
    with _lock:
        with open(path, "w") as f:
            json.dump(table, f, indent=2, sort_keys=True)
            f.write("\n")
        _cache, _cache_path = dict(table), path
    return path


def lookup(kernel: str, backend: str, P: int, E: int) -> int | None:
    """Best tile for (kernel, backend, P, E), or None (=> BLOCK_TILE)."""
    entry = load_table().get(_key(kernel, backend, P, E))
    if entry is None:
        return None
    tile = int(entry["tile"] if isinstance(entry, dict) else entry)
    return tile if tile in TILE_CANDIDATES else None


def record(kernel: str, backend: str, P: int, E: int, tile: int,
           us: float | None = None, path: str | None = None) -> None:
    """Record one sweep winner and persist the updated table."""
    table = dict(load_table(path))
    entry: dict = {"tile": int(tile)}
    if us is not None:
        entry["us"] = round(float(us), 3)
    table[_key(kernel, backend, P, E)] = entry
    save_table(table, path)
