"""Pallas kernel: blockwise causal/sliding-window GQA flash attention (fwd).

Online-softmax flash attention tiled for TPU VMEM:
  grid = (batch*q_heads, q_blocks, k_blocks), k axis sequential ("arbitrary")
  scratch: fp32 accumulator (bq, d), running max m (bq,), running sum l (bq,)
GQA is expressed in the K/V BlockSpec index maps (q head h reads kv head
h // group).  Sliding window masks blocks outside [q-window+1, q].

The backward pass falls back to the jnp reference via custom_vjp
(`_fa_bwd`): the kernel computes the primal on the training hot path too,
and gradients come from `jax.vjp` of `flash_attention_ref` — exact, since
the reference and the kernel implement the same fp32 masked softmax.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: int, q_offset: int, bq: int, bk: int,
    nk: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    s = q @ k.T                                       # (bq, bk)

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    m_ref[...] = m_cur
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v_ref[0].astype(jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, ...] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def _fa_impl(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool,
    window: int,
    q_offset: int,
    bq: int,
    bk: int,
    interpret: bool,
) -> jax.Array:
    """q: (B,S,H,D); k,v: (B,T,K,D) with H % K == 0.  Returns (B,S,H,D)."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    bq_ = min(bq, S)
    bk_ = min(bk, T)
    if S % bq_ or T % bk_:
        raise ValueError(f"S={S} % bq={bq_} or T={T} % bk={bk_} != 0")
    nq, nk = S // bq_, T // bk_
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * K, T, D)
    vh = v.transpose(0, 2, 1, 3).reshape(B * K, T, D)

    def qmap(bh, qi, ki):
        return (bh, qi, 0)

    def kvmap(bh, qi, ki):
        b = bh // H
        h = bh % H
        return (b * K + h // G, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _fa_kernel,
            scale=1.0 / np.sqrt(D),
            causal=causal,
            window=window,
            q_offset=q_offset,
            bq=bq_,
            bk=bk_,
            nk=nk,
        ),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq_, D), qmap),
            pl.BlockSpec((1, bk_, D), kvmap),
            pl.BlockSpec((1, bk_, D), kvmap),
        ],
        out_specs=pl.BlockSpec((1, bq_, D), qmap),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, D), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _fa_vjp(q, k, v, causal, window, q_offset, bq, bk, interpret):
    return _fa_impl(q, k, v, causal, window, q_offset, bq, bk, interpret)


def _fa_fwd(q, k, v, causal, window, q_offset, bq, bk, interpret):
    out = _fa_impl(q, k, v, causal, window, q_offset, bq, bk, interpret)
    return out, (q, k, v)


def _fa_bwd(causal, window, q_offset, bq, bk, interpret, res, g):
    from .ref import flash_attention_ref

    q, k, v = res
    _, pullback = jax.vjp(
        lambda q_, k_, v_: flash_attention_ref(
            q_, k_, v_, causal=causal, window=window, q_offset=q_offset
        ),
        q, k, v,
    )
    return pullback(g)


_fa_vjp.defvjp(_fa_fwd, _fa_bwd)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """Differentiable wrapper: Pallas kernel forward, jnp-reference VJP.

    The backward recomputes attention with `flash_attention_ref` under
    `jax.vjp` from the saved (q, k, v) residuals — exact (same fp32 masked
    softmax), memory-light (no attention matrix saved), and it keeps the
    training hot path on the fused kernel for the primal.
    """
    return _fa_vjp(q, k, v, causal, window, q_offset, bq, bk, interpret)
