"""jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively (interpret=False); everywhere else
(including this CPU container and the dry-run) interpret mode executes the
kernel bodies in Python for correctness validation.  Model code flips between
kernel and jnp paths via cfg.use_pallas.
"""
from __future__ import annotations

import jax

from . import autotune, ref
from .flash_attention import flash_attention as _flash
from .moe_gmm import moe_gmm as _gmm
from .ssd_scan import ssd_scan as _ssd
from .weighted_update import block_prefix_update as _bprefix
from .weighted_update import block_scatter_rows as _bscatter
from .weighted_update import weighted_update as _wupd

__all__ = ["on_tpu", "flash_attention", "ssd_scan", "moe_gmm", "weighted_update",
           "weighted_update_tree", "block_prefix_update", "block_scatter_rows"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp() -> bool:
    return not on_tpu()


def flash_attention(q, k, v, causal=True, window=0, q_offset=0, bq=128, bk=128):
    return _flash(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        bq=bq, bk=bk, interpret=_interp(),
    )


def ssd_scan(x, dt, A, Bm, Cm, chunk=64, init_state=None):
    if init_state is not None:
        # kernel path starts from zero state; fall back to the jnp reference
        return ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk, init_state)
    return _ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=_interp())


def moe_gmm(x, w, bc=128, bf=128, bd=128):
    return _gmm(x, w, bc=bc, bf=bf, bd=bd, interpret=_interp())


def weighted_update(w, g, scale, m=None, momentum=0.0):
    return _wupd(w, g, scale, m=m, momentum=momentum, interpret=_interp())


def block_prefix_update(snaps, w, D, slots, interpret=None):
    """Blocked server update with the autotuned column tile (if recorded).

    The tile comes from the cached sweep table (`repro.kernels.autotune`)
    keyed (backend, P, E); a miss keeps the full BLOCK_TILE — identical to
    calling the kernel directly.  This is the hook the blocked scan engine
    uses (``update="pallas"``, block_size > 1).
    """
    tile = autotune.lookup(
        "block_prefix_update", jax.default_backend(), snaps.shape[1], D.shape[0]
    )
    interp = _interp() if interpret is None else interpret
    return _bprefix(snaps, w, D, slots, interpret=interp, tile=tile)


def block_scatter_rows(snaps, w, W, slots, interpret=None):
    """Lane-partitioned row scatter with the autotuned column tile."""
    tile = autotune.lookup(
        "block_scatter_rows", jax.default_backend(), snaps.shape[1], W.shape[0]
    )
    interp = _interp() if interpret is None else interpret
    return _bscatter(snaps, w, W, slots, interpret=interp, tile=tile)


def weighted_update_tree(params, grads, scale, momenta=None, momentum=0.0):
    """Apply the fused Alg.-1 update across a whole parameter pytree."""
    if momenta is None:
        new = jax.tree_util.tree_map(
            lambda w, g: weighted_update(w, g, scale)[0], params, grads
        )
        return new, None
    pairs = jax.tree_util.tree_map(
        lambda w, g, m: weighted_update(w, g, scale, m=m, momentum=momentum),
        params, grads, momenta,
    )
    new = jax.tree_util.tree_map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    mom = jax.tree_util.tree_map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return new, mom
