"""Pallas kernel: Mamba2 chunked SSD scan.

One (batch, head) pair per grid row; the chunk axis is sequential
("arbitrary") with the inter-chunk SSM state carried in a fp32 VMEM scratch
(N, P).  Per chunk Q the kernel computes the within-chunk (dual/attention)
term and folds the carried state, exactly the algorithm of
repro.models.mamba2.ssd_chunked but with all intermediates resident in VMEM:

    a    = dt * A                      (Q,)        fp32
    L    = exp(segsum(a)) (masked)     (Q, Q)
    Ydiag= (C B^T * L) @ (dt * x)      (Q, P)
    Yoff = exp(cs) * (C @ state)       (Q, P)
    state= exp(cs_Q) state + B^T diag(dt exp(cs_Q - cs)) x

VMEM at (Q=128, N=128, P=64): ~200 KiB — comfortably resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state, *, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)
    A = a_ref[0]                              # scalar
    B = b_ref[0].astype(jnp.float32)          # (Q, N)
    C = c_ref[0].astype(jnp.float32)          # (Q, N)
    Q = x.shape[0]

    a = dt * A                                # (Q,)
    cs = jnp.cumsum(a)                        # (Q,)
    seg = cs[:, None] - cs[None, :]           # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    scores = C @ B.T                          # (Q, Q)
    y_diag = (scores * L) @ (x * dt[:, None])
    y_off = jnp.exp(cs)[:, None] * (C @ state[...])

    decay_to_end = jnp.exp(cs[-1] - cs)       # (Q,)
    new_state = jnp.exp(cs[-1]) * state[...] + B.T @ (x * (dt * decay_to_end)[:, None])
    state[...] = new_state

    y_ref[0, ...] = (y_diag + y_off).astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        st_ref[0, ...] = new_state


def _ssd_impl(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H) fp32 post-softplus
    A: jax.Array,    # (H,) fp32 negative
    Bm: jax.Array,   # (B, S, N)
    Cm: jax.Array,   # (B, S, N)
    chunk: int,
    interpret: bool,
) -> tuple[jax.Array, jax.Array]:
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    if S % Q:
        raise ValueError(f"S={S} % chunk={Q} != 0")
    nc = S // Q
    # layout: one (b, h) stream per grid row
    xh = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dth = dt.transpose(0, 2, 1).reshape(B * H, S).astype(jnp.float32)
    Ah = jnp.tile(A.astype(jnp.float32), B)                     # (B*H,)
    Bh = jnp.repeat(Bm.astype(jnp.float32), H, axis=0).reshape(B, H, S, N).reshape(B * H, S, N) if False else jnp.broadcast_to(Bm[:, None].astype(jnp.float32), (B, H, S, N)).reshape(B * H, S, N)
    Ch = jnp.broadcast_to(Cm[:, None].astype(jnp.float32), (B, H, S, N)).reshape(B * H, S, N)

    y, st = pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda r, c: (r, c, 0)),
            pl.BlockSpec((1, Q), lambda r, c: (r, c)),
            pl.BlockSpec((1,), lambda r, c: (r,)),
            pl.BlockSpec((1, Q, N), lambda r, c: (r, c, 0)),
            pl.BlockSpec((1, Q, N), lambda r, c: (r, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda r, c: (r, c, 0)),
            pl.BlockSpec((1, N, P), lambda r, c: (r, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B * H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xh, dth, Ah, Bh, Ch)
    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    st = st.reshape(B, H, N, P)
    return y, st


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd_vjp(x, dt, A, Bm, Cm, chunk, interpret):
    return _ssd_impl(x, dt, A, Bm, Cm, chunk, interpret)


def _ssd_fwd(x, dt, A, Bm, Cm, chunk, interpret):
    out = _ssd_impl(x, dt, A, Bm, Cm, chunk, interpret)
    return out, (x, dt, A, Bm, Cm)


def _ssd_bwd(chunk, interpret, res, g):
    from .ref import ssd_scan_ref

    x, dt, A, Bm, Cm = res
    _, pullback = jax.vjp(
        lambda x_, dt_, A_, Bm_, Cm_: ssd_scan_ref(x_, dt_, A_, Bm_, Cm_, chunk),
        x, dt, A, Bm, Cm,
    )
    return pullback(g)


_ssd_vjp.defvjp(_ssd_fwd, _ssd_bwd)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H) fp32 post-softplus
    A: jax.Array,    # (H,) fp32 negative
    Bm: jax.Array,   # (B, S, N)
    Cm: jax.Array,   # (B, S, N)
    chunk: int = 64,
    init_state=None,  # unsupported in the kernel path (prefill starts at 0)
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Differentiable wrapper: Pallas kernel forward, jnp-reference VJP.

    The backward recomputes the chunked SSD scan with `ssd_scan_ref` under
    `jax.vjp` from the saved inputs — both (y, state) outputs accept
    cotangents, so the kernel sits directly on the training hot path.
    """
    if init_state is not None:
        raise NotImplementedError("kernel path starts from zero state")
    return _ssd_vjp(x, dt, A, Bm, Cm, chunk, interpret)
