"""Pallas kernel: fused Generalized-AsyncSGD server update (Alg. 1 line 10).

    w' = w - scale * (momentum * m + g),     scale = eta / (n * p_j)

This is the hot loop of the central server — executed once per CS step over
every parameter.  Fusing the importance-weighted scale, momentum update and
parameter write into one VMEM pass makes the server update bandwidth-bound
at exactly one read+write per buffer (vs 3 reads/2 writes unfused).

Tiling: params are processed as flattened (rows, 1024) tiles — (8, 128)
VREG-aligned lanes; the scalar scale rides in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
TILE_ROWS = 64  # (64, 128) fp32 tile = 32 KiB -> 3 operands ~ 96 KiB VMEM


def _kernel(scale_ref, w_ref, g_ref, m_ref, ow_ref, om_ref, *, momentum: float):
    s = scale_ref[0]
    g = g_ref[...].astype(jnp.float32)
    m = momentum * m_ref[...].astype(jnp.float32) + g
    ow_ref[...] = (w_ref[...].astype(jnp.float32) - s * m).astype(ow_ref.dtype)
    om_ref[...] = m.astype(om_ref.dtype)


def _kernel_plain(scale_ref, w_ref, g_ref, ow_ref):
    s = scale_ref[0]
    g = g_ref[...].astype(jnp.float32)
    ow_ref[...] = (w_ref[...].astype(jnp.float32) - s * g).astype(ow_ref.dtype)


def _pad_to_tiles(x: jax.Array) -> tuple[jax.Array, int]:
    n = x.size
    per_tile = TILE_ROWS * LANE
    tiles = max((n + per_tile - 1) // per_tile, 1)
    pad = tiles * per_tile - n
    flat = jnp.pad(x.reshape(-1), (0, pad))
    return flat.reshape(tiles * TILE_ROWS, LANE), n


@functools.partial(jax.jit, static_argnames=("momentum", "interpret"))
def weighted_update(
    w: jax.Array,
    g: jax.Array,
    scale: jax.Array,
    m: jax.Array | None = None,
    momentum: float = 0.0,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array | None]:
    """Apply the fused update to one parameter tensor (any shape)."""
    shape, dtype = w.shape, w.dtype
    w2, n = _pad_to_tiles(w)
    g2, _ = _pad_to_tiles(g.astype(w.dtype))
    rows = w2.shape[0]
    grid = (rows // TILE_ROWS,)
    scale_arr = jnp.reshape(scale.astype(jnp.float32), (1,))
    tile = (TILE_ROWS, LANE)
    bspec = pl.BlockSpec(tile, lambda i: (i, 0))
    sspec = pl.BlockSpec((1,), lambda i: (0,))

    if m is not None:
        m2, _ = _pad_to_tiles(m.astype(jnp.float32))
        ow, om = pl.pallas_call(
            functools.partial(_kernel, momentum=momentum),
            grid=grid,
            in_specs=[sspec, bspec, bspec, bspec],
            out_specs=[bspec, bspec],
            out_shape=[
                jax.ShapeDtypeStruct(w2.shape, dtype),
                jax.ShapeDtypeStruct(m2.shape, m.dtype),
            ],
            interpret=interpret,
        )(scale_arr, w2, g2, m2)
        return (
            ow.reshape(-1)[:n].reshape(shape),
            om.reshape(-1)[:n].reshape(shape).astype(m.dtype),
        )

    ow = pl.pallas_call(
        _kernel_plain,
        grid=grid,
        in_specs=[sspec, bspec, bspec],
        out_specs=bspec,
        out_shape=jax.ShapeDtypeStruct(w2.shape, dtype),
        interpret=interpret,
    )(scale_arr, w2, g2)
    return ow.reshape(-1)[:n].reshape(shape), None


def tree_weighted_update(
    w, g, scale, momentum: float = 0.0, interpret: bool = True
):
    """Leaf-wise fused server update over a parameter pytree.

    This is the `update="pallas"` path of the compiled scan engine
    (`repro.core.engine_scan`): each leaf goes through the single-pass
    kernel; `scale` may be a traced scalar (it rides in SMEM).
    """
    return jax.tree_util.tree_map(
        lambda wl, gl: weighted_update(
            wl, gl, jnp.asarray(scale, jnp.float32), momentum=momentum,
            interpret=interpret,
        )[0],
        w,
        g,
    )
