"""Pallas kernels: fused Generalized-AsyncSGD server updates.

Per-event kernel (`weighted_update`, Alg. 1 line 10):

    w' = w - scale * (momentum * m + g),     scale = eta / (n * p_j)

Fusing the importance-weighted scale, momentum update and parameter write
into one VMEM pass makes the server update bandwidth-bound at exactly one
read+write per buffer (vs 3 reads/2 writes unfused).

Block kernel (`block_prefix_update`, the blocked engine's hot loop): one
conflict-free micro-block of E events applies

    W_i = w - sum_{j<=i} D_j          (D_j = per-event scaled update delta)
    snaps[slot_i] = W_i,   w' = W_{E-1}

in a single pass over column tiles: each tile loads the w tile once,
accumulates the E deltas in registers, and scatters the E intermediate
weight rows straight into the flat-packed (C+1, P) snapshot ring buffer —
no per-event snapshot copies are ever materialized, and the ring buffer is
updated in place (``input_output_aliases``, i.e. buffer donation).  Padded
lanes carry the trash row index C, so their stores are harmless; duplicate
slot stores resolve last-writer-wins (ascending event order), matching the
sequential semantics.  The jnp oracle lives in `repro.kernels.ref.
block_prefix_update_ref` — the CPU/parity fallback the engine uses by
default.

Lane-partitioned variant (`block_scatter_rows`): when the micro-block's E
lanes are sharded across devices, each device computes its lanes' prefix
contributions locally and the global iterates W_i fall out of one
cross-device all-gather — the kernel then only has to stream the
precomputed rows into the aliased ring buffer (same tiling, same trash-row
semantics) and hand back W_{E-1} as the new server weights.

Tiling: params are processed as flattened (rows, 1024) tiles — (8, 128)
VREG-aligned lanes; scalars (scale / slot ids) ride in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
TILE_ROWS = 64  # (64, 128) fp32 tile = 32 KiB -> 3 operands ~ 96 KiB VMEM


def _kernel(scale_ref, w_ref, g_ref, m_ref, ow_ref, om_ref, *, momentum: float):
    s = scale_ref[0]
    g = g_ref[...].astype(jnp.float32)
    m = momentum * m_ref[...].astype(jnp.float32) + g
    ow_ref[...] = (w_ref[...].astype(jnp.float32) - s * m).astype(ow_ref.dtype)
    om_ref[...] = m.astype(om_ref.dtype)


def _kernel_plain(scale_ref, w_ref, g_ref, ow_ref):
    s = scale_ref[0]
    g = g_ref[...].astype(jnp.float32)
    ow_ref[...] = (w_ref[...].astype(jnp.float32) - s * g).astype(ow_ref.dtype)


def _pad_to_tiles(x: jax.Array) -> tuple[jax.Array, int]:
    n = x.size
    per_tile = TILE_ROWS * LANE
    tiles = max((n + per_tile - 1) // per_tile, 1)
    pad = tiles * per_tile - n
    flat = jnp.pad(x.reshape(-1), (0, pad))
    return flat.reshape(tiles * TILE_ROWS, LANE), n


@functools.partial(jax.jit, static_argnames=("momentum", "interpret"))
def weighted_update(
    w: jax.Array,
    g: jax.Array,
    scale: jax.Array,
    m: jax.Array | None = None,
    momentum: float = 0.0,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array | None]:
    """Apply the fused update to one parameter tensor (any shape)."""
    shape, dtype = w.shape, w.dtype
    w2, n = _pad_to_tiles(w)
    g2, _ = _pad_to_tiles(g.astype(w.dtype))
    rows = w2.shape[0]
    grid = (rows // TILE_ROWS,)
    scale_arr = jnp.reshape(scale.astype(jnp.float32), (1,))
    tile = (TILE_ROWS, LANE)
    bspec = pl.BlockSpec(tile, lambda i: (i, 0))
    sspec = pl.BlockSpec((1,), lambda i: (0,))

    if m is not None:
        m2, _ = _pad_to_tiles(m.astype(jnp.float32))
        ow, om = pl.pallas_call(
            functools.partial(_kernel, momentum=momentum),
            grid=grid,
            in_specs=[sspec, bspec, bspec, bspec],
            out_specs=[bspec, bspec],
            out_shape=[
                jax.ShapeDtypeStruct(w2.shape, dtype),
                jax.ShapeDtypeStruct(m2.shape, m.dtype),
            ],
            interpret=interpret,
        )(scale_arr, w2, g2, m2)
        return (
            ow.reshape(-1)[:n].reshape(shape),
            om.reshape(-1)[:n].reshape(shape).astype(m.dtype),
        )

    ow = pl.pallas_call(
        _kernel_plain,
        grid=grid,
        in_specs=[sspec, bspec, bspec],
        out_specs=bspec,
        out_shape=jax.ShapeDtypeStruct(w2.shape, dtype),
        interpret=interpret,
    )(scale_arr, w2, g2)
    return ow.reshape(-1)[:n].reshape(shape), None


BLOCK_TILE = 1024  # column tile of the block kernel (fp32: 4 KiB per row)


def _block_kernel(slots_ref, w_ref, d_ref, _snaps_ref, ow_ref, osnaps_ref, *, E):
    """One column tile of the fused block update (see module docstring).

    ``osnaps_ref`` aliases the input ring buffer: only the E event rows are
    stored; every other snapshot row passes through untouched.
    """
    w = w_ref[...].astype(jnp.float32)   # (1, TILE)
    acc = jnp.zeros_like(w)
    for i in range(E):                   # static unroll over the micro-block
        acc = acc + d_ref[i, :][None, :].astype(jnp.float32)
        osnaps_ref[pl.ds(slots_ref[i], 1), :] = (w - acc).astype(osnaps_ref.dtype)
    ow_ref[...] = (w - acc).astype(ow_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "tile"))
def block_prefix_update(
    snaps: jax.Array,    # (R, P) flat-packed snapshot ring buffer (R = C + 1)
    w: jax.Array,        # (P,) current server weights (compute dtype)
    D: jax.Array,        # (E, P) per-event scaled update deltas, 0 on padding
    slots: jax.Array,    # (E,) int32 ring slot per event (C = trash row)
    interpret: bool = True,
    tile: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Apply one conflict-free event micro-block to (snaps, w).

    Requires ``P % BLOCK_TILE == 0`` — the blocked engine pads the packed
    parameter vector once at init (`engine_scan._snapshot_codec`), so the
    scan-time hot path never re-pads.  ``tile`` overrides the column tile
    width (must divide ``BLOCK_TILE``; the autotuner sweeps it — see
    `repro.kernels.autotune`).  Returns ``(snaps', w')``.
    """
    R, P = snaps.shape
    E = D.shape[0]
    TILE = BLOCK_TILE if tile is None else int(tile)
    if BLOCK_TILE % TILE:
        raise ValueError(f"tile={TILE} must divide BLOCK_TILE={BLOCK_TILE}")
    if P % BLOCK_TILE:
        raise ValueError(f"P={P} must be a multiple of BLOCK_TILE={BLOCK_TILE}")
    grid = (P // TILE,)
    tile = lambda rows: pl.BlockSpec((rows, TILE), lambda i: (0, i))
    ow, osnaps = pl.pallas_call(
        functools.partial(_block_kernel, E=E),
        grid=grid,
        in_specs=[
            pl.BlockSpec((E,), lambda i: (0,)),
            tile(1),
            tile(E),
            tile(R),
        ],
        out_specs=[tile(1), tile(R)],
        out_shape=[
            jax.ShapeDtypeStruct((1, P), w.dtype),
            jax.ShapeDtypeStruct(snaps.shape, snaps.dtype),
        ],
        input_output_aliases={3: 1},  # ring buffer updated in place
        interpret=interpret,
    )(slots.astype(jnp.int32), w[None, :], D, snaps)
    return osnaps, ow[0]


def _scatter_kernel(slots_ref, W_ref, _snaps_ref, ow_ref, osnaps_ref, *, E):
    """One column tile of the lane-partitioned scatter (see module docstring).

    The E intermediate weight rows arrive precomputed (the lane-sharded
    engine builds them from local prefixes + all-gathered device offsets);
    this kernel only streams them into the aliased ring buffer and emits the
    final row as the new server weights.
    """
    for i in range(E):                   # static unroll over the micro-block
        osnaps_ref[pl.ds(slots_ref[i], 1), :] = (
            W_ref[i, :][None, :].astype(osnaps_ref.dtype)
        )
    ow_ref[...] = W_ref[E - 1, :][None, :].astype(ow_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "tile"))
def block_scatter_rows(
    snaps: jax.Array,    # (R, P) flat-packed snapshot ring buffer (R = C + 1)
    w: jax.Array,        # (P,) current server weights (dtype reference only)
    W: jax.Array,        # (E, P) precomputed intermediate weight rows (fp32)
    slots: jax.Array,    # (E,) int32 ring slot per event (C = trash row)
    interpret: bool = True,
    tile: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Scatter one micro-block's precomputed iterates into (snaps, w).

    The lane-partitioned counterpart of `block_prefix_update`: when the E
    lanes are sharded across devices, the prefix accumulation happens per
    device (plus one cross-device all-gather), and only the row scatter
    remains — one pass over column tiles, ring buffer updated in place
    (``input_output_aliases``).  Requires ``P % BLOCK_TILE == 0`` like the
    fused kernel; jnp oracle in `repro.kernels.ref.block_scatter_rows_ref`.
    Returns ``(snaps', w')`` with ``w' = W[-1]`` cast to ``w.dtype``.
    """
    R, P = snaps.shape
    E = W.shape[0]
    TILE = BLOCK_TILE if tile is None else int(tile)
    if BLOCK_TILE % TILE:
        raise ValueError(f"tile={TILE} must divide BLOCK_TILE={BLOCK_TILE}")
    if P % BLOCK_TILE:
        raise ValueError(f"P={P} must be a multiple of BLOCK_TILE={BLOCK_TILE}")
    grid = (P // TILE,)
    tile = lambda rows: pl.BlockSpec((rows, TILE), lambda i: (0, i))
    ow, osnaps = pl.pallas_call(
        functools.partial(_scatter_kernel, E=E),
        grid=grid,
        in_specs=[
            pl.BlockSpec((E,), lambda i: (0,)),
            tile(E),
            tile(R),
        ],
        out_specs=[tile(1), tile(R)],
        out_shape=[
            jax.ShapeDtypeStruct((1, P), w.dtype),
            jax.ShapeDtypeStruct(snaps.shape, snaps.dtype),
        ],
        input_output_aliases={2: 1},  # ring buffer updated in place
        interpret=interpret,
    )(slots.astype(jnp.int32), W, snaps)
    return osnaps, ow[0]


def tree_weighted_update(
    w, g, scale, momentum: float = 0.0, interpret: bool = True
):
    """Leaf-wise fused server update over a parameter pytree.

    This is the `update="pallas"` path of the compiled scan engine
    (`repro.core.engine_scan`): each leaf goes through the single-pass
    kernel; `scale` may be a traced scalar (it rides in SMEM).
    """
    return jax.tree_util.tree_map(
        lambda wl, gl: weighted_update(
            wl, gl, jnp.asarray(scale, jnp.float32), momentum=momentum,
            interpret=interpret,
        )[0],
        w,
        g,
    )
