"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "weighted_update_ref",
    "block_prefix_update_ref",
    "block_scatter_rows_ref",
    "flash_attention_ref",
    "ssd_scan_ref",
    "moe_gmm_ref",
]


def weighted_update_ref(
    w: jax.Array, g: jax.Array, scale: jax.Array, m: jax.Array | None = None,
    momentum: float = 0.0,
) -> tuple[jax.Array, jax.Array | None]:
    """Generalized-AsyncSGD server update (Alg. 1 line 10):
        m' = momentum*m + g          (if momentum buffer provided)
        w' = w - scale * (m' or g)   scale = eta/(n p_j)
    fp32 math, params cast back to their storage dtype.
    """
    gf = g.astype(jnp.float32)
    if m is not None:
        mf = momentum * m.astype(jnp.float32) + gf
        step = mf
    else:
        mf = None
        step = gf
    wf = w.astype(jnp.float32) - scale.astype(jnp.float32) * step
    return wf.astype(w.dtype), (None if mf is None else mf.astype(m.dtype))


def block_prefix_update_ref(
    snaps: jax.Array,    # (R, P) flat-packed snapshot ring buffer
    w: jax.Array,        # (P,) current server weights
    D: jax.Array,        # (E, P) per-event scaled update deltas (0 on padding)
    slots: jax.Array,    # (E,) ring slot per event (trash row on padding)
) -> tuple[jax.Array, jax.Array]:
    """Blocked server update (the engine's jnp fallback / kernel oracle):

        W_i = w - sum_{j<=i} D_j,   snaps[slot_i] = W_i,   w' = W_{E-1}

    fp32 prefix accumulation, rows cast to the ring-buffer storage dtype.
    Within a conflict-free block all real slots are distinct; duplicate
    (padded) slots all target the trash row, so scatter order is moot.
    """
    W = w[None, :].astype(jnp.float32) - jnp.cumsum(D.astype(jnp.float32), axis=0)
    snaps = snaps.at[slots].set(W.astype(snaps.dtype))
    return snaps, W[-1].astype(w.dtype)


def block_scatter_rows_ref(
    snaps: jax.Array,    # (R, P) flat-packed snapshot ring buffer
    w: jax.Array,        # (P,) current server weights (dtype reference only)
    W: jax.Array,        # (E, P) precomputed intermediate weight rows
    slots: jax.Array,    # (E,) ring slot per event (trash row on padding)
) -> tuple[jax.Array, jax.Array]:
    """Scatter-only half of the blocked update (the lane-partitioned path):

        snaps[slot_i] = W_i,   w' = W_{E-1}

    The intermediate iterates W arrive precomputed — in the lane-sharded
    blocked engine each device builds them from its local lane prefix plus
    the all-gathered cross-device offsets — so only the row stores (and the
    final-weights handoff) remain.  Same duplicate-slot semantics as
    `block_prefix_update_ref`: last writer wins, which only padded trash
    rows exercise.
    """
    snaps = snaps.at[slots].set(W.astype(snaps.dtype))
    return snaps, W[-1].astype(w.dtype)


def flash_attention_ref(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, T, K, D)
    v: jax.Array,  # (B, T, K, D)
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) / np.sqrt(D)
    qpos = jnp.arange(S) + q_offset
    kpos = jnp.arange(T)
    rel = qpos[:, None] - kpos[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask = mask & (rel >= 0)
    if window:
        mask = mask & (rel < window)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, D)


def ssd_scan_ref(x, dt, A, Bm, Cm, chunk: int = 64, init_state=None):
    """Oracle = the (already recurrence-validated) chunked jnp implementation."""
    from repro.models.mamba2 import ssd_chunked

    return ssd_chunked(x, dt, A, Bm, Cm, chunk, init_state)


def moe_gmm_ref(xin: jax.Array, w: jax.Array) -> jax.Array:
    """Grouped (per-expert) matmul on dispatch-form tensors.

    xin: (E, C, D), w: (E, D, F) -> (E, C, F), fp32 accumulation.
    """
    return jnp.einsum(
        "ecd,edf->ecf", xin.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(xin.dtype)
