"""Resilient serving driver: continual training under live inference traffic.

Two coupled planes (ROADMAP item 5, `docs/architecture.md` "Serving under
live traffic"):

1. **Training plane** — the fused device-stream engine runs async-LM
   pre-training of the requested architecture (`repro.fl.engine.LMTask`)
   over the closed Jackson network, with an open Poisson inference stream
   merged into the event race (`repro.core.serving.ServingConfig`):
   token-bucket admission, load shedding above the queue-depth cap,
   deadline timeouts with capped exponential-backoff retries, and reads
   served from the last known-good snapshot (guard-rejected updates are
   never observable).
2. **Decode plane** — the trained weights then serve a batched prefill +
   decode loop through the unified ``api.serve_step`` (ring KV cache /
   SSM state / MoE routing, per architecture family).

CLI:

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --batch 4 --steps 32
    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
        --train-steps 200 --arrival-rate 2.0 --serve-rate 4.0

``--train-steps 0`` (default) skips the training plane and reproduces the
plain batched-decode driver; ``run_serve`` returns everything as a dict for
tests and `benchmarks/engine.py --serve`.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import api
from repro.models.module import init_params


def materialize_cache(spec: dict) -> dict:
    def one(s):
        if s.dtype == jnp.int32 and s.ndim == 1:  # ring positions: -1 = empty
            return jnp.full(s.shape, -1, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree_util.tree_map(one, spec)


def _parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--preset", choices=["small", "full"], default="small")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    # training-plane knobs (0 train steps = decode-only, the old driver)
    ap.add_argument("--train-steps", type=int, default=0)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--concurrency", type=int, default=4)
    ap.add_argument("--eta", type=float, default=0.05)
    # serving-plane knobs (arrival-rate 0 = train without live traffic)
    ap.add_argument("--arrival-rate", type=float, default=2.0)
    ap.add_argument("--serve-rate", type=float, default=4.0)
    ap.add_argument("--queue-cap", type=int, default=8)
    ap.add_argument("--bucket-rate", type=float, default=0.0)
    ap.add_argument("--bucket-cap", type=float, default=8.0)
    ap.add_argument("--deadline", type=float, default=2.0)
    ap.add_argument("--max-retries", type=int, default=2)
    return ap


def _train_under_traffic(cfg, args) -> tuple[dict, dict]:
    """Training plane: fused engine + merged open serving stream.

    Returns (final_params, serve_extras) — the serve_* counters of the
    merged run (arrival/served/shed/timed-out conservation, staleness
    histogram, p50/p99 sojourn inputs, known-good step).
    """
    from repro.configs.base import FLConfig
    from repro.core.serving import ServingConfig
    from repro.fl.engine import LMTask, run_experiment

    serving = None
    if args.arrival_rate > 0:
        serving = ServingConfig(
            arrival_rate=args.arrival_rate,
            serve_rate=args.serve_rate,
            queue_cap=args.queue_cap,
            bucket_rate=args.bucket_rate,
            bucket_cap=args.bucket_cap,
            deadline=args.deadline,
            max_retries=args.max_retries,
        )
    flc = FLConfig(
        n_clients=args.clients,
        concurrency=args.concurrency,
        server_steps=args.train_steps,
        sampling="uniform",
        seed=args.seed,
        engine="scan",
        stream="device",
        sparse=False,
    )
    run = run_experiment(
        flc, "gen_async", eta=args.eta, eval_every=0,
        task=LMTask(cfg, batch_size=2, seq_len=16, shard_size=8, eval_batch=2),
        serving=serving,
    )
    extras = {k: v for k, v in run.extras.items() if k.startswith("serve_")}
    return run.final_params, extras


def _decode(cfg, params, args) -> dict:
    """Decode plane: batched prefill + decode through api.serve_step."""
    rng = np.random.default_rng(args.seed)
    B = args.batch
    cache = materialize_cache(api.init_cache(cfg, B, args.prompt_len + args.steps))
    step = jax.jit(lambda p, c, b: api.serve_step(p, c, b, cfg))

    # prefill via repeated decode (exercises the ring cache exactly)
    if cfg.frontend == "audio_stub":
        prompt = rng.normal(size=(B, args.prompt_len, cfg.d_model)).astype(np.float32)
        feed = lambda t: {"embeds": jnp.asarray(prompt[:, t : t + 1])}
    else:
        prompt_ids = rng.integers(0, cfg.vocab_size, (B, args.prompt_len))
        feed = lambda t: {"tokens": jnp.asarray(prompt_ids[:, t : t + 1], jnp.int32)}
    t0 = time.time()
    out = None
    for t in range(args.prompt_len):
        out, cache = step(params, cache, feed(t))
    t_prefill = time.time() - t0

    generated = []
    t0 = time.time()
    nxt = out["next_ids"][:, None]
    logits_finite = bool(jnp.all(jnp.isfinite(out["logits"])))
    for _ in range(args.steps):
        if cfg.frontend == "audio_stub":
            batch = {"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.float32)}
        else:
            batch = {"tokens": nxt}
        out, cache = step(params, cache, batch)
        logits_finite = logits_finite and bool(jnp.all(jnp.isfinite(out["logits"])))
        nxt = out["next_ids"][:, None]
        generated.append(np.asarray(out["next_ids"]))
    t_dec = time.time() - t0
    gen = np.stack(generated, axis=1)
    return {
        "generated": gen,
        "prefill_s": t_prefill,
        "decode_s": t_dec,
        "tok_per_s": B * args.steps / max(t_dec, 1e-9),
        "logits_finite": logits_finite,
    }


def run_serve(argv: list[str] | None = None) -> dict:
    """Full driver as a callable: parse ``argv``, run both planes, return stats.

    The returned dict always has the decode stats; when ``--train-steps > 0``
    it also carries ``train_wall_s`` and the ``serve_*`` counters of the
    merged training run.
    """
    args = _parser().parse_args(argv)
    cfg = smoke_config(args.arch) if args.preset == "small" else get_config(args.arch)
    result: dict = {"arch": cfg.name}

    if args.train_steps > 0:
        t0 = time.time()
        params, serve_extras = _train_under_traffic(cfg, args)
        result["train_wall_s"] = time.time() - t0
        result.update(serve_extras)
    else:
        params = init_params(api.model_meta(cfg), jax.random.PRNGKey(args.seed))

    result.update(_decode(cfg, params, args))
    return result


def main() -> None:
    r = run_serve()
    print(
        f"arch={r['arch']} prefill={r['prefill_s']*1e3:.1f}ms "
        f"decode={r['decode_s']*1e3:.1f}ms ({r['tok_per_s']:.1f} tok/s aggregate) "
        f"logits_finite={r['logits_finite']}"
    )
    if "serve_arrivals" in r:
        print(
            f"serving: arrivals={int(r['serve_arrivals'])} "
            f"served={int(r['serve_served'])} shed={int(r['serve_shed'])} "
            f"timed_out={int(r['serve_timed_out'])} "
            f"retried={int(r['serve_retried'])} "
            f"known_good_step={int(r['serve_kg_step'])}"
        )
    print("sample generation (client 0):", r["generated"][0][:16].tolist())


if __name__ == "__main__":
    main()
