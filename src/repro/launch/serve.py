"""Batched serving driver: prefill + decode loop with the unified serve_step.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --batch 4 --steps 32

Runs the reduced (smoke) variant on CPU; on TPU pass --preset full and a mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import api
from repro.models.module import init_params


def materialize_cache(spec: dict) -> dict:
    def one(s):
        if s.dtype == jnp.int32 and s.ndim == 1:  # ring positions: -1 = empty
            return jnp.full(s.shape, -1, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree_util.tree_map(one, spec)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--preset", choices=["small", "full"], default="small")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.preset == "small" else get_config(args.arch)
    params = init_params(api.model_meta(cfg), jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    B = args.batch
    cache = materialize_cache(api.init_cache(cfg, B, args.prompt_len + args.steps))
    step = jax.jit(lambda p, c, b: api.serve_step(p, c, b, cfg))

    # prefill via repeated decode (exercises the ring cache exactly)
    if cfg.frontend == "audio_stub":
        prompt = rng.normal(size=(B, args.prompt_len, cfg.d_model)).astype(np.float32)
        feed = lambda t: {"embeds": jnp.asarray(prompt[:, t : t + 1])}
    else:
        prompt_ids = rng.integers(0, cfg.vocab_size, (B, args.prompt_len))
        feed = lambda t: {"tokens": jnp.asarray(prompt_ids[:, t : t + 1], jnp.int32)}
    t0 = time.time()
    out = None
    for t in range(args.prompt_len):
        out, cache = step(params, cache, feed(t))
    t_prefill = time.time() - t0

    generated = []
    t0 = time.time()
    nxt = out["next_ids"][:, None]
    for _ in range(args.steps):
        if cfg.frontend == "audio_stub":
            batch = {"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.float32)}
        else:
            batch = {"tokens": nxt}
        out, cache = step(params, cache, batch)
        nxt = out["next_ids"][:, None]
        generated.append(np.asarray(out["next_ids"]))
    t_dec = time.time() - t0
    gen = np.stack(generated, axis=1)
    print(f"arch={cfg.name} batch={B} prefill={args.prompt_len}tok/{t_prefill*1e3:.1f}ms "
          f"decode={args.steps}tok/{t_dec*1e3:.1f}ms "
          f"({B*args.steps/t_dec:.1f} tok/s aggregate)")
    print("sample generation (client 0):", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
