"""Mini HLO cost model: trip-count-aware FLOPs / bytes / collective analysis.

XLA's `compiled.cost_analysis()` counts a `while` body ONCE — under
scan-over-layers that understates FLOPs, HBM traffic and collective bytes by
a factor of num_layers.  This module parses the post-SPMD HLO text and walks
the call graph with trip-count multipliers:

  * dot FLOPs: 2 * prod(result_dims) * contraction_size (from dot dnums),
  * memory traffic: operand+result bytes at fusion/instruction boundaries
    (fusion-internal traffic assumed register/VMEM resident),
  * collective bytes: result-shape bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (async `-start` only,
    `-done` skipped),
  * while trip counts: largest integer constant compared against in the
    loop condition computation (exact for lax.scan/fori_loop lowerings).

Validated in tests against hand-computed counts on known graphs.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["HloCostModel", "analyze_hlo"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-~]+)\s*\(.*->.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-~]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$")


def _shapes_in(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _nbytes(text: str) -> int:
    total = 0
    for dt, dims in _shapes_in(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Instr:
    name: str
    result_types: str
    op: str
    rest: str
    line: str

    @property
    def result_bytes(self) -> int:
        return _nbytes(self.result_types)


@dataclass
class _Computation:
    name: str
    instrs: dict[str, _Instr] = field(default_factory=dict)
    order: list[str] = field(default_factory=list)


def _parse_computations(text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line.strip())
        if hdr and line.strip().endswith("{"):
            cur = _Computation(hdr.group(1))
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = _Instr(m.group(1), m.group(2), m.group(3), m.group(4), line)
            cur.instrs[ins.name] = ins
            cur.order.append(ins.name)
    return comps


def _called_comps(rest: str) -> list[str]:
    """Computation names referenced via calls=/condition=/body=/to_apply= etc."""
    out = []
    for key in ("calls", "condition", "body", "to_apply", "branch_computations",
                "true_computation", "false_computation"):
        for m in re.finditer(key + r"=\{?%?([\w.\-~]+(?:,\s*%?[\w.\-~]+)*)\}?", rest):
            for name in m.group(1).split(","):
                out.append(name.strip().lstrip("%"))
    return out


def _operand_names(rest: str) -> list[str]:
    """Operand instruction names from the call argument list (up to ')')."""
    depth = 1
    args = []
    buf = ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args.append(buf)
                break
        if depth >= 1 and ch != ")":
            buf += ch
    arglist = "".join(args)
    return [m.group(1) for m in re.finditer(r"%([\w.\-~]+)", arglist)]


class HloCostModel:
    def __init__(self, text: str):
        self.comps = _parse_computations(text)
        self._memo: dict[str, dict] = {}

    # ---------------------------------------------------------------- #
    def _dot_flops(self, comp: _Computation, ins: _Instr) -> float:
        result_elems = 0
        for dt, dims in _shapes_in(ins.result_types):
            n = 1
            for d in dims:
                n *= d
            result_elems += n
        # contraction size from lhs operand shape + lhs_contracting_dims
        ops = _operand_names(ins.rest)
        k = 1
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
        if m and ops:
            lhs = comp.instrs.get(ops[0])
            lhs_shape = None
            if lhs is not None:
                shp = _shapes_in(lhs.result_types)
                if shp:
                    lhs_shape = shp[0][1]
            else:
                # operand may carry inline type in the arg list
                m2 = re.search(r"%" + re.escape(ops[0]), ins.rest)
                lhs_shape = None
            if lhs_shape:
                for d in m.group(1).split(","):
                    if d:
                        di = int(d)
                        if di < len(lhs_shape):
                            k *= lhs_shape[di]
        return 2.0 * result_elems * k

    def _trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        best = 1
        for ins in comp.instrs.values():
            for m in re.finditer(r"constant\((\d+)\)", ins.line):
                best = max(best, int(m.group(1)))
        return best

    def analyze(self, comp_name: str) -> dict:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        zero = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                "collectives": {c: 0.0 for c in _COLLECTIVES}, "dots": 0}
        if comp is None:
            return zero
        tot = dict(zero)
        tot["collectives"] = dict(zero["collectives"])
        self._memo[comp_name] = tot  # break cycles
        for name in comp.order:
            ins = comp.instrs[name]
            op = ins.op
            if op in ("parameter", "constant", "get-tuple-element", "tuple"):
                continue
            if op == "dot":
                tot["flops"] += self._dot_flops(comp, ins)
                tot["bytes"] += ins.result_bytes
                tot["dots"] += 1
                continue
            base = op.replace("-start", "")
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                b = ins.result_bytes
                tot["collective_bytes"] += b
                tot["collectives"][base] += b
                tot["bytes"] += b
                continue
            if op == "while":
                body, cond = None, None
                m = re.search(r"body=%?([\w.\-~]+)", ins.line)
                if m:
                    body = m.group(1)
                m = re.search(r"condition=%?([\w.\-~]+)", ins.line)
                if m:
                    cond = m.group(1)
                # XLA annotates known trip counts directly — prefer that
                m = re.search(r"known_trip_count.*?\"n\":\"(\d+)\"", ins.line)
                if m:
                    trips = int(m.group(1))
                else:
                    trips = self._trip_count(cond) if cond else 1
                sub = self.analyze(body) if body else zero
                tot["flops"] += trips * sub["flops"]
                tot["bytes"] += trips * sub["bytes"]
                tot["collective_bytes"] += trips * sub["collective_bytes"]
                for c in _COLLECTIVES:
                    tot["collectives"][c] += trips * sub["collectives"][c]
                continue
            if op in ("fusion", "call", "conditional", "custom-call", "reduce", "sort", "map", "scatter", "select-and-scatter", "reduce-window", "all-reduce-scatter"):
                # boundary traffic: result bytes (operand bytes approximated
                # by producers' result bytes already counted once)
                tot["bytes"] += ins.result_bytes
                for sub_name in _called_comps(ins.line):
                    # only dive for flops/collectives (internal traffic is fused)
                    sub = self.analyze(sub_name)
                    tot["flops"] += sub["flops"]
                    tot["collective_bytes"] += sub["collective_bytes"]
                    for c in _COLLECTIVES:
                        tot["collectives"][c] += sub["collectives"][c]
                    tot["dots"] += sub["dots"]
                continue
            # default elementwise/copy/convert/dynamic-slice/...: result bytes
            tot["bytes"] += ins.result_bytes
        self._memo[comp_name] = tot
        return tot

    def entry(self) -> dict:
        # the ENTRY computation is conventionally named 'main...'
        for name in self.comps:
            if name.startswith("main"):
                return self.analyze(name)
        # fallback: the computation that no one calls
        called: set[str] = set()
        for comp in self.comps.values():
            for ins in comp.instrs.values():
                called.update(_called_comps(ins.line))
        for name in self.comps:
            if name not in called:
                return self.analyze(name)
        raise ValueError("cannot find entry computation")


def analyze_hlo(text: str) -> dict:
    return HloCostModel(text).entry()
