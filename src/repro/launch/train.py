"""Training driver: Generalized AsyncSGD end-to-end.

Two modes:
  * `--mode fl`  — the paper's §5 experiment: n heterogeneous clients,
    non-iid classification, compare {gen_async, async_sgd, fedbuff, fedavg}.
  * `--mode lm`  — asynchronous LM pre-training of an assigned architecture
    (reduced preset by default; CPU-friendly) with the same queueing engine:
    clients are data-parallel groups with heterogeneous speeds; the server
    applies importance-weighted updates (Alg. 1 line 10).

    PYTHONPATH=src python -m repro.launch.train --mode fl --steps 400
    PYTHONPATH=src python -m repro.launch.train --mode lm --arch granite-3-2b
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import save
from repro.configs import get_config, smoke_config
from repro.configs.base import FLConfig
from repro.core import ServerConfig, run_generalized_async_sgd
from repro.data.pipeline import SyntheticLMStream, make_client_speeds
from repro.fl import run_experiment, sampling_for
from repro.models import api
from repro.models.module import init_params


class LMClients:
    """GradientSource: each client draws from its own synthetic LM stream."""

    def __init__(self, cfg, n_clients: int, batch: int, seq: int, seed: int = 0):
        self.cfg = cfg
        self.streams = [
            SyntheticLMStream(cfg.vocab_size, seq, seed=seed * 1000 + i) for i in range(n_clients)
        ]
        self.batch = batch
        self._grad = jax.jit(
            lambda p, b: jax.grad(lambda pp: api.loss_fn(pp, b, cfg)[0])(p)
        )

    def grad(self, client_id: int, params, server_step: int):
        b = self.streams[client_id].batch(self.batch)
        return self._grad(params, {k: jnp.asarray(v) for k, v in b.items()})


def run_lm(args) -> None:
    cfg = smoke_config(args.arch) if args.preset == "small" else get_config(args.arch)
    if args.preset == "100m":
        cfg = cfg.replace(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                          head_dim=64, d_ff=3072, vocab_size=32768, dtype="float32",
                          remat="none")
    n, C = args.clients, args.concurrency
    mu = make_client_speeds(n, 0.5, args.speed_ratio, seed=args.seed)
    flc = FLConfig(n_clients=n, concurrency=C, server_steps=args.steps,
                   sampling=args.sampling, speed_ratio=args.speed_ratio, seed=args.seed)
    p = sampling_for(flc, mu)
    clients = LMClients(cfg, n, args.batch, args.seq, seed=args.seed)
    params = init_params(api.model_meta(cfg), jax.random.PRNGKey(args.seed))
    eval_stream = SyntheticLMStream(cfg.vocab_size, args.seq, seed=9999)
    eval_batch = {k: jnp.asarray(v) for k, v in eval_stream.batch(args.batch).items()}
    loss_j = jax.jit(lambda pp: api.loss_fn(pp, eval_batch, cfg)[0])

    scfg = ServerConfig(n=n, C=C, T=args.steps, eta=args.lr, p=p, mu=mu,
                        seed=args.seed, eval_every=args.eval_every)
    t0 = time.time()
    w, tr = run_generalized_async_sgd(params, clients, scfg, eval_fn=lambda pp: float(loss_j(pp)))
    print(f"# lm training done in {time.time()-t0:.1f}s; grad calls offloaded to {n} clients")
    for s, v in zip(tr.eval_steps, tr.eval_values):
        print(f"step {s:6d} eval_loss {v:.4f}")
    delays = np.array([np.mean(d) if d else np.nan for d in tr.delays])
    print(f"mean delay fast={np.nanmean(delays[mu>mu.min()]):.1f} slow={np.nanmean(delays[mu==mu.min()]):.1f} steps")
    if args.ckpt_dir:
        save(args.ckpt_dir, args.steps, w, metadata={"arch": args.arch, "mode": "lm"})
        print(f"checkpoint saved to {args.ckpt_dir}")


def run_fl(args) -> None:
    flc = FLConfig(n_clients=args.clients, concurrency=args.concurrency,
                   server_steps=args.steps, sampling=args.sampling,
                   speed_ratio=args.speed_ratio, seed=args.seed)
    for method in args.methods.split(","):
        t0 = time.time()
        r = run_experiment(flc, method, eta=args.lr, eval_every=args.eval_every)
        accs = ", ".join(f"{s}:{a:.3f}" for s, a in zip(r.eval_steps, r.eval_acc))
        print(f"{method:10s} final_acc={r.eval_acc[-1]:.3f}  [{accs}]  ({time.time()-t0:.1f}s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["fl", "lm"], default="fl")
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--preset", choices=["small", "100m", "full"], default="small")
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--concurrency", type=int, default=8)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--speed-ratio", type=float, default=10.0)
    ap.add_argument("--sampling", default="optimal",
                    choices=["uniform", "optimal", "physical_time"])
    ap.add_argument("--methods", default="gen_async,async_sgd,fedbuff")
    ap.add_argument("--eval-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()
    if args.mode == "lm":
        run_lm(args)
    else:
        run_fl(args)


if __name__ == "__main__":
    main()
