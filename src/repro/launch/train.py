"""Training driver: Generalized AsyncSGD end-to-end.

Two modes:
  * `--mode fl`  — the paper's §5 experiment: n heterogeneous clients,
    non-iid classification, compare {gen_async, async_sgd, fedbuff, fedavg}.
  * `--mode lm`  — asynchronous LM pre-training of an assigned architecture
    (reduced preset by default; CPU-friendly) with the same queueing engine:
    clients are data-parallel groups with heterogeneous speeds; the server
    applies importance-weighted updates (Alg. 1 line 10).

    `--engine` picks the LM server loop: "python" is the per-event
    reference loop (the parity oracle), "scan" the compiled device-resident
    engine (host-replayed events; supports `--block-size` micro-blocking),
    "fused" the device-stream engine (events generated inside the one XLA
    program).  All three consume the same `LMTask` shards, so
    scan == python to float tolerance on any config.

    PYTHONPATH=src python -m repro.launch.train --mode fl --steps 400
    PYTHONPATH=src python -m repro.launch.train --mode lm --engine scan --block-size 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import save
from repro.configs import get_config, smoke_config
from repro.configs.base import FLConfig
from repro.data.pipeline import SyntheticLMStream
from repro.fl import LMTask, run_experiment
from repro.models import api


class LMClients:
    """GradientSource: each client draws from its own synthetic LM stream.

    The legacy streaming source of the original per-event Python loop: each
    `grad` call consumes fresh host RNG state, so runs are NOT replayable
    against the compiled engine.  `LMTask` (fixed per-client shards,
    identical minibatches on every path) supersedes it for anything that
    needs parity; this stays for host-streaming experiments whose datasets
    don't fit device memory.
    """

    def __init__(self, cfg, n_clients: int, batch: int, seq: int, seed: int = 0):
        self.cfg = cfg
        self.streams = [
            SyntheticLMStream(cfg.vocab_size, seq, seed=seed * 1000 + i) for i in range(n_clients)
        ]
        self.batch = batch
        self._grad = jax.jit(
            lambda p, b: jax.grad(lambda pp: api.loss_fn(pp, b, cfg)[0])(p)
        )
        self.grad_calls = 0

    def grad(self, client_id: int, params, server_step: int):
        b = self.streams[client_id].batch(self.batch)
        self.grad_calls += 1
        return self._grad(params, {k: jnp.asarray(v) for k, v in b.items()})


def lm_config(args):
    cfg = smoke_config(args.arch) if args.preset == "small" else get_config(args.arch)
    if args.preset == "100m":
        cfg = cfg.replace(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                          head_dim=64, d_ff=3072, vocab_size=32768, dtype="float32",
                          remat="none")
    return cfg


def run_lm(args) -> None:
    cfg = lm_config(args)
    n, C = args.clients, args.concurrency
    engine = "python" if args.engine == "python" else "scan"
    stream = "device" if args.engine == "fused" else "host"
    task = LMTask(cfg=cfg, batch_size=args.batch, seq_len=args.seq,
                  shard_size=args.shard_size)
    flc = FLConfig(n_clients=n, concurrency=C, server_steps=args.steps,
                   sampling=args.sampling, speed_ratio=args.speed_ratio,
                   seed=args.seed, engine=engine, stream=stream,
                   block_size=args.block_size)

    t0 = time.time()
    r = run_experiment(flc, "gen_async", eta=args.lr,
                       eval_every=args.eval_every, engine=engine, task=task)
    print(f"# lm training done in {time.time()-t0:.1f}s "
          f"(engine={args.engine}, block_size={args.block_size}); "
          f"grad calls offloaded to {n} clients")
    for s, v in zip(r.eval_steps, r.eval_acc):
        print(f"step {s:6d} eval_loss {v:.4f}")
    if r.mean_delays is not None:
        print(f"mean delay overall {np.nanmean(r.mean_delays):.1f} steps")
    if args.ckpt_dir:
        save(args.ckpt_dir, args.steps, r.final_params,
             metadata={"arch": args.arch, "mode": "lm"})
        print(f"checkpoint saved to {args.ckpt_dir}")


def run_fl(args) -> None:
    flc = FLConfig(n_clients=args.clients, concurrency=args.concurrency,
                   server_steps=args.steps, sampling=args.sampling,
                   speed_ratio=args.speed_ratio, seed=args.seed)
    for method in args.methods.split(","):
        t0 = time.time()
        r = run_experiment(flc, method, eta=args.lr, eval_every=args.eval_every)
        accs = ", ".join(f"{s}:{a:.3f}" for s, a in zip(r.eval_steps, r.eval_acc))
        print(f"{method:10s} final_acc={r.eval_acc[-1]:.3f}  [{accs}]  ({time.time()-t0:.1f}s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["fl", "lm"], default="fl")
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--preset", choices=["small", "100m", "full"], default="small")
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--concurrency", type=int, default=8)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--shard-size", type=int, default=256,
                    help="per-client LM dataset rows (device-resident)")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--speed-ratio", type=float, default=10.0)
    ap.add_argument("--sampling", default="optimal",
                    choices=["uniform", "optimal", "physical_time"])
    ap.add_argument("--methods", default="gen_async,async_sgd,fedbuff")
    ap.add_argument("--engine", choices=["python", "scan", "fused"],
                    default="scan", help="LM server loop (fused = device stream)")
    ap.add_argument("--block-size", type=int, default=1,
                    help="micro-block size E for the blocked scan engine")
    ap.add_argument("--eval-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()
    if args.mode == "lm":
        run_lm(args)
    else:
        run_fl(args)


if __name__ == "__main__":
    main()
