"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state.  Single pod: (data=16, model=16) = 256 chips.  Multi-pod:
(pod=2, data=16, model=16) = 512 chips.  Uses the first N devices so a
512-fake-device dry-run process can build both meshes.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, found {len(devices)} — the dry-run entrypoint "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax"
        )
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes)


def make_debug_mesh(data: int = 2, model: int = 2) -> Mesh:
    """Small mesh for tests (subprocesses set a small device count)."""
    n = data * model
    dev = np.asarray(jax.devices()[:n]).reshape(data, model)
    return Mesh(dev, ("data", "model"))
