"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
mesh, extract roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --multi-pod

Writes one JSON artifact per run under artifacts/dryrun/.
"""
from __future__ import annotations

import os

# MUST run before any jax import: device count locks on first init.
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (
    SHAPES,
    ARCH_IDS,
    batch_logical_axes,
    for_shape,
    get_config,
    input_specs,
)
from repro.configs.base import ModelConfig, OptimConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.launch import shardings as SH
from repro.models import api
from repro.models.module import abstract_params, logical_specs, param_count
from repro.optim import make_optimizer

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-shape bytes of every collective op in the (SPMD) HLO."""
    out = {c: 0.0 for c in _COLLECTIVES}
    out["count"] = 0
    shape_re = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(.+?)\s+(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(", stripped)
        if not m:
            continue
        if m.group(3) == "-done":    # avoid double counting async pairs
            continue
        result_types, coll = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in shape_re.findall(result_types):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[coll] += float(nbytes)
        out["count"] += 1
    out["total"] = float(sum(out[c] for c in _COLLECTIVES))
    return out


def optimizer_for(cfg: ModelConfig) -> OptimConfig:
    # >100B-param models: bf16-momentum SGD keeps optimizer state in budget
    if cfg.name == "arctic_480b":
        return OptimConfig(name="momentum", state_dtype="bfloat16")
    return OptimConfig(name="adamw", state_dtype="float32")


def _opt_state_shardings(opt_cfg: OptimConfig, pshard, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P

    rep = NamedSharding(mesh, P())
    if opt_cfg.name == "sgd":
        return {"count": rep}
    if opt_cfg.name == "momentum":
        return {"count": rep, "m": pshard}
    return {"count": rep, "m": pshard, "v": pshard}


def model_flops_estimate(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = processed tokens."""
    from repro.models.module import param_count as pc

    meta = api.model_meta(cfg)
    n_params = pc(meta)
    if cfg.family == "moe":
        # subtract inactive expert params
        e_all = 3 * cfg.d_model * cfg.d_ff_expert * cfg.num_experts
        e_act = 3 * cfg.d_model * cfg.d_ff_expert * cfg.num_experts_per_tok
        n_params = n_params - cfg.num_layers * (e_all - e_act)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_params * tokens


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh, rules):
    """Returns (jitted_fn, example_args_abstract) for the pair's step kind."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    pmeta = api.model_meta(cfg)
    aparams = abstract_params(pmeta)
    frules = SH.filter_rules(rules, mesh)
    pshard = SH.param_shardings(pmeta, mesh, frules)
    batch_abs = input_specs(cfg, shape)
    baxes = batch_logical_axes(cfg, shape)
    avail = set(mesh.axis_names)
    b_ok = shape.global_batch % int(np.prod([mesh.shape[a] for a in ("pod", "data") if a in avail])) == 0
    if not b_ok:
        frules["batch"] = None
    bshard = {
        k: NamedSharding(
            mesh,
            SH.logical_to_pspec(baxes[k], {**frules, "seq": None}, batch_abs[k].shape, mesh),
        )
        for k in batch_abs
    }
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt_cfg = optimizer_for(cfg)
        opt = make_optimizer(opt_cfg)
        aopt = jax.eval_shape(opt.init, aparams)
        oshard = _opt_state_shardings(opt_cfg, pshard, mesh)

        def train_step(params, opt_state, batch, sampling_weight):
            with SH.activate_rules(frules, mesh):
                return api.train_step(params, opt_state, batch, cfg, opt, sampling_weight)

        fn = jax.jit(
            train_step,
            in_shardings=(pshard, oshard, bshard, rep),
            out_shardings=(pshard, oshard, {"loss": rep, "moe_aux": rep, "grad_norm": rep}),
            donate_argnums=(0, 1),
        )
        args = (aparams, aopt, batch_abs, jax.ShapeDtypeStruct((), jnp.float32))
        return fn, args

    if shape.kind == "prefill":

        def prefill_step(params, batch):
            with SH.activate_rules(frules, mesh):
                logits, _ = api.forward(params, batch, cfg)
                return logits

        fn = jax.jit(prefill_step, in_shardings=(pshard, bshard))
        return fn, (aparams, batch_abs)

    # decode
    cache_abs = api.init_cache(cfg, shape.global_batch, shape.seq_len)
    caxes = api.cache_logical_axes(cfg)
    cshard = {
        k: NamedSharding(mesh, SH.logical_to_pspec(caxes[k], frules, cache_abs[k].shape, mesh))
        for k in cache_abs
    }

    def serve_step(params, cache, batch):
        with SH.activate_rules(frules, mesh):
            return api.serve_step(params, cache, batch, cfg)

    fn = jax.jit(
        serve_step,
        in_shardings=(pshard, cshard, bshard),
        out_shardings=({"logits": rep, "next_ids": rep}, cshard),
        donate_argnums=(1,),
    )
    return fn, (aparams, cache_abs, batch_abs)


def run_pair(arch: str, shape_name: str, multi_pod: bool, rules_name: str = "default",
             out_dir: str = "artifacts/dryrun", overrides: dict | None = None,
             tag_suffix: str = "") -> dict:
    t0 = time.time()
    shape = SHAPES[shape_name]
    cfg = for_shape(get_config(arch), shape)
    if overrides:
        cfg = cfg.replace(**overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    rules = dict(SH.DEFAULT_RULES if rules_name == "default" else SH.RULE_SETS[rules_name])

    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "rules": rules_name,
        "kind": shape.kind,
        "sliding_window": cfg.sliding_window,
        "params": param_count(api.model_meta(cfg)),
    }
    try:
        fn, args = build_step(cfg, shape, mesh, rules)
        lowered = fn.lower(*args)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        xla_flops = float(cost.get("flops", -1.0))
        xla_bytes = float(cost.get("bytes accessed", -1.0))
        # trip-count-aware analysis (XLA cost_analysis counts while bodies once)
        from repro.launch.hlo_analysis import analyze_hlo

        hlo = analyze_hlo(compiled.as_text())
        flops = hlo["flops"]
        bytes_acc = hlo["bytes"]
        try:
            mem = compiled.memory_analysis()
            mem_stats = {
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", -1)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", -1)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", -1)),
                "peak_bytes": int(
                    getattr(mem, "peak_memory_in_bytes",
                            getattr(mem, "temp_size_in_bytes", -1))
                ),
            }
        except Exception as e:  # noqa: BLE001
            mem_stats = {"error": str(e)}
        coll = {**hlo["collectives"], "total": hlo["collective_bytes"]}
        mf = model_flops_estimate(cfg, shape)
        # roofline terms (seconds); cost_analysis is the per-device program
        compute_t = flops / PEAK_FLOPS
        memory_t = bytes_acc / HBM_BW
        collective_t = coll["total"] / ICI_BW
        terms = {"compute_s": compute_t, "memory_s": memory_t, "collective_s": collective_t}
        dominant = max(terms, key=terms.get)
        rec.update(
            ok=True,
            lower_s=round(t_lower - t0, 2),
            compile_s=round(t_compile - t_lower, 2),
            flops_per_device=flops,
            bytes_per_device=bytes_acc,
            xla_cost_flops=xla_flops,
            xla_cost_bytes=xla_bytes,
            collective_bytes_per_device=coll["total"],
            collectives=coll,
            memory=mem_stats,
            roofline=terms,
            dominant=dominant.replace("_s", ""),
            model_flops_total=mf,
            hlo_flops_total=flops * n_chips,
            useful_flops_ratio=(mf / (flops * n_chips)) if flops > 0 else None,
        )
    except Exception as e:  # noqa: BLE001
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    rec["wall_s"] = round(time.time() - t0, 2)
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{'multipod' if multi_pod else 'singlepod'}__{rules_name}"
    if tag_suffix:
        tag += "__" + tag_suffix
        rec["variant"] = tag_suffix
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK " if rec.get("ok") else "FAIL"
    print(f"[{status}] {tag} wall={rec['wall_s']}s "
          + (f"dom={rec.get('dominant')}" if rec.get("ok") else rec.get("error", "")[:200]),
          flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--rules", default="default")
    ap.add_argument("--out-dir", default="artifacts/dryrun")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (hillclimb variants)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        key, val = kv.split("=", 1)
        for cast in (int, float):
            try:
                val = cast(val)
                break
            except ValueError:
                continue
        overrides[key] = val
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_fail = 0
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                rec = run_pair(arch, shp, mp, args.rules, args.out_dir,
                               overrides=overrides or None, tag_suffix=args.tag)
                n_fail += 0 if rec.get("ok") else 1
    if n_fail:
        raise SystemExit(f"{n_fail} dry-run pair(s) failed")


if __name__ == "__main__":
    main()
